//! Serving front end: one coordinator, three tenants, one budget.
//!
//!     cargo run --release --example serving_front_end
//!
//! Admits three tenant sessions into a `ServeCoordinator` under a shared
//! worker-thread and snapshot-memory budget, then interleaves the two
//! sides of a serving deployment: streaming ingest mutating each live
//! session while point-query batches and top-K scans are answered from
//! the published snapshots. Queries always see one consistent
//! sweep-boundary generation — ingest only surfaces after the next
//! decompose republishes — and the per-tenant `ServeRecord` at the end
//! shows exactly that lag, alongside throughput telemetry.

use tucker_lite::coordinator::{SchemeChoice, TuckerSession, Workload};
use tucker_lite::hooi::CoreRanks;
use tucker_lite::serve::{QueryBatch, ServeBudget, ServeCoordinator};
use tucker_lite::tensor::synth::{generate, ModeDist};
use tucker_lite::tensor::TensorDelta;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_si, Table};

fn tenant_session(name: &str, zipf: f64, nnz: usize, seed: u64) -> TuckerSession {
    let modes = vec![
        ModeDist { len: 300, zipf },
        ModeDist { len: 200, zipf: 0.0 },
        ModeDist { len: 80, zipf: 0.4 },
    ];
    let tensor = generate(&modes, nnz, seed);
    TuckerSession::builder(Workload::from_tensor(name, tensor))
        .scheme(SchemeChoice::Lite)
        .ranks(4)
        .core(CoreRanks::Uniform(6))
        .seed(seed)
        .build()
        .expect("valid tenant session")
}

fn main() {
    // 1. one global budget across every tenant: 8 worker threads, 32 MiB
    //    of resident snapshots, engine batches capped at 256 queries
    let budget =
        ServeBudget { worker_threads: 8, snapshot_bytes: 32 * 1024 * 1024, max_batch: 256 };
    let mut coord = ServeCoordinator::new(budget);
    println!(
        "budget: {} threads, {} snapshot bytes, max batch {}",
        budget.worker_threads, budget.snapshot_bytes, budget.max_batch
    );

    // 2. admit three tenants with different reservations; a fourth that
    //    would oversubscribe the thread budget is turned away with a
    //    typed error and its session handed back untouched
    let tenants = ["ads", "search", "recs"];
    for (i, name) in tenants.iter().enumerate() {
        coord
            .admit(name, tenant_session(name, 0.3 * i as f64, 20_000 + 5_000 * i, 7 + i as u64), 2, 8 * 1024 * 1024)
            .unwrap_or_else(|(_, e)| panic!("{name}: {e}"));
    }
    let (rejected, err) = coord
        .admit("latecomer", tenant_session("latecomer", 0.0, 5_000, 42), 4, 1024)
        .unwrap_err();
    println!("admission: {:?} admitted; latecomer rejected: {err}", coord.tenants());
    drop(rejected); // the caller keeps the session and can retry smaller

    // 3. first sweep for everyone: decompose publishes the generation-1
    //    serving snapshot per tenant
    for name in &tenants {
        let snap = coord.decompose(name).expect("first decompose");
        println!("{name}: published generation {} (fit {:.3})", snap.generation(), snap.fit());
    }

    // 4. interleaved serving and ingest: each round streams a delta into
    //    every session (snapshots keep serving the old generation), runs
    //    a query batch plus a top-K scan, then republishes
    let mut rng = Rng::new(0xFE);
    for round in 0..3 {
        for name in &tenants {
            let dims = coord.session(name).unwrap().workload().tensor.dims.clone();
            let mut delta = TensorDelta::new();
            for _ in 0..1_500 {
                let coord_idx: Vec<u32> =
                    dims.iter().map(|&l| rng.below(l as u64) as u32).collect();
                delta = delta.append(&coord_idx, rng.f32());
            }
            coord.ingest(name, &delta).expect("in-bounds delta");

            let mut batch = QueryBatch::new();
            for _ in 0..600 {
                let idx: Vec<usize> =
                    dims.iter().map(|&l| rng.usize_below(l as usize)).collect();
                batch.add(&idx);
            }
            let vals = coord.query(name, &batch).expect("served from the resident snapshot");
            assert_eq!(vals.len(), batch.len());
            let top = coord.top_k(name, 0, rng.usize_below(dims[0] as usize), 5).expect("top-k");
            assert_eq!(top.len(), 5);
            // mid-round the snapshot lags the mutated session by design
            assert!(coord.record(name).unwrap().generation_lag() >= 1);
        }
        // republish: the next decompose folds the ingested deltas in
        for name in &tenants {
            coord.decompose(name).expect("republish");
        }
        println!("round {round}: ingested, served, republished for all tenants");
    }

    // 5. the per-tenant serving record: throughput, batch shape, latency
    //    quantiles, and how far serving lagged the live session
    let mut t = Table::new(
        "per-tenant serving records",
        &["tenant", "queries", "batches", "mean batch", "top-K", "p50 µs", "p99 µs", "gen lag", "resident gens"],
    );
    for name in &tenants {
        let gens = coord.resident_generations(name);
        let rec = coord.record(name).unwrap();
        t.row(vec![
            name.to_string(),
            fmt_si(rec.queries_served as f64),
            rec.batches.to_string(),
            format!("{:.0}", rec.mean_batch()),
            rec.topk_queries.to_string(),
            format!("{:.1}", rec.p50_latency() * 1e6),
            format!("{:.1}", rec.p99_latency() * 1e6),
            rec.generation_lag().to_string(),
            format!("{gens:?}"),
        ]);
    }
    t.print();
    println!(
        "coordinator: {} / {} threads reserved, {} resident snapshot bytes",
        coord.threads_reserved(),
        budget.worker_threads,
        coord.resident_bytes()
    );
    println!("serving_front_end OK");
}
