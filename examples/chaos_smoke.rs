//! Chaos smoke: real faults against the channel transport's robustness
//! envelope.
//!
//!     cargo run --release --example chaos_smoke
//!
//! Every trial runs a full HOOI session over `TransportChoice::Channel`
//! — real framed bytes, checksums, heartbeats, phase deadlines — while
//! the chaos hooks break things for real: corrupted frames past the
//! retransmit budget, a silently wedged rank, a straggler sleeping past
//! the deadline. No `FaultPlan` is armed anywhere; every failure here
//! is *detected*, classified, and recovered by the PR 6 loop. The smoke
//! criterion is convergence (a finite fit on every trial), not
//! bit-equality — deadlines are randomized per trial.

use tucker_lite::coordinator::{RetryPolicy, TuckerSession, Workload};
use tucker_lite::dist::{TransportChoice, TransportTuning};
use tucker_lite::hooi::CoreRanks;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::Table;

struct Trial {
    name: &'static str,
    tuning: TransportTuning,
    wedge: Option<usize>,
}

fn main() {
    let mut rng = Rng::new(2024);
    let tensor = SparseTensor::random(vec![14, 10, 8], 250, &mut rng);
    let w = Workload::from_tensor("chaos", tensor);

    // randomized-but-generous deadlines: far above the microseconds a
    // healthy in-process exchange takes, small enough to keep the hang
    // and straggler trials snappy
    let mut deadline = || 0.03 + f64::from(rng.f32()) * 0.09;

    let d1 = deadline();
    let d2 = deadline();
    let trials = vec![
        Trial {
            name: "healthy",
            tuning: TransportTuning::default(),
            wedge: None,
        },
        Trial {
            name: "corrupt-absorbed",
            // three damaged frames, each retransmitted inside the budget:
            // the session must not even notice
            tuning: TransportTuning { corrupt_frames: 3, ..TransportTuning::default() },
            wedge: None,
        },
        Trial {
            name: "corrupt-transient",
            // zero retransmit budget: the first damaged frame escalates to
            // a transient failure → rollback → clean replay
            tuning: TransportTuning {
                corrupt_frames: 1,
                max_retries: 0,
                ..TransportTuning::default()
            },
            wedge: None,
        },
        Trial {
            name: "wedged-rank",
            // rank 2 hangs silently; the deadline monitor must classify
            // the crash and recovery must re-place onto the survivors
            tuning: TransportTuning { phase_deadline: d1, ..TransportTuning::default() },
            wedge: Some(2),
        },
        Trial {
            name: "straggler",
            // rank 3 heartbeats but sleeps past the deadline once: a
            // straggler timeout, recovered without any eviction
            tuning: TransportTuning {
                phase_deadline: d2,
                delay_rank: Some(3),
                delay_secs: d2 * 2.5,
                ..TransportTuning::default()
            },
            wedge: None,
        },
    ];

    let mut t = Table::new(
        "chaos trials (channel transport, no injected faults)",
        &["trial", "deadline", "recoveries", "dead ranks", "fit"],
    );
    for trial in trials {
        let mut s = TuckerSession::builder(w.clone())
            .ranks(4)
            .core(CoreRanks::Uniform(2))
            .invocations(2)
            .seed(11)
            .transport(TransportChoice::Channel)
            .transport_tuning(trial.tuning)
            .retry_policy(RetryPolicy { max_attempts: 5, straggler_timeout: None })
            .build()
            .expect("valid session configuration");
        if let Some(r) = trial.wedge {
            s.wedge_rank(r);
        }
        let d = s
            .try_decompose()
            .unwrap_or_else(|e| panic!("trial {}: unrecovered: {e}", trial.name));
        assert!(d.fit().is_finite(), "trial {}: fit diverged", trial.name);
        assert_eq!(d.record.transport, "channel");
        assert_eq!(s.faults_injected(), 0, "chaos is real, never injected");
        match trial.name {
            "healthy" | "corrupt-absorbed" => {
                assert_eq!(s.recoveries(), 0, "trial {} must not recover", trial.name);
            }
            "wedged-rank" => {
                assert_eq!(s.dead_ranks(), vec![2], "the hung rank is evicted");
                assert!(s.recoveries() >= 1);
            }
            _ => assert!(s.recoveries() >= 1, "trial {} must recover", trial.name),
        }
        t.row(vec![
            trial.name.to_string(),
            format!("{:.0} ms", trial.tuning.phase_deadline * 1e3),
            s.recoveries().to_string(),
            format!("{:?}", s.dead_ranks()),
            format!("{:.4}", d.fit()),
        ]);
    }
    t.print();
    println!("chaos_smoke OK");
}
