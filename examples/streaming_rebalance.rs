//! Streaming rebalance: a long-lived session absorbing data drift.
//!
//!     cargo run --release --example streaming_rebalance
//!
//! Builds a session with `RebalancePolicy::Auto`, streams skewed delta
//! batches into it, and shows the full rebalance lifecycle: ingest
//! revalidates the Theorem 6.1 sharing bounds, the §4 cost model
//! compares the live `PlacementPlan` against a Lite re-plan, and a
//! migration (applied through `PlacementPlan::diff`) touches only the
//! diffed (mode, rank) TTM plans — never a full re-prepare.

use tucker_lite::coordinator::{
    RebalancePolicy, SchemeChoice, TuckerSession, Workload,
};
use tucker_lite::tensor::synth::{generate, ModeDist};
use tucker_lite::tensor::TensorDelta;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_secs, Table};

fn main() {
    // 1. a modest workload so three ingest rounds stay snappy
    let modes = vec![
        ModeDist { len: 600, zipf: 1.0 },
        ModeDist { len: 400, zipf: 0.0 },
        ModeDist { len: 200, zipf: 0.6 },
    ];
    let tensor = generate(&modes, 40_000, 17);
    println!("tensor: dims={:?} nnz={}", tensor.dims, tensor.nnz());

    // 2. an auto-rebalancing session: when streaming drift breaks the
    //    sharing bounds, migrate iff the predicted per-sweep savings
    //    amortize the re-plan + migration within 4 further sweeps
    let mut session = TuckerSession::builder(Workload::from_tensor("drift", tensor))
        .scheme(SchemeChoice::Lite)
        .ranks(8)
        .core(8usize)
        .rebalance_policy(RebalancePolicy::Auto { hooi_iters_amortization: 4 })
        .seed(3)
        .build()
        .expect("valid session configuration");
    let d0 = session.decompose();
    println!("initial fit {:.4}", d0.fit());

    // 3. stream drift: each round piles appends onto a few hot slices —
    //    exactly the skew that erodes Lite's Theorem 6.1 guarantees
    let mut rng = Rng::new(99);
    let mut t = Table::new(
        "streaming rounds",
        &["round", "appends", "plans touched", "flagged modes", "auto decision"],
    );
    for round in 0..3 {
        let dims = session.workload().tensor.dims.clone();
        let mut delta = TensorDelta::new();
        let appends = 4_000 * (round + 1);
        for i in 0..appends {
            let hot = (i % 4) as u32;
            let coord: Vec<u32> = dims
                .iter()
                .enumerate()
                .map(|(m, &l)| if m == 0 { hot } else { rng.below(l as u64) as u32 })
                .collect();
            delta = delta.append(&coord, rng.f32());
        }
        let rep = session.ingest(&delta).expect("valid drift delta");
        let decision = match &rep.rebalance {
            None => "bounds hold".to_string(),
            Some(rb) if rb.migrated => format!(
                "migrated {} elems ({} B), saves {}/sweep",
                rb.moved_elements,
                rb.migration_bytes,
                fmt_secs(rb.decision.savings_per_sweep)
            ),
            Some(rb) => format!(
                "skipped: {}/sweep saved < {} migration",
                fmt_secs(rb.decision.savings_per_sweep),
                fmt_secs(rb.decision.replan_secs + rb.decision.migration_secs)
            ),
        };
        t.row(vec![
            round.to_string(),
            rep.appended.to_string(),
            format!("{}/{}", rep.plans_touched(), rep.plan_count),
            format!("{:?}", rep.rebalance_modes),
            decision,
        ]);
    }
    t.print();

    // 4. refine on the (possibly migrated) plans and read the record:
    //    the decision trail and redistribution time travel with it
    let d = session.decompose_more(2);
    let rec = &d.record;
    println!(
        "refined fit {:.4} | rebalances {} (skipped {}) | redist {} | dist {}",
        d.fit(),
        rec.rebalances,
        rec.rebalance_skips,
        fmt_secs(rec.redist_secs),
        fmt_secs(rec.dist_secs),
    );
    println!(
        "pending rebalance: {:?} | plan builds {} | plan rebuilds {}",
        session.pending_rebalance(),
        session.plan_builds(),
        session.plan_rebuilds(),
    );
    assert_eq!(session.plan_builds(), 1, "prepare_modes ran exactly once");
    assert!(d.fit().is_finite());
    println!("streaming_rebalance OK");
}
