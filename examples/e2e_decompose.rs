//! END-TO-END DRIVER (the EXPERIMENTS.md validation run): exercises every
//! layer of the stack on a realistic workload and reports the paper's
//! headline comparison.
//!
//!     cargo run --release --example e2e_decompose [-- --scale 0.2 --p 64]
//!
//! Pipeline proved here:
//!   L1/L2  AOT Pallas/JAX artifacts (HLO text)  →  compiled on PJRT CPU
//!   L3     Lite + prior schemes distribute the tensor over the simulated
//!          cluster; HOOI (TTM → Lanczos SVD → FM transfer) runs on the
//!          compiled kernels; fit/metrics/volumes measured — all through
//!          the `TuckerSession` front door
//!
//! Output: per-scheme HOOI time table on the flickr analogue (4-D) and the
//! reddit analogue (3-D big), plus a convergence trace (fit per
//! invocation) under Lite — the end-to-end evidence that all layers
//! compose. Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use tucker_lite::coordinator::{EngineChoice, SchemeChoice, TuckerSession, Workload};
use tucker_lite::runtime::Engine;
use tucker_lite::sched;
use tucker_lite::tensor::datasets;
use tucker_lite::util::args::Args;
use tucker_lite::util::table::{fmt_secs, Table};

fn main() {
    let args = Args::from_env();
    let scale: f64 = args.parse_or("scale", 0.1);
    let p: usize = args.parse_or("p", 16);
    let k: usize = args.parse_or("k", 10);

    // one engine for every session below: artifacts load once, and the
    // label tells the truth when the pjrt path fell back to native
    let (engine, label) = Engine::pjrt_or_native();
    let engine = Arc::new(engine);
    println!("# engine: {label} (the e2e driver exercises the pjrt path)");

    // --- part 1: all four schemes through the compiled artifacts on a
    // 4-D medium analogue. On CPU-PJRT the per-dispatch overhead (~ms)
    // dominates wallclock, so the check here is *composition and
    // correctness*, not scheme-shape (that is Fig 10, native engine):
    // every scheme must complete and converge to the same fit — the
    // decomposition is distribution-invariant.
    let spec = datasets::by_name("flickr").unwrap();
    let w = Arc::new(Workload::from_spec(&spec, scale));
    println!(
        "\nflickr analogue: dims={:?} nnz={} P={p} K={k}",
        w.tensor.dims,
        w.tensor.nnz()
    );
    let mut t1 = Table::new(
        "e2e — all schemes through PJRT (flickr, 4-D)",
        &["scheme", "HOOI(sim)", "TTM", "SVD", "core", "comm", "fit"],
    );
    let mut fits1 = Vec::new();
    for scheme in sched::all_schemes() {
        let mut session = TuckerSession::builder(w.clone())
            .scheme(SchemeChoice::custom(scheme))
            .ranks(p)
            .core(k)
            .engine(EngineChoice::Shared(engine.clone()))
            .seed(4)
            .build()
            .expect("valid e2e configuration");
        let d = session.decompose();
        let rec = &d.record;
        fits1.push(d.fit());
        t1.row(vec![
            rec.scheme.clone(),
            fmt_secs(rec.hooi_secs),
            fmt_secs(rec.ttm_secs),
            fmt_secs(rec.svd_secs),
            fmt_secs(rec.core_secs),
            fmt_secs(rec.comm_secs),
            format!("{:.4}", d.fit()),
        ]);
    }
    t1.print();
    let spread = fits1.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - fits1.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("fit spread across schemes: {spread:.2e} (distribution-invariance)");
    assert!(spread < 1e-3, "schemes must agree on the decomposition");

    // --- part 2: convergence trace under Lite on a 3-D big-tensor
    // analogue (scaled), still through the compiled artifacts. One
    // session: the first invocation decomposes, the later ones refine
    // over the cached TTM plans (prepare_modes runs exactly once).
    let spec = datasets::by_name("reddit").unwrap();
    let wb = Workload::from_spec(&spec, scale * 0.2);
    // (single session: the workload moves in, no Arc needed)
    println!(
        "\nreddit analogue: dims={:?} nnz={}",
        wb.tensor.dims,
        wb.tensor.nnz()
    );
    let mut session = TuckerSession::builder(wb)
        .scheme(SchemeChoice::Lite)
        .ranks(p)
        .core(k)
        .engine(EngineChoice::Shared(engine.clone()))
        .seed(4)
        .build()
        .expect("valid e2e configuration");
    // per-row times are *incremental*: row 1 is the bootstrap run
    // (including the one-off plan-compilation charge), rows 2-3 are one
    // cached-plan refinement sweep each — exactly the cost profile a
    // long-running service pays
    let mut t2 = Table::new(
        "e2e — fit per HOOI invocation (reddit, Lite, one session)",
        &["invocations", "fit", "this increment (simulated)"],
    );
    let mut fits = Vec::new();
    for inv in 1..=3usize {
        let d = if inv == 1 { session.decompose() } else { session.decompose_more(1) };
        fits.push(d.fit());
        t2.row(vec![
            if inv == 1 { "1 (bootstrap + plans)".into() } else { format!("+1 → {inv}") },
            format!("{:.4}", d.fit()),
            fmt_secs(d.record.hooi_secs),
        ]);
    }
    t2.print();
    assert_eq!(session.plan_builds(), 1, "refinement reuses the compiled plans");

    // e2e assertions: all layers composed, ALS did not diverge
    assert!(fits.iter().all(|f| f.is_finite()));
    assert!(
        fits[2] >= fits[0] - 0.02,
        "fit should not degrade across invocations: {fits:?}"
    );
    println!("\ne2e_decompose OK — full stack (artifacts → PJRT → schemes → HOOI) composes");
}
