//! Quickstart: decompose a small sparse tensor with the Lite scheme.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a synthetic 3-D tensor, distributes it over 8 simulated ranks
//! with Lite, runs two HOOI invocations through the PJRT engine (native
//! fallback if artifacts are missing) and prints the decomposition
//! summary — the 60-second tour of the public API.

use tucker_lite::coordinator::{run_scheme, Workload};
use tucker_lite::dist::NetModel;
use tucker_lite::runtime::Engine;
use tucker_lite::sched::Lite;
use tucker_lite::tensor::slices::build_all;
use tucker_lite::tensor::synth::{generate, ModeDist};
use tucker_lite::util::table::{fmt_secs, fmt_si, Table};

fn main() {
    // 1. a workload: 3-D sparse tensor, one skewed mode (real tensors are
    //    never uniform — that skew is what distribution schemes fight)
    let modes = vec![
        ModeDist { len: 2000, zipf: 1.1 },
        ModeDist { len: 1500, zipf: 0.0 },
        ModeDist { len: 800, zipf: 0.8 },
    ];
    let tensor = generate(&modes, 120_000, 42);
    println!(
        "tensor: dims={:?} nnz={} sparsity={:.2e}",
        tensor.dims,
        tensor.nnz(),
        tensor.sparsity()
    );
    let idx = build_all(&tensor);
    let w = Workload { name: "quickstart".into(), tensor, idx };

    // 2. engine: compiled HLO artifacts over PJRT when built
    let (engine, label) = Engine::pjrt_or_native();
    println!("engine: {label}");

    // 3. decompose: Lite scheme, 8 simulated ranks, core 10×10×10,
    //    two HOOI invocations
    let rec = run_scheme(&w, &Lite, 8, 10, 2, &engine, NetModel::default(), 7);

    let mut t = Table::new("quickstart result", &["quantity", "value"]);
    t.row(vec!["fit".into(), format!("{:.4}", rec.fit)]);
    t.row(vec!["HOOI time (simulated)".into(), fmt_secs(rec.hooi_secs)]);
    t.row(vec!["TTM balance".into(), format!("{:.2}", rec.ttm_balance)]);
    t.row(vec!["SVD redundancy".into(), format!("{:.2}", rec.svd_load_norm)]);
    t.row(vec!["comm volume (units)".into(), fmt_si(rec.svd_volume + rec.fm_volume)]);
    t.print();

    // Theorem 6.1 in action: near-perfect balance, near-1 redundancy.
    assert!(rec.ttm_balance < 1.01);
    assert!(rec.svd_load_norm < 1.2);
    println!("quickstart OK");
}
