//! Quickstart: decompose a small sparse tensor through `TuckerSession`.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a synthetic 3-D tensor, configures a session (Lite scheme, 8
//! simulated ranks, 10×10×10 core, PJRT engine with native fallback),
//! runs two HOOI invocations, then refines with one more sweep over the
//! *cached* TTM plans — the 60-second tour of the public API.

use tucker_lite::coordinator::{EngineChoice, SchemeChoice, TuckerSession, Workload};
use tucker_lite::tensor::synth::{generate, ModeDist};
use tucker_lite::util::table::{fmt_secs, fmt_si, Table};

fn main() {
    // 1. a workload: 3-D sparse tensor, one skewed mode (real tensors are
    //    never uniform — that skew is what distribution schemes fight)
    let modes = vec![
        ModeDist { len: 2000, zipf: 1.1 },
        ModeDist { len: 1500, zipf: 0.0 },
        ModeDist { len: 800, zipf: 0.8 },
    ];
    let tensor = generate(&modes, 120_000, 42);
    println!(
        "tensor: dims={:?} nnz={} sparsity={:.2e}",
        tensor.dims,
        tensor.nnz(),
        tensor.sparsity()
    );
    let w = Workload::from_tensor("quickstart", tensor);

    // 2. a session: every choice is a typed option (scheme registry,
    //    ranks, core, engine); unset options fall back to env, then
    //    defaults. The build compiles the distribution and the per-rank
    //    TTM plans once.
    let mut session = TuckerSession::builder(w)
        .scheme(SchemeChoice::Lite)
        .ranks(8)
        .core(10usize) // uniform 10×10×10; try CoreRanks::PerMode(vec![...])
        .invocations(2)
        .engine(EngineChoice::PjrtOrNative)
        .seed(7)
        .build()
        .expect("valid session configuration");

    // 3. decompose: two HOOI invocations
    let d = session.decompose();
    let rec = &d.record;
    let mut t = Table::new("quickstart result", &["quantity", "value"]);
    t.row(vec!["fit".into(), format!("{:.4}", d.fit())]);
    t.row(vec!["core dims".into(), format!("{:?}", d.core_dims())]);
    t.row(vec!["HOOI time (simulated)".into(), fmt_secs(rec.hooi_secs)]);
    t.row(vec!["TTM balance".into(), format!("{:.2}", rec.ttm_balance)]);
    t.row(vec!["SVD redundancy".into(), format!("{:.2}", rec.svd_load_norm)]);
    t.row(vec!["comm volume (units)".into(), fmt_si(rec.svd_volume + rec.fm_volume)]);
    t.print();

    // 4. refine: one more sweep over the cached plans — no second
    //    prepare_modes, the session state (factors, RNG) carries over
    let refined = session.decompose_more(1);
    println!(
        "refined fit after one more sweep: {:.4} (plan builds: {})",
        refined.fit(),
        session.plan_builds()
    );
    assert_eq!(session.plan_builds(), 1, "TTM plans compiled exactly once");
    assert!(refined.fit() >= d.fit() - 0.02, "ALS must not diverge");

    // Theorem 6.1 in action: near-perfect balance, near-1 redundancy.
    assert!(rec.ttm_balance < 1.01);
    assert!(rec.svd_load_norm < 1.2);

    // 5. the decomposition is a handle, not just numbers: spot-check the
    //    reconstruction against a stored element
    let (coords, val) = {
        let t = &session.workload().tensor;
        let idx: Vec<usize> = (0..t.ndim()).map(|m| t.coord(m, 0) as usize).collect();
        (idx, t.vals[0])
    };
    let approx = refined.reconstruct_at(&coords).expect("stored coords are in range");
    println!("reconstruct{coords:?} = {approx:.3} (stored {val:.3})");
    println!("quickstart OK");
}
