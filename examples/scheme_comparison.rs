//! Scheme comparison: the paper's §7.2 analysis on one tensor.
//!
//!     cargo run --release --example scheme_comparison [-- --dataset enron --p 32]
//!
//! Distributes the same workload under all four schemes and prints the
//! §4 metrics, communication volumes, memory and the simulated HOOI time —
//! a single-table view of why Lite wins: near-perfect TTM balance at
//! near-optimal SVD redundancy, while CoarseG sacrifices balance and
//! MediumG/HyperG sacrifice redundancy.

use tucker_lite::coordinator::{run_scheme, Workload};
use tucker_lite::dist::NetModel;
use tucker_lite::runtime::Engine;
use tucker_lite::sched;
use tucker_lite::tensor::datasets;
use tucker_lite::util::args::Args;
use tucker_lite::util::table::{fmt_secs, fmt_si, Table};

fn main() {
    let args = Args::from_env();
    let name = args.str_or("dataset", "enron");
    let p: usize = args.parse_or("p", 32);
    let k: usize = args.parse_or("k", 10);
    let scale: f64 = args.parse_or("scale", 0.2);

    let spec = datasets::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown dataset {name}; see `tucker-lite datasets`");
        std::process::exit(2);
    });
    let w = Workload::from_spec(&spec, scale);
    println!(
        "{name}: dims={:?} nnz={} | P={p} K={k}",
        w.tensor.dims,
        w.tensor.nnz()
    );
    // native = timing-faithful at simulation scale (see DESIGN.md §Perf);
    // pass --engine pjrt to run on the compiled artifacts instead.
    let engine = match args.get("engine") {
        Some("pjrt") => Engine::pjrt_or_native().0,
        _ => Engine::Native,
    };
    println!("engine: {}", engine.name());

    let mut t = Table::new(
        "scheme comparison",
        &[
            "scheme", "HOOI", "TTM", "SVD", "comm", "TTM bal", "SVD load",
            "vol(SVD)", "vol(FM)", "mem MB", "dist time",
        ],
    );
    for scheme in sched::all_schemes() {
        let rec = run_scheme(&w, scheme.as_ref(), p, k, 1, &engine, NetModel::default(), 1);
        t.row(vec![
            rec.scheme.clone(),
            fmt_secs(rec.hooi_secs),
            fmt_secs(rec.ttm_secs),
            fmt_secs(rec.svd_secs),
            fmt_secs(rec.comm_secs),
            format!("{:.2}", rec.ttm_balance),
            format!("{:.2}", rec.svd_load_norm),
            fmt_si(rec.svd_volume),
            fmt_si(rec.fm_volume),
            format!("{:.1}", rec.mem_mb),
            fmt_secs(rec.dist_secs),
        ]);
    }
    t.print();
    println!("(expect: Lite best HOOI; CoarseG worst TTM bal; MediumG/HyperG higher SVD load)");
}
