//! Scheme comparison: the paper's §7.2 analysis on one tensor.
//!
//!     cargo run --release --example scheme_comparison [-- --dataset enron --p 32]
//!
//! Distributes the same workload under all four schemes (one
//! `TuckerSession` per scheme) and prints the §4 metrics, communication
//! volumes, memory and the simulated HOOI time — a single-table view of
//! why Lite wins: near-perfect TTM balance at near-optimal SVD
//! redundancy, while CoarseG sacrifices balance and MediumG/HyperG
//! sacrifice redundancy.

use std::sync::Arc;
use tucker_lite::coordinator::{EngineChoice, SchemeChoice, TuckerSession, Workload};
use tucker_lite::sched;
use tucker_lite::tensor::datasets;
use tucker_lite::util::args::Args;
use tucker_lite::util::table::{fmt_secs, fmt_si, Table};

fn main() {
    let args = Args::from_env();
    let name = args.str_or("dataset", "enron");
    let p: usize = args.parse_or("p", 32);
    let k: usize = args.parse_or("k", 10);
    let scale: f64 = args.parse_or("scale", 0.2);

    let spec = datasets::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown dataset {name}; see `tucker-lite datasets`");
        std::process::exit(2);
    });
    let w = Arc::new(Workload::from_spec(&spec, scale));
    println!(
        "{name}: dims={:?} nnz={} | P={p} K={k}",
        w.tensor.dims,
        w.tensor.nnz()
    );
    // native = timing-faithful at simulation scale (see DESIGN.md §Perf);
    // pass --engine pjrt to run on the compiled artifacts instead.
    let engine_choice = || match args.get("engine") {
        Some("pjrt") => EngineChoice::PjrtOrNative,
        _ => EngineChoice::Native,
    };

    let mut t = Table::new(
        "scheme comparison",
        &[
            "scheme", "HOOI", "TTM", "SVD", "core", "comm", "TTM bal", "SVD load",
            "vol(SVD)", "vol(FM)", "mem MB", "dist time",
        ],
    );
    for scheme in sched::all_schemes() {
        let mut session = TuckerSession::builder(w.clone())
            .scheme(SchemeChoice::custom(scheme))
            .ranks(p)
            .core(k)
            .engine(engine_choice())
            .seed(1)
            .build()
            .expect("valid comparison configuration");
        let d = session.decompose();
        let rec = &d.record;
        t.row(vec![
            rec.scheme.clone(),
            fmt_secs(rec.hooi_secs),
            fmt_secs(rec.ttm_secs),
            fmt_secs(rec.svd_secs),
            fmt_secs(rec.core_secs),
            fmt_secs(rec.comm_secs),
            format!("{:.2}", rec.ttm_balance),
            format!("{:.2}", rec.svd_load_norm),
            fmt_si(rec.svd_volume),
            fmt_si(rec.fm_volume),
            format!("{:.1}", rec.mem_mb),
            fmt_secs(rec.dist_secs),
        ]);
    }
    t.print();
    println!("(expect: Lite best HOOI; CoarseG worst TTM bal; MediumG/HyperG higher SVD load)");
}
