//! Domain example: topic structure in an NLP-style (doc × term × time)
//! tensor — the kind of workload the paper's intro motivates (NELL,
//! text analytics [21]).
//!
//!     cargo run --release --example nlp_topics
//!
//! Plants 4 disjoint rank-1 "topics" (a document community using a term
//! community in a time window, with separable intensities), adds sparse
//! background noise, decomposes with Tucker/HOOI under Lite, and checks
//! recovery: the tensor has multilinear rank exactly (4,4,4) up to noise,
//! so a K=6 core must capture nearly all the energy (fit ≈ 1), while a
//! K=1 decomposition cannot — both are asserted.

use tucker_lite::coordinator::{run_scheme, Workload};
use tucker_lite::dist::NetModel;
use tucker_lite::runtime::Engine;
use tucker_lite::sched::Lite;
use tucker_lite::tensor::slices::build_all;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;

const TOPICS: usize = 4;
const DOCS_PER: u32 = 100;
const TERMS_PER: u32 = 80;
const TIMES_PER: u32 = 12;

fn main() {
    let dims = vec![
        DOCS_PER * TOPICS as u32 + 50,   // extra "inactive" docs
        TERMS_PER * TOPICS as u32 + 40,  // extra vocabulary
        TIMES_PER * TOPICS as u32,
    ];
    let mut rng = Rng::new(2026);
    let mut t = SparseTensor::new(dims.clone());

    // planted topics: disjoint rank-1 blocks with separable intensities
    for topic in 0..TOPICS as u32 {
        let (d0, w0, s0) = (topic * DOCS_PER, topic * TERMS_PER, topic * TIMES_PER);
        let du: Vec<f32> = (0..DOCS_PER).map(|_| 0.5 + rng.f32()).collect();
        let tv: Vec<f32> = (0..TERMS_PER).map(|_| 0.5 + rng.f32()).collect();
        let sw: Vec<f32> = (0..TIMES_PER).map(|_| 0.5 + rng.f32()).collect();
        for d in 0..DOCS_PER {
            for w in 0..TERMS_PER {
                for s in 0..TIMES_PER {
                    t.push(
                        &[d0 + d, w0 + w, s0 + s],
                        du[d as usize] * tv[w as usize] * sw[s as usize],
                    );
                }
            }
        }
    }
    // sparse background noise over the whole tensor
    for _ in 0..20_000 {
        t.push(
            &[
                rng.below(dims[0] as u64) as u32,
                rng.below(dims[1] as u64) as u32,
                rng.below(dims[2] as u64) as u32,
            ],
            0.1 * (rng.f32() - 0.5),
        );
    }
    t.coalesce();
    println!("doc×term×time tensor: dims={:?} nnz={}", t.dims, t.nnz());

    let idx = build_all(&t);
    let w = Workload { name: "nlp_topics".into(), tensor: t, idx };
    let engine = Engine::Native; // timing-faithful path for the demo
    println!("engine: {}", engine.name());

    // K=6 > 4 topics: room to isolate them; 2 sweeps for ALS to settle
    let rec6 = run_scheme(&w, &Lite, 16, 6, 2, &engine, NetModel::default(), 9);
    // K=1 control: a single component cannot span 4 disjoint topics
    let rec1 = run_scheme(&w, &Lite, 16, 1, 2, &engine, NetModel::default(), 9);
    println!(
        "fit(K=6)={:.4}  fit(K=1)={:.4}  (HOOI {:.1}ms simulated, P=16)",
        rec6.fit,
        rec1.fit,
        rec6.hooi_secs * 1e3
    );

    assert!(
        rec6.fit > 0.85,
        "rank-(4,4,4) structure must be captured at K=6, fit={}",
        rec6.fit
    );
    assert!(
        rec6.fit > rec1.fit + 0.3,
        "K=6 must far exceed the K=1 control: {} vs {}",
        rec6.fit,
        rec1.fit
    );
    println!("nlp_topics OK — planted topic structure recovered");
}
