//! Domain example: topic structure in an NLP-style (doc × term × time)
//! tensor — the kind of workload the paper's intro motivates (NELL,
//! text analytics [21]).
//!
//!     cargo run --release --example nlp_topics
//!
//! Plants 4 disjoint rank-1 "topics" (a document community using a term
//! community in a time window, with separable intensities), adds sparse
//! background noise, decomposes with Tucker/HOOI under Lite, and checks
//! recovery: the tensor has multilinear rank exactly (4,4,4) up to noise,
//! so a 6×6×5 per-mode core (`CoreRanks::PerMode` — the time mode is
//! short, no need to spend a full K on it) must capture nearly all the
//! energy (fit ≈ 1), while a K=1 decomposition cannot — both asserted.

use std::sync::Arc;
use tucker_lite::coordinator::{SchemeChoice, TuckerSession, Workload};
use tucker_lite::hooi::CoreRanks;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;

const TOPICS: usize = 4;
const DOCS_PER: u32 = 100;
const TERMS_PER: u32 = 80;
const TIMES_PER: u32 = 12;

fn main() {
    let dims = vec![
        DOCS_PER * TOPICS as u32 + 50,   // extra "inactive" docs
        TERMS_PER * TOPICS as u32 + 40,  // extra vocabulary
        TIMES_PER * TOPICS as u32,
    ];
    let mut rng = Rng::new(2026);
    let mut t = SparseTensor::new(dims.clone());

    // planted topics: disjoint rank-1 blocks with separable intensities
    for topic in 0..TOPICS as u32 {
        let (d0, w0, s0) = (topic * DOCS_PER, topic * TERMS_PER, topic * TIMES_PER);
        let du: Vec<f32> = (0..DOCS_PER).map(|_| 0.5 + rng.f32()).collect();
        let tv: Vec<f32> = (0..TERMS_PER).map(|_| 0.5 + rng.f32()).collect();
        let sw: Vec<f32> = (0..TIMES_PER).map(|_| 0.5 + rng.f32()).collect();
        for d in 0..DOCS_PER {
            for w in 0..TERMS_PER {
                for s in 0..TIMES_PER {
                    t.push(
                        &[d0 + d, w0 + w, s0 + s],
                        du[d as usize] * tv[w as usize] * sw[s as usize],
                    );
                }
            }
        }
    }
    // sparse background noise over the whole tensor
    for _ in 0..20_000 {
        t.push(
            &[
                rng.below(dims[0] as u64) as u32,
                rng.below(dims[1] as u64) as u32,
                rng.below(dims[2] as u64) as u32,
            ],
            0.1 * (rng.f32() - 0.5),
        );
    }
    t.coalesce();
    println!("doc×term×time tensor: dims={:?} nnz={}", t.dims, t.nnz());
    let w = Arc::new(Workload::from_tensor("nlp_topics", t));

    // 6×6×5 core: room above the 4 planted topics on every mode, with a
    // narrower time rank (the new per-mode capability) — the default
    // Native engine is the timing-faithful path for the demo
    let session = |core: CoreRanks| {
        TuckerSession::builder(w.clone())
            .scheme(SchemeChoice::Lite)
            .ranks(16)
            .core(core)
            .invocations(2) // 2 sweeps for ALS to settle
            .seed(9)
            .build()
            .expect("valid topic-recovery configuration")
    };
    let d6 = session(CoreRanks::PerMode(vec![6, 6, 5])).decompose();
    // K=1 control: a single component cannot span 4 disjoint topics
    let d1 = session(CoreRanks::Uniform(1)).decompose();
    println!(
        "fit(6x6x5)={:.4}  fit(K=1)={:.4}  (HOOI {:.1}ms simulated, P=16)",
        d6.fit(),
        d1.fit(),
        d6.record.hooi_secs * 1e3
    );
    assert_eq!(d6.core_dims(), &[6, 6, 5]);
    assert_eq!(d6.factors[2].cols, 5, "time factor is L2 x 5");

    assert!(
        d6.fit() > 0.85,
        "rank-(4,4,4) structure must be captured at 6x6x5, fit={}",
        d6.fit()
    );
    assert!(
        d6.fit() > d1.fit() + 0.3,
        "6x6x5 must far exceed the K=1 control: {} vs {}",
        d6.fit(),
        d1.fit()
    );
    println!("nlp_topics OK — planted topic structure recovered");
}
