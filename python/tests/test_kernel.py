"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and value distributions; assert_allclose against
ref.py is the core correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kron_contrib as kk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 1e-5
RTOL = 1e-5


def _rows(rng, b, k):
    return jnp.asarray(rng.standard_normal((b, k)), dtype=jnp.float32)


def _vals(rng, b):
    return jnp.asarray(rng.standard_normal((b,)), dtype=jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 48),
    ka=st.integers(1, 9),
    kb=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron3_matches_ref(b, ka, kb, seed):
    rng = np.random.default_rng(seed)
    ra, rb, v = _rows(rng, b, ka), _rows(rng, b, kb), _vals(rng, b)
    got = kk.kron_contrib_3d(ra, rb, v)
    want = ref.kron_contrib_3d(ra, rb, v)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 24),
    ka=st.integers(1, 6),
    kb=st.integers(1, 6),
    kc=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_kron4_matches_ref(b, ka, kb, kc, seed):
    rng = np.random.default_rng(seed)
    ra, rb, rc = _rows(rng, b, ka), _rows(rng, b, kb), _rows(rng, b, kc)
    v = _vals(rng, b)
    got = kk.kron_contrib_4d(ra, rb, rc, v)
    want = ref.kron_contrib_4d(ra, rb, rc, v)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_kron3_layout_contract():
    """contr[c_a + c_b*K_a] = val * a[c_a] * b[c_b] — the exact indexing the
    rust coordinator assumes (earliest mode fastest)."""
    ka, kb = 3, 2
    a = jnp.arange(ka, dtype=jnp.float32) + 1.0  # [1,2,3]
    b = jnp.arange(kb, dtype=jnp.float32) + 10.0  # [10,11]
    out = np.asarray(kk.kron_contrib_3d(a[None, :], b[None, :], jnp.ones(1)))[0]
    for cb in range(kb):
        for ca in range(ka):
            assert out[ca + cb * ka] == pytest.approx(a[ca] * b[cb])


def test_kron4_layout_contract():
    ka, kb, kc = 2, 3, 2
    a = jnp.array([1.0, 2.0])
    b = jnp.array([1.0, 10.0, 100.0])
    c = jnp.array([1.0, 1000.0])
    out = np.asarray(
        kk.kron_contrib_4d(a[None], b[None], c[None], jnp.ones(1))
    )[0]
    for cc in range(kc):
        for cb in range(kb):
            for ca in range(ka):
                assert out[ca + cb * ka + cc * ka * kb] == pytest.approx(
                    float(a[ca] * b[cb] * c[cc])
                )


def test_kron3_zero_vals_pad_rows_are_zero():
    """The rust runtime pads ragged batches with val=0 rows; those rows must
    contribute exactly zero regardless of row content."""
    rng = np.random.default_rng(0)
    ra, rb = _rows(rng, 8, 5), _rows(rng, 8, 5)
    v = jnp.zeros(8, dtype=jnp.float32).at[:3].set(1.0)
    out = np.asarray(kk.kron_contrib_3d(ra, rb, v))
    assert np.all(out[3:] == 0.0)


@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 64),
    khat=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(r, khat, seed):
    rng = np.random.default_rng(seed)
    z = _rows(rng, r, khat)
    x = jnp.asarray(rng.standard_normal(khat), dtype=jnp.float32)
    got = kk.z_matvec(z, x)
    want = ref.z_matvec(z, x)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    r=st.integers(1, 64),
    khat=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmatvec_matches_ref(r, khat, seed):
    rng = np.random.default_rng(seed)
    z = _rows(rng, r, khat)
    y = jnp.asarray(rng.standard_normal(r), dtype=jnp.float32)
    got = kk.z_rmatvec(y, z)
    want = ref.z_rmatvec(y, z)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_matvec_zero_row_padding():
    """Tiled matvec: zero rows (tile padding) must produce zero outputs."""
    rng = np.random.default_rng(1)
    z = np.zeros((16, 10), dtype=np.float32)
    z[:5] = rng.standard_normal((5, 10))
    x = jnp.asarray(rng.standard_normal(10), dtype=jnp.float32)
    out = np.asarray(kk.z_matvec(jnp.asarray(z), x))
    assert np.all(out[5:] == 0.0)


@pytest.mark.parametrize("blk", [1, 2, 4, 8])
def test_kron3_block_size_invariance(blk):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    ra, rb, v = _rows(rng, 8, 6), _rows(rng, 8, 6), _vals(rng, 8)
    base = ref.kron_contrib_3d(ra, rb, v)
    got = kk.kron_contrib_3d(ra, rb, v, blk_b=blk)
    np.testing.assert_allclose(got, base, atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("blk", [1, 4, 16])
def test_rmatvec_block_size_invariance(blk):
    rng = np.random.default_rng(8)
    z = _rows(rng, 16, 12)
    y = jnp.asarray(rng.standard_normal(16), dtype=jnp.float32)
    base = ref.z_rmatvec(y, z)
    got = kk.z_rmatvec(y, z, blk_r=blk)
    np.testing.assert_allclose(got, base, atol=1e-4, rtol=1e-4)
