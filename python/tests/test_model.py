"""L2 correctness: model graphs compose the kernels correctly."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_ttm_contrib_3d_returns_tuple():
    ra = jnp.ones((4, 3), jnp.float32)
    v = jnp.ones((4,), jnp.float32)
    out = model.ttm_contrib_3d(ra, ra, v)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (4, 9)


def test_ttm_contrib_4d_shape():
    r = jnp.ones((4, 3), jnp.float32)
    v = jnp.ones((4,), jnp.float32)
    (out,) = model.ttm_contrib_4d(r, r, r, v)
    assert out.shape == (4, 27)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 16),
    k=st.integers(1, 6),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_segsum_fused_matches_unfused(b, k, r, seed):
    """Fused segsum graph == ref contributions followed by ref seg_matmul."""
    rng = np.random.default_rng(seed)
    ra = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    rb = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    v = jnp.asarray(rng.standard_normal(b), jnp.float32)
    assign = rng.integers(0, r, size=b)
    onehot = jnp.asarray(np.eye(r, dtype=np.float32)[assign])
    (got,) = model.ttm_contrib_segsum_3d(ra, rb, v, onehot)
    want = ref.seg_matmul(ref.kron_contrib_3d(ra, rb, v), onehot)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_segsum_accumulates_duplicate_rows():
    """Two batch elements hitting the same local row must sum (Eq. 1)."""
    ra = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
    rb = jnp.asarray([[1.0, 1.0], [1.0, 1.0]], jnp.float32)
    v = jnp.asarray([2.0, 3.0], jnp.float32)
    onehot = jnp.asarray([[1.0], [1.0]], jnp.float32)  # both -> row 0
    (got,) = model.ttm_contrib_segsum_3d(ra, rb, v, onehot)
    want = ref.kron_contrib_3d(ra, rb, v).sum(axis=0, keepdims=True)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_matvec_tile_graphs():
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.standard_normal((8, 5)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(5), jnp.float32)
    y = jnp.asarray(rng.standard_normal(8), jnp.float32)
    (xv,) = model.z_matvec_tile(z, x)
    (yv,) = model.z_rmatvec_tile(y, z)
    np.testing.assert_allclose(xv, ref.z_matvec(z, x), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(yv, ref.z_rmatvec(y, z), atol=1e-4, rtol=1e-4)
