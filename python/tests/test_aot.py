"""AOT emitter: lowering produces parseable HLO text with the right shapes."""

import os
import re
import tempfile

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def _lower_ttm3(k, b):
    spec = jax.ShapeDtypeStruct((b, k), jnp.float32)
    vspec = jax.ShapeDtypeStruct((b,), jnp.float32)
    return jax.jit(model.ttm_contrib_3d).lower(spec, spec, vspec)


def test_hlo_text_is_emitted():
    text = aot.to_hlo_text(_lower_ttm3(4, 8))
    assert "HloModule" in text
    # output is a 1-tuple of (B, K^2) f32
    assert re.search(r"f32\[8,16\]", text)


def test_hlo_text_has_no_mosaic_custom_call():
    """interpret=True must lower to plain HLO (no tpu custom-call), or the
    rust CPU PJRT client cannot run the artifact."""
    text = aot.to_hlo_text(_lower_ttm3(4, 8))
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_emit_writes_manifest(tmp_path):
    # Monkeypatch configs down to the smoke sizes so the test stays fast.
    old = (
        aot.TTM3D_CONFIGS,
        aot.TTM4D_CONFIGS,
        aot.SEGSUM3D_CONFIGS,
        aot.MATVEC_CONFIGS,
    )
    aot.TTM3D_CONFIGS = [(4, 16)]
    aot.TTM4D_CONFIGS = [(4, 8)]
    aot.SEGSUM3D_CONFIGS = [(4, 8, 4)]
    aot.MATVEC_CONFIGS = [(16, 8)]
    try:
        aot.emit(str(tmp_path))
    finally:
        (
            aot.TTM3D_CONFIGS,
            aot.TTM4D_CONFIGS,
            aot.SEGSUM3D_CONFIGS,
            aot.MATVEC_CONFIGS,
        ) = old
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    # 1 ttm3d + 1 ttm4d + 1 segsum + 2 matvec kinds
    assert len(manifest) == 5
    for line in manifest:
        name = line.split()[0]
        assert (tmp_path / name).exists()
        meta = dict(kv.split("=") for kv in line.split()[1:])
        assert "kind" in meta
