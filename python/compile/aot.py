"""AOT emitter: lower the L2 graphs to HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is listed in artifacts/manifest.txt with its static shapes:

    <name>.hlo.txt kind=<kind> n=<N> k=<K> khat=<K^{N-1}> b=<B> rtile=<R>

The rust runtime/artifacts.rs registry parses the manifest, compiles each
module once on the PJRT CPU client, and dispatches padded batches.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32

# (K, B) configs for the TTM contribution artifacts. K covers the paper's
# configurations (K=10 and the K=20 core-size study) plus a small smoke
# size used by tests; B is the padded batch the rust hot loop dispatches.
TTM3D_CONFIGS = [(4, 256), (10, 8192), (16, 4096), (20, 4096)]
TTM4D_CONFIGS = [(4, 256), (10, 2048)]
# Fused segsum ablation: (K, B, R_BLK).
SEGSUM3D_CONFIGS = [(10, 2048, 256)]
# Lanczos matvec tiles: (khat, rtile). Khat = K^{N-1} for each config above.
MATVEC_CONFIGS = [(16, 256), (100, 512), (256, 512), (400, 512), (1000, 256), (64, 256)]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def emit(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def write(name, lowered, **meta):
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            f"{name}.hlo.txt " + " ".join(f"{k}={v}" for k, v in meta.items())
        )
        print(f"  wrote {path} ({len(text)} chars)")

    for k, b in TTM3D_CONFIGS:
        name = f"ttm3d_k{k}_b{b}"
        lowered = jax.jit(model.ttm_contrib_3d).lower(
            _spec(b, k), _spec(b, k), _spec(b)
        )
        write(name, lowered, kind="ttm", n=3, k=k, khat=k * k, b=b)

    for k, b in TTM4D_CONFIGS:
        name = f"ttm4d_k{k}_b{b}"
        lowered = jax.jit(model.ttm_contrib_4d).lower(
            _spec(b, k), _spec(b, k), _spec(b, k), _spec(b)
        )
        write(name, lowered, kind="ttm", n=4, k=k, khat=k**3, b=b)

    for k, b, r in SEGSUM3D_CONFIGS:
        name = f"segsum3d_k{k}_b{b}_r{r}"
        lowered = jax.jit(model.ttm_contrib_segsum_3d).lower(
            _spec(b, k), _spec(b, k), _spec(b), _spec(b, r)
        )
        write(name, lowered, kind="segsum", n=3, k=k, khat=k * k, b=b, rtile=r)

    for khat, rtile in MATVEC_CONFIGS:
        name = f"matvec_kh{khat}_r{rtile}"
        lowered = jax.jit(model.z_matvec_tile).lower(
            _spec(rtile, khat), _spec(khat)
        )
        write(name, lowered, kind="matvec", khat=khat, rtile=rtile)

        name = f"rmatvec_kh{khat}_r{rtile}"
        lowered = jax.jit(model.z_rmatvec_tile).lower(
            _spec(rtile), _spec(rtile, khat)
        )
        write(name, lowered, kind="rmatvec", khat=khat, rtile=rtile)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    emit(args.out)


if __name__ == "__main__":
    main()
