"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: every Pallas kernel in this package is
checked against the functions here (pytest + hypothesis sweeps in
python/tests/test_kernel.py). They also document the *layout contract* the
rust L3 coordinator relies on.

Layout contract (paper, Appendix A): the Kronecker product of rows taken in
ascending mode order places the EARLIEST mode fastest-varying, i.e. for a
3-D tensor and modes (a, b) with a < b, the contribution vector satisfies

    contr[c_a + c_b * K_a] = val * F_a[l_a, c_a] * F_b[l_b, c_b]

so as a row-major (B, K_b, K_a) array the fastest axis is mode a. For 4-D
and modes (a, b, c) ascending:

    contr[c_a + c_b*K_a + c_c*K_a*K_b] = val * F_a[.,c_a] F_b[.,c_b] F_c[.,c_c]
"""

import jax.numpy as jnp


def kron_contrib_3d(rows_a, rows_b, vals):
    """Batched mode-skipping Kronecker contribution for 3-D tensors.

    Args:
      rows_a: (B, K_a) factor-matrix rows of the *earlier* non-skipped mode.
      rows_b: (B, K_b) rows of the later non-skipped mode.
      vals:   (B,)     element values.
    Returns:
      (B, K_a * K_b) contributions, mode-a fastest (see layout contract).
    """
    b = rows_a.shape[0]
    # [B, K_b, K_a]: axis order makes mode-a fastest after row-major reshape.
    outer = rows_b[:, :, None] * rows_a[:, None, :]
    return (vals[:, None] * outer.reshape(b, -1)).astype(rows_a.dtype)


def kron_contrib_4d(rows_a, rows_b, rows_c, vals):
    """Batched Kronecker contribution for 4-D tensors (three rows).

    Returns (B, K_a*K_b*K_c), mode-a fastest, then b, then c.
    """
    b = rows_a.shape[0]
    outer = (
        rows_c[:, :, None, None]
        * rows_b[:, None, :, None]
        * rows_a[:, None, None, :]
    )
    return (vals[:, None] * outer.reshape(b, -1)).astype(rows_a.dtype)


def seg_matmul(contrib, onehot):
    """Segment-reduce contributions into local penultimate rows via matmul.

    The MXU-friendly formulation of the scatter-add (DESIGN.md
    §Hardware-Adaptation): Z_partial = S^T @ C.

    Args:
      contrib: (B, Khat) contribution batch.
      onehot:  (B, R) one-hot slice-row assignment.
    Returns: (R, Khat).
    """
    return onehot.T @ contrib


def z_matvec(z_tile, x):
    """x-query tile: (R_TILE, Khat) @ (Khat,) -> (R_TILE,)."""
    return z_tile @ x


def z_rmatvec(y, z_tile):
    """y-query tile: (R_TILE,) @ (R_TILE, Khat) -> (Khat,)."""
    return y @ z_tile
