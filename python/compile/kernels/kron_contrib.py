"""L1 Pallas kernels: batched Kronecker contributions (the TTM hot spot).

The HOOI TTM-chain reformulation (paper §3, Eq. 1) reduces the per-mode
TTM-chain to, per non-zero element e:

    contr_n(e) = val(e) * F_a[l_a,:] (x) F_b[l_b,:] ( (x) F_c[l_c,:] )

followed by a segment-sum into the slice rows of the local penultimate
matrix Z^p. These kernels compute the contribution batch; the reduction is
either done by the rust runtime (scatter-add) or by the fused `seg_matmul`
graph in model.py (MXU formulation).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the batch dimension B is
tiled via BlockSpec into BLK_B-row blocks so each grid step streams
(BLK_B, K) row-gathers from HBM into VMEM and writes a (BLK_B, K^{N-1})
contribution block. The outer product is broadcast-multiply work on the
VPU; the fused reduction variant turns it into an MXU matmul.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowering emits plain HLO that any
backend (including the rust `xla`-crate client) runs. Correctness is
asserted against kernels/ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_blk(b: int, preferred: int) -> int:
    """Largest block size <= preferred that divides b."""
    blk = min(b, preferred)
    while b % blk != 0:
        blk -= 1
    return blk


def _kron3_kernel(a_ref, b_ref, v_ref, o_ref):
    """One grid step: (BLK,Ka),(BLK,Kb),(BLK,) -> (BLK, Ka*Kb)."""
    a = a_ref[...]
    b = b_ref[...]
    v = v_ref[...]
    blk = a.shape[0]
    # [BLK, Kb, Ka] so that mode-a is fastest after the row-major reshape —
    # the layout contract in ref.py.
    outer = b[:, :, None] * a[:, None, :]
    o_ref[...] = v[:, None] * outer.reshape(blk, -1)


def kron_contrib_3d(rows_a, rows_b, vals, *, blk_b: int = 256):
    """Pallas TTM contribution kernel for 3-D tensors.

    Args/returns match ref.kron_contrib_3d. `blk_b` is the B-tile streamed
    through VMEM per grid step (auto-shrunk to divide B).
    """
    b, ka = rows_a.shape
    kb = rows_b.shape[1]
    blk = _pick_blk(b, blk_b)
    grid = (b // blk,)
    return pl.pallas_call(
        _kron3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, ka), lambda i: (i, 0)),
            pl.BlockSpec((blk, kb), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk, ka * kb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ka * kb), rows_a.dtype),
        interpret=True,
    )(rows_a, rows_b, vals)


def _kron4_kernel(a_ref, b_ref, c_ref, v_ref, o_ref):
    """One grid step: three row blocks -> (BLK, Ka*Kb*Kc)."""
    a = a_ref[...]
    b = b_ref[...]
    c = c_ref[...]
    v = v_ref[...]
    blk = a.shape[0]
    outer = c[:, :, None, None] * b[:, None, :, None] * a[:, None, None, :]
    o_ref[...] = v[:, None] * outer.reshape(blk, -1)


def kron_contrib_4d(rows_a, rows_b, rows_c, vals, *, blk_b: int = 128):
    """Pallas TTM contribution kernel for 4-D tensors (kron of three rows)."""
    b, ka = rows_a.shape
    kb = rows_b.shape[1]
    kc = rows_c.shape[1]
    blk = _pick_blk(b, blk_b)
    grid = (b // blk,)
    return pl.pallas_call(
        _kron4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, ka), lambda i: (i, 0)),
            pl.BlockSpec((blk, kb), lambda i: (i, 0)),
            pl.BlockSpec((blk, kc), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk, ka * kb * kc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ka * kb * kc), rows_a.dtype),
        interpret=True,
    )(rows_a, rows_b, rows_c, vals)


def _matvec_kernel(z_ref, x_ref, o_ref):
    o_ref[...] = z_ref[...] @ x_ref[...]


def z_matvec(z_tile, x, *, blk_r: int = 128):
    """Pallas x-query tile: (R, Khat) @ (Khat,) -> (R,), tiled over R.

    Used by the Lanczos oracle; R is the fixed artifact tile (R_TILE), the
    rust runtime pads the ragged last tile with zero rows.
    """
    r, khat = z_tile.shape
    blk = _pick_blk(r, blk_r)
    return pl.pallas_call(
        _matvec_kernel,
        grid=(r // blk,),
        in_specs=[
            pl.BlockSpec((blk, khat), lambda i: (i, 0)),
            pl.BlockSpec((khat,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), z_tile.dtype),
        interpret=True,
    )(z_tile, x)


def _rmatvec_kernel(y_ref, z_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += y_ref[...] @ z_ref[...]


def z_rmatvec(y, z_tile, *, blk_r: int = 128):
    """Pallas y-query tile: (R,) @ (R, Khat) -> (Khat,), accumulated over R
    blocks (sequential grid, accumulator output block)."""
    r, khat = z_tile.shape
    blk = _pick_blk(r, blk_r)
    return pl.pallas_call(
        _rmatvec_kernel,
        grid=(r // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk, khat), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((khat,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((khat,), z_tile.dtype),
        interpret=True,
    )(y, z_tile)
