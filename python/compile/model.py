"""L2: JAX compute graphs for the HOOI hot spots.

Each function here is a build-time graph that aot.py lowers ONCE to HLO
text; the rust runtime loads + compiles the artifacts and executes them on
the request path. Python never runs at decomposition time.

Graphs (all fixed-shape; the rust side pads ragged batches/tiles):

  ttm_contrib_3d / ttm_contrib_4d
      gather-free contribution batch: the rust coordinator gathers the
      factor-matrix rows per element (cheap, cache-friendly, and keeps the
      artifact shape independent of L_n) and the graph computes the batched
      Kronecker contributions via the L1 Pallas kernel.

  ttm_contrib_segsum_3d
      fused variant: contributions + one-hot segment reduction (S^T @ C),
      the MXU formulation of the scatter-add; ablated in
      rust/benches/ablate_runtime.rs.

  z_matvec_tile / z_rmatvec_tile
      Lanczos oracle tiles over the truncated local penultimate matrix.
"""

import jax.numpy as jnp

from .kernels import kron_contrib as kk


# AOT block sizing: on a real TPU the BlockSpec tile would be bounded by
# VMEM (BLK_B ≈ 256 for 3-D K=20, 128 for 4-D K=10 — DESIGN.md §7); the
# CPU-PJRT execution target prefers grid=1 (one block covering the whole
# batch), because interpret-mode multi-step grids lower to a while-loop of
# dynamic slices that the CPU backend executes an order of magnitude
# slower. The tiling machinery itself is exercised by the hypothesis tests
# (block-size invariance), so correctness is independent of this choice.


def ttm_contrib_3d(rows_a, rows_b, vals):
    """(B,K),(B,K),(B,) -> (B,K^2) contributions. Pallas on the inside."""
    return (kk.kron_contrib_3d(rows_a, rows_b, vals, blk_b=rows_a.shape[0]),)


def ttm_contrib_4d(rows_a, rows_b, rows_c, vals):
    """(B,K)x3,(B,) -> (B,K^3) contributions."""
    return (
        kk.kron_contrib_4d(rows_a, rows_b, rows_c, vals, blk_b=rows_a.shape[0]),
    )


def ttm_contrib_segsum_3d(rows_a, rows_b, vals, onehot):
    """Fused contribution + segment reduction.

    onehot: (B, R_BLK) one-hot assignment of each batch element to a local
    penultimate row. Output (R_BLK, K^2) partial Z^p block.
    """
    contrib = kk.kron_contrib_3d(rows_a, rows_b, vals, blk_b=rows_a.shape[0])
    return (onehot.T @ contrib,)


def z_matvec_tile(z_tile, x):
    """(R_TILE, Khat),(Khat,) -> (R_TILE,) local x-query tile."""
    return (kk.z_matvec(z_tile, x, blk_r=z_tile.shape[0]),)


def z_rmatvec_tile(y, z_tile):
    """(R_TILE,),(R_TILE, Khat) -> (Khat,) local y-query tile."""
    return (kk.z_rmatvec(y, z_tile, blk_r=z_tile.shape[0]),)
