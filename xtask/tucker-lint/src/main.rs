//! `tucker-lint` — dependency-free static analysis for the tucker-lite
//! tree. The offline image vendors no crates, so this is a hand-rolled
//! lexer (comments, strings, char literals, `#[cfg(test)]` regions)
//! plus a handful of repo-specific rules, all deny-by-default:
//!
//! - **L1** `std::env::var*` only inside `rust/src/util/env.rs` — the
//!   typed-option > env > default precedence lives there and nowhere
//!   else.
//! - **L2** every `unsafe` keyword (block, fn, impl) immediately
//!   preceded by a `// SAFETY:` comment (attribute lines between the
//!   comment and the keyword are fine).
//! - **L3** no `.unwrap()`, no `.expect(..)` whose message does not
//!   start with `"invariant: "`, and no constant-literal slice indexing
//!   in the fault-facing modules (`serve/`, `dist/transport.rs`,
//!   `dist/fault.rs`, `coordinator/checkpoint.rs`) outside
//!   `#[cfg(test)]`.
//! - **L4** `Instant`/`SystemTime` only inside `rust/src/util/timer.rs`
//!   — all other timing goes through `timer::Stopwatch`/`Deadline` so
//!   clock reads stay auditable.
//! - **L5** every category const declared in `dist::cluster::cat` must
//!   be a member of `cat::IN_PHASE_SUM` or `cat::OUT_OF_PHASE_SUM`, the
//!   arrays the Fig 11 phase-sum-invariance checks iterate.
//! - **L6** no bare `==`/`!=` against an `f32`/`f64` literal outside
//!   the designated helpers in `rust/src/util/float.rs` — exact float
//!   comparisons must be spelled through the quarantined helpers or
//!   `to_bits()`.
//!
//! Diagnostics print as `path:line: [RULE] message: line text`. The
//! checked-in allowlist (`xtask/tucker-lint/allowlist.txt`) can
//! grandfather L3–L6 sites with a one-line justification; L1 and L2 are
//! not allowlistable. Stale entries (matching nothing) are themselves
//! errors, so the burn-down is monotone.
//!
//! Run from the workspace root: `cargo run -p tucker-lint` (optionally
//! with an explicit repo root argument).

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Directories scanned relative to the repo root.
const WALK_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// The one file allowed to read process environment variables.
const ENV_HOME: &str = "rust/src/util/env.rs";

/// The one file allowed to touch `Instant`/`SystemTime` directly.
const TIMER_HOME: &str = "rust/src/util/timer.rs";

/// The quarantine for exact float comparisons.
const FLOAT_HOME: &str = "rust/src/util/float.rs";

/// Fault-facing modules where panicking calls are banned (L3).
const NO_PANIC_FILES: [&str; 3] =
    ["rust/src/dist/transport.rs", "rust/src/dist/fault.rs", "rust/src/coordinator/checkpoint.rs"];
const NO_PANIC_DIR: &str = "rust/src/serve/";

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Rule {
    L1,
    L2,
    L3,
    L4,
    L5,
    L6,
}

impl Rule {
    fn id(self) -> &'static str {
        match self {
            Rule::L1 => "L1",
            Rule::L2 => "L2",
            Rule::L3 => "L3",
            Rule::L4 => "L4",
            Rule::L5 => "L5",
            Rule::L6 => "L6",
        }
    }

    fn parse(s: &str) -> Option<Rule> {
        match s {
            "L1" => Some(Rule::L1),
            "L2" => Some(Rule::L2),
            "L3" => Some(Rule::L3),
            "L4" => Some(Rule::L4),
            "L5" => Some(Rule::L5),
            "L6" => Some(Rule::L6),
            _ => None,
        }
    }

    /// L1 (env containment) and L2 (SAFETY comments) must be fixed at
    /// the source, never grandfathered.
    fn allowlistable(self) -> bool {
        !matches!(self, Rule::L1 | Rule::L2)
    }
}

#[derive(Debug)]
struct Diagnostic {
    rule: Rule,
    path: String,
    line: usize,
    message: String,
    text: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}: {}",
            self.path,
            self.line,
            self.rule.id(),
            self.message,
            self.text
        )
    }
}

/// One source line after lexing: `code` is the raw text with comment
/// bodies and string/char-literal contents blanked to spaces
/// (byte-aligned with `raw`), `in_test` marks `#[cfg(test)]` regions.
struct LineInfo {
    raw: String,
    code: String,
    in_test: bool,
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Recognize a raw-string opener (`r"`, `r#"`, `br##"`, ...) at `i`.
/// Returns (hash count, bytes consumed by the opener).
fn raw_str_start(bytes: &[u8], i: usize) -> Option<(usize, usize)> {
    if i > 0 && is_ident_byte(bytes[i - 1]) {
        return None;
    }
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while bytes.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if bytes.get(j) == Some(&b'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Blank comments and string/char-literal contents out of `text`,
/// byte-for-byte, then split into per-line records with `#[cfg(test)]`
/// region tracking.
fn lex(text: &str) -> Vec<LineInfo> {
    let bytes = text.as_bytes();
    let mut code = vec![b' '; bytes.len()];

    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(usize),
        Chr,
    }
    let mut st = St::Code;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        match st {
            St::Code => {
                if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
                    st = St::Line;
                    i += 2;
                    continue;
                }
                if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    st = St::Block(1);
                    i += 2;
                    continue;
                }
                if let Some((hashes, skip)) = raw_str_start(bytes, i) {
                    code[i..i + skip].copy_from_slice(&bytes[i..i + skip]);
                    st = St::RawStr(hashes);
                    i += skip;
                    continue;
                }
                if b == b'"' {
                    code[i] = b'"';
                    st = St::Str;
                    i += 1;
                    continue;
                }
                if b == b'b' && bytes.get(i + 1) == Some(&b'"') {
                    code[i] = b'b';
                    code[i + 1] = b'"';
                    st = St::Str;
                    i += 2;
                    continue;
                }
                if b == b'\'' {
                    // char literal vs lifetime: 'x' / '\..' open a
                    // literal, 'ident without a closing quote is a
                    // lifetime and stays code
                    let next = bytes.get(i + 1).copied();
                    let is_char = match next {
                        Some(b'\\') => true,
                        Some(c) if c != b'\'' => bytes.get(i + 2) == Some(&b'\''),
                        _ => false,
                    };
                    if is_char {
                        code[i] = b'\'';
                        st = St::Chr;
                        i += 1;
                        continue;
                    }
                }
                code[i] = if b.is_ascii() { b } else { b' ' };
                i += 1;
            }
            St::Line => {
                if b == b'\n' {
                    st = St::Code;
                }
                i += 1;
            }
            St::Block(d) => {
                if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    st = St::Block(d + 1);
                    i += 2;
                } else if b == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => {
                if b == b'\\' {
                    i += 2;
                } else if b == b'"' {
                    code[i] = b'"';
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                let tail = &bytes[i + 1..];
                let closed = b == b'"'
                    && tail.len() >= hashes
                    && tail[..hashes].iter().all(|&h| h == b'#');
                if closed {
                    st = St::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
            St::Chr => {
                if b == b'\\' {
                    i += 2;
                } else if b == b'\'' || b == b'\n' {
                    if b == b'\'' {
                        code[i] = b'\'';
                    }
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            code[i] = b'\n';
        }
    }
    let code_text = String::from_utf8_lossy(&code).into_owned();

    // cfg(test) regions: an attribute arms `pending`; the next `{` at
    // the same brace depth opens a test region, a `;` there cancels it
    // (brace-less item).
    let mut lines = Vec::new();
    let mut depth: i64 = 0;
    let mut pending: Option<i64> = None;
    let mut active: Vec<i64> = Vec::new();
    for (raw, code) in text.lines().zip(code_text.lines()) {
        let started_in_test = !active.is_empty();
        let cb = code.as_bytes();
        let mut p = 0;
        while p < cb.len() {
            if code[p..].starts_with("#[cfg(test)]") {
                pending = Some(depth);
                p += "#[cfg(test)]".len();
                continue;
            }
            match cb[p] {
                b'{' => {
                    if pending == Some(depth) {
                        pending = None;
                        active.push(depth);
                    }
                    depth += 1;
                }
                b'}' => {
                    depth -= 1;
                    if active.last() == Some(&depth) {
                        active.pop();
                    }
                }
                b';' => {
                    if pending == Some(depth) {
                        pending = None;
                    }
                }
                _ => {}
            }
            p += 1;
        }
        lines.push(LineInfo {
            raw: raw.to_string(),
            code: code.to_string(),
            in_test: started_in_test || !active.is_empty(),
        });
    }
    lines
}

/// Byte offsets of word-boundary occurrences of `word` in `hay`.
fn word_hits(hay: &str, word: &str) -> Vec<usize> {
    let hb = hay.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(word) {
        let at = from + rel;
        let before_ok = at == 0 || !is_ident_byte(hb[at - 1]);
        let end = at + word.len();
        let after_ok = end >= hb.len() || !is_ident_byte(hb[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len();
    }
    out
}

fn push_diag(
    out: &mut Vec<Diagnostic>,
    rule: Rule,
    path: &str,
    line: usize,
    message: &str,
    text: &str,
) {
    out.push(Diagnostic {
        rule,
        path: path.to_string(),
        line,
        message: message.to_string(),
        text: text.trim().to_string(),
    });
}

/// L2 helper: the comment block immediately above line `i` (skipping
/// attribute lines) must contain a line starting `// SAFETY:`.
fn has_safety_comment(lines: &[LineInfo], i: usize) -> bool {
    let mut j = i;
    loop {
        if j == 0 {
            return false;
        }
        j -= 1;
        let code = lines[j].code.trim();
        if code.starts_with("#[") || code.starts_with("#![") {
            continue; // attribute between the comment and the keyword
        }
        if !code.is_empty() {
            return false; // real code directly above
        }
        // blank in code: either a comment line or truly empty
        if !lines[j].raw.trim_start().starts_with("//") {
            return false;
        }
        // walk the contiguous comment block upward
        let mut k = j + 1;
        while k > 0 && lines[k - 1].raw.trim_start().starts_with("//") {
            if lines[k - 1].raw.trim_start().starts_with("// SAFETY:") {
                return true;
            }
            k -= 1;
        }
        return false;
    }
}

/// True when `tok` is exactly an f32/f64 literal (`0.0`, `1.`, `2.5e-3`,
/// `1.0f32`) with nothing trailing — `0.0f32.to_bits` is *not* one.
fn is_float_literal(tok: &str) -> bool {
    let b = tok.as_bytes();
    let mut i = 0;
    let mut digits = false;
    while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
        digits |= b[i].is_ascii_digit();
        i += 1;
    }
    if !digits {
        return false;
    }
    let mut float = false;
    if i < b.len() && b[i] == b'.' {
        float = true;
        i += 1;
        while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
            i += 1;
        }
    }
    if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
        let mut j = i + 1;
        if j < b.len() && (b[j] == b'+' || b[j] == b'-') {
            j += 1;
        }
        if j < b.len() && b[j].is_ascii_digit() {
            float = true;
            i = j;
            while i < b.len() && b[i].is_ascii_digit() {
                i += 1;
            }
        }
    }
    if tok[i..].starts_with("f32") || tok[i..].starts_with("f64") {
        float = true;
        i += 3;
    }
    float && i == b.len()
}

/// The token (ident/number chars plus `.`) ending at byte `end` of
/// `line` (exclusive), skipping trailing spaces.
fn token_before(line: &str, end: usize) -> &str {
    let b = line.as_bytes();
    let mut e = end;
    while e > 0 && b[e - 1] == b' ' {
        e -= 1;
    }
    let mut s = e;
    while s > 0 && (is_ident_byte(b[s - 1]) || b[s - 1] == b'.') {
        s -= 1;
    }
    &line[s..e]
}

/// The token starting at byte `start` of `line`, skipping leading
/// spaces.
fn token_after(line: &str, start: usize) -> &str {
    let b = line.as_bytes();
    let mut s = start;
    while s < b.len() && b[s] == b' ' {
        s += 1;
    }
    let mut e = s;
    while e < b.len() && (is_ident_byte(b[e]) || b[e] == b'.') {
        e += 1;
    }
    &line[s..e]
}

fn in_no_panic_zone(vpath: &str) -> bool {
    vpath.starts_with(NO_PANIC_DIR) || NO_PANIC_FILES.contains(&vpath)
}

/// All per-file rules over one lexed source file.
fn analyze_file(vpath: &str, text: &str) -> Vec<Diagnostic> {
    let lines = lex(text);
    let mut out = Vec::new();

    for (idx, li) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = li.code.as_str();

        // L1: env reads stay inside util/env.
        if vpath != ENV_HOME && code.contains("env::var") {
            push_diag(
                &mut out,
                Rule::L1,
                vpath,
                lineno,
                "process env read outside util/env (route through util::env::resolve)",
                &li.raw,
            );
        }

        // L2: every `unsafe` needs an adjacent SAFETY comment.
        if !word_hits(code, "unsafe").is_empty() && !has_safety_comment(&lines, idx) {
            push_diag(
                &mut out,
                Rule::L2,
                vpath,
                lineno,
                "`unsafe` without an immediately preceding `// SAFETY:` comment",
                &li.raw,
            );
        }

        // L3: panicking calls in fault-facing modules.
        if in_no_panic_zone(vpath) && !li.in_test {
            if code.contains(".unwrap()") {
                push_diag(
                    &mut out,
                    Rule::L3,
                    vpath,
                    lineno,
                    "`.unwrap()` on a fault-facing path (convert to a typed error)",
                    &li.raw,
                );
            }
            let mut from = 0;
            while let Some(rel) = code[from..].find(".expect(") {
                let at = from + rel;
                if !li.raw[at..].starts_with(".expect(\"invariant: ") {
                    push_diag(
                        &mut out,
                        Rule::L3,
                        vpath,
                        lineno,
                        "`.expect(..)` message must start with \"invariant: \" on a \
                         fault-facing path",
                        &li.raw,
                    );
                }
                from = at + ".expect(".len();
            }
            let cb = code.as_bytes();
            for (p, &b) in cb.iter().enumerate() {
                if b != b'[' || p == 0 {
                    continue;
                }
                let prev = cb[p - 1];
                if !(is_ident_byte(prev) || prev == b')' || prev == b']') {
                    continue;
                }
                if let Some(close) = code[p + 1..].find(']') {
                    let inner = code[p + 1..p + 1 + close].trim();
                    if !inner.is_empty()
                        && inner.bytes().all(|c| c.is_ascii_digit() || c == b'_')
                    {
                        push_diag(
                            &mut out,
                            Rule::L3,
                            vpath,
                            lineno,
                            "constant slice index on a fault-facing path (can panic)",
                            &li.raw,
                        );
                    }
                }
            }
        }

        // L4: clock reads stay inside util/timer.
        if vpath != TIMER_HOME
            && (!word_hits(code, "Instant").is_empty()
                || !word_hits(code, "SystemTime").is_empty())
        {
            push_diag(
                &mut out,
                Rule::L4,
                vpath,
                lineno,
                "direct clock type outside util/timer (use timer::Stopwatch / timer::Deadline)",
                &li.raw,
            );
        }

        // L6: bare float (in)equality against a literal.
        if vpath != FLOAT_HOME {
            for op in ["==", "!="] {
                let mut from = 0;
                while let Some(rel) = code[from..].find(op) {
                    let at = from + rel;
                    from = at + op.len();
                    // skip `..=`, `=>`, `<=`, `>=` neighborhoods: the
                    // two-byte ops here are exact, but `!` of `!=` must
                    // not be the `=` of a preceding op
                    if at > 0 && matches!(code.as_bytes()[at - 1], b'=' | b'!' | b'<' | b'>') {
                        continue;
                    }
                    if code.as_bytes().get(at + 2) == Some(&b'=') {
                        continue;
                    }
                    let lhs = token_before(code, at);
                    let rhs = token_after(code, at + 2);
                    if is_float_literal(lhs) || is_float_literal(rhs) {
                        push_diag(
                            &mut out,
                            Rule::L6,
                            vpath,
                            lineno,
                            "bare float ==/!= (use util::float helpers or to_bits())",
                            &li.raw,
                        );
                    }
                }
            }
        }
    }

    // L5: the cat category partition, only in files declaring the module.
    out.extend(rule_l5(vpath, &lines));
    out
}

/// Keep only all-caps identifiers — the category const names.
fn push_member(members: &mut Vec<(String, usize)>, tok: &str, line: usize) {
    let caps = !tok.is_empty()
        && tok
            .bytes()
            .all(|b| b.is_ascii_uppercase() || b == b'_' || b.is_ascii_digit());
    if caps {
        members.push((tok.to_string(), line));
    }
}

/// L5: every `pub const NAME: &str` inside `pub mod cat` must appear in
/// `IN_PHASE_SUM` or `OUT_OF_PHASE_SUM`; unknown names in the arrays
/// are flagged too.
fn rule_l5(vpath: &str, lines: &[LineInfo]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let Some(open) = lines.iter().position(|l| l.code.contains("pub mod cat")) else {
        return out;
    };
    // find the module's closing line by brace depth
    let mut depth = 0i64;
    let mut end = lines.len();
    'outer: for (idx, li) in lines.iter().enumerate().skip(open) {
        for b in li.code.bytes() {
            match b {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = idx + 1;
                        break 'outer;
                    }
                }
                _ => {}
            }
        }
    }
    let region = &lines[open..end];

    let mut consts: Vec<(String, usize)> = Vec::new();
    for (idx, li) in region.iter().enumerate() {
        let t = li.code.trim();
        if let Some(rest) = t.strip_prefix("pub const ") {
            if let Some(colon) = rest.find(':') {
                let name = rest[..colon].trim().to_string();
                if rest[colon..].starts_with(": &str") {
                    consts.push((name, open + idx + 1));
                }
            }
        }
    }

    let mut members: Vec<(String, usize)> = Vec::new();
    for array in ["IN_PHASE_SUM", "OUT_OF_PHASE_SUM"] {
        let Some(decl) = region
            .iter()
            .position(|l| l.code.contains(array) && l.code.contains("pub const"))
        else {
            continue;
        };
        // collect uppercase identifiers between the initializer's `[`
        // (the first one after `=` — the type's `&[&str]` bracket sits
        // before it) and the matching `]`
        let mut after_eq = false;
        let mut in_init = false;
        'array: for (idx, li) in region.iter().enumerate().skip(decl) {
            let mut tok_start: Option<usize> = None;
            for (p, b) in li.code.bytes().enumerate() {
                let ident = is_ident_byte(b);
                if in_init {
                    match (ident, tok_start) {
                        (true, None) => tok_start = Some(p),
                        (false, Some(s)) => {
                            push_member(&mut members, &li.code[s..p], open + idx + 1);
                            tok_start = None;
                        }
                        _ => {}
                    }
                }
                if !ident {
                    match b {
                        b'=' if !in_init => after_eq = true,
                        b'[' if after_eq && !in_init => in_init = true,
                        b']' if in_init => {
                            break 'array;
                        }
                        _ => {}
                    }
                }
            }
            if let (true, Some(s)) = (in_init, tok_start) {
                push_member(&mut members, &li.code[s..], open + idx + 1);
            }
        }
    }
    if members.is_empty() && !consts.is_empty() {
        let (_, line) = consts[0];
        push_diag(
            &mut out,
            Rule::L5,
            vpath,
            line,
            "cat module declares categories but no IN_PHASE_SUM / OUT_OF_PHASE_SUM partition",
            &lines[line - 1].raw,
        );
        return out;
    }
    for (name, line) in &consts {
        if !members.iter().any(|(m, _)| m == name) {
            push_diag(
                &mut out,
                Rule::L5,
                vpath,
                *line,
                "category missing from cat::IN_PHASE_SUM / cat::OUT_OF_PHASE_SUM",
                &lines[line - 1].raw,
            );
        }
    }
    for (name, line) in &members {
        if !consts.iter().any(|(c, _)| c == name) {
            push_diag(
                &mut out,
                Rule::L5,
                vpath,
                *line,
                "phase-sum array names an undeclared category",
                &lines[line - 1].raw,
            );
        }
    }
    out
}

/// One allowlist entry: `RULE|path|needle|justification`.
struct AllowEntry {
    rule: Rule,
    path: String,
    needle: String,
    line: usize,
    used: bool,
}

fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.splitn(4, '|').collect();
        if parts.len() != 4 {
            return Err(format!(
                "allowlist line {}: expected RULE|path|needle|justification",
                idx + 1
            ));
        }
        let rule = Rule::parse(parts[0].trim())
            .ok_or_else(|| format!("allowlist line {}: unknown rule {:?}", idx + 1, parts[0]))?;
        if !rule.allowlistable() {
            return Err(format!(
                "allowlist line {}: rule {} is not allowlistable (fix the site instead)",
                idx + 1,
                rule.id()
            ));
        }
        if parts[3].trim().is_empty() {
            return Err(format!("allowlist line {}: empty justification", idx + 1));
        }
        out.push(AllowEntry {
            rule,
            path: parts[1].trim().to_string(),
            needle: parts[2].trim().to_string(),
            line: idx + 1,
            used: false,
        });
    }
    Ok(out)
}

/// Split diagnostics into (suppressed, remaining), marking entries used.
fn apply_allowlist(
    diags: Vec<Diagnostic>,
    entries: &mut [AllowEntry],
) -> (Vec<Diagnostic>, Vec<Diagnostic>) {
    let mut suppressed = Vec::new();
    let mut remaining = Vec::new();
    for d in diags {
        let hit = entries.iter_mut().find(|e| {
            e.rule == d.rule && e.path == d.path && d.text.contains(&e.needle)
        });
        match hit {
            Some(e) => {
                e.used = true;
                suppressed.push(d);
            }
            None => remaining.push(d),
        }
    }
    (suppressed, remaining)
}

fn collect_files(root: &Path) -> Result<Vec<(String, PathBuf)>, String> {
    let mut out = Vec::new();
    for wr in WALK_ROOTS {
        let dir = root.join(wr);
        if dir.is_dir() {
            walk_dir(&dir, root, &mut out)?;
        }
    }
    if out.is_empty() && root.is_dir() {
        // not a workspace root: lint the directory itself, so the binary
        // can be pointed straight at a snippet directory (e.g. the
        // bad-fixture set, which must exit nonzero)
        walk_dir(root, root, &mut out)?;
    }
    out.sort();
    Ok(out)
}

fn walk_dir(
    dir: &Path,
    root: &Path,
    out: &mut Vec<(String, PathBuf)>,
) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            walk_dir(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| e.to_string())?
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

fn run(root: &Path) -> Result<usize, String> {
    let files = collect_files(root)?;
    if files.is_empty() {
        return Err(format!("no .rs files found under {}", root.display()));
    }
    let mut diags = Vec::new();
    for (vpath, path) in &files {
        let text =
            fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        diags.extend(analyze_file(vpath, &text));
    }

    let allow_path = root.join("xtask/tucker-lint/allowlist.txt");
    let allow_text = fs::read_to_string(&allow_path).unwrap_or_default();
    let mut entries = parse_allowlist(&allow_text)?;
    let (suppressed, remaining) = apply_allowlist(diags, &mut entries);

    let mut problems = 0;
    for d in &remaining {
        eprintln!("{d}");
        problems += 1;
    }
    for e in entries.iter().filter(|e| !e.used) {
        eprintln!(
            "xtask/tucker-lint/allowlist.txt:{}: stale allowlist entry ([{}] {} {:?}) — \
             the site is gone, delete the entry",
            e.line,
            e.rule.id(),
            e.path,
            e.needle
        );
        problems += 1;
    }
    eprintln!(
        "tucker-lint: {} file(s), {} diagnostic(s) ({} allowlisted), {} problem(s)",
        files.len(),
        remaining.len() + suppressed.len(),
        suppressed.len(),
        problems
    );
    Ok(problems)
}

fn main() -> ExitCode {
    let root = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    match run(Path::new(&root)) {
        Ok(0) => ExitCode::SUCCESS,
        Ok(_) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("tucker-lint: {e}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        fs::read_to_string(&p).unwrap_or_else(|e| panic!("fixture {}: {e}", p.display()))
    }

    fn diag_lines(vpath: &str, text: &str, rule: Rule) -> Vec<usize> {
        analyze_file(vpath, text)
            .into_iter()
            .filter(|d| d.rule == rule)
            .map(|d| d.line)
            .collect()
    }

    #[test]
    fn lexer_blanks_comments_and_strings() {
        let lines = lex("let a = \"x == 0.0\"; // y == 1.0\nlet b = 'c';\n");
        assert!(!lines[0].code.contains("0.0"), "{}", lines[0].code);
        assert!(lines[0].code.contains("let a ="));
        assert_eq!(lines[1].code, "let b = ' ';");
    }

    #[test]
    fn lexer_handles_lifetimes_and_block_comments() {
        let lines = lex("fn f<'a>(x: &'a str) {}\n/* a == 1.0\n   b == 2.0 */\nlet y = 1;\n");
        assert!(lines[0].code.contains("fn f<'a>"));
        assert!(!lines[1].code.contains("1.0"));
        assert!(!lines[2].code.contains("2.0"));
        assert!(lines[3].code.contains("let y = 1;"));
    }

    #[test]
    fn cfg_test_region_tracking() {
        let src = "fn live() { a.unwrap(); }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn t() { b.unwrap(); }\n\
                   }\n\
                   fn live2() { c.unwrap(); }\n";
        let lines = lex(src);
        assert!(!lines[0].in_test);
        assert!(lines[3].in_test);
        assert!(!lines[5].in_test);
    }

    #[test]
    fn l1_bad_fixture_flagged_good_passes() {
        let bad = fixture("bad/l1.rs");
        assert_eq!(diag_lines("rust/src/dist/foo.rs", &bad, Rule::L1), vec![4]);
        let good = fixture("good/l1.rs");
        assert!(diag_lines("rust/src/dist/foo.rs", &good, Rule::L1).is_empty());
        // util/env.rs itself is exempt
        assert!(diag_lines(ENV_HOME, &bad, Rule::L1).is_empty());
    }

    #[test]
    fn l2_bad_fixture_flagged_good_passes() {
        let bad = fixture("bad/l2.rs");
        assert_eq!(diag_lines("rust/src/hooi/foo.rs", &bad, Rule::L2), vec![5, 10]);
        let good = fixture("good/l2.rs");
        assert!(diag_lines("rust/src/hooi/foo.rs", &good, Rule::L2).is_empty());
    }

    #[test]
    fn l3_bad_fixture_flagged_good_passes() {
        let bad = fixture("bad/l3.rs");
        assert_eq!(
            diag_lines("rust/src/serve/foo.rs", &bad, Rule::L3),
            vec![4, 6, 8]
        );
        // outside the fault-facing zone the same source is clean
        assert!(diag_lines("rust/src/hooi/foo.rs", &bad, Rule::L3).is_empty());
        let good = fixture("good/l3.rs");
        assert!(diag_lines("rust/src/serve/foo.rs", &good, Rule::L3).is_empty());
    }

    #[test]
    fn l4_bad_fixture_flagged_good_passes() {
        let bad = fixture("bad/l4.rs");
        assert_eq!(diag_lines("rust/src/sched/foo.rs", &bad, Rule::L4), vec![4, 5]);
        assert!(diag_lines(TIMER_HOME, &bad, Rule::L4).is_empty());
        let good = fixture("good/l4.rs");
        assert!(diag_lines("rust/src/sched/foo.rs", &good, Rule::L4).is_empty());
    }

    #[test]
    fn l5_bad_fixture_flagged_good_passes() {
        let bad = fixture("bad/l5.rs");
        assert_eq!(diag_lines("rust/src/dist/cluster.rs", &bad, Rule::L5), vec![6]);
        let good = fixture("good/l5.rs");
        assert!(diag_lines("rust/src/dist/cluster.rs", &good, Rule::L5).is_empty());
    }

    #[test]
    fn l6_bad_fixture_flagged_good_passes() {
        let bad = fixture("bad/l6.rs");
        assert_eq!(
            diag_lines("rust/src/linalg/foo.rs", &bad, Rule::L6),
            vec![3, 5]
        );
        assert!(diag_lines(FLOAT_HOME, &bad, Rule::L6).is_empty());
        let good = fixture("good/l6.rs");
        assert!(diag_lines("rust/src/linalg/foo.rs", &good, Rule::L6).is_empty());
    }

    #[test]
    fn float_literal_recognizer() {
        for yes in ["0.0", "1.", "2.5e-3", "1.0f32", "3f64", "1_000.5"] {
            assert!(is_float_literal(yes), "{yes}");
        }
        for no in ["0", "0x3f", "x", "0.0f32.to_bits", "f32", ""] {
            assert!(!is_float_literal(no), "{no}");
        }
    }

    #[test]
    fn allowlist_suppresses_and_detects_stale() {
        let bad = fixture("bad/l3.rs");
        let diags = analyze_file("rust/src/serve/foo.rs", &bad);
        let mut entries = parse_allowlist(
            "L3|rust/src/serve/foo.rs|.unwrap()|fixture justification\n\
             L3|rust/src/serve/foo.rs|no_such_site|stale entry\n",
        )
        .unwrap();
        let (suppressed, remaining) = apply_allowlist(diags, &mut entries);
        assert_eq!(suppressed.len(), 1);
        assert!(!remaining.is_empty());
        assert!(entries[0].used);
        assert!(!entries[1].used, "second entry must be stale");
    }

    #[test]
    fn allowlist_rejects_l1_l2_and_bad_shape() {
        assert!(parse_allowlist("L1|a|b|c\n").is_err());
        assert!(parse_allowlist("L2|a|b|c\n").is_err());
        assert!(parse_allowlist("L3|a|b\n").is_err());
        assert!(parse_allowlist("L3|a|b|\n").is_err());
        assert!(parse_allowlist("# comment\n\nL3|a|b|why\n").is_ok());
    }

    #[test]
    fn expect_invariant_convention_allowed() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   x.expect(\"invariant: caller checked\")\n\
                   }\n";
        assert!(diag_lines("rust/src/serve/foo.rs", src, Rule::L3).is_empty());
    }

    #[test]
    fn repo_self_scan_is_clean() {
        // The crate ships inside the repo it lints: running the full
        // pass over the real tree (workspace root = two levels up) must
        // produce zero problems. This is the same invocation CI runs.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let problems = run(&root).expect("lint run");
        assert_eq!(problems, 0, "repo must lint clean (see stderr)");
    }
}
