// L2 bad fixture: unsafe without an adjacent SAFETY comment.

fn lane_sum(p: *const f32) -> f32 {
    // adds the first two lanes
    unsafe { *p + *p.add(1) }
}

struct Raw(*mut u8);

unsafe impl Send for Raw {}
