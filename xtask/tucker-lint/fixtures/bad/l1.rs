// L1 bad fixture: raw env read outside util/env.

fn quick() -> Option<String> {
    std::env::var("TUCKER_PLAN").ok()
}
