// L3 bad fixture: panicking calls on a fault-facing path.

fn serve(values: &[f32], head: Option<f32>) -> f32 {
    let first = head.unwrap();
    let second = values.iter().copied().reduce(f32::max);
    let third = second.expect("nonempty");
    // constant indexing can panic on short slices
    first + third + values[0]
}
