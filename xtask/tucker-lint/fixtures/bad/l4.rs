// L4 bad fixture: direct clock reads outside util/timer.

fn elapsed_secs() -> f64 {
    let t0 = std::time::Instant::now();
    let _wall = std::time::SystemTime::now();
    t0.elapsed().as_secs_f64()
}
