// L5 bad fixture: a category outside the phase-sum partition.

pub mod cat {
    pub const TTM: &str = "TTM";
    pub const SVD: &str = "SVD";
    pub const CORE: &str = "CORE";

    pub const IN_PHASE_SUM: &[&str] = &[TTM, SVD];
    pub const OUT_OF_PHASE_SUM: &[&str] = &[];
}
