// L6 bad fixture: bare float equality against literals.

fn is_zero(x: f32) -> bool { x == 0.0 }

fn not_one(y: f64) -> bool { 1.0 != y }
