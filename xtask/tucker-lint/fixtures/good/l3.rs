// L3 good fixture: typed errors and invariant-named expects.

fn serve(values: &[f32], head: Option<f32>) -> Result<f32, String> {
    let first = head.ok_or("no head value")?;
    let tail = values.first().copied().unwrap_or(0.0);
    let anchor = head.expect("invariant: checked by ok_or above");
    Ok(first + tail + anchor)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_fine_in_tests() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
    }
}
