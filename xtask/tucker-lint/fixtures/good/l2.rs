// L2 good fixture: every unsafe keyword carries a SAFETY comment.

fn lane_sum(p: *const f32) -> f32 {
    // SAFETY: caller guarantees p points at >= 2 readable f32 lanes.
    unsafe { *p + *p.add(1) }
}

struct Raw(*mut u8);

// SAFETY: Raw owns its allocation exclusively; moving it between
// threads transfers ownership of the pointer with it.
unsafe impl Send for Raw {}

#[allow(dead_code)]
// SAFETY: thin wrapper over lane_sum; same contract as above.
unsafe fn lanes(p: *const f32) -> f32 {
    lane_sum(p)
}
