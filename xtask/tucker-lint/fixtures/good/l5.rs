// L5 good fixture: the partition covers every category.

pub mod cat {
    pub const TTM: &str = "TTM";
    pub const SVD: &str = "SVD";
    pub const CORE: &str = "CORE";

    pub const IN_PHASE_SUM: &[&str] = &[TTM, SVD];
    pub const OUT_OF_PHASE_SUM: &[&str] = &[CORE];
}
