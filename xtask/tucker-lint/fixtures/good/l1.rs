// L1 good fixture: options resolved through the util::env facade.

fn quick(opt: Option<usize>) -> usize {
    crate::util::env::resolve(opt, "TUCKER_P", 4)
}
