// L6 good fixture: exact comparisons through bits or helpers.

fn is_zero(x: f32) -> bool { x.to_bits() == 0.0f32.to_bits() }

fn within(y: f64) -> bool { (y - 1.0).abs() < 1e-12 }
