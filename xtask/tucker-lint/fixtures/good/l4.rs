// L4 good fixture: timing through the util::timer facade.

fn elapsed_secs() -> f64 {
    let sw = crate::util::timer::Stopwatch::start();
    sw.seconds()
}
