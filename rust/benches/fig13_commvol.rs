//! Fig 13: communication volume breakup (SVD oracle vs factor-matrix
//! transfer). Multi-policy schemes pay FM volume, uni-policy pay SVD.
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig13;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig13", &cfg);
    let t = fig13(&cfg);
    t.print();
    let _ = t.save_csv("fig13_commvol");
}
