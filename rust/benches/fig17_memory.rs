//! Fig 17: average memory per rank with tensor/penultimate/factor
//! breakdown — multi-policy schemes store N tensor copies but smaller
//! penultimate matrices.
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig17;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig17", &cfg);
    let t = fig17(&cfg);
    t.print();
    let _ = t.save_csv("fig17_memory");
}
