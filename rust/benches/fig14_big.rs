//! Fig 14: big tensors (amazon/patents/reddit analogues) — lightweight
//! schemes only (HyperG cannot partition them, as in the paper).
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig14;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig14", &cfg);
    let engine = common::bench_engine();
    let t = fig14(&cfg, &engine);
    t.print();
    let _ = t.save_csv("fig14_big");
}
