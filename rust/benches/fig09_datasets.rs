//! Fig 9: the dataset table (synthetic analogues + paper nnz column).
#[path = "common.rs"]
mod common;

fn main() {
    let t = tucker_lite::tensor::datasets::fig9_table();
    t.print();
    if let Ok(p) = t.save_csv("fig09_datasets") {
        eprintln!("# csv: {}", p.display());
    }
}
