//! Serving-path throughput: queries/sec for point reconstruction over a
//! synthetic Tucker model, three ways —
//!
//!   - scalar loop: one `reconstruct_at` per query (the oracle recomputes
//!     the K_{N−1}×K̂ core contraction for every query);
//!   - batched scalar: `reconstruct_batch` under `TUCKER_KERNEL=scalar` —
//!     the slice-grouped engine, amortizing the core contraction across
//!     every query in the same mode-(N−1) slice;
//!   - batched SIMD: the same engine through the detected lane-blocked
//!     microkernel (avx2 / neon / portable).
//!
//! All three produce bit-identical results (asserted here, and
//! property-tested in tests/serve.rs); the acceptance bar is batched ≥4×
//! the scalar loop. Emits BENCH_serve.json (and results/serve_bench.csv).

#[path = "common.rs"]
mod common;

use tucker_lite::util::timer::Stopwatch;

use tucker_lite::hooi::Kernel;
use tucker_lite::linalg::Mat;
use tucker_lite::serve::{DecompositionSnapshot, QueryBatch};
use tucker_lite::util::json::Json;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_si, Table};

fn random_model(rng: &mut Rng, dims: &[usize], ks: &[usize]) -> DecompositionSnapshot {
    let factors: Vec<Mat> = dims
        .iter()
        .zip(ks)
        .map(|(&l, &k)| {
            let mut m = Mat::zeros(l, k);
            for v in m.data.iter_mut() {
                *v = rng.f32() * 2.0 - 1.0;
            }
            m
        })
        .collect();
    let n = ks.len();
    let kh: usize = ks[..n - 1].iter().product();
    let mut core = Mat::zeros(ks[n - 1], kh);
    for v in core.data.iter_mut() {
        *v = rng.f32() * 2.0 - 1.0;
    }
    DecompositionSnapshot::from_parts(factors, core, vec![1.0; ks[n - 1]], 0.9, 1, 1)
}

fn time_qps(queries: usize, reps: usize, f: &mut dyn FnMut()) -> f64 {
    f(); // warmup
    let t0 = Stopwatch::start();
    for _ in 0..reps {
        f();
    }
    (queries * reps) as f64 / t0.seconds()
}

fn main() {
    let quick = common::bench_quick();
    // last-mode extent deliberately « query count: real serving load
    // concentrates many queries per slice, which is exactly what the
    // batch engine amortizes
    let (dims, ks, nq, reps) = if quick {
        (vec![120usize, 80, 16], vec![8usize, 6, 8], 4_000usize, 3usize)
    } else {
        (vec![1200, 800, 48], vec![12, 12, 16], 24_000, 5)
    };
    let mut rng = Rng::new(0x5E2E);
    let snap = random_model(&mut rng, &dims, &ks);
    let mut batch = QueryBatch::new();
    for _ in 0..nq {
        let idx: Vec<usize> = dims.iter().map(|&l| rng.usize_below(l)).collect();
        batch.add(&idx);
    }
    let simd = Kernel::detect();
    eprintln!(
        "# serve_bench: dims={dims:?} K={ks:?} queries={nq} reps={reps} simd={}",
        simd.name()
    );

    // bit-exactness first: the perf numbers only count if every path
    // returns the same bits
    let oracle: Vec<f32> =
        batch.queries().iter().map(|q| snap.reconstruct_at(q).unwrap()).collect();
    for kernel in [Kernel::Scalar, simd] {
        let got = snap.reconstruct_batch_with(&batch, kernel).unwrap();
        for (a, b) in got.iter().zip(&oracle) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "batched ({}) diverged from the scalar oracle",
                kernel.name()
            );
        }
    }

    let scalar_loop = time_qps(nq, reps, &mut || {
        for q in batch.queries() {
            std::hint::black_box(snap.reconstruct_at(q).unwrap());
        }
    });
    let batched_scalar = time_qps(nq, reps, &mut || {
        std::hint::black_box(snap.reconstruct_batch_with(&batch, Kernel::Scalar).unwrap());
    });
    let batched_simd = time_qps(nq, reps, &mut || {
        std::hint::black_box(snap.reconstruct_batch_with(&batch, simd).unwrap());
    });

    let speedup_batched = batched_scalar / scalar_loop;
    let speedup_simd = batched_simd / scalar_loop;
    let mut t = Table::new(
        "serve_bench: point-query throughput",
        &["path", "kernel", "queries/sec", "vs scalar loop"],
    );
    t.row(vec!["scalar loop".into(), "scalar".into(), fmt_si(scalar_loop), "1.00x".into()]);
    t.row(vec![
        "batched".into(),
        "scalar".into(),
        fmt_si(batched_scalar),
        format!("{speedup_batched:.2}x"),
    ]);
    t.row(vec![
        "batched".into(),
        simd.name().into(),
        fmt_si(batched_simd),
        format!("{speedup_simd:.2}x"),
    ]);
    t.print();
    if let Ok(p) = t.save_csv("serve_bench") {
        eprintln!("# csv: {}", p.display());
    }

    let mut qps = Json::obj();
    qps.set("scalar_loop", Json::Num(scalar_loop))
        .set("batched_scalar", Json::Num(batched_scalar))
        .set("batched_simd", Json::Num(batched_simd));
    let mut j = Json::obj();
    j.set("bench", Json::Str("serve".into()))
        .set("quick", Json::Bool(quick))
        .set("dims", Json::Arr(dims.iter().map(|&d| Json::Num(d as f64)).collect()))
        .set("core", Json::Arr(ks.iter().map(|&k| Json::Num(k as f64)).collect()))
        .set("queries", Json::Num(nq as f64))
        .set("simd_kernel", Json::Str(simd.name().into()))
        .set("qps", qps)
        .set("speedup_batched_vs_scalar_loop", Json::Num(speedup_batched))
        .set("speedup_simd_vs_scalar_loop", Json::Num(speedup_simd))
        .set("bit_exact", Json::Bool(true));
    std::fs::write("BENCH_serve.json", j.render()).expect("write BENCH_serve.json");
    eprintln!("# json: BENCH_serve.json");

    // the quick config is a CI smoke on noisy shared runners — hold it to
    // a softer bar than the full-size acceptance threshold
    let bar = if quick { 1.5 } else { 4.0 };
    assert!(
        speedup_batched >= bar,
        "batched engine must beat the scalar loop by ≥{bar}x, got {speedup_batched:.2}x"
    );
    println!("serve_bench OK");
}
