//! Fig 11: HOOI time breakup (TTM / SVD / communication) — computation
//! dominates; CoarseG better on SVD, MediumG/HyperG on TTM, Lite on both.
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig11;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig11", &cfg);
    let engine = common::bench_engine();
    let t = fig11(&cfg, &engine);
    t.print();
    let _ = t.save_csv("fig11_breakup");
}
