//! Ablation: the precompiled TTM plan layer, its lane-blocked SIMD
//! microkernels, the parallel rank executor, and the shared-CSF plan
//! layout (per-mode vs one tree per rank — section 6, mirrored to
//! results/BENCH_plan.json).
//!
//!   1. Plan + kernel ablation across K ∈ {5, 10, 16} for 3-D and 4-D:
//!      - naive: `assemble_local_z_fused` pays a row sort+dedup, one
//!        binary search per nonzero and a cold COO walk every invocation;
//!      - plan scalar: the PR 1 run-hoisted plan loops (`TUCKER_KERNEL=
//!        scalar`), the kernel-equivalence oracle;
//!      - plan tiled: the lane-blocked layout through the detected
//!        8-wide microkernel (avx2 / neon / portable — the column shows
//!        which one ran).
//!      The acceptance bar for the kernel layer is the `tiled vs scalar`
//!      column at K=16 (target ≥ 1.5x on the 3-D bench tensor).
//!   2. Executor scaling: the same 8-rank TTM phase through
//!      `SimCluster::phase_tasks` with the serial vs the scoped-thread
//!      parallel executor (wall-clock; the simulated makespan is
//!      reported too and must agree between the two).

#[path = "common.rs"]
mod common;

use tucker_lite::util::timer::Stopwatch;
use tucker_lite::coordinator::{
    KernelChoice, PlanChoice, SchemeChoice, TuckerSession, Workload,
};
use tucker_lite::dist::{cat, SimCluster};
use tucker_lite::hooi::{
    assemble_local_z_fused, prepare_modes, CoreRanks, Kernel, PlanWorkspace, TtmPlan,
};
use tucker_lite::linalg::{orthonormal_random, Mat};
use tucker_lite::tensor::{SparseTensor, TensorDelta};
use tucker_lite::util::json::Json;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_secs, Table};

fn time_it(reps: usize, f: &mut dyn FnMut()) -> f64 {
    f(); // warmup
    let t0 = Stopwatch::start();
    for _ in 0..reps {
        f();
    }
    t0.seconds() / reps as f64
}

fn assembly_case(
    table: &mut Table,
    label: &str,
    t: &SparseTensor,
    k: usize,
    tiled_kernel: Kernel,
    reps: usize,
) {
    let mut rng = Rng::new(11);
    let factors: Vec<Mat> = t
        .dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, &mut rng))
        .collect();
    let elems: Vec<u32> = (0..t.nnz() as u32).collect();

    let naive = time_it(reps, &mut || {
        let z = assemble_local_z_fused(t, 0, &elems, &factors);
        std::hint::black_box(z.rows.len());
    });

    let t0 = Stopwatch::start();
    let plan = TtmPlan::build(t, 0, &elems, k);
    let build = t0.seconds();

    let mut ws_scalar = PlanWorkspace::with_kernel(Kernel::Scalar);
    let scalar = time_it(reps, &mut || {
        let z = plan.assemble_fused(&factors, &mut ws_scalar);
        std::hint::black_box(z.rows.len());
        ws_scalar.recycle(z.z);
    });

    let mut ws_tiled = PlanWorkspace::with_kernel(tiled_kernel);
    let tiled = time_it(reps, &mut || {
        let z = plan.assemble_fused(&factors, &mut ws_tiled);
        std::hint::black_box(z.rows.len());
        ws_tiled.recycle(z.z);
    });

    // smoke equivalence so a broken dispatch arm fails the bench run too
    let zs = plan.assemble_fused(&factors, &mut ws_scalar);
    let zt = plan.assemble_fused(&factors, &mut ws_tiled);
    assert!(
        zs.z.max_abs_diff(&zt.z) < 1e-3,
        "{label}: tiled kernel diverged from the scalar oracle"
    );

    table.row(vec![
        label.into(),
        fmt_secs(naive),
        fmt_secs(scalar),
        fmt_secs(tiled),
        fmt_secs(build),
        format!("{:.2}x", scalar / tiled),
        format!("{:.2}x", naive / tiled),
    ]);
}

fn main() {
    let quick = common::bench_quick();
    let reps = if quick { 2 } else { 5 };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let tiled_kernel = Kernel::from_env().resolve();
    eprintln!(
        "# ablate_plan: reps={reps} host cores={cores} tiled kernel={}",
        tiled_kernel.name()
    );

    // --- 1. plan vs naive assembly, scalar vs tiled kernel ---
    let mut rng = Rng::new(3);
    let nnz3 = if quick { 15_000 } else { 150_000 };
    let nnz4 = if quick { 8_000 } else { 60_000 };
    let t3 = SparseTensor::random(vec![400, 300, 60], nnz3, &mut rng);
    let t4 = SparseTensor::random(vec![120, 80, 30, 12], nnz4, &mut rng);
    let mut t1 = Table::new(
        &format!(
            "ablate_plan — Z assembly, one full mode (3-D nnz={nnz3}, 4-D nnz={nnz4}, \
             tiled kernel={})",
            tiled_kernel.name()
        ),
        &[
            "config",
            "naive/inv",
            "plan scalar/inv",
            "plan tiled/inv",
            "plan build (once)",
            "tiled vs scalar",
            "tiled vs naive",
        ],
    );
    for k in [5usize, 10, 16] {
        assembly_case(&mut t1, &format!("3-D K={k}"), &t3, k, tiled_kernel, reps);
    }
    for k in [5usize, 10, 16] {
        assembly_case(&mut t1, &format!("4-D K={k}"), &t4, k, tiled_kernel, reps);
    }
    t1.print();
    let _ = t1.save_csv("ablate_plan_assembly");

    // --- 2. serial vs parallel rank executor on an 8-rank TTM phase ---
    let p = 8;
    let k = 10;
    let nnz = if quick { 40_000 } else { 400_000 };
    let t = SparseTensor::random(vec![600, 400, 80], nnz, &mut rng);
    let factors: Vec<Mat> = t
        .dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, &mut rng))
        .collect();
    let mut per_rank = vec![Vec::new(); p];
    for e in 0..t.nnz() as u32 {
        per_rank[rng.usize_below(p)].push(e);
    }
    let plans: Vec<TtmPlan> =
        per_rank.iter().map(|es| TtmPlan::build(&t, 0, es, k)).collect();

    let run_phase = |parallel: bool| -> (f64, f64) {
        let mut cluster = SimCluster::new(p).with_parallel(parallel);
        let mut workspaces: Vec<PlanWorkspace> =
            (0..p).map(|_| PlanWorkspace::new()).collect();
        let factors_ref = &factors;
        let one_round = |cluster: &mut SimCluster,
                         workspaces: &mut Vec<PlanWorkspace>| {
            let tasks: Vec<_> = plans
                .iter()
                .zip(workspaces.iter_mut())
                .map(|(plan, ws)| move || plan.assemble_fused(factors_ref, ws))
                .collect();
            let locals = cluster
                .phase_tasks(cat::TTM, tasks)
                .expect("no fault injector armed in this bench");
            for (ws, local) in workspaces.iter_mut().zip(locals) {
                ws.recycle(local.z);
            }
        };
        one_round(&mut cluster, &mut workspaces); // warmup
        let t0 = Stopwatch::start();
        for _ in 0..reps {
            one_round(&mut cluster, &mut workspaces);
        }
        let wall = t0.seconds() / reps as f64;
        // 1 warmup + reps rounds charged
        let sim = cluster.elapsed.get(cat::TTM) / (reps + 1) as f64;
        (wall, sim)
    };

    let (serial_wall, serial_sim) = run_phase(false);
    let (par_wall, par_sim) = run_phase(true);
    let mut t2 = Table::new(
        &format!(
            "ablate_plan — executor: P={p} rank TTM phase (nnz={nnz}, K={k}, {cores} cores)"
        ),
        &["executor", "wall/phase", "simulated makespan", "wall speedup"],
    );
    t2.row(vec![
        "serial".into(),
        fmt_secs(serial_wall),
        fmt_secs(serial_sim),
        "1.00x".into(),
    ]);
    t2.row(vec![
        "parallel (scoped threads)".into(),
        fmt_secs(par_wall),
        fmt_secs(par_sim),
        format!("{:.2}x", serial_wall / par_wall),
    ]);
    t2.print();
    let _ = t2.save_csv("ablate_plan_executor");

    // --- 3. session plan reuse: producing a fit-per-invocation trace
    // (1..=sweeps invocations). Without a session each point is a fresh
    // run (distribution + prepare_modes + all sweeps from scratch); one
    // TuckerSession pays prepare_modes once and rides the cached plans
    // through `decompose_more`, with bit-identical fits. ---
    let sweeps = if quick { 2 } else { 4 };
    let nnz = if quick { 30_000 } else { 200_000 };
    let t = SparseTensor::random(vec![500, 300, 70], nnz, &mut rng);
    let w = std::sync::Arc::new(Workload::from_tensor("ablate_session", t));
    let build_session = |w: std::sync::Arc<Workload>, invocations: usize| {
        TuckerSession::builder(w)
            .scheme(SchemeChoice::Lite)
            .ranks(p)
            .core(k)
            .invocations(invocations)
            .seed(5)
            .build()
            .expect("valid ablation session")
    };

    // fresh: one full run per trace point — the pre-session pattern
    let t0 = Stopwatch::start();
    let mut fresh_fit = 0.0;
    for inv in 1..=sweeps {
        fresh_fit = build_session(w.clone(), inv).decompose().fit();
    }
    let fresh_wall = t0.seconds();

    // reused: one session, one plan compilation, incremental refinement
    let t0 = Stopwatch::start();
    let mut session = build_session(w.clone(), 1);
    let mut d = session.decompose();
    for _ in 1..sweeps {
        d = session.decompose_more(1);
    }
    let reused_wall = t0.seconds();
    assert_eq!(session.plan_builds(), 1, "one compilation for all sweeps");
    assert!(
        (d.fit() - fresh_fit).abs() < 1e-6,
        "cached-plan refinement must match the fresh run: {} vs {}",
        d.fit(),
        fresh_fit
    );

    let mut t3 = Table::new(
        &format!(
            "ablate_plan — session plan reuse, fit trace over 1..={sweeps} \
             invocations (nnz={nnz}, P={p}, K={k})"
        ),
        &["strategy", "wall total", "prepare_modes runs", "speedup"],
    );
    t3.row(vec![
        "fresh run per trace point".into(),
        fmt_secs(fresh_wall),
        sweeps.to_string(),
        "1.00x".into(),
    ]);
    t3.row(vec![
        "one session + decompose_more".into(),
        fmt_secs(reused_wall),
        "1".into(),
        format!("{:.2}x", fresh_wall / reused_wall),
    ]);
    t3.print();
    let _ = t3.save_csv("ablate_plan_session");

    // --- 4. streaming ingest: incremental plan invalidation vs a full
    // re-prepare on the mutated tensor. The incremental path splices or
    // rebuilds only the dirty (mode, rank) plans; the baseline is what a
    // session without `ingest` would pay — `prepare_modes` over
    // everything, every delta. ---
    let nnz = if quick { 30_000 } else { 200_000 };
    let t = SparseTensor::random(vec![500, 300, 70], nnz, &mut rng);
    let mut session = TuckerSession::builder(Workload::from_tensor("ablate_ingest", t))
        .scheme(SchemeChoice::Lite)
        .ranks(p)
        .core(k)
        .seed(9)
        .build()
        .expect("valid ingest ablation session");
    let _ = session.decompose();
    let mut t4 = Table::new(
        &format!(
            "ablate_plan — streaming ingest: incremental invalidation vs full \
             re-prepare (nnz={nnz}, P={p}, K={k})"
        ),
        &[
            "appends/batch",
            "ingest (incremental)",
            "plans touched",
            "full prepare_modes",
            "speedup",
        ],
    );
    for batch in [16usize, 256, 4096] {
        let dims = session.workload().tensor.dims.clone();
        let mut delta = TensorDelta::new();
        for _ in 0..batch {
            let coord: Vec<u32> =
                dims.iter().map(|&l| rng.below(l as u64) as u32).collect();
            delta = delta.append(&coord, rng.f32() * 2.0 - 1.0);
        }
        let t0 = Stopwatch::start();
        let rep = session.ingest(&delta).expect("valid ablation delta");
        let ingest_secs = t0.seconds();
        // the full-rebuild baseline compiles every (mode, rank) plan of
        // the mutated tensor under the now-extended placement
        let w2 = Workload::from_tensor(
            "ablate_ingest_full",
            session.workload().tensor.clone(),
        );
        let t0 = Stopwatch::start();
        let modes = prepare_modes(
            &w2.tensor,
            &w2.idx,
            session.distribution(),
            &CoreRanks::Uniform(k),
        );
        let full_secs = t0.seconds();
        std::hint::black_box(modes.len());
        t4.row(vec![
            batch.to_string(),
            fmt_secs(ingest_secs),
            format!("{}/{}", rep.plans_touched(), rep.plan_count),
            fmt_secs(full_secs),
            format!("{:.2}x", full_secs / ingest_secs),
        ]);
    }
    t4.print();
    let _ = t4.save_csv("ablate_plan_ingest");

    // --- 5. streaming rebalance: migrating to a Lite re-plan via
    // PlacementPlan::diff (touching only the diffed (mode, rank) plans)
    // vs the naive full re-`prepare_modes` on the re-planned placement,
    // after a skewed delta. Also reports the §4 cost model's predicted
    // per-sweep savings against the observed simulated HOOI change. ---
    let nnz = if quick { 30_000 } else { 150_000 };
    let t = SparseTensor::random(vec![400, 250, 60], nnz, &mut rng);
    let mut session =
        TuckerSession::builder(Workload::from_tensor("ablate_rebalance", t))
            .scheme(SchemeChoice::Lite)
            .ranks(p)
            .core(k)
            .seed(11)
            .build()
            .expect("valid rebalance ablation session");
    // absorb the one-off plan-compilation charge before any timing
    let _ = session.decompose();
    // a skewed drift batch: every append lands in one of 8 hot slices
    let dims = session.workload().tensor.dims.clone();
    let batch = if quick { 2_000 } else { 20_000 };
    let mut delta = TensorDelta::new();
    for i in 0..batch {
        let hot = (i % 8) as u32;
        let coord: Vec<u32> = dims
            .iter()
            .enumerate()
            .map(|(m, &l)| if m == 0 { hot } else { rng.below(l as u64) as u32 })
            .collect();
        delta = delta.append(&coord, rng.f32() * 2.0 - 1.0);
    }
    let rep = session.ingest(&delta).expect("valid rebalance ablation delta");
    // baseline sweep on the *post-ingest* tensor (first run drains the
    // ingest's splice/rebuild charge) so predicted and observed savings
    // compare the same tensor under the old vs the re-planned placement
    let _ = session.decompose();
    let h_before = session.decompose().record.hooi_secs;
    let t0 = Stopwatch::start();
    let rb = session.rebalance();
    let rebal_secs = t0.seconds();
    // baseline: what a session without diff-driven migration would pay —
    // prepare_modes over everything on the re-planned placement
    let w2 = Workload::from_tensor(
        "ablate_rebalance_full",
        session.workload().tensor.clone(),
    );
    let t0 = Stopwatch::start();
    let modes = prepare_modes(
        &w2.tensor,
        &w2.idx,
        session.distribution(),
        &CoreRanks::Uniform(k),
    );
    let full_secs = t0.seconds();
    std::hint::black_box(modes.len());
    // drain the pending ingest/migration charges into a throwaway run,
    // then measure a clean post-rebalance sweep
    let _ = session.decompose();
    let h_after = session.decompose().record.hooi_secs;
    let plan_count = 3 * p;
    let mut t5 = Table::new(
        &format!(
            "ablate_plan — rebalance: migrate-via-diff vs full re-prepare \
             (nnz={nnz}+{batch} skewed, P={p}, K={k}, flagged modes: {:?})",
            rep.rebalance_modes
        ),
        &[
            "path",
            "wall",
            "plans spliced",
            "plans rebuilt",
            "predicted savings/sweep",
            "observed savings/sweep",
        ],
    );
    t5.row(vec![
        "migrate via MigrationPlan".into(),
        fmt_secs(rebal_secs),
        rb.plans_spliced.to_string(),
        format!("{}/{plan_count}", rb.plans_rebuilt),
        fmt_secs(rb.decision.savings_per_sweep),
        fmt_secs(h_before - h_after),
    ]);
    t5.row(vec![
        "full prepare_modes".into(),
        fmt_secs(full_secs),
        "0".into(),
        format!("{plan_count}/{plan_count}"),
        "-".into(),
        "-".into(),
    ]);
    t5.print();
    let _ = t5.save_csv("ablate_plan_rebalance");

    // --- 6. plan layout: N per-mode plans vs one shared CSF tree per
    // rank (`PlanChoice::SharedCsf`). Reports the analytic per-sweep TTM
    // FLOPs with and without cross-mode contribution reuse, the measured
    // plan-build wall time, and the per-sweep wall time under the scalar
    // and the detected tiled kernel — the two layouts' decompositions
    // asserted bit-identical inline. Machine-readable mirror:
    // results/BENCH_plan.json. ---
    let nnz = if quick { 20_000 } else { 150_000 };
    let t = SparseTensor::random(vec![300, 200, 80], nnz, &mut rng);
    let w = std::sync::Arc::new(Workload::from_tensor("ablate_layout", t));
    // a uni scheme: each rank owns one element set across all modes, so
    // the shared tree carries real views (Lite degrades to all-Stream)
    let build_layout = |w: std::sync::Arc<Workload>, kernel: Kernel, plan: PlanChoice| {
        TuckerSession::builder(w)
            .scheme(SchemeChoice::MediumG)
            .ranks(p)
            .core(k)
            .invocations(1)
            .kernel(KernelChoice::Fixed(kernel))
            .plan(plan)
            .seed(13)
            .build()
            .expect("valid layout ablation session")
    };

    let probe = build_layout(w.clone(), tiled_kernel, PlanChoice::SharedCsf);
    let sp = probe.shared_plans().expect("SharedCsf layout holds trees");
    let per_mode_flops = sp.per_mode_flops();
    let shared_flops = sp.sweep_flops();
    let build_shared: f64 = sp.plan_secs.iter().sum();
    let pm_probe = build_layout(w.clone(), tiled_kernel, PlanChoice::PerMode);
    let build_per_mode: f64 = pm_probe
        .mode_states()
        .iter()
        .flat_map(|st| st.plan_secs.iter())
        .sum();

    let mut t6 = Table::new(
        &format!(
            "ablate_plan — plan layout: per-mode vs shared CSF (MediumG, \
             nnz={nnz}, P={p}, K={k}, TTM FLOPs/sweep {per_mode_flops:.3e} -> \
             {shared_flops:.3e}, {:.1}% saved, plan build {} -> {})",
            100.0 * (1.0 - shared_flops / per_mode_flops),
            fmt_secs(build_per_mode),
            fmt_secs(build_shared),
        ),
        &["kernel", "per-mode sweep", "shared sweep", "shared vs per-mode"],
    );
    let mut kernel_rows = Vec::new();
    for kernel in [Kernel::Scalar, tiled_kernel] {
        let time_sweeps = |plan: PlanChoice| {
            let mut s = build_layout(w.clone(), kernel, plan);
            let d = s.decompose(); // absorbs the one-off plan charge
            let t0 = Stopwatch::start();
            for _ in 0..reps {
                let _ = s.decompose_more(1);
            }
            (t0.seconds() / reps as f64, d)
        };
        let (pm_secs, pm_d) = time_sweeps(PlanChoice::PerMode);
        let (sh_secs, sh_d) = time_sweeps(PlanChoice::SharedCsf);
        // the headline contract rides along: a broken shared assembly
        // arm fails the bench run, not just the test suite
        assert_eq!(
            pm_d.fit().to_bits(),
            sh_d.fit().to_bits(),
            "{}: shared layout fit diverged",
            kernel.name()
        );
        for (n, (a, b)) in pm_d.factors.iter().zip(&sh_d.factors).enumerate() {
            assert_eq!(
                a.data,
                b.data,
                "{}: mode {n} factors diverged across plan layouts",
                kernel.name()
            );
        }
        assert_eq!(pm_d.core.data, sh_d.core.data, "{}: core", kernel.name());
        t6.row(vec![
            kernel.name().into(),
            fmt_secs(pm_secs),
            fmt_secs(sh_secs),
            format!("{:.2}x", pm_secs / sh_secs),
        ]);
        let mut row = Json::obj();
        row.set("kernel", Json::Str(kernel.name().into()))
            .set("per_mode_sweep_secs", Json::Num(pm_secs))
            .set("shared_sweep_secs", Json::Num(sh_secs));
        kernel_rows.push(row);
    }
    t6.print();
    let _ = t6.save_csv("ablate_plan_layout");

    let mut j = Json::obj();
    j.set("bench", Json::Str("ablate_plan_layout".into()))
        .set("scheme", Json::Str("mediumg".into()))
        .set("p", Json::Num(p as f64))
        .set("k", Json::Num(k as f64))
        .set("nnz", Json::Num(nnz as f64))
        .set("ttm_flops_per_sweep_per_mode", Json::Num(per_mode_flops))
        .set("ttm_flops_per_sweep_shared", Json::Num(shared_flops))
        .set("flop_reduction", Json::Num(1.0 - shared_flops / per_mode_flops))
        .set("plan_build_secs_per_mode", Json::Num(build_per_mode))
        .set("plan_build_secs_shared", Json::Num(build_shared))
        .set("bit_identical", Json::Bool(true))
        .set("kernels", Json::Arr(kernel_rows));
    let _ = std::fs::create_dir_all("results");
    match std::fs::write("results/BENCH_plan.json", j.render()) {
        Ok(()) => eprintln!("# wrote results/BENCH_plan.json"),
        Err(e) => eprintln!("# BENCH_plan.json not written: {e}"),
    }
}
