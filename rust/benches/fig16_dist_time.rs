//! Fig 16: tensor distribution time per scheme vs a single Lite HOOI
//! invocation — the lightweight schemes are real-time, HyperG is offline.
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig16;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig16", &cfg);
    let engine = common::bench_engine();
    let t = fig16(&cfg, &engine);
    t.print();
    let _ = t.save_csv("fig16_dist_time");
}
