//! Ablation: scheme design choices DESIGN.md calls out.
//!
//!   1. Lite stage-1 round-robin vs CoarseG-BPF (best processor fit):
//!      §6.1 argues BPF alone cannot fix giant slices — measure E_max.
//!   2. Sample sort vs std sort for Lite's slice ordering: the parallel
//!      critical path vs a serial sort.
//!   3. HyperG refinement passes: connectivity cut vs passes (quality/time
//!      tradeoff of the multilevel partitioner).

#[path = "common.rs"]
mod common;

use tucker_lite::util::timer::Stopwatch;
use tucker_lite::sched::hypergraph::{partition, Hypergraph, PartitionParams};
use tucker_lite::sched::{self, ModeMetrics, Scheme};
use tucker_lite::tensor::datasets;
use tucker_lite::tensor::slices::build_all;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_secs, Table};

fn main() {
    let quick = common::bench_quick();
    let scale = if quick { 0.02 } else { 0.2 };
    let p = if quick { 4 } else { 64 };

    // --- 1. giant-slice handling: Lite vs BPF vs CoarseG ---
    let spec = datasets::by_name("enron").unwrap();
    let t = spec.scaled(scale).generate();
    let idx = build_all(&t);
    let limit = t.nnz().div_ceil(p);
    let mut t1 = Table::new(
        &format!("ablate — giant slices (enron, P={p}): E_max vs optimal {limit}"),
        &["scheme", "E_max(mode0)", "E_max/opt", "R_sum/L"],
    );
    for name in ["coarseg", "coarseg-bpf", "lite"] {
        let scheme = sched::by_name(name).unwrap();
        let d = scheme.policies(&t, &idx, p, &mut Rng::new(1));
        let m = ModeMetrics::compute(&idx[0], &d.policies[0]);
        t1.row(vec![
            scheme.name().into(),
            m.e_max.to_string(),
            format!("{:.2}", m.e_max as f64 / limit as f64),
            format!("{:.2}", m.svd_redundancy()),
        ]);
    }
    t1.print();
    let _ = t1.save_csv("ablate_giant_slices");

    // --- 2. sample sort vs std sort on Lite's slice ordering ---
    let sizes = idx[2].sizes();
    let reps = if quick { 3 } else { 20 };
    let mut t2 = Table::new(
        &format!("ablate — slice sort ({} slices)", sizes.len()),
        &["sort", "serial secs", "parallel critical path"],
    );
    let t0 = Stopwatch::start();
    for _ in 0..reps {
        let mut v: Vec<u32> = (0..sizes.len() as u32).collect();
        v.sort_unstable_by_key(|&i| sizes[i as usize]);
        std::hint::black_box(v.len());
    }
    let std_sort = t0.seconds() / reps as f64;
    t2.row(vec!["std (serial)".into(), fmt_secs(std_sort), "-".into()]);
    let mut rng = Rng::new(2);
    let t0 = Stopwatch::start();
    let mut crit = 0.0;
    for _ in 0..reps {
        let out = sched::samplesort::sample_sort(&sizes, p, &mut rng);
        crit += out.prefix_secs / p as f64 + out.max_bucket_secs;
        std::hint::black_box(out.order.len());
    }
    let ss = t0.seconds() / reps as f64;
    t2.row(vec![
        format!("sample sort (P={p})"),
        fmt_secs(ss),
        fmt_secs(crit / reps as f64),
    ]);
    t2.print();
    let _ = t2.save_csv("ablate_sort");

    // --- 3. HyperG refinement passes ---
    let spec = datasets::by_name("nell2").unwrap();
    let t = spec.scaled(scale * 0.5).generate();
    let idx = build_all(&t);
    let hg = Hypergraph::from_tensor(&t, &idx);
    let mut t3 = Table::new(
        &format!("ablate — HyperG refinement (nell2, nnz={}, P={p})", t.nnz()),
        &["passes", "connectivity-1 cut", "partition secs"],
    );
    for passes in [0usize, 1, 3, 6] {
        let params = PartitionParams { passes, ..Default::default() };
        let t0 = Stopwatch::start();
        let part = partition(&hg, p, params, &mut Rng::new(4));
        let secs = t0.seconds();
        let cut = hg.connectivity_cut(&part, p);
        t3.row(vec![passes.to_string(), cut.to_string(), fmt_secs(secs)]);
    }
    t3.print();
    let _ = t3.save_csv("ablate_hyperg_passes");
}
