//! Shared bench harness (criterion is not vendored in the offline image —
//! benches are harness=false binaries that print the paper's tables and
//! mirror them to results/*.csv).
//!
//! Environment knobs:
//!   TUCKER_BENCH_SCALE   dataset scale multiplier (default 0.2)
//!   TUCKER_BENCH_QUICK   set to any value for the tiny smoke config
//!   TUCKER_BENCH_ENGINE  pjrt (default) | native

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use tucker_lite::coordinator::ExpConfig;
use tucker_lite::runtime::Engine;
use tucker_lite::util::env;

/// Is the tiny smoke configuration requested? (presence-only flag)
pub fn bench_quick() -> bool {
    env::is_set(env::BENCH_QUICK)
}

pub fn bench_config() -> ExpConfig {
    let mut cfg = if bench_quick() { ExpConfig::quick() } else { ExpConfig::default() };
    let default_scale = cfg.scale;
    cfg.scale =
        env::resolve(None, env::BENCH_SCALE, |s| s.parse().ok(), || default_scale);
    cfg
}

pub fn bench_engine() -> Engine {
    match env::raw(env::BENCH_ENGINE).as_deref() {
        Some("pjrt") => {
            let (e, label) = Engine::pjrt_or_native();
            eprintln!("# engine: {label} (TUCKER_BENCH_ENGINE)");
            e
        }
        _ => {
            // native is the timing-faithful engine at simulation scale:
            // CPU-PJRT dispatch overhead (~ms/call) would swamp the
            // per-rank FLOP differences the figures measure. The pjrt
            // path is exercised by ablate_runtime, the e2e example and
            // the roundtrip integration tests.
            eprintln!("# engine: native (set TUCKER_BENCH_ENGINE=pjrt to override)");
            Engine::Native
        }
    }
}

pub fn banner(name: &str, cfg: &ExpConfig) {
    eprintln!(
        "# {name}: scale={} P=({},{}) K=({},{}) invocations={}",
        cfg.scale, cfg.p_lo, cfg.p_hi, cfg.k, cfg.k_big, cfg.invocations
    );
}
