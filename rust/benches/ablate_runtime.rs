//! Ablation: compute-engine variants on the TTM hot path.
//!
//!   - pjrt        batched contributions through the compiled HLO artifact
//!   - native      same batched contract, in-process reference kernel
//!   - fused       native scatter-fused assembly (no batch materialization)
//!
//! DESIGN.md calls this out: the batch-materialize-then-scatter structure
//! is the price of the fixed-shape AOT architecture; this bench quantifies
//! it (and the perf pass in EXPERIMENTS.md §Perf tracks the gap).

#[path = "common.rs"]
mod common;

use tucker_lite::util::timer::Stopwatch;
use tucker_lite::hooi::{assemble_local_z, assemble_local_z_fused};
use tucker_lite::linalg::orthonormal_random;
use tucker_lite::runtime::Engine;
use tucker_lite::tensor::SparseTensor;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_secs, Table};

fn main() {
    let quick = common::bench_quick();
    let nnz = if quick { 20_000 } else { 400_000 };
    let reps = if quick { 2 } else { 5 };
    let k = 10;
    let mut rng = Rng::new(3);
    let t = SparseTensor::random(vec![4000, 3000, 1500], nnz, &mut rng);
    let factors: Vec<_> = t
        .dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, &mut rng))
        .collect();
    let elems: Vec<u32> = (0..t.nnz() as u32).collect();
    let (pjrt, label) = Engine::pjrt_or_native();
    eprintln!("# pjrt engine: {label}; nnz={nnz} K={k} reps={reps}");

    let mut table = Table::new(
        "ablate_runtime — TTM local-Z assembly (one full mode)",
        &["variant", "secs/assembly", "Melem/s"],
    );
    let mut run = |name: &str, f: &mut dyn FnMut()| {
        f(); // warmup (compiles artifacts on first pjrt call)
        let t0 = Stopwatch::start();
        for _ in 0..reps {
            f();
        }
        let per = t0.seconds() / reps as f64;
        table.row(vec![
            name.into(),
            fmt_secs(per),
            format!("{:.2}", nnz as f64 / per / 1e6),
        ]);
    };

    run("pjrt", &mut || {
        let z = assemble_local_z(&t, 0, &elems, &factors, k, &pjrt);
        std::hint::black_box(z.rows.len());
    });
    run("native (batched)", &mut || {
        let z = assemble_local_z(&t, 0, &elems, &factors, k, &Engine::NativeBatched);
        std::hint::black_box(z.rows.len());
    });
    run("native (fused)", &mut || {
        let z = assemble_local_z_fused(&t, 0, &elems, &factors);
        std::hint::black_box(z.rows.len());
    });
    table.print();
    let _ = table.save_csv("ablate_runtime");
}
