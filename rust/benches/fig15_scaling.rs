//! Fig 15: strong scaling — (a) speedup P_lo→P_hi for every scheme,
//! (b) the Lite scaling curve over the P sweep.
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig15;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig15", &cfg);
    let engine = common::bench_engine();
    let (a, b) = fig15(&cfg, &engine);
    a.print();
    b.print();
    let _ = a.save_csv("fig15a_speedup");
    let _ = b.save_csv("fig15b_lite_scaling");
}
