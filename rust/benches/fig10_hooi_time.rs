//! Fig 10: HOOI execution time — 4 schemes × 5 medium tensors × 3 configs
//! (P_lo/K, P_hi/K, P_hi/K_big). The paper's headline table: Lite best
//! everywhere, gain growing with ranks and core size.
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig10;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig10", &cfg);
    let engine = common::bench_engine();
    for (i, t) in fig10(&cfg, &engine).iter().enumerate() {
        t.print();
        let _ = t.save_csv(&format!("fig10_config{}", i + 1));
    }
}
