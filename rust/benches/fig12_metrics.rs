//! Fig 12: the §4 computation metrics — (a) TTM load balance,
//! (b) normalized SVD load (redundancy), (c) SVD load balance.
//! Distribution-only (no HOOI timing needed).
#[path = "common.rs"]
mod common;
use tucker_lite::coordinator::experiments::fig12;

fn main() {
    let cfg = common::bench_config();
    common::banner("fig12", &cfg);
    let t = fig12(&cfg);
    t.print();
    let _ = t.save_csv("fig12_metrics");
}
