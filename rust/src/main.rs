//! tucker-lite CLI — the leader entrypoint.
//!
//! Subcommands:
//!   decompose   run HOOI on a dataset under a scheme, print the record
//!   distribute  construct a distribution and report the §4 metrics
//!   datasets    the Fig 9 dataset table
//!   exp         regenerate a paper figure: `exp --fig 10`
//!   bench-kernel  micro-benchmark the TTM kernel paths (pjrt vs native)
//!
//! Common options: --dataset NAME|file.tns --scheme lite|coarseg|mediumg|
//! hyperg --p N --k K --invocations I --scale S --engine pjrt|native
//! --config FILE --alpha A --beta B --seed S

use tucker_lite::coordinator::{
    experiments, EngineChoice, JobSpec, RunRecord, SchemeChoice, TuckerSession, Workload,
};
use tucker_lite::runtime::Engine;
use tucker_lite::sched;
use tucker_lite::tensor::datasets;
use tucker_lite::util::args::Args;
use tucker_lite::util::config::Config;
use tucker_lite::util::rng::Rng;
use tucker_lite::util::table::{fmt_secs, fmt_si, Table};

fn main() {
    let args = Args::from_env();
    let config = args.get("config").map(|path| {
        Config::load(path).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        })
    });
    let job = JobSpec::from_sources(config.as_ref(), &args).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    match args.subcommand() {
        Some("decompose") => decompose(&job, &args),
        Some("distribute") => distribute(&job),
        Some("datasets") => datasets::fig9_table().print(),
        Some("exp") => exp(&job, &args),
        Some("bench-kernel") => bench_kernel(&job, &args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            std::process::exit(2);
        }
        None => {
            usage();
        }
    }
}

fn usage() {
    println!(
        "tucker-lite — distributed Tucker decomposition (HOOI) for sparse tensors\n\
         \n\
         USAGE: tucker-lite <decompose|distribute|datasets|exp|bench-kernel> [options]\n\
         \n\
         Options:\n\
           --dataset NAME|FILE       one of the Fig 9 analogues or a FROSTT file\n\
           --scheme  lite|coarseg|coarseg-bpf|mediumg|hyperg\n\
           --p N --k K --invocations I --scale S --seed S\n\
           --core K0,K1,K2           per-mode core ranks (overrides --k)\n\
           --engine pjrt|native      compute backend (default pjrt)\n\
           --config FILE             key = value config (CLI overrides)\n\
           --alpha A --beta B        network model parameters\n\
           --fig N                   figure number for `exp` (9..17)\n\
           --quick                   tiny configuration (smoke)\n"
    );
}

fn make_engine(job: &JobSpec) -> Engine {
    match job.engine.as_str() {
        "native" => Engine::Native,
        _ => {
            let (e, label) = Engine::pjrt_or_native();
            eprintln!("# engine: {label}");
            e
        }
    }
}

fn decompose(job: &JobSpec, _args: &Args) {
    let w = Workload::resolve(job).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let scheme = SchemeChoice::by_name(&job.scheme).unwrap_or_else(|| {
        eprintln!("unknown scheme {:?}", job.scheme);
        std::process::exit(2);
    });
    let core = job.core_ranks();
    eprintln!(
        "# {} nnz={} dims={:?} scheme={} P={} K={} inv={}",
        w.name,
        w.tensor.nnz(),
        w.tensor.dims,
        job.scheme,
        job.p,
        core,
        job.invocations
    );
    // make_engine keeps the fallback diagnostic (`# engine: ...`) on the
    // session path too
    let mut session = TuckerSession::builder(w)
        .scheme(scheme)
        .ranks(job.p)
        .core(core)
        .invocations(job.invocations)
        .engine(EngineChoice::Custom(make_engine(job)))
        .net(job.net)
        .seed(job.seed)
        .build()
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let d = session.decompose();
    print_record(&d.record);
}

fn print_record(rec: &RunRecord) {
    let core: Vec<String> = rec.core.iter().map(|k| k.to_string()).collect();
    let mut t = Table::new(
        &format!(
            "{} / {} (P={}, core={})",
            rec.workload,
            rec.scheme,
            rec.p,
            core.join("x")
        ),
        &["quantity", "value"],
    );
    t.row(vec!["HOOI time (simulated)".into(), fmt_secs(rec.hooi_secs)]);
    t.row(vec!["  TTM compute".into(), fmt_secs(rec.ttm_secs)]);
    t.row(vec!["  SVD compute".into(), fmt_secs(rec.svd_secs)]);
    t.row(vec!["  core compute".into(), fmt_secs(rec.core_secs)]);
    t.row(vec!["  communication".into(), fmt_secs(rec.comm_secs)]);
    t.row(vec!["distribution time".into(), fmt_secs(rec.dist_secs)]);
    t.row(vec!["SVD comm volume (units)".into(), fmt_si(rec.svd_volume)]);
    t.row(vec!["FM comm volume (units)".into(), fmt_si(rec.fm_volume)]);
    t.row(vec!["TTM balance (max/avg)".into(), format!("{:.2}", rec.ttm_balance)]);
    t.row(vec!["SVD load (normalized)".into(), format!("{:.2}", rec.svd_load_norm)]);
    t.row(vec!["SVD balance (max/avg)".into(), format!("{:.2}", rec.svd_balance)]);
    t.row(vec!["memory MB/rank (avg)".into(), format!("{:.1}", rec.mem_mb)]);
    t.row(vec!["fit".into(), format!("{:.4}", rec.fit)]);
    t.row(vec![
        "executor / kernel".into(),
        format!("{} x{} / {}", rec.executor, rec.workers, rec.kernel),
    ]);
    t.row(vec![
        "TTM executor speedup".into(),
        format!("{:.2}x", rec.ttm_speedup),
    ]);
    t.print();
}

fn distribute(job: &JobSpec) {
    let w = Workload::resolve(job).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let schemes: Vec<Box<dyn sched::Scheme>> = if job.scheme == "all" {
        sched::all_schemes()
    } else {
        vec![sched::by_name(&job.scheme).unwrap_or_else(|| {
            eprintln!("unknown scheme {:?}", job.scheme);
            std::process::exit(2);
        })]
    };
    let core = job.core_ranks();
    let ks = core.validate(w.tensor.ndim()).unwrap_or_else(|e| {
        eprintln!("invalid core ranks: {e}");
        std::process::exit(2);
    });
    let khv: Vec<f64> = (0..w.tensor.ndim())
        .map(|n| tucker_lite::hooi::khat_of(&ks, n) as f64)
        .collect();
    let mut t = Table::new(
        &format!("distribution metrics — {} P={} K={}", w.name, job.p, core),
        &[
            "scheme", "dist time", "TTM bal", "SVD load", "SVD bal", "SVD vol",
            "FM vol", "mem MB",
        ],
    );
    for rec in experiments::distribution_records(&w, &schemes, job.p, &core, job.seed) {
        t.row(vec![
            rec.scheme.clone(),
            fmt_secs(rec.dist_secs),
            format!("{:.2}", rec.metrics.ttm_balance()),
            format!("{:.2}", rec.metrics.svd_load_normalized(&khv)),
            format!("{:.2}", rec.metrics.svd_balance(&khv)),
            fmt_si(rec.svd_volume),
            fmt_si(rec.fm_volume),
            format!("{:.1}", rec.mem_mb),
        ]);
    }
    t.print();
}

fn exp(job: &JobSpec, args: &Args) {
    let fig: usize = args.parse_or("fig", 0);
    if fig == 0 {
        eprintln!("exp requires --fig N (9..17)");
        std::process::exit(2);
    }
    if job.core.is_some() {
        eprintln!(
            "error: the figure harness reproduces the paper's uniform-K runs; \
             use --k (per-mode --core applies to decompose/distribute)"
        );
        std::process::exit(2);
    }
    let mut cfg = if args.flag("quick") {
        experiments::ExpConfig::quick()
    } else {
        experiments::ExpConfig::default()
    };
    cfg.scale = args.parse_or("scale", cfg.scale);
    cfg.k = args.parse_or("k", cfg.k);
    cfg.p_lo = args.parse_or("p-lo", cfg.p_lo);
    cfg.p_hi = args.parse_or("p-hi", cfg.p_hi);
    cfg.net = job.net;
    let engine = make_engine(job);
    println!("{}", experiments::run_figure(fig, &cfg, &engine));
}

/// Microbenchmark: PJRT vs native on the TTM contribution kernel + the
/// matvec tiles (the two artifact families).
fn bench_kernel(job: &JobSpec, args: &Args) {
    if job.core.is_some() {
        eprintln!(
            "error: bench-kernel measures the uniform-K engine kernels; \
             use --k (per-mode --core applies to decompose/distribute)"
        );
        std::process::exit(2);
    }
    let k = job.k;
    let reps: usize = args.parse_or("reps", 20);
    let (pjrt, label) = Engine::pjrt_or_native();
    eprintln!("# engine under test: {label}");
    let native = Engine::Native;
    let b = pjrt.ttm_batch_size(3, k);
    let mut rng = Rng::new(7);
    let rows_a: Vec<f32> = (0..b * k).map(|_| rng.normal() as f32).collect();
    let rows_b: Vec<f32> = (0..b * k).map(|_| rng.normal() as f32).collect();
    let vals: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();
    let mut t = Table::new(
        &format!("kernel microbench (K={k}, B={b}, reps={reps})"),
        &["kernel", "engine", "secs/call", "GFLOP/s"],
    );
    for (name, eng) in [("pjrt", &pjrt), ("native", &native)] {
        let t0 = tucker_lite::util::timer::Stopwatch::start();
        for _ in 0..reps {
            let out = eng.kron3_batch(k, &rows_a, &rows_b, &vals);
            std::hint::black_box(out.len());
        }
        let per = t0.seconds() / reps as f64;
        let flops = (b * k * k) as f64; // one multiply per output element (+scale)
        t.row(vec![
            "kron3".into(),
            name.into(),
            fmt_secs(per),
            format!("{:.2}", flops / per / 1e9),
        ]);
    }
    // matvec tile
    let khat = k * k;
    let rt = match &pjrt {
        Engine::Pjrt(r) => r.matvec_rtile(khat).unwrap_or(256),
        _ => 256,
    };
    let z = tucker_lite::linalg::Mat::from_fn(rt, khat, |_, _| rng.normal() as f32);
    let x: Vec<f32> = (0..khat).map(|_| rng.normal() as f32).collect();
    for (name, eng) in [("pjrt", &pjrt), ("native", &native)] {
        let t0 = tucker_lite::util::timer::Stopwatch::start();
        for _ in 0..reps {
            let out = eng.local_matvec(&z, &x);
            std::hint::black_box(out.len());
        }
        let per = t0.seconds() / reps as f64;
        let flops = (rt * khat * 2) as f64;
        t.row(vec![
            format!("matvec({rt}x{khat})"),
            name.into(),
            fmt_secs(per),
            format!("{:.2}", flops / per / 1e9),
        ]);
    }
    // device-resident Z variant (§Perf): upload once, execute_b per query
    if let Engine::Pjrt(rtm) = &pjrt {
        if let Ok(zdev) = rtm.upload_z(khat, rt, &z.data) {
            let _ = rtm.matvec_dev(&zdev, &x); // warmup/compile
            let t0 = tucker_lite::util::timer::Stopwatch::start();
            for _ in 0..reps {
                let out = rtm.matvec_dev(&zdev, &x).expect("matvec_dev");
                std::hint::black_box(out.len());
            }
            let per = t0.seconds() / reps as f64;
            let flops = (rt * khat * 2) as f64;
            t.row(vec![
                format!("matvec({rt}x{khat})"),
                "pjrt+zcache".into(),
                fmt_secs(per),
                format!("{:.2}", flops / per / 1e9),
            ]);
        }
    }
    t.print();
}
