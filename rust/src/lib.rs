//! # tucker-lite
//!
//! Distributed Tucker decomposition (HOOI) for sparse tensors, reproducing
//! *"On Optimizing Distributed Tucker Decomposition for Sparse Tensors"*
//! (Chakaravarthy et al., cs.DC 2018): the lightweight, provably
//! near-optimal **Lite** distribution scheme, the prior schemes it is
//! evaluated against (CoarseG, MediumG, HyperG), the Kaya–Uçar distributed
//! HOOI framework they all plug into, and the full experiment harness for
//! the paper's evaluation section.
//!
//! Architecture (DESIGN.md): a rust L3 coordinator owns the distribution
//! schemes, the simulated distributed runtime, and the HOOI driver; the
//! compute hot spots (batched Kronecker contributions, Lanczos matvec
//! tiles) are JAX/Pallas graphs AOT-lowered to HLO and executed through
//! the PJRT CPU client (`runtime`) — Python never runs at decomposition
//! time.
//!
//! # Quick tour
//!
//! The public entry point is [`coordinator::TuckerSession`] — one typed
//! builder for workloads, schemes, engines, kernels, executors and
//! per-mode core ranks, returning a reusable decomposition handle:
//!
//! ```no_run
//! use tucker_lite::coordinator::{SchemeChoice, TuckerSession, Workload};
//! use tucker_lite::hooi::CoreRanks;
//!
//! let workload = Workload::from_tns("tensor.tns".as_ref()).unwrap();
//! let mut session = TuckerSession::builder(workload)
//!     .scheme(SchemeChoice::Lite)
//!     .ranks(16)
//!     .core(CoreRanks::PerMode(vec![12, 12, 4])) // or .core(10) for uniform K
//!     .build()
//!     .unwrap();
//! let d = session.decompose();
//! println!("fit {:.4}, core {:?}", d.fit(), d.core_dims());
//! let refined = session.decompose_more(1); // cached TTM plans, no re-prepare
//! # let _ = refined;
//! ```
//!
//! Long-running sessions stream: [`tensor::TensorDelta`] batches
//! appended/changed/removed nonzeros, `TuckerSession::ingest` applies
//! them atomically, extends the placement with Lite's per-bin load
//! discipline ([`sched::incremental`]) and splices/rebuilds only the
//! dirty (mode, rank) TTM plans — bit-identical to a fresh build on the
//! mutated tensor, never a full re-prepare. When drift breaks a mode's
//! Theorem 6.1 bounds, the rebalance loop closes it: the session's
//! [`sched::PlacementPlan`] (policies + §4 metrics + cost estimate)
//! diffs against a Lite re-plan into a [`sched::MigrationPlan`] — the
//! exact per-(mode, rank) moved-element sets — and a
//! `RebalancePolicy::Auto` session migrates only when the cost model
//! says the per-sweep savings amortize the migration.
//!
//! Typed options replace the `TUCKER_*` env vars (which remain as
//! fallbacks — precedence table in [`util::env`]). Layer by layer:
//!
//! - [`coordinator`]: the [`coordinator::TuckerSession`] front door,
//!   job specs, the pipeline leader (the legacy `run_scheme` shim), the
//!   experiment harness for Figs 9–17.
//! - [`tensor`]: COO sparse tensors, slice indexing, streaming deltas,
//!   FROSTT I/O, the Fig 9 synthetic dataset analogues.
//! - [`sched`]: the distribution schemes, the first-class
//!   [`sched::PlacementPlan`] (policies + §4 metrics + cost model) with
//!   [`sched::MigrationPlan`] diffs, the paper's metrics
//!   (E_max, R_sum, R_max), the σ_n row-index mapping, and the
//!   incremental policy extension for streamed appends.
//! - [`dist`]: the simulated P-rank cluster (makespan timing, α–β comms)
//!   with a scoped-thread parallel rank executor.
//! - [`hooi`]: TTM via Eq. 1 contributions — precompiled per-rank plans
//!   on the hot path (`hooi::plan`), lane-blocked 8-wide SIMD
//!   microkernels with runtime AVX2/NEON dispatch (`hooi::kernel`),
//!   per-mode core ranks (`hooi::ranks`) — Lanczos-bidiagonalization
//!   SVD, factor-matrix transfer, the split driver
//!   (`prepare_modes` + `HooiState`) the session builds on.
//! - [`runtime`]: PJRT artifact registry + padded-batch dispatch.
//! - [`serve`]: the query-serving layer — batched reconstruction and
//!   top-K queries through the SIMD microkernels (pinned bit-exact to
//!   the scalar oracle), `Arc`-published
//!   [`serve::DecompositionSnapshot`]s with generation provenance for
//!   consistent reads under concurrent ingest/rebalance, and the
//!   multi-tenant [`serve::ServeCoordinator`] budgeting threads and
//!   snapshot memory across live sessions.
//! - [`util`]: from-scratch substrates (args, config, rng, tables) and
//!   the one [`util::env`] front door for every `TUCKER_*` variable.

pub mod coordinator;
pub mod dist;
pub mod hooi;
pub mod linalg;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod tensor;
pub mod util;
