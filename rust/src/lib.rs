//! # tucker-lite
//!
//! Distributed Tucker decomposition (HOOI) for sparse tensors, reproducing
//! *"On Optimizing Distributed Tucker Decomposition for Sparse Tensors"*
//! (Chakaravarthy et al., cs.DC 2018): the lightweight, provably
//! near-optimal **Lite** distribution scheme, the prior schemes it is
//! evaluated against (CoarseG, MediumG, HyperG), the Kaya–Uçar distributed
//! HOOI framework they all plug into, and the full experiment harness for
//! the paper's evaluation section.
//!
//! Architecture (DESIGN.md): a rust L3 coordinator owns the distribution
//! schemes, the simulated distributed runtime, and the HOOI driver; the
//! compute hot spots (batched Kronecker contributions, Lanczos matvec
//! tiles) are JAX/Pallas graphs AOT-lowered to HLO and executed through
//! the PJRT CPU client (`runtime`) — Python never runs at decomposition
//! time.
//!
//! Quick tour:
//! - [`tensor`]: COO sparse tensors, slice indexing, FROSTT I/O, the Fig 9
//!   synthetic dataset analogues.
//! - [`sched`]: the distribution schemes + the paper's metrics
//!   (E_max, R_sum, R_max) and the σ_n row-index mapping.
//! - [`dist`]: the simulated P-rank cluster (makespan timing, α–β comms)
//!   with a scoped-thread parallel rank executor.
//! - [`hooi`]: TTM via Eq. 1 contributions — precompiled per-rank plans
//!   on the hot path (`hooi::plan`), lane-blocked 8-wide SIMD
//!   microkernels with runtime AVX2/NEON dispatch (`hooi::kernel`) —
//!   Lanczos-bidiagonalization SVD, factor-matrix transfer, the full
//!   HOOI driver.
//! - [`runtime`]: PJRT artifact registry + padded-batch dispatch.
//! - [`coordinator`]: job specs, the pipeline leader, experiment harness.

pub mod coordinator;
pub mod dist;
pub mod hooi;
pub mod linalg;
pub mod runtime;
pub mod sched;
pub mod tensor;
pub mod util;
