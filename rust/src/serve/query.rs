//! Batched reconstruction queries over a Tucker decomposition.
//!
//! A point query X[i] = Σ_j G[j] · Π_n F_n[i_n, j_n] costs O(Π K_n)
//! when evaluated cold: the dominant term is contracting the flattened
//! core G_(N−1) (K_{N−1} × K̂) with the last-mode factor row — K̂·K_{N−1}
//! multiply-adds that depend only on i_{N−1}, not on the other
//! coordinates. The batched engine exploits exactly that: queries are
//! grouped by their mode-(N−1) slice, the per-slice core contraction
//! `g[col] = Σ_j G[j, col]·F_{N−1}[i_{N−1}, j]` is computed once per
//! group, and each query then reduces to a small Kronecker-chain GEMV —
//! build the weight vector `w = ⊗_{m<N−1} F_m[i_m, :]` through the
//! lane-blocked microkernels ([`crate::hooi::kernel`]) and take one
//! K̂-long dot against `g`. Per-query work drops from ~K_{N−1}·K̂ to
//! ~2·K̂ flops, and the weight build vectorizes.
//!
//! ## Bit-exactness contract
//!
//! [`reconstruct_batch`] is pinned *bit-identical* to the per-element
//! oracle [`reconstruct_at`] under **every** kernel (tests/serve.rs).
//! Three disciplines make that hold:
//!
//! 1. `g` is produced by the same scalar accumulation
//!    ([`slice_weights`]) in both paths — computed per group in the
//!    batch engine, per query in the oracle, but the arithmetic is the
//!    identical sequence either way;
//! 2. the Kronecker weights are *pure products* nested slowest-last,
//!    `f_{N−2}·(…·(f_1·f_0))`. A lone multiply rounds once on every
//!    kernel — the FMA tiles only fuse multiply-*adds* — so the tiled
//!    [`expand_store_tile`](crate::hooi::kernel::expand_store_tile)
//!    chain and the oracle's scalar nesting produce the same bits;
//! 3. the final dot runs scalar-sequential in ascending K̂-column order
//!    (earliest mode fastest) in both paths — no SIMD reduction, whose
//!    reassociation would break the pin.

use crate::hooi::kernel::{expand_store_tile, pad_to_lanes, Kernel};
use crate::linalg::Mat;

/// Typed contract violation of a reconstruction query. Queries never
/// panic on bad indices — a serving front end must be able to reject a
/// malformed request without tearing the process down.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryError {
    /// The query index has the wrong number of coordinates.
    Arity {
        /// Coordinates supplied.
        got: usize,
        /// Tensor order of the decomposition.
        want: usize,
    },
    /// A coordinate is outside its mode's extent.
    OutOfRange {
        /// The offending mode.
        mode: usize,
        /// The supplied coordinate.
        index: usize,
        /// The mode's extent L_n.
        extent: usize,
    },
    /// A slice mode (the `mode` argument of a top-K query) is outside
    /// the tensor order.
    Mode {
        /// The supplied mode.
        got: usize,
        /// Tensor order of the decomposition.
        order: usize,
    },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::Arity { got, want } => {
                write!(f, "query arity {got} does not match tensor order {want}")
            }
            QueryError::OutOfRange { mode, index, extent } => write!(
                f,
                "query index {index} out of range for mode {mode} (extent {extent})"
            ),
            QueryError::Mode { got, order } => write!(
                f,
                "slice mode {got} out of range for a {order}-mode decomposition"
            ),
        }
    }
}

impl std::error::Error for QueryError {}

/// A batch of point-reconstruction queries, evaluated together by
/// [`reconstruct_batch`] so queries landing on the same mode-(N−1)
/// slice share their core contraction.
///
/// ```
/// use tucker_lite::serve::QueryBatch;
/// let batch = QueryBatch::new().push(&[0, 1, 2]).push(&[3, 1, 2]);
/// assert_eq!(batch.len(), 2);
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueryBatch {
    queries: Vec<Vec<usize>>,
}

impl QueryBatch {
    /// An empty batch.
    pub fn new() -> QueryBatch {
        QueryBatch { queries: Vec::new() }
    }

    /// Append one query (chainable). Validation happens at evaluation
    /// time, against the decomposition the batch is run on.
    pub fn push(mut self, idx: &[usize]) -> QueryBatch {
        self.queries.push(idx.to_vec());
        self
    }

    /// Append one query in place.
    pub fn add(&mut self, idx: &[usize]) {
        self.queries.push(idx.to_vec());
    }

    /// Queries in insertion order — results come back in this order.
    pub fn queries(&self) -> &[Vec<usize>] {
        &self.queries
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }
}

impl From<Vec<Vec<usize>>> for QueryBatch {
    fn from(queries: Vec<Vec<usize>>) -> QueryBatch {
        QueryBatch { queries }
    }
}

/// Check one query index against the decomposition's shape (arity,
/// then per-mode extents — the factor row counts).
pub(crate) fn validate(factors: &[Mat], idx: &[usize]) -> Result<(), QueryError> {
    if idx.len() != factors.len() {
        return Err(QueryError::Arity { got: idx.len(), want: factors.len() });
    }
    for (mode, (&i, f)) in idx.iter().zip(factors).enumerate() {
        if i >= f.rows {
            return Err(QueryError::OutOfRange { mode, index: i, extent: f.rows });
        }
    }
    Ok(())
}

/// The per-slice core contraction shared by the oracle and the batch
/// engine: `g[col] = Σ_j G[j, col] · f_last[j]`, accumulated in the
/// identical scalar order in both paths (bit-exactness discipline 1).
pub(crate) fn slice_weights(core: &Mat, f_last: &[f32], g: &mut Vec<f32>) {
    g.clear();
    g.resize(core.cols, 0.0);
    for (col, slot) in g.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (j, &fl) in f_last.iter().enumerate() {
            acc += core.get(j, col) * fl;
        }
        *slot = acc;
    }
}

/// Reusable per-caller buffers for the weight build (the batch engine
/// and the top-K scan both evaluate many queries back to back).
#[derive(Debug, Default)]
pub(crate) struct Scratch {
    apad: Vec<f32>,
    wa: Vec<f32>,
    wb: Vec<f32>,
}

/// Evaluate one query against a precomputed slice contraction `g`:
/// build the Kronecker weight vector of the non-last modes through the
/// tiled microkernels, then dot it against `g` scalar-sequentially.
/// Arithmetic order matches [`reconstruct_at`] exactly (module docs).
pub(crate) fn eval_with_g(
    factors: &[Mat],
    g: &[f32],
    idx: &[usize],
    kernel: Kernel,
    s: &mut Scratch,
) -> f32 {
    let n = factors.len();
    let k0 = factors[0].cols;
    let kp = pad_to_lanes(k0);
    let Scratch { apad, wa, wb } = s;
    // kp-padded copy of the fastest factor row: the zeroed tail keeps
    // padded lanes at exact zero through every product
    apad.clear();
    apad.resize(kp, 0.0);
    apad[..k0].copy_from_slice(factors[0].row(idx[0]));
    let (mut cur, mut next) = (wa, wb);
    cur.clear();
    cur.extend_from_slice(apad);
    for m in 1..n - 1 {
        let fm = factors[m].row(idx[m]);
        next.clear();
        next.resize(fm.len() * cur.len(), 0.0);
        expand_store_tile(kernel, fm, cur, next);
        std::mem::swap(&mut cur, &mut next);
    }
    // scalar dot in ascending K̂-column order, skipping the kp padding
    let mut acc = 0.0f32;
    for (outer, wseg) in cur.chunks_exact(kp).enumerate() {
        let gseg = &g[outer * k0..outer * k0 + k0];
        for (&w, &gv) in wseg[..k0].iter().zip(gseg) {
            acc += w * gv;
        }
    }
    acc
}

/// Per-element scalar oracle: reconstruct one tensor entry,
/// bounds-checked. This is the reference arithmetic the batch engine
/// is pinned against — the same `g` contraction, the same
/// slowest-last product nesting for the Kronecker weight, the same
/// sequential dot.
pub(crate) fn reconstruct_at(
    factors: &[Mat],
    core: &Mat,
    idx: &[usize],
) -> Result<f32, QueryError> {
    validate(factors, idx)?;
    let n = factors.len();
    let mut g = Vec::new();
    slice_weights(core, factors[n - 1].row(idx[n - 1]), &mut g);
    let mut acc = 0.0f32;
    for (col, &gv) in g.iter().enumerate() {
        // decode col into (j_0, …, j_{N−2}), earliest mode fastest, and
        // nest the weight products slowest-last: f_{N−2}·(…·(f_1·f_0))
        let mut rest = col;
        let j0 = rest % factors[0].cols;
        rest /= factors[0].cols;
        let mut w = factors[0].row(idx[0])[j0];
        for (m, f) in factors.iter().enumerate().take(n - 1).skip(1) {
            let jm = rest % f.cols;
            rest /= f.cols;
            w = f.row(idx[m])[jm] * w;
        }
        acc += w * gv;
    }
    Ok(acc)
}

/// Evaluate a batch of queries, grouped by mode-(N−1) slice so each
/// group shares one core contraction. Results come back in input
/// order. The whole batch is validated before anything is evaluated —
/// an error means no query ran.
pub(crate) fn reconstruct_batch(
    factors: &[Mat],
    core: &Mat,
    queries: &[Vec<usize>],
    kernel: Kernel,
) -> Result<Vec<f32>, QueryError> {
    for q in queries {
        validate(factors, q)?;
    }
    let n = factors.len();
    let b = queries.len();
    let mut out = vec![0.0f32; b];
    if b == 0 {
        return Ok(out);
    }
    // group by the last coordinate (stable: ties keep input order)
    let mut order: Vec<usize> = (0..b).collect();
    order.sort_by_key(|&i| queries[i][n - 1]);
    let mut scratch = Scratch::default();
    let mut g: Vec<f32> = Vec::new();
    let mut i = 0usize;
    while i < b {
        let last = queries[order[i]][n - 1];
        slice_weights(core, factors[n - 1].row(last), &mut g);
        while i < b && queries[order[i]][n - 1] == last {
            let q = order[i];
            out[q] = eval_with_g(factors, &g, &queries[q], kernel, &mut scratch);
            i += 1;
        }
    }
    Ok(out)
}
