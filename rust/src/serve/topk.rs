//! Top-K reconstruction queries over one tensor slice.
//!
//! `top_k_per_slice(mode, index, k)` scans every entry of the
//! mode-`mode` slice at coordinate `index` — the recommendation query
//! of the DynamicCF exemplar ("best items for user `index`") — and
//! keeps the `k` largest reconstructed values in a bounded min-heap:
//! O(S·log k) ordering work over a slice of S entries, never
//! materializing the slice. The scan walks the slice in last-mode-major
//! order so the per-slice core contraction of [`super::query`] is
//! reused across the whole fiber of each mode-(N−1) index, and every
//! element evaluation goes through the same tiled weight build as the
//! batch engine — the values are bit-identical to
//! [`reconstruct_at`](super::query::reconstruct_at).
//!
//! Ordering contract: entries rank by value descending, ties broken by
//! ascending (lexicographic) tensor index. This total order makes the
//! result independent of scan order and lets tests pin the heap against
//! a full-sort oracle exactly.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::hooi::kernel::Kernel;
use crate::linalg::Mat;

use super::query::{self, QueryError};

/// One result of a top-K query: a full tensor index and its
/// reconstructed value.
#[derive(Debug, Clone, PartialEq)]
pub struct TopEntry {
    /// Full tensor coordinates of the entry.
    pub idx: Vec<usize>,
    /// Reconstructed value at `idx`.
    pub value: f32,
}

/// Heap element with the ranking order baked into `Ord`: higher value
/// ranks higher; equal values rank the *smaller* index higher, so the
/// retained set is unique regardless of push order.
#[derive(Debug, Clone)]
struct Ranked {
    value: f32,
    idx: Vec<usize>,
}

impl PartialEq for Ranked {
    fn eq(&self, other: &Ranked) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Ranked {}

impl PartialOrd for Ranked {
    fn partial_cmp(&self, other: &Ranked) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ranked {
    fn cmp(&self, other: &Ranked) -> Ordering {
        // total_cmp gives NaN a defined slot instead of poisoning the
        // heap invariant
        self.value
            .total_cmp(&other.value)
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// A bounded min-heap keeping the `k` best [`Ranked`] entries seen so
/// far. `k == 0` keeps nothing.
#[derive(Debug)]
pub(crate) struct BoundedTopK {
    k: usize,
    heap: BinaryHeap<Reverse<Ranked>>,
}

impl BoundedTopK {
    pub(crate) fn new(k: usize) -> BoundedTopK {
        BoundedTopK { k, heap: BinaryHeap::with_capacity(k.saturating_add(1)) }
    }

    /// Offer one candidate; the index is only cloned if it displaces
    /// the current worst retained entry.
    pub(crate) fn push(&mut self, idx: &[usize], value: f32) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(Reverse(Ranked { value, idx: idx.to_vec() }));
            return;
        }
        if let Some(Reverse(worst)) = self.heap.peek() {
            let better = match value.total_cmp(&worst.value) {
                Ordering::Greater => true,
                Ordering::Less => false,
                Ordering::Equal => idx < &worst.idx[..],
            };
            if better {
                self.heap.pop();
                self.heap.push(Reverse(Ranked { value, idx: idx.to_vec() }));
            }
        }
    }

    /// Drain into ranked order: best first.
    pub(crate) fn into_sorted(self) -> Vec<TopEntry> {
        let mut entries: Vec<Ranked> = self.heap.into_iter().map(|r| r.0).collect();
        entries.sort_by(|a, b| b.cmp(a));
        entries
            .into_iter()
            .map(|r| TopEntry { idx: r.idx, value: r.value })
            .collect()
    }
}

/// Scan the mode-`mode` slice at coordinate `index` and return the `k`
/// largest reconstructed entries, best first (ordering contract in the
/// module docs). Returns fewer than `k` entries when the slice is
/// smaller than `k`.
pub(crate) fn top_k_per_slice(
    factors: &[Mat],
    core: &Mat,
    mode: usize,
    index: usize,
    k: usize,
    kernel: Kernel,
) -> Result<Vec<TopEntry>, QueryError> {
    let n = factors.len();
    if mode >= n {
        return Err(QueryError::Mode { got: mode, order: n });
    }
    if index >= factors[mode].rows {
        return Err(QueryError::OutOfRange { mode, index, extent: factors[mode].rows });
    }
    let last = n - 1;
    // free modes vary over their full extents; the pinned `mode` stays
    // at `index`. The last mode is outermost so each slice contraction
    // `g` serves a whole fiber of evaluations.
    let free: Vec<usize> = (0..last).filter(|&m| m != mode).collect();
    let last_range = if mode == last { index..index + 1 } else { 0..factors[last].rows };
    let mut heap = BoundedTopK::new(k);
    let mut g: Vec<f32> = Vec::new();
    let mut scratch = query::Scratch::default();
    let mut idx = vec![0usize; n];
    idx[mode] = index;
    for j_last in last_range {
        idx[last] = j_last;
        query::slice_weights(core, factors[last].row(j_last), &mut g);
        for &m in &free {
            idx[m] = 0;
        }
        'fiber: loop {
            let v = query::eval_with_g(factors, &g, &idx, kernel, &mut scratch);
            heap.push(&idx, v);
            // odometer over the free modes, earliest fastest
            let mut pos = 0usize;
            loop {
                if pos == free.len() {
                    break 'fiber;
                }
                let m = free[pos];
                idx[m] += 1;
                if idx[m] < factors[m].rows {
                    break;
                }
                idx[m] = 0;
                pos += 1;
            }
        }
    }
    Ok(heap.into_sorted())
}
