//! Immutable, snapshot-consistent views of a live decomposition.
//!
//! A [`DecompositionSnapshot`] is published by `TuckerSession` at sweep
//! boundaries (every successful `decompose`/`decompose_more`) as an
//! `Arc` — readers clone the `Arc` and keep serving one consistent
//! factor/core generation while `ingest`/`rebalance`/`decompose_more`
//! mutate the session underneath. Readers never block writers and
//! writers never block readers: publication swaps an `Arc`, nothing is
//! locked, and the snapshot itself has no interior mutability.
//!
//! Each snapshot carries **generation provenance**: the session bumps a
//! monotone generation counter on every mutation (ingest, rebalance,
//! eviction, restore, sweep) and stamps the snapshot with the
//! generation it was taken at, so a serving layer can report how far a
//! resident snapshot lags the live session
//! ([`ServeRecord::generation_lag`](super::ServeRecord::generation_lag)).
//!
//! Snapshots serialize under the same bit-exact discipline as
//! `coordinator::checkpoint`: every f32 round-trips as its u32 bit
//! pattern (and the f64 fit as its u64 bits), so `parse(serialize)`
//! reproduces the snapshot exactly — including -0.0, subnormals, and
//! values that would be perturbed by decimal formatting.

use std::sync::Arc;

use crate::coordinator::checkpoint::{bits_arr, get_usize, parse_bits_arr};
use crate::coordinator::Decomposition;
use crate::hooi::kernel::Kernel;
use crate::linalg::Mat;
use crate::util::json::Json;

use super::query::{self, QueryBatch, QueryError};
use super::topk::{self, TopEntry};

/// Serialization format version of [`DecompositionSnapshot::serialize`].
const SNAPSHOT_VERSION: u64 = 1;

/// An immutable factor/core view frozen at one session generation.
/// Construct via [`TuckerSession::latest_snapshot`] (the published
/// `Arc`) or [`DecompositionSnapshot::from_decomposition`].
///
/// [`TuckerSession::latest_snapshot`]: crate::coordinator::TuckerSession::latest_snapshot
#[derive(Debug, Clone, PartialEq)]
pub struct DecompositionSnapshot {
    generation: u64,
    sweep: usize,
    fit: f64,
    factors: Vec<Mat>,
    core: Mat,
    sigma: Vec<f32>,
}

impl DecompositionSnapshot {
    /// Assemble a snapshot from raw parts — for models that did not
    /// come out of a live session (deserialized artifacts, synthetic
    /// benchmark models). `core` is the flattened G_(N−1)
    /// (K_{N−1} × K̂, earliest mode fastest along the columns);
    /// `factors[n]` is L_n × K_n. `generation` and `sweep` are caller
    /// provenance.
    pub fn from_parts(
        factors: Vec<Mat>,
        core: Mat,
        sigma: Vec<f32>,
        fit: f64,
        generation: u64,
        sweep: usize,
    ) -> DecompositionSnapshot {
        DecompositionSnapshot { generation, sweep, fit, factors, core, sigma }
    }

    /// Freeze a finished [`Decomposition`] into a queryable snapshot,
    /// stamped with the given generation and sweep count. The factor
    /// and core data are cloned — the snapshot shares nothing with the
    /// source.
    pub fn from_decomposition(
        d: &Decomposition,
        generation: u64,
        sweep: usize,
    ) -> DecompositionSnapshot {
        DecompositionSnapshot {
            generation,
            sweep,
            fit: d.fit(),
            factors: d.factors.clone(),
            core: d.core.clone(),
            sigma: d.sigma.clone(),
        }
    }

    /// Wrap into the `Arc` form the serving layer publishes.
    pub fn into_shared(self) -> Arc<DecompositionSnapshot> {
        Arc::new(self)
    }

    /// Session generation this snapshot was taken at.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// HOOI sweeps completed when the snapshot was taken.
    pub fn sweep(&self) -> usize {
        self.sweep
    }

    /// Fit of the decomposition at snapshot time.
    pub fn fit(&self) -> f64 {
        self.fit
    }

    /// Frozen factor matrices (one per mode, L_n × K_n).
    pub fn factors(&self) -> &[Mat] {
        &self.factors
    }

    /// Frozen flattened core, G_(N−1): K_{N−1} × K̂.
    pub fn core(&self) -> &Mat {
        &self.core
    }

    /// Leading singular values of the last-updated mode.
    pub fn sigma(&self) -> &[f32] {
        &self.sigma
    }

    /// Tensor dimensions L_n (factor row counts).
    pub fn dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.rows).collect()
    }

    /// Core ranks K_n (factor column counts).
    pub fn core_dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.cols).collect()
    }

    /// Approximate resident size in bytes — factor + core + sigma
    /// payloads. The serving layer charges this against per-tenant
    /// snapshot-memory quotas.
    pub fn approx_bytes(&self) -> usize {
        let floats = self.factors.iter().map(|f| f.data.len()).sum::<usize>()
            + self.core.data.len()
            + self.sigma.len();
        floats * std::mem::size_of::<f32>() + std::mem::size_of::<DecompositionSnapshot>()
    }

    /// Reconstruct one tensor entry (bounds-checked scalar oracle).
    pub fn reconstruct_at(&self, idx: &[usize]) -> Result<f32, QueryError> {
        query::reconstruct_at(&self.factors, &self.core, idx)
    }

    /// Evaluate a query batch with the host-detected kernel.
    /// Bit-identical to calling [`reconstruct_at`] per query.
    ///
    /// [`reconstruct_at`]: DecompositionSnapshot::reconstruct_at
    pub fn reconstruct_batch(&self, batch: &QueryBatch) -> Result<Vec<f32>, QueryError> {
        self.reconstruct_batch_with(batch, Kernel::from_env())
    }

    /// Evaluate a query batch under an explicit microkernel.
    pub fn reconstruct_batch_with(
        &self,
        batch: &QueryBatch,
        kernel: Kernel,
    ) -> Result<Vec<f32>, QueryError> {
        query::reconstruct_batch(&self.factors, &self.core, batch.queries(), kernel)
    }

    /// The `k` largest reconstructed entries of the mode-`mode` slice
    /// at coordinate `index`, best first (host-detected kernel).
    pub fn top_k_per_slice(
        &self,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<TopEntry>, QueryError> {
        self.top_k_per_slice_with(mode, index, k, Kernel::from_env())
    }

    /// [`top_k_per_slice`](DecompositionSnapshot::top_k_per_slice)
    /// under an explicit microkernel.
    pub fn top_k_per_slice_with(
        &self,
        mode: usize,
        index: usize,
        k: usize,
        kernel: Kernel,
    ) -> Result<Vec<TopEntry>, QueryError> {
        topk::top_k_per_slice(&self.factors, &self.core, mode, index, k, kernel)
    }

    /// Serialize to the bit-exact JSON form (module docs).
    pub fn serialize(&self) -> String {
        let mut j = Json::obj();
        j.set("version", Json::Num(SNAPSHOT_VERSION as f64))
            .set("generation", Json::Str(format!("{:016x}", self.generation)))
            .set("sweep", Json::Num(self.sweep as f64))
            .set("fit_bits", Json::Str(format!("{:016x}", self.fit.to_bits())))
            .set("sigma", bits_arr(&self.sigma))
            .set(
                "factors",
                Json::Arr(self.factors.iter().map(mat_json).collect()),
            )
            .set("core", mat_json(&self.core));
        j.render()
    }

    /// Parse the output of [`serialize`](DecompositionSnapshot::serialize).
    pub fn parse(text: &str) -> Result<DecompositionSnapshot, String> {
        let j = Json::parse(text)?;
        let version = get_usize(&j, "version")?;
        if version as u64 != SNAPSHOT_VERSION {
            return Err(format!("unsupported snapshot version {version}"));
        }
        let generation = parse_hex_u64(&j, "generation")?;
        let sweep = get_usize(&j, "sweep")?;
        let fit = f64::from_bits(parse_hex_u64(&j, "fit_bits")?);
        let sigma = parse_bits_arr(j.get("sigma").ok_or("missing field 'sigma'")?)?;
        let factors = match j.get("factors") {
            Some(Json::Arr(fs)) => fs
                .iter()
                .map(parse_mat)
                .collect::<Result<Vec<_>, String>>()?,
            _ => return Err("missing array field 'factors'".into()),
        };
        let core = parse_mat(j.get("core").ok_or("missing field 'core'")?)?;
        Ok(DecompositionSnapshot { generation, sweep, fit, factors, core, sigma })
    }
}

fn mat_json(m: &Mat) -> Json {
    let mut j = Json::obj();
    j.set("rows", Json::Num(m.rows as f64))
        .set("cols", Json::Num(m.cols as f64))
        .set("data", bits_arr(&m.data));
    j
}

fn parse_mat(j: &Json) -> Result<Mat, String> {
    let rows = get_usize(j, "rows")?;
    let cols = get_usize(j, "cols")?;
    let data = parse_bits_arr(j.get("data").ok_or("matrix missing 'data'")?)?;
    if data.len() != rows * cols {
        return Err(format!("matrix data length {} != {rows}x{cols}", data.len()));
    }
    Ok(Mat { rows, cols, data })
}

fn parse_hex_u64(j: &Json, key: &str) -> Result<u64, String> {
    match j.get(key) {
        Some(Json::Str(s)) => {
            u64::from_str_radix(s, 16).map_err(|e| format!("bad hex field '{key}': {e}"))
        }
        _ => Err(format!("missing string field '{key}'")),
    }
}
