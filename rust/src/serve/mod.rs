//! Query-serving layer: the decomposition as a live, readable index.
//!
//! Everything upstream of this module produces a Tucker decomposition;
//! this module is where it gets *read* at scale, the way the DynamicCF
//! exemplar serves recommendations from an HOSVD model:
//!
//! * [`query`] — the batched reconstruction engine: a [`QueryBatch`]
//!   is grouped by mode-(N−1) slice so each group shares one core
//!   contraction, and every query reduces to a Kronecker-chain GEMV
//!   through the lane-blocked microkernels. Pinned **bit-identical**
//!   to the bounds-checked per-element oracle under every kernel.
//! * [`topk`] — bounded-heap top-K over a tensor slice, the
//!   "best items for this user" query.
//! * [`snapshot`] — [`DecompositionSnapshot`]: immutable
//!   `Arc`-published views with generation provenance and bit-exact
//!   serialization, so reads stay consistent while the session
//!   ingests, rebalances, and refines.
//! * [`tenant`] — [`ServeCoordinator`]: many tenants' sessions behind
//!   one thread + snapshot-memory budget, with typed admission
//!   rejection, LRU snapshot eviction, and per-tenant [`ServeRecord`]
//!   telemetry.
#![warn(clippy::unwrap_used)]

pub mod query;
pub mod snapshot;
pub mod tenant;
pub mod topk;

pub use query::{QueryBatch, QueryError};
pub use snapshot::DecompositionSnapshot;
pub use tenant::{AdmissionError, ServeBudget, ServeCoordinator, ServeError, ServeRecord};
pub use topk::TopEntry;
