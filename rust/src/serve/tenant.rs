//! Multi-tenant serving coordinator: many live sessions, one budget.
//!
//! A [`ServeCoordinator`] owns a fleet of [`TuckerSession`]s — one per
//! tenant — and arbitrates two global resources across them:
//!
//! * **worker threads** — each tenant reserves a fixed worker count at
//!   admission; the sum across tenants can never exceed
//!   [`ServeBudget::worker_threads`];
//! * **resident snapshot memory** — each tenant reserves a byte quota
//!   at admission (Σ quotas ≤ [`ServeBudget::snapshot_bytes`]), and
//!   published [`DecompositionSnapshot`]s are cached against it with
//!   LRU eviction of cold generations (the latest snapshot is pinned —
//!   a tenant with any snapshot can always serve).
//!
//! Admission is all-or-nothing with a typed [`AdmissionError`]; a
//! rejected tenant's session is handed back untouched. Per-tenant
//! [`ServeRecord`]s accumulate serving telemetry: queries served,
//! batch sizes, p50/p99 batch latency, and how far the serving
//! snapshot's generation lags the live session.
//!
//! Budgets resolve through the usual typed-option > env > default
//! precedence ([`ServeBudget::resolve`]): `TUCKER_SERVE_THREADS`,
//! `TUCKER_SERVE_SNAPSHOT_BYTES`, `TUCKER_SERVE_BATCH`.

use std::sync::Arc;
use crate::util::timer::Stopwatch;

use crate::coordinator::TuckerSession;
use crate::hooi::kernel::Kernel;
use crate::tensor::TensorDelta;
use crate::util::env;

use super::query::{self, QueryBatch, QueryError};
use super::snapshot::DecompositionSnapshot;
use super::topk::TopEntry;

/// Global resource budget of a [`ServeCoordinator`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeBudget {
    /// Worker threads available for reservation across all tenants.
    pub worker_threads: usize,
    /// Resident snapshot memory available for quota across all
    /// tenants, in bytes.
    pub snapshot_bytes: usize,
    /// Largest query batch evaluated in one engine call; longer
    /// batches are split into chunks of this size (results are
    /// unaffected — queries are independent).
    pub max_batch: usize,
}

impl ServeBudget {
    /// Typed-option > env > default resolution for every field:
    /// `Some(v)` wins, else `TUCKER_SERVE_THREADS` /
    /// `TUCKER_SERVE_SNAPSHOT_BYTES` / `TUCKER_SERVE_BATCH`, else the
    /// defaults (16 threads, 64 MiB, 1024 queries).
    pub fn resolve(
        worker_threads: Option<usize>,
        snapshot_bytes: Option<usize>,
        max_batch: Option<usize>,
    ) -> ServeBudget {
        ServeBudget {
            worker_threads: env::serve_threads(worker_threads),
            snapshot_bytes: env::serve_snapshot_bytes(snapshot_bytes),
            max_batch: env::serve_batch(max_batch),
        }
    }

    /// Env > default resolution (no typed overrides).
    pub fn from_env() -> ServeBudget {
        ServeBudget::resolve(None, None, None)
    }
}

/// Typed admission rejection: the coordinator refuses a tenant rather
/// than oversubscribe a budget. The session is returned untouched
/// inside [`ServeCoordinator::admit`]'s error path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// A tenant with this name is already admitted.
    DuplicateTenant(String),
    /// A tenant must reserve at least one worker thread.
    ZeroWorkers(String),
    /// Requested workers exceed the unreserved thread budget.
    ThreadBudget {
        tenant: String,
        requested: usize,
        available: usize,
    },
    /// Requested snapshot quota exceeds the unreserved memory budget.
    MemoryBudget {
        tenant: String,
        requested: usize,
        available: usize,
    },
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::DuplicateTenant(t) => write!(f, "tenant '{t}' already admitted"),
            AdmissionError::ZeroWorkers(t) => {
                write!(f, "tenant '{t}' must reserve at least one worker thread")
            }
            AdmissionError::ThreadBudget { tenant, requested, available } => write!(
                f,
                "tenant '{tenant}' requested {requested} worker threads, {available} available"
            ),
            AdmissionError::MemoryBudget { tenant, requested, available } => write!(
                f,
                "tenant '{tenant}' requested {requested} snapshot bytes, {available} available"
            ),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Serving-path failure for an admitted (or unknown) tenant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// No tenant admitted under this name.
    UnknownTenant(String),
    /// The tenant has never published a snapshot — run
    /// [`ServeCoordinator::decompose`] (or `refresh` after a direct
    /// session decompose) first.
    NoSnapshot(String),
    /// The query itself violated the snapshot's shape contract.
    Query(QueryError),
    /// The tenant's session failed to decompose.
    Session(String),
    /// The tenant's session rejected the ingested delta.
    Ingest(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownTenant(t) => write!(f, "unknown tenant '{t}'"),
            ServeError::NoSnapshot(t) => {
                write!(f, "tenant '{t}' has no published snapshot to serve from")
            }
            ServeError::Query(e) => write!(f, "query error: {e}"),
            ServeError::Session(e) => write!(f, "session error: {e}"),
            ServeError::Ingest(e) => write!(f, "ingest error: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<QueryError> for ServeError {
    fn from(e: QueryError) -> ServeError {
        ServeError::Query(e)
    }
}

/// Per-tenant serving telemetry.
#[derive(Debug, Clone, Default)]
pub struct ServeRecord {
    /// Point queries answered through the batch engine (batch
    /// entries, summed; top-K scans count under `topk_queries`).
    pub queries_served: u64,
    /// Engine batches evaluated (a user batch longer than
    /// [`ServeBudget::max_batch`] counts once per chunk).
    pub batches: u64,
    /// Largest engine batch evaluated.
    pub max_batch: usize,
    /// Top-K slice scans answered.
    pub topk_queries: u64,
    /// Generation of the snapshot the last query was served from.
    pub snapshot_generation: u64,
    /// Live session generation at that moment.
    pub session_generation: u64,
    /// Per-engine-call wall latencies, seconds.
    latencies: Vec<f64>,
}

impl ServeRecord {
    /// How many mutations the serving snapshot lags the live session.
    pub fn generation_lag(&self) -> u64 {
        self.session_generation.saturating_sub(self.snapshot_generation)
    }

    /// Mean queries per engine batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.queries_served as f64 / self.batches as f64
        }
    }

    /// Median engine-call latency, seconds (0.0 before any call).
    pub fn p50_latency(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 99th-percentile engine-call latency, seconds.
    pub fn p99_latency(&self) -> f64 {
        self.quantile(0.99)
    }

    fn quantile(&self, q: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let mut sorted = self.latencies.clone();
        sorted.sort_by(f64::total_cmp);
        let pos = (sorted.len() - 1) as f64 * q;
        sorted[pos.round() as usize]
    }

    fn observe(&mut self, queries: usize, secs: f64) {
        self.queries_served += queries as u64;
        self.batches += 1;
        self.max_batch = self.max_batch.max(queries);
        self.latencies.push(secs);
    }
}

/// A cached snapshot generation with its LRU stamp.
#[derive(Debug)]
struct CachedSnapshot {
    snap: Arc<DecompositionSnapshot>,
    bytes: usize,
    last_used: u64,
}

/// One admitted tenant: its live session, reservations, resident
/// snapshot cache (publication order, latest last), and telemetry.
#[derive(Debug)]
struct Tenant {
    name: String,
    session: TuckerSession,
    workers: usize,
    quota_bytes: usize,
    snapshots: Vec<CachedSnapshot>,
    record: ServeRecord,
}

impl Tenant {
    fn resident_bytes(&self) -> usize {
        self.snapshots.iter().map(|c| c.bytes).sum()
    }

    /// Evict coldest non-latest snapshots until the tenant fits its
    /// quota. The latest snapshot is pinned even if it alone exceeds
    /// the quota — a tenant that has decomposed can always serve.
    fn evict_cold(&mut self) {
        while self.resident_bytes() > self.quota_bytes && self.snapshots.len() > 1 {
            let last = self.snapshots.len() - 1;
            let coldest = self.snapshots[..last]
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| c.last_used)
                .map(|(i, _)| i);
            match coldest {
                Some(i) => {
                    self.snapshots.remove(i);
                }
                None => break,
            }
        }
    }
}

/// The multi-tenant serving front end (module docs).
#[derive(Debug)]
pub struct ServeCoordinator {
    budget: ServeBudget,
    kernel: Kernel,
    clock: u64,
    tenants: Vec<Tenant>,
}

impl ServeCoordinator {
    /// A coordinator with the given budget, serving through the
    /// host-detected kernel (`TUCKER_KERNEL` honored).
    pub fn new(budget: ServeBudget) -> ServeCoordinator {
        ServeCoordinator { budget, kernel: Kernel::from_env(), clock: 0, tenants: Vec::new() }
    }

    /// Override the serving microkernel (builder style).
    pub fn with_kernel(mut self, kernel: Kernel) -> ServeCoordinator {
        self.kernel = kernel;
        self
    }

    /// The budget this coordinator enforces.
    pub fn budget(&self) -> ServeBudget {
        self.budget
    }

    /// Worker threads currently reserved across tenants.
    pub fn threads_reserved(&self) -> usize {
        self.tenants.iter().map(|t| t.workers).sum()
    }

    /// Snapshot bytes currently reserved (Σ tenant quotas).
    pub fn bytes_reserved(&self) -> usize {
        self.tenants.iter().map(|t| t.quota_bytes).sum()
    }

    /// Snapshot bytes actually resident across all tenant caches.
    pub fn resident_bytes(&self) -> usize {
        self.tenants.iter().map(|t| t.resident_bytes()).sum()
    }

    /// Admitted tenant names, admission order.
    pub fn tenants(&self) -> Vec<&str> {
        self.tenants.iter().map(|t| t.name.as_str()).collect()
    }

    /// Admit a tenant, reserving `workers` threads and `quota_bytes`
    /// of snapshot memory for it. All-or-nothing: on `Err` nothing was
    /// reserved and the session was dropped back to the caller via the
    /// error — admit again with a smaller reservation.
    pub fn admit(
        &mut self,
        name: &str,
        session: TuckerSession,
        workers: usize,
        quota_bytes: usize,
    ) -> Result<(), (TuckerSession, AdmissionError)> {
        if self.tenants.iter().any(|t| t.name == name) {
            return Err((session, AdmissionError::DuplicateTenant(name.to_string())));
        }
        if workers == 0 {
            return Err((session, AdmissionError::ZeroWorkers(name.to_string())));
        }
        let threads_free = self.budget.worker_threads.saturating_sub(self.threads_reserved());
        if workers > threads_free {
            return Err((
                session,
                AdmissionError::ThreadBudget {
                    tenant: name.to_string(),
                    requested: workers,
                    available: threads_free,
                },
            ));
        }
        let bytes_free = self.budget.snapshot_bytes.saturating_sub(self.bytes_reserved());
        if quota_bytes > bytes_free {
            return Err((
                session,
                AdmissionError::MemoryBudget {
                    tenant: name.to_string(),
                    requested: quota_bytes,
                    available: bytes_free,
                },
            ));
        }
        self.tenants.push(Tenant {
            name: name.to_string(),
            session,
            workers,
            quota_bytes,
            snapshots: Vec::new(),
            record: ServeRecord::default(),
        });
        Ok(())
    }

    /// Remove a tenant, releasing its reservations and returning its
    /// session to the caller.
    pub fn evict_tenant(&mut self, name: &str) -> Result<TuckerSession, ServeError> {
        let i = self.index_of(name)?;
        Ok(self.tenants.remove(i).session)
    }

    /// Borrow a tenant's live session.
    pub fn session(&self, name: &str) -> Result<&TuckerSession, ServeError> {
        let i = self.index_of(name)?;
        Ok(&self.tenants[i].session)
    }

    /// Mutably borrow a tenant's live session. After direct mutations,
    /// call [`refresh`](ServeCoordinator::refresh) to publish the new
    /// snapshot into the serving cache.
    pub fn session_mut(&mut self, name: &str) -> Result<&mut TuckerSession, ServeError> {
        let i = self.index_of(name)?;
        Ok(&mut self.tenants[i].session)
    }

    /// Run the tenant's session to a sweep boundary and publish the
    /// resulting snapshot.
    pub fn decompose(&mut self, name: &str) -> Result<Arc<DecompositionSnapshot>, ServeError> {
        let i = self.index_of(name)?;
        self.tenants[i]
            .session
            .try_decompose()
            .map_err(|e| ServeError::Session(e.to_string()))?;
        self.refresh(name)
    }

    /// Refine the tenant's decomposition by `invocations` more HOOI
    /// invocations and publish the resulting snapshot.
    pub fn decompose_more(
        &mut self,
        name: &str,
        invocations: usize,
    ) -> Result<Arc<DecompositionSnapshot>, ServeError> {
        let i = self.index_of(name)?;
        self.tenants[i]
            .session
            .try_decompose_more(invocations)
            .map_err(|e| ServeError::Session(e.to_string()))?;
        self.refresh(name)
    }

    /// Stream a delta into the tenant's session. Resident snapshots
    /// keep serving the pre-ingest generations — the refreshed view
    /// appears at the next decompose/refresh.
    pub fn ingest(&mut self, name: &str, delta: &TensorDelta) -> Result<(), ServeError> {
        let i = self.index_of(name)?;
        self.tenants[i]
            .session
            .ingest(delta)
            .map(|_| ())
            .map_err(|e| ServeError::Ingest(e.to_string()))
    }

    /// Publish the session's latest snapshot into the tenant's serving
    /// cache (no-op if that generation is already resident), then
    /// LRU-evict cold generations beyond the tenant's quota.
    pub fn refresh(&mut self, name: &str) -> Result<Arc<DecompositionSnapshot>, ServeError> {
        let i = self.index_of(name)?;
        self.clock += 1;
        let clock = self.clock;
        let t = &mut self.tenants[i];
        let snap = t
            .session
            .latest_snapshot()
            .ok_or_else(|| ServeError::NoSnapshot(name.to_string()))?;
        let resident = t.snapshots.last().map(|c| c.snap.generation());
        if resident == Some(snap.generation()) {
            if let Some(latest) = t.snapshots.last_mut() {
                latest.last_used = clock;
            }
        } else {
            let bytes = snap.approx_bytes();
            t.snapshots.push(CachedSnapshot { snap: Arc::clone(&snap), bytes, last_used: clock });
            t.evict_cold();
        }
        Ok(snap)
    }

    /// Generations resident in a tenant's cache, publication order
    /// (latest last). Empty for unknown tenants.
    pub fn resident_generations(&self, name: &str) -> Vec<u64> {
        match self.index_of(name) {
            Ok(i) => self.tenants[i].snapshots.iter().map(|c| c.snap.generation()).collect(),
            Err(_) => Vec::new(),
        }
    }

    /// Fetch a specific resident generation (touches its LRU stamp),
    /// e.g. to keep serving an older view a client has pinned.
    pub fn fetch(&mut self, name: &str, generation: u64) -> Option<Arc<DecompositionSnapshot>> {
        let i = self.index_of(name).ok()?;
        self.clock += 1;
        let clock = self.clock;
        let cached = self.tenants[i]
            .snapshots
            .iter_mut()
            .find(|c| c.snap.generation() == generation)?;
        cached.last_used = clock;
        Some(Arc::clone(&cached.snap))
    }

    /// Serve a query batch from the tenant's latest resident snapshot.
    /// Batches longer than [`ServeBudget::max_batch`] are evaluated in
    /// chunks; results come back in input order either way.
    pub fn query(&mut self, name: &str, batch: &QueryBatch) -> Result<Vec<f32>, ServeError> {
        let i = self.index_of(name)?;
        self.clock += 1;
        let clock = self.clock;
        let chunk_len = self.budget.max_batch.max(1);
        let kernel = self.kernel;
        let t = &mut self.tenants[i];
        let latest = t
            .snapshots
            .last_mut()
            .ok_or_else(|| ServeError::NoSnapshot(name.to_string()))?;
        latest.last_used = clock;
        let snap = Arc::clone(&latest.snap);
        let mut out = Vec::with_capacity(batch.len());
        for chunk in batch.queries().chunks(chunk_len) {
            let start = Stopwatch::start();
            let vals = query::reconstruct_batch(snap.factors(), snap.core(), chunk, kernel)?;
            t.record.observe(chunk.len(), start.seconds());
            out.extend_from_slice(&vals);
        }
        t.record.snapshot_generation = snap.generation();
        t.record.session_generation = t.session.generation();
        Ok(out)
    }

    /// Serve a top-K slice query from the tenant's latest resident
    /// snapshot.
    pub fn top_k(
        &mut self,
        name: &str,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<TopEntry>, ServeError> {
        let i = self.index_of(name)?;
        self.clock += 1;
        let clock = self.clock;
        let kernel = self.kernel;
        let t = &mut self.tenants[i];
        let latest = t
            .snapshots
            .last_mut()
            .ok_or_else(|| ServeError::NoSnapshot(name.to_string()))?;
        latest.last_used = clock;
        let snap = Arc::clone(&latest.snap);
        let start = Stopwatch::start();
        let entries = snap.top_k_per_slice_with(mode, index, k, kernel)?;
        t.record.topk_queries += 1;
        t.record.latencies.push(start.seconds());
        t.record.snapshot_generation = snap.generation();
        t.record.session_generation = t.session.generation();
        Ok(entries)
    }

    /// Serving telemetry for a tenant.
    pub fn record(&self, name: &str) -> Result<&ServeRecord, ServeError> {
        let i = self.index_of(name)?;
        Ok(&self.tenants[i].record)
    }

    fn index_of(&self, name: &str) -> Result<usize, ServeError> {
        self.tenants
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| ServeError::UnknownTenant(name.to_string()))
    }
}
