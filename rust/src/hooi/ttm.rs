//! TTM component (paper §3): per-mode assembly of the *truncated local
//! penultimate matrix* Z^p via the Kronecker-product reformulation (Eq. 1):
//!
//!   Z_(n)[l,:] = Σ_{e ∈ Slice_n^l} contr_n(e),
//!   contr_n(e) = val(e) · ⊗_{j≠n} F_j[l_j,:]
//!
//! Each rank p materializes only the rows of slices it shares (R_n^p rows)
//! — the truncation that makes the SVD oracle cheap. The contribution
//! batches run through the compute engine (PJRT artifacts on the hot path,
//! native reference otherwise); the gather of factor rows and the
//! scatter-add into Z^p stay in rust.
//!
//! ## Layout contracts
//!
//! - **Z layout**: `LocalZ.rows` is ascending and distinct; row r of `z`
//!   is the K̂-long slice of global row `rows[r]`, with the Kronecker
//!   factors ordered earliest-other-mode fastest (3-D: column
//!   `ca + cb·K`; 4-D: `ca + cb·K + cc·K²` — see python kernels/ref.py).
//! - **Batch padding**: the fixed-shape engine contract requires full
//!   batches; the tail slots past `fill` are neutralized *only* by their
//!   `vals` entry being zeroed — the row buffers beyond `fill`
//!   deliberately carry stale data from earlier batches.
//!   [`flush_contrib_batch`] makes that contract explicit by checking
//!   the padded outputs — a debug assertion on the legacy path, a hard
//!   error when fed from the lane-blocked plan streams (whose own
//!   val==0 lane padding extends the same contract).
//! - **Plan layer** ([`super::plan`]): a `TtmPlan` precompiles, per
//!   (mode, rank), the same assembly as [`assemble_local_z`] — rows
//!   sorted/deduped once, elements CSR-grouped by local row, and within
//!   each row sorted by the slowest-varying other-mode coordinate(s) so
//!   equal-coordinate runs share their slow factor rows. Plan-based
//!   assembly must produce the same `rows` and (up to f32 reassociation)
//!   the same `z` as this module's element-order path, which therefore
//!   stays as the correctness oracle (tests/plan_equivalence.rs).

use super::kernel::{axpy_any, Kernel};
use crate::linalg::Mat;
use crate::runtime::Engine;
use crate::tensor::SparseTensor;
use crate::util::float::exactly_zero_f32;

/// Truncated local penultimate matrix of one rank.
#[derive(Debug, Clone)]
pub struct LocalZ {
    /// Global slice index of each local row, ascending.
    pub rows: Vec<u32>,
    /// R^p × K̂ dense local copy.
    pub z: Mat,
}

impl LocalZ {
    pub fn empty(khat: usize) -> LocalZ {
        LocalZ { rows: Vec::new(), z: Mat::zeros(0, khat) }
    }

    /// Local row index of global slice l (binary search).
    #[inline]
    pub fn local_row(&self, l: u32) -> Option<usize> {
        self.rows.binary_search(&l).ok()
    }
}

/// Modes other than `n`, ascending — the Kronecker factor order
/// (layout contract: earliest mode fastest; see python kernels/ref.py).
pub fn other_modes(ndim: usize, n: usize) -> Vec<usize> {
    (0..ndim).filter(|&m| m != n).collect()
}

/// K̂_n = Π_{j≠n} K_j for a uniform core length K. See
/// [`crate::hooi::ranks::khat_of`] for the per-mode general form.
pub fn khat(k: usize, ndim: usize) -> usize {
    k.pow(ndim as u32 - 1)
}

/// Assemble Z^p for `mode` from the rank's elements, batching the
/// Kronecker contributions through `engine`.
pub fn assemble_local_z(
    t: &SparseTensor,
    mode: usize,
    elems: &[u32],
    factors: &[Mat],
    k: usize,
    engine: &Engine,
) -> LocalZ {
    if engine.prefers_fused_ttm() {
        // §Perf: the native engine skips the batch materialization the
        // fixed-shape PJRT contract requires (ablate_runtime quantifies).
        return assemble_local_z_fused(t, mode, elems, factors);
    }
    let ndim = t.ndim();
    let kh = khat(k, ndim);
    // local row mapping: sorted distinct slice coords of this rank
    let mut rows: Vec<u32> = elems.iter().map(|&e| t.coord(mode, e as usize)).collect();
    rows.sort_unstable();
    rows.dedup();
    let mut z = Mat::zeros(rows.len(), kh);
    if elems.is_empty() {
        return LocalZ { rows, z };
    }
    let others = other_modes(ndim, mode);
    let bsz = engine.ttm_batch_size(ndim, k);
    let mut rows_a = vec![0.0f32; bsz * k];
    let mut rows_b = vec![0.0f32; bsz * k];
    let mut rows_c = vec![0.0f32; bsz * k]; // 4-D only
    let mut vals = vec![0.0f32; bsz];
    let mut targets = vec![0u32; bsz];
    let mut fill = 0usize;

    for &eu in elems {
        let e = eu as usize;
        let l = t.coord(mode, e);
        let target = rows.binary_search(&l).expect("row mapping complete") as u32;
        // gather factor rows in ascending other-mode order
        for (slot, &m) in others.iter().enumerate() {
            let frow = factors[m].row(t.coord(m, e) as usize);
            let dst = match slot {
                0 => &mut rows_a[fill * k..(fill + 1) * k],
                1 => &mut rows_b[fill * k..(fill + 1) * k],
                _ => &mut rows_c[fill * k..(fill + 1) * k],
            };
            dst.copy_from_slice(frow);
        }
        vals[fill] = t.vals[e];
        targets[fill] = target;
        fill += 1;
        if fill == bsz {
            flush_contrib_batch(
                engine, ndim, k, kh, fill, &rows_a, &rows_b, &rows_c, &mut vals,
                &targets, &mut z, false, Kernel::Scalar,
            );
            fill = 0;
        }
    }
    flush_contrib_batch(
        engine, ndim, k, kh, fill, &rows_a, &rows_b, &rows_c, &mut vals,
        &targets, &mut z, false, Kernel::Scalar,
    );
    LocalZ { rows, z }
}

/// Run one padded contribution batch through the engine and scatter-add
/// the first `fill` results into their target Z rows.
///
/// Padding contract: slots `fill..` are neutralized *only* by zeroing
/// their `vals` entry here — `rows_a`/`rows_b`/`rows_c` beyond `fill`
/// deliberately keep stale data from earlier batches (the fixed-shape
/// PJRT artifacts require full batches and multiply every row by its
/// val). The padded outputs are verified to really be zero, so an engine
/// that mishandles val==0 (or stale non-finite row data that turns 0·x
/// into NaN) fails loudly: in debug builds always, and in *all* builds
/// when `strict` is set — the lane-blocked plan layer passes `strict`
/// because its own streams extend the same val==0 contract to lane
/// padding, and a violation there is a data-layout bug, not a
/// debug-only hazard. (Full batches have no padded slots, so the strict
/// check only ever scans the final partial batch.)
///
/// The scatter-add into Z runs K̂-tiled through `kernel`
/// ([`axpy_any`]): whole-lane prefixes through the dispatched SIMD
/// tile, the K̂ % LANES tail scalar. With a == 1.0 the FMA tiles round
/// exactly like the scalar add (round(y + 1·x) = round(y + x),
/// element-wise), so any kernel choice is bit-identical here — the
/// legacy oracle path passes `Kernel::Scalar`, the plan layer its
/// workspace kernel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn flush_contrib_batch(
    engine: &Engine,
    ndim: usize,
    k: usize,
    kh: usize,
    fill: usize,
    rows_a: &[f32],
    rows_b: &[f32],
    rows_c: &[f32],
    vals: &mut [f32],
    targets: &[u32],
    z: &mut Mat,
    strict: bool,
    kernel: Kernel,
) {
    if fill == 0 {
        return;
    }
    // zero-val padding rows contribute nothing by construction
    for v in vals[fill..].iter_mut() {
        *v = 0.0;
    }
    let contribs = if ndim == 3 {
        engine.kron3_batch(k, rows_a, rows_b, vals)
    } else {
        engine.kron4_batch(k, rows_a, rows_b, rows_c, vals)
    };
    let padding_clean = || contribs[fill * kh..].iter().all(|&x| exactly_zero_f32(x));
    if strict {
        assert!(
            padding_clean(),
            "stale-buffer hazard: padding slots {fill}.. produced nonzero \
             contributions (val==0 padding contract violated)"
        );
    } else {
        debug_assert!(
            padding_clean(),
            "stale-buffer hazard: padding slots {fill}.. produced nonzero \
             contributions (val==0 padding contract violated)"
        );
    }
    for i in 0..fill {
        let target = targets[i] as usize;
        axpy_any(kernel, 1.0, &contribs[i * kh..(i + 1) * kh], z.row_mut(target));
    }
}

/// Fused native assembly: accumulates each element's outer product
/// directly into its Z^p row without materializing the contribution batch.
/// Baseline for the runtime ablation (benches/ablate_runtime.rs).
///
/// The per-mode ranks are read off the factor matrices themselves
/// (`factors[j].cols = K_j`), so this path is the correctness oracle for
/// ragged `CoreRanks::PerMode` cores as well as the uniform case. The
/// generalized K̂ layout keeps the earliest other mode fastest:
/// 3-D column `ca + cb·K_fast`, 4-D `ca + cb·K_fast + cc·K_fast·K_slow`.
pub fn assemble_local_z_fused(
    t: &SparseTensor,
    mode: usize,
    elems: &[u32],
    factors: &[Mat],
) -> LocalZ {
    let ndim = t.ndim();
    let others = other_modes(ndim, mode);
    let kh: usize = others.iter().map(|&m| factors[m].cols).product();
    let mut rows: Vec<u32> = elems.iter().map(|&e| t.coord(mode, e as usize)).collect();
    rows.sort_unstable();
    rows.dedup();
    let mut z = Mat::zeros(rows.len(), kh);
    for &eu in elems {
        let e = eu as usize;
        let l = t.coord(mode, e);
        let target = rows.binary_search(&l).unwrap();
        let v = t.vals[e];
        let zrow = z.row_mut(target);
        match others.len() {
            2 => {
                let ra = factors[others[0]].row(t.coord(others[0], e) as usize);
                let rb = factors[others[1]].row(t.coord(others[1], e) as usize);
                let ka = ra.len();
                for (cb, &bv) in rb.iter().enumerate() {
                    let w = v * bv;
                    let seg = &mut zrow[cb * ka..(cb + 1) * ka];
                    for (ca, &av) in ra.iter().enumerate() {
                        seg[ca] += w * av;
                    }
                }
            }
            3 => {
                let ra = factors[others[0]].row(t.coord(others[0], e) as usize);
                let rb = factors[others[1]].row(t.coord(others[1], e) as usize);
                let rc = factors[others[2]].row(t.coord(others[2], e) as usize);
                let (ka, kb) = (ra.len(), rb.len());
                for (cc, &cv) in rc.iter().enumerate() {
                    let wv = v * cv;
                    for (cb, &bv) in rb.iter().enumerate() {
                        let w = wv * bv;
                        let base = (cc * kb + cb) * ka;
                        let seg = &mut zrow[base..base + ka];
                        for (ca, &av) in ra.iter().enumerate() {
                            seg[ca] += w * av;
                        }
                    }
                }
            }
            _ => panic!("HOOI supports 3-D and 4-D tensors"),
        }
    }
    LocalZ { rows, z }
}

/// Dense reference: the full penultimate matrix Z_(n) (L_n × K̂), summing
/// every element's contribution — the correctness oracle for the
/// distributed assembly (global Z must equal the sum of local copies).
/// Ranks are inferred from the factor widths like
/// [`assemble_local_z_fused`].
pub fn dense_penultimate(t: &SparseTensor, mode: usize, factors: &[Mat]) -> Mat {
    let all: Vec<u32> = (0..t.nnz() as u32).collect();
    let local = assemble_local_z_fused(t, mode, &all, factors);
    // scatter local rows into the full L_n × K̂ matrix
    let mut full = Mat::zeros(t.dims[mode] as usize, local.z.cols);
    for (r, &l) in local.rows.iter().enumerate() {
        full.row_mut(l as usize).copy_from_slice(local.z.row(r));
    }
    full
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{axpy, orthonormal_random};
    use crate::util::rng::Rng;

    fn setup(dims: Vec<u32>, nnz: usize, k: usize, seed: u64) -> (SparseTensor, Vec<Mat>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(dims, nnz, &mut rng);
        let factors = t
            .dims
            .iter()
            .map(|&l| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        (t, factors)
    }

    #[test]
    fn batched_matches_fused_3d() {
        let (t, factors) = setup(vec![12, 9, 7], 400, 5, 1);
        let elems: Vec<u32> = (0..400).collect();
        for mode in 0..3 {
            let a =
                assemble_local_z(&t, mode, &elems, &factors, 5, &Engine::NativeBatched);
            let b = assemble_local_z_fused(&t, mode, &elems, &factors);
            assert_eq!(a.rows, b.rows);
            assert!(a.z.max_abs_diff(&b.z) < 1e-4, "mode {mode}");
        }
    }

    #[test]
    fn batched_matches_fused_4d() {
        let (t, factors) = setup(vec![8, 6, 5, 4], 300, 3, 2);
        let elems: Vec<u32> = (0..300).collect();
        for mode in 0..4 {
            let a =
                assemble_local_z(&t, mode, &elems, &factors, 3, &Engine::NativeBatched);
            let b = assemble_local_z_fused(&t, mode, &elems, &factors);
            assert!(a.z.max_abs_diff(&b.z) < 1e-4, "mode {mode}");
        }
    }

    #[test]
    fn local_copies_sum_to_global() {
        // Eq. 1 / §3: the global penultimate matrix is the sum of the
        // per-rank local copies, whatever the element partition.
        let (t, factors) = setup(vec![10, 8, 6], 500, 4, 3);
        let mut rng = Rng::new(9);
        let p = 4;
        let assign: Vec<u32> = (0..t.nnz()).map(|_| rng.below(p) as u32).collect();
        let mode = 1;
        let dense = dense_penultimate(&t, mode, &factors);
        let mut summed = Mat::zeros(dense.rows, dense.cols);
        for rank in 0..p as u32 {
            let elems: Vec<u32> = (0..t.nnz() as u32)
                .filter(|&e| assign[e as usize] == rank)
                .collect();
            let local = assemble_local_z(&t, mode, &elems, &factors, 4, &Engine::Native);
            for (r, &l) in local.rows.iter().enumerate() {
                axpy(1.0, local.z.row(r), summed.row_mut(l as usize));
            }
        }
        assert!(summed.max_abs_diff(&dense) < 1e-4);
    }

    #[test]
    fn truncation_only_stores_shared_slices() {
        let (t, factors) = setup(vec![50, 8, 6], 60, 4, 4);
        let elems: Vec<u32> = (0..10).collect();
        let local = assemble_local_z(&t, 0, &elems, &factors, 4, &Engine::Native);
        assert!(local.rows.len() <= 10);
        assert_eq!(local.z.rows, local.rows.len());
        // every stored row corresponds to a slice this rank touches
        for &e in &elems {
            assert!(local.local_row(t.coord(0, e as usize)).is_some());
        }
    }

    #[test]
    fn ttm_mode_unfolding_identity() {
        // For a tensor with a single element of value v at (i, j, k),
        // Z_(0)[i, :] = v * F1[j,:] ⊗ F2[k,:] with mode-1 fastest.
        let mut t = SparseTensor::new(vec![3, 4, 5]);
        t.push(&[2, 1, 3], 2.0);
        let mut rng = Rng::new(5);
        let k = 3;
        let factors: Vec<Mat> = t
            .dims
            .iter()
            .map(|&l| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        let dense = dense_penultimate(&t, 0, &factors);
        let f1 = factors[1].row(1);
        let f2 = factors[2].row(3);
        for c2 in 0..k {
            for c1 in 0..k {
                let want = 2.0 * f1[c1] * f2[c2];
                let got = dense.get(2, c1 + c2 * k);
                assert!((got - want).abs() < 1e-5);
            }
        }
        // all other rows zero
        for l in [0usize, 1] {
            assert!(dense.row(l).iter().all(|&x| exactly_zero_f32(x)));
        }
    }

    #[test]
    fn partial_final_batch_reuses_stale_buffers_safely() {
        // PJRT-shaped path: batch size 4096, 5000 elements ⇒ one full
        // flush, then a partial final flush whose row buffers beyond
        // `fill` still hold the previous batch's data. The val==0
        // padding contract (asserted in flush_contrib_batch) must keep
        // those stale rows from contributing.
        let (t, factors) = setup(vec![40, 30, 20], 5000, 4, 7);
        let bsz = Engine::NativeBatched.ttm_batch_size(3, 4);
        assert!(t.nnz() > bsz && t.nnz() % bsz != 0, "partial final batch");
        let elems: Vec<u32> = (0..t.nnz() as u32).collect();
        for mode in 0..3 {
            let a = assemble_local_z(&t, mode, &elems, &factors, 4, &Engine::NativeBatched);
            let b = assemble_local_z_fused(&t, mode, &elems, &factors);
            assert_eq!(a.rows, b.rows);
            assert!(a.z.max_abs_diff(&b.z) < 1e-3, "mode {mode}");
        }
    }

    #[test]
    fn partial_final_batch_4d() {
        let (t, factors) = setup(vec![12, 10, 8, 6], 4500, 3, 8);
        assert!(t.nnz() > Engine::NativeBatched.ttm_batch_size(4, 3));
        let elems: Vec<u32> = (0..t.nnz() as u32).collect();
        let a = assemble_local_z(&t, 1, &elems, &factors, 3, &Engine::NativeBatched);
        let b = assemble_local_z_fused(&t, 1, &elems, &factors);
        assert_eq!(a.rows, b.rows);
        assert!(a.z.max_abs_diff(&b.z) < 1e-3);
    }

    #[test]
    fn empty_rank_is_empty_local() {
        let (t, factors) = setup(vec![5, 5, 5], 50, 3, 6);
        let local = assemble_local_z(&t, 0, &[], &factors, 3, &Engine::Native);
        assert_eq!(local.rows.len(), 0);
        assert_eq!(local.z.rows, 0);
    }
}
