//! The distributed HOOI procedure (paper Fig 2) on the Kaya–Uçar framework
//! (§3): TTM via the Kronecker reformulation, matrix-free Lanczos SVD over
//! the sum-distributed penultimate matrix, factor-matrix transfer, and the
//! end-of-run core computation.

pub mod csf;
pub mod driver;
pub mod fm;
pub mod kernel;
pub mod lanczos;
pub mod plan;
pub mod ranks;
pub mod ttm;

pub use driver::{
    charge_plan_compilation, charge_shared_plan_compilation, memory_model,
    memory_model_shared, memory_model_with, prepare_modes, prepare_modes_unplanned,
    prepare_modes_unplanned_with_sharers, prepare_modes_with_executor,
    prepare_modes_with_sharers, prepare_shared_plans,
    run_hooi, DeltaStats, HooiConfig, HooiOutcome, HooiSnapshot, HooiState, MemoryReport,
    ModeDelta, ModeState, TensorAccounting,
};
pub use csf::{check_csf_invariants, CsfLower, CsfMaint, CsfModeView, CsfPlan, CsfView, SharedPlans};
pub use fm::{fm_pattern, FmPattern};
pub use kernel::{contrib_run, contrib_run_scalar, pad_to_lanes, Kernel, LANES};
pub use lanczos::{lanczos_svd, LanczosResult, Oracle};
pub use plan::{
    check_lane_invariants, check_lane_invariants_for, check_lane_invariants_over,
    for_each_element_over, fused_flops, ModePlan, PlanWorkspace, TtmPlan,
};
pub use ranks::{khat_of, CoreRanks};
pub use ttm::{assemble_local_z, assemble_local_z_fused, dense_penultimate, khat, LocalZ};
