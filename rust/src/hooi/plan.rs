//! Precompiled per-(mode, rank) TTM plans — the HOOI hot-path layer.
//!
//! `assemble_local_z` pays three per-invocation costs that are invariant
//! across HOOI sweeps: (1) sorting + deduplicating the rank's slice rows,
//! (2) one binary search per nonzero to find its local Z row, and (3) a
//! cold walk of the COO coordinate streams. The paper's central
//! observation (§7.2) is that this per-rank TTM assembly *dominates* HOOI
//! execution, so anything invariant must be hoisted out of the sweep loop
//! — the same build-once/execute-many structure the dense companion work
//! (arXiv:1707.05594) uses for its data layouts.
//!
//! A [`TtmPlan`] is built once per (mode, rank) in `prepare_modes` and
//! holds:
//! - the rank's distinct slice rows (ascending — the `LocalZ` contract),
//! - a CSR `row_ptr` over the rank's elements grouped by local row, so
//!   assembly streams contributions row by row with zero searches,
//! - per-element factor-row indices and values flattened in plan order
//!   (no COO indirection on the hot path),
//! - and, within each row, elements sorted by the slowest-varying
//!   other-mode coordinate(s). Equal-coordinate runs then share their
//!   slow Kronecker factor row, so the fused kernel accumulates the
//!   value-weighted fast-factor sum once per run (K flops/element) and
//!   expands it by the shared slow row(s) once per run (K²/K³ flops/run)
//!   — hoisting the `v·b[cb]` (3-D) / `v·c[cc]` (4-D) partial products
//!   out of the per-element loop entirely.
//!
//! [`PlanWorkspace`] gives each rank reusable batch buffers and a Z
//! arena, replacing the fresh allocations the legacy path makes per mode
//! per sweep. `benches/ablate_plan.rs` quantifies plan vs. naive
//! assembly; `tests/plan_equivalence.rs` pins the equivalence with the
//! element-order oracle (`assemble_local_z_fused`).

use super::ttm::{flush_contrib_batch, khat, other_modes, LocalZ};
use crate::linalg::{axpy, Mat};
use crate::runtime::Engine;
use crate::tensor::SparseTensor;

/// Reusable per-rank scratch: fused-kernel accumulators, batched-path
/// buffers, and the Z arena (flat buffers recycled across modes/sweeps).
#[derive(Debug, Default)]
pub struct PlanWorkspace {
    /// Fast-factor accumulator (K).
    acc: Vec<f32>,
    /// 4-D middle accumulator (K²).
    acc2: Vec<f32>,
    rows_a: Vec<f32>,
    rows_b: Vec<f32>,
    rows_c: Vec<f32>,
    bvals: Vec<f32>,
    targets: Vec<u32>,
    z_pool: Vec<Vec<f32>>,
}

impl PlanWorkspace {
    pub fn new() -> PlanWorkspace {
        PlanWorkspace::default()
    }

    /// Pop a zeroed buffer of exactly `len` floats from the Z arena.
    fn take_z(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.z_pool.pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a finished `LocalZ` buffer to the arena so the next
    /// assembly (any mode, any sweep) reuses the allocation.
    pub fn recycle(&mut self, z: Mat) {
        self.z_pool.push(z.data);
    }

    fn ensure_batch(&mut self, bsz: usize, k: usize) {
        self.rows_a.resize(bsz * k, 0.0);
        self.rows_b.resize(bsz * k, 0.0);
        self.rows_c.resize(bsz * k, 0.0);
        self.bvals.resize(bsz, 0.0);
        self.targets.resize(bsz, 0);
    }
}

/// Precompiled assembly plan for one (mode, rank): CSR-grouped, run-sorted
/// element streams (layout documented in the module docs).
#[derive(Debug, Clone)]
pub struct TtmPlan {
    pub mode: usize,
    pub k: usize,
    /// K̂ = K^{N−1}.
    pub khat: usize,
    /// Modes other than `mode`, ascending (Kronecker factor order).
    pub others: Vec<usize>,
    /// Global slice index of each local row, ascending.
    pub rows: Vec<u32>,
    /// CSR: plan slots of local row r are `row_ptr[r]..row_ptr[r+1]`.
    pub row_ptr: Vec<u32>,
    /// Factor-row index stream per other mode (plan order; `fidx[0]` is
    /// the fastest-varying Kronecker factor, matching `other_modes`).
    pub fidx: Vec<Vec<u32>>,
    /// Element values in plan order.
    pub vals: Vec<f32>,
}

impl TtmPlan {
    /// Build the plan for `mode` over this rank's `elems`. O(|E| log s)
    /// where s is the largest per-row segment — paid once, amortized over
    /// every sweep and invocation.
    pub fn build(t: &SparseTensor, mode: usize, elems: &[u32], k: usize) -> TtmPlan {
        let ndim = t.ndim();
        assert!(
            ndim == 3 || ndim == 4,
            "HOOI supports 3-D and 4-D tensors"
        );
        let others = other_modes(ndim, mode);
        let kh = khat(k, ndim);
        let mut rows: Vec<u32> =
            elems.iter().map(|&e| t.coord(mode, e as usize)).collect();
        rows.sort_unstable();
        rows.dedup();
        // dense global→local row map (L_n is always addressable)
        let mut local_of = vec![u32::MAX; t.dims[mode] as usize];
        for (i, &l) in rows.iter().enumerate() {
            local_of[l as usize] = i as u32;
        }
        // counting sort of elements into their local rows
        let mut row_ptr = vec![0u32; rows.len() + 1];
        for &e in elems {
            let r = local_of[t.coord(mode, e as usize) as usize] as usize;
            row_ptr[r + 1] += 1;
        }
        for r in 0..rows.len() {
            row_ptr[r + 1] += row_ptr[r];
        }
        let mut cursor: Vec<u32> = row_ptr[..rows.len()].to_vec();
        let mut order = vec![0u32; elems.len()];
        for &e in elems {
            let r = local_of[t.coord(mode, e as usize) as usize] as usize;
            order[cursor[r] as usize] = e;
            cursor[r] += 1;
        }
        // within each row: sort by the slowest-varying other-mode
        // coordinate(s) so equal-coordinate runs share slow factor rows
        for r in 0..rows.len() {
            let seg = &mut order[row_ptr[r] as usize..row_ptr[r + 1] as usize];
            if others.len() == 2 {
                seg.sort_unstable_by_key(|&e| t.coord(others[1], e as usize));
            } else {
                seg.sort_unstable_by_key(|&e| {
                    (t.coord(others[2], e as usize), t.coord(others[1], e as usize))
                });
            }
        }
        let fidx: Vec<Vec<u32>> = others
            .iter()
            .map(|&m| order.iter().map(|&e| t.coord(m, e as usize)).collect())
            .collect();
        let vals: Vec<f32> = order.iter().map(|&e| t.vals[e as usize]).collect();
        // element ids themselves are not retained: the streams above are
        // all the hot path needs, and dropping them saves nnz·4 bytes
        // per (mode, rank) for the lifetime of the run
        TtmPlan { mode, k, khat: kh, others, rows, row_ptr, fidx, vals }
    }

    /// Elements covered by this plan.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Assemble Z^p, dispatching on the engine like `assemble_local_z`
    /// (fused native kernel vs. the padded-batch engine contract).
    pub fn assemble(
        &self,
        factors: &[Mat],
        engine: &Engine,
        ws: &mut PlanWorkspace,
    ) -> LocalZ {
        if engine.prefers_fused_ttm() {
            self.assemble_fused(factors, ws)
        } else {
            self.assemble_batched(factors, engine, ws)
        }
    }

    /// Fused plan kernel: stream rows via CSR, hoist slow-factor products
    /// across equal-coordinate runs (see module docs for the count).
    pub fn assemble_fused(&self, factors: &[Mat], ws: &mut PlanWorkspace) -> LocalZ {
        let k = self.k;
        let kh = self.khat;
        let nrows = self.rows.len();
        let data = ws.take_z(nrows * kh);
        let mut z = Mat { rows: nrows, cols: kh, data };
        ws.acc.clear();
        ws.acc.resize(k, 0.0);
        if self.others.len() == 2 {
            let (oa, ob) = (self.others[0], self.others[1]);
            let (fa, fb) = (&self.fidx[0], &self.fidx[1]);
            let acc = &mut ws.acc;
            for r in 0..nrows {
                let (lo, hi) =
                    (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
                let zrow = z.row_mut(r);
                let mut i = lo;
                while i < hi {
                    let bi = fb[i];
                    acc.fill(0.0);
                    while i < hi && fb[i] == bi {
                        axpy(self.vals[i], factors[oa].row(fa[i] as usize), acc);
                        i += 1;
                    }
                    let rb = factors[ob].row(bi as usize);
                    for (cb, &bv) in rb.iter().enumerate() {
                        axpy(bv, acc, &mut zrow[cb * k..(cb + 1) * k]);
                    }
                }
            }
        } else {
            let (oa, ob, oc) = (self.others[0], self.others[1], self.others[2]);
            let (fa, fb, fc) = (&self.fidx[0], &self.fidx[1], &self.fidx[2]);
            let kk = k * k;
            ws.acc2.clear();
            ws.acc2.resize(kk, 0.0);
            let PlanWorkspace { acc, acc2, .. } = ws;
            for r in 0..nrows {
                let (lo, hi) =
                    (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
                let zrow = z.row_mut(r);
                let mut i = lo;
                while i < hi {
                    let ci = fc[i];
                    acc2.fill(0.0);
                    while i < hi && fc[i] == ci {
                        let bi = fb[i];
                        acc.fill(0.0);
                        while i < hi && fc[i] == ci && fb[i] == bi {
                            axpy(self.vals[i], factors[oa].row(fa[i] as usize), acc);
                            i += 1;
                        }
                        let rb = factors[ob].row(bi as usize);
                        for (cb, &bv) in rb.iter().enumerate() {
                            axpy(bv, acc, &mut acc2[cb * k..(cb + 1) * k]);
                        }
                    }
                    let rc = factors[oc].row(ci as usize);
                    for (cc, &cv) in rc.iter().enumerate() {
                        axpy(cv, acc2, &mut zrow[cc * kk..(cc + 1) * kk]);
                    }
                }
            }
        }
        LocalZ { rows: self.rows.clone(), z }
    }

    /// Batched plan path: same padded fixed-shape engine contract as
    /// `assemble_local_z`, but fed from the precompiled streams (no
    /// searches, targets come straight from the CSR walk).
    pub fn assemble_batched(
        &self,
        factors: &[Mat],
        engine: &Engine,
        ws: &mut PlanWorkspace,
    ) -> LocalZ {
        let k = self.k;
        let kh = self.khat;
        let ndim = self.others.len() + 1;
        let nrows = self.rows.len();
        let data = ws.take_z(nrows * kh);
        let mut z = Mat { rows: nrows, cols: kh, data };
        if self.vals.is_empty() {
            return LocalZ { rows: self.rows.clone(), z };
        }
        let bsz = engine.ttm_batch_size(ndim, k);
        ws.ensure_batch(bsz, k);
        let PlanWorkspace { rows_a, rows_b, rows_c, bvals, targets, .. } = ws;
        let mut fill = 0usize;
        for r in 0..nrows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                for (slot, stream) in self.fidx.iter().enumerate() {
                    let frow = factors[self.others[slot]].row(stream[i] as usize);
                    let dst = match slot {
                        0 => &mut rows_a[fill * k..(fill + 1) * k],
                        1 => &mut rows_b[fill * k..(fill + 1) * k],
                        _ => &mut rows_c[fill * k..(fill + 1) * k],
                    };
                    dst.copy_from_slice(frow);
                }
                bvals[fill] = self.vals[i];
                targets[fill] = r as u32;
                fill += 1;
                if fill == bsz {
                    flush_contrib_batch(
                        engine, ndim, k, kh, fill, rows_a, rows_b, rows_c, bvals,
                        targets, &mut z,
                    );
                    fill = 0;
                }
            }
        }
        flush_contrib_batch(
            engine, ndim, k, kh, fill, rows_a, rows_b, rows_c, bvals, targets,
            &mut z,
        );
        LocalZ { rows: self.rows.clone(), z }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormal_random;
    use crate::util::rng::Rng;

    fn setup(dims: Vec<u32>, nnz: usize, k: usize, seed: u64) -> (SparseTensor, Vec<Mat>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(dims, nnz, &mut rng);
        let factors = t
            .dims
            .iter()
            .map(|&l| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        (t, factors)
    }

    #[test]
    fn plan_layout_invariants_3d() {
        let (t, _) = setup(vec![15, 11, 7], 500, 4, 1);
        let elems: Vec<u32> = (0..500).collect();
        for mode in 0..3 {
            let plan = TtmPlan::build(&t, mode, &elems, 4);
            assert_eq!(plan.nnz(), 500);
            assert_eq!(*plan.row_ptr.last().unwrap() as usize, 500);
            // rows ascending & distinct
            assert!(plan.rows.windows(2).all(|w| w[0] < w[1]));
            for r in 0..plan.rows.len() {
                let (lo, hi) = (plan.row_ptr[r] as usize, plan.row_ptr[r + 1] as usize);
                assert!(lo < hi, "every stored row has elements");
                // the row's plan slots carry exactly the slice's elements:
                // multiset of (other-mode coords, value bits) must match
                let mut got: Vec<(u32, u32, u32)> = (lo..hi)
                    .map(|i| (plan.fidx[0][i], plan.fidx[1][i], plan.vals[i].to_bits()))
                    .collect();
                let mut want: Vec<(u32, u32, u32)> = (0..t.nnz())
                    .filter(|&e| t.coord(mode, e) == plan.rows[r])
                    .map(|e| {
                        (
                            t.coord(plan.others[0], e),
                            t.coord(plan.others[1], e),
                            t.vals[e].to_bits(),
                        )
                    })
                    .collect();
                got.sort_unstable();
                want.sort_unstable();
                assert_eq!(got, want, "mode {mode} row {r}");
                // slow coordinate non-decreasing within the row
                let slow = plan.fidx.last().unwrap();
                assert!(slow[lo..hi].windows(2).all(|w| w[0] <= w[1]));
            }
        }
    }

    #[test]
    fn fused_plan_matches_element_order_oracle() {
        let (t, factors) = setup(vec![12, 9, 7], 400, 5, 2);
        let elems: Vec<u32> = (0..400).collect();
        let mut ws = PlanWorkspace::new();
        for mode in 0..3 {
            let plan = TtmPlan::build(&t, mode, &elems, 5);
            let a = plan.assemble_fused(&factors, &mut ws);
            let b = crate::hooi::ttm::assemble_local_z_fused(&t, mode, &elems, &factors, 5);
            assert_eq!(a.rows, b.rows);
            assert!(a.z.max_abs_diff(&b.z) < 1e-4, "mode {mode}");
        }
    }

    #[test]
    fn fused_plan_matches_oracle_4d() {
        let (t, factors) = setup(vec![8, 6, 5, 4], 300, 3, 3);
        let elems: Vec<u32> = (0..300).collect();
        let mut ws = PlanWorkspace::new();
        for mode in 0..4 {
            let plan = TtmPlan::build(&t, mode, &elems, 3);
            let a = plan.assemble_fused(&factors, &mut ws);
            let b = crate::hooi::ttm::assemble_local_z_fused(&t, mode, &elems, &factors, 3);
            assert_eq!(a.rows, b.rows);
            assert!(a.z.max_abs_diff(&b.z) < 1e-4, "mode {mode}");
        }
    }

    #[test]
    fn empty_plan_yields_empty_local() {
        let (t, factors) = setup(vec![5, 5, 5], 50, 3, 4);
        let plan = TtmPlan::build(&t, 0, &[], 3);
        let mut ws = PlanWorkspace::new();
        let local = plan.assemble(&factors, &Engine::Native, &mut ws);
        assert!(local.rows.is_empty());
        assert_eq!(local.z.rows, 0);
        assert_eq!(local.z.cols, 9);
    }

    #[test]
    fn z_arena_reuses_buffers_across_assemblies() {
        let (t, factors) = setup(vec![10, 8, 6], 300, 4, 5);
        let elems: Vec<u32> = (0..300).collect();
        let plan = TtmPlan::build(&t, 0, &elems, 4);
        let mut ws = PlanWorkspace::new();
        let first = plan.assemble_fused(&factors, &mut ws);
        let ptr = first.z.data.as_ptr();
        let want = first.z.clone();
        ws.recycle(first.z);
        let second = plan.assemble_fused(&factors, &mut ws);
        assert_eq!(second.z.data.as_ptr(), ptr, "arena buffer reused");
        assert_eq!(second.z.data, want.data, "recycled buffer fully re-zeroed");
    }
}
