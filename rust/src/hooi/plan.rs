//! Precompiled per-(mode, rank) TTM plans — the HOOI hot-path layer.
//!
//! `assemble_local_z` pays three per-invocation costs that are invariant
//! across HOOI sweeps: (1) sorting + deduplicating the rank's slice rows,
//! (2) one binary search per nonzero to find its local Z row, and (3) a
//! cold walk of the COO coordinate streams. The paper's central
//! observation (§7.2) is that this per-rank TTM assembly *dominates* HOOI
//! execution, so anything invariant must be hoisted out of the sweep loop
//! — the same build-once/execute-many structure the dense companion work
//! (arXiv:1707.05594) uses for its data layouts.
//!
//! ## Lane-blocked plan layout
//!
//! A [`TtmPlan`] is built once per (mode, rank) in `prepare_modes` and
//! stores the rank's elements in a layout shaped for the 8-lane
//! microkernels of [`super::kernel`]:
//!
//! - `rows` — the rank's distinct slice rows, ascending (the `LocalZ`
//!   contract);
//! - elements are grouped by local row and, within each row, sorted by
//!   the slowest-varying other-mode coordinate(s). Maximal
//!   equal-coordinate stretches become **runs** that share their slow
//!   Kronecker factor row(s), so the fused kernel accumulates the
//!   value-weighted fast-factor sum once per run (K flops/element) and
//!   expands it by the shared slow row(s) once per run (K²/K³ flops/run);
//! - per run, the fast-factor index stream `fa` and value stream `vals`
//!   are padded to a whole number of [`LANES`]-wide slots. Padding slots
//!   carry `val == 0.0` (extending the batch path's val==0 padding
//!   contract) and repeat the run's last real factor index, so they
//!   contribute exactly nothing while letting the accumulation loop run
//!   `chunks_exact(LANES)` with no per-element scalar tail;
//! - for 3-D, `row_runs` maps each local row to its run range; for 4-D
//!   an extra level (`outer_c`/`outer_ptr`) groups runs by the
//!   slowest-varying coordinate so its factor row is hoisted too.
//!
//! At assembly time the fast-mode factor is copied into a `kp`-stride
//! table (`kp = ⌈K/LANES⌉·LANES` — the K̂ column tile width) and each Z
//! row is accumulated in a `kp`-stride tile buffer, then compacted into
//! the dense K̂ layout. Every microkernel call is therefore a whole
//! number of 8-wide tiles. Kernel selection (scalar oracle / portable /
//! AVX2 / NEON) lives on the [`PlanWorkspace`] — see [`super::kernel`]
//! for dispatch rules.
//!
//! [`PlanWorkspace`] also gives each rank reusable batch buffers and a Z
//! arena, replacing the fresh allocations the legacy path makes per mode
//! per sweep. `benches/ablate_plan.rs` quantifies plan vs. naive assembly
//! and scalar vs. tiled kernels; `tests/plan_equivalence.rs` and
//! `tests/kernel_equivalence.rs` pin the equivalences against the
//! element-order oracle (`assemble_local_z_fused`).

use super::kernel::{accumulate_run, pad_to_lanes, Kernel, PortableTile, Tile, LANES};
use super::ranks::CoreRanks;
use super::ttm::{flush_contrib_batch, other_modes, LocalZ};
use crate::linalg::{axpy, Mat};
use crate::runtime::Engine;
use crate::tensor::SparseTensor;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use super::kernel::Avx2Tile;
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
use super::kernel::NeonTile;

/// Reusable per-rank scratch: the selected microkernel, fused-kernel
/// accumulators and tile buffers, batched-path buffers, and the Z arena
/// (flat buffers recycled across modes/sweeps).
#[derive(Debug)]
pub struct PlanWorkspace {
    /// Microkernel this rank executes (threaded into every assembly;
    /// recorded by the cluster's concurrency report).
    kernel: Kernel,
    /// Fast-factor accumulator (kp tiled / K scalar).
    acc: Vec<f32>,
    /// 4-D middle accumulator (K·kp tiled / K² scalar).
    acc2: Vec<f32>,
    /// kp-stride padded copy of the fast-mode factor (tiled path).
    apad: Vec<f32>,
    /// kp-stride Z row tile, compacted into the K̂ layout per row.
    ztile: Vec<f32>,
    rows_a: Vec<f32>,
    rows_b: Vec<f32>,
    rows_c: Vec<f32>,
    bvals: Vec<f32>,
    targets: Vec<u32>,
    z_pool: Vec<Vec<f32>>,
    /// Per-fiber contribution cache (kp-stride, one slot per spine run)
    /// — filled by the first shared-tree view assembly of a sweep,
    /// reused by the later non-leaf modes (`hooi::csf`).
    contrib: Vec<f32>,
    contrib_runs: usize,
    contrib_stride: usize,
    contrib_ready: bool,
}

impl Default for PlanWorkspace {
    fn default() -> Self {
        PlanWorkspace::new()
    }
}

impl PlanWorkspace {
    /// Workspace with the host-selected kernel ([`Kernel::from_env`]:
    /// best detected SIMD tier, `TUCKER_KERNEL` override honored).
    pub fn new() -> PlanWorkspace {
        PlanWorkspace::with_kernel(Kernel::from_env())
    }

    /// Workspace pinned to a specific kernel (ablations, oracles).
    pub fn with_kernel(kernel: Kernel) -> PlanWorkspace {
        PlanWorkspace {
            kernel,
            acc: Vec::new(),
            acc2: Vec::new(),
            apad: Vec::new(),
            ztile: Vec::new(),
            rows_a: Vec::new(),
            rows_b: Vec::new(),
            rows_c: Vec::new(),
            bvals: Vec::new(),
            targets: Vec::new(),
            z_pool: Vec::new(),
            contrib: Vec::new(),
            contrib_runs: 0,
            contrib_stride: 0,
            contrib_ready: false,
        }
    }

    /// Drop the per-fiber contribution cache (sweep restart, factor
    /// update of the fast mode, or any structural plan change).
    pub(crate) fn contrib_invalidate(&mut self) {
        self.contrib_ready = false;
    }

    /// Is the cache valid for a plan with this many fibers at this
    /// column stride? (Defensive shape guard on top of the sweep-order
    /// lifecycle `hooi::csf` maintains.)
    pub(crate) fn contrib_matches(&self, runs: usize, stride: usize) -> bool {
        self.contrib_ready && self.contrib_runs == runs && self.contrib_stride == stride
    }

    /// Size the cache for a fill pass (`runs` fibers × `stride` floats).
    /// The fill itself happens inside the fused assembly; the caller
    /// marks the cache live with [`PlanWorkspace::contrib_commit`] once
    /// that assembly returns.
    pub(crate) fn contrib_prepare(&mut self, runs: usize, stride: usize) {
        self.contrib_ready = false;
        self.contrib_runs = runs;
        self.contrib_stride = stride;
        self.contrib.clear();
        self.contrib.resize(runs * stride, 0.0);
    }

    pub(crate) fn contrib_commit(&mut self) {
        self.contrib_ready = true;
    }

    /// The kernel this workspace dispatches to.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Pop a zeroed buffer of exactly `len` floats from the Z arena.
    fn take_z(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.z_pool.pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a finished `LocalZ` buffer to the arena so the next
    /// assembly (any mode, any sweep) reuses the allocation.
    pub fn recycle(&mut self, z: Mat) {
        self.z_pool.push(z.data);
    }

    fn ensure_batch(&mut self, bsz: usize, k: usize) {
        self.rows_a.resize(bsz * k, 0.0);
        self.rows_b.resize(bsz * k, 0.0);
        self.rows_c.resize(bsz * k, 0.0);
        self.bvals.resize(bsz, 0.0);
        self.targets.resize(bsz, 0);
    }

    /// Copy factor `f` into the kp-stride padded table (tail columns
    /// zeroed so padded lanes multiply to exact zeros).
    fn prepare_apad(&mut self, f: &Mat, kp: usize) {
        self.apad.clear();
        self.apad.resize(f.rows * kp, 0.0);
        for r in 0..f.rows {
            self.apad[r * kp..r * kp + f.cols].copy_from_slice(f.row(r));
        }
    }
}

/// Precompiled assembly plan for one (mode, rank): lane-blocked,
/// run-sorted element streams (layout documented in the module docs).
///
/// `PartialEq` compares the full stream encoding — the form the
/// incremental-invalidation tests use to pin "spliced plan ≡ freshly
/// built plan" bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct TtmPlan {
    pub mode: usize,
    /// Core rank K_j of each *other* mode, in [`TtmPlan::others`] order
    /// (fast Kronecker factor first). Uniform cores have all entries
    /// equal; `CoreRanks::PerMode` makes them ragged.
    pub oks: Vec<usize>,
    /// K̂_n = Π_{j≠n} K_j.
    pub khat: usize,
    /// The fast-mode rank `oks[0]` rounded up to a whole number of
    /// [`LANES`] — the column tile width of the padded factor table,
    /// accumulators and Z row tiles.
    pub kp: usize,
    /// Modes other than `mode`, ascending (Kronecker factor order).
    pub others: Vec<usize>,
    /// Global slice index of each local row, ascending.
    pub rows: Vec<u32>,
    /// 3-D: run range of local row r is `row_runs[r]..row_runs[r+1]`.
    /// 4-D: *outer*-run range of local row r.
    pub row_runs: Vec<u32>,
    /// 4-D only: slowest-mode factor row per outer run (empty for 3-D).
    pub outer_c: Vec<u32>,
    /// 4-D only: run range per outer run (empty for 3-D).
    pub outer_ptr: Vec<u32>,
    /// Slow-mode factor row per run.
    pub run_b: Vec<u32>,
    /// Real (unpadded) element count per run.
    pub run_len: Vec<u32>,
    /// Slot range of run j is `slot_ptr[j]..slot_ptr[j+1]`; every range
    /// length is a multiple of [`LANES`].
    pub slot_ptr: Vec<u32>,
    /// Fast-mode factor row per slot (padding slots repeat the run's
    /// last real index).
    pub fa: Vec<u32>,
    /// Element value per slot (padding slots are exactly 0.0 — the lane
    /// extension of the val==0 padding contract).
    pub vals: Vec<f32>,
    /// Total real elements (Σ `run_len`).
    nnz: usize,
}

impl TtmPlan {
    /// Build the plan for `mode` over this rank's `elems` with a uniform
    /// core length K — see [`TtmPlan::build_with`] for per-mode ranks.
    pub fn build(t: &SparseTensor, mode: usize, elems: &[u32], k: usize) -> TtmPlan {
        TtmPlan::build_with(t, mode, elems, &CoreRanks::Uniform(k))
    }

    /// Build the plan for `mode` over this rank's `elems` under the
    /// given per-mode core ranks. O(|E| log s) where s is the largest
    /// per-row segment — paid once, amortized over every sweep and
    /// invocation. The element stream layout is rank-independent; only
    /// the kp column tiling (`kp = ⌈K_fast/LANES⌉·LANES`) and the K̂
    /// width depend on the core choice.
    pub fn build_with(
        t: &SparseTensor,
        mode: usize,
        elems: &[u32],
        core: &CoreRanks,
    ) -> TtmPlan {
        let ndim = t.ndim();
        assert!(
            ndim == 3 || ndim == 4,
            "HOOI supports 3-D and 4-D tensors"
        );
        let ks = core.resolve(ndim);
        let others = other_modes(ndim, mode);
        let oks: Vec<usize> = others.iter().map(|&m| ks[m]).collect();
        let kh: usize = oks.iter().product();
        let kp = pad_to_lanes(oks[0]);
        let mut rows: Vec<u32> =
            elems.iter().map(|&e| t.coord(mode, e as usize)).collect();
        rows.sort_unstable();
        rows.dedup();
        // dense global→local row map (L_n is always addressable)
        let mut local_of = vec![u32::MAX; t.dims[mode] as usize];
        for (i, &l) in rows.iter().enumerate() {
            local_of[l as usize] = i as u32;
        }
        // counting sort of elements into their local rows
        let mut row_ptr = vec![0u32; rows.len() + 1];
        for &e in elems {
            let r = local_of[t.coord(mode, e as usize) as usize] as usize;
            row_ptr[r + 1] += 1;
        }
        for r in 0..rows.len() {
            row_ptr[r + 1] += row_ptr[r];
        }
        let mut cursor: Vec<u32> = row_ptr[..rows.len()].to_vec();
        let mut order = vec![0u32; elems.len()];
        for &e in elems {
            let r = local_of[t.coord(mode, e as usize) as usize] as usize;
            order[cursor[r] as usize] = e;
            cursor[r] += 1;
        }
        // within each row: sort by the slowest-varying other-mode
        // coordinate(s) so equal-coordinate runs share slow factor rows.
        // The sort must be *stable*: equal-key elements keep element-id
        // order (the per-rank lists are id-ordered within a slice), which
        // is what lets `splice_append` place a streamed element at its
        // run's tail and produce the exact stream a fresh build would.
        for r in 0..rows.len() {
            let seg = &mut order[row_ptr[r] as usize..row_ptr[r + 1] as usize];
            if others.len() == 2 {
                seg.sort_by_key(|&e| t.coord(others[1], e as usize));
            } else {
                seg.sort_by_key(|&e| {
                    (t.coord(others[2], e as usize), t.coord(others[1], e as usize))
                });
            }
        }

        // --- lane-blocked encoding of the ordered streams ---
        // Pad every run's fa/vals block to a whole number of LANES slots
        // (val==0, index repeated) so the tiled kernels never see a
        // scalar tail. Element ids are not retained: the streams below
        // are all the hot path needs.
        fn pad_run(fa: &mut Vec<u32>, vals: &mut Vec<f32>, len: usize) {
            let rem = len % LANES;
            if rem != 0 {
                let last = *fa.last().expect("padding a non-empty run");
                for _ in rem..LANES {
                    fa.push(last);
                    vals.push(0.0);
                }
            }
        }
        let fast = others[0];
        let slow = others[1];
        let mut row_runs = Vec::with_capacity(rows.len() + 1);
        row_runs.push(0u32);
        let mut outer_c: Vec<u32> = Vec::new();
        let mut outer_ptr: Vec<u32> = Vec::new();
        let mut run_b: Vec<u32> = Vec::new();
        let mut run_len: Vec<u32> = Vec::new();
        let mut slot_ptr = vec![0u32];
        let mut fa: Vec<u32> = Vec::with_capacity(elems.len());
        let mut vals: Vec<f32> = Vec::with_capacity(elems.len());
        if ndim == 3 {
            for r in 0..rows.len() {
                let seg = &order[row_ptr[r] as usize..row_ptr[r + 1] as usize];
                let mut i = 0usize;
                while i < seg.len() {
                    let b = t.coord(slow, seg[i] as usize);
                    let start = i;
                    while i < seg.len() && t.coord(slow, seg[i] as usize) == b {
                        let e = seg[i] as usize;
                        fa.push(t.coord(fast, e));
                        vals.push(t.vals[e]);
                        i += 1;
                    }
                    pad_run(&mut fa, &mut vals, i - start);
                    run_b.push(b);
                    run_len.push((i - start) as u32);
                    slot_ptr.push(fa.len() as u32);
                }
                row_runs.push(run_b.len() as u32);
            }
        } else {
            let slowest = others[2];
            outer_ptr.push(0);
            for r in 0..rows.len() {
                let seg = &order[row_ptr[r] as usize..row_ptr[r + 1] as usize];
                let mut i = 0usize;
                while i < seg.len() {
                    let c = t.coord(slowest, seg[i] as usize);
                    while i < seg.len() && t.coord(slowest, seg[i] as usize) == c {
                        let b = t.coord(slow, seg[i] as usize);
                        let start = i;
                        while i < seg.len()
                            && t.coord(slowest, seg[i] as usize) == c
                            && t.coord(slow, seg[i] as usize) == b
                        {
                            let e = seg[i] as usize;
                            fa.push(t.coord(fast, e));
                            vals.push(t.vals[e]);
                            i += 1;
                        }
                        pad_run(&mut fa, &mut vals, i - start);
                        run_b.push(b);
                        run_len.push((i - start) as u32);
                        slot_ptr.push(fa.len() as u32);
                    }
                    outer_c.push(c);
                    outer_ptr.push(run_b.len() as u32);
                }
                row_runs.push(outer_c.len() as u32);
            }
        }
        TtmPlan {
            mode,
            oks,
            khat: kh,
            kp,
            others,
            rows,
            row_runs,
            outer_c,
            outer_ptr,
            run_b,
            run_len,
            slot_ptr,
            fa,
            vals,
            nnz: elems.len(),
        }
    }

    /// Real elements covered by this plan (padding slots excluded).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Total stream slots including lane padding.
    pub fn padded_slots(&self) -> usize {
        self.vals.len()
    }

    /// Bytes this plan's streams occupy (every entry is a 4-byte index
    /// or value), lane padding included — what `memory_model` charges
    /// per (mode, rank) under plan-stream accounting.
    pub fn stream_bytes(&self) -> u64 {
        4 * (self.rows.len()
            + self.row_runs.len()
            + self.outer_c.len()
            + self.outer_ptr.len()
            + self.run_b.len()
            + self.run_len.len()
            + self.slot_ptr.len()
            + self.fa.len()
            + self.vals.len()) as u64
    }

    /// Update the stored value of the element at
    /// `(row, a, b, c)` in place — the value-splice path of the
    /// incremental invalidation subsystem (`c` is ignored for 3-D
    /// plans; pass 0). Returns `false` when the coordinate is not in
    /// this plan.
    ///
    /// With duplicate coordinates the *first* matching slot is updated;
    /// run slots are in element-id order (stable build sort), so this
    /// is exactly the element `TensorDelta`'s first-match change
    /// semantics names — the spliced stream equals a fresh build on the
    /// mutated tensor bit-for-bit. Setting a value to `0.0` (removal)
    /// keeps the slot: an explicit zero contributes nothing to any
    /// accumulation.
    pub fn splice_value(&mut self, row: u32, a: u32, b: u32, c: u32, new_val: f32) -> bool {
        let j = match self.find_run(row, b, c) {
            Some(j) => j,
            None => return false,
        };
        let slo = self.slot_ptr[j] as usize;
        for s in slo..slo + self.run_len[j] as usize {
            if self.fa[s] == a {
                self.vals[s] = new_val;
                return true;
            }
        }
        false
    }

    /// Structurally insert one *appended* element into the plan — the
    /// run-splice path of the incremental invalidation subsystem (`c` is
    /// ignored for 3-D plans; pass 0). The element joins the tail of its
    /// `(row, c, b)` run, re-padding the run's lane block (a spare
    /// padding slot absorbs it in place; a full block grows by one
    /// [`LANES`] block); missing runs/outer-runs/rows are created at
    /// their sorted positions.
    ///
    /// Appended elements have ids past every existing one, and the
    /// build sort is stable, so splicing a batch in id order yields the
    /// exact stream `build_with` would produce on the grown element
    /// list — the bit-identity contract `TuckerSession::ingest` pins.
    pub fn splice_append(&mut self, row: u32, a: u32, b: u32, c: u32, val: f32) {
        let four = self.others.len() == 3;
        match self.rows.binary_search(&row) {
            Ok(r) => {
                if four {
                    self.splice_append_4d_row(r, a, b, c, val);
                } else {
                    let (jlo, jhi) =
                        (self.row_runs[r] as usize, self.row_runs[r + 1] as usize);
                    match self.run_b[jlo..jhi].binary_search(&b) {
                        Ok(off) => self.append_to_run(jlo + off, a, val),
                        Err(off) => {
                            self.insert_run_at(jlo + off, b, a, val);
                            for x in &mut self.row_runs[r + 1..] {
                                *x += 1;
                            }
                        }
                    }
                }
            }
            Err(r) => {
                // brand-new local row with a single new run (and, for
                // 4-D, a single new outer run)
                self.rows.insert(r, row);
                if four {
                    let oj = self.row_runs[r] as usize;
                    let j = self.outer_ptr[oj] as usize;
                    self.insert_run_at(j, b, a, val);
                    self.insert_outer_at(oj, c);
                } else {
                    let j = self.row_runs[r] as usize;
                    self.insert_run_at(j, b, a, val);
                }
                let boundary = self.row_runs[r] + 1;
                self.row_runs.insert(r + 1, boundary);
                for x in &mut self.row_runs[r + 2..] {
                    *x += 1;
                }
            }
        }
        self.nnz += 1;
    }

    /// 4-D splice into an existing local row `r`.
    fn splice_append_4d_row(&mut self, r: usize, a: u32, b: u32, c: u32, val: f32) {
        let (olo, ohi) = (self.row_runs[r] as usize, self.row_runs[r + 1] as usize);
        match self.outer_c[olo..ohi].binary_search(&c) {
            Ok(coff) => {
                let oj = olo + coff;
                let (jlo, jhi) =
                    (self.outer_ptr[oj] as usize, self.outer_ptr[oj + 1] as usize);
                match self.run_b[jlo..jhi].binary_search(&b) {
                    Ok(boff) => self.append_to_run(jlo + boff, a, val),
                    Err(boff) => {
                        self.insert_run_at(jlo + boff, b, a, val);
                        for x in &mut self.outer_ptr[oj + 1..] {
                            *x += 1;
                        }
                    }
                }
            }
            Err(coff) => {
                let oj = olo + coff;
                let j = self.outer_ptr[oj] as usize;
                self.insert_run_at(j, b, a, val);
                self.insert_outer_at(oj, c);
                for x in &mut self.row_runs[r + 1..] {
                    *x += 1;
                }
            }
        }
    }

    /// Locate the run holding `(row, c, b)`; `None` if absent. Rows are
    /// ascending, outer runs ascending in `c` per row, runs ascending in
    /// `b` per (outer) run — all binary searches.
    fn find_run(&self, row: u32, b: u32, c: u32) -> Option<usize> {
        let r = self.rows.binary_search(&row).ok()?;
        if self.others.len() == 3 {
            let (olo, ohi) = (self.row_runs[r] as usize, self.row_runs[r + 1] as usize);
            let oj = olo + self.outer_c[olo..ohi].binary_search(&c).ok()?;
            let (jlo, jhi) =
                (self.outer_ptr[oj] as usize, self.outer_ptr[oj + 1] as usize);
            Some(jlo + self.run_b[jlo..jhi].binary_search(&b).ok()?)
        } else {
            let (jlo, jhi) = (self.row_runs[r] as usize, self.row_runs[r + 1] as usize);
            Some(jlo + self.run_b[jlo..jhi].binary_search(&b).ok()?)
        }
    }

    /// Insert a brand-new run (one real element + lane padding) at run
    /// index `j`. Callers fix up the level above (`row_runs` for 3-D,
    /// `outer_ptr` for 4-D).
    fn insert_run_at(&mut self, j: usize, b: u32, a: u32, val: f32) {
        let s = self.slot_ptr[j] as usize;
        self.run_b.insert(j, b);
        self.run_len.insert(j, 1);
        let boundary = self.slot_ptr[j] + LANES as u32;
        self.slot_ptr.insert(j + 1, boundary);
        for x in &mut self.slot_ptr[j + 2..] {
            *x += LANES as u32;
        }
        // one real slot + LANES-1 padding slots (val 0, index repeated)
        let pad_fa = vec![a; LANES];
        let mut pad_vals = vec![0.0f32; LANES];
        pad_vals[0] = val;
        self.fa.splice(s..s, pad_fa);
        self.vals.splice(s..s, pad_vals);
    }

    /// Insert a new outer run covering exactly the (just-inserted) run
    /// at `outer_ptr[oj]`.
    fn insert_outer_at(&mut self, oj: usize, c: u32) {
        self.outer_c.insert(oj, c);
        let boundary = self.outer_ptr[oj] + 1;
        self.outer_ptr.insert(oj + 1, boundary);
        for x in &mut self.outer_ptr[oj + 2..] {
            *x += 1;
        }
    }

    /// Append one real element to existing run `j`, re-padding its lane
    /// block: a spare padding slot absorbs the element in place (the
    /// remaining pads re-point at the new last real index); a full block
    /// grows by one [`LANES`] block.
    fn append_to_run(&mut self, j: usize, a: u32, val: f32) {
        let len = self.run_len[j] as usize;
        let (slo, shi) = (self.slot_ptr[j] as usize, self.slot_ptr[j + 1] as usize);
        if len < shi - slo {
            self.fa[slo + len] = a;
            self.vals[slo + len] = val;
            for f in &mut self.fa[slo + len + 1..shi] {
                *f = a;
            }
        } else {
            let pad_fa = vec![a; LANES];
            let mut pad_vals = vec![0.0f32; LANES];
            pad_vals[0] = val;
            self.fa.splice(shi..shi, pad_fa);
            self.vals.splice(shi..shi, pad_vals);
            for x in &mut self.slot_ptr[j + 1..] {
                *x += LANES as u32;
            }
        }
        self.run_len[j] += 1;
    }

    /// Visit every *real* element in plan order as
    /// `(local_row, fa, fb, fc, val)` — `fc` is 0 for 3-D plans. Padding
    /// slots are skipped via `run_len`, not by value, so explicit zeros
    /// in the tensor are still visited.
    pub fn for_each_element(&self, mut f: impl FnMut(usize, u32, u32, u32, f32)) {
        let four = self.others.len() == 3;
        for r in 0..self.rows.len() {
            let (lo, hi) = (self.row_runs[r] as usize, self.row_runs[r + 1] as usize);
            if four {
                for oj in lo..hi {
                    let (jlo, jhi) =
                        (self.outer_ptr[oj] as usize, self.outer_ptr[oj + 1] as usize);
                    for j in jlo..jhi {
                        let s0 = self.slot_ptr[j] as usize;
                        for s in s0..s0 + self.run_len[j] as usize {
                            f(r, self.fa[s], self.run_b[j], self.outer_c[oj], self.vals[s]);
                        }
                    }
                }
            } else {
                for j in lo..hi {
                    let s0 = self.slot_ptr[j] as usize;
                    for s in s0..s0 + self.run_len[j] as usize {
                        f(r, self.fa[s], self.run_b[j], 0, self.vals[s]);
                    }
                }
            }
        }
    }

    /// Are all other-mode ranks equal? The fixed-shape engine batch
    /// contract ((B, K) row blocks with one shared K) only exists for
    /// uniform cores; ragged cores always take the fused path.
    pub fn uniform_core(&self) -> bool {
        self.oks.windows(2).all(|w| w[0] == w[1])
    }

    /// Assemble Z^p, dispatching on the engine like `assemble_local_z`
    /// (fused native kernel vs. the padded-batch engine contract).
    /// Ragged `CoreRanks::PerMode` plans always run fused — the batched
    /// engine contract requires one shared K.
    pub fn assemble(
        &self,
        factors: &[Mat],
        engine: &Engine,
        ws: &mut PlanWorkspace,
    ) -> LocalZ {
        assemble_over(self, factors, engine, ws, CachePolicy::Off)
    }

    /// Fused plan kernel, dispatched on the workspace's [`Kernel`]:
    /// the scalar oracle replays the PR 1 per-element arithmetic; the
    /// tiled kernels run the lane-blocked layout through the 8-wide
    /// microkernels (monomorphized per instruction set). Thin wrapper
    /// over the generic [`assemble_fused_over`] with the contribution
    /// cache off — per-mode plans have no cross-mode fibers to share.
    pub fn assemble_fused(&self, factors: &[Mat], ws: &mut PlanWorkspace) -> LocalZ {
        assemble_fused_over(self, factors, ws, CachePolicy::Off)
    }

    /// Batched plan path: same padded fixed-shape engine contract as
    /// `assemble_local_z`, but fed from the lane-blocked streams (no
    /// searches, targets come straight from the run walk). Runs the
    /// padding check in `flush_contrib_batch` strictly: with the
    /// lane-blocked layout a violated val==0 contract is a data-layout
    /// bug, not a debug-only hazard. The gather is run-tiled (slow
    /// factor rows hoisted out of the element loop) and the scatter-add
    /// into Z runs K̂-tiled through the workspace kernel — both
    /// bit-neutral: the element order and the a == 1.0 axpy rounding
    /// are unchanged.
    pub fn assemble_batched(
        &self,
        factors: &[Mat],
        engine: &Engine,
        ws: &mut PlanWorkspace,
    ) -> LocalZ {
        assemble_batched_over(self, factors, engine, ws)
    }
}

/// The stream/padding/workspace contract every TTM assembly runs over:
/// a mode's elements as lane-padded `(fa, vals)` run blocks grouped
/// under rows (and, for 4-D, outer runs), exactly the [`TtmPlan`]
/// layout. [`TtmPlan`] implements it by owning its streams; the
/// shared-tree mode views of [`super::csf::CsfPlan`] implement it by
/// *aliasing* the spine plan's streams through a fiber map. The fused
/// and batched assemblies, the lane-invariant checker, and the FLOP
/// model are all generic over this trait (monomorphized — the per-mode
/// path compiles to the same code as before the trait existed).
pub trait ModePlan {
    /// The mode this plan assembles Z for.
    fn mode(&self) -> usize;
    /// Real elements covered (padding slots excluded).
    fn nnz(&self) -> usize;
    /// Core rank of each *other* mode, fast Kronecker factor first.
    fn oks(&self) -> &[usize];
    /// K̂_n = Π_{j≠n} K_j.
    fn khat(&self) -> usize;
    /// Lane-padded fast-mode column tile width.
    fn kp(&self) -> usize;
    /// Modes other than `mode`, ascending.
    fn others(&self) -> &[usize];
    /// Global slice index of each local row, ascending.
    fn rows(&self) -> &[u32];
    /// Per-row run range (3-D) or outer-run range (4-D).
    fn row_runs(&self) -> &[u32];
    /// 4-D only: slowest-mode factor row per outer run.
    fn outer_c(&self) -> &[u32];
    /// 4-D only: run range per outer run.
    fn outer_ptr(&self) -> &[u32];
    /// Slow-mode factor row per run.
    fn run_b(&self) -> &[u32];
    /// Real (unpadded) element count of run `j`.
    fn run_len(&self, j: usize) -> usize;
    /// Slot range of run `j` in the leaf streams (whole [`LANES`] tiles).
    fn run_slots(&self, j: usize) -> (usize, usize);
    /// The lane-padded leaf streams `(fa, vals)` the runs index into.
    fn streams(&self) -> (&[u32], &[f32]);
    /// Contribution-cache slot of run `j`: the shared-tree fiber index
    /// for a CSF view, identity for a per-mode plan.
    fn cache_slot(&self, j: usize) -> usize {
        j
    }
    /// Are all other-mode ranks equal? (Batched-engine eligibility.)
    fn uniform_core(&self) -> bool {
        self.oks().windows(2).all(|w| w[0] == w[1])
    }
}

impl ModePlan for TtmPlan {
    fn mode(&self) -> usize {
        self.mode
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn oks(&self) -> &[usize] {
        &self.oks
    }
    fn khat(&self) -> usize {
        self.khat
    }
    fn kp(&self) -> usize {
        self.kp
    }
    fn others(&self) -> &[usize] {
        &self.others
    }
    fn rows(&self) -> &[u32] {
        &self.rows
    }
    fn row_runs(&self) -> &[u32] {
        &self.row_runs
    }
    fn outer_c(&self) -> &[u32] {
        &self.outer_c
    }
    fn outer_ptr(&self) -> &[u32] {
        &self.outer_ptr
    }
    fn run_b(&self) -> &[u32] {
        &self.run_b
    }
    fn run_len(&self, j: usize) -> usize {
        self.run_len[j] as usize
    }
    fn run_slots(&self, j: usize) -> (usize, usize) {
        (self.slot_ptr[j] as usize, self.slot_ptr[j + 1] as usize)
    }
    fn streams(&self) -> (&[u32], &[f32]) {
        (&self.fa, &self.vals)
    }
}

/// What a fused assembly does with the workspace's per-fiber
/// contribution cache. Per-mode plans always run `Off`; the shared CSF
/// tree fills on its first non-leaf view of a sweep and reuses on the
/// later ones (`hooi::csf` owns the lifecycle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CachePolicy {
    /// No cache interaction (per-mode plans; batched engine path).
    Off,
    /// Compute every run contribution and store it at its cache slot.
    Fill,
    /// Skip the accumulation and read each run's cached contribution.
    Use,
}

/// Engine-routing assembly over any [`ModePlan`] — fused native kernel
/// vs. the padded-batch engine contract, exactly [`TtmPlan::assemble`]'s
/// dispatch rule. The batched path never touches the contribution cache
/// (its per-element gather has no per-run accumulator to reuse).
pub(crate) fn assemble_over<P: ModePlan>(
    p: &P,
    factors: &[Mat],
    engine: &Engine,
    ws: &mut PlanWorkspace,
    cache: CachePolicy,
) -> LocalZ {
    if engine.prefers_fused_ttm() || !p.uniform_core() {
        assemble_fused_over(p, factors, ws, cache)
    } else {
        assemble_batched_over(p, factors, engine, ws)
    }
}

/// Kernel-dispatching fused assembly over any [`ModePlan`].
pub(crate) fn assemble_fused_over<P: ModePlan>(
    p: &P,
    factors: &[Mat],
    ws: &mut PlanWorkspace,
    cache: CachePolicy,
) -> LocalZ {
    match ws.kernel.resolve() {
        Kernel::Scalar => assemble_fused_scalar_over(p, factors, ws, cache),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: the dispatch contract — Kernel::resolve only yields
        // Avx2 after runtime detection of avx2+fma succeeded.
        Kernel::Avx2 => unsafe { assemble_fused_avx2_over(p, factors, ws, cache) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: NEON is architecturally guaranteed on aarch64.
        Kernel::Neon => unsafe { assemble_fused_neon_over(p, factors, ws, cache) },
        _ => assemble_fused_tiled_over::<PortableTile, P>(p, factors, ws, cache),
    }
}

/// AVX2 entry point: `target_feature` on the *whole* assembly so the
/// intrinsic microkernels inline into the run/row loops (a
/// `target_feature` fn cannot inline into a plain caller — wrapping
/// only the 8-float microkernel would pay a call per 2 FMAs).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller must have verified avx2+fma at runtime (the dispatch
// in assemble_fused_over does); the body is safe code whose intrinsic
// tiles inherit the enabled features.
unsafe fn assemble_fused_avx2_over<P: ModePlan>(
    p: &P,
    factors: &[Mat],
    ws: &mut PlanWorkspace,
    cache: CachePolicy,
) -> LocalZ {
    assemble_fused_tiled_over::<Avx2Tile, P>(p, factors, ws, cache)
}

/// NEON entry point (see `assemble_fused_avx2_over` for why the feature
/// is enabled on the whole assembly).
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[target_feature(enable = "neon")]
// SAFETY: NEON is baseline on aarch64, so the feature precondition
// always holds; the body is safe code using NEON tiles.
unsafe fn assemble_fused_neon_over<P: ModePlan>(
    p: &P,
    factors: &[Mat],
    ws: &mut PlanWorkspace,
    cache: CachePolicy,
) -> LocalZ {
    assemble_fused_tiled_over::<NeonTile, P>(p, factors, ws, cache)
}

/// Scalar reference path: the PR 1 run-hoisted loops over unpadded
/// K-length rows (padding slots skipped via `run_len`). Kept as the
/// equivalence oracle and the ablation baseline. Under `Fill`/`Use` the
/// cache holds the unpadded `K_fast`-prefix of each fiber's accumulator
/// (stored at the same `kp` stride the tiled path uses), so cache reuse
/// replays the exact per-run arithmetic of a cache-off assembly.
fn assemble_fused_scalar_over<P: ModePlan>(
    p: &P,
    factors: &[Mat],
    ws: &mut PlanWorkspace,
    cache: CachePolicy,
) -> LocalZ {
    let ka = p.oks()[0];
    let kp = p.kp();
    let nrows = p.rows().len();
    let data = ws.take_z(nrows * p.khat());
    let mut z = Mat { rows: nrows, cols: p.khat(), data };
    if p.nnz() == 0 {
        return LocalZ { rows: p.rows().to_vec(), z };
    }
    let (fa, vals) = p.streams();
    let fm_a = &factors[p.others()[0]];
    let fm_b = &factors[p.others()[1]];
    ws.acc.clear();
    ws.acc.resize(ka, 0.0);
    if p.others().len() == 2 {
        let PlanWorkspace { acc, contrib, .. } = ws;
        for r in 0..nrows {
            let zrow = z.row_mut(r);
            for j in p.row_runs()[r] as usize..p.row_runs()[r + 1] as usize {
                let acc_row: &[f32] = if cache == CachePolicy::Use {
                    let cs = p.cache_slot(j) * kp;
                    &contrib[cs..cs + ka]
                } else {
                    acc.fill(0.0);
                    let (s0, _) = p.run_slots(j);
                    for s in s0..s0 + p.run_len(j) {
                        axpy(vals[s], fm_a.row(fa[s] as usize), acc);
                    }
                    if cache == CachePolicy::Fill {
                        let cs = p.cache_slot(j) * kp;
                        contrib[cs..cs + ka].copy_from_slice(acc);
                    }
                    &acc[..]
                };
                let rb = fm_b.row(p.run_b()[j] as usize);
                for (cb, &bv) in rb.iter().enumerate() {
                    axpy(bv, acc_row, &mut zrow[cb * ka..(cb + 1) * ka]);
                }
            }
        }
    } else {
        let fm_c = &factors[p.others()[2]];
        let kk = ka * p.oks()[1];
        ws.acc2.clear();
        ws.acc2.resize(kk, 0.0);
        let PlanWorkspace { acc, acc2, contrib, .. } = ws;
        for r in 0..nrows {
            let zrow = z.row_mut(r);
            for oj in p.row_runs()[r] as usize..p.row_runs()[r + 1] as usize {
                acc2.fill(0.0);
                for j in p.outer_ptr()[oj] as usize..p.outer_ptr()[oj + 1] as usize {
                    let acc_row: &[f32] = if cache == CachePolicy::Use {
                        let cs = p.cache_slot(j) * kp;
                        &contrib[cs..cs + ka]
                    } else {
                        acc.fill(0.0);
                        let (s0, _) = p.run_slots(j);
                        for s in s0..s0 + p.run_len(j) {
                            axpy(vals[s], fm_a.row(fa[s] as usize), acc);
                        }
                        if cache == CachePolicy::Fill {
                            let cs = p.cache_slot(j) * kp;
                            contrib[cs..cs + ka].copy_from_slice(acc);
                        }
                        &acc[..]
                    };
                    let rb = fm_b.row(p.run_b()[j] as usize);
                    for (cb, &bv) in rb.iter().enumerate() {
                        axpy(bv, acc_row, &mut acc2[cb * ka..(cb + 1) * ka]);
                    }
                }
                let rc = fm_c.row(p.outer_c()[oj] as usize);
                for (cc, &cv) in rc.iter().enumerate() {
                    axpy(cv, acc2, &mut zrow[cc * kk..(cc + 1) * kk]);
                }
            }
        }
    }
    LocalZ { rows: p.rows().to_vec(), z }
}

/// Tiled fused path: every inner loop is whole 8-lane tiles — run
/// accumulation over the padded fa/vals blocks against the kp-stride
/// factor table, fused slow×fast expansion into kp-stride tiles, then
/// one compaction copy per row into the K̂ layout. Under `Fill`/`Use`
/// the cache stores each fiber's full kp-wide accumulator tile, so a
/// cache hit feeds the expansion the bit-identical tile the
/// accumulation would have produced.
fn assemble_fused_tiled_over<MK: Tile, P: ModePlan>(
    p: &P,
    factors: &[Mat],
    ws: &mut PlanWorkspace,
    cache: CachePolicy,
) -> LocalZ {
    let (ka, kp) = (p.oks()[0], p.kp());
    let nrows = p.rows().len();
    let data = ws.take_z(nrows * p.khat());
    let mut z = Mat { rows: nrows, cols: p.khat(), data };
    if p.nnz() == 0 {
        return LocalZ { rows: p.rows().to_vec(), z };
    }
    let (fa, vals) = p.streams();
    ws.prepare_apad(&factors[p.others()[0]], kp);
    ws.acc.clear();
    ws.acc.resize(kp, 0.0);
    if p.others().len() == 2 {
        let kb = p.oks()[1];
        let fm_b = &factors[p.others()[1]];
        ws.ztile.clear();
        ws.ztile.resize(kb * kp, 0.0);
        let PlanWorkspace { apad, acc, ztile, contrib, .. } = ws;
        for r in 0..nrows {
            let (jlo, jhi) = (p.row_runs()[r] as usize, p.row_runs()[r + 1] as usize);
            for j in jlo..jhi {
                let acc_row: &[f32] = if cache == CachePolicy::Use {
                    let cs = p.cache_slot(j) * kp;
                    &contrib[cs..cs + kp]
                } else {
                    let (slo, shi) = p.run_slots(j);
                    accumulate_run::<MK>(&fa[slo..shi], &vals[slo..shi], apad, kp, acc);
                    if cache == CachePolicy::Fill {
                        let cs = p.cache_slot(j) * kp;
                        contrib[cs..cs + kp].copy_from_slice(acc);
                    }
                    &acc[..]
                };
                let rb = fm_b.row(p.run_b()[j] as usize);
                if j == jlo {
                    MK::expand_store(rb, acc_row, ztile);
                } else {
                    MK::expand(rb, acc_row, ztile);
                }
            }
            // compact the kp-stride tile into the dense K̂ row
            let zrow = z.row_mut(r);
            for cb in 0..kb {
                zrow[cb * ka..(cb + 1) * ka]
                    .copy_from_slice(&ztile[cb * kp..cb * kp + ka]);
            }
        }
    } else {
        let (kb, kc) = (p.oks()[1], p.oks()[2]);
        let fm_b = &factors[p.others()[1]];
        let fm_c = &factors[p.others()[2]];
        ws.acc2.clear();
        ws.acc2.resize(kb * kp, 0.0);
        ws.ztile.clear();
        ws.ztile.resize(kc * kb * kp, 0.0);
        let PlanWorkspace { apad, acc, acc2, ztile, contrib, .. } = ws;
        for r in 0..nrows {
            let (olo, ohi) = (p.row_runs()[r] as usize, p.row_runs()[r + 1] as usize);
            for oj in olo..ohi {
                let (jlo, jhi) =
                    (p.outer_ptr()[oj] as usize, p.outer_ptr()[oj + 1] as usize);
                for j in jlo..jhi {
                    let acc_row: &[f32] = if cache == CachePolicy::Use {
                        let cs = p.cache_slot(j) * kp;
                        &contrib[cs..cs + kp]
                    } else {
                        let (slo, shi) = p.run_slots(j);
                        accumulate_run::<MK>(
                            &fa[slo..shi],
                            &vals[slo..shi],
                            apad,
                            kp,
                            acc,
                        );
                        if cache == CachePolicy::Fill {
                            let cs = p.cache_slot(j) * kp;
                            contrib[cs..cs + kp].copy_from_slice(acc);
                        }
                        &acc[..]
                    };
                    let rb = fm_b.row(p.run_b()[j] as usize);
                    if j == jlo {
                        MK::expand_store(rb, acc_row, acc2);
                    } else {
                        MK::expand(rb, acc_row, acc2);
                    }
                }
                let rc = fm_c.row(p.outer_c()[oj] as usize);
                if oj == olo {
                    MK::expand_store(rc, acc2, ztile);
                } else {
                    MK::expand(rc, acc2, ztile);
                }
            }
            let zrow = z.row_mut(r);
            for seg in 0..kc * kb {
                zrow[seg * ka..(seg + 1) * ka]
                    .copy_from_slice(&ztile[seg * kp..seg * kp + ka]);
            }
        }
    }
    LocalZ { rows: p.rows().to_vec(), z }
}

/// Batched plan path over any [`ModePlan`]: same padded fixed-shape
/// engine contract as `assemble_local_z`, but fed from the lane-blocked
/// streams (no searches, targets come straight from the run walk). Runs
/// the padding check in `flush_contrib_batch` strictly: with the
/// lane-blocked layout a violated val==0 contract is a data-layout bug,
/// not a debug-only hazard. The gather is run-tiled (slow factor rows
/// hoisted out of the element loop) and the scatter-add into Z runs
/// K̂-tiled through the workspace kernel — both bit-neutral: the element
/// order and the a == 1.0 axpy rounding are unchanged. A CSF view walks
/// its runs in its own plan order here, which is exactly the element
/// order of the equivalent per-mode plan — identical batch boundaries,
/// identical bits.
pub(crate) fn assemble_batched_over<P: ModePlan>(
    p: &P,
    factors: &[Mat],
    engine: &Engine,
    ws: &mut PlanWorkspace,
) -> LocalZ {
    assert!(
        p.uniform_core(),
        "the batched engine contract requires a uniform core \
         (ragged ranks {:?} must use the fused path)",
        p.oks()
    );
    let k = p.oks()[0];
    let kh = p.khat();
    let ndim = p.others().len() + 1;
    let nrows = p.rows().len();
    let data = ws.take_z(nrows * kh);
    let mut z = Mat { rows: nrows, cols: kh, data };
    if p.nnz() == 0 {
        return LocalZ { rows: p.rows().to_vec(), z };
    }
    let (fa, vals) = p.streams();
    let bsz = engine.ttm_batch_size(ndim, k);
    let kern = ws.kernel;
    ws.ensure_batch(bsz, k);
    let PlanWorkspace { rows_a, rows_b, rows_c, bvals, targets, .. } = ws;
    let (fm_a, fm_b) = (&factors[p.others()[0]], &factors[p.others()[1]]);
    let fm_c = if ndim == 4 { Some(&factors[p.others()[2]]) } else { None };
    let mut fill = 0usize;
    for r in 0..nrows {
        let (lo, hi) = (p.row_runs()[r] as usize, p.row_runs()[r + 1] as usize);
        if let Some(fm_c) = fm_c {
            for oj in lo..hi {
                let rc = fm_c.row(p.outer_c()[oj] as usize);
                let (jlo, jhi) =
                    (p.outer_ptr()[oj] as usize, p.outer_ptr()[oj + 1] as usize);
                for j in jlo..jhi {
                    let rb = fm_b.row(p.run_b()[j] as usize);
                    let (s0, _) = p.run_slots(j);
                    for s in s0..s0 + p.run_len(j) {
                        rows_a[fill * k..(fill + 1) * k]
                            .copy_from_slice(fm_a.row(fa[s] as usize));
                        rows_b[fill * k..(fill + 1) * k].copy_from_slice(rb);
                        rows_c[fill * k..(fill + 1) * k].copy_from_slice(rc);
                        bvals[fill] = vals[s];
                        targets[fill] = r as u32;
                        fill += 1;
                        if fill == bsz {
                            flush_contrib_batch(
                                engine, ndim, k, kh, fill, rows_a, rows_b, rows_c,
                                bvals, targets, &mut z, true, kern,
                            );
                            fill = 0;
                        }
                    }
                }
            }
        } else {
            for j in lo..hi {
                let rb = fm_b.row(p.run_b()[j] as usize);
                let (s0, _) = p.run_slots(j);
                for s in s0..s0 + p.run_len(j) {
                    rows_a[fill * k..(fill + 1) * k]
                        .copy_from_slice(fm_a.row(fa[s] as usize));
                    rows_b[fill * k..(fill + 1) * k].copy_from_slice(rb);
                    bvals[fill] = vals[s];
                    targets[fill] = r as u32;
                    fill += 1;
                    if fill == bsz {
                        flush_contrib_batch(
                            engine, ndim, k, kh, fill, rows_a, rows_b, rows_c, bvals,
                            targets, &mut z, true, kern,
                        );
                        fill = 0;
                    }
                }
            }
        }
    }
    flush_contrib_batch(
        engine, ndim, k, kh, fill, rows_a, rows_b, rows_c, bvals, targets, &mut z,
        true, kern,
    );
    LocalZ { rows: p.rows().to_vec(), z }
}

/// Analytic FLOP count of one fused assembly of `p`: run accumulation
/// (2·K_fast per real element) plus the per-run (and, for 4-D,
/// per-outer) Kronecker expansions. With `cached == true` the
/// accumulation term is dropped — the cost a shared-CSF mode pays when
/// it reuses the sweep's fiber contributions instead of recomputing
/// them. `benches/ablate_plan.rs` and the shared-plan `CostEstimate`
/// discount are both derived from this model.
pub fn fused_flops<P: ModePlan>(p: &P, cached: bool) -> f64 {
    let ka = p.oks()[0] as f64;
    let runs = p.run_b().len() as f64;
    let mut fl = 0.0;
    if !cached {
        fl += 2.0 * p.nnz() as f64 * ka;
    }
    if p.others().len() == 2 {
        fl += 2.0 * p.khat() as f64 * runs;
    } else {
        fl += 2.0 * ka * p.oks()[1] as f64 * runs;
        fl += 2.0 * p.khat() as f64 * p.outer_c().len() as f64;
    }
    fl
}

/// Visit every *real* element of any [`ModePlan`] in plan order as
/// `(local_row, fa, fb, fc, val)` — the generic counterpart of
/// [`TtmPlan::for_each_element`] (`fc` is 0 for 3-D plans).
pub fn for_each_element_over<P: ModePlan>(
    p: &P,
    mut f: impl FnMut(usize, u32, u32, u32, f32),
) {
    let four = p.others().len() == 3;
    let (fa, vals) = p.streams();
    for r in 0..p.rows().len() {
        let (lo, hi) = (p.row_runs()[r] as usize, p.row_runs()[r + 1] as usize);
        if four {
            for oj in lo..hi {
                let (jlo, jhi) =
                    (p.outer_ptr()[oj] as usize, p.outer_ptr()[oj + 1] as usize);
                for j in jlo..jhi {
                    let (s0, _) = p.run_slots(j);
                    for s in s0..s0 + p.run_len(j) {
                        f(r, fa[s], p.run_b()[j], p.outer_c()[oj], vals[s]);
                    }
                }
            }
        } else {
            for j in lo..hi {
                let (s0, _) = p.run_slots(j);
                for s in s0..s0 + p.run_len(j) {
                    f(r, fa[s], p.run_b()[j], 0, vals[s]);
                }
            }
        }
    }
}

/// Assert the shared invariants of the lane-blocked layout for a plan
/// that covers every tensor element whose `mode` coordinate is one of
/// the plan's rows (true for whole-tensor plans and slice-aligned rank
/// plans). Rank plans over split slices should use
/// [`check_lane_invariants_for`] with the rank's element list.
pub fn check_lane_invariants(t: &SparseTensor, plan: &TtmPlan) {
    let elems: Vec<u32> = (0..t.nnz() as u32)
        .filter(|&e| {
            plan.rows.binary_search(&t.coord(plan.mode, e as usize)).is_ok()
        })
        .collect();
    check_lane_invariants_for(t, plan, &elems);
}

/// Assert the lane-blocked layout invariants of one plan against the
/// element ids it is supposed to encode: ascending rows, lane-aligned
/// run blocks, the val==0/repeated-index padding contract, `run_len`
/// summing to `nnz`, and the real-element multiset matching `elems`.
///
/// Validation/debug helper (O(|E| log |E|), panics on violation) — used
/// by the plan unit tests and by the streaming-ingest tests to pin that
/// incrementally spliced/rebuilt plans stay well-formed.
pub fn check_lane_invariants_for(t: &SparseTensor, plan: &TtmPlan, elems: &[u32]) {
    // stream totals only an owning plan can promise (CSF views alias the
    // spine streams, so these stay TtmPlan-specific)
    assert_eq!(*plan.slot_ptr.last().unwrap() as usize, plan.fa.len());
    assert_eq!(plan.fa.len(), plan.vals.len());
    check_lane_invariants_over(t, plan, elems);
}

/// The [`ModePlan`]-generic core of [`check_lane_invariants_for`]:
/// ascending rows, lane-aligned run blocks, the val==0/repeated-index
/// padding contract, `run_len` summing to `nnz`, and the real-element
/// multiset matching `elems` — the form `hooi::csf` runs over the
/// shared tree's spine, streams, *and* fiber-mapped views alike.
pub fn check_lane_invariants_over<P: ModePlan>(t: &SparseTensor, plan: &P, elems: &[u32]) {
    let mode = plan.mode();
    let (fa, vals) = plan.streams();
    assert!(plan.rows().windows(2).all(|w| w[0] < w[1]), "rows ascending");
    assert_eq!(plan.kp() % LANES, 0);
    assert!(plan.kp() >= plan.oks()[0]);
    let mut real = 0usize;
    for j in 0..plan.run_b().len() {
        let (lo, hi) = plan.run_slots(j);
        let len = plan.run_len(j);
        assert!(len >= 1, "runs are non-empty");
        assert_eq!(hi - lo, pad_to_lanes(len), "run {j} aligned");
        // padded slots: val exactly 0.0, index repeats a real slot
        for s in lo + len..hi {
            assert_eq!(vals[s].to_bits(), 0.0f32.to_bits(), "pad val run {j}");
            assert_eq!(fa[s], fa[lo + len - 1], "pad idx run {j}");
        }
        real += len;
    }
    assert_eq!(real, plan.nnz(), "run_len sums to nnz");
    // multiset of real elements matches the given element list
    let mut got: Vec<(u32, u32, u32, u32, u32)> = Vec::new();
    for_each_element_over(plan, |r, ia, ib, ic, v| {
        got.push((plan.rows()[r], ia, ib, ic, v.to_bits()));
    });
    let mut want: Vec<(u32, u32, u32, u32, u32)> = Vec::new();
    for &eu in elems {
        let e = eu as usize;
        let ic = if plan.others().len() == 3 {
            t.coord(plan.others()[2], e)
        } else {
            0
        };
        want.push((
            t.coord(mode, e),
            t.coord(plan.others()[0], e),
            t.coord(plan.others()[1], e),
            ic,
            t.vals[e].to_bits(),
        ));
    }
    got.sort_unstable();
    want.sort_unstable();
    assert_eq!(got, want, "mode {mode} element multiset");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormal_random;
    use crate::util::rng::Rng;

    fn setup(dims: Vec<u32>, nnz: usize, k: usize, seed: u64) -> (SparseTensor, Vec<Mat>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(dims, nnz, &mut rng);
        let factors = t
            .dims
            .iter()
            .map(|&l| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        (t, factors)
    }

    #[test]
    fn plan_layout_invariants_3d() {
        let (t, _) = setup(vec![15, 11, 7], 500, 4, 1);
        let elems: Vec<u32> = (0..500).collect();
        for mode in 0..3 {
            let plan = TtmPlan::build(&t, mode, &elems, 4);
            assert_eq!(plan.nnz(), 500);
            assert!(plan.outer_c.is_empty() && plan.outer_ptr.is_empty());
            assert_eq!(plan.row_runs.len(), plan.rows.len() + 1);
            check_lane_invariants(&t, &plan);
            for r in 0..plan.rows.len() {
                let (lo, hi) =
                    (plan.row_runs[r] as usize, plan.row_runs[r + 1] as usize);
                assert!(lo < hi, "every stored row has runs");
                // slow factor row strictly increasing across a row's runs
                assert!(plan.run_b[lo..hi].windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn plan_layout_invariants_4d() {
        let (t, _) = setup(vec![10, 8, 6, 5], 400, 3, 2);
        let elems: Vec<u32> = (0..400).collect();
        for mode in 0..4 {
            let plan = TtmPlan::build(&t, mode, &elems, 3);
            assert_eq!(plan.nnz(), 400);
            assert_eq!(plan.row_runs.len(), plan.rows.len() + 1);
            assert_eq!(plan.outer_ptr.len(), plan.outer_c.len() + 1);
            check_lane_invariants(&t, &plan);
            for r in 0..plan.rows.len() {
                let (lo, hi) =
                    (plan.row_runs[r] as usize, plan.row_runs[r + 1] as usize);
                assert!(lo < hi, "every stored row has outer runs");
                // slowest coordinate strictly increasing across outer runs
                assert!(plan.outer_c[lo..hi].windows(2).all(|w| w[0] < w[1]));
                for oj in lo..hi {
                    let (jlo, jhi) =
                        (plan.outer_ptr[oj] as usize, plan.outer_ptr[oj + 1] as usize);
                    assert!(jlo < jhi, "outer runs are non-empty");
                    assert!(plan.run_b[jlo..jhi].windows(2).all(|w| w[0] < w[1]));
                }
            }
        }
    }

    #[test]
    fn fused_plan_matches_element_order_oracle() {
        let (t, factors) = setup(vec![12, 9, 7], 400, 5, 2);
        let elems: Vec<u32> = (0..400).collect();
        let mut ws = PlanWorkspace::new();
        let mut ws_scalar = PlanWorkspace::with_kernel(Kernel::Scalar);
        for mode in 0..3 {
            let plan = TtmPlan::build(&t, mode, &elems, 5);
            let want =
                crate::hooi::ttm::assemble_local_z_fused(&t, mode, &elems, &factors);
            let tiled = plan.assemble_fused(&factors, &mut ws);
            assert_eq!(tiled.rows, want.rows);
            assert!(tiled.z.max_abs_diff(&want.z) < 1e-4, "tiled mode {mode}");
            ws.recycle(tiled.z);
            let scalar = plan.assemble_fused(&factors, &mut ws_scalar);
            assert_eq!(scalar.rows, want.rows);
            assert!(scalar.z.max_abs_diff(&want.z) < 1e-4, "scalar mode {mode}");
            ws_scalar.recycle(scalar.z);
        }
    }

    #[test]
    fn fused_plan_matches_oracle_4d() {
        let (t, factors) = setup(vec![8, 6, 5, 4], 300, 3, 3);
        let elems: Vec<u32> = (0..300).collect();
        let mut ws = PlanWorkspace::new();
        let mut ws_scalar = PlanWorkspace::with_kernel(Kernel::Scalar);
        for mode in 0..4 {
            let plan = TtmPlan::build(&t, mode, &elems, 3);
            let want =
                crate::hooi::ttm::assemble_local_z_fused(&t, mode, &elems, &factors);
            let tiled = plan.assemble_fused(&factors, &mut ws);
            assert_eq!(tiled.rows, want.rows);
            assert!(tiled.z.max_abs_diff(&want.z) < 1e-4, "tiled mode {mode}");
            ws.recycle(tiled.z);
            let scalar = plan.assemble_fused(&factors, &mut ws_scalar);
            assert!(scalar.z.max_abs_diff(&want.z) < 1e-4, "scalar mode {mode}");
            ws_scalar.recycle(scalar.z);
        }
    }

    #[test]
    fn empty_plan_yields_empty_local() {
        let (t, factors) = setup(vec![5, 5, 5], 50, 3, 4);
        let plan = TtmPlan::build(&t, 0, &[], 3);
        let mut ws = PlanWorkspace::new();
        let local = plan.assemble(&factors, &Engine::Native, &mut ws);
        assert!(local.rows.is_empty());
        assert_eq!(local.z.rows, 0);
        assert_eq!(local.z.cols, 9);
    }

    #[test]
    fn z_arena_reuses_buffers_across_assemblies() {
        let (t, factors) = setup(vec![10, 8, 6], 300, 4, 5);
        let elems: Vec<u32> = (0..300).collect();
        let plan = TtmPlan::build(&t, 0, &elems, 4);
        let mut ws = PlanWorkspace::new();
        let first = plan.assemble_fused(&factors, &mut ws);
        let ptr = first.z.data.as_ptr();
        let want = first.z.clone();
        ws.recycle(first.z);
        let second = plan.assemble_fused(&factors, &mut ws);
        assert_eq!(second.z.data.as_ptr(), ptr, "arena buffer reused");
        assert_eq!(second.z.data, want.data, "recycled buffer fully re-zeroed");
    }

    /// `(row, a, b, c)` of element `e` in `plan`'s coordinate roles.
    fn coords_for(t: &SparseTensor, plan: &TtmPlan, e: usize) -> (u32, u32, u32, u32) {
        let c = if plan.others.len() == 3 {
            t.coord(plan.others[2], e)
        } else {
            0
        };
        (
            t.coord(plan.mode, e),
            t.coord(plan.others[0], e),
            t.coord(plan.others[1], e),
            c,
        )
    }

    #[test]
    fn splice_append_matches_fresh_build() {
        // streaming appends spliced in id order must reproduce the fresh
        // build bit-for-bit (rows/runs/outer levels, lane re-padding and
        // all) — 3-D and 4-D, every mode
        for (dims, seed) in [(vec![12u32, 9, 7], 11u64), (vec![8, 6, 5, 4], 12)] {
            let ndim = dims.len();
            for mode in 0..ndim {
                let mut rng = Rng::new(seed + mode as u64);
                let mut t = SparseTensor::random(dims.clone(), 200, &mut rng);
                let elems0: Vec<u32> = (0..200).collect();
                let mut plan = TtmPlan::build(&t, mode, &elems0, 4);
                for _ in 0..60 {
                    let coord: Vec<u32> = t
                        .dims
                        .iter()
                        .map(|&d| rng.below(d as u64) as u32)
                        .collect();
                    let val = rng.f32() * 2.0 - 1.0;
                    t.push(&coord, val);
                    let e = t.nnz() - 1;
                    let (row, a, b, c) = coords_for(&t, &plan, e);
                    plan.splice_append(row, a, b, c, val);
                }
                let elems: Vec<u32> = (0..t.nnz() as u32).collect();
                let fresh = TtmPlan::build(&t, mode, &elems, 4);
                assert_eq!(plan, fresh, "mode {mode}: spliced ≡ fresh build");
                check_lane_invariants(&t, &plan);
            }
        }
    }

    #[test]
    fn splice_append_grows_an_empty_plan() {
        let (t, _) = setup(vec![6, 5, 4, 3], 80, 3, 14);
        let mut plan = TtmPlan::build(&t, 2, &[], 3);
        for e in 0..t.nnz() {
            let (row, a, b, c) = coords_for(&t, &plan, e);
            plan.splice_append(row, a, b, c, t.vals[e]);
        }
        let elems: Vec<u32> = (0..t.nnz() as u32).collect();
        assert_eq!(plan, TtmPlan::build(&t, 2, &elems, 3));
    }

    #[test]
    fn splice_value_matches_fresh_build_and_targets_first_duplicate() {
        let mut rng = Rng::new(13);
        let mut t = SparseTensor::random(vec![10, 8, 6], 250, &mut rng);
        // force a duplicate coordinate: copy element 5's coords to the end
        let coord: Vec<u32> = (0..3).map(|m| t.coord(m, 5)).collect();
        t.push(&coord, 9.0);
        let elems: Vec<u32> = (0..t.nnz() as u32).collect();
        let mut plan = TtmPlan::build(&t, 1, &elems, 3);
        // change the first duplicate (element 5) — TensorDelta semantics
        t.vals[5] = -4.5;
        let (row, a, b, c) = coords_for(&t, &plan, 5);
        assert!(plan.splice_value(row, a, b, c, -4.5));
        assert_eq!(plan, TtmPlan::build(&t, 1, &elems, 3));
        // removal keeps the slot as an explicit zero
        t.vals[7] = 0.0;
        let (row, a, b, c) = coords_for(&t, &plan, 7);
        assert!(plan.splice_value(row, a, b, c, 0.0));
        assert_eq!(plan, TtmPlan::build(&t, 1, &elems, 3));
        check_lane_invariants(&t, &plan);
        // an absent coordinate reports not-found instead of corrupting
        let mut empty = TtmPlan::build(&t, 1, &[], 3);
        assert!(!empty.splice_value(0, 0, 0, 0, 1.0));
    }

    #[test]
    fn stream_bytes_counts_lane_padding() {
        let (t, _) = setup(vec![30, 10, 4], 200, 5, 6);
        let elems: Vec<u32> = (0..200).collect();
        let plan = TtmPlan::build(&t, 0, &elems, 5);
        assert!(plan.padded_slots() >= plan.nnz());
        assert!(plan.padded_slots() % LANES == 0);
        // fa + vals alone are 8 bytes per padded slot; the run/row tables
        // only add to that
        assert!(plan.stream_bytes() >= 8 * plan.padded_slots() as u64);
    }
}
