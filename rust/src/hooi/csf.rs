//! One shared CSF plan per rank: all N mode TTMs of a HOOI sweep served
//! from a single hierarchical layout, with cross-mode reuse of the
//! partial Kronecker-product fiber contributions.
//!
//! ## Why one tree can serve every mode
//!
//! For every non-leaf mode `n ≥ 1` the fast Kronecker factor is mode 0
//! (`others[0] == 0`), so each of those modes' [`TtmPlan`] runs is a
//! *fiber*: the set of elements with every coordinate fixed except
//! `i_0`, sorted by element id, lane-padded by repeating the last real
//! `fa` index with `val == 0.0`. The fiber set is a property of the
//! element list, not of the mode — so when a rank owns the *same*
//! element set in every mode (the uniform-partition schemes MediumG and
//! HyperG guarantee exactly this), the per-mode plans of modes
//! `1..N-1` all encode the same fibers with byte-identical `fa`/`vals`
//! blocks, merely grouped under different row/run orderings.
//!
//! A [`CsfPlan`] therefore stores the leaf streams **once**, on the
//! *spine* — the mode-`N-1` [`TtmPlan`], whose runs are the canonical
//! fibers — and represents each other non-leaf mode as a [`CsfView`]:
//! the mode's row/run/outer tables plus a `fiber` map from view run to
//! spine run. A view owns no leaf streams; [`CsfModeView`] adapts it to
//! the [`ModePlan`] assembly contract by aliasing the spine's streams
//! through the fiber map. Mode 0 (whose fast factor is mode 1, not
//! mode 0) always keeps its own stream plan, as does any mode whose
//! element set differs from the spine's (Lite/CoarseG split slices
//! across ranks per mode — the tree degrades to per-mode streams under
//! one roof: unified bookkeeping, no arithmetic reuse, still
//! bit-exact).
//!
//! ## Cross-mode contribution reuse
//!
//! Every fused TTM assembly starts by accumulating, per run, the
//! value-weighted fast-factor combination `acc = Σ_s vals[s]·F_0[fa[s]]`
//! (`kernel::accumulate_run`). Since shared-tree view runs *are* spine
//! fibers, that per-fiber accumulator is identical across modes
//! `1..N-1` — it depends only on F_0 and the tensor values, neither of
//! which changes between mode 1's TTM and mode N-1's within a sweep
//! (HOOI updates F_n *after* mode n's TTM, and F_0 only at mode 0).
//! So the first view assembly of a sweep **fills** a per-fiber cache in
//! the rank's [`PlanWorkspace`] and every later non-leaf mode **uses**
//! it, skipping the accumulation (the `2·nnz·K_0` term — the dominant
//! share of the paper's `2·nnz·K̂` TTM cost) and paying only the
//! Kronecker expansion. The cache holds the same accumulator tile the
//! cache-off assembly would have produced (same slots, same kernel,
//! same operation order), so reuse is bit-identical per kernel — the
//! `SharedCsf ≡ PerMode` contract `tests/csf.rs` pins across kernels,
//! executors, and the ingest/rebalance/recovery lifecycle.
//!
//! ## Unified maintenance
//!
//! Streaming updates touch **one structure per rank** instead of N
//! plans: [`CsfPlan::apply_delta`] splices the spine and any stream
//! components through the single `TtmPlan` splice path and re-derives
//! the views from the spliced spine (views are pure functions of the
//! spine), falling back to one whole-tree rebuild when the batch is
//! large, non-uniform across modes, or hits an unknown coordinate.
//! Dirty tracking is per *subtree* (rank), not per (mode, rank) × N —
//! `IngestReport::plan_count` reports `p` shared trees instead of
//! `ndim·p` plans when a session runs `PlanChoice::SharedCsf`.

use super::kernel::pad_to_lanes;
use super::plan::{
    assemble_over, check_lane_invariants_for, check_lane_invariants_over, fused_flops,
    CachePolicy, ModePlan, PlanWorkspace, TtmPlan,
};
use super::ranks::CoreRanks;
use super::ttm::{other_modes, LocalZ};
use crate::linalg::Mat;
use crate::runtime::Engine;
use crate::tensor::SparseTensor;

/// One mode of a [`CsfPlan`] below the spine.
#[derive(Debug, Clone, PartialEq)]
pub enum CsfLower {
    /// The mode owns its own leaf streams (mode 0 always; any mode whose
    /// element set differs from the spine's).
    Stream(TtmPlan),
    /// The mode shares the spine's fibers through a fiber map.
    View(CsfView),
}

/// A shared-tree mode view: the row/run/outer grouping tables of a
/// per-mode [`TtmPlan`] plus the `fiber` map into the spine's runs —
/// and **no leaf streams**. Field semantics match [`TtmPlan`]'s
/// equally-named fields; [`CsfModeView`] adapts a view to [`ModePlan`].
#[derive(Debug, Clone, PartialEq)]
pub struct CsfView {
    pub mode: usize,
    pub oks: Vec<usize>,
    pub khat: usize,
    pub kp: usize,
    pub others: Vec<usize>,
    pub rows: Vec<u32>,
    pub row_runs: Vec<u32>,
    pub outer_c: Vec<u32>,
    pub outer_ptr: Vec<u32>,
    pub run_b: Vec<u32>,
    pub run_len: Vec<u32>,
    /// Spine run index of each view run — a bijection onto
    /// `0..spine.run_b.len()` (each spine fiber appears exactly once).
    pub fiber: Vec<u32>,
    nnz: usize,
}

impl CsfView {
    /// Bytes of this view's grouping tables (4 bytes per entry). The
    /// leaf streams it reads belong to the spine and are charged there.
    pub fn table_bytes(&self) -> u64 {
        4 * (self.rows.len()
            + self.row_runs.len()
            + self.outer_c.len()
            + self.outer_ptr.len()
            + self.run_b.len()
            + self.run_len.len()
            + self.fiber.len()) as u64
    }
}

/// Borrowed [`ModePlan`] adapter pairing a [`CsfView`] with its spine:
/// run `j` reads the spine's lane-padded slots of fiber `fiber[j]`, and
/// its contribution-cache slot *is* the spine run index — which is what
/// lets one cache fill serve every non-leaf mode.
#[derive(Debug, Clone, Copy)]
pub struct CsfModeView<'a> {
    pub view: &'a CsfView,
    pub spine: &'a TtmPlan,
}

impl ModePlan for CsfModeView<'_> {
    fn mode(&self) -> usize {
        self.view.mode
    }
    fn nnz(&self) -> usize {
        self.view.nnz
    }
    fn oks(&self) -> &[usize] {
        &self.view.oks
    }
    fn khat(&self) -> usize {
        self.view.khat
    }
    fn kp(&self) -> usize {
        self.view.kp
    }
    fn others(&self) -> &[usize] {
        &self.view.others
    }
    fn rows(&self) -> &[u32] {
        &self.view.rows
    }
    fn row_runs(&self) -> &[u32] {
        &self.view.row_runs
    }
    fn outer_c(&self) -> &[u32] {
        &self.view.outer_c
    }
    fn outer_ptr(&self) -> &[u32] {
        &self.view.outer_ptr
    }
    fn run_b(&self) -> &[u32] {
        &self.view.run_b
    }
    fn run_len(&self, j: usize) -> usize {
        self.view.run_len[j] as usize
    }
    fn run_slots(&self, j: usize) -> (usize, usize) {
        let f = self.view.fiber[j] as usize;
        (self.spine.slot_ptr[f] as usize, self.spine.slot_ptr[f + 1] as usize)
    }
    fn streams(&self) -> (&[u32], &[f32]) {
        (&self.spine.fa, &self.spine.vals)
    }
    fn cache_slot(&self, j: usize) -> usize {
        self.view.fiber[j] as usize
    }
}

/// Maintenance outcome of one shared tree: at most one unit of work per
/// rank — the dirty-subtree accounting `IngestReport` aggregates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CsfMaint {
    /// 1 when the update was absorbed by splicing the shared tree.
    pub spliced: usize,
    /// 1 when the whole tree was rebuilt.
    pub rebuilt: usize,
}

/// One rank's shared CSF plan: the spine [`TtmPlan`] (mode `N-1`, owner
/// of the canonical fiber streams) plus one [`CsfLower`] per mode
/// `0..N-1`. See the module docs for the layout and reuse model.
#[derive(Debug, Clone, PartialEq)]
pub struct CsfPlan {
    /// Mode-`N-1` plan; its runs are the tree's fibers and its
    /// `fa`/`vals` are the only leaf streams the views read.
    pub spine: TtmPlan,
    /// Modes `0..N-1` (mode 0 is always a `Stream`).
    pub lower: Vec<CsfLower>,
    ndim: usize,
}

impl CsfPlan {
    /// Build one rank's shared tree from its per-mode element lists
    /// (`elems[n]` is the rank's list for mode `n`; `elems.len() ==
    /// t.ndim()`). Mode `n ∈ 1..N-1` becomes a [`CsfView`] exactly when
    /// its element *set* equals mode `N-1`'s — deterministic, so two
    /// builds over the same lists are `==`.
    pub fn build(t: &SparseTensor, elems: &[&[u32]], core: &CoreRanks) -> CsfPlan {
        let ndim = t.ndim();
        assert!(ndim == 3 || ndim == 4, "HOOI supports 3-D and 4-D tensors");
        assert_eq!(elems.len(), ndim, "one element list per mode");
        let spine = TtmPlan::build_with(t, ndim - 1, elems[ndim - 1], core);
        let mut spine_set: Vec<u32> = elems[ndim - 1].to_vec();
        spine_set.sort_unstable();
        let mut lower = Vec::with_capacity(ndim - 1);
        lower.push(CsfLower::Stream(TtmPlan::build_with(t, 0, elems[0], core)));
        for n in 1..ndim - 1 {
            let mut set: Vec<u32> = elems[n].to_vec();
            set.sort_unstable();
            if set == spine_set {
                lower.push(CsfLower::View(derive_view(&spine, n, core)));
            } else {
                lower.push(CsfLower::Stream(TtmPlan::build_with(t, n, elems[n], core)));
            }
        }
        CsfPlan { spine, lower, ndim }
    }

    pub fn ndim(&self) -> usize {
        self.ndim
    }

    /// Does any mode share the spine's fibers? (When false the tree is
    /// per-mode streams under one roof — no cache, no reuse.)
    pub fn has_views(&self) -> bool {
        self.lower.iter().any(|l| matches!(l, CsfLower::View(_)))
    }

    /// Real elements the given mode's component covers.
    pub fn mode_nnz(&self, mode: usize) -> usize {
        if mode == self.ndim - 1 {
            return self.spine.nnz();
        }
        match &self.lower[mode] {
            CsfLower::Stream(p) => p.nnz(),
            CsfLower::View(v) => v.nnz,
        }
    }

    /// Assemble Z for `mode`, with the sweep-scoped contribution-cache
    /// lifecycle: mode 0 (always a stream) invalidates the cache — its
    /// TTM precedes the F_0 update that stales any previous sweep's
    /// contributions — the first view of the sweep fills it, and every
    /// later view plus the spine reuses it. Callers must assemble modes
    /// in sweep order `0..N-1` (the HOOI driver always does); the cache
    /// additionally shape-guards itself against structural changes.
    /// Engine routing per component matches [`TtmPlan::assemble`]; the
    /// batched engine path runs cache-off (identical batch boundaries,
    /// no per-run accumulator to reuse).
    pub fn assemble(
        &self,
        mode: usize,
        factors: &[Mat],
        engine: &Engine,
        ws: &mut PlanWorkspace,
    ) -> LocalZ {
        let spine_runs = self.spine.run_b.len();
        let kp = self.spine.kp;
        if mode == self.ndim - 1 {
            let fused = engine.prefers_fused_ttm() || !ModePlan::uniform_core(&self.spine);
            let cache = if fused && ws.contrib_matches(spine_runs, kp) {
                CachePolicy::Use
            } else {
                CachePolicy::Off
            };
            return assemble_over(&self.spine, factors, engine, ws, cache);
        }
        match &self.lower[mode] {
            CsfLower::Stream(p) => {
                if mode == 0 {
                    ws.contrib_invalidate();
                }
                p.assemble(factors, engine, ws)
            }
            CsfLower::View(v) => {
                let mv = CsfModeView { view: v, spine: &self.spine };
                let fused = engine.prefers_fused_ttm() || !mv.uniform_core();
                let cache = if !fused {
                    CachePolicy::Off
                } else if ws.contrib_matches(spine_runs, kp) {
                    CachePolicy::Use
                } else {
                    ws.contrib_prepare(spine_runs, kp);
                    CachePolicy::Fill
                };
                let z = assemble_over(&mv, factors, engine, ws, cache);
                if cache == CachePolicy::Fill {
                    ws.contrib_commit();
                }
                z
            }
        }
    }

    /// Apply one rank's ingest delta to the shared tree — the single
    /// splice/rebuild path replacing the N per-mode ones. `elems[n]`,
    /// `appended[n]`, `changed[n]` are this rank's post-update element
    /// list, appended ids (ascending), and changed ids for mode `n`.
    ///
    /// Splice fast paths (mirroring the per-mode driver guards): a
    /// changes-only batch splices per component with no uniformity
    /// requirement (values can't flip the view/stream split); a batch
    /// with appends must be small (≤ 64 updates, ≤ nnz/4) *and* uniform
    /// — the same appended/changed ids in every mode, which is what the
    /// uni placement schemes produce and what guarantees the view/stream
    /// split cannot flip (appended ids are new, so adding one identical
    /// id set to two element sets preserves their (in)equality). The
    /// spine and every stream component splice through the `TtmPlan`
    /// paths; views are then re-derived from the spliced spine. Any
    /// other delta rebuilds the whole tree. Either way the result is
    /// `==` to a fresh [`CsfPlan::build`] on the updated lists — the
    /// shared-tree extension of the splice ≡ fresh-build contract.
    pub fn apply_delta(
        &mut self,
        t: &SparseTensor,
        core: &CoreRanks,
        elems: &[&[u32]],
        appended: &[&[u32]],
        changed: &[&[u32]],
    ) -> CsfMaint {
        let ndim = self.ndim;
        let total: usize =
            (0..ndim).map(|n| appended[n].len() + changed[n].len()).sum();
        if total == 0 {
            return CsfMaint::default();
        }
        if (0..ndim).all(|n| appended[n].is_empty()) {
            // Value-only delta: the structure is untouched, so each
            // component splices its own mode's changed ids without any
            // uniformity requirement — a view's element set equals the
            // spine's, so its changed set coincides with the spine's and
            // the spine splice covers it (views read values through the
            // spine and need no refresh).
            let updates = changed.iter().map(|c| c.len()).max().unwrap_or(0);
            let small = updates <= 64 && updates * 4 <= self.spine.nnz().max(1);
            if small && self.try_splice_values(t, changed) {
                return CsfMaint { spliced: 1, rebuilt: 0 };
            }
            *self = CsfPlan::build(t, elems, core);
            return CsfMaint { spliced: 0, rebuilt: 1 };
        }
        let uniform = (1..ndim)
            .all(|n| appended[n] == appended[0] && changed[n] == changed[0]);
        let updates = appended[0].len() + changed[0].len();
        let small = updates <= 64 && updates * 4 <= self.spine.nnz().max(1);
        if uniform && small && self.try_splice(t, appended[0], changed[0]) {
            self.refresh_views(core);
            CsfMaint { spliced: 1, rebuilt: 0 }
        } else {
            *self = CsfPlan::build(t, elems, core);
            CsfMaint { spliced: 0, rebuilt: 1 }
        }
    }

    /// Rebuild this rank's tree from scratch — the migration/recovery
    /// path (`MigrationPlan` apply and survivor re-placement both hand a
    /// rank a reshaped element set; ownership changes don't satisfy the
    /// append-only splice contract, so dirty subtrees rebuild whole).
    pub fn rebuild(&mut self, t: &SparseTensor, core: &CoreRanks, elems: &[&[u32]]) {
        *self = CsfPlan::build(t, elems, core);
    }

    /// Per-component value splice for a changes-only delta: the spine
    /// takes mode N−1's changed ids, each stream component its own
    /// mode's. `false` when a changed coordinate is missing (caller
    /// rebuilds; partial mutation is fine — the rebuild overwrites the
    /// whole tree).
    fn try_splice_values(&mut self, t: &SparseTensor, changed: &[&[u32]]) -> bool {
        for &eu in changed[self.ndim - 1] {
            let e = eu as usize;
            let (row, a, b, c) = plan_coords(&self.spine, t, e);
            if !self.spine.splice_value(row, a, b, c, t.vals[e]) {
                return false;
            }
        }
        for (n, low) in self.lower.iter_mut().enumerate() {
            if let CsfLower::Stream(p) = low {
                for &eu in changed[n] {
                    let e = eu as usize;
                    let (row, a, b, c) = plan_coords(p, t, e);
                    if !p.splice_value(row, a, b, c, t.vals[e]) {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Splice `changed` then `appended` (id order) into the spine and
    /// every stream component. `false` when a changed coordinate is
    /// missing (caller rebuilds; partial mutation is fine — the rebuild
    /// overwrites the whole tree).
    fn try_splice(&mut self, t: &SparseTensor, appended: &[u32], changed: &[u32]) -> bool {
        for &eu in changed {
            let e = eu as usize;
            let (row, a, b, c) = plan_coords(&self.spine, t, e);
            if !self.spine.splice_value(row, a, b, c, t.vals[e]) {
                return false;
            }
            for low in &mut self.lower {
                if let CsfLower::Stream(p) = low {
                    let (row, a, b, c) = plan_coords(p, t, e);
                    if !p.splice_value(row, a, b, c, t.vals[e]) {
                        return false;
                    }
                }
            }
        }
        for &eu in appended {
            let e = eu as usize;
            let (row, a, b, c) = plan_coords(&self.spine, t, e);
            self.spine.splice_append(row, a, b, c, t.vals[e]);
            for low in &mut self.lower {
                if let CsfLower::Stream(p) = low {
                    let (row, a, b, c) = plan_coords(p, t, e);
                    p.splice_append(row, a, b, c, t.vals[e]);
                }
            }
        }
        true
    }

    /// Re-derive every view from the (possibly spliced) spine. Views
    /// are pure functions of the spine, so this lands exactly the
    /// grouping tables a fresh build would.
    fn refresh_views(&mut self, core: &CoreRanks) {
        for n in 1..self.ndim - 1 {
            if matches!(self.lower[n], CsfLower::View(_)) {
                self.lower[n] = CsfLower::View(derive_view(&self.spine, n, core));
            }
        }
    }

    /// Bytes of the whole tree: spine streams, stream components, view
    /// tables, plus the per-fiber contribution cache the workspace
    /// carries when any view exists (`spine runs × kp` floats) — what
    /// `memory_model` charges per rank under `PlanChoice::SharedCsf`.
    pub fn stream_bytes(&self) -> u64 {
        let mut b = self.spine.stream_bytes();
        for low in &self.lower {
            b += match low {
                CsfLower::Stream(p) => p.stream_bytes(),
                CsfLower::View(v) => v.table_bytes(),
            };
        }
        if self.has_views() {
            b += 4 * (self.spine.run_b.len() * self.spine.kp) as u64;
        }
        b
    }

    /// Analytic FLOPs of one sweep's N fused TTMs through this tree
    /// (first view fills, later views and the spine reuse).
    pub fn sweep_flops(&self) -> f64 {
        let mut filled = false;
        let mut fl = 0.0;
        for low in &self.lower {
            fl += match low {
                CsfLower::Stream(p) => fused_flops(p, false),
                CsfLower::View(v) => {
                    let mv = CsfModeView { view: v, spine: &self.spine };
                    let f = fused_flops(&mv, filled);
                    filled = true;
                    f
                }
            };
        }
        fl + fused_flops(&self.spine, filled)
    }

    /// Analytic FLOPs the same sweep costs without sharing (every mode
    /// pays its full accumulation) — the per-mode baseline the reuse is
    /// measured against in `benches/ablate_plan.rs`.
    pub fn per_mode_flops(&self) -> f64 {
        let mut fl = fused_flops(&self.spine, false);
        for low in &self.lower {
            fl += match low {
                CsfLower::Stream(p) => fused_flops(p, false),
                CsfLower::View(v) => {
                    fused_flops(&CsfModeView { view: v, spine: &self.spine }, false)
                }
            };
        }
        fl
    }
}

/// `(row, a, b, c)` of tensor element `e` in `p`'s coordinate roles
/// (`c` is 0 for 3-D plans — the `TtmPlan` splice convention).
fn plan_coords(p: &TtmPlan, t: &SparseTensor, e: usize) -> (u32, u32, u32, u32) {
    let c = if p.others.len() == 3 { t.coord(p.others[2], e) } else { 0 };
    (
        t.coord(p.mode, e),
        t.coord(p.others[0], e),
        t.coord(p.others[1], e),
        c,
    )
}

/// Derive mode `mode`'s view from the spine: enumerate the spine's runs
/// with their fiber coordinates, re-sort them under the view mode's
/// (row, slowest, slow) ordering — the exact sort keys
/// `TtmPlan::build_with` uses for that mode — and emit the grouping
/// tables. Keys are unique (one per fiber), so the result is the
/// deterministic bijection the bit-exactness contract needs.
fn derive_view(spine: &TtmPlan, mode: usize, core: &CoreRanks) -> CsfView {
    let ndim = spine.others.len() + 1;
    debug_assert!(mode >= 1 && mode < ndim - 1);
    let ks = core.resolve(ndim);
    let others = other_modes(ndim, mode);
    let oks: Vec<usize> = others.iter().map(|&m| ks[m]).collect();
    let khat: usize = oks.iter().product();
    let kp = pad_to_lanes(oks[0]);
    debug_assert_eq!(kp, spine.kp, "all non-leaf modes share the fast tile width");
    // (row, c, b, spine_run) per spine run, in the view's coordinate
    // roles: row = coord(mode), b = coord(others[1]), c = coord(others[2])
    let mut keys: Vec<(u32, u32, u32, u32)> = Vec::with_capacity(spine.run_b.len());
    if ndim == 3 {
        // spine: mode 2, runs keyed (i2 = row, i1 = run_b); view mode 1
        // has row = i1, b = i2, no outer level
        for r in 0..spine.rows.len() {
            for j in spine.row_runs[r] as usize..spine.row_runs[r + 1] as usize {
                keys.push((spine.run_b[j], 0, spine.rows[r], j as u32));
            }
        }
    } else {
        // spine: mode 3 — fiber coords i1 = run_b, i2 = outer_c, i3 = row
        for r in 0..spine.rows.len() {
            for oj in spine.row_runs[r] as usize..spine.row_runs[r + 1] as usize {
                let i2 = spine.outer_c[oj];
                let i3 = spine.rows[r];
                for j in spine.outer_ptr[oj] as usize..spine.outer_ptr[oj + 1] as usize
                {
                    let i1 = spine.run_b[j];
                    // mode 1: others [0,2,3] → row i1, b i2, c i3
                    // mode 2: others [0,1,3] → row i2, b i1, c i3
                    let (row, b) = if mode == 1 { (i1, i2) } else { (i2, i1) };
                    keys.push((row, i3, b, j as u32));
                }
            }
        }
    }
    keys.sort_unstable();
    let four = ndim == 4;
    let mut rows: Vec<u32> = Vec::new();
    let mut row_runs = vec![0u32];
    let mut outer_c: Vec<u32> = Vec::new();
    let mut outer_ptr: Vec<u32> = if four { vec![0u32] } else { Vec::new() };
    let mut run_b: Vec<u32> = Vec::with_capacity(keys.len());
    let mut run_len: Vec<u32> = Vec::with_capacity(keys.len());
    let mut fiber: Vec<u32> = Vec::with_capacity(keys.len());
    let mut i = 0usize;
    while i < keys.len() {
        let row = keys[i].0;
        while i < keys.len() && keys[i].0 == row {
            if four {
                let c = keys[i].1;
                while i < keys.len() && keys[i].0 == row && keys[i].1 == c {
                    let (_, _, b, j) = keys[i];
                    run_b.push(b);
                    run_len.push(spine.run_len[j as usize]);
                    fiber.push(j);
                    i += 1;
                }
                outer_c.push(c);
                outer_ptr.push(run_b.len() as u32);
            } else {
                let (_, _, b, j) = keys[i];
                run_b.push(b);
                run_len.push(spine.run_len[j as usize]);
                fiber.push(j);
                i += 1;
            }
        }
        rows.push(row);
        row_runs.push(if four { outer_c.len() as u32 } else { run_b.len() as u32 });
    }
    CsfView {
        mode,
        oks,
        khat,
        kp,
        others,
        rows,
        row_runs,
        outer_c,
        outer_ptr,
        run_b,
        run_len,
        fiber,
        nnz: spine.nnz(),
    }
}

/// The session-level bundle `PlanChoice::SharedCsf` threads through the
/// HOOI driver: one shared tree per rank plus the measured per-rank
/// build times (charged to the TTM phase like per-mode compilation).
#[derive(Debug, Clone)]
pub struct SharedPlans {
    pub per_rank: Vec<CsfPlan>,
    /// Wall-clock seconds each rank's tree took to build.
    pub plan_secs: Vec<f64>,
}

impl SharedPlans {
    /// Total plan bytes across ranks (see [`CsfPlan::stream_bytes`]).
    pub fn stream_bytes(&self) -> u64 {
        self.per_rank.iter().map(CsfPlan::stream_bytes).sum()
    }

    /// Analytic per-sweep TTM FLOPs with cross-mode reuse.
    pub fn sweep_flops(&self) -> f64 {
        self.per_rank.iter().map(CsfPlan::sweep_flops).sum()
    }

    /// Analytic per-sweep TTM FLOPs without reuse (per-mode baseline).
    pub fn per_mode_flops(&self) -> f64 {
        self.per_rank.iter().map(CsfPlan::per_mode_flops).sum()
    }
}

/// Assert every invariant of one shared tree against the per-mode
/// element lists it encodes: the spine and every stream component pass
/// the [`TtmPlan`] lane invariants; every view's fiber map is a
/// bijection onto the spine's runs with matching run lengths, and the
/// fiber-mapped view passes the same lane/multiset invariants through
/// its [`CsfModeView`] adapter.
pub fn check_csf_invariants(t: &SparseTensor, plan: &CsfPlan, elems: &[&[u32]]) {
    let ndim = plan.ndim();
    assert_eq!(elems.len(), ndim);
    assert_eq!(plan.lower.len(), ndim - 1);
    assert_eq!(plan.spine.mode, ndim - 1, "spine is the last mode");
    check_lane_invariants_for(t, &plan.spine, elems[ndim - 1]);
    assert!(
        matches!(plan.lower[0], CsfLower::Stream(_)),
        "mode 0 never shares the spine's fast factor"
    );
    for n in 0..ndim - 1 {
        match &plan.lower[n] {
            CsfLower::Stream(p) => {
                assert_eq!(p.mode, n);
                check_lane_invariants_for(t, p, elems[n]);
            }
            CsfLower::View(v) => {
                assert_eq!(v.mode, n);
                let mut seen = v.fiber.clone();
                seen.sort_unstable();
                assert!(
                    seen.iter().enumerate().all(|(i, &f)| i as u32 == f),
                    "mode {n} fiber map is a bijection onto spine runs"
                );
                for (j, &f) in v.fiber.iter().enumerate() {
                    assert_eq!(
                        v.run_len[j], plan.spine.run_len[f as usize],
                        "mode {n} run {j} length matches its spine fiber"
                    );
                }
                let mv = CsfModeView { view: v, spine: &plan.spine };
                check_lane_invariants_over(t, &mv, elems[n]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hooi::kernel::Kernel;
    use crate::linalg::orthonormal_random;
    use crate::util::rng::Rng;

    fn setup(dims: Vec<u32>, nnz: usize, k: usize, seed: u64) -> (SparseTensor, Vec<Mat>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(dims, nnz, &mut rng);
        let factors = t
            .dims
            .iter()
            .map(|&l| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        (t, factors)
    }

    fn all_elems(t: &SparseTensor) -> Vec<u32> {
        (0..t.nnz() as u32).collect()
    }

    #[test]
    fn shared_tree_has_views_and_passes_invariants() {
        for (dims, seed) in [(vec![14u32, 11, 9], 7u64), (vec![9, 7, 6, 5], 8)] {
            let ndim = dims.len();
            let (t, _) = setup(dims, 400, 4, seed);
            let elems = all_elems(&t);
            let lists: Vec<&[u32]> = (0..ndim).map(|_| elems.as_slice()).collect();
            let plan = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(4));
            assert!(plan.has_views(), "uniform element sets share the spine");
            for n in 1..ndim - 1 {
                assert!(matches!(plan.lower[n], CsfLower::View(_)), "mode {n}");
            }
            check_csf_invariants(&t, &plan, &lists);
            // deterministic: a second build is identical
            let again = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(4));
            assert_eq!(plan, again);
        }
    }

    #[test]
    fn disjoint_mode_sets_degrade_to_streams() {
        let (t, _) = setup(vec![10, 9, 8], 300, 3, 9);
        let elems = all_elems(&t);
        let (half_a, half_b) = elems.split_at(150);
        // mode 1 owns a different element set than the spine
        let lists: Vec<&[u32]> = vec![&elems, half_a, half_b];
        let plan = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(3));
        assert!(!plan.has_views());
        assert!(matches!(plan.lower[1], CsfLower::Stream(_)));
        check_csf_invariants(&t, &plan, &lists);
    }

    #[test]
    fn shared_sweep_is_bit_identical_to_per_mode_plans() {
        // the core contract: every mode's Z, assembled through the
        // shared tree with cache fill/reuse, is bit-identical to the
        // standalone per-mode plan on the same kernel — 3-D and 4-D,
        // scalar oracle and the detected tile, two consecutive sweeps
        // (the second exercises cache invalidation at mode 0)
        for (dims, seed) in [(vec![13u32, 10, 8], 21u64), (vec![8, 7, 6, 5], 22)] {
            let ndim = dims.len();
            let (t, factors) = setup(dims, 500, 5, seed);
            let elems = all_elems(&t);
            let lists: Vec<&[u32]> = (0..ndim).map(|_| elems.as_slice()).collect();
            let shared = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(5));
            let per_mode: Vec<TtmPlan> =
                (0..ndim).map(|n| TtmPlan::build(&t, n, &elems, 5)).collect();
            let mut rng = Rng::new(seed + 100);
            let factors2: Vec<Mat> = t
                .dims
                .iter()
                .map(|&l| orthonormal_random(l as usize, 5, &mut rng))
                .collect();
            for kern in [Kernel::Scalar, Kernel::detect()] {
                let mut ws_shared = PlanWorkspace::with_kernel(kern);
                let mut ws_pm = PlanWorkspace::with_kernel(kern);
                for fs in [&factors, &factors2] {
                    for n in 0..ndim {
                        let got = shared.assemble(n, fs, &Engine::Native, &mut ws_shared);
                        let want = per_mode[n].assemble(fs, &Engine::Native, &mut ws_pm);
                        assert_eq!(got.rows, want.rows);
                        let same = got
                            .z
                            .data
                            .iter()
                            .zip(&want.z.data)
                            .all(|(a, b)| a.to_bits() == b.to_bits());
                        assert!(same, "mode {n} kernel {} bit-exact", kern.name());
                        ws_shared.recycle(got.z);
                        ws_pm.recycle(want.z);
                    }
                }
            }
        }
    }

    #[test]
    fn splice_matches_fresh_build_on_the_shared_tree() {
        for (dims, seed) in [(vec![12u32, 9, 7], 31u64), (vec![8, 6, 5, 4], 32)] {
            let ndim = dims.len();
            let mut rng = Rng::new(seed);
            let mut t = SparseTensor::random(dims, 300, &mut rng);
            let elems0 = all_elems(&t);
            let lists0: Vec<&[u32]> = (0..ndim).map(|_| elems0.as_slice()).collect();
            let mut plan = CsfPlan::build(&t, &lists0, &CoreRanks::Uniform(4));
            // a small uniform batch: 10 appends + 3 value changes
            let mut appended: Vec<u32> = Vec::new();
            for _ in 0..10 {
                let coord: Vec<u32> =
                    t.dims.iter().map(|&d| rng.below(d as u64) as u32).collect();
                t.push(&coord, rng.f32() * 2.0 - 1.0);
                appended.push(t.nnz() as u32 - 1);
            }
            let changed: Vec<u32> = vec![3, 77, 150];
            for &e in &changed {
                t.vals[e as usize] = rng.f32() * 2.0 - 1.0;
            }
            let elems = all_elems(&t);
            let lists: Vec<&[u32]> = (0..ndim).map(|_| elems.as_slice()).collect();
            let apps: Vec<&[u32]> = (0..ndim).map(|_| appended.as_slice()).collect();
            let chgs: Vec<&[u32]> = (0..ndim).map(|_| changed.as_slice()).collect();
            let m = plan.apply_delta(&t, &CoreRanks::Uniform(4), &lists, &apps, &chgs);
            assert_eq!(m, CsfMaint { spliced: 1, rebuilt: 0 }, "small uniform batch splices");
            let fresh = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(4));
            assert_eq!(plan, fresh, "spliced shared tree ≡ fresh build");
            check_csf_invariants(&t, &plan, &lists);
            // a large batch takes the rebuild path and still matches
            let mut appended2: Vec<u32> = Vec::new();
            for _ in 0..200 {
                let coord: Vec<u32> =
                    t.dims.iter().map(|&d| rng.below(d as u64) as u32).collect();
                t.push(&coord, rng.f32() * 2.0 - 1.0);
                appended2.push(t.nnz() as u32 - 1);
            }
            let elems2 = all_elems(&t);
            let lists2: Vec<&[u32]> = (0..ndim).map(|_| elems2.as_slice()).collect();
            let apps2: Vec<&[u32]> = (0..ndim).map(|_| appended2.as_slice()).collect();
            let none: Vec<&[u32]> = (0..ndim).map(|_| &[] as &[u32]).collect();
            let m2 = plan.apply_delta(&t, &CoreRanks::Uniform(4), &lists2, &apps2, &none);
            assert_eq!(m2, CsfMaint { spliced: 0, rebuilt: 1 }, "large batch rebuilds");
            assert_eq!(plan, CsfPlan::build(&t, &lists2, &CoreRanks::Uniform(4)));
        }
    }

    #[test]
    fn value_only_deltas_splice_without_uniformity() {
        // disjoint per-mode element lists (all-Stream tree): a small
        // changes-only batch splices per component even though the
        // per-mode changed sets differ — values can't flip structure
        let mut rng = Rng::new(61);
        let mut t = SparseTensor::random(vec![11, 9, 8], 280, &mut rng);
        let elems = all_elems(&t);
        let (half_a, half_b) = elems.split_at(140);
        let lists: Vec<&[u32]> = vec![&elems, half_a, half_b];
        let mut plan = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(3));
        assert!(!plan.has_views());
        let touched = [5u32, 100, 139, 140, 200];
        for &e in &touched {
            t.vals[e as usize] = rng.f32() * 2.0 - 1.0;
        }
        // each mode's changed set is its rank list's share of the batch
        let chg_full: Vec<u32> = touched.to_vec();
        let chg_a: Vec<u32> = vec![5, 100, 139];
        let chg_b: Vec<u32> = vec![140, 200];
        let none: Vec<&[u32]> = (0..3).map(|_| &[] as &[u32]).collect();
        let chgs: Vec<&[u32]> = vec![&chg_full, &chg_a, &chg_b];
        let m = plan.apply_delta(&t, &CoreRanks::Uniform(3), &lists, &none, &chgs);
        assert_eq!(m, CsfMaint { spliced: 1, rebuilt: 0 }, "non-uniform values splice");
        assert_eq!(plan, CsfPlan::build(&t, &lists, &CoreRanks::Uniform(3)));
        check_csf_invariants(&t, &plan, &lists);
    }

    #[test]
    fn sweep_flops_show_the_reuse() {
        let (t, _) = setup(vec![20, 16, 12], 2000, 6, 41);
        let elems = all_elems(&t);
        let lists: Vec<&[u32]> = (0..3).map(|_| elems.as_slice()).collect();
        let plan = CsfPlan::build(&t, &lists, &CoreRanks::Uniform(6));
        let shared = plan.sweep_flops();
        let baseline = plan.per_mode_flops();
        assert!(
            shared < baseline,
            "reuse drops FLOPs: {shared} !< {baseline}"
        );
        // bytes: the tree (one stream set + view tables + cache) stays
        // well under three independent stream plans
        let per_mode_bytes: u64 =
            (0..3).map(|n| TtmPlan::build(&t, n, &elems, 6).stream_bytes()).sum();
        assert!(plan.stream_bytes() < per_mode_bytes);
    }

    #[test]
    fn ragged_cores_share_through_the_fused_path() {
        // per-mode (ragged) cores force the fused path everywhere; the
        // shared tree must still be bit-exact vs per-mode plans
        let (t, _) = setup(vec![10, 8, 7, 6], 400, 5, 51);
        let core = CoreRanks::PerMode(vec![5, 4, 3, 2]);
        let mut rng = Rng::new(151);
        let factors: Vec<Mat> = t
            .dims
            .iter()
            .zip(core.resolve(4))
            .map(|(&l, k)| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        let elems = all_elems(&t);
        let lists: Vec<&[u32]> = (0..4).map(|_| elems.as_slice()).collect();
        let shared = CsfPlan::build(&t, &lists, &core);
        assert!(shared.has_views());
        check_csf_invariants(&t, &shared, &lists);
        let mut ws_a = PlanWorkspace::new();
        let mut ws_b = PlanWorkspace::new();
        for n in 0..4 {
            let pm = TtmPlan::build_with(&t, n, &elems, &core);
            let got = shared.assemble(n, &factors, &Engine::Native, &mut ws_a);
            let want = pm.assemble(&factors, &Engine::Native, &mut ws_b);
            assert_eq!(got.rows, want.rows);
            let same = got
                .z
                .data
                .iter()
                .zip(&want.z.data)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "ragged mode {n} bit-exact");
            ws_a.recycle(got.z);
            ws_b.recycle(want.z);
        }
    }
}
