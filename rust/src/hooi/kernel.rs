//! Lane-blocked SIMD microkernels for the fused TTM plan streams.
//!
//! The plan layer ([`super::plan`]) lays every hot array out in dense
//! 8-wide tiles: factor rows are padded to `kp = ⌈K/LANES⌉·LANES`
//! columns, each equal-coordinate element run is padded to a multiple of
//! [`LANES`] slots (padding slots carry `val == 0.0`, extending the
//! batch path's val==0 padding contract), and Z rows are assembled in a
//! `kp`-stride tile buffer before being compacted to the `LocalZ`
//! layout. With that layout the three microkernels below never see a
//! scalar tail — every call is a whole number of 8-lane tiles:
//!
//! - [`Tile::axpy`] — `y += a·x` (the run accumulation, K flops/element),
//! - [`Tile::scale`] — `y = a·x` (the scale-accumulate form that opens a
//!   run or tile, replacing a zero-fill + axpy pair),
//! - [`Tile::expand`] / [`Tile::expand_store`] — the fused slow-factor ×
//!   fast-factor product `out[c·|acc|..] (+)= coeffs[c]·acc`, expanding
//!   one accumulated fast-factor tile by a shared slow Kronecker row.
//!
//! Three implementations share the trait: [`PortableTile`] uses
//! `chunks_exact(LANES)` loops that lower to SIMD on stable Rust on any
//! target; `Avx2Tile` (x86_64) and `NeonTile` (aarch64) are explicit
//! intrinsic paths compiled behind the `simd` cargo feature and selected
//! at *runtime* via [`Kernel::detect`] (`is_x86_feature_detected!` on
//! x86), with the portable tile as the universal fallback. The `scalar`
//! kernel is the PR 1 per-element reference path kept as the
//! equivalence oracle (`tests/kernel_equivalence.rs`) and the baseline
//! of the `benches/ablate_plan.rs` scalar-vs-tiled ablation.
//!
//! Selection is threaded through [`super::plan::PlanWorkspace`], so each
//! simulated rank records which kernel it executed (surfaced in
//! `dist::SimCluster::concurrency_report` and the `RunRecord`).
//! `TUCKER_KERNEL=scalar|portable|avx2|neon` overrides detection;
//! unavailable requests fall back to detection.

/// SIMD lane width every tiled array is padded to (f32 lanes of one
/// AVX2 register; two NEON registers).
pub const LANES: usize = 8;

/// Round `n` up to a whole number of lanes.
#[inline]
pub fn pad_to_lanes(n: usize) -> usize {
    n.div_ceil(LANES) * LANES
}

/// A TTM microkernel implementation, selected once per run and carried
/// by every `PlanWorkspace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Plain per-element loops over unpadded K-length rows — the PR 1
    /// reference arithmetic, kept as the equivalence oracle and the
    /// ablation baseline.
    Scalar,
    /// `chunks_exact(LANES)` tiles; auto-vectorizes on stable Rust and
    /// compiles on every target (the `--no-default-features` CI arm).
    Portable,
    /// AVX2+FMA intrinsics (x86_64 only, runtime-detected, behind the
    /// `simd` feature).
    Avx2,
    /// NEON intrinsics (aarch64 only, behind the `simd` feature; NEON is
    /// baseline on aarch64 so no runtime probe is needed).
    Neon,
}

impl Kernel {
    /// Every kernel, for test/bench sweeps.
    pub const ALL: [Kernel; 4] =
        [Kernel::Scalar, Kernel::Portable, Kernel::Avx2, Kernel::Neon];

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Portable => "portable",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }

    pub fn by_name(s: &str) -> Option<Kernel> {
        Kernel::ALL.into_iter().find(|k| k.name().eq_ignore_ascii_case(s))
    }

    /// Can this kernel execute on the running host (compile target,
    /// `simd` feature, and CPU feature detection all permitting)?
    pub fn available(self) -> bool {
        match self {
            Kernel::Scalar | Kernel::Portable => true,
            Kernel::Avx2 => {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                {
                    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
                }
                #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
                {
                    false
                }
            }
            Kernel::Neon => cfg!(all(feature = "simd", target_arch = "aarch64")),
        }
    }

    /// Best available tiled kernel: AVX2 → NEON → portable.
    pub fn detect() -> Kernel {
        if Kernel::Avx2.available() {
            Kernel::Avx2
        } else if Kernel::Neon.available() {
            Kernel::Neon
        } else {
            Kernel::Portable
        }
    }

    /// Detection with the `TUCKER_KERNEL` override (read through
    /// `util::env` — typed option > env > detection). Unknown names and
    /// kernels the host cannot run fall back to [`Kernel::detect`]
    /// (`scalar` and `portable` are always honored).
    pub fn from_env() -> Kernel {
        crate::util::env::resolve(
            None,
            crate::util::env::KERNEL,
            |s| Kernel::by_name(s).filter(|k| k.available()),
            Kernel::detect,
        )
    }

    /// Map to a kernel that can actually run here (unavailable SIMD
    /// requests degrade to the portable tile, never to scalar).
    pub fn resolve(self) -> Kernel {
        if self.available() {
            self
        } else {
            Kernel::Portable
        }
    }
}

/// The microkernel contract. Every slice is a whole number of
/// [`LANES`]-wide tiles: `x.len() == y.len()`, `acc.len()` and
/// `out.len() == coeffs.len() · acc.len()` are all multiples of `LANES`
/// (the plan layout guarantees this; `debug_assert`ed here).
pub(crate) trait Tile {
    /// y += a·x over whole tiles.
    fn axpy(a: f32, x: &[f32], y: &mut [f32]);

    /// y = a·x over whole tiles — the scale(-accumulate) opener that
    /// replaces `fill(0.0)` + `axpy` for the first element of a run.
    fn scale(a: f32, x: &[f32], y: &mut [f32]);

    /// Fused slow×fast product: `out[c·|acc|..][..|acc|] += coeffs[c]·acc`
    /// for every slow-factor coefficient.
    #[inline]
    fn expand(coeffs: &[f32], acc: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), coeffs.len() * acc.len());
        for (&c, seg) in coeffs.iter().zip(out.chunks_exact_mut(acc.len())) {
            Self::axpy(c, acc, seg);
        }
    }

    /// Storing variant of [`Tile::expand`] (`=` instead of `+=`) — opens
    /// a fresh output tile without zero-filling it first.
    #[inline]
    fn expand_store(coeffs: &[f32], acc: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), coeffs.len() * acc.len());
        for (&c, seg) in coeffs.iter().zip(out.chunks_exact_mut(acc.len())) {
            Self::scale(c, acc, seg);
        }
    }
}

/// Portable 8-lane tiles: fixed-width inner loops over
/// `chunks_exact(LANES)` that LLVM lowers to SIMD with no scalar tail.
pub(crate) struct PortableTile;

impl Tile for PortableTile {
    #[inline]
    fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len() % LANES, 0);
        for (xc, yc) in x.chunks_exact(LANES).zip(y.chunks_exact_mut(LANES)) {
            for l in 0..LANES {
                yc[l] += a * xc[l];
            }
        }
    }

    #[inline]
    fn scale(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len() % LANES, 0);
        for (xc, yc) in x.chunks_exact(LANES).zip(y.chunks_exact_mut(LANES)) {
            for l in 0..LANES {
                yc[l] = a * xc[l];
            }
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::LANES;
    use std::arch::x86_64::*;

    // Safety contract for this module: callers must have verified
    // avx2+fma via Kernel::Avx2.available() (runtime detection), and
    // x.len() == y.len() must be a multiple of LANES.

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: caller upholds the module contract above (runtime-verified
    // avx2+fma, equal whole-LANES lengths); every unaligned load/store
    // below stays in bounds because i*LANES + LANES <= x.len().
    pub(super) unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let va = _mm256_set1_ps(a);
        for i in 0..x.len() / LANES {
            let px = x.as_ptr().add(i * LANES);
            let py = y.as_mut_ptr().add(i * LANES);
            let fma = _mm256_fmadd_ps(va, _mm256_loadu_ps(px), _mm256_loadu_ps(py));
            _mm256_storeu_ps(py, fma);
        }
    }

    #[target_feature(enable = "avx2,fma")]
    // SAFETY: same contract as axpy above.
    pub(super) unsafe fn scale(a: f32, x: &[f32], y: &mut [f32]) {
        let va = _mm256_set1_ps(a);
        for i in 0..x.len() / LANES {
            let px = x.as_ptr().add(i * LANES);
            let py = y.as_mut_ptr().add(i * LANES);
            _mm256_storeu_ps(py, _mm256_mul_ps(va, _mm256_loadu_ps(px)));
        }
    }
}

/// AVX2+FMA tiles. Only dispatched after [`Kernel::Avx2`]`.available()`
/// confirmed the CPU features at runtime (the kernel-selection contract
/// that makes the `unsafe` sound).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) struct Avx2Tile;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
impl Tile for Avx2Tile {
    #[inline]
    fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len() % LANES, 0);
        // SAFETY: dispatch guarantees avx2+fma (see Avx2Tile docs); the
        // length asserts uphold the whole-tile contract.
        unsafe { avx2::axpy(a, x, y) }
    }

    #[inline]
    fn scale(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len() % LANES, 0);
        // SAFETY: as for axpy above.
        unsafe { avx2::scale(a, x, y) }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use super::LANES;
    use std::arch::aarch64::*;

    // Safety contract: NEON is baseline on aarch64; x.len() == y.len()
    // must be a multiple of LANES (two q-registers per tile).

    #[target_feature(enable = "neon")]
    // SAFETY: caller upholds the module contract above (aarch64 baseline
    // NEON, equal whole-LANES lengths); both q-register load/store pairs
    // stay in bounds because i*LANES + LANES <= x.len().
    pub(super) unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        let va = vdupq_n_f32(a);
        for i in 0..x.len() / LANES {
            let px = x.as_ptr().add(i * LANES);
            let py = y.as_mut_ptr().add(i * LANES);
            vst1q_f32(py, vfmaq_f32(vld1q_f32(py), va, vld1q_f32(px)));
            vst1q_f32(
                py.add(4),
                vfmaq_f32(vld1q_f32(py.add(4)), va, vld1q_f32(px.add(4))),
            );
        }
    }

    #[target_feature(enable = "neon")]
    // SAFETY: same contract as axpy above.
    pub(super) unsafe fn scale(a: f32, x: &[f32], y: &mut [f32]) {
        let va = vdupq_n_f32(a);
        for i in 0..x.len() / LANES {
            let px = x.as_ptr().add(i * LANES);
            let py = y.as_mut_ptr().add(i * LANES);
            vst1q_f32(py, vmulq_f32(va, vld1q_f32(px)));
            vst1q_f32(py.add(4), vmulq_f32(va, vld1q_f32(px.add(4))));
        }
    }
}

/// NEON tiles (aarch64; NEON is architecturally guaranteed there).
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
pub(crate) struct NeonTile;

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
impl Tile for NeonTile {
    #[inline]
    fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len() % LANES, 0);
        // SAFETY: NEON is baseline on aarch64; lengths asserted above.
        unsafe { neon::axpy(a, x, y) }
    }

    #[inline]
    fn scale(a: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        debug_assert_eq!(x.len() % LANES, 0);
        // SAFETY: as for axpy above.
        unsafe { neon::scale(a, x, y) }
    }
}

/// Non-generic microkernel dispatchers (tests, benches and one-off
/// callers; the plan assembly monomorphizes over [`Tile`] instead).
/// Tile contract as in [`Tile`]: equal lengths, whole [`LANES`] tiles
/// (the scalar arm alone accepts any equal lengths).
pub fn axpy_tile(k: Kernel, a: f32, x: &[f32], y: &mut [f32]) {
    match k.resolve() {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Kernel::Avx2 => Avx2Tile::axpy(a, x, y),
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Kernel::Neon => NeonTile::axpy(a, x, y),
        Kernel::Scalar => {
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi += a * xi;
            }
        }
        _ => PortableTile::axpy(a, x, y),
    }
}

/// See [`axpy_tile`].
pub fn scale_tile(k: Kernel, a: f32, x: &[f32], y: &mut [f32]) {
    match k.resolve() {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Kernel::Avx2 => Avx2Tile::scale(a, x, y),
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Kernel::Neon => NeonTile::scale(a, x, y),
        Kernel::Scalar => {
            for (yi, &xi) in y.iter_mut().zip(x) {
                *yi = a * xi;
            }
        }
        _ => PortableTile::scale(a, x, y),
    }
}

/// See [`axpy_tile`].
pub fn expand_tile(k: Kernel, coeffs: &[f32], acc: &[f32], out: &mut [f32]) {
    match k.resolve() {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Kernel::Avx2 => Avx2Tile::expand(coeffs, acc, out),
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Kernel::Neon => NeonTile::expand(coeffs, acc, out),
        Kernel::Scalar => {
            for (&c, seg) in coeffs.iter().zip(out.chunks_exact_mut(acc.len())) {
                for (s, &a) in seg.iter_mut().zip(acc) {
                    *s += c * a;
                }
            }
        }
        _ => PortableTile::expand(coeffs, acc, out),
    }
}

/// Storing variant of [`expand_tile`] (`=` instead of `+=`): the
/// Kronecker-weight builder of the serving query engine
/// (`serve::query`). Every lane is a *pure product* `coeffs[c]·acc[i]`
/// — a single IEEE rounding on every kernel (including the FMA tiles,
/// which only fuse multiply-*add*s) — so the output is bit-identical
/// across Scalar/Portable/AVX2/NEON. Tile contract as in [`Tile`]
/// (the scalar arm alone accepts any `acc` length).
pub fn expand_store_tile(k: Kernel, coeffs: &[f32], acc: &[f32], out: &mut [f32]) {
    match k.resolve() {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Kernel::Avx2 => Avx2Tile::expand_store(coeffs, acc, out),
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Kernel::Neon => NeonTile::expand_store(coeffs, acc, out),
        Kernel::Scalar => {
            for (&c, seg) in coeffs.iter().zip(out.chunks_exact_mut(acc.len())) {
                for (s, &a) in seg.iter_mut().zip(acc) {
                    *s = c * a;
                }
            }
        }
        _ => PortableTile::expand_store(coeffs, acc, out),
    }
}

/// Accumulate one lane-padded run of plan slots into `acc`:
/// `acc = Σ_s vals[s] · apad[fa[s]·kp..][..kp]`. This is the
/// subtree-contribution microkernel of the plan layer — the per-fiber
/// fast-factor combination every mode's TTM starts from, and the
/// quantity `hooi::csf::CsfPlan` caches across the sweep's N modes.
/// `fa`/`vals` are whole lane tiles (run padding carries `val == 0.0`);
/// `apad` is the `kp`-stride padded fast factor; `acc.len() == kp`.
/// The first tile opens with [`Tile::scale`], so `acc` need not be
/// zeroed. Monomorphized per [`Tile`] from the plan assembly; the
/// [`contrib_run`] dispatcher below is the standalone entry point.
pub(crate) fn accumulate_run<MK: Tile>(
    fa: &[u32],
    vals: &[f32],
    apad: &[f32],
    kp: usize,
    acc: &mut [f32],
) {
    debug_assert!(!fa.is_empty() && fa.len() % LANES == 0);
    debug_assert_eq!(fa.len(), vals.len());
    let row = |f: u32| &apad[f as usize * kp..f as usize * kp + kp];
    // First tile: scale-open the accumulator, then axpy the rest.
    MK::scale(vals[0], row(fa[0]), acc);
    for l in 1..LANES {
        MK::axpy(vals[l], row(fa[l]), acc);
    }
    for (f8, v8) in fa[LANES..]
        .chunks_exact(LANES)
        .zip(vals[LANES..].chunks_exact(LANES))
    {
        for l in 0..LANES {
            MK::axpy(v8[l], row(f8[l]), acc);
        }
    }
}

/// Scalar oracle for the subtree contribution: the same per-element
/// multiply-add sequence as [`accumulate_run`] written as plain loops —
/// one rounding per operation, no FMA, the reference the tiled paths
/// are pinned against. Accepts the same padded layout (`acc.len() ==
/// kp`); padding slots contribute `0.0 · row`, which leaves every
/// accumulator lane bit-unchanged.
pub fn contrib_run_scalar(fa: &[u32], vals: &[f32], apad: &[f32], kp: usize, acc: &mut [f32]) {
    debug_assert!(!fa.is_empty());
    debug_assert_eq!(fa.len(), vals.len());
    debug_assert_eq!(acc.len(), kp);
    let row = |f: u32| &apad[f as usize * kp..f as usize * kp + kp];
    for (a, &x) in acc.iter_mut().zip(row(fa[0])) {
        *a = vals[0] * x;
    }
    for (&f, &v) in fa[1..].iter().zip(&vals[1..]) {
        for (a, &x) in acc.iter_mut().zip(row(f)) {
            *a += v * x;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2,fma")]
// SAFETY: caller must have verified avx2+fma at runtime (contrib_run's
// dispatch does); the body is safe code whose tiles inherit the feature.
unsafe fn contrib_run_avx2(fa: &[u32], vals: &[f32], apad: &[f32], kp: usize, acc: &mut [f32]) {
    accumulate_run::<Avx2Tile>(fa, vals, apad, kp, acc)
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[target_feature(enable = "neon")]
// SAFETY: NEON is baseline on aarch64; the body is safe code.
unsafe fn contrib_run_neon(fa: &[u32], vals: &[f32], apad: &[f32], kp: usize, acc: &mut [f32]) {
    accumulate_run::<NeonTile>(fa, vals, apad, kp, acc)
}

/// Kernel-dispatched subtree-contribution entry point: one run's
/// fast-factor accumulation `acc = Σ_s vals[s]·apad[fa[s]]` behind the
/// same runtime [`Kernel`] selection as the other microkernels (scalar
/// oracle, portable tile, AVX2/NEON intrinsics). Layout contract as in
/// [`contrib_run_scalar`]; the tiled arms additionally require whole
/// [`LANES`] tiles in `fa`/`vals` (plan padding guarantees this).
pub fn contrib_run(k: Kernel, fa: &[u32], vals: &[f32], apad: &[f32], kp: usize, acc: &mut [f32]) {
    match k.resolve() {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: dispatch guarantees avx2+fma via Kernel::available().
        Kernel::Avx2 => unsafe { contrib_run_avx2(fa, vals, apad, kp, acc) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: NEON is baseline on aarch64.
        Kernel::Neon => unsafe { contrib_run_neon(fa, vals, apad, kp, acc) },
        Kernel::Scalar => contrib_run_scalar(fa, vals, apad, kp, acc),
        _ => accumulate_run::<PortableTile>(fa, vals, apad, kp, acc),
    }
}

/// `y += a·x` over slices of *any* equal length: the whole-[`LANES`]
/// prefix runs through the tiled kernel, the remainder through the
/// scalar tail — the K̂-tiled scatter-add of `flush_contrib_batch`
/// (K̂ is not lane-padded there). With `a == 1.0` the result is
/// bit-identical to the plain scalar loop on every kernel: FMA computes
/// `round(y + 1·x) = round(y + x)`, the same single rounding as the
/// scalar add, and the operation is element-wise (no reassociation).
pub fn axpy_any(k: Kernel, a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % LANES;
    axpy_tile(k, a, &x[..split], &mut y[..split]);
    for (yi, &xi) in y[split..].iter_mut().zip(&x[split..]) {
        *yi += a * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile_inputs(n: usize, seed: u32) -> (Vec<f32>, Vec<f32>) {
        // deterministic, sign-mixed values without pulling in Rng
        let x: Vec<f32> =
            (0..n).map(|i| ((i as f32 + seed as f32) * 0.37).sin()).collect();
        let y: Vec<f32> =
            (0..n).map(|i| ((i as f32 * 1.3 - seed as f32) * 0.21).cos()).collect();
        (x, y)
    }

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (&u, &v)) in a.iter().zip(b).enumerate() {
            assert!(
                (u - v).abs() <= 1e-5 * (1.0 + u.abs().max(v.abs())),
                "lane {i}: {u} vs {v}"
            );
        }
    }

    fn check_kernel_ops(k: Kernel) {
        for n in [LANES, 2 * LANES, 5 * LANES] {
            let (x, y0) = tile_inputs(n, 3);
            // axpy vs scalar reference
            let mut want = y0.clone();
            axpy_tile(Kernel::Scalar, 0.75, &x, &mut want);
            let mut got = y0.clone();
            axpy_tile(k, 0.75, &x, &mut got);
            assert_close(&got, &want);
            // scale vs scalar reference
            let mut want = y0.clone();
            scale_tile(Kernel::Scalar, -1.25, &x, &mut want);
            let mut got = y0;
            scale_tile(k, -1.25, &x, &mut got);
            assert_close(&got, &want);
            // expand vs scalar reference (3 coefficients)
            let coeffs = [0.5f32, -2.0, 3.0];
            let mut want = vec![0.25f32; 3 * n];
            expand_tile(Kernel::Scalar, &coeffs, &x, &mut want);
            let mut got = vec![0.25f32; 3 * n];
            expand_tile(k, &coeffs, &x, &mut got);
            assert_close(&got, &want);
        }
    }

    #[test]
    fn portable_tile_matches_scalar_reference() {
        check_kernel_ops(Kernel::Portable);
    }

    #[test]
    fn detected_kernel_matches_scalar_reference() {
        // exercises the intrinsic path whenever the host supports one
        check_kernel_ops(Kernel::detect());
    }

    #[test]
    fn detection_and_resolution_are_sane() {
        let d = Kernel::detect();
        assert!(d.available());
        assert_ne!(d, Kernel::Scalar, "detection never picks the oracle");
        for k in Kernel::ALL {
            assert!(k.resolve().available());
            assert_eq!(Kernel::by_name(k.name()), Some(k));
        }
        assert_eq!(Kernel::by_name("AVX2"), Some(Kernel::Avx2));
        assert_eq!(Kernel::by_name("nope"), None);
        // unavailable kernels degrade to the portable tile, not scalar
        for k in [Kernel::Avx2, Kernel::Neon] {
            if !k.available() {
                assert_eq!(k.resolve(), Kernel::Portable);
            }
        }
        assert!(Kernel::from_env().available());
    }

    #[test]
    fn expand_store_is_bit_identical_across_kernels() {
        // pure products round once everywhere — the serve-engine
        // contract expand_store_tile's docs state
        let (x, _) = tile_inputs(3 * LANES, 11);
        let coeffs = [0.5f32, -1.75, 3.1415];
        let mut want = vec![f32::NAN; coeffs.len() * x.len()];
        expand_store_tile(Kernel::Scalar, &coeffs, &x, &mut want);
        for k in [Kernel::Portable, Kernel::detect()] {
            let mut got = vec![f32::NAN; coeffs.len() * x.len()];
            expand_store_tile(k, &coeffs, &x, &mut got);
            let same = want
                .iter()
                .zip(&got)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "kernel {}", k.name());
        }
    }

    #[test]
    fn axpy_any_handles_ragged_lengths() {
        for n in [1usize, 7, LANES, LANES + 3, 4 * LANES + 5] {
            let (x, y0) = tile_inputs(n, 5);
            // a == 1.0: bit-identical to the scalar loop on every kernel
            let mut want = y0.clone();
            axpy_any(Kernel::Scalar, 1.0, &x, &mut want);
            for k in [Kernel::Portable, Kernel::detect()] {
                let mut got = y0.clone();
                axpy_any(k, 1.0, &x, &mut got);
                let same = want
                    .iter()
                    .zip(&got)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "n {n}, kernel {}", k.name());
            }
            // general a: numerically close (FMA may round differently)
            let mut want = y0.clone();
            axpy_any(Kernel::Scalar, 0.3, &x, &mut want);
            let mut got = y0;
            axpy_any(Kernel::detect(), 0.3, &x, &mut got);
            assert_close(&got, &want);
        }
    }

    #[test]
    fn contrib_run_matches_scalar_oracle() {
        // one padded run: 11 real elements → 16 slots, kp = 2 lanes
        let kp = 2 * LANES;
        let nrows = 6usize;
        let apad: Vec<f32> =
            (0..nrows * kp).map(|i| ((i as f32) * 0.11).sin()).collect();
        let real = 11usize;
        let slots = pad_to_lanes(real);
        let mut fa: Vec<u32> = (0..real as u32).map(|i| i % nrows as u32).collect();
        let mut vals: Vec<f32> =
            (0..real).map(|i| ((i as f32) * 0.7 - 1.0).cos()).collect();
        // plan padding contract: repeat the last real row id, val == 0.0
        fa.resize(slots, fa[real - 1]);
        vals.resize(slots, 0.0);

        let mut want = vec![f32::NAN; kp];
        contrib_run(Kernel::Scalar, &fa, &vals, &apad, kp, &mut want);
        for k in [Kernel::Portable, Kernel::detect()] {
            let mut got = vec![f32::NAN; kp];
            contrib_run(k, &fa, &vals, &apad, kp, &mut got);
            assert_close(&got, &want);
        }
        // the generic tile path is what the plan assembly monomorphizes
        let mut got = vec![f32::NAN; kp];
        accumulate_run::<PortableTile>(&fa, &vals, &apad, kp, &mut got);
        assert_close(&got, &want);
    }

    #[test]
    fn pad_to_lanes_rounds_up() {
        assert_eq!(pad_to_lanes(0), 0);
        assert_eq!(pad_to_lanes(1), LANES);
        assert_eq!(pad_to_lanes(LANES), LANES);
        assert_eq!(pad_to_lanes(LANES + 1), 2 * LANES);
        assert_eq!(pad_to_lanes(16), 16);
    }
}
