//! Per-mode core ranks K_n (paper §2, Eq. 1).
//!
//! The paper's experiments fix K_n = K, but its formulation is stated
//! for general per-mode ranks — a (doc × term × time) tensor may well
//! want a wide doc/term core and a narrow time core. [`CoreRanks`] is
//! the typed choice threaded through the whole stack: `HooiConfig`, the
//! kp-tiled TTM plans (`hooi::plan`), the per-mode Lanczos truncation,
//! the factor-matrix transfer patterns, and the Fig 17 memory model.

use std::fmt;

/// Core tensor shape choice: one K for every mode, or one K_n per mode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoreRanks {
    /// K_n = K for all modes (the paper's configuration).
    Uniform(usize),
    /// Explicit per-mode ranks `[K_0, …, K_{N−1}]`; the length must
    /// match the tensor order.
    PerMode(Vec<usize>),
}

impl CoreRanks {
    /// Per-mode ranks for an order-`ndim` tensor, or an error message
    /// when the choice cannot apply (length mismatch, zero rank).
    pub fn validate(&self, ndim: usize) -> Result<Vec<usize>, String> {
        let ks = match self {
            CoreRanks::Uniform(k) => vec![*k; ndim],
            CoreRanks::PerMode(v) => {
                if v.len() != ndim {
                    return Err(format!(
                        "core ranks {v:?} name {} modes but the tensor has {ndim}",
                        v.len()
                    ));
                }
                v.clone()
            }
        };
        if let Some(n) = ks.iter().position(|&k| k == 0) {
            return Err(format!("core rank K_{n} must be at least 1"));
        }
        Ok(ks)
    }

    /// [`validate`](CoreRanks::validate) that panics on misuse — for
    /// internal callers past the session/CLI validation boundary.
    pub fn resolve(&self, ndim: usize) -> Vec<usize> {
        self.validate(ndim).expect("core ranks match the tensor order")
    }

    /// All modes share one K?
    pub fn is_uniform(&self) -> bool {
        match self {
            CoreRanks::Uniform(_) => true,
            CoreRanks::PerMode(v) => v.windows(2).all(|w| w[0] == w[1]),
        }
    }

    /// The largest K_n (bounds Lanczos iteration counts, RunRecord `k`).
    pub fn max_rank(&self) -> usize {
        match self {
            CoreRanks::Uniform(k) => *k,
            CoreRanks::PerMode(v) => v.iter().copied().max().unwrap_or(0),
        }
    }
}

impl From<usize> for CoreRanks {
    fn from(k: usize) -> CoreRanks {
        CoreRanks::Uniform(k)
    }
}

impl From<Vec<usize>> for CoreRanks {
    fn from(v: Vec<usize>) -> CoreRanks {
        CoreRanks::PerMode(v)
    }
}

impl fmt::Display for CoreRanks {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoreRanks::Uniform(k) => write!(f, "{k}"),
            CoreRanks::PerMode(v) => {
                let parts: Vec<String> = v.iter().map(|k| k.to_string()).collect();
                write!(f, "{}", parts.join("x"))
            }
        }
    }
}

/// K̂_n = Π_{j≠n} K_j — the penultimate-matrix width of mode `n`.
pub fn khat_of(ks: &[usize], n: usize) -> usize {
    ks.iter()
        .enumerate()
        .filter(|&(j, _)| j != n)
        .map(|(_, &k)| k)
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_resolves_to_equal_ranks() {
        assert_eq!(CoreRanks::Uniform(5).resolve(3), vec![5, 5, 5]);
        assert!(CoreRanks::Uniform(5).is_uniform());
        assert_eq!(CoreRanks::Uniform(5).to_string(), "5");
    }

    #[test]
    fn per_mode_validates_length_and_zero() {
        let c = CoreRanks::PerMode(vec![3, 4, 5]);
        assert_eq!(c.resolve(3), vec![3, 4, 5]);
        assert!(c.validate(4).is_err(), "length mismatch");
        assert!(CoreRanks::PerMode(vec![3, 0, 5]).validate(3).is_err());
        assert!(CoreRanks::Uniform(0).validate(3).is_err());
        assert!(!c.is_uniform());
        assert!(CoreRanks::PerMode(vec![4, 4, 4]).is_uniform());
        assert_eq!(c.to_string(), "3x4x5");
        assert_eq!(c.max_rank(), 5);
    }

    #[test]
    fn khat_is_product_of_other_ranks() {
        let ks = [3, 4, 5];
        assert_eq!(khat_of(&ks, 0), 20);
        assert_eq!(khat_of(&ks, 1), 15);
        assert_eq!(khat_of(&ks, 2), 12);
        let ks4 = [2, 3, 4, 5];
        assert_eq!(khat_of(&ks4, 1), 40);
    }
}
