//! SVD component (paper §3): Lanczos (Golub–Kahan) bidiagonalization over
//! the *sum-distributed* penultimate matrix, matrix-free through the
//! oracle model — each iteration raises one x-query (Z·v) and one y-query
//! (u·Z), answered from the truncated local copies Z^p with point-to-point
//! reduction to the σ_n row owners (x) / owner-broadcast + allreduce (y).
//!
//! Query count matches the paper's accounting (§4.3): 2K iterations ⇒
//! Q_n = 4K queries; oracle comm volume = Q_n · (R_n^sum − L_n).

use super::ttm::LocalZ;
use crate::dist::{cat, RankFailure, SimCluster};
use crate::linalg::{axpy, dot, norm2, scale, svd, Mat};
use crate::runtime::Engine;
use crate::sched::{RowMap, Sharers};
use crate::util::rng::Rng;
use crate::util::float::exactly_zero_f32;
use crate::util::timer::Stopwatch;

/// Per-mode oracle context: local copies + the communication patterns,
/// which are query-invariant and therefore precomputed once.
pub struct Oracle<'a> {
    pub locals: &'a [LocalZ],
    pub rowmap: &'a RowMap,
    pub l_n: usize,
    pub khat: usize,
    /// x-query sends per rank: (msgs, units) of partial-row reduction.
    x_comm: Vec<(u64, u64)>,
    /// y-query sends per rank: (msgs, units) of owner → sharer values.
    y_comm: Vec<(u64, u64)>,
    /// Per-rank prepared Z (device-resident tiles on the PJRT path; the
    /// upload happens once per mode and amortizes over Q_n queries).
    prepared: Vec<crate::runtime::engine::PreparedZ>,
    /// Run queries on the parallel executor only when each rank's share
    /// of the query is big enough to amortize a thread dispatch.
    parallel_worth: bool,
}

/// Average Z elements per rank below which an oracle query runs serially:
/// a ~64k-element matvec is ~50–100 µs of work, the break-even point
/// against spawning and joining a scoped worker per query.
const PAR_QUERY_MIN_ELEMS_PER_RANK: usize = 1 << 16;

impl<'a> Oracle<'a> {
    pub fn new(
        locals: &'a [LocalZ],
        rowmap: &'a RowMap,
        sharers: &Sharers,
        l_n: usize,
        khat: usize,
    ) -> Oracle<'a> {
        Self::with_engine(locals, rowmap, sharers, l_n, khat, None)
    }

    /// `engine`: pass the run's engine to enable device-side Z caching.
    pub fn with_engine(
        locals: &'a [LocalZ],
        rowmap: &'a RowMap,
        sharers: &Sharers,
        l_n: usize,
        khat: usize,
        engine: Option<&Engine>,
    ) -> Oracle<'a> {
        let p = locals.len();
        // x-query: every rank sends each non-owned local row (1 unit) to
        // its owner; messages ≈ distinct destination owners.
        let mut x_comm = vec![(0u64, 0u64); p];
        for (rank, local) in locals.iter().enumerate() {
            let mut dests: Vec<u32> = local
                .rows
                .iter()
                .map(|&l| rowmap.of(l as usize))
                .filter(|&o| o as usize != rank)
                .collect();
            let units = dests.len() as u64;
            dests.sort_unstable();
            dests.dedup();
            x_comm[rank] = (dests.len() as u64, units);
        }
        // y-query: each owner sends y(l) to every sharer but itself.
        let mut y_comm = vec![(0u64, 0u64); p];
        for l in 0..l_n {
            let owner = rowmap.of(l) as usize;
            let others = sharers
                .of(l)
                .iter()
                .filter(|&&r| r as usize != owner)
                .count() as u64;
            if others > 0 {
                y_comm[owner].0 += others; // one message per (row, dest)
                y_comm[owner].1 += others;
            }
        }
        let prepared = match engine {
            Some(e) => locals.iter().map(|l| e.prepare_z(&l.z)).collect(),
            None => locals
                .iter()
                .map(|_| crate::runtime::engine::PreparedZ::Host)
                .collect(),
        };
        let total_z: usize = locals.iter().map(|l| l.z.rows * l.z.cols).sum();
        let parallel_worth = total_z / p.max(1) >= PAR_QUERY_MIN_ELEMS_PER_RANK;
        Oracle { locals, rowmap, l_n, khat, x_comm, y_comm, prepared, parallel_worth }
    }

    /// x-query: global Z_(n) · x, answered distributed (accounting) but
    /// returned assembled. Compute really executes per rank — concurrently
    /// on the scoped-thread executor — and is timed; the reduction below
    /// runs in rank order, so the result is bit-identical to serial.
    /// Fallible: a rank failure in the SVD phase propagates out.
    pub fn matvec(
        &self,
        x: &[f32],
        engine: &Engine,
        cluster: &mut SimCluster,
    ) -> Result<Vec<f32>, RankFailure> {
        debug_assert_eq!(x.len(), self.khat);
        let mut out = vec![0.0f32; self.l_n];
        let query = |rank: usize| {
            let local = &self.locals[rank];
            engine.matvec_prepared(&self.prepared[rank], &local.z, x)
        };
        let partials: Vec<Vec<f32>> = if self.parallel_worth {
            cluster.phase_map(cat::SVD, query)?
        } else {
            // tiny query: a thread dispatch would cost more than the work
            let mut ps = Vec::with_capacity(self.locals.len());
            cluster.phase(cat::SVD, |rank| ps.push(query(rank)))?;
            ps
        };
        for (local, partial) in self.locals.iter().zip(&partials) {
            for (r, &l) in local.rows.iter().enumerate() {
                out[l as usize] += partial[r];
            }
        }
        cluster.p2p(cat::COMM_SVD, &self.x_comm)?;
        Ok(out)
    }

    /// y-query: y · Z_(n), length K̂. Owners broadcast their y values to
    /// sharers, ranks multiply locally, partials allreduce.
    pub fn rmatvec(
        &self,
        y: &[f32],
        engine: &Engine,
        cluster: &mut SimCluster,
    ) -> Result<Vec<f32>, RankFailure> {
        debug_assert_eq!(y.len(), self.l_n);
        cluster.p2p(cat::COMM_SVD, &self.y_comm)?;
        let mut out = vec![0.0f32; self.khat];
        let query = |rank: usize| {
            let local = &self.locals[rank];
            // assemble the rank's partial y over its local rows
            let y_local: Vec<f32> =
                local.rows.iter().map(|&l| y[l as usize]).collect();
            engine.rmatvec_prepared(&self.prepared[rank], &y_local, &local.z)
        };
        let partials: Vec<Vec<f32>> = if self.parallel_worth {
            cluster.phase_map(cat::SVD, query)?
        } else {
            let mut ps = Vec::with_capacity(self.locals.len());
            cluster.phase(cat::SVD, |rank| ps.push(query(rank)))?;
            ps
        };
        for partial in &partials {
            axpy(1.0, partial, &mut out);
        }
        cluster.allreduce(cat::COMM_COMMON, self.khat as u64)?;
        Ok(out)
    }
}

/// Result of the per-mode SVD step.
pub struct LanczosResult {
    /// New factor matrix F̃_n (L_n × K), rows conceptually produced at
    /// their σ_n owners.
    pub factor: Mat,
    /// Leading singular values (diagnostics).
    pub sigma: Vec<f32>,
    /// Oracle queries raised (Q_n).
    pub queries: usize,
}

/// Golub–Kahan bidiagonalization with full reorthogonalization; J = 2K
/// iterations (SLEPc-style, §7.1), followed by the small J×J bidiagonal
/// SVD. Left singular vectors U·P give the new factor matrix.
pub fn lanczos_svd(
    oracle: &Oracle,
    k: usize,
    engine: &Engine,
    cluster: &mut SimCluster,
    rng: &mut Rng,
) -> Result<LanczosResult, RankFailure> {
    let l_n = oracle.l_n;
    let khat = oracle.khat;
    let j_max = (2 * k).min(l_n).min(khat).max(1);
    let mut us: Vec<Vec<f32>> = Vec::with_capacity(j_max);
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(j_max);
    let mut alphas: Vec<f32> = Vec::new();
    let mut betas: Vec<f32> = Vec::new();
    let mut queries = 0usize;

    // v_1: random unit K̂-vector (replicated on all ranks)
    let mut v: Vec<f32> = (0..khat).map(|_| rng.normal() as f32).collect();
    let nv = norm2(&v) as f32;
    scale(1.0 / nv.max(f32::MIN_POSITIVE), &mut v);

    let eps = 1e-7f64;
    for j in 0..j_max {
        vs.push(v.clone());
        // u_j = Z v_j − β_{j−1} u_{j−1}
        let mut u = oracle.matvec(&v, engine, cluster)?;
        queries += 1;
        let t0 = Stopwatch::start();
        if j > 0 {
            let beta = betas[j - 1];
            axpy(-beta, &us[j - 1], &mut u);
        }
        // full reorthogonalization against prior u's (distributed vectors:
        // balanced by σ_n row ownership — charged total/P)
        for uu in &us {
            let c = dot(uu, &u);
            axpy(-c, uu, &mut u);
        }
        let alpha = norm2(&u);
        cluster.charge_balanced(cat::SVD, t0.seconds());
        // dots/norms on distributed vectors: one fused allreduce per iter
        cluster.allreduce(cat::COMM_COMMON, us.len() as u64 + 1)?;
        if alpha < eps {
            vs.pop();
            break;
        }
        scale(1.0 / alpha as f32, &mut u);
        us.push(u);
        alphas.push(alpha as f32);

        // w = u_j Z − α_j v_j  (y-query)
        let mut w = oracle.rmatvec(us.last().unwrap(), engine, cluster)?;
        queries += 1;
        let t1 = Stopwatch::start();
        axpy(-(alpha as f32), &v, &mut w);
        for vv in &vs {
            let c = dot(vv, &w);
            axpy(-c, vv, &mut w);
        }
        let beta = norm2(&w);
        // v-side vectors are K̂-long and replicated: every rank does this
        // work, so it charges at full measured cost
        cluster.elapsed.add(cat::SVD, t1.seconds());
        if beta < eps {
            break;
        }
        scale(1.0 / beta as f32, &mut w);
        v = w;
        betas.push(beta as f32);
    }

    let j = alphas.len();
    if j == 0 {
        // zero matrix: return an arbitrary orthonormal factor
        let f = crate::linalg::orthonormal_random(l_n, k, rng);
        return Ok(LanczosResult { factor: f, sigma: vec![0.0; k], queries });
    }
    // B: j×j upper bidiagonal (α diagonal, β superdiagonal)
    let t2 = Stopwatch::start();
    let mut b = Mat::zeros(j, j);
    for i in 0..j {
        b.set(i, i, alphas[i]);
        if i + 1 < j && i < betas.len() {
            b.set(i, i + 1, betas[i]);
        }
    }
    let small = svd(&b);
    // F̃ = U_lanczos (L×j) · P (j×k), rows distributed by σ_n
    let kk = k.min(j);
    let mut factor = Mat::zeros(l_n, k);
    for col in 0..kk {
        for (jj, uu) in us.iter().enumerate() {
            let w = small.u.get(jj, col);
            if !exactly_zero_f32(w) {
                for (l, &ul) in uu.iter().enumerate() {
                    factor.data[l * k + col] += w * ul;
                }
            }
        }
    }
    // projection work is distributed over rows (owners)
    cluster.charge_balanced(cat::SVD, t2.seconds());
    let mut sigma = small.s.clone();
    sigma.truncate(k);
    Ok(LanczosResult { factor, sigma, queries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::ttm::{assemble_local_z, dense_penultimate};
    use crate::linalg::orthonormal_random;
    use crate::linalg::qr::ortho_defect;
    use crate::sched::{ModePolicy, Sharers};
    use crate::tensor::{SliceIndex, SparseTensor};

    struct Fixture {
        t: SparseTensor,
        factors: Vec<Mat>,
        pol: ModePolicy,
        locals: Vec<LocalZ>,
        rowmap: RowMap,
        sharers: Sharers,
        k: usize,
    }

    fn fixture(p: usize, k: usize, seed: u64) -> Fixture {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(vec![30, 10, 8], 600, &mut rng);
        let factors: Vec<Mat> = t
            .dims
            .iter()
            .map(|&l| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        let assign: Vec<u32> =
            (0..t.nnz()).map(|_| rng.below(p as u64) as u32).collect();
        let pol = ModePolicy::new(p, assign);
        let idx = SliceIndex::build(&t, 0);
        let sharers = Sharers::build(&idx, &pol);
        let rowmap = RowMap::build(&sharers, p);
        let per_rank = pol.rank_elements(&idx);
        let locals: Vec<LocalZ> = per_rank
            .iter()
            .map(|elems| assemble_local_z(&t, 0, elems, &factors, k, &Engine::Native))
            .collect();
        Fixture { t, factors, pol, locals, rowmap, sharers, k }
    }

    #[test]
    fn oracle_matvec_matches_dense() {
        let fx = fixture(4, 4, 1);
        let dense = dense_penultimate(&fx.t, 0, &fx.factors);
        let oracle =
            Oracle::new(&fx.locals, &fx.rowmap, &fx.sharers, dense.rows, dense.cols);
        let mut cluster = SimCluster::new(4);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..dense.cols).map(|_| rng.normal() as f32).collect();
        let got = oracle.matvec(&x, &Engine::Native, &mut cluster).unwrap();
        let want = dense.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
        // volume accounted: x_comm units = Σ_p (rows not owned)
        assert!(cluster.volume.get(cat::COMM_SVD) >= 0.0);
    }

    #[test]
    fn oracle_rmatvec_matches_dense() {
        let fx = fixture(3, 4, 2);
        let dense = dense_penultimate(&fx.t, 0, &fx.factors);
        let oracle =
            Oracle::new(&fx.locals, &fx.rowmap, &fx.sharers, dense.rows, dense.cols);
        let mut cluster = SimCluster::new(3);
        let mut rng = Rng::new(8);
        let y: Vec<f32> = (0..dense.rows).map(|_| rng.normal() as f32).collect();
        let got = oracle.rmatvec(&y, &Engine::Native, &mut cluster).unwrap();
        let want = dense.tmatvec(&y);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn oracle_volume_is_rsum_minus_l_per_query_pair() {
        // §4.2: each x-query and each y-query move exactly R_sum − L_n units
        let fx = fixture(5, 3, 3);
        let idx = SliceIndex::build(&fx.t, 0);
        let m = crate::sched::ModeMetrics::from_sharers(&idx, &fx.pol, &fx.sharers);
        let dense_cols = super::super::ttm::khat(fx.k, 3);
        let oracle =
            Oracle::new(&fx.locals, &fx.rowmap, &fx.sharers, 30, dense_cols);
        let mut cluster = SimCluster::new(5);
        let x = vec![1.0f32; dense_cols];
        let y = vec![1.0f32; 30];
        oracle.matvec(&x, &Engine::Native, &mut cluster).unwrap();
        oracle.rmatvec(&y, &Engine::Native, &mut cluster).unwrap();
        let expect = (m.r_sum - m.l_nonempty) as f64 * 2.0;
        assert_eq!(cluster.volume.get(cat::COMM_SVD), expect);
    }

    #[test]
    fn lanczos_matches_jacobi_on_dense() {
        // leading singular values from the distributed Lanczos must match
        // a dense Jacobi SVD of the assembled penultimate matrix
        let fx = fixture(4, 5, 4);
        let dense = dense_penultimate(&fx.t, 0, &fx.factors);
        let oracle =
            Oracle::new(&fx.locals, &fx.rowmap, &fx.sharers, dense.rows, dense.cols);
        let mut cluster = SimCluster::new(4);
        let mut rng = Rng::new(11);
        let res =
            lanczos_svd(&oracle, fx.k, &Engine::Native, &mut cluster, &mut rng).unwrap();
        let full = svd(&dense);
        for i in 0..fx.k.min(3) {
            let rel = (res.sigma[i] - full.s[i]).abs() / full.s[i].max(1e-6);
            assert!(rel < 0.02, "σ_{i}: {} vs {}", res.sigma[i], full.s[i]);
        }
        assert_eq!(res.queries, 4 * fx.k.min(res.queries));
        // factor columns orthonormal
        assert!(ortho_defect(&res.factor) < 1e-2);
    }

    #[test]
    fn query_count_is_4k() {
        let fx = fixture(2, 3, 5);
        let khat = super::super::ttm::khat(fx.k, 3);
        let oracle = Oracle::new(&fx.locals, &fx.rowmap, &fx.sharers, 30, khat);
        let mut cluster = SimCluster::new(2);
        let mut rng = Rng::new(12);
        let res =
            lanczos_svd(&oracle, fx.k, &Engine::Native, &mut cluster, &mut rng).unwrap();
        // 2K iterations × 2 queries each (unless early termination)
        assert!(res.queries <= 4 * fx.k);
        assert!(res.queries >= 2 * fx.k);
    }
}
