//! Factor-matrix transfer (paper §3/§4.2): after the SVD step, each new
//! row F̃_n[l,:] lives at its owner σ_n(l) and must reach every rank that
//! needs it for the next invocation's TTM.
//!
//! Who needs row l?
//! - uni-policy: the ranks sharing Slice_n^l under the single policy π
//!   (volume K_n · (R_n^sum − L_n), determined by the metric — §4.2);
//! - multi-policy: the ranks owning an element of Slice_n^l under *any*
//!   π_j with j ≠ n — not expressible through the metrics, so it is
//!   measured empirically here, exactly as the paper does.

use crate::sched::{Distribution, RowMap};
use crate::tensor::SliceIndex;

/// Query-invariant transfer pattern for one mode (precomputed once).
#[derive(Debug, Clone)]
pub struct FmPattern {
    /// Per-rank sends: (messages, units) for the cluster's p2p accounting.
    pub per_rank: Vec<(u64, u64)>,
    /// Rows of F_n each rank must store (needers ∪ owners) — memory model.
    pub stored_rows: Vec<u64>,
    /// Total transfer volume in units (Σ (needers−1)·K_n).
    pub total_units: u64,
}

/// Build the transfer pattern for mode `n`.
pub fn fm_pattern(
    idx_n: &SliceIndex,
    dist: &Distribution,
    n: usize,
    rowmap: &RowMap,
    k_n: usize,
) -> FmPattern {
    let p = dist.p;
    let l_n = idx_n.num_slices();
    let mut per_rank = vec![(0u64, 0u64); p];
    let mut stored = vec![0u64; p];
    let mut total = 0u64;
    // stamp[r] = current slice marker, avoids per-slice clearing
    let mut stamp = vec![u32::MAX; p];
    for l in 0..l_n {
        let owner = rowmap.of(l) as usize;
        let marker = l as u32;
        let mut needers = 0u64;
        let mut owner_needs = false;
        for &e in idx_n.slice(l) {
            for (j, pol) in dist.policies.iter().enumerate() {
                if j == n {
                    continue;
                }
                let r = pol.assign[e as usize] as usize;
                if stamp[r] != marker {
                    stamp[r] = marker;
                    needers += 1;
                    stored[r] += 1;
                    if r == owner {
                        owner_needs = true;
                    }
                }
                if dist.uni {
                    break; // all policies identical: one pass suffices
                }
            }
        }
        if !owner_needs && needers > 0 {
            stored[owner] += 1; // the owner also keeps its produced row
        }
        let sends = needers.saturating_sub(if owner_needs { 1 } else { 0 });
        if sends > 0 {
            per_rank[owner].0 += sends;
            per_rank[owner].1 += sends * k_n as u64;
            total += sends * k_n as u64;
        }
    }
    FmPattern { per_rank, stored_rows: stored, total_units: total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::metrics::{ModeMetrics, Sharers};
    use crate::sched::policy::{DistTime, Distribution, ModePolicy};
    use crate::tensor::slices::build_all;
    use crate::tensor::SparseTensor;
    use crate::util::rng::Rng;

    fn random_setup(p: usize, seed: u64) -> (SparseTensor, Vec<SliceIndex>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(vec![25, 15, 10], 800, &mut rng);
        let _ = p;
        let idx = build_all(&t);
        (t, idx)
    }

    fn uni_dist(t: &SparseTensor, p: usize, seed: u64) -> Distribution {
        let mut rng = Rng::new(seed);
        let assign: Vec<u32> =
            (0..t.nnz()).map(|_| rng.below(p as u64) as u32).collect();
        Distribution {
            scheme: "uni".into(),
            p,
            policies: vec![ModePolicy::new(p, assign); t.ndim()],
            uni: true,
            time: DistTime::default(),
        }
    }

    #[test]
    fn uni_policy_volume_matches_metric_formula() {
        // §4.2: uni-policy FM volume = K_n (R_n^sum − L_n), with owners
        // chosen among sharers (σ_n construction guarantees it).
        let p = 4;
        let (t, idx) = random_setup(p, 1);
        let dist = uni_dist(&t, p, 2);
        let k_n = 5;
        for n in 0..t.ndim() {
            let sharers = Sharers::build(&idx[n], &dist.policies[n]);
            let rowmap = RowMap::build(&sharers, p);
            let m = ModeMetrics::from_sharers(&idx[n], &dist.policies[n], &sharers);
            let pat = fm_pattern(&idx[n], &dist, n, &rowmap, k_n);
            let want = (k_n * (m.r_sum - m.l_nonempty)) as u64;
            assert_eq!(pat.total_units, want, "mode {n}");
        }
    }

    #[test]
    fn multi_policy_volume_counts_union_of_other_modes() {
        // two ranks, multi-policy: mode-0 rows are needed wherever modes
        // 1..N-1 placed the slice's elements.
        let mut t = SparseTensor::new(vec![2, 2, 2]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[0, 1, 1], 1.0);
        t.push(&[1, 0, 1], 1.0);
        let idx = build_all(&t);
        let p = 2;
        // mode 0 policy: e0,e1 -> r0; e2 -> r1
        // mode 1 policy: e0 -> r1, e1 -> r0, e2 -> r1
        // mode 2 policy: e0 -> r0, e1 -> r1, e2 -> r0
        let dist = Distribution {
            scheme: "multi".into(),
            p,
            policies: vec![
                ModePolicy::new(p, vec![0, 0, 1]),
                ModePolicy::new(p, vec![1, 0, 1]),
                ModePolicy::new(p, vec![0, 1, 0]),
            ],
            uni: false,
            time: DistTime::default(),
        };
        let sharers = Sharers::build(&idx[0], &dist.policies[0]);
        let rowmap = RowMap::build(&sharers, p);
        let k_n = 3;
        let pat = fm_pattern(&idx[0], &dist, 0, &rowmap, k_n);
        // slice 0 (e0,e1): needers via π_1 {r1, r0}, via π_2 {r0, r1} -> {0,1}
        // slice 1 (e2): needers via π_1 {r1}, via π_2 {r0} -> {0,1}
        // each slice sends to 1 non-owner -> total = 2 rows * k_n
        assert_eq!(pat.total_units, (2 * k_n) as u64);
        // both ranks store both rows
        assert_eq!(pat.stored_rows, vec![2, 2]);
    }

    #[test]
    fn empty_slices_send_nothing() {
        let mut t = SparseTensor::new(vec![10, 3, 3]);
        t.push(&[0, 0, 0], 1.0);
        t.push(&[9, 2, 2], 1.0);
        let idx = build_all(&t);
        let dist = uni_dist(&t, 2, 3);
        let sharers = Sharers::build(&idx[0], &dist.policies[0]);
        let rowmap = RowMap::build(&sharers, 2);
        let pat = fm_pattern(&idx[0], &dist, 0, &rowmap, 4);
        // only 2 nonempty slices, each with exactly 1 sharer (single elem)
        assert_eq!(pat.total_units, 0);
    }

    #[test]
    fn stored_rows_at_least_owned() {
        let p = 3;
        let (t, idx) = random_setup(p, 4);
        let dist = uni_dist(&t, p, 5);
        let sharers = Sharers::build(&idx[0], &dist.policies[0]);
        let rowmap = RowMap::build(&sharers, p);
        let pat = fm_pattern(&idx[0], &dist, 0, &rowmap, 4);
        let total_stored: u64 = pat.stored_rows.iter().sum();
        // every nonempty slice is stored by each of its sharers exactly once
        let m = ModeMetrics::from_sharers(&idx[0], &dist.policies[0], &sharers);
        assert_eq!(total_stored, m.r_sum as u64);
    }
}
