//! The HOOI driver (paper Fig 2): N per-mode iterations of TTM-chain +
//! SVD per invocation, factor-matrix transfer between invocations, core
//! computed once at the end (§2.2 — refinement never needs the core).
//!
//! Everything is orchestrated over the simulated cluster: TTM assembly and
//! oracle matvecs really execute (through the engine — PJRT artifacts on
//! the hot path) and are timed per rank; communication is charged to the
//! α–β model with byte-exact volumes.
//!
//! The driver is split into session-friendly pieces: [`prepare_modes`]
//! compiles the sweep-invariant distribution state (sharers, σ_n, FM
//! patterns, per-rank TTM plans), [`HooiState`] owns everything that
//! evolves across sweeps (factors, RNG, rank workspaces, the final
//! mode's locals), and [`run_hooi`] is the one-shot composition of the
//! two that the legacy `run_scheme` shim and the tests use.
//! `coordinator::TuckerSession` keeps the prepared modes and the state
//! alive between calls, so `decompose_more` re-sweeps without paying
//! `prepare_modes` again.

use super::csf::{CsfPlan, SharedPlans};
use super::fm::{fm_pattern, FmPattern};
use super::kernel::Kernel;
use super::lanczos::{lanczos_svd, Oracle};
use super::plan::{PlanWorkspace, TtmPlan};
use super::ranks::{khat_of, CoreRanks};
use super::ttm::LocalZ;
use crate::dist::{cat, RankFailure, SimCluster};
use crate::util::float::exactly_zero_f32;
use crate::linalg::{orthonormal_random, Mat};
use crate::runtime::Engine;
use crate::sched::{Distribution, RowMap, Sharers};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct HooiConfig {
    /// Core ranks K_n — uniform (the paper's setup) or per mode.
    pub core: CoreRanks,
    /// Number of HOOI invocations (refinement sweeps).
    pub invocations: usize,
    pub seed: u64,
    /// Microkernel the rank workspaces dispatch to; `None` falls back to
    /// the `TUCKER_KERNEL` env override, then host detection.
    pub kernel: Option<Kernel>,
    /// Fig 17 tensor accounting; `None` falls back to
    /// `TUCKER_MEM_ACCOUNTING`, then plan-stream accounting.
    pub accounting: Option<TensorAccounting>,
}

impl Default for HooiConfig {
    fn default() -> Self {
        HooiConfig {
            core: CoreRanks::Uniform(10),
            invocations: 1,
            seed: 0x70C4E4,
            kernel: None,
            accounting: None,
        }
    }
}

impl HooiConfig {
    /// The paper's configuration: uniform core length K, one invocation.
    pub fn uniform(k: usize) -> HooiConfig {
        HooiConfig { core: CoreRanks::Uniform(k), ..HooiConfig::default() }
    }
}

/// Per-rank memory accounting (Fig 17 model).
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    /// Bytes per rank for stored tensor copies (N copies if multi-policy).
    pub tensor_bytes: Vec<u64>,
    /// Bytes for the largest concurrent local penultimate matrix.
    pub penultimate_bytes: Vec<u64>,
    /// Bytes for stored factor-matrix rows (Σ modes).
    pub factor_bytes: Vec<u64>,
}

impl MemoryReport {
    pub fn avg_total_mb(&self) -> f64 {
        let p = self.tensor_bytes.len().max(1);
        let total: u64 = self
            .tensor_bytes
            .iter()
            .zip(&self.penultimate_bytes)
            .zip(&self.factor_bytes)
            .map(|((&t, &z), &f)| t + z + f)
            .sum();
        total as f64 / p as f64 / (1024.0 * 1024.0)
    }

    pub fn avg_component_mb(&self) -> (f64, f64, f64) {
        let p = self.tensor_bytes.len().max(1) as f64;
        let mb = |v: &Vec<u64>| v.iter().sum::<u64>() as f64 / p / (1024.0 * 1024.0);
        (
            mb(&self.tensor_bytes),
            mb(&self.penultimate_bytes),
            mb(&self.factor_bytes),
        )
    }
}

/// Outcome of a HOOI run.
pub struct HooiOutcome {
    pub factors: Vec<Mat>,
    /// Core tensor, flattened in the K̂-layout of the last mode
    /// (G_(N-1): K_{N-1} × K̂_{N-1} row-major).
    pub core: Mat,
    /// Fit = 1 − ‖T − X‖ / ‖T‖ (X the reconstructed tensor).
    pub fit: f64,
    pub memory: MemoryReport,
    /// Leading singular values of the last mode (diagnostics).
    pub sigma: Vec<f32>,
}

/// Precomputed per-mode distribution state, reused across invocations.
pub struct ModeState {
    pub elems: Vec<Vec<u32>>,
    pub sharers: Sharers,
    pub rowmap: RowMap,
    pub fm: FmPattern,
    /// This mode's core rank K_n.
    pub k_n: usize,
    /// This mode's penultimate width K̂_n = Π_{j≠n} K_j.
    pub khat_n: usize,
    /// Precompiled per-rank TTM plans (sweep-invariant assembly layout).
    /// Empty when built with [`prepare_modes_unplanned`].
    pub plans: Vec<TtmPlan>,
    /// Measured per-rank plan compilation seconds (charged once to the
    /// cluster's TTM bucket by `run_hooi` — a real implementation pays
    /// this per rank exactly once, then amortizes it over every sweep).
    pub plan_secs: Vec<f64>,
}

/// Build the per-mode state (sharers, σ_n, FM pattern, rank elements,
/// and the precompiled TTM plans — the sweep-invariant part of the TTM
/// hot path, paid once here and amortized over every invocation). Plan
/// compilation runs on the executor the environment selects
/// (`TUCKER_PHASE_EXECUTOR`); typed callers use
/// [`prepare_modes_with_executor`].
pub fn prepare_modes(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    core: &CoreRanks,
) -> Vec<ModeState> {
    let parallel = crate::util::env::phase_executor_parallel(None);
    prepare_modes_impl(t, idx, dist, core, true, parallel, None)
}

/// [`prepare_modes`] with an explicit executor choice for the per-rank
/// plan compilation (`true` = scoped-thread pool, `false` = serial).
/// The session threads its `ExecutorChoice` through here so plan_secs
/// timings honor the serial-executor contract on busy hosts.
pub fn prepare_modes_with_executor(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    core: &CoreRanks,
    parallel: bool,
) -> Vec<ModeState> {
    prepare_modes_impl(t, idx, dist, core, true, parallel, None)
}

/// [`prepare_modes_with_executor`] reusing per-mode sharer indices the
/// caller already built against `dist` (e.g. a `PlacementPlan`'s —
/// the session hands them over so building a session does not pay the
/// O(nnz) `Sharers::build` pass twice per mode).
pub fn prepare_modes_with_sharers(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    core: &CoreRanks,
    parallel: bool,
    sharers: Vec<Sharers>,
) -> Vec<ModeState> {
    assert_eq!(sharers.len(), t.ndim(), "one sharer index per mode");
    prepare_modes_impl(t, idx, dist, core, true, parallel, Some(sharers))
}

/// Metrics/memory-only variant: skips TTM plan compilation. For
/// distribution-only figures (metrics, volumes, Fig 17 memory) that
/// never assemble a Z — `memory_model` reads none of the plan state.
pub fn prepare_modes_unplanned(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    core: &CoreRanks,
) -> Vec<ModeState> {
    prepare_modes_impl(t, idx, dist, core, false, false, None)
}

/// [`prepare_modes_unplanned`] reusing caller-built sharer indices —
/// the `PlanChoice::SharedCsf` session build path: the mode states
/// carry the distribution structure (sharers, σ_n, FM patterns, rank
/// element lists) while the assembly layout is compiled separately by
/// [`prepare_shared_plans`], one tree per rank instead of N plans.
pub fn prepare_modes_unplanned_with_sharers(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    core: &CoreRanks,
    sharers: Vec<Sharers>,
) -> Vec<ModeState> {
    assert_eq!(sharers.len(), t.ndim(), "one sharer index per mode");
    prepare_modes_impl(t, idx, dist, core, false, false, Some(sharers))
}

fn prepare_modes_impl(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    core: &CoreRanks,
    build_plans: bool,
    parallel: bool,
    precomputed: Option<Vec<Sharers>>,
) -> Vec<ModeState> {
    let ks = core.resolve(t.ndim());
    let mut pre: Vec<Option<Sharers>> = match precomputed {
        Some(v) => v.into_iter().map(Some).collect(),
        None => (0..t.ndim()).map(|_| None).collect(),
    };
    (0..t.ndim())
        .map(|n| {
            let sharers = pre[n]
                .take()
                .unwrap_or_else(|| Sharers::build(&idx[n], &dist.policies[n]));
            let rowmap = RowMap::build(&sharers, dist.p);
            let fm = fm_pattern(&idx[n], dist, n, &rowmap, ks[n]);
            let elems = dist.policies[n].rank_elements(&idx[n]);
            let (plans, plan_secs): (Vec<TtmPlan>, Vec<f64>) = if build_plans {
                // per-rank plans are independent: compile them on the
                // scoped worker pool (honoring the executor choice),
                // keeping per-rank build times honest
                let tasks: Vec<_> = elems
                    .iter()
                    .map(|es| move || TtmPlan::build_with(t, n, es, core))
                    .collect();
                crate::dist::run_scoped(tasks, parallel).into_iter().unzip()
            } else {
                (Vec::new(), vec![0.0; dist.p])
            };
            ModeState {
                elems,
                sharers,
                rowmap,
                fm,
                k_n: ks[n],
                khat_n: khat_of(&ks, n),
                plans,
                plan_secs,
            }
        })
        .collect()
}

/// Build one shared [`CsfPlan`] per rank over the prepared modes'
/// element lists — the `PlanChoice::SharedCsf` analogue of the per-mode
/// plan compilation inside [`prepare_modes`]. Pair it with
/// [`prepare_modes_unplanned`]: the mode states keep carrying the
/// distribution structure (sharers, σ_n, FM patterns, element lists)
/// while the assembly layout lives in the one tree per rank. Per-rank
/// builds run on the scoped worker pool; `plan_secs` carries the
/// measured per-rank build times for [`charge_shared_plan_compilation`].
pub fn prepare_shared_plans(
    t: &SparseTensor,
    modes: &[ModeState],
    core: &CoreRanks,
    parallel: bool,
) -> SharedPlans {
    assert_eq!(modes.len(), t.ndim(), "one mode state per mode");
    let p = modes[0].elems.len();
    let tasks: Vec<_> = (0..p)
        .map(|rank| {
            move || {
                let lists: Vec<&[u32]> =
                    modes.iter().map(|st| st.elems[rank].as_slice()).collect();
                CsfPlan::build(t, &lists, core)
            }
        })
        .collect();
    let (per_rank, plan_secs) =
        crate::dist::run_scoped(tasks, parallel).into_iter().unzip();
    SharedPlans { per_rank, plan_secs }
}

/// Charge the shared trees' compilation makespan to the TTM bucket —
/// one tree per rank replaces N per-mode plans, so the charge is a
/// single per-rank makespan rather than [`charge_plan_compilation`]'s
/// per-mode sum.
pub fn charge_shared_plan_compilation(shared: &SharedPlans, cluster: &mut SimCluster) {
    let worst = shared.plan_secs.iter().copied().fold(0.0, f64::max);
    cluster.elapsed.add(cat::TTM, worst);
}

/// One mode's share of an applied [`TensorDelta`]: the touched element
/// ids bucketed by the rank that owns them under this mode's policy.
/// Built by `TuckerSession::ingest`; the (mode, rank) pairs with a
/// non-empty bucket are exactly the *dirty* plans.
///
/// [`TensorDelta`]: crate::tensor::TensorDelta
#[derive(Debug, Clone)]
pub struct ModeDelta {
    /// Appended element ids per rank, ascending (id order).
    pub appended: Vec<Vec<u32>>,
    /// Value-changed element ids per rank (removals included), ascending.
    pub changed: Vec<Vec<u32>>,
}

impl ModeDelta {
    /// An empty delta over `p` ranks.
    pub fn empty(p: usize) -> ModeDelta {
        ModeDelta { appended: vec![Vec::new(); p], changed: vec![Vec::new(); p] }
    }

    /// Any structural (appended) updates?
    pub fn structural(&self) -> bool {
        self.appended.iter().any(|v| !v.is_empty())
    }

    /// Ranks whose plan this delta touches.
    pub fn dirty_ranks(&self) -> usize {
        self.appended
            .iter()
            .zip(&self.changed)
            .filter(|(a, c)| !a.is_empty() || !c.is_empty())
            .count()
    }
}

/// What [`ModeState::apply_delta`] did to one mode's plans.
#[derive(Debug, Clone, Default)]
pub struct DeltaStats {
    /// Plans updated in place (value splice / run splice).
    pub spliced: usize,
    /// Plans recompiled from their element list.
    pub rebuilt: usize,
    /// Makespan (max per-rank seconds) of this mode's splice/rebuild
    /// work — the partial-rebuild analogue of `plan_secs`, charged by
    /// the session to the next run's TTM bucket.
    pub rebuild_secs: f64,
}

/// `(row, a, b, c)` of element `e` in `plan`'s coordinate roles (`c` is
/// 0 for 3-D plans).
fn plan_coords(t: &SparseTensor, plan: &TtmPlan, e: usize) -> (u32, u32, u32, u32) {
    let c = if plan.others.len() == 3 {
        t.coord(plan.others[2], e)
    } else {
        0
    };
    (
        t.coord(plan.mode, e),
        t.coord(plan.others[0], e),
        t.coord(plan.others[1], e),
        c,
    )
}

/// Apply one rank's share of a delta to its plan: splice when the batch
/// is small relative to the plan (changes update slots in place, appends
/// re-pad their runs), recompile from the element list otherwise.
/// Returns whether the plan was rebuilt (vs spliced).
fn apply_rank_delta(
    plan: &mut TtmPlan,
    t: &SparseTensor,
    mode: usize,
    core: &CoreRanks,
    elems: &[u32],
    appended: &[u32],
    changed: &[u32],
) -> bool {
    let updates = appended.len() + changed.len();
    // splice only genuinely small batches: every structural splice that
    // opens a run or grows a lane block shifts the stream tail
    // (O(plan) per append), so an absolute cap — not just a fraction of
    // the plan — keeps the worst case at ~64·O(plan), well under the
    // O(|E| log |E|) recompile. Either path yields the identical
    // stream; this is purely a performance choice.
    if updates <= 64 && updates * 4 <= plan.nnz().max(1) {
        let mut ok = true;
        for &e in changed {
            let (row, a, b, c) = plan_coords(t, plan, e as usize);
            if !plan.splice_value(row, a, b, c, t.vals[e as usize]) {
                // a changed element missing from its plan means the
                // plan drifted from the tensor — recompiling from the
                // element list restores consistency either way
                ok = false;
                break;
            }
        }
        if ok {
            for &e in appended {
                let (row, a, b, c) = plan_coords(t, plan, e as usize);
                plan.splice_append(row, a, b, c, t.vals[e as usize]);
            }
            return false;
        }
    }
    *plan = TtmPlan::build_with(t, mode, elems, core);
    true
}

impl ModeState {
    /// Apply one mode's share of an ingested delta: refresh the
    /// structural state (sharers, σ_n, FM pattern, rank element lists)
    /// when elements were appended, then splice or rebuild exactly the
    /// dirty ranks' plans — never the clean ones, never a full
    /// `prepare_modes`. Dirty ranks run on the scoped worker pool
    /// (`parallel` follows the session's executor choice) and their
    /// per-rank seconds are reported as [`DeltaStats::rebuild_secs`].
    #[allow(clippy::too_many_arguments)]
    pub fn apply_delta(
        &mut self,
        t: &SparseTensor,
        idx_n: &SliceIndex,
        dist: &Distribution,
        n: usize,
        core: &CoreRanks,
        md: &ModeDelta,
        parallel: bool,
    ) -> DeltaStats {
        if md.structural() {
            // appends can open new (slice, rank) sharer pairs and move
            // row ownership/transfer patterns; these rebuilds are
            // O(nnz + L_n) — cheap next to plan compilation — and
            // deterministic, so they match a fresh prepare exactly
            self.sharers = Sharers::build(idx_n, &dist.policies[n]);
            self.rowmap = RowMap::build(&self.sharers, dist.p);
            self.fm = fm_pattern(idx_n, dist, n, &self.rowmap, self.k_n);
            for (rank, ids) in md.appended.iter().enumerate() {
                for &e in ids {
                    // keep the rank list in slice-grouped order: the new
                    // id goes after every element of its slice (they all
                    // have smaller ids) and before the next slice
                    let l = t.coord(n, e as usize);
                    let list = &mut self.elems[rank];
                    let pos =
                        list.partition_point(|&x| t.coord(n, x as usize) <= l);
                    list.insert(pos, e);
                }
            }
        }
        if self.plans.is_empty() {
            // metrics-only states ([`prepare_modes_unplanned`]) hold no
            // plans to invalidate
            return DeltaStats::default();
        }
        let ModeState { plans, elems, .. } = self;
        let mut tasks = Vec::new();
        for ((plan, es), (app, chg)) in plans
            .iter_mut()
            .zip(elems.iter())
            .zip(md.appended.iter().zip(md.changed.iter()))
        {
            if app.is_empty() && chg.is_empty() {
                continue;
            }
            tasks.push(move || apply_rank_delta(plan, t, n, core, es, app, chg));
        }
        let timed = crate::dist::run_scoped(tasks, parallel);
        let mut stats = DeltaStats::default();
        for (was_rebuilt, secs) in timed {
            if was_rebuilt {
                stats.rebuilt += 1;
            } else {
                stats.spliced += 1;
            }
            stats.rebuild_secs = stats.rebuild_secs.max(secs);
        }
        stats
    }

    /// Recompute only the factor-matrix transfer pattern against a new
    /// distribution. A migration that left this mode's own policy π_n
    /// untouched keeps its sharers, σ_n and plans valid, but the FM
    /// pattern is a function of the *other* modes' policies and must
    /// track them.
    pub fn refresh_fm(&mut self, idx_n: &SliceIndex, dist: &Distribution, n: usize) {
        self.fm = fm_pattern(idx_n, dist, n, &self.rowmap, self.k_n);
    }

    /// Apply one mode's share of a placement migration (a
    /// `MigrationPlan` produced by diffing two placements): refresh the
    /// structural state (sharers, σ_n, FM pattern, rank element lists)
    /// under the *new* distribution, then update exactly the dirty
    /// ranks' plans — a rank gaining only a small batch of
    /// strictly-newer elements splices them into its runs
    /// (`TtmPlan::splice_append`); any rank losing elements, or gaining
    /// a large or older batch, recompiles its plan from the new element
    /// list. Clean ranks keep their plans untouched and `prepare_modes`
    /// never reruns.
    ///
    /// Either path yields the exact stream a fresh
    /// `prepare_modes` on the new distribution would compile (the
    /// splice guard only fires when each incoming id exceeds every id
    /// the rank held, which pins the element to its run's tail — the
    /// same position the stable build sort produces), so migrating and
    /// rebuilding from scratch are bit-identical.
    ///
    /// `dist` must already hold the migrated policies for mode `n`;
    /// `outgoing`/`incoming` are that mode's per-rank moved-element
    /// sets, ascending by id.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_migration(
        &mut self,
        t: &SparseTensor,
        idx_n: &SliceIndex,
        dist: &Distribution,
        n: usize,
        core: &CoreRanks,
        outgoing: &[Vec<u32>],
        incoming: &[Vec<u32>],
        parallel: bool,
    ) -> DeltaStats {
        // ownership moved: the sharing structure, row ownership and
        // transfer patterns are all stale — rebuild them (O(nnz + L_n),
        // cheap next to plan compilation, deterministic ⇒ identical to
        // a fresh prepare)
        self.sharers = Sharers::build(idx_n, &dist.policies[n]);
        self.rowmap = RowMap::build(&self.sharers, dist.p);
        self.fm = fm_pattern(idx_n, dist, n, &self.rowmap, self.k_n);
        let new_elems = dist.policies[n].rank_elements(idx_n);
        if self.plans.is_empty() {
            // metrics-only states hold no plans to migrate
            self.elems = new_elems;
            return DeltaStats::default();
        }
        // the splice guard needs each rank's pre-migration id ceiling
        let old_max: Vec<Option<u32>> =
            self.elems.iter().map(|es| es.iter().copied().max()).collect();
        let mut stats = DeltaStats::default();
        {
            let plans = &mut self.plans;
            let mut tasks = Vec::new();
            for (rank, (((plan, es), inc), out)) in plans
                .iter_mut()
                .zip(new_elems.iter())
                .zip(incoming.iter())
                .zip(outgoing.iter())
                .enumerate()
            {
                if inc.is_empty() && out.is_empty() {
                    continue;
                }
                // splice only incoming-only batches of strictly-newer
                // elements, under the same size cap as streaming
                // appends; everything else recompiles this rank's plan
                let can_splice = out.is_empty()
                    && inc.len() <= 64
                    && inc.len() * 4 <= plan.nnz().max(1)
                    && match old_max[rank] {
                        None => true,
                        Some(m) => inc.iter().all(|&e| e > m),
                    };
                tasks.push(move || {
                    if can_splice {
                        for &e in inc {
                            let (row, a, b, c) = plan_coords(t, plan, e as usize);
                            plan.splice_append(row, a, b, c, t.vals[e as usize]);
                        }
                        false
                    } else {
                        *plan = TtmPlan::build_with(t, n, es, core);
                        true
                    }
                });
            }
            let timed = crate::dist::run_scoped(tasks, parallel);
            for (was_rebuilt, secs) in timed {
                if was_rebuilt {
                    stats.rebuilt += 1;
                } else {
                    stats.spliced += 1;
                }
                stats.rebuild_secs = stats.rebuild_secs.max(secs);
            }
        }
        self.elems = new_elems;
        stats
    }
}

/// Everything a HOOI run mutates across sweeps: the factor matrices,
/// the RNG stream (bootstrap + Lanczos restarts), the per-rank plan
/// workspaces (kernel selection + Z arena), and the final mode's local
/// penultimate copies (needed for the core computation).
///
/// Splitting this out of [`run_hooi`] is what lets
/// `coordinator::TuckerSession` continue a decomposition: running
/// `invocations = a` sweeps, taking an outcome, then `b` more sweeps is
/// bit-identical to a single `a + b`-invocation run, because the state
/// (including the RNG position) carries over exactly.
pub struct HooiState {
    pub factors: Vec<Mat>,
    ks: Vec<usize>,
    rng: Rng,
    workspaces: Vec<PlanWorkspace>,
    last_locals: Vec<LocalZ>,
    last_sigma: Vec<f32>,
    /// Completed sweeps since init — the sweep label the cluster's fault
    /// addressing and the session's checkpoints key off.
    sweep: usize,
}

/// A sweep-boundary capture of everything [`HooiState`] needs to resume
/// bit-exactly: the factor matrices, the RNG cursor, the completed-sweep
/// count and the last sweep's singular values. Workspaces and the final
/// mode's locals are deliberately absent — they are scratch the next
/// sweep rebuilds, so restoring and re-sweeping reproduces the exact
/// bits of an uninterrupted run (the [`HooiState::restore`] contract).
#[derive(Debug, Clone)]
pub struct HooiSnapshot {
    /// Completed sweeps at capture time.
    pub sweep: usize,
    pub factors: Vec<Mat>,
    /// The RNG cursor ([`Rng::state`]).
    pub rng_state: [u64; 4],
    pub last_sigma: Vec<f32>,
}

impl HooiState {
    /// Bootstrap: random orthonormal factor matrices (§2.2) and one
    /// fresh workspace per rank, with the kernel override applied.
    pub fn init(
        t: &SparseTensor,
        p: usize,
        core: &CoreRanks,
        seed: u64,
        kernel: Option<Kernel>,
    ) -> HooiState {
        let ks = core.resolve(t.ndim());
        let mut rng = Rng::new(seed);
        let factors: Vec<Mat> = t
            .dims
            .iter()
            .zip(&ks)
            .map(|(&l, &k)| orthonormal_random(l as usize, k, &mut rng))
            .collect();
        let workspaces: Vec<PlanWorkspace> = (0..p)
            .map(|_| match kernel {
                Some(k) => PlanWorkspace::with_kernel(k),
                None => PlanWorkspace::new(),
            })
            .collect();
        HooiState {
            factors,
            ks,
            rng,
            workspaces,
            last_locals: Vec::new(),
            last_sigma: Vec::new(),
            sweep: 0,
        }
    }

    /// Completed sweeps since init.
    pub fn sweep(&self) -> usize {
        self.sweep
    }

    /// Capture a sweep-boundary snapshot (see [`HooiSnapshot`]).
    pub fn snapshot(&self) -> HooiSnapshot {
        HooiSnapshot {
            sweep: self.sweep,
            factors: self.factors.clone(),
            rng_state: self.rng.state(),
            last_sigma: self.last_sigma.clone(),
        }
    }

    /// Roll the evolving state back to a snapshot. The final mode's
    /// locals are recycled into the workspaces (they belong to the
    /// abandoned sweep); the next sweep rebuilds them, so resuming from
    /// here is bit-identical to a run that never went past the snapshot.
    pub fn restore(&mut self, snap: &HooiSnapshot) {
        self.factors = snap.factors.clone();
        self.rng = Rng::from_state(snap.rng_state);
        self.last_sigma = snap.last_sigma.clone();
        self.sweep = snap.sweep;
        let locals = std::mem::take(&mut self.last_locals);
        for (ws, old) in self.workspaces.iter_mut().zip(locals) {
            ws.recycle(old.z);
        }
    }

    /// Record kernel provenance for the cluster's concurrency report:
    /// selection is fixed for the whole run (the fused path dispatches
    /// each workspace's kernel; other engines run the padded-batch
    /// contract), so it is recorded once rather than per phase.
    pub fn record_kernels(&self, engine: &Engine, cluster: &mut SimCluster) {
        cluster.record_kernels(
            cat::TTM,
            self.workspaces
                .iter()
                .map(|ws| {
                    if engine.prefers_fused_ttm() {
                        ws.kernel().resolve().name()
                    } else {
                        "engine-batched"
                    }
                })
                .collect(),
        );
    }

    /// Run `invocations` HOOI sweeps over the prepared modes, charging
    /// all compute/comm to `cluster`. May be called repeatedly; each
    /// call continues exactly where the previous one stopped.
    ///
    /// Fallible: a rank failure (injected fault or caught panic)
    /// surfaces as `Err` with the sweep counter *not* advanced past the
    /// failed sweep — the state is mid-sweep dirty and the caller must
    /// [`HooiState::restore`] a snapshot before retrying (the session's
    /// recovery loop does exactly that).
    pub fn sweeps(
        &mut self,
        t: &SparseTensor,
        modes: &[ModeState],
        engine: &Engine,
        cluster: &mut SimCluster,
        invocations: usize,
    ) -> Result<(), RankFailure> {
        self.sweeps_with(t, modes, None, engine, cluster, invocations)
    }

    /// [`HooiState::sweeps`] with an optional set of shared CSF trees.
    /// When `shared` is present the TTM phases assemble through
    /// [`CsfPlan::assemble`] — one tree per rank serving all N modes,
    /// with the sweep's mode order (0..N-1 per invocation) driving the
    /// contribution-cache fill/reuse lifecycle — and the mode states'
    /// per-mode `plans` are ignored (sessions pair this with
    /// [`prepare_modes_unplanned`]). The phase timings are of the work
    /// actually executed, so the cluster's TTM bucket (Fig 11) reflects
    /// the cross-mode reuse directly.
    pub fn sweeps_with(
        &mut self,
        t: &SparseTensor,
        modes: &[ModeState],
        shared: Option<&SharedPlans>,
        engine: &Engine,
        cluster: &mut SimCluster,
        invocations: usize,
    ) -> Result<(), RankFailure> {
        let ndim = t.ndim();
        for _inv in 0..invocations {
            cluster.begin_sweep(self.sweep);
            for (n, st) in modes.iter().enumerate() {
                // --- TTM: assemble truncated local penultimate matrices
                // from the precompiled plans; ranks execute concurrently
                // on the scoped-thread executor, results in rank order ---
                let locals: Vec<LocalZ> = {
                    let factors_ref = &self.factors;
                    let tasks: Vec<Box<dyn FnOnce() -> LocalZ + Send>> = match shared
                    {
                        Some(sp) => sp
                            .per_rank
                            .iter()
                            .zip(self.workspaces.iter_mut())
                            .map(|(csf, ws)| {
                                Box::new(move || {
                                    csf.assemble(n, factors_ref, engine, ws)
                                })
                                    as Box<dyn FnOnce() -> LocalZ + Send>
                            })
                            .collect(),
                        None => st
                            .plans
                            .iter()
                            .zip(self.workspaces.iter_mut())
                            .map(|(plan, ws)| {
                                Box::new(move || plan.assemble(factors_ref, engine, ws))
                                    as Box<dyn FnOnce() -> LocalZ + Send>
                            })
                            .collect(),
                    };
                    cluster.phase_tasks(cat::TTM, tasks)?
                };
                // --- SVD: Lanczos bidiagonalization over the oracle ---
                let l_n = t.dims[n] as usize;
                let res = {
                    let oracle = Oracle::with_engine(
                        &locals,
                        &st.rowmap,
                        &st.sharers,
                        l_n,
                        st.khat_n,
                        Some(engine),
                    );
                    lanczos_svd(&oracle, st.k_n, engine, cluster, &mut self.rng)?
                };
                // --- factor-matrix transfer for the next TTM ---
                cluster.p2p(cat::COMM_FM, &st.fm.per_rank)?;
                self.factors[n] = res.factor;
                self.last_sigma = res.sigma;
                if n == ndim - 1 {
                    // keep the final mode's locals for the core
                    // computation; recycle the previous sweep's copies
                    for (ws, old) in
                        self.workspaces.iter_mut().zip(self.last_locals.drain(..))
                    {
                        ws.recycle(old.z);
                    }
                    self.last_locals = locals;
                } else {
                    // Z arena: hand each rank's buffer back for the next
                    // mode
                    for (ws, local) in self.workspaces.iter_mut().zip(locals) {
                        ws.recycle(local.z);
                    }
                }
            }
            self.sweep += 1;
        }
        Ok(())
    }

    /// Compute the core, fit and memory report from the current state —
    /// non-destructive, so a session can take an outcome, sweep further,
    /// and take another.
    ///
    /// Core, once, from the final mode's penultimate matrices:
    /// G_(N-1) = F̃_{N-1}^T · Z_(N-1); Z was built with the final factors
    /// of all other modes, F̃_{N-1} is this sweep's SVD output. Each rank
    /// contributes F̃[rows_p,:]^T Z^p; partials allreduce (charged common).
    pub fn outcome(
        &self,
        t: &SparseTensor,
        dist: &Distribution,
        modes: &[ModeState],
        cluster: &mut SimCluster,
        accounting: Option<TensorAccounting>,
    ) -> Result<HooiOutcome, RankFailure> {
        self.outcome_with(t, dist, modes, None, cluster, accounting)
    }

    /// [`HooiState::outcome`] with an optional set of shared CSF trees,
    /// so the memory report charges the one-tree-per-rank layout
    /// ([`memory_model_shared`]) instead of N per-mode stream plans.
    /// The core/fit/factor arithmetic is untouched — the outcome bits
    /// are identical to the per-mode path by the shared-tree assembly
    /// contract.
    pub fn outcome_with(
        &self,
        t: &SparseTensor,
        dist: &Distribution,
        modes: &[ModeState],
        shared: Option<&SharedPlans>,
        cluster: &mut SimCluster,
        accounting: Option<TensorAccounting>,
    ) -> Result<HooiOutcome, RankFailure> {
        let ndim = t.ndim();
        let n_last = ndim - 1;
        let (k_last, kh_last) = (self.ks[n_last], modes[n_last].khat_n);
        let mut core = Mat::zeros(k_last, kh_last);
        if !self.last_locals.is_empty() {
            // the core phase is addressed as phase 0 of the post-sweep
            // position (sweep = completed count) for fault injection
            cluster.begin_sweep(self.sweep);
            let f_last = &self.factors[n_last];
            let last_locals = &self.last_locals;
            cluster.phase(cat::CORE, |rank| {
                let local = &last_locals[rank];
                for (r, &l) in local.rows.iter().enumerate() {
                    let zrow = local.z.row(r);
                    let frow = f_last.row(l as usize);
                    for kk in 0..k_last {
                        let w = frow[kk];
                        if !exactly_zero_f32(w) {
                            crate::linalg::axpy(w, zrow, core.row_mut(kk));
                        }
                    }
                }
            })?;
            cluster.allreduce(cat::COMM_COMMON, (k_last * kh_last) as u64)?;
        }

        // fit via ‖T‖² − ‖G‖² (orthonormal factors)
        let tnorm_sq = t.norm_sq();
        let gnorm_sq = core.frob_norm().powi(2);
        let fit =
            1.0 - ((tnorm_sq - gnorm_sq).max(0.0)).sqrt() / tnorm_sq.sqrt().max(1e-30);

        let acct = TensorAccounting::resolve(accounting);
        let memory = match shared {
            Some(sp) => memory_model_shared(t, dist, modes, sp, acct),
            None => memory_model_with(t, dist, modes, acct),
        };
        Ok(HooiOutcome {
            factors: self.factors.clone(),
            core,
            fit,
            memory,
            sigma: self.last_sigma.clone(),
        })
    }
}

/// Run `cfg.invocations` HOOI sweeps of the distributed framework over the
/// given distribution, charging all compute/comm to `cluster`.
///
/// One-shot composition of [`prepare_modes`] + [`HooiState`]; callers
/// that decompose the same distribution repeatedly should hold a
/// `coordinator::TuckerSession` instead, which keeps the prepared modes
/// (and the TTM plans inside them) alive across calls.
pub fn run_hooi(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    engine: &Engine,
    cluster: &mut SimCluster,
    cfg: &HooiConfig,
) -> HooiOutcome {
    // plan compilation follows the cluster's executor so serial runs
    // stay serial end to end (timing-noise contract)
    let modes =
        prepare_modes_with_executor(t, idx, dist, &cfg.core, cluster.is_parallel());
    // plan compilation is per-rank work a real implementation pays once;
    // charge its per-mode makespan to the TTM bucket so simulated totals
    // keep accounting for all per-rank compute
    charge_plan_compilation(&modes, cluster);
    let mut state = HooiState::init(t, dist.p, &cfg.core, cfg.seed, cfg.kernel);
    state.record_kernels(engine, cluster);
    // the one-shot path runs without fault injection or checkpoints, so
    // a rank failure here is a caught panic — re-raise it (sessions that
    // want recovery hold a `TuckerSession` instead)
    if let Err(f) = state.sweeps(t, &modes, engine, cluster, cfg.invocations) {
        panic!("unrecoverable rank failure outside a session: {f}");
    }
    match state.outcome(t, dist, &modes, cluster, cfg.accounting) {
        Ok(out) => out,
        Err(f) => panic!("unrecoverable rank failure outside a session: {f}"),
    }
}

/// Charge each mode's plan-compilation makespan to the TTM bucket.
pub fn charge_plan_compilation(modes: &[ModeState], cluster: &mut SimCluster) {
    for st in modes {
        let worst = st.plan_secs.iter().copied().fold(0.0, f64::max);
        cluster.elapsed.add(cat::TTM, worst);
    }
}

/// How the per-rank tensor working copy is charged by [`memory_model`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorAccounting {
    /// Charge the actual TTM plan streams — run tables, slot pointers
    /// and the lane-padded `fa`/`vals` blocks of every (mode, rank)
    /// plan. This is what a plan-layer rank really holds: even
    /// single-policy (uni) distributions store one stream encoding *per
    /// mode*. Requires planned mode states; metrics-only states built
    /// with [`prepare_modes_unplanned`] fall back to the COO accounting
    /// (they never materialize streams).
    PlanStreams,
    /// The paper's COO accounting ((N+1)·4 bytes per stored element,
    /// one copy per policy) — kept behind this flag so Fig 17 stays
    /// comparable to the published numbers.
    PaperCoo,
}

impl TensorAccounting {
    pub fn by_name(s: &str) -> Option<TensorAccounting> {
        if s.eq_ignore_ascii_case("coo") {
            Some(TensorAccounting::PaperCoo)
        } else if s.eq_ignore_ascii_case("plan") {
            Some(TensorAccounting::PlanStreams)
        } else {
            None
        }
    }

    /// Precedence: typed choice > `TUCKER_MEM_ACCOUNTING` env override
    /// (`coo` / `plan`) > plan-stream default. Unrecognized env values
    /// are flagged on stderr rather than silently changing Fig 17
    /// numbers (see `util::env`).
    pub fn resolve(option: Option<TensorAccounting>) -> TensorAccounting {
        crate::util::env::resolve(
            option,
            crate::util::env::MEM_ACCOUNTING,
            TensorAccounting::by_name,
            || TensorAccounting::PlanStreams,
        )
    }

    /// Default accounting with only the env override applied.
    pub fn from_env() -> TensorAccounting {
        TensorAccounting::resolve(None)
    }
}

/// Fig 17 memory model: tensor working copies + largest local
/// penultimate + stored factor rows, per rank. Usable without running
/// HOOI ([`prepare_modes_unplanned`] + this) — the distribution fully
/// determines it. Per-mode core ranks are read off the mode states
/// (K_n, K̂_n), so ragged cores are charged exactly.
///
/// The tensor component follows [`TensorAccounting::from_env`]: planned
/// states charge the real plan streams (lane padding included), closing
/// the ROADMAP item on the COO/plan accounting mismatch; unplanned
/// states and the `TUCKER_MEM_ACCOUNTING=coo` flag keep the paper's
/// COO model for Fig 17 comparability.
pub fn memory_model(
    t: &SparseTensor,
    dist: &Distribution,
    modes: &[ModeState],
) -> MemoryReport {
    memory_model_with(t, dist, modes, TensorAccounting::from_env())
}

/// [`memory_model`] with an explicit [`TensorAccounting`] choice.
pub fn memory_model_with(
    t: &SparseTensor,
    dist: &Distribution,
    modes: &[ModeState],
    acct: TensorAccounting,
) -> MemoryReport {
    let p = dist.p;
    let bytes_elem = t.bytes_per_element() as u64;
    let planned = modes.iter().all(|st| st.plans.len() == p);
    let mut tensor = vec![0u64; p];
    if acct == TensorAccounting::PlanStreams && planned {
        // the rank's working copy is its per-mode plan streams — charged
        // exactly, lane padding and run tables included
        for st in modes {
            for (rank, b) in tensor.iter_mut().enumerate() {
                *b += st.plans[rank].stream_bytes();
            }
        }
    } else if dist.uni {
        for (rank, b) in tensor.iter_mut().enumerate() {
            *b = modes[0].elems[rank].len() as u64 * bytes_elem;
        }
    } else {
        for st in modes {
            for (rank, b) in tensor.iter_mut().enumerate() {
                *b += st.elems[rank].len() as u64 * bytes_elem;
            }
        }
    }
    // penultimate: max over modes of R_n^p · K̂_n · 4 (Z freed between
    // modes)
    let mut penult = vec![0u64; p];
    for st in modes {
        let r_counts = st.sharers.r_counts(p);
        for (rank, b) in penult.iter_mut().enumerate() {
            *b = (*b).max(r_counts[rank] as u64 * st.khat_n as u64 * 4);
        }
    }
    // factors: stored rows per mode × K_n × 4
    let mut fact = vec![0u64; p];
    for st in modes {
        for (rank, b) in fact.iter_mut().enumerate() {
            *b += st.fm.stored_rows[rank] * st.k_n as u64 * 4;
        }
    }
    MemoryReport {
        tensor_bytes: tensor,
        penultimate_bytes: penult,
        factor_bytes: fact,
    }
}

/// [`memory_model_with`] for a `PlanChoice::SharedCsf` session: under
/// plan-stream accounting the per-rank tensor working copy is the one
/// shared tree (spine streams + stream components + view tables + the
/// contribution cache), not N per-mode stream plans. The penultimate
/// and factor components are layout-independent and identical to the
/// per-mode model; `TUCKER_MEM_ACCOUNTING=coo` likewise bypasses the
/// plan layout entirely.
pub fn memory_model_shared(
    t: &SparseTensor,
    dist: &Distribution,
    modes: &[ModeState],
    shared: &SharedPlans,
    acct: TensorAccounting,
) -> MemoryReport {
    let mut rep = memory_model_with(t, dist, modes, acct);
    if acct == TensorAccounting::PlanStreams {
        assert_eq!(shared.per_rank.len(), dist.p, "one shared tree per rank");
        rep.tensor_bytes =
            shared.per_rank.iter().map(CsfPlan::stream_bytes).collect();
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::sched::{Lite, Scheme};
    use crate::tensor::slices::build_all;

    fn small_tensor(seed: u64) -> (SparseTensor, Vec<SliceIndex>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(vec![24, 18, 12], 900, &mut rng);
        let idx = build_all(&t);
        (t, idx)
    }

    fn run(
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        k: usize,
        invocations: usize,
    ) -> (HooiOutcome, SimCluster) {
        let dist = Lite.policies(t, idx, p, &mut Rng::new(5));
        let mut cluster = SimCluster::new(p);
        let cfg = HooiConfig {
            core: CoreRanks::Uniform(k),
            invocations,
            seed: 42,
            ..HooiConfig::default()
        };
        let out = run_hooi(t, idx, &dist, &Engine::Native, &mut cluster, &cfg);
        (out, cluster)
    }

    #[test]
    fn factors_stay_orthonormal() {
        let (t, idx) = small_tensor(1);
        let (out, _) = run(&t, &idx, 4, 4, 1);
        for (n, f) in out.factors.iter().enumerate() {
            assert_eq!(f.rows, t.dims[n] as usize);
            assert_eq!(f.cols, 4);
            assert!(ortho_defect(f) < 1e-2, "mode {n}: {}", ortho_defect(f));
        }
    }

    #[test]
    fn fit_improves_or_holds_with_invocations() {
        let (t, idx) = small_tensor(2);
        let (out1, _) = run(&t, &idx, 3, 5, 1);
        let (out3, _) = run(&t, &idx, 3, 5, 3);
        assert!(out1.fit.is_finite() && (0.0..=1.0).contains(&out1.fit));
        // ALS refinement: fit after 3 sweeps ≥ fit after 1 (tolerance for
        // stochastic Lanczos noise)
        assert!(
            out3.fit >= out1.fit - 0.02,
            "fit degraded: {} -> {}",
            out1.fit,
            out3.fit
        );
    }

    #[test]
    fn fit_is_exact_for_exactly_low_rank_tensor() {
        // build a rank-1 tensor: T = u ⊗ v ⊗ w over a sparse pattern —
        // dense here for exactness (small dims)
        let (lu, lv, lw) = (8usize, 7usize, 6usize);
        let mut rng = Rng::new(9);
        let u: Vec<f32> = (0..lu).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..lv).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..lw).map(|_| rng.normal() as f32).collect();
        let mut t = SparseTensor::new(vec![lu as u32, lv as u32, lw as u32]);
        for i in 0..lu {
            for j in 0..lv {
                for l in 0..lw {
                    t.push(&[i as u32, j as u32, l as u32], u[i] * v[j] * w[l]);
                }
            }
        }
        let idx = build_all(&t);
        let (out, _) = run(&t, &idx, 2, 2, 2);
        assert!(out.fit > 0.999, "rank-1 tensor should fit exactly: {}", out.fit);
    }

    #[test]
    fn cluster_accounts_all_components() {
        let (t, idx) = small_tensor(3);
        let (_, cluster) = run(&t, &idx, 4, 4, 1);
        assert!(cluster.elapsed.get(cat::TTM) > 0.0);
        assert!(cluster.elapsed.get(cat::SVD) > 0.0);
        // the core phase charges its own category (folded into the
        // leader's hooi_secs — it used to be dropped from every total)
        assert!(cluster.elapsed.get(cat::CORE) > 0.0);
        assert!(cluster.volume.get(cat::COMM_FM) >= 0.0);
        // oracle volume present when slices are shared (random tensor: yes)
        assert!(cluster.volume.get(cat::COMM_SVD) > 0.0);
    }

    #[test]
    fn memory_model_charges_plan_streams_with_coo_behind_flag() {
        let (t, idx) = small_tensor(4);
        let dist = Lite.policies(&t, &idx, 4, &mut Rng::new(5));
        let core = CoreRanks::Uniform(4);
        let modes = prepare_modes(&t, &idx, &dist, &core);
        // plan-stream accounting: exactly the bytes the per-(mode, rank)
        // streams occupy, lane padding included
        let plan_rep =
            memory_model_with(&t, &dist, &modes, TensorAccounting::PlanStreams);
        let want: u64 = modes
            .iter()
            .map(|st| st.plans.iter().map(|p| p.stream_bytes()).sum::<u64>())
            .sum();
        assert_eq!(plan_rep.tensor_bytes.iter().sum::<u64>(), want);
        // fa+vals alone are 8 bytes per real element across 3 per-mode
        // plans; padding and run tables only add to that
        assert!(want >= 3 * 8 * t.nnz() as u64);
        assert!(plan_rep.avg_total_mb() > 0.0);
        // the paper's COO accounting stays available behind the flag:
        // Lite is multi-policy, 3 copies of every element
        let coo_rep = memory_model_with(&t, &dist, &modes, TensorAccounting::PaperCoo);
        assert_eq!(
            coo_rep.tensor_bytes.iter().sum::<u64>(),
            3 * t.nnz() as u64 * t.bytes_per_element() as u64
        );
        // unplanned (metrics-only) states never materialize streams and
        // fall back to COO under either accounting
        let unplanned = prepare_modes_unplanned(&t, &idx, &dist, &core);
        let fallback =
            memory_model_with(&t, &dist, &unplanned, TensorAccounting::PlanStreams);
        assert_eq!(fallback.tensor_bytes, coo_rep.tensor_bytes);
        // both accountings share penultimate/factor components
        assert_eq!(plan_rep.penultimate_bytes, coo_rep.penultimate_bytes);
        assert_eq!(plan_rep.factor_bytes, coo_rep.factor_bytes);
    }

    #[test]
    fn four_dimensional_tensor_runs() {
        let mut rng = Rng::new(6);
        let t = SparseTensor::random(vec![10, 8, 6, 5], 500, &mut rng);
        let idx = build_all(&t);
        let (out, _) = {
            let dist = Lite.policies(&t, &idx, 3, &mut Rng::new(7));
            let mut cluster = SimCluster::new(3);
            let cfg = HooiConfig {
                core: CoreRanks::Uniform(3),
                invocations: 1,
                seed: 1,
                ..HooiConfig::default()
            };
            (
                run_hooi(&t, &idx, &dist, &Engine::Native, &mut cluster, &cfg),
                cluster,
            )
        };
        assert_eq!(out.factors.len(), 4);
        assert_eq!(out.core.rows, 3);
        assert_eq!(out.core.cols, 27);
        assert!(out.fit.is_finite());
    }

    #[test]
    fn per_mode_core_shapes_flow_through_the_driver() {
        let (t, idx) = small_tensor(7);
        let dist = Lite.policies(&t, &idx, 3, &mut Rng::new(8));
        let mut cluster = SimCluster::new(3);
        let cfg = HooiConfig {
            core: CoreRanks::PerMode(vec![3, 4, 5]),
            invocations: 1,
            seed: 2,
            ..HooiConfig::default()
        };
        let out = run_hooi(&t, &idx, &dist, &Engine::Native, &mut cluster, &cfg);
        for (n, want) in [3usize, 4, 5].iter().enumerate() {
            assert_eq!(out.factors[n].cols, *want, "mode {n} factor width");
            assert_eq!(out.factors[n].rows, t.dims[n] as usize);
        }
        // core is G_(N-1): K_2 × K_0·K_1
        assert_eq!(out.core.rows, 5);
        assert_eq!(out.core.cols, 12);
        assert!(out.fit.is_finite() && (0.0..=1.0).contains(&out.fit));
    }

    #[test]
    fn split_sweeps_match_one_shot_run_exactly() {
        // the HooiState contract behind TuckerSession::decompose_more:
        // 2 sweeps + outcome + 1 sweep must equal a 3-sweep run
        let (t, idx) = small_tensor(8);
        let dist = Lite.policies(&t, &idx, 3, &mut Rng::new(9));
        let core = CoreRanks::Uniform(4);
        let modes = prepare_modes(&t, &idx, &dist, &core);

        let mut c1 = SimCluster::new(3);
        let mut s1 = HooiState::init(&t, 3, &core, 21, None);
        s1.sweeps(&t, &modes, &Engine::Native, &mut c1, 3).unwrap();
        let one_shot = s1.outcome(&t, &dist, &modes, &mut c1, None).unwrap();

        let mut c2 = SimCluster::new(3);
        let mut s2 = HooiState::init(&t, 3, &core, 21, None);
        s2.sweeps(&t, &modes, &Engine::Native, &mut c2, 2).unwrap();
        let mid = s2.outcome(&t, &dist, &modes, &mut c2, None).unwrap();
        s2.sweeps(&t, &modes, &Engine::Native, &mut c2, 1).unwrap();
        let resumed = s2.outcome(&t, &dist, &modes, &mut c2, None).unwrap();

        assert!(mid.fit.is_finite());
        assert_eq!(one_shot.fit, resumed.fit, "continuation is bit-identical");
        for (a, b) in one_shot.factors.iter().zip(&resumed.factors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(one_shot.core.data, resumed.core.data);
    }

    #[test]
    fn snapshot_restore_resumes_bit_exactly() {
        // roll back over an abandoned sweep: 2 sweeps + snapshot + 1
        // sweep + restore + 1 sweep must equal an uninterrupted 3-sweep
        // run (the recovery-rollback contract)
        let (t, idx) = small_tensor(10);
        let dist = Lite.policies(&t, &idx, 3, &mut Rng::new(11));
        let core = CoreRanks::Uniform(4);
        let modes = prepare_modes(&t, &idx, &dist, &core);

        let mut c1 = SimCluster::new(3);
        let mut s1 = HooiState::init(&t, 3, &core, 33, None);
        s1.sweeps(&t, &modes, &Engine::Native, &mut c1, 3).unwrap();
        let want = s1.outcome(&t, &dist, &modes, &mut c1, None).unwrap();

        let mut c2 = SimCluster::new(3);
        let mut s2 = HooiState::init(&t, 3, &core, 33, None);
        s2.sweeps(&t, &modes, &Engine::Native, &mut c2, 2).unwrap();
        let snap = s2.snapshot();
        assert_eq!(snap.sweep, 2);
        // go one sweep past the snapshot, then roll back and redo it
        s2.sweeps(&t, &modes, &Engine::Native, &mut c2, 1).unwrap();
        s2.restore(&snap);
        assert_eq!(s2.sweep(), 2);
        s2.sweeps(&t, &modes, &Engine::Native, &mut c2, 1).unwrap();
        let got = s2.outcome(&t, &dist, &modes, &mut c2, None).unwrap();

        assert_eq!(want.fit, got.fit);
        for (a, b) in want.factors.iter().zip(&got.factors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(want.core.data, got.core.data);
        assert_eq!(want.sigma, got.sigma);
    }
}
