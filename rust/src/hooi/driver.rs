//! The HOOI driver (paper Fig 2): N per-mode iterations of TTM-chain +
//! SVD per invocation, factor-matrix transfer between invocations, core
//! computed once at the end (§2.2 — refinement never needs the core).
//!
//! Everything is orchestrated over the simulated cluster: TTM assembly and
//! oracle matvecs really execute (through the engine — PJRT artifacts on
//! the hot path) and are timed per rank; communication is charged to the
//! α–β model with byte-exact volumes.

use super::fm::{fm_pattern, FmPattern};
use super::lanczos::{lanczos_svd, Oracle};
use super::ttm::{assemble_local_z, khat, LocalZ};
use crate::dist::{cat, SimCluster};
use crate::linalg::{orthonormal_random, Mat};
use crate::runtime::Engine;
use crate::sched::{Distribution, RowMap, Sharers};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct HooiConfig {
    /// Uniform core length K (the paper uses K_n = K, default 10).
    pub k: usize,
    /// Number of HOOI invocations (refinement sweeps).
    pub invocations: usize,
    pub seed: u64,
}

impl Default for HooiConfig {
    fn default() -> Self {
        HooiConfig { k: 10, invocations: 1, seed: 0x70C4E4 }
    }
}

/// Per-rank memory accounting (Fig 17 model).
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    /// Bytes per rank for stored tensor copies (N copies if multi-policy).
    pub tensor_bytes: Vec<u64>,
    /// Bytes for the largest concurrent local penultimate matrix.
    pub penultimate_bytes: Vec<u64>,
    /// Bytes for stored factor-matrix rows (Σ modes).
    pub factor_bytes: Vec<u64>,
}

impl MemoryReport {
    pub fn avg_total_mb(&self) -> f64 {
        let p = self.tensor_bytes.len().max(1);
        let total: u64 = self
            .tensor_bytes
            .iter()
            .zip(&self.penultimate_bytes)
            .zip(&self.factor_bytes)
            .map(|((&t, &z), &f)| t + z + f)
            .sum();
        total as f64 / p as f64 / (1024.0 * 1024.0)
    }

    pub fn avg_component_mb(&self) -> (f64, f64, f64) {
        let p = self.tensor_bytes.len().max(1) as f64;
        let mb = |v: &Vec<u64>| v.iter().sum::<u64>() as f64 / p / (1024.0 * 1024.0);
        (
            mb(&self.tensor_bytes),
            mb(&self.penultimate_bytes),
            mb(&self.factor_bytes),
        )
    }
}

/// Outcome of a HOOI run.
pub struct HooiOutcome {
    pub factors: Vec<Mat>,
    /// Core tensor, flattened in the K̂-layout of the last mode
    /// (G_(N-1): K × K̂_{N-1} row-major).
    pub core: Mat,
    /// Fit = 1 − ‖T − X‖ / ‖T‖ (X the reconstructed tensor).
    pub fit: f64,
    pub memory: MemoryReport,
    /// Leading singular values of the last mode (diagnostics).
    pub sigma: Vec<f32>,
}

/// Precomputed per-mode distribution state, reused across invocations.
pub struct ModeState {
    pub elems: Vec<Vec<u32>>,
    pub sharers: Sharers,
    pub rowmap: RowMap,
    pub fm: FmPattern,
}

/// Build the per-mode state (sharers, σ_n, FM pattern, rank elements).
pub fn prepare_modes(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    k: usize,
) -> Vec<ModeState> {
    (0..t.ndim())
        .map(|n| {
            let sharers = Sharers::build(&idx[n], &dist.policies[n]);
            let rowmap = RowMap::build(&sharers, dist.p);
            let fm = fm_pattern(&idx[n], dist, n, &rowmap, k);
            let elems = dist.policies[n].rank_elements(&idx[n]);
            ModeState { elems, sharers, rowmap, fm }
        })
        .collect()
}

/// Run `cfg.invocations` HOOI sweeps of the distributed framework over the
/// given distribution, charging all compute/comm to `cluster`.
pub fn run_hooi(
    t: &SparseTensor,
    idx: &[SliceIndex],
    dist: &Distribution,
    engine: &Engine,
    cluster: &mut SimCluster,
    cfg: &HooiConfig,
) -> HooiOutcome {
    let ndim = t.ndim();
    let k = cfg.k;
    let kh = khat(k, ndim);
    let mut rng = Rng::new(cfg.seed);
    // bootstrap: random orthonormal factor matrices (§2.2)
    let mut factors: Vec<Mat> = t
        .dims
        .iter()
        .map(|&l| orthonormal_random(l as usize, k, &mut rng))
        .collect();
    let modes = prepare_modes(t, idx, dist, k);

    let mut last_locals: Vec<LocalZ> = Vec::new();
    let mut last_sigma: Vec<f32> = Vec::new();
    for _inv in 0..cfg.invocations {
        for n in 0..ndim {
            let st = &modes[n];
            // --- TTM: assemble truncated local penultimate matrices ---
            let mut locals: Vec<LocalZ> = Vec::with_capacity(dist.p);
            cluster.phase(cat::TTM, |rank| {
                locals.push(assemble_local_z(
                    t,
                    n,
                    &st.elems[rank],
                    &factors,
                    k,
                    engine,
                ));
            });
            // --- SVD: Lanczos bidiagonalization over the oracle ---
            let l_n = t.dims[n] as usize;
            let res = {
                let oracle = Oracle::with_engine(
                    &locals,
                    &st.rowmap,
                    &st.sharers,
                    l_n,
                    kh,
                    Some(engine),
                );
                lanczos_svd(&oracle, k, engine, cluster, &mut rng)
            };
            // --- factor-matrix transfer for the next TTM ---
            cluster.p2p(cat::COMM_FM, &st.fm.per_rank);
            factors[n] = res.factor;
            last_sigma = res.sigma;
            if n == ndim - 1 {
                last_locals = locals;
            }
        }
    }

    // --- core, once, from the final mode's penultimate matrices:
    // G_(N-1) = F̃_{N-1}^T · Z_(N-1); Z was built with the final factors of
    // all other modes, F̃_{N-1} is this sweep's SVD output. Each rank
    // contributes F̃[rows_p,:]^T Z^p; partials allreduce (charged common).
    let n_last = ndim - 1;
    let mut core = Mat::zeros(k, kh);
    let f_last = &factors[n_last];
    cluster.phase("core", |rank| {
        let local = &last_locals[rank];
        for (r, &l) in local.rows.iter().enumerate() {
            let zrow = local.z.row(r);
            let frow = f_last.row(l as usize);
            for kk in 0..k {
                let w = frow[kk];
                if w != 0.0 {
                    crate::linalg::axpy(w, zrow, core.row_mut(kk));
                }
            }
        }
    });
    cluster.allreduce(cat::COMM_COMMON, (k * kh) as u64);

    // fit via ‖T‖² − ‖G‖² (orthonormal factors)
    let tnorm_sq = t.norm_sq();
    let gnorm_sq = core.frob_norm().powi(2);
    let fit = 1.0 - ((tnorm_sq - gnorm_sq).max(0.0)).sqrt() / tnorm_sq.sqrt().max(1e-30);

    let memory = memory_model(t, dist, &modes, k, kh);
    HooiOutcome { factors, core, fit, memory, sigma: last_sigma }
}

/// Fig 17 memory model: tensor copies + largest local penultimate +
/// stored factor rows, per rank. Usable without running HOOI
/// (`prepare_modes` + this) — the distribution fully determines it.
pub fn memory_model(
    t: &SparseTensor,
    dist: &Distribution,
    modes: &[ModeState],
    k: usize,
    kh: usize,
) -> MemoryReport {
    let p = dist.p;
    let bytes_elem = t.bytes_per_element() as u64;
    let mut tensor = vec![0u64; p];
    if dist.uni {
        for (rank, b) in tensor.iter_mut().enumerate() {
            *b = modes[0].elems[rank].len() as u64 * bytes_elem;
        }
    } else {
        for st in modes {
            for (rank, b) in tensor.iter_mut().enumerate() {
                *b += st.elems[rank].len() as u64 * bytes_elem;
            }
        }
    }
    // penultimate: max over modes of R_n^p · K̂ · 4 (Z freed between modes)
    let mut penult = vec![0u64; p];
    for st in modes {
        let r_counts = st.sharers.r_counts(p);
        for (rank, b) in penult.iter_mut().enumerate() {
            *b = (*b).max(r_counts[rank] as u64 * kh as u64 * 4);
        }
    }
    // factors: stored rows per mode × K × 4
    let mut fact = vec![0u64; p];
    for st in modes {
        for (rank, b) in fact.iter_mut().enumerate() {
            *b += st.fm.stored_rows[rank] * k as u64 * 4;
        }
    }
    MemoryReport {
        tensor_bytes: tensor,
        penultimate_bytes: penult,
        factor_bytes: fact,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::sched::{Lite, Scheme};
    use crate::tensor::slices::build_all;

    fn small_tensor(seed: u64) -> (SparseTensor, Vec<SliceIndex>) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(vec![24, 18, 12], 900, &mut rng);
        let idx = build_all(&t);
        (t, idx)
    }

    fn run(
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        k: usize,
        invocations: usize,
    ) -> (HooiOutcome, SimCluster) {
        let dist = Lite.distribute(t, idx, p, &mut Rng::new(5));
        let mut cluster = SimCluster::new(p);
        let cfg = HooiConfig { k, invocations, seed: 42 };
        let out = run_hooi(t, idx, &dist, &Engine::Native, &mut cluster, &cfg);
        (out, cluster)
    }

    #[test]
    fn factors_stay_orthonormal() {
        let (t, idx) = small_tensor(1);
        let (out, _) = run(&t, &idx, 4, 4, 1);
        for (n, f) in out.factors.iter().enumerate() {
            assert_eq!(f.rows, t.dims[n] as usize);
            assert_eq!(f.cols, 4);
            assert!(ortho_defect(f) < 1e-2, "mode {n}: {}", ortho_defect(f));
        }
    }

    #[test]
    fn fit_improves_or_holds_with_invocations() {
        let (t, idx) = small_tensor(2);
        let (out1, _) = run(&t, &idx, 3, 5, 1);
        let (out3, _) = run(&t, &idx, 3, 5, 3);
        assert!(out1.fit.is_finite() && (0.0..=1.0).contains(&out1.fit));
        // ALS refinement: fit after 3 sweeps ≥ fit after 1 (tolerance for
        // stochastic Lanczos noise)
        assert!(
            out3.fit >= out1.fit - 0.02,
            "fit degraded: {} -> {}",
            out1.fit,
            out3.fit
        );
    }

    #[test]
    fn fit_is_exact_for_exactly_low_rank_tensor() {
        // build a rank-1 tensor: T = u ⊗ v ⊗ w over a sparse pattern —
        // dense here for exactness (small dims)
        let (lu, lv, lw) = (8usize, 7usize, 6usize);
        let mut rng = Rng::new(9);
        let u: Vec<f32> = (0..lu).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..lv).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..lw).map(|_| rng.normal() as f32).collect();
        let mut t = SparseTensor::new(vec![lu as u32, lv as u32, lw as u32]);
        for i in 0..lu {
            for j in 0..lv {
                for l in 0..lw {
                    t.push(&[i as u32, j as u32, l as u32], u[i] * v[j] * w[l]);
                }
            }
        }
        let idx = build_all(&t);
        let (out, _) = run(&t, &idx, 2, 2, 2);
        assert!(out.fit > 0.999, "rank-1 tensor should fit exactly: {}", out.fit);
    }

    #[test]
    fn cluster_accounts_all_components() {
        let (t, idx) = small_tensor(3);
        let (_, cluster) = run(&t, &idx, 4, 4, 1);
        assert!(cluster.elapsed.get(cat::TTM) > 0.0);
        assert!(cluster.elapsed.get(cat::SVD) > 0.0);
        assert!(cluster.volume.get(cat::COMM_FM) >= 0.0);
        // oracle volume present when slices are shared (random tensor: yes)
        assert!(cluster.volume.get(cat::COMM_SVD) > 0.0);
    }

    #[test]
    fn memory_report_positive_and_multi_policy_counts_n_copies() {
        let (t, idx) = small_tensor(4);
        let (out, _) = run(&t, &idx, 4, 4, 1);
        let total_tensor: u64 = out.memory.tensor_bytes.iter().sum();
        // Lite is multi-policy: 3 copies of every element
        assert_eq!(
            total_tensor,
            3 * t.nnz() as u64 * t.bytes_per_element() as u64
        );
        assert!(out.memory.avg_total_mb() > 0.0);
    }

    #[test]
    fn four_dimensional_tensor_runs() {
        let mut rng = Rng::new(6);
        let t = SparseTensor::random(vec![10, 8, 6, 5], 500, &mut rng);
        let idx = build_all(&t);
        let (out, _) = {
            let dist = Lite.distribute(&t, &idx, 3, &mut Rng::new(7));
            let mut cluster = SimCluster::new(3);
            let cfg = HooiConfig { k: 3, invocations: 1, seed: 1 };
            (
                run_hooi(&t, &idx, &dist, &Engine::Native, &mut cluster, &cfg),
                cluster,
            )
        };
        assert_eq!(out.factors.len(), 4);
        assert_eq!(out.core.rows, 3);
        assert_eq!(out.core.cols, 27);
        assert!(out.fit.is_finite());
    }
}
