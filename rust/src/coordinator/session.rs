//! `TuckerSession` — the typed front door to the whole stack.
//!
//! The paper's pitch is that the Lite scheme makes distribution cheap
//! enough to choose *at run time*; the session API makes that choice a
//! one-liner instead of an eight-positional-argument call threaded
//! through five `TUCKER_*` env vars:
//!
//! ```no_run
//! use tucker_lite::coordinator::{SchemeChoice, TuckerSession, Workload};
//! use tucker_lite::hooi::CoreRanks;
//!
//! # let workload: Workload = unimplemented!();
//! let mut session = TuckerSession::builder(workload)
//!     .scheme(SchemeChoice::Lite)
//!     .ranks(16)
//!     .core(CoreRanks::PerMode(vec![12, 12, 4]))
//!     .invocations(2)
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! let d = session.decompose();
//! println!("fit {:.4}, core {:?}", d.fit(), d.core_dims());
//! let refined = session.decompose_more(1); // plans reused, no re-prepare
//! assert!(session.plan_builds() == 1);
//! # let _ = refined;
//! ```
//!
//! Every typed option replaces — but still env-falls-back to — the old
//! knobs (precedence: typed option > env var > default, see
//! `util::env`):
//!
//! | builder option        | env fallback             |
//! |-----------------------|--------------------------|
//! | `.kernel(..)`         | `TUCKER_KERNEL`          |
//! | `.executor(..)`       | `TUCKER_PHASE_EXECUTOR`  |
//! | `.plan(..)`           | `TUCKER_PLAN`            |
//! | `.pin_threads(..)`    | `TUCKER_PIN_THREADS`     |
//! | `.transport(..)`      | `TUCKER_TRANSPORT`       |
//! | `.memory_accounting(..)` | `TUCKER_MEM_ACCOUNTING` |
//!
//! The session owns the compiled distribution and the per-rank TTM
//! plans; [`TuckerSession::decompose_more`] continues the decomposition
//! (factors, RNG stream, rank workspaces all carry over bit-exactly)
//! without re-running `prepare_modes`.
//!
//! ## Streaming updates
//!
//! A long-running session ingests nonzero deltas without rebuilding its
//! world: [`TuckerSession::ingest`] applies a
//! [`TensorDelta`](crate::tensor::TensorDelta) to the held tensor,
//! extends each mode's placement with Lite's per-bin load discipline
//! (`sched::incremental`), and splices or rebuilds *only* the
//! (mode, rank) plans the delta touches — never a full `prepare_modes`.
//! [`TuckerSession::plan_rebuilds`] counts the touched plans, mirroring
//! [`TuckerSession::plan_builds`]. Ingesting then decomposing is
//! bit-identical to building a fresh session on the mutated tensor
//! under the same placement (`tests/ingest.rs` pins this).
//!
//! ## Rebalancing
//!
//! The session holds a first-class [`PlacementPlan`](crate::sched::PlacementPlan)
//! — policies plus the §4 metrics and cost estimate they induce. When
//! streaming drift breaks a mode's Theorem 6.1 sharing bounds
//! ([`IngestReport::rebalance_modes`]), the configured
//! [`RebalancePolicy`] closes the loop:
//!
//! - [`RebalancePolicy::Auto`] re-plans the flagged modes with Lite,
//!   diffs the candidate against the live plan
//!   ([`PlacementPlan::diff`](crate::sched::PlacementPlan::diff) →
//!   [`MigrationPlan`](crate::sched::MigrationPlan)), and migrates only
//!   if the §4 cost model says the per-sweep savings amortize the
//!   re-plan + migration time within the configured horizon;
//! - `Manual` records the flags ([`TuckerSession::pending_rebalance`])
//!   and waits for an explicit [`TuckerSession::rebalance`], which
//!   migrates unconditionally;
//! - `Never` only warns.
//!
//! A migration touches exactly the diffed (mode, rank) TTM plans
//! through the same splice/rebuild machinery `ingest` uses — never a
//! full `prepare_modes` — and is bit-identical to a fresh session on
//! the re-planned placement (`tests/rebalance.rs` pins this). The
//! decision and redistribution time surface in `RunRecord`
//! (`rebalances`, `rebalance_skips`, `redist_secs`, and `dist_secs`
//! growing by the redistribution — the Fig 16 quantity).
//!
//! ## Fault tolerance
//!
//! The session is the recovery authority for the fault-injection layer
//! (`dist::fault`): arm a seeded [`FaultPlan`](crate::dist::FaultPlan)
//! with [`TuckerSessionBuilder::fault_plan`] and the decompose calls run
//! a sweep-at-a-time recovery loop —
//!
//! 1. **checkpoints**: at every sweep boundary the configured
//!    [`CheckpointPolicy`] says so, the session captures a
//!    [`SessionCheckpoint`] (factors, RNG cursor, σ diagnostics — the
//!    full [`HooiSnapshot`] resume state, serializable bit-exactly);
//!    the bootstrap state is always retained, so even
//!    `CheckpointPolicy::Never` recovers (from the start of the call);
//! 2. **transient failures** roll the state back to the last retained
//!    checkpoint and re-sweep — bit-identical to a run that never
//!    faulted, because the RNG cursor and factors restore exactly;
//! 3. **rank crashes** first re-place the dead rank's elements across
//!    the survivors ([`sched::evict_rank`](crate::sched::evict_rank) —
//!    Lite's min-load discipline, preferring ranks that already share
//!    the slice), rebuild exactly the diffed (mode, rank) plans through
//!    the migration machinery above, then roll back and re-sweep. The
//!    same eviction is available as a planned operation
//!    ([`TuckerSession::evict_rank`]), and crash recovery is
//!    bit-identical to planning that eviction at the rollback boundary
//!    (`tests/fault_tolerance.rs` pins this at every (sweep, phase));
//! 4. **stragglers** slow the makespan, escalating to a failure only
//!    past [`RetryPolicy::straggler_timeout`].
//!
//! Retries are bounded by [`RetryPolicy::max_attempts`]; exhaustion (or
//! losing every rank) surfaces as a typed [`SessionError`] from the
//! `try_*` variants. `RunRecord` reports `faults_injected`,
//! `recoveries`, `recovery_secs` (the `cat::RECOVER` bucket — alongside
//! `hooi_secs`, like `redist_secs`, so the Fig 11 breakdown stays
//! sum-invariant) and `checkpoint_secs`/`checkpoint_bytes`.
//!
//! The same recovery loop also consumes *real* failures: with
//! [`TuckerSessionBuilder::transport`] set to
//! [`TransportChoice::Channel`], collectives move real framed bytes and
//! the transport's heartbeat/deadline monitor classifies a genuinely
//! hung or corrupting peer into the same
//! [`FailureKind`](crate::dist::FailureKind) taxonomy — a detected
//! crash is evicted and recovered bit-identically to the equivalent
//! injected one (`tests/transport.rs` pins this).

use super::checkpoint::{CheckpointPolicy, RetryPolicy, SessionCheckpoint};
use super::leader::{collect_record, RunRecord, Workload};
use crate::dist::{
    cat, ChannelTransport, FailureKind, FaultInjector, FaultPlan, NetModel, RankFailure,
    SimCluster, SimTransport, Transport, TransportChoice, TransportTuning,
};
use crate::hooi::{
    charge_plan_compilation, charge_shared_plan_compilation,
    prepare_modes_unplanned_with_sharers, prepare_modes_with_sharers,
    prepare_shared_plans, CoreRanks, HooiSnapshot, HooiState, Kernel, ModeDelta,
    ModeState, SharedPlans, TensorAccounting,
};
use crate::linalg::Mat;
use crate::runtime::Engine;
use crate::sched::{self, CostModel, DistTime, Distribution, PlacementPlan, Scheme};
use crate::serve::{DecompositionSnapshot, QueryBatch, QueryError, TopEntry};
use crate::tensor::slices::build_all;
use crate::tensor::{DeltaError, TensorDelta};
use crate::util::rng::Rng;
use std::sync::Arc;
use crate::util::timer::Stopwatch;

/// Typed distribution-scheme selection: the paper's four registry
/// entries plus an escape hatch for user-provided schemes.
pub enum SchemeChoice {
    /// The paper's lightweight multi-policy scheme (default).
    Lite,
    /// CoarseG — whole slices per rank (first-fit).
    CoarseG,
    /// CoarseG with best-fit slice packing.
    CoarseGBestFit,
    /// MediumG — processor-grid medium-grained scheme.
    MediumG,
    /// HyperG — fine-grained hypergraph partitioning.
    HyperG,
    /// Any user-provided [`Scheme`] implementation.
    Custom(Box<dyn Scheme>),
}

impl SchemeChoice {
    /// Registry lookup by the CLI/config names (`lite`, `coarseg`,
    /// `coarseg-bpf`, `mediumg`, `hyperg`, plus the aliases
    /// `sched::by_name` accepts).
    pub fn by_name(name: &str) -> Option<SchemeChoice> {
        sched::by_name(name).map(SchemeChoice::Custom)
    }

    /// Wrap a user-provided scheme.
    pub fn custom(scheme: Box<dyn Scheme>) -> SchemeChoice {
        SchemeChoice::Custom(scheme)
    }

    fn into_scheme(self) -> Box<dyn Scheme> {
        match self {
            SchemeChoice::Lite => Box::new(sched::Lite),
            SchemeChoice::CoarseG => Box::new(sched::CoarseG::default()),
            SchemeChoice::CoarseGBestFit => Box::new(sched::CoarseG {
                strategy: sched::coarse::SliceAssign::BestFit,
            }),
            SchemeChoice::MediumG => Box::new(sched::MediumG),
            SchemeChoice::HyperG => Box::new(sched::HyperG::default()),
            SchemeChoice::Custom(s) => s,
        }
    }
}

/// Typed compute-engine selection.
pub enum EngineChoice {
    /// In-process reference, fused TTM path (timing-faithful default).
    Native,
    /// Native through the batched fixed-shape contract (ablations).
    NativeBatched,
    /// Compiled PJRT artifacts when built, native fallback otherwise.
    PjrtOrNative,
    /// A fully constructed engine (e.g. a specific `PjrtRuntime`).
    Custom(Engine),
    /// An engine shared across several sessions (e.g. one PJRT runtime
    /// driving a multi-scheme comparison — artifacts load once).
    Shared(Arc<Engine>),
}

impl EngineChoice {
    fn into_engine(self) -> Arc<Engine> {
        match self {
            EngineChoice::Native => Arc::new(Engine::Native),
            EngineChoice::NativeBatched => Arc::new(Engine::NativeBatched),
            EngineChoice::PjrtOrNative => Arc::new(Engine::pjrt_or_native().0),
            EngineChoice::Custom(e) => Arc::new(e),
            EngineChoice::Shared(e) => e,
        }
    }
}

/// Typed microkernel selection (replaces `TUCKER_KERNEL`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// `TUCKER_KERNEL` if set, else best detected SIMD tier.
    #[default]
    Auto,
    /// Pin a specific microkernel (degrades to portable if the host
    /// cannot run it — same rule as the env override).
    Fixed(Kernel),
}

impl KernelChoice {
    fn as_option(self) -> Option<Kernel> {
        match self {
            KernelChoice::Auto => None,
            KernelChoice::Fixed(k) => Some(k),
        }
    }
}

/// Typed rank-executor selection (replaces `TUCKER_PHASE_EXECUTOR`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorChoice {
    /// `TUCKER_PHASE_EXECUTOR` if set, else parallel on multi-core hosts.
    #[default]
    Auto,
    /// Scoped-thread parallel rank executor.
    Parallel,
    /// Reference serial executor (minimal timing noise).
    Serial,
}

impl ExecutorChoice {
    fn as_option(self) -> Option<bool> {
        match self {
            ExecutorChoice::Auto => None,
            ExecutorChoice::Parallel => Some(true),
            ExecutorChoice::Serial => Some(false),
        }
    }
}

/// Typed TTM plan-layout selection (replaces `TUCKER_PLAN`): how each
/// rank stores its sweep-invariant assembly layout.
///
/// Either layout produces bit-identical decompositions on every kernel
/// and executor — including after ingest, rebalance migration, and
/// fault recovery (`tests/csf.rs` pins this). The difference is cost:
/// [`PlanChoice::SharedCsf`] holds one fiber-shared tree per rank
/// instead of N independent per-mode plans, reuses each fiber's
/// fast-factor contribution across the sweep's later TTMs (the FLOP
/// reduction `CsfPlan::sweep_flops` reports), and maintains one
/// splice/rebuild bookkeeping path per rank instead of N.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanChoice {
    /// `TUCKER_PLAN` if set (`shared` / `per-mode`), else per-mode.
    #[default]
    Auto,
    /// One independent `TtmPlan` per (mode, rank) — the classic layout.
    PerMode,
    /// One shared CSF tree per rank serving every mode's TTM
    /// (`hooi::CsfPlan`), with cross-mode contribution reuse.
    SharedCsf,
}

impl PlanChoice {
    fn as_option(self) -> Option<bool> {
        match self {
            PlanChoice::Auto => None,
            PlanChoice::PerMode => Some(false),
            PlanChoice::SharedCsf => Some(true),
        }
    }
}

/// What a streaming session does when ingest detects that a mode's
/// Theorem 6.1 sharing bounds no longer hold (see the module docs'
/// *Rebalancing* section).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RebalancePolicy {
    /// No automation: the session keeps decomposing on the stale
    /// placement. Flagged modes are still recorded
    /// ([`TuckerSession::pending_rebalance`]) and warned about once, and
    /// an explicit [`TuckerSession::rebalance`] still works — it
    /// re-plans the flagged modes, or every mode when none is flagged.
    Never,
    /// Record the flagged modes ([`TuckerSession::pending_rebalance`])
    /// and warn on the next decompose; the caller decides when to pay
    /// for [`TuckerSession::rebalance`]. The default.
    #[default]
    Manual,
    /// Decide on every flagged ingest from the §4 cost model: re-plan
    /// the flagged modes with Lite, diff, and migrate iff
    /// `savings_per_sweep × hooi_iters_amortization ≥ replan + migration`
    /// seconds — i.e. the caller expects at least this many further
    /// HOOI sweeps, over which the redistribution must pay for itself.
    Auto {
        /// Amortization horizon in HOOI sweeps.
        hooi_iters_amortization: usize,
    },
}

/// The cost-model verdict behind one rebalance attempt.
#[derive(Debug, Clone)]
pub struct RebalanceDecision {
    /// Predicted seconds per sweep under the live placement.
    pub current_secs_per_sweep: f64,
    /// Predicted seconds per sweep under the Lite re-plan.
    pub candidate_secs_per_sweep: f64,
    /// `current − candidate` (negative when the re-plan is worse).
    pub savings_per_sweep: f64,
    /// Simulated Lite re-plan seconds (paid either way).
    pub replan_secs: f64,
    /// Simulated migration seconds under the session's α–β model.
    pub migration_secs: f64,
    /// The amortization horizon the decision used; `None` for an
    /// explicit [`TuckerSession::rebalance`] (which migrates
    /// unconditionally).
    pub horizon: Option<usize>,
    /// The verdict: apply the migration?
    pub migrate: bool,
}

/// What one rebalance attempt (explicit or auto) did.
#[derive(Debug, Clone)]
pub struct RebalanceReport {
    /// Modes that were re-planned with Lite.
    pub modes: Vec<usize>,
    /// Whether the migration was applied (false: cost model declined,
    /// or the diff was empty).
    pub migrated: bool,
    /// Moved element copies (uni-pair placements count their single
    /// stored copy once).
    pub moved_elements: usize,
    /// Migration byte volume ((N+1)·4 bytes per moved copy).
    pub migration_bytes: u64,
    /// Dirty plans updated in place (run splice).
    pub plans_spliced: usize,
    /// Dirty plans recompiled from their element list.
    pub plans_rebuilt: usize,
    /// The cost-model verdict and its inputs.
    pub decision: RebalanceDecision,
}

/// Why a session could not be built — or, from the `try_*` decompose
/// variants, why a faulted run could not be recovered.
#[derive(Debug, PartialEq, Eq)]
pub enum SessionError {
    /// `CoreRanks` does not apply to this tensor (length mismatch or a
    /// zero rank) — the message is the `CoreRanks::validate` detail.
    InvalidCore(String),
    /// World size P must be at least 1.
    ZeroRanks,
    /// HOOI supports 3-D and 4-D tensors.
    UnsupportedOrder(usize),
    /// Every rank is dead: there is no survivor to re-place onto.
    NoSurvivors,
    /// A sweep (or the outcome) failed [`RetryPolicy::max_attempts`]
    /// times in a row; the message is the last failure's detail.
    RecoveryExhausted(String),
    /// A [`SessionCheckpoint`] does not belong to this session's
    /// configuration (world size, core ranks or factor shapes differ).
    CheckpointMismatch(String),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::InvalidCore(msg) => write!(f, "invalid core ranks: {msg}"),
            SessionError::ZeroRanks => write!(f, "world size P must be at least 1"),
            SessionError::UnsupportedOrder(n) => {
                write!(f, "HOOI supports 3-D and 4-D tensors, got {n}-D")
            }
            SessionError::NoSurvivors => {
                write!(f, "every rank is dead: no survivor to re-place onto")
            }
            SessionError::RecoveryExhausted(msg) => {
                write!(f, "recovery exhausted: {msg}")
            }
            SessionError::CheckpointMismatch(msg) => {
                write!(f, "checkpoint does not match this session: {msg}")
            }
        }
    }
}

impl std::error::Error for SessionError {}

/// Fluent, typed configuration for a [`TuckerSession`] — see the module
/// docs for the full option/env table.
pub struct TuckerSessionBuilder {
    workload: Arc<Workload>,
    scheme: SchemeChoice,
    p: usize,
    core: CoreRanks,
    invocations: usize,
    engine: EngineChoice,
    kernel: KernelChoice,
    executor: ExecutorChoice,
    plan_choice: PlanChoice,
    pin: Option<bool>,
    transport: Option<TransportChoice>,
    transport_tuning: TransportTuning,
    net: NetModel,
    accounting: Option<TensorAccounting>,
    rebalance: RebalancePolicy,
    checkpoint: CheckpointPolicy,
    retry: RetryPolicy,
    faults: FaultPlan,
    seed: u64,
}

impl TuckerSessionBuilder {
    fn new(workload: Arc<Workload>) -> TuckerSessionBuilder {
        TuckerSessionBuilder {
            workload,
            scheme: SchemeChoice::Lite,
            p: 8,
            core: CoreRanks::Uniform(10),
            invocations: 1,
            engine: EngineChoice::Native,
            kernel: KernelChoice::Auto,
            executor: ExecutorChoice::Auto,
            plan_choice: PlanChoice::Auto,
            pin: None,
            transport: None,
            transport_tuning: TransportTuning::default(),
            net: NetModel::default(),
            accounting: None,
            rebalance: RebalancePolicy::default(),
            checkpoint: CheckpointPolicy::default(),
            retry: RetryPolicy::default(),
            faults: FaultPlan::new(),
            seed: 0xBEEF,
        }
    }

    /// Distribution scheme (default: [`SchemeChoice::Lite`]).
    pub fn scheme(mut self, scheme: SchemeChoice) -> Self {
        self.scheme = scheme;
        self
    }

    /// Simulated MPI world size P (default 8).
    pub fn ranks(mut self, p: usize) -> Self {
        self.p = p;
        self
    }

    /// Core ranks — uniform K or per-mode K_n (default: uniform 10).
    ///
    /// `build()` rejects length mismatches and zero ranks. A K_n larger
    /// than what the data supports (K_n > L_n, or K_n > K̂_n) is *not* an
    /// error — degenerate modes are a supported regime (e.g. the scaled
    /// enron analogue has L_3 = 4 « K): Lanczos caps its iteration count
    /// at min(2K_n, L_n, K̂_n) and the surplus factor columns come back
    /// zero, so the effective rank is the data's, not the request's.
    pub fn core(mut self, core: impl Into<CoreRanks>) -> Self {
        self.core = core.into();
        self
    }

    /// HOOI invocations per [`TuckerSession::decompose`] call (default 1).
    pub fn invocations(mut self, invocations: usize) -> Self {
        self.invocations = invocations;
        self
    }

    /// Compute engine (default: [`EngineChoice::Native`], the
    /// timing-faithful path at simulation scale).
    pub fn engine(mut self, engine: EngineChoice) -> Self {
        self.engine = engine;
        self
    }

    /// TTM microkernel (default: [`KernelChoice::Auto`] —
    /// `TUCKER_KERNEL`, then detection).
    pub fn kernel(mut self, kernel: KernelChoice) -> Self {
        self.kernel = kernel;
        self
    }

    /// Rank executor (default: [`ExecutorChoice::Auto`] —
    /// `TUCKER_PHASE_EXECUTOR`, then parallel on multi-core hosts).
    pub fn executor(mut self, executor: ExecutorChoice) -> Self {
        self.executor = executor;
        self
    }

    /// TTM plan layout (default: [`PlanChoice::Auto`] — `TUCKER_PLAN`,
    /// then per-mode plans). [`PlanChoice::SharedCsf`] compiles one
    /// fiber-shared tree per rank instead of N per-mode plans;
    /// decompositions are bit-identical either way.
    pub fn plan(mut self, plan: PlanChoice) -> Self {
        self.plan_choice = plan;
        self
    }

    /// Pin the parallel executor's worker threads to distinct CPUs
    /// with a static rank→worker mapping (default: `TUCKER_PIN_THREADS`,
    /// then off). On NUMA hosts pinning keeps each rank's plan streams
    /// on the memory node that first touched them; results are
    /// bit-identical pinned or not.
    pub fn pin_threads(mut self, pin: bool) -> Self {
        self.pin = Some(pin);
        self
    }

    /// Communication transport (default: `TUCKER_TRANSPORT`, then
    /// [`TransportChoice::Sim`] — the analytic α–β charger).
    /// [`TransportChoice::Channel`] moves real framed bytes between
    /// ranks over in-process channels, with heartbeat/deadline failure
    /// detection feeding the recovery loop. Accounting is
    /// transport-invariant: decompositions are bit-identical either way.
    pub fn transport(mut self, transport: TransportChoice) -> Self {
        self.transport = Some(transport);
        self
    }

    /// Robustness knobs for the channel transport (heartbeat interval,
    /// phase deadline, retransmit budget/backoff, chaos hooks). Ignored
    /// by [`TransportChoice::Sim`].
    pub fn transport_tuning(mut self, tuning: TransportTuning) -> Self {
        self.transport_tuning = tuning;
        self
    }

    /// α–β network model for communication charging.
    pub fn net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }

    /// Fig 17 tensor accounting (default: `TUCKER_MEM_ACCOUNTING`, then
    /// plan-stream accounting).
    pub fn memory_accounting(mut self, accounting: TensorAccounting) -> Self {
        self.accounting = Some(accounting);
        self
    }

    /// What the session does when ingest flags broken Theorem 6.1
    /// bounds (default: [`RebalancePolicy::Manual`] — record and warn,
    /// migrate on explicit [`TuckerSession::rebalance`]).
    pub fn rebalance_policy(mut self, policy: RebalancePolicy) -> Self {
        self.rebalance = policy;
        self
    }

    /// When to capture a [`SessionCheckpoint`] at sweep boundaries
    /// (default: [`CheckpointPolicy::EverySweeps`]`(1)` — every
    /// boundary). The bootstrap state is retained regardless, so
    /// recovery works under [`CheckpointPolicy::Never`] too (it just
    /// replays the whole call).
    pub fn checkpoint_policy(mut self, policy: CheckpointPolicy) -> Self {
        self.checkpoint = policy;
        self
    }

    /// Recovery bounds: retry attempts per position and the straggler
    /// escalation timeout (default: 3 attempts, no timeout).
    pub fn retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Arm a deterministic [`FaultPlan`]: its events fire at their
    /// (sweep, phase) positions on every run, and the session recovers
    /// per the module docs' *Fault tolerance* section.
    pub fn fault_plan(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Seed for the distribution construction and the HOOI bootstrap.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Validate the configuration (tensor order, P ≥ 1, core-rank shape
    /// — see [`core`](TuckerSessionBuilder::core) for the K_n > L_n
    /// degenerate regime, which is allowed), construct the distribution,
    /// and compile the per-rank TTM plans — everything sweep-invariant
    /// is paid here, once, and reused by every decompose call.
    pub fn build(self) -> Result<TuckerSession, SessionError> {
        let ndim = self.workload.tensor.ndim();
        if !(ndim == 3 || ndim == 4) {
            return Err(SessionError::UnsupportedOrder(ndim));
        }
        if self.p == 0 {
            return Err(SessionError::ZeroRanks);
        }
        let ks = self.core.validate(ndim).map_err(SessionError::InvalidCore)?;
        let scheme = self.scheme.into_scheme();
        let mut rng = Rng::new(self.seed);
        let model = CostModel::default().with_net(self.net);
        let mut plan = scheme.plan(
            &self.workload.tensor,
            &self.workload.idx,
            self.p,
            &mut rng,
            &ks,
            &model,
        );
        // plan compilation honors the executor choice (serial stays
        // serial end to end — the timing-noise contract); the plan's
        // sharer indices are reused (cheap O(L_n) clones) so the build
        // pays one Sharers pass per mode, not two
        let parallel =
            crate::util::env::phase_executor_parallel(self.executor.as_option());
        let shared_csf =
            crate::util::env::plan_shared_csf(self.plan_choice.as_option());
        let sharers: Vec<sched::Sharers> =
            plan.modes.iter().map(|m| m.sharers.clone()).collect();
        let (modes, shared) = if shared_csf {
            // the mode states keep the distribution structure (sharers,
            // σ_n, FM patterns, element lists); the assembly layout is
            // one fiber-shared tree per rank, not N per-mode plans
            let modes = prepare_modes_unplanned_with_sharers(
                &self.workload.tensor,
                &self.workload.idx,
                &plan.dist,
                &self.core,
                sharers,
            );
            let shared = prepare_shared_plans(
                &self.workload.tensor,
                &modes,
                &self.core,
                parallel,
            );
            // the §4 estimate must price the tree's cross-mode
            // contribution reuse, not N independent TTMs
            plan.cost = plan.cost.with_shared_csf(&ks, &model);
            (modes, Some(shared))
        } else {
            let modes = prepare_modes_with_sharers(
                &self.workload.tensor,
                &self.workload.idx,
                &plan.dist,
                &self.core,
                parallel,
                sharers,
            );
            (modes, None)
        };
        let injector =
            if self.faults.is_empty() { None } else { Some(self.faults.injector()) };
        let transport_choice = crate::util::env::transport_choice(self.transport);
        Ok(TuckerSession {
            workload: self.workload,
            plan,
            core: self.core,
            ks,
            invocations: self.invocations,
            engine: self.engine.into_engine(),
            kernel: self.kernel.as_option(),
            executor: self.executor,
            pin: self.pin,
            transport_choice,
            transport_tuning: self.transport_tuning,
            wedged: vec![false; self.p],
            net: self.net,
            accounting: self.accounting,
            rebalance_policy: self.rebalance,
            checkpoint_policy: self.checkpoint,
            retry: self.retry,
            injector,
            dead: vec![false; self.p],
            seed: self.seed,
            modes,
            shared,
            plan_builds: 1,
            plan_rebuilds: 0,
            plan_charge_pending: true,
            pending_ingest_secs: 0.0,
            pending_redist_secs: 0.0,
            pending_rebalance: Vec::new(),
            pending_warned: false,
            rebalances: 0,
            rebalance_skips: 0,
            redist_secs_total: 0.0,
            recoveries: 0,
            recovery_secs_total: 0.0,
            checkpoint_secs_total: 0.0,
            checkpoint_bytes_total: 0,
            last_snap: None,
            last_checkpoint: None,
            state: None,
            generation: 0,
            snapshot: None,
        })
    }
}

/// A reusable decomposition session: one workload, one compiled
/// distribution, one set of per-rank TTM plans — any number of
/// decompositions and refinements over them.
pub struct TuckerSession {
    workload: Arc<Workload>,
    plan: PlacementPlan,
    core: CoreRanks,
    ks: Vec<usize>,
    invocations: usize,
    engine: Arc<Engine>,
    kernel: Option<Kernel>,
    executor: ExecutorChoice,
    /// Typed thread-pinning override (`None` = `TUCKER_PIN_THREADS`).
    pin: Option<bool>,
    /// Resolved communication transport (typed option > env > Sim).
    transport_choice: TransportChoice,
    transport_tuning: TransportTuning,
    /// Ranks deliberately wedged through [`TuckerSession::wedge_rank`] —
    /// real hangs the channel transport must *detect*, not be told about.
    wedged: Vec<bool>,
    net: NetModel,
    accounting: Option<TensorAccounting>,
    rebalance_policy: RebalancePolicy,
    checkpoint_policy: CheckpointPolicy,
    retry: RetryPolicy,
    /// The armed fault injector, persisted across clusters so consumed
    /// events and dead-rank tombstones survive between decompose calls.
    injector: Option<FaultInjector>,
    /// Evicted ranks (crashed, or explicitly evicted) — they own no
    /// elements and are skipped by every future eviction.
    dead: Vec<bool>,
    seed: u64,
    modes: Vec<ModeState>,
    /// Under [`PlanChoice::SharedCsf`]: the one fiber-shared tree per
    /// rank serving every mode's TTM (`None` = per-mode `TtmPlan`s in
    /// the mode states). Maintained by the same ingest/migration
    /// bookkeeping, per rank instead of per (mode, rank).
    shared: Option<SharedPlans>,
    plan_builds: usize,
    plan_rebuilds: usize,
    plan_charge_pending: bool,
    pending_ingest_secs: f64,
    /// Simulated redistribution seconds not yet charged to a cluster
    /// (`cat::REDIST` on the next run).
    pending_redist_secs: f64,
    /// Modes whose Theorem 6.1 bounds were violated by the last
    /// structural ingest and have not been rebalanced since.
    pending_rebalance: Vec<usize>,
    pending_warned: bool,
    rebalances: usize,
    rebalance_skips: usize,
    redist_secs_total: f64,
    recoveries: usize,
    /// Session-lifetime `cat::RECOVER` seconds (survivor re-placement,
    /// migration, rollback — the wall and simulated cost of recovery).
    recovery_secs_total: f64,
    checkpoint_secs_total: f64,
    checkpoint_bytes_total: u64,
    /// The in-memory restore point recovery rolls back to: the
    /// bootstrap at first, then the last policy-due sweep boundary.
    last_snap: Option<HooiSnapshot>,
    /// The last policy-due serialized checkpoint (observable artifact).
    last_checkpoint: Option<SessionCheckpoint>,
    state: Option<HooiState>,
    /// Monotone mutation counter: bumped on every ingest, rebalance,
    /// eviction, restore, and completed decompose — the provenance
    /// stamp on published [`DecompositionSnapshot`]s.
    generation: u64,
    /// The latest snapshot published at a sweep boundary. Readers hold
    /// their own `Arc` clones; publication never blocks them.
    snapshot: Option<Arc<DecompositionSnapshot>>,
}

/// Summary form only: a session owns compiled plans and engine state
/// far too large to dump — shown are the identity and the counters a
/// serving layer cares about.
impl std::fmt::Debug for TuckerSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TuckerSession")
            .field("workload", &self.workload.name)
            .field("ks", &self.ks)
            .field("generation", &self.generation)
            .field("plan_builds", &self.plan_builds)
            .field("has_snapshot", &self.snapshot.is_some())
            .finish_non_exhaustive()
    }
}

impl TuckerSession {
    /// Start configuring a session over a workload. Accepts an owned
    /// [`Workload`] or an `Arc<Workload>` — pass a shared `Arc` to run
    /// several sessions (e.g. a scheme comparison) over one tensor
    /// without deep-copying it.
    pub fn builder(workload: impl Into<Arc<Workload>>) -> TuckerSessionBuilder {
        TuckerSessionBuilder::new(workload.into())
    }

    /// The workload this session decomposes.
    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    /// The raw compiled distribution (retained across decompose calls).
    pub fn distribution(&self) -> &Distribution {
        &self.plan.dist
    }

    /// The live [`PlacementPlan`] — the distribution plus the per-mode
    /// §4 metrics/sharers and the cost estimate it was priced at.
    /// Refreshed by every structural ingest and every rebalance.
    pub fn placement(&self) -> &PlacementPlan {
        &self.plan
    }

    /// Modes whose Theorem 6.1 sharing bounds were violated by
    /// streaming and have not been rebalanced since — non-empty means
    /// the session is decomposing on a stale placement (under
    /// `Never`/`Manual`; `Auto` clears it when a migration lands).
    pub fn pending_rebalance(&self) -> &[usize] {
        &self.pending_rebalance
    }

    /// The resolved per-mode core ranks `[K_0, …, K_{N−1}]`.
    pub fn core_ranks(&self) -> &[usize] {
        &self.ks
    }

    /// How many times this session has compiled its TTM plans
    /// (`prepare_modes`). Stays 1 across any number of
    /// [`decompose`](TuckerSession::decompose) /
    /// [`decompose_more`](TuckerSession::decompose_more) calls — the
    /// observable form of the plan-reuse contract.
    pub fn plan_builds(&self) -> usize {
        self.plan_builds
    }

    /// How many (mode, rank) plans [`ingest`](TuckerSession::ingest)
    /// has spliced or rebuilt over the session's lifetime — the
    /// observable form of the incremental-invalidation contract: a
    /// localized delta keeps this far below
    /// `ndim × P × ingest_count`, where a full re-prepare would not.
    pub fn plan_rebuilds(&self) -> usize {
        self.plan_rebuilds
    }

    /// The prepared per-mode states (sharers, σ_n, FM pattern, rank
    /// element lists and compiled TTM plans) — read-only introspection
    /// for tests, benches and memory tooling.
    pub fn mode_states(&self) -> &[ModeState] {
        &self.modes
    }

    /// The per-rank shared CSF trees under [`PlanChoice::SharedCsf`]
    /// (`None` when the session holds per-mode plans) — read-only
    /// introspection for tests, benches and memory tooling.
    pub fn shared_plans(&self) -> Option<&SharedPlans> {
        self.shared.as_ref()
    }

    /// Build the transport this session's clusters communicate over:
    /// a fresh instance per run, seeded with the session's tuning, with
    /// wedged ranks wedged (they hang silently — the monitor must catch
    /// them) and evicted ranks excluded from the collectives.
    fn make_transport(&self) -> Box<dyn Transport> {
        match self.transport_choice {
            TransportChoice::Sim => Box::new(SimTransport::new()),
            TransportChoice::Channel => {
                let mut t =
                    ChannelTransport::new(self.plan.dist.p, self.transport_tuning);
                for (r, &w) in self.wedged.iter().enumerate() {
                    if w {
                        t.wedge_rank(r);
                    }
                }
                for (r, &d) in self.dead.iter().enumerate() {
                    if d {
                        t.mark_dead(r);
                    }
                }
                Box::new(t)
            }
        }
    }

    /// The resolved communication transport this session runs on.
    pub fn transport_choice(&self) -> TransportChoice {
        self.transport_choice
    }

    /// Chaos hook: make `rank` hang silently in every future collective
    /// — a *real* fault, with no [`FaultPlan`] involvement. Only the
    /// channel transport's heartbeat/deadline monitor can detect it
    /// (under [`TransportChoice::Sim`] nothing happens: no bytes move,
    /// so there is nothing to hang).
    pub fn wedge_rank(&mut self, rank: usize) {
        if rank < self.wedged.len() {
            self.wedged[rank] = true;
        }
    }

    fn new_cluster(&mut self) -> SimCluster {
        let mut cluster = SimCluster::new(self.plan.dist.p).with_net(self.net);
        cluster.set_transport(self.make_transport());
        if let Some(parallel) = self.executor.as_option() {
            cluster = cluster.with_parallel(parallel);
        }
        if let Some(pin) = self.pin {
            cluster = cluster.with_pinned(pin);
        }
        if let Some(inj) = &self.injector {
            // hand the persistent injector state over: events consumed
            // in earlier runs stay consumed, tombstones stay dead
            cluster.set_injector(inj.clone());
        }
        cluster.set_phase_timeout(self.retry.straggler_timeout);
        if self.pending_ingest_secs > 0.0 {
            // partial-rebuild work from ingest is real per-rank compute:
            // charge it (once) to the next run, like plan compilation
            cluster.elapsed.add(cat::TTM, self.pending_ingest_secs);
            self.pending_ingest_secs = 0.0;
        }
        if self.pending_redist_secs > 0.0 {
            // rebalance work (Lite re-plan + migration) is
            // redistribution time, charged once to its own bucket
            cluster.elapsed.add(cat::REDIST, self.pending_redist_secs);
            self.pending_redist_secs = 0.0;
        }
        cluster
    }

    /// Fresh-run prelude: new cluster (dist time + one-off plan
    /// compilation charge) and a bootstrapped [`HooiState`].
    fn start(&mut self) -> (SimCluster, HooiState) {
        let mut cluster = self.new_cluster();
        cluster.elapsed.add(cat::DIST, self.plan.dist.time.simulated_secs);
        if self.plan_charge_pending {
            // plan compilation is paid exactly once per session — charge
            // it to the first run's TTM bucket, amortized thereafter
            match &self.shared {
                Some(sp) => charge_shared_plan_compilation(sp, &mut cluster),
                None => charge_plan_compilation(&self.modes, &mut cluster),
            }
            self.plan_charge_pending = false;
        }
        let state = HooiState::init(
            &self.workload.tensor,
            self.plan.dist.p,
            &self.core,
            self.seed,
            self.kernel,
        );
        state.record_kernels(&self.engine, &mut cluster);
        // the bootstrap is always a valid restore point (and the only
        // one under CheckpointPolicy::Never); stale snapshots from a
        // previous bootstrap must not survive into this run
        self.last_snap = Some(state.snapshot());
        self.last_checkpoint = None;
        (cluster, state)
    }

    /// Satellite of the rebalance loop: decomposing on a placement the
    /// bounds revalidation flagged is legal but usually unintended —
    /// say so once per flag event (Auto handles it itself).
    fn warn_if_pending(&mut self) {
        if self.pending_warned || self.pending_rebalance.is_empty() {
            return;
        }
        if matches!(self.rebalance_policy, RebalancePolicy::Auto { .. }) {
            return;
        }
        eprintln!(
            "tucker-lite: warning: decomposing on a placement whose Theorem 6.1 \
             bounds no longer hold (modes {:?}); call TuckerSession::rebalance() \
             or configure RebalancePolicy::Auto",
            self.pending_rebalance
        );
        self.pending_warned = true;
    }

    /// Run the configured number of HOOI invocations from a fresh
    /// bootstrap (any previous refinement state is discarded; the
    /// compiled plans are reused). Panics if recovery is exhausted —
    /// use [`try_decompose`](TuckerSession::try_decompose) when a fault
    /// plan is armed.
    pub fn decompose(&mut self) -> Decomposition {
        match self.try_decompose() {
            Ok(d) => d,
            Err(e) => panic!("unrecovered session failure: {e}"),
        }
    }

    /// Fallible [`decompose`](TuckerSession::decompose): surfaces
    /// retry exhaustion and survivor loss as a [`SessionError`] instead
    /// of panicking.
    pub fn try_decompose(&mut self) -> Result<Decomposition, SessionError> {
        self.warn_if_pending();
        let (mut cluster, state) = self.start();
        self.state = Some(state);
        self.run_to(&mut cluster, self.invocations)?;
        self.finish(cluster)
    }

    /// Continue the decomposition with `invocations` further HOOI sweeps
    /// over the *cached* plans — no `prepare_modes`, no re-bootstrap:
    /// running `decompose()` then `decompose_more(m)` is bit-identical
    /// to a single run configured with `invocations + m`. With no
    /// decomposition in flight, bootstraps and runs the configured
    /// invocations plus `invocations` in one pass. Panics if recovery
    /// is exhausted — use
    /// [`try_decompose_more`](TuckerSession::try_decompose_more) when a
    /// fault plan is armed.
    pub fn decompose_more(&mut self, invocations: usize) -> Decomposition {
        match self.try_decompose_more(invocations) {
            Ok(d) => d,
            Err(e) => panic!("unrecovered session failure: {e}"),
        }
    }

    /// Fallible [`decompose_more`](TuckerSession::decompose_more).
    pub fn try_decompose_more(
        &mut self,
        invocations: usize,
    ) -> Result<Decomposition, SessionError> {
        self.warn_if_pending();
        let mut cluster;
        let target;
        if self.state.is_none() {
            // start() already records kernel provenance on the cluster
            let (c, state) = self.start();
            cluster = c;
            self.state = Some(state);
            target = self.invocations + invocations;
        } else {
            cluster = self.new_cluster();
            let state = self.state.as_ref().expect("decomposition state in flight");
            target = state.sweep() + invocations;
            state.record_kernels(&self.engine, &mut cluster);
        }
        self.run_to(&mut cluster, target)?;
        self.finish(cluster)
    }

    /// The recovery loop: drive the in-flight state to `target`
    /// completed sweeps, one sweep at a time — checkpointing at
    /// policy-due boundaries, and on failure evicting crashed ranks,
    /// rolling back to the last retained checkpoint and re-sweeping,
    /// bounded by [`RetryPolicy::max_attempts`] consecutive failures.
    fn run_to(
        &mut self,
        cluster: &mut SimCluster,
        target: usize,
    ) -> Result<(), SessionError> {
        let mut failures_in_a_row = 0usize;
        loop {
            let done = self.state.as_ref().expect("state in flight").sweep();
            if done >= target {
                self.sync_injector(cluster);
                return Ok(());
            }
            let res = {
                let state = self.state.as_mut().expect("state in flight");
                state.sweeps_with(
                    &self.workload.tensor,
                    &self.modes,
                    self.shared.as_ref(),
                    &self.engine,
                    cluster,
                    1,
                )
            };
            match res {
                Ok(()) => {
                    failures_in_a_row = 0;
                    let done =
                        self.state.as_ref().expect("state in flight").sweep();
                    // never checkpoint the final boundary: an outcome
                    // failure must re-run at least one sweep, or the
                    // final mode's locals (which only a sweep rebuilds)
                    // would be missing at the retried core phase
                    if self.checkpoint_policy.due(done) && done != target {
                        self.take_checkpoint();
                    }
                }
                Err(f) => {
                    self.sync_injector(cluster);
                    failures_in_a_row += 1;
                    if failures_in_a_row >= self.retry.max_attempts {
                        return Err(SessionError::RecoveryExhausted(format!(
                            "{f} ({failures_in_a_row} consecutive failed attempts)"
                        )));
                    }
                    self.recover(cluster, &f)?;
                }
            }
        }
    }

    /// One recovery cycle: evict any newly dead ranks onto the
    /// survivors, then roll the HOOI state back to the last retained
    /// checkpoint. All cost — eviction migration, plan rebuilds,
    /// rollback wall time — is charged to `cat::RECOVER`. Dead ranks
    /// come from two detectors with one treatment: the injector's
    /// tombstones (injected crashes) and the triggering failure itself
    /// when the transport's liveness monitor classified a *real* crash.
    fn recover(
        &mut self,
        cluster: &mut SimCluster,
        failure: &RankFailure,
    ) -> Result<(), SessionError> {
        let t0 = Stopwatch::start();
        self.recoveries += 1;
        let mut newly_dead: Vec<usize> = cluster
            .injector()
            .map(|inj| inj.dead_ranks())
            .unwrap_or_default()
            .into_iter()
            .filter(|&r| !self.dead[r])
            .collect();
        if failure.kind == FailureKind::Crash
            && failure.rank < self.dead.len()
            && !self.dead[failure.rank]
            && !newly_dead.contains(&failure.rank)
        {
            newly_dead.push(failure.rank);
            newly_dead.sort_unstable();
        }
        let mut sim_secs = 0.0;
        if !newly_dead.is_empty() {
            if self.survivors_after(&newly_dead) == 0 {
                return Err(SessionError::NoSurvivors);
            }
            for &r in &newly_dead {
                self.dead[r] = true;
                // future collectives on this cluster (and on fresh ones,
                // via make_transport) run over the survivors only
                cluster.mark_rank_dead(r);
            }
            let (migration_sim, rebuild_secs) = self.apply_eviction();
            sim_secs += migration_sim + rebuild_secs;
        }
        let snap = self.last_snap.clone().ok_or_else(|| {
            SessionError::RecoveryExhausted("no restore point retained".into())
        })?;
        if let Some(state) = self.state.as_mut() {
            state.restore(&snap);
        }
        let secs = sim_secs + t0.seconds();
        cluster.elapsed.add(cat::RECOVER, secs);
        self.recovery_secs_total += secs;
        Ok(())
    }

    fn survivors_after(&self, newly_dead: &[usize]) -> usize {
        self.dead
            .iter()
            .enumerate()
            .filter(|&(r, &d)| !d && !newly_dead.contains(&r))
            .count()
    }

    /// Capture a policy-due checkpoint: the in-memory restore point
    /// plus the serialized [`SessionCheckpoint`] artifact (its
    /// serialization cost and size are what `RunRecord` reports).
    fn take_checkpoint(&mut self) {
        let state = self.state.as_ref().expect("state in flight");
        let t0 = Stopwatch::start();
        let snap = state.snapshot();
        let cp = SessionCheckpoint::from_snapshot(&snap, self.plan.dist.p, &self.ks);
        self.checkpoint_bytes_total += cp.serialize().len() as u64;
        self.checkpoint_secs_total += t0.seconds();
        self.last_snap = Some(snap);
        self.last_checkpoint = Some(cp);
    }

    /// Persist the cluster's injector state (consumed events, fired
    /// count, tombstones) back into the session, so the next cluster —
    /// and the next retry — continues from it instead of re-arming.
    fn sync_injector(&mut self, cluster: &SimCluster) {
        if let Some(inj) = cluster.injector() {
            self.injector = Some(inj.clone());
        }
    }

    /// Apply a streaming [`TensorDelta`] to the held tensor and
    /// incrementally revalidate the session around it:
    ///
    /// 1. the delta is applied atomically to the workload's tensor
    ///    (copy-on-write if the `Arc<Workload>` is shared) and, on
    ///    appends, its slice indices are refreshed;
    /// 2. each mode's placement is extended over the appended elements
    ///    with Lite's per-bin load discipline
    ///    ([`crate::sched::incremental::extend_policy`]) — the ⌈|E′|/P⌉ limit
    ///    is preserved unconditionally, and the Theorem 6.1 sharing
    ///    bounds are revalidated (violations come back in
    ///    [`IngestReport::rebalance_modes`]: the signal to schedule a
    ///    full, cheap, Lite redistribution);
    /// 3. only the *dirty* (mode, rank) plans — those owning a touched
    ///    element under that mode's policy — are spliced in place or
    ///    recompiled ([`ModeState::apply_delta`]); clean plans are not
    ///    touched and `prepare_modes` never reruns.
    ///
    /// Ingesting into a fresh session and then decomposing is
    /// bit-identical to building a new session on the mutated tensor
    /// under the same placement. With a decomposition in flight the
    /// factors are kept as a warm start; the first sweep after ingest
    /// runs over the updated plans (take outcomes only after that
    /// sweep). On error the session — tensor included — is unchanged.
    pub fn ingest(&mut self, delta: &TensorDelta) -> Result<IngestReport, DeltaError> {
        let ndim = self.workload.tensor.ndim();
        // under SharedCsf the unit of maintenance is the rank's one
        // tree, not a (mode, rank) plan — the localization denominator
        // follows
        let plan_count = if self.shared.is_some() {
            self.plan.dist.p
        } else {
            ndim * self.plan.dist.p
        };
        let (n_appended, n_changed, n_removed) = delta.counts();
        let mut report = IngestReport {
            appended: n_appended,
            changed: n_changed,
            removed: n_removed,
            plans_spliced: 0,
            plans_rebuilt: 0,
            plan_count,
            rebalance_modes: Vec::new(),
            rebuild_secs: 0.0,
            rebalance: None,
        };
        if delta.is_empty() {
            return Ok(report);
        }
        // 1. mutate the tensor; refresh the slice indices on appends.
        // The CSR slice layout keeps every slice's ids contiguous, so
        // folding a batch in is an O(nnz) merge either way — the rebuild
        // is the same asymptotic cost as any in-place splice of the
        // offsets/elems arrays and stays bit-identical to a fresh build.
        let applied = {
            let w = Arc::make_mut(&mut self.workload);
            let applied = delta.apply(&mut w.tensor, &w.idx)?;
            if !applied.appended.is_empty() {
                w.idx = build_all(&w.tensor);
            }
            applied
        };
        let structural = !applied.appended.is_empty();
        // 2. placement + bounds revalidation
        if structural {
            let nnz_after = self.workload.tensor.nnz();
            let t = &self.workload.tensor;
            if self.plan.dist.uni {
                // uni-policy schemes alias one Arc'd assignment across
                // all modes: detach the aliases, extend the single
                // buffer in place (make_mut sees it unshared — no O(nnz)
                // copy), then re-share, keeping the single-copy
                // invariant (and Fig 17 accounting) true
                let coords: Vec<u32> = applied
                    .appended
                    .iter()
                    .map(|&e| t.coord(0, e as usize))
                    .collect();
                {
                    let (head, tail) = self.plan.dist.policies.split_at_mut(1);
                    for pol in tail.iter_mut() {
                        pol.assign = Arc::new(Vec::new());
                    }
                    sched::incremental::extend_policy(
                        &mut head[0],
                        &self.modes[0].sharers,
                        &coords,
                        nnz_after,
                    );
                }
                let shared = self.plan.dist.policies[0].assign.clone();
                for pol in self.plan.dist.policies[1..].iter_mut() {
                    pol.assign = shared.clone();
                }
            } else {
                for n in 0..ndim {
                    let coords: Vec<u32> = applied
                        .appended
                        .iter()
                        .map(|&e| t.coord(n, e as usize))
                        .collect();
                    sched::incremental::extend_policy(
                        &mut self.plan.dist.policies[n],
                        &self.modes[n].sharers,
                        &coords,
                        nnz_after,
                    );
                }
            }
            for n in 0..ndim {
                let bounds = sched::incremental::theorem_bounds(
                    &self.workload.idx[n],
                    &self.plan.dist.policies[n],
                );
                if !bounds.all_ok() {
                    report.rebalance_modes.push(n);
                }
            }
        }
        // 3. bucket the touched ids by (mode, rank); splice/rebuild
        // exactly those plans
        let parallel =
            crate::util::env::phase_executor_parallel(self.executor.as_option());
        let mds: Vec<ModeDelta> = (0..ndim)
            .map(|n| {
                let mut md = ModeDelta::empty(self.plan.dist.p);
                let assign = &self.plan.dist.policies[n].assign;
                for &e in &applied.changed {
                    md.changed[assign[e as usize] as usize].push(e);
                }
                for &e in &applied.appended {
                    md.appended[assign[e as usize] as usize].push(e);
                }
                md
            })
            .collect();
        for (n, md) in mds.iter().enumerate() {
            // under SharedCsf the mode states are plan-less: this pass
            // refreshes the structural state (sharers, σ_n, FM pattern,
            // element lists) and touches no plans
            let stats = self.modes[n].apply_delta(
                &self.workload.tensor,
                &self.workload.idx[n],
                &self.plan.dist,
                n,
                &self.core,
                md,
                parallel,
            );
            report.plans_spliced += stats.spliced;
            report.plans_rebuilt += stats.rebuilt;
            report.rebuild_secs += stats.rebuild_secs;
        }
        if let Some(shared) = self.shared.as_mut() {
            // one maintenance pass over the shared trees: a rank is
            // dirty if ANY mode's policy assigns it a touched element;
            // each dirty rank splices or rebuilds its single tree
            // against the just-updated element lists
            let t = &self.workload.tensor;
            let modes = &self.modes;
            let core = &self.core;
            let mds_ref = &mds;
            let mut tasks = Vec::new();
            for (rank, csf) in shared.per_rank.iter_mut().enumerate() {
                let dirty = mds.iter().any(|md| {
                    !md.appended[rank].is_empty() || !md.changed[rank].is_empty()
                });
                if !dirty {
                    continue;
                }
                tasks.push(move || {
                    let lists: Vec<&[u32]> =
                        modes.iter().map(|st| st.elems[rank].as_slice()).collect();
                    let appended: Vec<&[u32]> = mds_ref
                        .iter()
                        .map(|md| md.appended[rank].as_slice())
                        .collect();
                    let changed: Vec<&[u32]> = mds_ref
                        .iter()
                        .map(|md| md.changed[rank].as_slice())
                        .collect();
                    csf.apply_delta(t, core, &lists, &appended, &changed)
                });
            }
            let timed = crate::dist::run_scoped(tasks, parallel);
            let mut makespan = 0.0f64;
            for (maint, secs) in timed {
                report.plans_spliced += maint.spliced;
                report.plans_rebuilt += maint.rebuilt;
                makespan = makespan.max(secs);
            }
            report.rebuild_secs += makespan;
        }
        self.plan_rebuilds += report.plans_spliced + report.plans_rebuilt;
        self.pending_ingest_secs += report.rebuild_secs;
        // the tensor mutated: published snapshots now lag the session
        self.generation += 1;
        // 4. keep the plan's §4 provenance (metrics, cost) tracking the
        // live placement, then close the rebalance loop per policy
        if structural {
            let model = self.cost_model();
            // apply_delta just rebuilt every mode's sharers against the
            // extended policies — hand them over instead of paying a
            // second O(nnz) Sharers::build pass per mode
            let sharers: Vec<&sched::Sharers> =
                self.modes.iter().map(|st| &st.sharers).collect();
            self.plan.refresh_from(&self.workload.idx, &sharers, &model);
            if self.shared.is_some() {
                // refresh_from re-priced the sweep per-mode: re-apply
                // the shared tree's cross-mode reuse discount
                self.plan.cost = self.plan.cost.with_shared_csf(&self.ks, &model);
            }
            if report.rebalance_modes.is_empty() {
                self.pending_rebalance.clear();
            } else {
                // record first: a declined Auto migration must leave the
                // flags visible (a landed one recomputes/clears them)
                self.pending_rebalance = report.rebalance_modes.clone();
                self.pending_warned = false;
                if let RebalancePolicy::Auto { hooi_iters_amortization } =
                    self.rebalance_policy
                {
                    let rb = self.rebalance_with(
                        report.rebalance_modes.clone(),
                        Some(hooi_iters_amortization),
                    );
                    report.rebalance = Some(rb);
                }
            }
        }
        Ok(report)
    }

    fn cost_model(&self) -> CostModel {
        CostModel::default().with_net(self.net)
    }

    /// Under [`PlanChoice::SharedCsf`]: rebuild the shared tree of
    /// every rank the migration moved elements to or from, under *any*
    /// mode — ownership changes don't satisfy the append-only splice
    /// contract, so dirty trees rebuild whole (the per-rank analogue of
    /// the per-mode migration machinery; must run after the mode
    /// states' element lists were migrated). Returns the rebuilt-tree
    /// count and the rebuild makespan.
    fn rebuild_shared_for(
        &mut self,
        migration: &sched::MigrationPlan,
        parallel: bool,
    ) -> (usize, f64) {
        let Some(shared) = self.shared.as_mut() else {
            return (0, 0.0);
        };
        let mut dirty = vec![false; self.plan.dist.p];
        for mm in &migration.per_mode {
            for (r, (inc, out)) in
                mm.incoming.iter().zip(&mm.outgoing).enumerate()
            {
                if !inc.is_empty() || !out.is_empty() {
                    dirty[r] = true;
                }
            }
        }
        let t = &self.workload.tensor;
        let modes = &self.modes;
        let core = &self.core;
        let mut tasks = Vec::new();
        for (rank, csf) in shared.per_rank.iter_mut().enumerate() {
            if !dirty[rank] {
                continue;
            }
            tasks.push(move || {
                let lists: Vec<&[u32]> =
                    modes.iter().map(|st| st.elems[rank].as_slice()).collect();
                csf.rebuild(t, core, &lists);
            });
        }
        let count = tasks.len();
        let timed = crate::dist::run_scoped(tasks, parallel);
        let makespan = timed.iter().map(|&((), s)| s).fold(0.0, f64::max);
        (count, makespan)
    }

    /// Re-plan the pending modes with Lite and migrate to the
    /// re-planned placement — the explicit arm of the rebalance loop
    /// (see the module docs). With nothing pending, every mode is
    /// re-planned. The migration is applied *unconditionally* when the
    /// diff is non-empty (the caller already decided); the returned
    /// report still carries the cost-model verdict for inspection. An
    /// empty diff is a no-op: no plan is touched.
    ///
    /// Only the diffed (mode, rank) TTM plans are spliced or rebuilt —
    /// [`plan_rebuilds`](TuckerSession::plan_rebuilds) grows by exactly
    /// the migration's dirty-plan count, never by a full re-prepare.
    /// With a decomposition in flight the factors carry over as a warm
    /// start, exactly as with [`ingest`](TuckerSession::ingest).
    pub fn rebalance(&mut self) -> RebalanceReport {
        let modes: Vec<usize> = if self.pending_rebalance.is_empty() {
            (0..self.workload.tensor.ndim()).collect()
        } else {
            self.pending_rebalance.clone()
        };
        self.rebalance_with(modes, None)
    }

    /// Shared rebalance engine: re-plan `modes` with Lite, diff, decide
    /// (`horizon`: `Some(h)` = §4 cost-model amortization over `h`
    /// sweeps, `None` = explicit call, migrate on any non-empty diff),
    /// and apply the migration through the HOOI layer when the verdict
    /// says so.
    fn rebalance_with(
        &mut self,
        modes: Vec<usize>,
        horizon: Option<usize>,
    ) -> RebalanceReport {
        let t0 = Stopwatch::start();
        let model = self.cost_model();
        let w = self.workload.clone();
        let t = &w.tensor;
        let idx = &w.idx;
        let p = self.plan.dist.p;
        let mut candidate = self.plan.dist.clone();
        let mut replan_sim = 0.0f64;
        for &n in &modes {
            // deterministic per (seed, mode): a mode's re-plan does not
            // depend on which other modes are in the set, so re-planning
            // an already-rebalanced mode on an unchanged tensor
            // reproduces its policy exactly (repeat rebalances over the
            // same or smaller mode sets diff empty)
            let mut rng = Rng::new(self.seed ^ 0x5EBA_1A5E ^ ((n as u64) << 32));
            let (pol, sim) = sched::lite::plan_mode(t, &idx[n], p, &mut rng);
            candidate.policies[n] = pol;
            replan_sim += sim;
        }
        if candidate.uni && !modes.is_empty() {
            // per-mode Lite policies break the single-assignment
            // invariant; the candidate is multi-policy from here on
            candidate.uni = false;
        }
        if candidate.scheme != "Lite" && !candidate.scheme.ends_with("+Lite-rebal") {
            // provenance must say the placement is no longer purely the
            // original scheme's: post-migration records (RunRecord
            // scheme column, placement().scheme()) report the hybrid
            candidate.scheme.push_str("+Lite-rebal");
        }
        let mut candidate_plan =
            PlacementPlan::compile(candidate, idx, &self.ks, &model);
        if self.shared.is_some() {
            // price the candidate under the same shared-tree reuse
            // discount the live plan carries — the savings comparison
            // must be apples-to-apples
            candidate_plan.cost = candidate_plan.cost.with_shared_csf(&self.ks, &model);
        }
        let migration = self.plan.diff(&candidate_plan);
        let migration_sim = migration.simulated_secs(&self.net);
        let savings =
            self.plan.cost.secs_per_sweep - candidate_plan.cost.secs_per_sweep;
        let migrate = match horizon {
            None => !migration.is_empty(),
            Some(h) => {
                !migration.is_empty()
                    && savings > 0.0
                    && savings * h as f64 >= replan_sim + migration_sim
            }
        };
        let decision = RebalanceDecision {
            current_secs_per_sweep: self.plan.cost.secs_per_sweep,
            candidate_secs_per_sweep: candidate_plan.cost.secs_per_sweep,
            savings_per_sweep: savings,
            replan_secs: replan_sim,
            migration_secs: migration_sim,
            horizon,
            migrate,
        };
        let mut report = RebalanceReport {
            modes,
            migrated: false,
            moved_elements: migration.moved_elements,
            migration_bytes: migration.bytes,
            plans_spliced: 0,
            plans_rebuilt: 0,
            decision,
        };
        // the re-plan really ran either way: account for it
        self.pending_redist_secs += replan_sim;
        self.redist_secs_total += replan_sim;
        if !migrate {
            if horizon.is_some() {
                // only a cost-model decline counts as a skip; an
                // explicit rebalance whose diff came back empty is a
                // no-op, not a decision
                self.rebalance_skips += 1;
            }
            return report;
        }
        // apply: exactly the diffed (mode, rank) plans, via the same
        // splice/rebuild machinery ingest uses
        let parallel =
            crate::util::env::phase_executor_parallel(self.executor.as_option());
        let mut rebuild_secs = 0.0f64;
        for mm in &migration.per_mode {
            if mm.is_empty() {
                // π_n unchanged: sharers/plans stay valid, but the FM
                // transfer pattern depends on the *other* modes'
                // (migrated) policies — refresh it so memory/volume
                // accounting matches a fresh prepare
                self.modes[mm.mode].refresh_fm(
                    &idx[mm.mode],
                    &candidate_plan.dist,
                    mm.mode,
                );
                continue;
            }
            let stats = self.modes[mm.mode].apply_migration(
                t,
                &idx[mm.mode],
                &candidate_plan.dist,
                mm.mode,
                &self.core,
                &mm.outgoing,
                &mm.incoming,
                parallel,
            );
            report.plans_spliced += stats.spliced;
            report.plans_rebuilt += stats.rebuilt;
            rebuild_secs += stats.rebuild_secs;
        }
        let (trees, tree_secs) = self.rebuild_shared_for(&migration, parallel);
        report.plans_rebuilt += trees;
        rebuild_secs += tree_secs;
        self.plan_rebuilds += report.plans_spliced + report.plans_rebuilt;
        self.pending_ingest_secs += rebuild_secs;
        self.pending_redist_secs += migration_sim;
        self.redist_secs_total += migration_sim;
        // swap the plan in, folding the redistribution into the
        // distribution time (Fig 16's quantity keeps growing with the
        // session's total distribution investment)
        let old_time = self.plan.dist.time;
        self.plan = candidate_plan;
        self.plan.dist.time = DistTime {
            serial_secs: old_time.serial_secs + t0.seconds(),
            simulated_secs: old_time.simulated_secs + replan_sim + migration_sim,
        };
        self.rebalances += 1;
        report.migrated = true;
        self.generation += 1;
        // revalidate: a fresh Lite mode satisfies Theorem 6.1, so this
        // normally clears; a mode left un-replanned keeps its flag
        self.pending_rebalance = (0..t.ndim())
            .filter(|&n| {
                !sched::incremental::theorem_bounds(
                    &idx[n],
                    &self.plan.dist.policies[n],
                )
                .all_ok()
            })
            .collect();
        self.pending_warned = false;
        report
    }

    /// Re-place every element owned by a dead rank across the
    /// survivors and migrate the session onto the evicted placement.
    /// Shared by crash recovery and the planned
    /// [`evict_rank`](TuckerSession::evict_rank): both paths produce
    /// the identical placement from the identical starting plan — the
    /// root of the crash-recovery ≡ planned-eviction bit contract.
    /// Returns (simulated migration seconds, plan-rebuild makespan).
    fn apply_eviction(&mut self) -> (f64, f64) {
        let t0 = Stopwatch::start();
        let model = self.cost_model();
        let w = self.workload.clone();
        let t = &w.tensor;
        let idx = &w.idx;
        let mut candidate = self.plan.dist.clone();
        if candidate.uni {
            // uni-pair placements share one assignment buffer across
            // modes: evict it once (against mode 0's slice structure)
            // and re-alias, keeping the single-copy invariant true
            let pol = sched::evict_rank(&candidate.policies[0], &idx[0], &self.dead);
            let shared = pol.assign.clone();
            candidate.policies[0] = pol;
            for other in candidate.policies[1..].iter_mut() {
                other.assign = shared.clone();
            }
        } else {
            for n in 0..t.ndim() {
                candidate.policies[n] =
                    sched::evict_rank(&candidate.policies[n], &idx[n], &self.dead);
            }
        }
        if !candidate.scheme.ends_with("+evict") {
            // provenance: the placement is no longer purely the
            // original scheme's
            candidate.scheme.push_str("+evict");
        }
        let mut candidate_plan =
            PlacementPlan::compile(candidate, idx, &self.ks, &model);
        if self.shared.is_some() {
            candidate_plan.cost = candidate_plan.cost.with_shared_csf(&self.ks, &model);
        }
        let migration = self.plan.diff(&candidate_plan);
        let migration_sim = migration.simulated_secs(&self.net);
        // apply: exactly the diffed (mode, rank) plans, via the same
        // splice/rebuild machinery ingest and rebalance use
        let parallel =
            crate::util::env::phase_executor_parallel(self.executor.as_option());
        let mut rebuild_secs = 0.0f64;
        let mut touched = 0usize;
        for mm in &migration.per_mode {
            if mm.is_empty() {
                self.modes[mm.mode].refresh_fm(
                    &idx[mm.mode],
                    &candidate_plan.dist,
                    mm.mode,
                );
                continue;
            }
            let stats = self.modes[mm.mode].apply_migration(
                t,
                &idx[mm.mode],
                &candidate_plan.dist,
                mm.mode,
                &self.core,
                &mm.outgoing,
                &mm.incoming,
                parallel,
            );
            touched += stats.spliced + stats.rebuilt;
            rebuild_secs = rebuild_secs.max(stats.rebuild_secs);
        }
        let (trees, tree_secs) = self.rebuild_shared_for(&migration, parallel);
        touched += trees;
        rebuild_secs = rebuild_secs.max(tree_secs);
        self.plan_rebuilds += touched;
        let old_time = self.plan.dist.time;
        self.plan = candidate_plan;
        self.plan.dist.time = DistTime {
            serial_secs: old_time.serial_secs + t0.seconds(),
            simulated_secs: old_time.simulated_secs + migration_sim,
        };
        self.generation += 1;
        (migration_sim, rebuild_secs)
    }

    /// Planned eviction: drain `rank` (re-placing its elements across
    /// the survivors with Lite's min-load discipline, preferring ranks
    /// that already share each slice) and migrate the session onto the
    /// evicted placement. Idempotent per rank. The identical operation
    /// crash recovery performs — evicting at a sweep boundary and
    /// continuing is bit-identical to crashing that rank and recovering
    /// from a checkpoint at the same boundary.
    pub fn evict_rank(&mut self, rank: usize) -> Result<(), SessionError> {
        assert!(rank < self.plan.dist.p, "rank {rank} out of range");
        if self.dead[rank] {
            return Ok(());
        }
        if self.survivors_after(&[rank]) == 0 {
            return Err(SessionError::NoSurvivors);
        }
        self.dead[rank] = true;
        let (migration_sim, rebuild_secs) = self.apply_eviction();
        // a planned eviction is redistribution work, not recovery:
        // charge it like a rebalance migration
        self.pending_redist_secs += migration_sim;
        self.redist_secs_total += migration_sim;
        self.pending_ingest_secs += rebuild_secs;
        Ok(())
    }

    /// Ranks drained so far (crashed or explicitly evicted).
    pub fn dead_ranks(&self) -> Vec<usize> {
        self.dead
            .iter()
            .enumerate()
            .filter_map(|(r, &d)| if d { Some(r) } else { None })
            .collect()
    }

    /// Rollback-and-retry cycles run so far (session lifetime).
    pub fn recoveries(&self) -> usize {
        self.recoveries
    }

    /// Seeded fault events that have fired so far (session lifetime).
    pub fn faults_injected(&self) -> usize {
        self.injector.as_ref().map_or(0, FaultInjector::faults_injected)
    }

    /// Capture a checkpoint of the in-flight decomposition state
    /// (`None` when no decomposition has started).
    pub fn checkpoint(&self) -> Option<SessionCheckpoint> {
        self.state.as_ref().map(|state| {
            SessionCheckpoint::from_snapshot(
                &state.snapshot(),
                self.plan.dist.p,
                &self.ks,
            )
        })
    }

    /// The last checkpoint the [`CheckpointPolicy`] captured during a
    /// decompose call (`None` before the first due boundary).
    pub fn last_checkpoint(&self) -> Option<&SessionCheckpoint> {
        self.last_checkpoint.as_ref()
    }

    /// Restore the in-flight decomposition state from a checkpoint —
    /// the resumed session continues bit-exactly (same factors, same
    /// RNG cursor), provided the placement and plans match the ones the
    /// checkpoint was captured under (same builder configuration). With
    /// no decomposition in flight, bootstraps one first.
    pub fn restore(&mut self, cp: &SessionCheckpoint) -> Result<(), SessionError> {
        if cp.p != self.plan.dist.p {
            return Err(SessionError::CheckpointMismatch(format!(
                "checkpoint world size {} vs session {}",
                cp.p, self.plan.dist.p
            )));
        }
        if cp.ks != self.ks {
            return Err(SessionError::CheckpointMismatch(format!(
                "checkpoint core ranks {:?} vs session {:?}",
                cp.ks, self.ks
            )));
        }
        for (n, f) in cp.factors.iter().enumerate() {
            let l_n = self.workload.tensor.dims[n] as usize;
            if f.rows != l_n || f.cols != self.ks[n] {
                return Err(SessionError::CheckpointMismatch(format!(
                    "mode {n} factor is {}x{}, expected {l_n}x{}",
                    f.rows, f.cols, self.ks[n]
                )));
            }
        }
        let snap = cp.to_snapshot();
        match self.state.as_mut() {
            Some(state) => state.restore(&snap),
            None => {
                let mut state = HooiState::init(
                    &self.workload.tensor,
                    self.plan.dist.p,
                    &self.core,
                    self.seed,
                    self.kernel,
                );
                state.restore(&snap);
                self.state = Some(state);
            }
        }
        self.last_snap = Some(snap);
        self.generation += 1;
        Ok(())
    }

    /// Monotone mutation counter: how many times this session's
    /// logical state has advanced (ingest, rebalance, eviction,
    /// restore, completed decompose). The provenance stamp on
    /// published snapshots — `generation() −
    /// snapshot.generation()` is the staleness of the serving view.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The snapshot published at the last completed
    /// decompose/refine, if any. Cloning the `Arc` is the whole read
    /// path — the returned view never changes, no matter what the
    /// session does next, and holding it never blocks the session.
    pub fn latest_snapshot(&self) -> Option<Arc<DecompositionSnapshot>> {
        self.snapshot.clone()
    }

    fn finish(&mut self, mut cluster: SimCluster) -> Result<Decomposition, SessionError> {
        let mut failures_in_a_row = 0usize;
        let out = loop {
            let res = {
                let state =
                    self.state.as_ref().expect("decomposition state in flight");
                state.outcome_with(
                    &self.workload.tensor,
                    &self.plan.dist,
                    &self.modes,
                    self.shared.as_ref(),
                    &mut cluster,
                    self.accounting,
                )
            };
            self.sync_injector(&cluster);
            match res {
                Ok(out) => break out,
                Err(f) => {
                    // the core phase faulted: recover exactly like a
                    // failed sweep, then replay up to the pre-outcome
                    // boundary (the final checkpoint is never at that
                    // boundary, so ≥ 1 sweep re-runs and rebuilds the
                    // final mode's locals the core phase needs)
                    failures_in_a_row += 1;
                    if failures_in_a_row >= self.retry.max_attempts {
                        return Err(SessionError::RecoveryExhausted(format!(
                            "{f} ({failures_in_a_row} consecutive failed attempts)"
                        )));
                    }
                    let target =
                        self.state.as_ref().expect("state in flight").sweep();
                    self.recover(&mut cluster, &f)?;
                    let resumed =
                        self.state.as_ref().expect("state in flight").sweep();
                    if resumed >= target {
                        return Err(SessionError::RecoveryExhausted(format!(
                            "outcome failed with no sweep to replay: {f}"
                        )));
                    }
                    self.run_to(&mut cluster, target)?;
                }
            }
        };
        let mut record =
            collect_record(&self.workload, &self.plan.dist, &self.ks, &cluster, &out);
        // rebalance + fault-tolerance provenance: session-lifetime
        // counters (the cluster bucket only sees this run's charges)
        record.rebalances = self.rebalances;
        record.rebalance_skips = self.rebalance_skips;
        record.redist_secs = self.redist_secs_total;
        record.faults_injected = self.faults_injected();
        record.recoveries = self.recoveries;
        record.recovery_secs = self.recovery_secs_total;
        record.checkpoint_secs = self.checkpoint_secs_total;
        record.checkpoint_bytes = self.checkpoint_bytes_total;
        let d = Decomposition {
            factors: out.factors,
            core: out.core,
            sigma: out.sigma,
            record,
        };
        // publish the sweep-boundary snapshot: readers holding older
        // Arc clones keep serving their generation untouched
        self.generation += 1;
        let sweep = self.state.as_ref().map(|s| s.sweep()).unwrap_or(0);
        self.snapshot = Some(Arc::new(DecompositionSnapshot::from_decomposition(
            &d,
            self.generation,
            sweep,
        )));
        Ok(d)
    }
}

/// What one [`TuckerSession::ingest`] call did — the observability
/// record of the incremental invalidation subsystem.
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Nonzeros appended.
    pub appended: usize,
    /// Values changed (removals not included).
    pub changed: usize,
    /// Nonzeros removed (kept as explicit zeros — see
    /// [`TensorDelta`](crate::tensor::TensorDelta)).
    pub removed: usize,
    /// Dirty plans updated in place (value/run splice).
    pub plans_spliced: usize,
    /// Dirty plans recompiled from their element list.
    pub plans_rebuilt: usize,
    /// Total (mode, rank) plans held by the session — the denominator
    /// for "how localized was this delta".
    pub plan_count: usize,
    /// Modes whose Theorem 6.1 sharing bounds no longer hold after
    /// placement: the signal to schedule a full (cheap, Lite)
    /// redistribution. Empty while streaming stays within bounds.
    pub rebalance_modes: Vec<usize>,
    /// Sum over modes of the splice/rebuild makespans (charged to the
    /// next run's TTM bucket, like plan compilation).
    pub rebuild_secs: f64,
    /// Under [`RebalancePolicy::Auto`], the rebalance attempt this
    /// ingest triggered (cost-model verdict included); `None` when no
    /// mode was flagged or the policy leaves the decision to the
    /// caller.
    pub rebalance: Option<RebalanceReport>,
}

impl IngestReport {
    /// Plans this ingest touched (spliced + rebuilt).
    pub fn plans_touched(&self) -> usize {
        self.plans_spliced + self.plans_rebuilt
    }
}

/// A finished (possibly still refinable) Tucker decomposition: the
/// factor matrices, the core tensor, and the consolidated
/// [`RunRecord`] (fit, timings, metrics) of the run that produced it.
#[derive(Debug, Clone)]
pub struct Decomposition {
    /// Factor matrices F_n (L_n × K_n), orthonormal columns (surplus
    /// columns are zero in the K_n > L_n degenerate regime — see
    /// [`TuckerSessionBuilder::core`]).
    pub factors: Vec<Mat>,
    /// Core tensor flattened as G_(N−1): K_{N−1} × K̂_{N−1} row-major
    /// (earliest mode fastest along the columns).
    pub core: Mat,
    /// Leading singular values of the last mode (diagnostics).
    pub sigma: Vec<f32>,
    /// Consolidated measurements of the run that produced this
    /// (`record.core` holds the per-mode core dims, `record.fit` the
    /// fit — accessors below).
    pub record: RunRecord,
}

impl Decomposition {
    /// Fit = 1 − ‖T − X‖ / ‖T‖ (X the reconstruction).
    pub fn fit(&self) -> f64 {
        self.record.fit
    }

    /// Core tensor dimensions `[K_0, …, K_{N−1}]`.
    pub fn core_dims(&self) -> &[usize] {
        &self.record.core
    }

    /// Core entry G[j_0, …, j_{N−1}] (decodes the flattened G_(N−1)
    /// layout). Panics on a wrong arity or an out-of-range index — a
    /// bad index must never silently alias another core entry.
    pub fn core_at(&self, j: &[usize]) -> f32 {
        let dims = self.core_dims();
        let n = dims.len();
        assert_eq!(j.len(), n, "core index arity");
        let mut col = 0usize;
        let mut stride = 1usize;
        for m in 0..n - 1 {
            assert!(j[m] < dims[m], "core index {} out of range for K_{m}", j[m]);
            col += j[m] * stride;
            stride *= dims[m];
        }
        assert!(j[n - 1] < dims[n - 1], "core index out of range for the last mode");
        self.core.get(j[n - 1], col)
    }

    /// Reconstruct one tensor entry:
    /// X[i] = Σ_{j} G[j] · Π_n F_n[i_n, j_n]. A point query costs
    /// O(Π K_n) — intended for spot checks and residual sampling; use
    /// [`reconstruct_batch`](Decomposition::reconstruct_batch) for
    /// query traffic. Wrong arity or an out-of-range coordinate
    /// returns a typed [`QueryError`] instead of panicking — this is
    /// the scalar oracle the batched serving engine is pinned
    /// bit-exactly against.
    pub fn reconstruct_at(&self, idx: &[usize]) -> Result<f32, QueryError> {
        crate::serve::query::reconstruct_at(&self.factors, &self.core, idx)
    }

    /// Evaluate a batch of point queries with the host-detected
    /// kernel: queries sharing a mode-(N−1) slice share one core
    /// contraction and each evaluates as a Kronecker-chain GEMV
    /// through the lane-blocked microkernels. Bit-identical to calling
    /// [`reconstruct_at`](Decomposition::reconstruct_at) per query.
    pub fn reconstruct_batch(&self, batch: &QueryBatch) -> Result<Vec<f32>, QueryError> {
        self.reconstruct_batch_with(batch, Kernel::from_env())
    }

    /// [`reconstruct_batch`](Decomposition::reconstruct_batch) under
    /// an explicit microkernel.
    pub fn reconstruct_batch_with(
        &self,
        batch: &QueryBatch,
        kernel: Kernel,
    ) -> Result<Vec<f32>, QueryError> {
        crate::serve::query::reconstruct_batch(
            &self.factors,
            &self.core,
            batch.queries(),
            kernel,
        )
    }

    /// The `k` largest reconstructed entries of the mode-`mode` slice
    /// at coordinate `index`, best first (value descending, ties by
    /// ascending index). Host-detected kernel.
    pub fn top_k_per_slice(
        &self,
        mode: usize,
        index: usize,
        k: usize,
    ) -> Result<Vec<TopEntry>, QueryError> {
        self.top_k_per_slice_with(mode, index, k, Kernel::from_env())
    }

    /// [`top_k_per_slice`](Decomposition::top_k_per_slice) under an
    /// explicit microkernel.
    pub fn top_k_per_slice_with(
        &self,
        mode: usize,
        index: usize,
        k: usize,
        kernel: Kernel,
    ) -> Result<Vec<TopEntry>, QueryError> {
        crate::serve::topk::top_k_per_slice(&self.factors, &self.core, mode, index, k, kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::datasets::by_name;

    fn tiny_workload() -> Workload {
        let spec = by_name("enron").unwrap().scaled(0.02);
        Workload::from_spec(&spec, 1.0)
    }

    #[test]
    fn builder_validates_core_and_ranks() {
        let w = tiny_workload();
        let err = TuckerSession::builder(w.clone())
            .core(CoreRanks::PerMode(vec![4, 4]))
            .build()
            .err()
            .expect("length mismatch rejected");
        assert!(matches!(err, SessionError::InvalidCore(_)), "{err}");
        let err = TuckerSession::builder(w.clone()).ranks(0).build().err().unwrap();
        assert_eq!(err, SessionError::ZeroRanks);
        let err =
            TuckerSession::builder(w).core(CoreRanks::Uniform(0)).build().err().unwrap();
        assert!(matches!(err, SessionError::InvalidCore(_)));
    }

    #[test]
    fn scheme_choice_registry_matches_sched_names() {
        for name in ["lite", "coarseg", "coarseg-bpf", "mediumg", "hyperg"] {
            assert!(SchemeChoice::by_name(name).is_some(), "{name}");
        }
        assert!(SchemeChoice::by_name("nope").is_none());
    }

    #[test]
    fn session_decomposes_and_reports() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w)
            .ranks(4)
            .core(CoreRanks::Uniform(4))
            .seed(1)
            .build()
            .unwrap();
        let d = s.decompose();
        assert!(d.fit().is_finite());
        assert_eq!(d.core_dims(), &[4, 4, 4]);
        assert_eq!(d.record.scheme, "Lite");
        assert!(d.record.hooi_secs > 0.0);
        assert_eq!(s.plan_builds(), 1);
    }

    #[test]
    fn ingest_localized_delta_touches_one_plan_per_mode() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w)
            .ranks(4)
            .core(CoreRanks::Uniform(3))
            .seed(9)
            .plan(PlanChoice::PerMode)
            .build()
            .unwrap();
        assert_eq!(s.plan_rebuilds(), 0);
        let rep = s.ingest(&TensorDelta::new().append(&[0, 0, 0], 0.5)).unwrap();
        assert_eq!(rep.plan_count, 12, "3 modes x 4 ranks");
        // one appended element dirties exactly one rank per mode
        assert_eq!(rep.plans_touched(), 3);
        assert!(rep.plans_touched() < rep.plan_count, "localized delta");
        assert_eq!(s.plan_rebuilds(), 3);
        assert_eq!(s.plan_builds(), 1, "never a full re-prepare");
        let d = s.decompose();
        assert!(d.fit().is_finite());
    }

    #[test]
    fn shared_csf_session_is_bit_identical_to_per_mode() {
        let w = tiny_workload();
        // lite: multi-policy, the tree degrades to streams; mediumg:
        // uni placement, the tree carries real views and the ingest
        // splice fast path — both must land the per-mode bits
        for scheme in ["lite", "mediumg"] {
            let mk = |choice| {
                TuckerSession::builder(w.clone())
                    .scheme(SchemeChoice::by_name(scheme).unwrap())
                    .ranks(4)
                    .core(CoreRanks::Uniform(4))
                    .invocations(2)
                    .seed(13)
                    .plan(choice)
                    .build()
                    .unwrap()
            };
            let mut a = mk(PlanChoice::PerMode);
            let mut b = mk(PlanChoice::SharedCsf);
            assert!(a.shared_plans().is_none());
            assert_eq!(b.shared_plans().unwrap().per_rank.len(), 4);
            // the shared estimate prices the reuse: never above per-mode
            assert!(
                b.placement().cost.secs_per_sweep
                    <= a.placement().cost.secs_per_sweep,
                "{scheme}"
            );
            let da = a.decompose();
            let db = b.decompose();
            for (fa, fb) in da.factors.iter().zip(&db.factors) {
                assert_eq!(fa.data, fb.data, "{scheme}");
            }
            assert_eq!(da.core.data, db.core.data, "{scheme}");
            assert_eq!(da.record.fit, db.record.fit, "{scheme}");
            // one delta through both maintenance paths: the shared
            // denominator counts trees (one per rank), not (mode, rank)
            // plans, and the next decompose stays bit-identical
            let delta =
                TensorDelta::new().append(&[0, 0, 0], 0.5).append(&[1, 1, 1], -0.25);
            let ra = a.ingest(&delta).unwrap();
            let rb = b.ingest(&delta).unwrap();
            assert_eq!(ra.plan_count, 12, "{scheme}: 3 modes x 4 ranks");
            assert_eq!(rb.plan_count, 4, "{scheme}: one tree per rank");
            assert!(rb.plans_touched() >= 1, "{scheme}");
            assert!(rb.plans_touched() <= rb.plan_count, "{scheme}");
            let da = a.decompose();
            let db = b.decompose();
            for (fa, fb) in da.factors.iter().zip(&db.factors) {
                assert_eq!(fa.data, fb.data, "{scheme}");
            }
            assert_eq!(da.core.data, db.core.data, "{scheme}");
        }
    }

    #[test]
    fn ingest_rejects_bad_deltas_atomically() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w)
            .ranks(3)
            .core(CoreRanks::Uniform(3))
            .build()
            .unwrap();
        let nnz = s.workload().tensor.nnz();
        let dim0 = s.workload().tensor.dims[0];
        // out-of-range append plus a valid one: neither applies
        let err = s
            .ingest(&TensorDelta::new().append(&[0, 0, 0], 1.0).append(&[dim0, 0, 0], 1.0))
            .unwrap_err();
        assert!(matches!(err, crate::tensor::DeltaError::CoordOutOfRange { .. }));
        assert_eq!(s.workload().tensor.nnz(), nnz, "tensor untouched");
        assert_eq!(s.plan_rebuilds(), 0);
        // the session still decomposes
        assert!(s.decompose().fit().is_finite());
        // an empty delta is a no-op
        let rep = s.ingest(&TensorDelta::new()).unwrap();
        assert_eq!(rep.plans_touched(), 0);
    }

    #[test]
    fn placement_plan_is_exposed_and_refreshed() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w)
            .ranks(4)
            .core(CoreRanks::Uniform(3))
            .seed(2)
            .build()
            .unwrap();
        assert_eq!(s.placement().scheme(), "Lite");
        assert_eq!(s.placement().p(), 4);
        let cost0 = s.placement().cost.secs_per_sweep;
        assert!(cost0 > 0.0);
        assert!(s.pending_rebalance().is_empty());
        s.ingest(&TensorDelta::new().append(&[0, 0, 0], 0.5)).unwrap();
        // the plan's metrics track the live (extended) placement
        let total: usize = s.placement().modes[0].metrics.e_counts.iter().sum();
        assert_eq!(total, s.workload().tensor.nnz());
    }

    #[test]
    fn explicit_rebalance_is_idempotent_on_an_unchanged_tensor() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w)
            .ranks(3)
            .core(CoreRanks::Uniform(3))
            .seed(5)
            .build()
            .unwrap();
        // nothing pending → every mode is re-planned; the first call may
        // migrate (the re-plan RNG differs from the build RNG) …
        let rb1 = s.rebalance();
        assert_eq!(rb1.modes, vec![0, 1, 2]);
        assert!(rb1.decision.horizon.is_none());
        // … but the re-plan is deterministic, so an immediate second
        // call reproduces the placement exactly: empty diff, no plan
        // touched — the no-op contract
        let n = s.plan_rebuilds();
        let rb2 = s.rebalance();
        assert!(!rb2.migrated, "identical re-plan must not migrate");
        assert_eq!(rb2.moved_elements, 0);
        assert_eq!(rb2.plans_spliced + rb2.plans_rebuilt, 0);
        assert_eq!(s.plan_rebuilds(), n, "empty diff ⇒ no plan rebuilds");
        assert!(s.decompose().fit().is_finite());
    }

    #[test]
    fn decompose_more_without_decompose_bootstraps() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w)
            .ranks(3)
            .core(CoreRanks::Uniform(3))
            .build()
            .unwrap();
        let d = s.decompose_more(1);
        // 1 configured invocation + 1 more
        assert!(d.fit().is_finite());
        assert_eq!(s.plan_builds(), 1);
    }

    fn ft_session(w: &Workload, faults: FaultPlan) -> TuckerSession {
        TuckerSession::builder(w.clone())
            .ranks(4)
            .core(CoreRanks::Uniform(3))
            .invocations(2)
            .seed(11)
            .fault_plan(faults)
            .build()
            .unwrap()
    }

    #[test]
    fn transient_fault_rollback_matches_fault_free_run() {
        let w = tiny_workload();
        let clean = ft_session(&w, FaultPlan::new()).decompose();
        let mut s = ft_session(&w, FaultPlan::new().transient_at(1, 2, 1));
        let d = s.try_decompose().expect("recovers");
        assert_eq!(s.faults_injected(), 1);
        assert_eq!(s.recoveries(), 1);
        assert_eq!(d.record.faults_injected, 1);
        assert_eq!(d.record.recoveries, 1);
        assert!(d.record.recovery_secs > 0.0);
        // rollback + retry is bit-identical to never faulting
        for (a, b) in clean.factors.iter().zip(&d.factors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(clean.core.data, d.core.data);
        assert_eq!(clean.record.fit, d.record.fit);
        // the Fig 11 breakdown stays sum-invariant with recovery around
        assert!(
            (d.record.ttm_secs + d.record.svd_secs + d.record.core_secs
                + d.record.comm_secs
                - d.record.hooi_secs)
                .abs()
                < 1e-9
        );
    }

    #[test]
    fn crash_recovery_matches_planned_eviction() {
        let w = tiny_workload();
        // baseline: 1 sweep, planned eviction at the boundary, 1 more
        let mut base = TuckerSession::builder(w.clone())
            .ranks(4)
            .core(CoreRanks::Uniform(3))
            .invocations(1)
            .seed(11)
            .build()
            .unwrap();
        base.decompose();
        base.evict_rank(2).expect("3 survivors");
        let want = base.decompose_more(1);
        // faulted: rank 2 crashes mid-sweep-1; the due boundary-1
        // checkpoint is the rollback point, so recovery re-places and
        // replays exactly the sweep the baseline ran post-eviction
        let mut s = ft_session(&w, FaultPlan::new().crash_at(1, 0, 2));
        let got = s.try_decompose().expect("recovers");
        assert_eq!(s.dead_ranks(), vec![2]);
        assert_eq!(s.recoveries(), 1);
        assert!(s.placement().scheme().ends_with("+evict"));
        for (a, b) in want.factors.iter().zip(&got.factors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(want.core.data, got.core.data);
        assert_eq!(want.record.fit, got.record.fit);
        // the dead rank owns nothing under any mode's policy
        for pol in &s.placement().dist.policies {
            assert!(pol.assign.iter().all(|&r| r != 2));
        }
    }

    #[test]
    fn retry_exhaustion_and_survivor_loss_surface_typed_errors() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w.clone())
            .ranks(3)
            .core(CoreRanks::Uniform(3))
            .seed(3)
            .fault_plan(FaultPlan::new().transient_at(0, 0, 1))
            .retry_policy(RetryPolicy { max_attempts: 1, straggler_timeout: None })
            .build()
            .unwrap();
        assert!(matches!(
            s.try_decompose(),
            Err(SessionError::RecoveryExhausted(_))
        ));
        // losing every rank at once leaves no survivor to re-place onto
        let mut s2 = TuckerSession::builder(w.clone())
            .ranks(2)
            .core(CoreRanks::Uniform(3))
            .seed(3)
            .fault_plan(FaultPlan::new().crash_at(0, 0, 0).crash_at(0, 0, 1))
            .build()
            .unwrap();
        assert!(matches!(s2.try_decompose(), Err(SessionError::NoSurvivors)));
        // planned eviction refuses to drain the last rank
        let mut s3 = TuckerSession::builder(w)
            .ranks(1)
            .core(CoreRanks::Uniform(3))
            .build()
            .unwrap();
        assert!(matches!(s3.evict_rank(0), Err(SessionError::NoSurvivors)));
    }

    #[test]
    fn serialized_checkpoint_restores_into_a_fresh_session() {
        let w = tiny_workload();
        let mk = || {
            TuckerSession::builder(w.clone())
                .ranks(3)
                .core(CoreRanks::Uniform(3))
                .invocations(2)
                .seed(7)
                .build()
                .unwrap()
        };
        let mut s1 = mk();
        s1.decompose();
        let cp = s1.checkpoint().expect("state in flight");
        let want = s1.decompose_more(1);
        // ship the checkpoint over the wire into an identically
        // configured fresh session: the resumed sweep is bit-identical
        let mut s2 = mk();
        let wire = SessionCheckpoint::parse(&cp.serialize()).unwrap();
        s2.restore(&wire).unwrap();
        let got = s2.decompose_more(1);
        for (a, b) in want.factors.iter().zip(&got.factors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(want.core.data, got.core.data);
        // a mismatched configuration is rejected, session untouched
        let mut s3 = TuckerSession::builder(w.clone())
            .ranks(4)
            .core(CoreRanks::Uniform(3))
            .build()
            .unwrap();
        assert!(matches!(
            s3.restore(&wire),
            Err(SessionError::CheckpointMismatch(_))
        ));
    }

    #[test]
    fn checkpoint_policy_gates_boundary_captures() {
        let w = tiny_workload();
        let mut s = TuckerSession::builder(w.clone())
            .ranks(3)
            .core(CoreRanks::Uniform(3))
            .invocations(3)
            .checkpoint_policy(CheckpointPolicy::EverySweeps(2))
            .build()
            .unwrap();
        let d = s.decompose();
        // boundary 2 is due (and not final); boundary 3 is excluded
        let cp = s.last_checkpoint().expect("boundary 2 captured");
        assert_eq!(cp.sweep, 2);
        assert!(d.record.checkpoint_bytes > 0);
        let mut s2 = TuckerSession::builder(w)
            .ranks(3)
            .core(CoreRanks::Uniform(3))
            .invocations(3)
            .checkpoint_policy(CheckpointPolicy::Never)
            .build()
            .unwrap();
        let d2 = s2.decompose();
        assert!(s2.last_checkpoint().is_none());
        assert_eq!(d2.record.checkpoint_bytes, 0);
        assert_eq!(d2.record.checkpoint_secs, 0.0);
    }

    #[test]
    fn straggler_slows_without_failing_unless_timed_out() {
        let w = tiny_workload();
        let faults = || FaultPlan::new().straggler_at(0, 0, 1, 1000.0);
        // no timeout configured: the fault fires, nothing fails
        let mut s = ft_session(&w, faults());
        let d = s.try_decompose().expect("no failure");
        assert_eq!(s.faults_injected(), 1);
        assert_eq!(s.recoveries(), 0);
        // a tight timeout escalates the same straggler to a failure;
        // rollback + retry still lands the fault-free bits
        let clean = ft_session(&w, FaultPlan::new()).decompose();
        let mut s2 = TuckerSession::builder(w)
            .ranks(4)
            .core(CoreRanks::Uniform(3))
            .invocations(2)
            .seed(11)
            .fault_plan(faults())
            .retry_policy(RetryPolicy {
                max_attempts: 3,
                straggler_timeout: Some(1e-12),
            })
            .build()
            .unwrap();
        let d2 = s2.try_decompose().expect("recovers");
        assert_eq!(s2.recoveries(), 1);
        for (a, b) in clean.factors.iter().zip(&d2.factors) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(clean.core.data, d2.core.data);
        assert_eq!(clean.record.fit, d.record.fit);
    }
}
