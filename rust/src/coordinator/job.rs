//! Job specification: everything a run needs, assembled from config file +
//! CLI overrides (util::config / util::args). The config system is the
//! paper's "experimental setup" made explicit and reproducible.

use crate::dist::NetModel;
use crate::hooi::CoreRanks;
use crate::util::args::Args;
use crate::util::config::Config;

#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Dataset name (tensor::datasets) or a path to a FROSTT tensor file.
    pub dataset: String,
    /// Dataset scale multiplier (synthetic analogues only).
    pub scale: f64,
    /// Scheme name (sched::by_name).
    pub scheme: String,
    /// Simulated MPI world size.
    pub p: usize,
    /// Core length K (uniform, as in the paper). Overridden by `core`
    /// when per-mode ranks are given.
    pub k: usize,
    /// Per-mode core ranks (`--core K0,K1,K2` / `core = K0,K1,K2`);
    /// `None` means uniform `k`.
    pub core: Option<Vec<usize>>,
    /// HOOI invocations.
    pub invocations: usize,
    /// Engine: "pjrt" or "native".
    pub engine: String,
    pub seed: u64,
    pub net: NetModel,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            dataset: "enron".into(),
            scale: 1.0,
            scheme: "lite".into(),
            p: 64,
            k: 10,
            core: None,
            invocations: 1,
            // Default to the native engine for *timing* runs: on the CPU
            // PJRT client a dispatch costs ~ms, which swamps the
            // microsecond-scale per-rank work of the scaled-down
            // simulation and would hide the schemes' FLOP differences
            // (EXPERIMENTS.md §Perf quantifies this). The pjrt path is
            // validated end-to-end by examples/e2e_decompose.rs and the
            // roundtrip tests; opt in with --engine pjrt.
            engine: "native".into(),
            seed: 0xBEEF,
            net: NetModel::default(),
        }
    }
}

impl JobSpec {
    /// Layer config file under CLI args (args win). Errs on a malformed
    /// `core` list (an invalid override must never silently change
    /// results — callers decide whether that exits the process).
    pub fn from_sources(config: Option<&Config>, args: &Args) -> Result<JobSpec, String> {
        let mut j = JobSpec::default();
        if let Some(c) = config {
            j.dataset = c.get("dataset").unwrap_or(&j.dataset).to_string();
            j.scheme = c.get("scheme").unwrap_or(&j.scheme).to_string();
            j.engine = c.get("engine").unwrap_or(&j.engine).to_string();
            j.scale = c.parse_or("scale", j.scale);
            j.p = c.parse_or("p", j.p);
            j.k = c.parse_or("k", j.k);
            if let Some(core) = c.get("core") {
                j.core = Some(parse_core_list(core).ok_or_else(|| {
                    format!(
                        "config `core = {core}` is not a comma-separated rank \
                         list, e.g. core = 10,10,4"
                    )
                })?);
            }
            j.invocations = c.parse_or("invocations", j.invocations);
            j.seed = c.parse_or("seed", j.seed);
            j.net.alpha = c.parse_or("net.alpha", j.net.alpha);
            j.net.beta = c.parse_or("net.beta", j.net.beta);
        }
        j.dataset = args.str_or("dataset", &j.dataset).to_string();
        j.scheme = args.str_or("scheme", &j.scheme).to_string();
        j.engine = args.str_or("engine", &j.engine).to_string();
        j.scale = args.parse_or("scale", j.scale);
        j.p = args.parse_or("p", j.p);
        j.k = args.parse_or("k", j.k);
        if let Some(core) = args.get("core") {
            j.core = Some(parse_core_list(core).ok_or_else(|| {
                format!(
                    "--core expects a comma-separated rank list, e.g. 10,10,4, \
                     got {core:?}"
                )
            })?);
        }
        j.invocations = args.parse_or("invocations", j.invocations);
        j.seed = args.parse_or("seed", j.seed);
        j.net.alpha = args.parse_or("alpha", j.net.alpha);
        j.net.beta = args.parse_or("beta", j.net.beta);
        Ok(j)
    }

    /// The typed core choice this job asks for: per-mode ranks when
    /// `--core`/`core =` was given, otherwise uniform `k`.
    pub fn core_ranks(&self) -> CoreRanks {
        match &self.core {
            Some(v) => CoreRanks::PerMode(v.clone()),
            None => CoreRanks::Uniform(self.k),
        }
    }
}

/// Parse `"10,10,4"`. Strict: every comma-separated segment must be a
/// number — empty segments (`"10,,4"`) and stray commas are rejected,
/// not skipped. (A single value is a 1-element list and therefore a
/// length-mismatch error later — use `k` for uniform cores.)
fn parse_core_list(s: &str) -> Option<Vec<usize>> {
    let parts: Vec<&str> = s.split(',').map(str::trim).collect();
    if parts.is_empty() || parts.iter().any(|p| p.is_empty()) {
        return None;
    }
    parts.iter().map(|p| p.parse().ok()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_override_config() {
        let cfg = Config::parse("p = 32\nscheme = coarseg\nk = 20").unwrap();
        let argv: Vec<String> =
            ["--p", "128"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv);
        let j = JobSpec::from_sources(Some(&cfg), &args).unwrap();
        assert_eq!(j.p, 128); // CLI wins
        assert_eq!(j.scheme, "coarseg"); // config survives
        assert_eq!(j.k, 20);
    }

    #[test]
    fn defaults_without_sources() {
        let args = Args::parse(&[]);
        let j = JobSpec::from_sources(None, &args).unwrap();
        assert_eq!(j.k, 10);
        assert_eq!(j.scheme, "lite");
    }

    #[test]
    fn net_model_knobs() {
        let cfg = Config::parse("net.alpha = 5e-6\nnet.beta = 2e-9").unwrap();
        let j = JobSpec::from_sources(Some(&cfg), &Args::parse(&[])).unwrap();
        assert!((j.net.alpha - 5e-6).abs() < 1e-18);
        assert!((j.net.beta - 2e-9).abs() < 1e-18);
    }

    #[test]
    fn per_mode_core_parses_from_cli_and_config() {
        let argv: Vec<String> =
            ["--core", "10,10,4"].iter().map(|s| s.to_string()).collect();
        let j = JobSpec::from_sources(None, &Args::parse(&argv)).unwrap();
        assert_eq!(j.core, Some(vec![10, 10, 4]));
        assert_eq!(j.core_ranks(), CoreRanks::PerMode(vec![10, 10, 4]));

        let cfg = Config::parse("core = 3, 4, 5").unwrap();
        let j = JobSpec::from_sources(Some(&cfg), &Args::parse(&[])).unwrap();
        assert_eq!(j.core, Some(vec![3, 4, 5]));

        // no core option: uniform k
        let j = JobSpec::from_sources(None, &Args::parse(&[])).unwrap();
        assert_eq!(j.core_ranks(), CoreRanks::Uniform(10));

        assert_eq!(parse_core_list("bad,list"), None);
        assert_eq!(parse_core_list("10,,4"), None, "typos are rejected, not skipped");
        assert_eq!(parse_core_list(""), None);

        // invalid values surface as errors, not process exits
        let cfg = Config::parse("core = garbage").unwrap();
        assert!(JobSpec::from_sources(Some(&cfg), &Args::parse(&[])).is_err());
        let argv: Vec<String> =
            ["--core", "10,,4"].iter().map(|s| s.to_string()).collect();
        assert!(JobSpec::from_sources(None, &Args::parse(&argv)).is_err());
    }
}
