//! Job specification: everything a run needs, assembled from config file +
//! CLI overrides (util::config / util::args). The config system is the
//! paper's "experimental setup" made explicit and reproducible.

use crate::dist::NetModel;
use crate::util::args::Args;
use crate::util::config::Config;

#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Dataset name (tensor::datasets) or a path to a FROSTT .tns file.
    pub dataset: String,
    /// Dataset scale multiplier (synthetic analogues only).
    pub scale: f64,
    /// Scheme name (sched::by_name).
    pub scheme: String,
    /// Simulated MPI world size.
    pub p: usize,
    /// Core length K (uniform, as in the paper).
    pub k: usize,
    /// HOOI invocations.
    pub invocations: usize,
    /// Engine: "pjrt" or "native".
    pub engine: String,
    pub seed: u64,
    pub net: NetModel,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            dataset: "enron".into(),
            scale: 1.0,
            scheme: "lite".into(),
            p: 64,
            k: 10,
            invocations: 1,
            // Default to the native engine for *timing* runs: on the CPU
            // PJRT client a dispatch costs ~ms, which swamps the
            // microsecond-scale per-rank work of the scaled-down
            // simulation and would hide the schemes' FLOP differences
            // (EXPERIMENTS.md §Perf quantifies this). The pjrt path is
            // validated end-to-end by examples/e2e_decompose.rs and the
            // roundtrip tests; opt in with --engine pjrt.
            engine: "native".into(),
            seed: 0xBEEF,
            net: NetModel::default(),
        }
    }
}

impl JobSpec {
    /// Layer config file under CLI args (args win).
    pub fn from_sources(config: Option<&Config>, args: &Args) -> JobSpec {
        let mut j = JobSpec::default();
        if let Some(c) = config {
            j.dataset = c.get("dataset").unwrap_or(&j.dataset).to_string();
            j.scheme = c.get("scheme").unwrap_or(&j.scheme).to_string();
            j.engine = c.get("engine").unwrap_or(&j.engine).to_string();
            j.scale = c.parse_or("scale", j.scale);
            j.p = c.parse_or("p", j.p);
            j.k = c.parse_or("k", j.k);
            j.invocations = c.parse_or("invocations", j.invocations);
            j.seed = c.parse_or("seed", j.seed);
            j.net.alpha = c.parse_or("net.alpha", j.net.alpha);
            j.net.beta = c.parse_or("net.beta", j.net.beta);
        }
        j.dataset = args.str_or("dataset", &j.dataset).to_string();
        j.scheme = args.str_or("scheme", &j.scheme).to_string();
        j.engine = args.str_or("engine", &j.engine).to_string();
        j.scale = args.parse_or("scale", j.scale);
        j.p = args.parse_or("p", j.p);
        j.k = args.parse_or("k", j.k);
        j.invocations = args.parse_or("invocations", j.invocations);
        j.seed = args.parse_or("seed", j.seed);
        j.net.alpha = args.parse_or("alpha", j.net.alpha);
        j.net.beta = args.parse_or("beta", j.net.beta);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_override_config() {
        let cfg = Config::parse("p = 32\nscheme = coarseg\nk = 20").unwrap();
        let argv: Vec<String> =
            ["--p", "128"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv);
        let j = JobSpec::from_sources(Some(&cfg), &args);
        assert_eq!(j.p, 128); // CLI wins
        assert_eq!(j.scheme, "coarseg"); // config survives
        assert_eq!(j.k, 20);
    }

    #[test]
    fn defaults_without_sources() {
        let args = Args::parse(&[]);
        let j = JobSpec::from_sources(None, &args);
        assert_eq!(j.k, 10);
        assert_eq!(j.scheme, "lite");
    }

    #[test]
    fn net_model_knobs() {
        let cfg = Config::parse("net.alpha = 5e-6\nnet.beta = 2e-9").unwrap();
        let j = JobSpec::from_sources(Some(&cfg), &Args::parse(&[]));
        assert!((j.net.alpha - 5e-6).abs() < 1e-18);
        assert!((j.net.beta - 2e-9).abs() < 1e-18);
    }
}
