//! Sweep-boundary checkpoints for fault-tolerant HOOI sessions.
//!
//! A [`SessionCheckpoint`] captures everything a [`crate::hooi::HooiState`]
//! needs to resume bit-exactly: the sweep counter, every factor matrix,
//! the last Lanczos sigma vector, and the RNG cursor. Serialization uses
//! the in-tree [`crate::util::json`] writer; `f32` payloads round-trip
//! through `to_bits`/`from_bits` so NaN payloads and signed zeros survive
//! unchanged, and the four `u64` RNG words travel as hex strings because
//! an `f64` mantissa cannot hold them exactly.
#![warn(clippy::unwrap_used)]

use crate::hooi::HooiSnapshot;
use crate::linalg::Mat;
use crate::util::json::Json;
use crate::util::float::is_integral_f64;

/// When a [`crate::coordinator::TuckerSession`] snapshots its HOOI state.
///
/// Checkpoints are only ever taken at sweep boundaries (the paper's Fig 2
/// loop has no cheaper consistent cut), and never after the *final* sweep
/// of a `decompose` call: a failure in the trailing core phase must roll
/// back far enough to re-run at least one sweep, because the per-rank TTM
/// locals the core computation consumes are rebuilt by sweeps, not stored
/// in the checkpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckpointPolicy {
    /// Only the bootstrap snapshot (taken before sweep 0) is kept; any
    /// recovery restarts the whole invocation.
    Never,
    /// Snapshot after every `k`-th completed sweep (`k >= 1`).
    EverySweeps(usize),
}

impl CheckpointPolicy {
    /// Should a checkpoint be taken after `done` sweeps have completed?
    /// `done` counts completed sweeps, so it is never 0 here; the caller
    /// additionally skips `done == total` (see type-level docs).
    pub fn due(&self, done: usize) -> bool {
        match *self {
            CheckpointPolicy::Never => false,
            CheckpointPolicy::EverySweeps(k) => k != 0 && done % k == 0,
        }
    }
}

impl Default for CheckpointPolicy {
    fn default() -> Self {
        CheckpointPolicy::EverySweeps(1)
    }
}

/// Bounds on how hard a session tries to survive injected or organic
/// failures before giving up and surfacing the error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts per recovery scope (first try included). 1 means
    /// "no retries"; the default 3 tolerates a crash plus a transient.
    pub max_attempts: usize,
    /// Simulated-seconds budget per phase before a straggling rank is
    /// escalated to a failure (`None` disables straggler detection).
    pub straggler_timeout: Option<f64>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, straggler_timeout: None }
    }
}

/// A serializable snapshot of a session's HOOI state at a sweep boundary.
///
/// The factor/sigma payloads are bit-exact copies, so `restore` followed
/// by re-running the remaining sweeps reproduces the uninterrupted run to
/// the last ULP (pinned by `tests/fault_tolerance.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionCheckpoint {
    /// Format version for forward compatibility (currently 1).
    pub version: u32,
    /// Completed sweeps at capture time.
    pub sweep: usize,
    /// Cluster size the checkpoint was taken under (validation only —
    /// recovery may resume on fewer live ranks than `p`).
    pub p: usize,
    /// Per-mode core ranks (validation).
    pub ks: Vec<usize>,
    /// Factor matrices, one per mode.
    pub factors: Vec<Mat>,
    /// Last Lanczos singular values (empty before the first sweep).
    pub sigma: Vec<f32>,
    /// xoshiro256** cursor of the driver RNG.
    pub rng_state: [u64; 4],
}

impl SessionCheckpoint {
    /// Wrap a driver snapshot with session context.
    pub fn from_snapshot(snap: &HooiSnapshot, p: usize, ks: &[usize]) -> Self {
        SessionCheckpoint {
            version: 1,
            sweep: snap.sweep,
            p,
            ks: ks.to_vec(),
            factors: snap.factors.clone(),
            sigma: snap.last_sigma.clone(),
            rng_state: snap.rng_state,
        }
    }

    /// Back to the driver-level snapshot `HooiState::restore` consumes.
    pub fn to_snapshot(&self) -> HooiSnapshot {
        HooiSnapshot {
            sweep: self.sweep,
            factors: self.factors.clone(),
            rng_state: self.rng_state,
            last_sigma: self.sigma.clone(),
        }
    }

    /// Serialized size in bytes (what `RunRecord::checkpoint_bytes` sums).
    pub fn bytes(&self) -> usize {
        self.serialize().len()
    }

    /// Render to the tiny in-tree JSON dialect. Stable across runs: the
    /// object writer sorts keys (BTreeMap) and floats travel as bits.
    pub fn serialize(&self) -> String {
        let mut j = Json::obj();
        j.set("version", Json::Num(self.version as f64))
            .set("sweep", Json::Num(self.sweep as f64))
            .set("p", Json::Num(self.p as f64))
            .set(
                "ks",
                Json::Arr(self.ks.iter().map(|&k| Json::Num(k as f64)).collect()),
            )
            .set("sigma", bits_arr(&self.sigma))
            .set(
                "rng",
                Json::Arr(
                    self.rng_state
                        .iter()
                        .map(|w| Json::Str(format!("{w:016x}")))
                        .collect(),
                ),
            )
            .set(
                "factors",
                Json::Arr(
                    self.factors
                        .iter()
                        .map(|m| {
                            let mut f = Json::obj();
                            f.set("rows", Json::Num(m.rows as f64))
                                .set("cols", Json::Num(m.cols as f64))
                                .set("data", bits_arr(&m.data));
                            f
                        })
                        .collect(),
                ),
            );
        j.render()
    }

    /// Parse a serialized checkpoint. Errors are human-readable strings
    /// (this is an operator-facing recovery path, not a hot loop).
    pub fn parse(text: &str) -> Result<SessionCheckpoint, String> {
        let j = Json::parse(text)?;
        let version = get_usize(&j, "version")? as u32;
        if version != 1 {
            return Err(format!("unsupported checkpoint version {version}"));
        }
        let sweep = get_usize(&j, "sweep")?;
        let p = get_usize(&j, "p")?;
        let ks = match j.get("ks") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|v| v as usize)
                        .ok_or_else(|| "non-numeric entry in 'ks'".to_string())
                })
                .collect::<Result<Vec<_>, _>>()?,
            _ => return Err("missing array field 'ks'".into()),
        };
        let sigma = parse_bits_arr(j.get("sigma").ok_or("missing field 'sigma'")?)?;
        let rng_words = match j.get("rng") {
            Some(Json::Arr(xs)) if xs.len() == 4 => xs,
            _ => return Err("field 'rng' must be an array of 4 hex words".into()),
        };
        let mut rng_state = [0u64; 4];
        for (slot, w) in rng_state.iter_mut().zip(rng_words.iter()) {
            let s = w.as_str().ok_or("non-string entry in 'rng'")?;
            *slot = u64::from_str_radix(s, 16)
                .map_err(|e| format!("bad rng word {s:?}: {e}"))?;
        }
        let factors = match j.get("factors") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|f| {
                    let rows = get_usize(f, "rows")?;
                    let cols = get_usize(f, "cols")?;
                    let data =
                        parse_bits_arr(f.get("data").ok_or("factor missing 'data'")?)?;
                    if data.len() != rows * cols {
                        return Err(format!(
                            "factor data length {} != {rows}x{cols}",
                            data.len()
                        ));
                    }
                    Ok(Mat { rows, cols, data })
                })
                .collect::<Result<Vec<_>, String>>()?,
            _ => return Err("missing array field 'factors'".into()),
        };
        Ok(SessionCheckpoint { version, sweep, p, ks, factors, sigma, rng_state })
    }
}

/// f32 slice → JSON array of bit patterns. A u32 fits an f64 mantissa
/// exactly, so `Num(bits as f64)` is lossless and renders as an integer.
/// Shared with `serve::snapshot`, which serializes under the same
/// bit-exact discipline.
pub(crate) fn bits_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(x.to_bits() as f64)).collect())
}

pub(crate) fn parse_bits_arr(j: &Json) -> Result<Vec<f32>, String> {
    match j {
        Json::Arr(xs) => xs
            .iter()
            .map(|x| {
                let v = x.as_f64().ok_or("non-numeric bit pattern")?;
                if v < 0.0 || v > u32::MAX as f64 || !is_integral_f64(v) {
                    return Err(format!("value {v} is not a valid f32 bit pattern"));
                }
                Ok(f32::from_bits(v as u32))
            })
            .collect(),
        _ => Err("expected a bit-pattern array".into()),
    }
}

pub(crate) fn get_usize(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .map(|v| v as usize)
        .ok_or_else(|| format!("missing numeric field '{key}'"))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn sample() -> SessionCheckpoint {
        SessionCheckpoint {
            version: 1,
            sweep: 3,
            p: 8,
            ks: vec![4, 3, 2],
            factors: vec![
                Mat { rows: 2, cols: 2, data: vec![1.0, -0.0, f32::MIN_POSITIVE, 2.5] },
                Mat { rows: 1, cols: 3, data: vec![0.1, 1e-30, -7.25] },
            ],
            sigma: vec![3.25, 1.125, 0.5],
            rng_state: [u64::MAX, 0, 0xDEAD_BEEF_CAFE_F00D, 42],
        }
    }

    #[test]
    fn serialize_parse_roundtrip_is_bit_exact() {
        let cp = sample();
        let text = cp.serialize();
        let back = SessionCheckpoint::parse(&text).unwrap();
        assert_eq!(back, cp);
        // signed zero survives (PartialEq on f32 treats -0.0 == 0.0,
        // so check the bits explicitly)
        assert_eq!(back.factors[0].data[1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn bytes_matches_serialized_length() {
        let cp = sample();
        assert_eq!(cp.bytes(), cp.serialize().len());
        assert!(cp.bytes() > 0);
    }

    #[test]
    fn snapshot_conversion_roundtrips() {
        let cp = sample();
        let snap = cp.to_snapshot();
        let back = SessionCheckpoint::from_snapshot(&snap, cp.p, &cp.ks);
        assert_eq!(back, cp);
    }

    #[test]
    fn parse_rejects_malformed_input() {
        assert!(SessionCheckpoint::parse("not json").is_err());
        assert!(SessionCheckpoint::parse("{}").is_err());
        // wrong version
        let mut cp = sample();
        cp.version = 9;
        assert!(SessionCheckpoint::parse(&cp.serialize()).is_err());
        // truncated factor data
        let text = sample().serialize().replace("\"rows\": 2", "\"rows\": 3");
        assert!(SessionCheckpoint::parse(&text).is_err());
    }

    #[test]
    fn policy_due_matches_interval() {
        assert!(!CheckpointPolicy::Never.due(1));
        assert!(!CheckpointPolicy::Never.due(4));
        let every2 = CheckpointPolicy::EverySweeps(2);
        assert!(!every2.due(1));
        assert!(every2.due(2));
        assert!(!every2.due(3));
        assert!(every2.due(4));
        // degenerate k=0 never fires rather than dividing by zero
        assert!(!CheckpointPolicy::EverySweeps(0).due(3));
        assert_eq!(CheckpointPolicy::default(), CheckpointPolicy::EverySweeps(1));
    }

    #[test]
    fn retry_policy_default_is_three_attempts() {
        let rp = RetryPolicy::default();
        assert_eq!(rp.max_attempts, 3);
        assert!(rp.straggler_timeout.is_none());
    }
}
