//! The pipeline leader: dataset → distribution scheme → simulated cluster
//! → HOOI → consolidated run record.
//!
//! The typed front door is [`super::session::TuckerSession`]; the
//! free functions here ([`run_scheme`], [`run_distribution`]) are kept as
//! thin shims over the same machinery so the paper-figure harness and
//! pre-session callers stay reproducible. Prefer the session for new
//! code — it validates its inputs, replaces the `TUCKER_*` env knobs
//! with typed options, and retains the compiled TTM plans across
//! repeated decompositions.
//!
//! The cluster's parallel rank executor is on by default (per-rank TTM
//! plans assemble concurrently; see `dist::cluster`); set
//! `TUCKER_PHASE_EXECUTOR=serial` (or `.executor(ExecutorChoice::Serial)`
//! on the session builder) for the reference serial executor when a
//! figure run needs minimal timing noise on a loaded host.

use super::job::JobSpec;
use crate::dist::{cat, NetModel, SimCluster};
use crate::hooi::{run_hooi, CoreRanks, HooiConfig, HooiOutcome};
use crate::runtime::Engine;
use crate::sched::{Distribution, Scheme, SchemeMetrics};
use crate::tensor::datasets::DatasetSpec;
use crate::tensor::io::TensorIoError;
use crate::tensor::slices::build_all;
use crate::tensor::{io, SliceIndex, SparseTensor};
use crate::util::rng::Rng;

/// A loaded workload: tensor + its per-mode slice indices.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub tensor: SparseTensor,
    pub idx: Vec<SliceIndex>,
}

/// Why a [`JobSpec`] dataset could not be turned into a [`Workload`].
#[derive(Debug)]
pub enum WorkloadError {
    /// Not a known synthetic analogue and not an existing file.
    UnknownDataset { name: String },
    /// The dataset named a path the OS could not read (missing file,
    /// permissions, a read failing mid-stream).
    Io { path: std::path::PathBuf, source: std::io::Error },
    /// The dataset file was readable but is not a FROSTT tensor — the
    /// typed [`TensorIoError::Parse`] detail carries the line number.
    Tensor { path: std::path::PathBuf, source: TensorIoError },
}

impl std::fmt::Display for WorkloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkloadError::UnknownDataset { name } => write!(
                f,
                "unknown dataset {name:?} (expected one of the Fig 9 names or a \
                 path to a FROSTT tensor file)"
            ),
            WorkloadError::Io { path, source } => {
                write!(f, "{}: {source}", path.display())
            }
            WorkloadError::Tensor { path, source } => {
                write!(f, "{}: {source}", path.display())
            }
        }
    }
}

impl std::error::Error for WorkloadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WorkloadError::UnknownDataset { .. } => None,
            WorkloadError::Io { source, .. } => Some(source),
            WorkloadError::Tensor { source, .. } => Some(source),
        }
    }
}

impl Workload {
    pub fn from_spec(spec: &DatasetSpec, scale: f64) -> Workload {
        let spec = if (scale - 1.0).abs() > 1e-9 { spec.scaled(scale) } else { spec.clone() };
        let tensor = spec.generate();
        let idx = build_all(&tensor);
        Workload { name: spec.name.to_string(), tensor, idx }
    }

    /// Load a FROSTT file with typed errors ([`TensorIoError`] keeps
    /// "file missing" and "file malformed" apart).
    pub fn load_tns(path: &std::path::Path) -> Result<Workload, TensorIoError> {
        let tensor = io::load_tns(path)?;
        let idx = build_all(&tensor);
        Ok(Workload {
            name: path.file_stem().unwrap_or_default().to_string_lossy().into(),
            tensor,
            idx,
        })
    }

    /// [`Workload::load_tns`] degraded to `std::io::Result` —
    /// compatibility shim for callers that predate [`TensorIoError`].
    pub fn from_tns(path: &std::path::Path) -> std::io::Result<Workload> {
        Self::load_tns(path).map_err(TensorIoError::into_io)
    }

    /// Build a workload from an in-memory tensor (slice indices built
    /// here) — the entry point for programmatic/streaming callers.
    pub fn from_tensor(name: impl Into<String>, tensor: SparseTensor) -> Workload {
        let idx = build_all(&tensor);
        Workload { name: name.into(), tensor, idx }
    }

    /// Resolve a JobSpec dataset: a known synthetic name, or any path to
    /// an existing FROSTT-format tensor file (the extension does not
    /// matter; a `.tns` suffix is also accepted for not-yet-existing
    /// paths so the error names the file instead of "unknown dataset").
    pub fn resolve(job: &JobSpec) -> Result<Workload, WorkloadError> {
        if let Some(spec) = crate::tensor::datasets::by_name(&job.dataset) {
            return Ok(Workload::from_spec(&spec, job.scale));
        }
        let path = std::path::Path::new(&job.dataset);
        if path.is_file() || job.dataset.ends_with(".tns") {
            Workload::load_tns(path).map_err(|source| match source {
                TensorIoError::Io(source) => {
                    WorkloadError::Io { path: path.to_path_buf(), source }
                }
                parse => WorkloadError::Tensor {
                    path: path.to_path_buf(),
                    source: parse,
                },
            })
        } else {
            Err(WorkloadError::UnknownDataset { name: job.dataset.clone() })
        }
    }
}

/// Consolidated measurements of one (workload, scheme, P, core) run.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub workload: String,
    pub scheme: String,
    pub p: usize,
    /// Largest core rank max_n K_n (equals K for uniform cores — the
    /// paper's configuration and what the figure tables print).
    pub k: usize,
    /// Per-mode core ranks `[K_0, …, K_{N−1}]`.
    pub core: Vec<usize>,
    /// Simulated HOOI execution time (single/multiple invocations as run).
    pub hooi_secs: f64,
    /// Breakup (Fig 11): TTM compute, SVD compute, end-of-run core
    /// computation, total communication. These four sum to `hooi_secs`.
    pub ttm_secs: f64,
    pub svd_secs: f64,
    pub core_secs: f64,
    pub comm_secs: f64,
    /// Distribution time (Fig 16): simulated parallel construction.
    /// For streaming sessions this grows when a rebalance lands (the
    /// re-plan + migration are redistribution work — Fig 16's column).
    pub dist_secs: f64,
    /// Streaming rebalance provenance (sessions only; zero on the
    /// legacy paths): migrations applied over the session's lifetime,
    /// cost-model decisions that declined to migrate, and the
    /// cumulative simulated redistribution seconds (Lite re-plan +
    /// element migration under the α–β model).
    pub rebalances: usize,
    pub rebalance_skips: usize,
    pub redist_secs: f64,
    /// Fault-tolerance provenance. `faults_injected` counts the seeded
    /// [`FaultPlan`](crate::dist::FaultPlan) events that actually fired;
    /// `recoveries` the rollback-and-retry cycles the session ran;
    /// `recovery_secs` the simulated `cat::RECOVER` bucket (survivor
    /// re-placement + migration + re-run of rolled-back sweeps) — like
    /// `redist_secs`, reported alongside `hooi_secs`, not inside it, so
    /// the Fig 11 breakdown stays sum-invariant. `checkpoint_secs` /
    /// `checkpoint_bytes` price the sweep-boundary snapshots.
    pub faults_injected: usize,
    pub recoveries: usize,
    pub recovery_secs: f64,
    pub checkpoint_secs: f64,
    pub checkpoint_bytes: u64,
    /// Communication volumes in units (Fig 13).
    pub svd_volume: f64,
    pub fm_volume: f64,
    /// §4 metrics aggregates (Fig 12).
    pub ttm_balance: f64,
    pub svd_load_norm: f64,
    pub svd_balance: f64,
    /// Fig 17 memory (avg MB/rank + breakdown).
    pub mem_mb: f64,
    pub mem_breakdown_mb: (f64, f64, f64),
    pub fit: f64,
    /// Per-phase concurrency provenance (how the TTM numbers were
    /// produced): rank executor (`parallel`/`serial`), its worker
    /// count, the microkernel the ranks ran, and the measured executor
    /// speedup (Σ busy / wall) — recorded so figure CSVs carry their
    /// own execution conditions.
    pub executor: String,
    pub workers: usize,
    pub kernel: String,
    pub ttm_speedup: f64,
    /// Which communication transport carried the collectives: `"sim"`
    /// (analytic α–β charging, the historical behavior) or `"channel"`
    /// (real framed bytes over in-process channels).
    pub transport: String,
    /// Predicted-vs-measured `NetModel` error per communication
    /// category: signed relative seconds error
    /// `(measured − predicted) / predicted`. Exactly `0.0` under the
    /// sim transport (measured is defined as the prediction); under the
    /// channel transport this is the empirical check on the §4 cost
    /// model that drives `RebalancePolicy::Auto` — a large positive
    /// error means the α–β model is underpricing that category's
    /// traffic on this host.
    pub net_model_error: Vec<(String, f64)>,
}

/// Assemble a [`RunRecord`] from a finished HOOI run — shared by the
/// legacy shims and the session layer so every path reports identically.
pub(crate) fn collect_record(
    w: &Workload,
    dist: &Distribution,
    ks: &[usize],
    cluster: &SimCluster,
    out: &HooiOutcome,
) -> RunRecord {
    let metrics = SchemeMetrics::compute(&w.tensor, &w.idx, dist);
    let khv: Vec<f64> = (0..w.tensor.ndim())
        .map(|n| crate::hooi::khat_of(ks, n) as f64)
        .collect();
    let comm_secs = cluster.elapsed.get(cat::COMM_SVD)
        + cluster.elapsed.get(cat::COMM_FM)
        + cluster.elapsed.get(cat::COMM_COMMON);
    let conc = cluster.concurrency_report(cat::TTM);
    RunRecord {
        workload: w.name.clone(),
        scheme: dist.scheme.clone(),
        p: dist.p,
        k: ks.iter().copied().max().unwrap_or(0),
        core: ks.to_vec(),
        // every charged HOOI component — the in-phase side of the
        // cat::IN_PHASE_SUM / cat::OUT_OF_PHASE_SUM partition (lint L5)
        hooi_secs: cat::IN_PHASE_SUM
            .iter()
            .map(|c| cluster.elapsed.get(c))
            .sum(),
        ttm_secs: cluster.elapsed.get(cat::TTM),
        svd_secs: cluster.elapsed.get(cat::SVD),
        core_secs: cluster.elapsed.get(cat::CORE),
        comm_secs,
        dist_secs: dist.time.simulated_secs,
        rebalances: 0,
        rebalance_skips: 0,
        redist_secs: cluster.elapsed.get(cat::REDIST),
        faults_injected: cluster.faults_injected(),
        recoveries: 0,
        recovery_secs: cluster.elapsed.get(cat::RECOVER),
        checkpoint_secs: 0.0,
        checkpoint_bytes: 0,
        svd_volume: cluster.volume.get(cat::COMM_SVD),
        fm_volume: cluster.volume.get(cat::COMM_FM),
        ttm_balance: metrics.ttm_balance(),
        svd_load_norm: metrics.svd_load_normalized(&khv),
        svd_balance: metrics.svd_balance(&khv),
        mem_mb: out.memory.avg_total_mb(),
        mem_breakdown_mb: out.memory.avg_component_mb(),
        fit: out.fit,
        executor: conc.executor.to_string(),
        workers: conc.workers,
        kernel: conc.kernel.to_string(),
        ttm_speedup: conc.speedup,
        transport: cluster.transport_name().to_string(),
        net_model_error: cluster.net_model_error(),
    }
}

/// Distribute + run HOOI, collecting every figure's quantities at once.
///
/// Legacy shim (uniform core length, positional arguments, env-driven
/// kernel/executor/accounting): prefer
/// [`TuckerSession`](super::session::TuckerSession) for new code.
pub fn run_scheme(
    w: &Workload,
    scheme: &dyn Scheme,
    p: usize,
    k: usize,
    invocations: usize,
    engine: &Engine,
    net: NetModel,
    seed: u64,
) -> RunRecord {
    let mut rng = Rng::new(seed);
    let dist = scheme.policies(&w.tensor, &w.idx, p, &mut rng);
    run_distribution(w, &dist, k, invocations, engine, net, seed)
}

/// Run HOOI under an already-constructed distribution. Legacy shim —
/// see [`run_scheme`].
pub fn run_distribution(
    w: &Workload,
    dist: &Distribution,
    k: usize,
    invocations: usize,
    engine: &Engine,
    net: NetModel,
    seed: u64,
) -> RunRecord {
    let mut cluster = SimCluster::new(dist.p).with_net(net);
    cluster.elapsed.add(cat::DIST, dist.time.simulated_secs);
    let core = CoreRanks::Uniform(k);
    let cfg = HooiConfig { core: core.clone(), invocations, seed, ..HooiConfig::default() };
    let out: HooiOutcome =
        run_hooi(&w.tensor, &w.idx, dist, engine, &mut cluster, &cfg);
    let ks = core.resolve(w.tensor.ndim());
    collect_record(w, dist, &ks, &cluster, &out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{CoarseG, Lite};
    use crate::tensor::datasets::by_name;

    fn tiny_workload() -> Workload {
        let spec = by_name("enron").unwrap().scaled(0.02);
        Workload::from_spec(&spec, 1.0)
    }

    #[test]
    fn run_record_is_consistent() {
        let w = tiny_workload();
        let rec = run_scheme(
            &w,
            &Lite,
            4,
            4,
            1,
            &Engine::Native,
            NetModel::default(),
            1,
        );
        assert!(rec.hooi_secs > 0.0);
        // breakdown-sum invariant: TTM + SVD + core + comm = total — the
        // core phase is part of the total, not silently dropped
        assert!(
            (rec.ttm_secs + rec.svd_secs + rec.core_secs + rec.comm_secs
                - rec.hooi_secs)
                .abs()
                < 1e-9
        );
        assert!(rec.core_secs > 0.0, "core phase is timed and charged");
        assert!(rec.ttm_balance >= 1.0);
        assert!(rec.svd_load_norm >= 1.0);
        assert!(rec.mem_mb > 0.0);
        assert_eq!(rec.scheme, "Lite");
        assert_eq!(rec.core, vec![4, 4, 4]);
        assert_eq!(rec.k, 4);
        // concurrency provenance: Native prefers the fused path, so the
        // recorded kernel is a real microkernel name
        assert!(rec.executor == "parallel" || rec.executor == "serial");
        assert!(rec.workers >= 1);
        assert!(["scalar", "portable", "avx2", "neon"].contains(&rec.kernel.as_str()));
        assert!(rec.ttm_speedup > 0.0);
    }

    #[test]
    fn coarseg_optimal_redundancy_lite_near() {
        let w = tiny_workload();
        let rc = run_scheme(
            &w,
            &CoarseG::default(),
            4,
            4,
            1,
            &Engine::Native,
            NetModel::default(),
            1,
        );
        let rl = run_scheme(&w, &Lite, 4, 4, 1, &Engine::Native, NetModel::default(), 1);
        assert!((rc.svd_load_norm - 1.0).abs() < 1e-9, "CoarseG redundancy 1.0");
        assert!(rl.svd_load_norm < 1.5, "Lite near-optimal: {}", rl.svd_load_norm);
    }

    #[test]
    fn resolve_rejects_unknown() {
        let job = JobSpec { dataset: "not-a-tensor".into(), ..Default::default() };
        match Workload::resolve(&job) {
            Err(WorkloadError::UnknownDataset { name }) => {
                assert_eq!(name, "not-a-tensor")
            }
            other => panic!("expected UnknownDataset, got {other:?}"),
        }
    }

    #[test]
    fn resolve_missing_tns_path_reports_io_error() {
        let job = JobSpec {
            dataset: "/nonexistent/dir/tensor.tns".into(),
            ..Default::default()
        };
        match Workload::resolve(&job) {
            Err(WorkloadError::Io { path, .. }) => {
                assert_eq!(path, std::path::Path::new("/nonexistent/dir/tensor.tns"))
            }
            other => panic!("expected Io error, got {other:?}"),
        }
    }

    #[test]
    fn resolve_malformed_file_reports_typed_parse_error() {
        let dir = std::env::temp_dir().join("tucker_lite_resolve_parse");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("broken.tns");
        std::fs::write(&path, "1 1 1 2.0\n0 1 1 3.0\n").unwrap();
        let job = JobSpec {
            dataset: path.to_string_lossy().into_owned(),
            ..Default::default()
        };
        match Workload::resolve(&job) {
            Err(WorkloadError::Tensor { path: p, source }) => {
                assert_eq!(p, path);
                match source {
                    TensorIoError::Parse { line, .. } => assert_eq!(line, 2),
                    other => panic!("expected Parse, got {other:?}"),
                }
            }
            other => panic!("expected Tensor error, got {other:?}"),
        }
    }

    #[test]
    fn resolve_accepts_any_existing_file_path() {
        // a FROSTT file without the .tns suffix must load fine
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let t = SparseTensor::random(vec![8, 7, 6], 60, &mut rng);
        let dir = std::env::temp_dir().join("tucker_lite_resolve");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tensor.frostt.txt");
        io::write_tns(&t, &path).unwrap();
        let job = JobSpec {
            dataset: path.to_string_lossy().into_owned(),
            ..Default::default()
        };
        let w = Workload::resolve(&job).expect("existing non-.tns path resolves");
        assert_eq!(w.tensor.nnz(), 60);
    }

    #[test]
    fn workload_from_spec_scales() {
        let spec = by_name("nell2").unwrap();
        let w = Workload::from_spec(&spec, 0.01);
        assert!(w.tensor.nnz() < spec.nnz);
        assert_eq!(w.idx.len(), 3);
    }
}
