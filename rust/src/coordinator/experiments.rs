//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§7) on the synthetic analogues + simulated cluster. Each
//! `figNN` function prints the paper's rows/series; the bench binaries
//! (rust/benches/figNN_*.rs) and the CLI (`tucker-lite exp --fig NN`) are
//! thin wrappers around these.
//!
//! Scaling defaults (DESIGN.md §2): the paper's 32–512 ranks map to 8–64
//! here (same tensors-per-rank regime after the nnz scale-down); the
//! dataset scale multiplier trades fidelity for wallclock and is
//! overridable everywhere (`--scale`).

use super::leader::{run_scheme, Workload};
use crate::dist::NetModel;
use crate::hooi::{self, CoreRanks};
use crate::runtime::Engine;
use crate::sched::{self, CostModel, Scheme, SchemeMetrics};
use crate::tensor::datasets;
use crate::util::rng::Rng;
use crate::util::table::{fmt_secs, fmt_si, Table};

#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub p_lo: usize,
    pub p_hi: usize,
    pub k: usize,
    pub k_big: usize,
    pub scale: f64,
    pub invocations: usize,
    pub seed: u64,
    pub net: NetModel,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            p_lo: 8,
            p_hi: 64,
            k: 10,
            k_big: 20,
            scale: 0.2,
            invocations: 1,
            seed: 0xE4A,
            net: NetModel::default(),
        }
    }
}

impl ExpConfig {
    /// Tiny configuration for tests / smoke runs.
    pub fn quick() -> Self {
        ExpConfig { p_lo: 2, p_hi: 4, scale: 0.02, k: 4, k_big: 4, ..Default::default() }
    }
}

fn medium_workloads(cfg: &ExpConfig) -> Vec<Workload> {
    datasets::medium()
        .iter()
        .map(|s| Workload::from_spec(s, cfg.scale))
        .collect()
}

fn big_workloads(cfg: &ExpConfig) -> Vec<Workload> {
    datasets::big()
        .iter()
        .map(|s| Workload::from_spec(s, cfg.scale))
        .collect()
}

/// Fig 9: dataset table.
pub fn fig9() -> Table {
    datasets::fig9_table()
}

/// Fig 10: HOOI execution time, medium tensors, three configurations
/// (P_lo/K, P_hi/K, P_hi/K_big) × four schemes.
pub fn fig10(cfg: &ExpConfig, engine: &Engine) -> Vec<Table> {
    let workloads = medium_workloads(cfg);
    let configs = [
        (cfg.p_lo, cfg.k, format!("ranks={} K={}", cfg.p_lo, cfg.k)),
        (cfg.p_hi, cfg.k, format!("ranks={} K={}", cfg.p_hi, cfg.k)),
        (cfg.p_hi, cfg.k_big, format!("ranks={} K={}", cfg.p_hi, cfg.k_big)),
    ];
    let mut tables = Vec::new();
    for (p, k, label) in configs {
        let mut t = Table::new(
            &format!("Fig 10 — HOOI execution time, {label}"),
            &["tensor", "CoarseG", "MediumG", "HyperG", "Lite", "best-prior/Lite"],
        );
        for w in &workloads {
            let mut times = Vec::new();
            for scheme in sched::all_schemes() {
                let rec = run_scheme(
                    w, scheme.as_ref(), p, k, cfg.invocations, engine, cfg.net, cfg.seed,
                );
                times.push(rec.hooi_secs);
            }
            let best_prior = times[..3].iter().cloned().fold(f64::INFINITY, f64::min);
            t.row(vec![
                w.name.clone(),
                fmt_secs(times[0]),
                fmt_secs(times[1]),
                fmt_secs(times[2]),
                fmt_secs(times[3]),
                format!("{:.2}x", best_prior / times[3]),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Fig 11: HOOI time breakup (TTM / SVD / core compute / communication)
/// on the first three tensors at (P_hi, K).
pub fn fig11(cfg: &ExpConfig, engine: &Engine) -> Table {
    let workloads: Vec<Workload> = medium_workloads(cfg).into_iter().take(3).collect();
    let mut t = Table::new(
        &format!("Fig 11 — time breakup, ranks={} K={}", cfg.p_hi, cfg.k),
        &["tensor", "scheme", "TTM", "SVD", "core", "comm", "total", "produced-by"],
    );
    for w in &workloads {
        for scheme in sched::all_schemes() {
            let rec = run_scheme(
                w, scheme.as_ref(), cfg.p_hi, cfg.k, cfg.invocations, engine, cfg.net, cfg.seed,
            );
            t.row(vec![
                w.name.clone(),
                rec.scheme.clone(),
                fmt_secs(rec.ttm_secs),
                fmt_secs(rec.svd_secs),
                fmt_secs(rec.core_secs),
                fmt_secs(rec.comm_secs),
                fmt_secs(rec.hooi_secs),
                // concurrency provenance: executor × workers, kernel,
                // measured executor speedup
                format!(
                    "{}x{} {} {:.2}x",
                    rec.executor, rec.workers, rec.kernel, rec.ttm_speedup
                ),
            ]);
        }
    }
    t
}

/// Distribution-only record (no HOOI run): the §4 metrics and volumes are
/// fully determined by the distribution, so Figs 12/13/17 are cheap.
pub struct DistRecord {
    pub workload: String,
    pub scheme: String,
    pub metrics: SchemeMetrics,
    pub svd_volume: f64,
    pub fm_volume: f64,
    pub mem_mb: f64,
    pub mem_breakdown: (f64, f64, f64),
    pub dist_secs: f64,
}

/// Distribute and compute metric/volume/memory records without timing
/// HOOI. `core` may be uniform (the paper's figures) or per-mode — the
/// oracle volume uses each mode's own Q_n = 4·K_n.
pub fn distribution_records(
    w: &Workload,
    schemes: &[Box<dyn Scheme>],
    p: usize,
    core: &CoreRanks,
    seed: u64,
) -> Vec<DistRecord> {
    let ks = core.resolve(w.tensor.ndim());
    schemes
        .iter()
        .map(|scheme| {
            let mut rng = Rng::new(seed);
            // first-class plan: the distribution plus the §4 metrics it
            // induces, compiled once (no second metrics pass)
            let plan = scheme.plan(
                &w.tensor,
                &w.idx,
                p,
                &mut rng,
                &ks,
                &CostModel::default(),
            );
            let dist = plan.dist;
            let metrics = SchemeMetrics {
                per_mode: plan.modes.into_iter().map(|m| m.metrics).collect(),
            };
            // oracle volume: Q_n (R_sum − L_nonempty) per mode, Q_n = 4K_n
            let svd_volume: f64 = metrics
                .per_mode
                .iter()
                .zip(&ks)
                .map(|(m, &k_n)| (4 * k_n * m.oracle_volume_per_query()) as f64)
                .sum();
            // FM volume from the transfer patterns (plan compilation
            // skipped: these records never assemble a Z)
            let modes = hooi::prepare_modes_unplanned(&w.tensor, &w.idx, &dist, core);
            let fm_volume: f64 =
                modes.iter().map(|st| st.fm.total_units as f64).sum();
            let mem = hooi::memory_model(&w.tensor, &dist, &modes);
            DistRecord {
                workload: w.name.clone(),
                scheme: dist.scheme.clone(),
                metrics,
                svd_volume,
                fm_volume,
                mem_mb: mem.avg_total_mb(),
                mem_breakdown: mem.avg_component_mb(),
                dist_secs: dist.time.simulated_secs,
            }
        })
        .collect()
}

/// Fig 12: computation metrics at (P_hi, K) on the first three tensors —
/// (a) TTM load balance, (b) normalized SVD load, (c) SVD load balance.
pub fn fig12(cfg: &ExpConfig) -> Table {
    let workloads: Vec<Workload> = medium_workloads(cfg).into_iter().take(3).collect();
    let mut t = Table::new(
        &format!("Fig 12 — computation metrics, ranks={} K={}", cfg.p_hi, cfg.k),
        &["tensor", "scheme", "TTM balance", "SVD load (norm)", "SVD balance"],
    );
    for w in &workloads {
        let khv: Vec<f64> = (0..w.tensor.ndim())
            .map(|_| (cfg.k as f64).powi(w.tensor.ndim() as i32 - 1))
            .collect();
        for rec in
            distribution_records(
                w,
                &sched::all_schemes(),
                cfg.p_hi,
                &CoreRanks::Uniform(cfg.k),
                cfg.seed,
            )
        {
            t.row(vec![
                w.name.clone(),
                rec.scheme.clone(),
                format!("{:.2}", rec.metrics.ttm_balance()),
                format!("{:.2}", rec.metrics.svd_load_normalized(&khv)),
                format!("{:.2}", rec.metrics.svd_balance(&khv)),
            ]);
        }
    }
    t
}

/// Fig 13: communication volume breakup (SVD oracle vs factor-matrix).
pub fn fig13(cfg: &ExpConfig) -> Table {
    let workloads: Vec<Workload> = medium_workloads(cfg).into_iter().take(3).collect();
    let mut t = Table::new(
        &format!("Fig 13 — communication volume (units), ranks={} K={}", cfg.p_hi, cfg.k),
        &["tensor", "scheme", "SVD", "FM", "total"],
    );
    for w in &workloads {
        for rec in
            distribution_records(
                w,
                &sched::all_schemes(),
                cfg.p_hi,
                &CoreRanks::Uniform(cfg.k),
                cfg.seed,
            )
        {
            t.row(vec![
                w.name.clone(),
                rec.scheme.clone(),
                fmt_si(rec.svd_volume),
                fmt_si(rec.fm_volume),
                fmt_si(rec.svd_volume + rec.fm_volume),
            ]);
        }
    }
    t
}

/// Fig 14: big tensors, lightweight schemes only (HyperG cannot partition
/// them — same exclusion as the paper).
pub fn fig14(cfg: &ExpConfig, engine: &Engine) -> Table {
    let workloads = big_workloads(cfg);
    let mut t = Table::new(
        &format!("Fig 14 — big tensors HOOI time, ranks={} K={}", cfg.p_hi, cfg.k),
        &["tensor", "CoarseG", "MediumG", "Lite", "MediumG/Lite"],
    );
    for w in &workloads {
        let mut times = Vec::new();
        for scheme in sched::lightweight_schemes() {
            let rec = run_scheme(
                w, scheme.as_ref(), cfg.p_hi, cfg.k, cfg.invocations, engine, cfg.net, cfg.seed,
            );
            times.push(rec.hooi_secs);
        }
        t.row(vec![
            w.name.clone(),
            fmt_secs(times[0]),
            fmt_secs(times[1]),
            fmt_secs(times[2]),
            format!("{:.2}x", times[1] / times[2]),
        ]);
    }
    t
}

/// Fig 15: strong scaling P_lo → P_hi. Returns (speedup table over all
/// schemes and datasets, Lite scaling curve over the P sweep).
pub fn fig15(cfg: &ExpConfig, engine: &Engine) -> (Table, Table) {
    let mut all: Vec<Workload> = medium_workloads(cfg);
    all.extend(big_workloads(cfg));
    let ideal = cfg.p_hi as f64 / cfg.p_lo as f64;
    let mut speedup = Table::new(
        &format!(
            "Fig 15a — speedup {}→{} ranks (ideal {:.0}x), K={}",
            cfg.p_lo, cfg.p_hi, ideal, cfg.k
        ),
        &["tensor", "CoarseG", "MediumG", "HyperG", "Lite"],
    );
    for w in &all {
        let big = datasets::by_name(&w.name).map(|d| d.big).unwrap_or(false);
        let mut cells = vec![w.name.clone()];
        for scheme in sched::all_schemes() {
            if scheme.name() == "HyperG" && big {
                cells.push("X".into());
                continue;
            }
            let lo = run_scheme(
                w, scheme.as_ref(), cfg.p_lo, cfg.k, cfg.invocations, engine, cfg.net, cfg.seed,
            );
            let hi = run_scheme(
                w, scheme.as_ref(), cfg.p_hi, cfg.k, cfg.invocations, engine, cfg.net, cfg.seed,
            );
            cells.push(format!("{:.1}x", lo.hooi_secs / hi.hooi_secs));
        }
        speedup.row(cells);
    }
    // Lite strong-scaling curve over a P sweep
    let mut sweep = Vec::new();
    let mut p = cfg.p_lo;
    while p <= cfg.p_hi {
        sweep.push(p);
        p *= 2;
    }
    let header: Vec<String> = std::iter::once("tensor".to_string())
        .chain(sweep.iter().map(|p| format!("P={p}")))
        .collect();
    let mut curve = Table::new(
        "Fig 15b — Lite strong scaling (simulated HOOI seconds)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for w in &all {
        let mut cells = vec![w.name.clone()];
        for &p in &sweep {
            let rec = run_scheme(
                w, &sched::Lite, p, cfg.k, cfg.invocations, engine, cfg.net, cfg.seed,
            );
            cells.push(fmt_secs(rec.hooi_secs));
        }
        curve.row(cells);
    }
    (speedup, curve)
}

/// Fig 16: distribution time of every scheme vs a single Lite HOOI
/// invocation, all eight tensors at (P_hi, K).
pub fn fig16(cfg: &ExpConfig, engine: &Engine) -> Table {
    let mut all: Vec<Workload> = medium_workloads(cfg);
    all.extend(big_workloads(cfg));
    let mut t = Table::new(
        &format!("Fig 16 — distribution time, ranks={} K={}", cfg.p_hi, cfg.k),
        &["tensor", "CoarseG", "MediumG", "HyperG", "Lite", "HOOI(Lite)"],
    );
    for w in &all {
        let big = datasets::by_name(&w.name).map(|d| d.big).unwrap_or(false);
        let mut cells = vec![w.name.clone()];
        for scheme in sched::all_schemes() {
            if scheme.name() == "HyperG" && big {
                cells.push("X".into());
                continue;
            }
            let mut rng = Rng::new(cfg.seed);
            let ks = CoreRanks::Uniform(cfg.k).resolve(w.tensor.ndim());
            let plan = scheme.plan(
                &w.tensor,
                &w.idx,
                cfg.p_hi,
                &mut rng,
                &ks,
                &CostModel::default(),
            );
            cells.push(fmt_secs(plan.dist.time.simulated_secs));
        }
        let rec = run_scheme(
            w, &sched::Lite, cfg.p_hi, cfg.k, 1, engine, cfg.net, cfg.seed,
        );
        cells.push(fmt_secs(rec.hooi_secs));
        t.row(cells);
    }
    t
}

/// Fig 17: average memory per rank (MB) with tensor/penultimate/factor
/// breakdown for the first three tensors.
pub fn fig17(cfg: &ExpConfig) -> Table {
    let mut all: Vec<Workload> = medium_workloads(cfg);
    all.extend(big_workloads(cfg));
    let mut t = Table::new(
        &format!("Fig 17 — memory per rank (MB), ranks={} K={}", cfg.p_hi, cfg.k),
        &["tensor", "scheme", "total", "tensor", "penult", "factors"],
    );
    for (wi, w) in all.iter().enumerate() {
        let big = datasets::by_name(&w.name).map(|d| d.big).unwrap_or(false);
        let schemes =
            if big { sched::lightweight_schemes() } else { sched::all_schemes() };
        for rec in
            distribution_records(w, &schemes, cfg.p_hi, &CoreRanks::Uniform(cfg.k), cfg.seed)
        {
            let (tm, zm, fm) = rec.mem_breakdown;
            let detail = wi < 3;
            t.row(vec![
                w.name.clone(),
                rec.scheme.clone(),
                format!("{:.1}", rec.mem_mb),
                if detail { format!("{tm:.1}") } else { "-".into() },
                if detail { format!("{zm:.1}") } else { "-".into() },
                if detail { format!("{fm:.1}") } else { "-".into() },
            ]);
        }
    }
    t
}

/// Dispatch by figure number (CLI `exp --fig N`). Returns rendered text.
pub fn run_figure(fig: usize, cfg: &ExpConfig, engine: &Engine) -> String {
    match fig {
        9 => fig9().render(),
        10 => fig10(cfg, engine)
            .iter()
            .map(|t| t.render())
            .collect::<Vec<_>>()
            .join("\n"),
        11 => fig11(cfg, engine).render(),
        12 => fig12(cfg).render(),
        13 => fig13(cfg).render(),
        14 => fig14(cfg, engine).render(),
        15 => {
            let (a, b) = fig15(cfg, engine);
            format!("{}\n{}", a.render(), b.render())
        }
        16 => fig16(cfg, engine).render(),
        17 => fig17(cfg).render(),
        _ => format!("unknown figure {fig} (valid: 9..=17)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_always_available() {
        let r = fig9().render();
        assert!(r.contains("reddit"));
    }

    #[test]
    fn fig12_13_17_distribution_only_paths() {
        let cfg = ExpConfig::quick();
        let r12 = fig12(&cfg).render();
        assert!(r12.contains("Lite") && r12.contains("HyperG"));
        let r13 = fig13(&cfg).render();
        assert!(r13.contains("FM"));
        let r17 = fig17(&cfg).render();
        assert!(r17.contains("amazon"));
    }

    #[test]
    fn fig10_quick_smoke() {
        let cfg = ExpConfig::quick();
        let tables = fig10(&cfg, &Engine::Native);
        assert_eq!(tables.len(), 3);
        for t in &tables {
            let r = t.render();
            assert!(r.contains("enron"));
            assert!(r.contains("Lite"));
        }
    }

    #[test]
    fn run_figure_dispatch() {
        let cfg = ExpConfig::quick();
        assert!(run_figure(9, &cfg, &Engine::Native).contains("Fig 9"));
        assert!(run_figure(99, &cfg, &Engine::Native).contains("unknown"));
    }
}
