//! L3 coordinator: the [`TuckerSession`] typed front door, job specs
//! (config + CLI), the pipeline leader (dataset → scheme → simulated
//! cluster → HOOI → record) and the experiment harness regenerating
//! every table/figure of §7.

pub mod checkpoint;
pub mod experiments;
pub mod job;
pub mod leader;
pub mod session;

pub use checkpoint::{CheckpointPolicy, RetryPolicy, SessionCheckpoint};
pub use experiments::{run_figure, ExpConfig};
pub use job::JobSpec;
pub use leader::{run_distribution, run_scheme, RunRecord, Workload, WorkloadError};
pub use session::{
    Decomposition, EngineChoice, ExecutorChoice, IngestReport, KernelChoice,
    PlanChoice, RebalanceDecision, RebalancePolicy, RebalanceReport, SchemeChoice,
    SessionError, TuckerSession, TuckerSessionBuilder,
};
