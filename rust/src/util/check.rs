//! Mini property-testing framework (offline image vendors no proptest).
//!
//! `props::run` drives N randomized cases from a seeded RNG; on failure it
//! re-runs with progressively simpler size hints to report a smaller
//! counterexample (linear shrinking on the `size` parameter — not full
//! structural shrinking, but enough to localize invariant violations).
//!
//! Used throughout the test suites, most importantly for the Theorem 6.1
//! invariants of the Lite scheme (sched::lite tests).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct Case {
    /// Case index within the run.
    pub index: usize,
    /// Size hint in [min_size, max_size]; generators should scale with it.
    pub size: usize,
    /// Seed for this case's RNG.
    pub seed: u64,
}

pub struct Runner {
    pub cases: usize,
    pub min_size: usize,
    pub max_size: usize,
    pub seed: u64,
}

impl Default for Runner {
    fn default() -> Self {
        Runner { cases: 64, min_size: 1, max_size: 200, seed: 0xC0FFEE }
    }
}

impl Runner {
    pub fn new(cases: usize, max_size: usize) -> Self {
        Runner { cases, max_size, ..Default::default() }
    }

    /// Run `prop` on `cases` randomized cases; panic with a reproducible
    /// counterexample description on the smallest failing size found.
    pub fn run<F>(&self, name: &str, prop: F)
    where
        F: Fn(Case, &mut Rng) -> Result<(), String>,
    {
        let mut meta = Rng::new(self.seed);
        let mut failure: Option<(Case, String)> = None;
        for index in 0..self.cases {
            let span = (self.max_size - self.min_size).max(1);
            let size = self.min_size + (index * span) / self.cases.max(1)
                + meta.usize_below(span / 4 + 1);
            let seed = meta.next_u64();
            let case = Case { index, size: size.min(self.max_size), seed };
            let mut rng = Rng::new(seed);
            if let Err(msg) = prop(case, &mut rng) {
                failure = Some((case, msg));
                break;
            }
        }
        let Some((case, msg)) = failure else { return };
        // shrink: binary-search the smallest failing size for this seed
        // (exact under monotone failure, a good localizer otherwise)
        let mut smallest = (case, msg);
        let (mut lo, mut hi) = (self.min_size, case.size);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let c = Case { size: mid, ..case };
            let mut rng = Rng::new(case.seed);
            match prop(c, &mut rng) {
                Err(m) => {
                    smallest = (c, m);
                    hi = mid;
                }
                Ok(()) => lo = mid + 1,
            }
        }
        panic!(
            "property '{}' failed: case #{} size={} seed={:#x}: {}",
            name, smallest.0.index, smallest.0.size, smallest.0.seed, smallest.1
        );
    }
}

/// Convenience: assert with a formatted error for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        Runner::new(32, 50).run("sum-commutes", |case, rng| {
            let a = rng.below(case.size as u64 + 1);
            let b = rng.below(case.size as u64 + 1);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        Runner::new(8, 50).run("always-fails", |_case, _rng| Err("nope".into()));
    }

    #[test]
    fn shrink_reports_small_size() {
        let result = std::panic::catch_unwind(|| {
            Runner::new(16, 128).run("fails-when-big", |case, _rng| {
                if case.size >= 2 {
                    Err("too big".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // shrinking halves down to a failing size of 2
        assert!(msg.contains("size=2"), "got: {msg}");
    }
}
