//! Minimal CLI argument parser (the offline image vendors no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments and subcommands; every option self-registers for `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    pub fn parse(argv: &[String]) -> Self {
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(body.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(body.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { opts, flags, positional }
    }

    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional(0)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
            None => default,
        }
    }

    /// Comma-separated list, e.g. `--ranks 8,16,32`.
    pub fn list_or<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        eprintln!("error: bad element {s:?} in --{name}");
                        std::process::exit(2);
                    })
                })
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = args(&["exp", "--fig", "10", "--scheme=lite"]);
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.get("fig"), Some("10"));
        assert_eq!(a.get("scheme"), Some("lite"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = args(&["run", "--verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn parse_or_falls_back() {
        let a = args(&["run", "--p", "64"]);
        assert_eq!(a.parse_or::<usize>("p", 8), 64);
        assert_eq!(a.parse_or::<usize>("k", 10), 10);
    }

    #[test]
    fn list_parsing() {
        let a = args(&["x", "--ranks", "8,16,32"]);
        assert_eq!(a.list_or::<usize>("ranks", &[1]), vec![8, 16, 32]);
        assert_eq!(a.list_or::<usize>("other", &[1, 2]), vec![1, 2]);
    }
}
