//! Wallclock timing + a tiny scoped profiler used by the perf pass
//! (EXPERIMENTS.md §Perf). Real measured seconds everywhere; the simulated
//! cluster combines them into makespans (dist::cluster).

use std::time::Instant;

/// Measure a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Accumulating named timer buckets, e.g. ttm/svd/comm breakups.
#[derive(Debug, Default, Clone)]
pub struct Buckets {
    entries: Vec<(String, f64)>,
}

impl Buckets {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(n, s)| (n.as_str(), *s))
    }

    pub fn merge(&mut self, other: &Buckets) {
        for (n, s) in other.iter() {
            self.add(n, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_positive() {
        let (v, s) = time(|| (0..10_000).sum::<u64>());
        assert_eq!(v, 49_995_000);
        assert!(s >= 0.0);
    }

    #[test]
    fn buckets_accumulate() {
        let mut b = Buckets::new();
        b.add("ttm", 1.0);
        b.add("ttm", 0.5);
        b.add("svd", 2.0);
        assert!((b.get("ttm") - 1.5).abs() < 1e-12);
        assert!((b.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = Buckets::new();
        a.add("x", 1.0);
        let mut b = Buckets::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert!((a.get("x") - 3.0).abs() < 1e-12);
        assert!((a.get("y") - 3.0).abs() < 1e-12);
    }
}
