//! Wallclock timing + a tiny scoped profiler used by the perf pass
//! (EXPERIMENTS.md §Perf). Real measured seconds everywhere; the simulated
//! cluster combines them into makespans (dist::cluster).
//!
//! This module is the **only** place allowed to touch
//! `std::time::Instant`/`SystemTime` directly (lint rule L4,
//! `cargo run -p tucker-lint`): every other clock read goes through
//! [`time`], [`Stopwatch`] or [`Deadline`], so the accounting that
//! feeds the Fig 11 phase breakups has a single auditable source.

use std::time::{Duration, Instant};

/// Measure a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// A started monotonic clock: `Stopwatch::start()` … `sw.seconds()` is
/// the sanctioned spelling of `Instant::now()` … `elapsed()`.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    /// Seconds elapsed since [`start`](Stopwatch::start).
    pub fn seconds(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Stopwatch::start()
    }
}

/// A monotonic deadline: answers only "has it passed yet?" so callers
/// never handle raw `Instant`s. Used by the transport's phase/heartbeat
/// monitors.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `secs` from now.
    pub fn in_secs(secs: f64) -> Deadline {
        Deadline { at: Instant::now() + Duration::from_secs_f64(secs.max(0.0)) }
    }

    /// True once the deadline has passed.
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }
}

/// Accumulating named timer buckets, e.g. ttm/svd/comm breakups.
#[derive(Debug, Default, Clone)]
pub struct Buckets {
    entries: Vec<(String, f64)>,
}

impl Buckets {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.entries.push((name.to_string(), secs));
        }
    }

    pub fn get(&self, name: &str) -> f64 {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, s)| s).sum()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.entries.iter().map(|(n, s)| (n.as_str(), *s))
    }

    pub fn merge(&mut self, other: &Buckets) {
        for (n, s) in other.iter() {
            self.add(n, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures_positive() {
        let (v, s) = time(|| (0..10_000).sum::<u64>());
        assert_eq!(v, 49_995_000);
        assert!(s >= 0.0);
    }

    #[test]
    fn buckets_accumulate() {
        let mut b = Buckets::new();
        b.add("ttm", 1.0);
        b.add("ttm", 0.5);
        b.add("svd", 2.0);
        assert!((b.get("ttm") - 1.5).abs() < 1e-12);
        assert!((b.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.seconds();
        let b = sw.seconds();
        assert!(a >= 0.0);
        assert!(b >= a);
    }

    #[test]
    fn deadline_expires() {
        assert!(Deadline::in_secs(0.0).expired());
        assert!(Deadline::in_secs(-1.0).expired());
        assert!(!Deadline::in_secs(60.0).expired());
    }

    #[test]
    fn merge_adds() {
        let mut a = Buckets::new();
        a.add("x", 1.0);
        let mut b = Buckets::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert!((a.get("x") - 3.0).abs() < 1e-12);
        assert!((a.get("y") - 3.0).abs() < 1e-12);
    }
}
