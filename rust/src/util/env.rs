//! One front door for every `TUCKER_*` environment variable.
//!
//! Before the session API existed, the env reads were scattered across
//! five modules (`hooi::kernel`, `dist::cluster`, `hooi::driver`,
//! `runtime::artifacts`, the bench harness), each with its own parsing
//! and fallback behavior. They are centralized here so the precedence
//! contract is stated — and tested — exactly once:
//!
//! | source                     | wins over |
//! |----------------------------|-----------|
//! | typed builder option       | env var   |
//! | env var (valid value)      | default   |
//! | env var (invalid value)    | nothing — warns on stderr, default used |
//!
//! [`resolve`] implements that table; the typed accessors below it are
//! the per-variable entry points the rest of the crate uses.

use crate::dist::transport::TransportChoice;

/// Microkernel override: `scalar|portable|avx2|neon` (`hooi::Kernel`).
pub const KERNEL: &str = "TUCKER_KERNEL";
/// Rank executor override: `serial|parallel` (`dist::SimCluster`).
pub const PHASE_EXECUTOR: &str = "TUCKER_PHASE_EXECUTOR";
/// Communication transport override: `sim|channel` (`dist::transport`).
pub const TRANSPORT: &str = "TUCKER_TRANSPORT";
/// Fig 17 accounting override: `coo|plan` (`hooi::TensorAccounting`).
pub const MEM_ACCOUNTING: &str = "TUCKER_MEM_ACCOUNTING";
/// Plan layout override: `per-mode|shared` (`coordinator::PlanChoice`).
pub const PLAN: &str = "TUCKER_PLAN";
/// Pin parallel-executor worker threads to cores: `on|off`
/// (`dist::SimCluster`; NUMA first-touch placement).
pub const PIN_THREADS: &str = "TUCKER_PIN_THREADS";
/// PJRT artifact directory (`runtime::artifacts`).
pub const ARTIFACTS: &str = "TUCKER_ARTIFACTS";
/// Bench harness: any value selects the tiny smoke configuration.
pub const BENCH_QUICK: &str = "TUCKER_BENCH_QUICK";
/// Bench harness: dataset scale multiplier.
pub const BENCH_SCALE: &str = "TUCKER_BENCH_SCALE";
/// Bench harness: `pjrt|native` engine selection.
pub const BENCH_ENGINE: &str = "TUCKER_BENCH_ENGINE";
/// Serving coordinator: worker-thread budget across all tenants
/// (`serve::ServeBudget`).
pub const SERVE_THREADS: &str = "TUCKER_SERVE_THREADS";
/// Serving coordinator: resident snapshot-memory budget across all
/// tenants, in bytes.
pub const SERVE_SNAPSHOT_BYTES: &str = "TUCKER_SERVE_SNAPSHOT_BYTES";
/// Serving engine: largest query batch evaluated in one engine call.
pub const SERVE_BATCH: &str = "TUCKER_SERVE_BATCH";

/// Raw trimmed value of an environment variable; `None` when unset,
/// empty, or not valid UTF-8.
pub fn raw(name: &str) -> Option<String> {
    match std::env::var(name) {
        Ok(s) => {
            let t = s.trim();
            if t.is_empty() {
                None
            } else {
                Some(t.to_string())
            }
        }
        Err(_) => None,
    }
}

/// Is the variable set at all (any value, including empty)? Used by the
/// bench harness's presence-only flags ([`BENCH_QUICK`]).
pub fn is_set(name: &str) -> bool {
    std::env::var_os(name).is_some()
}

/// The precedence contract: typed option > env var > default. An env
/// value `parse` rejects is reported on stderr (naming the variable and
/// the value) and the default is used — an invalid override must never
/// silently change results.
pub fn resolve<T>(
    option: Option<T>,
    name: &str,
    parse: impl Fn(&str) -> Option<T>,
    default: impl FnOnce() -> T,
) -> T {
    resolve_with(option, name, raw(name), parse, default)
}

/// [`resolve`] with the env value passed in — the testable seam (unit
/// tests exercise the precedence table without mutating the process
/// environment, which is unsound under the parallel test harness).
fn resolve_with<T>(
    option: Option<T>,
    name: &str,
    env_value: Option<String>,
    parse: impl Fn(&str) -> Option<T>,
    default: impl FnOnce() -> T,
) -> T {
    if let Some(v) = option {
        return v;
    }
    match env_value {
        Some(s) => parse(&s).unwrap_or_else(|| {
            eprintln!("{name}={s:?} not recognized; using the default");
            default()
        }),
        None => default(),
    }
}

/// [`PHASE_EXECUTOR`] as "should the parallel rank executor be used"
/// (`option` from a typed executor choice; env accepts `serial` /
/// `parallel`; default: parallel when the host has more than one core).
pub fn phase_executor_parallel(option: Option<bool>) -> bool {
    resolve(option, PHASE_EXECUTOR, parse_executor, || {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 1
    })
}

fn parse_executor(s: &str) -> Option<bool> {
    if s.eq_ignore_ascii_case("serial") {
        Some(false)
    } else if s.eq_ignore_ascii_case("parallel") {
        Some(true)
    } else {
        None
    }
}

/// [`PLAN`] as "should the sweep run over one shared CSF tree per rank"
/// (`option` from the session's typed `PlanChoice`; env accepts
/// `shared`/`csf` and `per-mode`/`permode`; default: per-mode plans —
/// the historical layout).
pub fn plan_shared_csf(option: Option<bool>) -> bool {
    resolve(option, PLAN, parse_plan, || false)
}

fn parse_plan(s: &str) -> Option<bool> {
    if s.eq_ignore_ascii_case("shared") || s.eq_ignore_ascii_case("csf") {
        Some(true)
    } else if s.eq_ignore_ascii_case("per-mode") || s.eq_ignore_ascii_case("permode") {
        Some(false)
    } else {
        None
    }
}

/// [`PIN_THREADS`] as "should parallel-executor workers pin to cores"
/// (`option` from the session builder; env accepts `on`/`off`; default:
/// off — pinning helps NUMA hosts but hurts oversubscribed ones, so it
/// stays opt-in).
pub fn pin_threads(option: Option<bool>) -> bool {
    resolve(option, PIN_THREADS, parse_on_off, || false)
}

fn parse_on_off(s: &str) -> Option<bool> {
    if s.eq_ignore_ascii_case("on") || s == "1" {
        Some(true)
    } else if s.eq_ignore_ascii_case("off") || s == "0" {
        Some(false)
    } else {
        None
    }
}

/// [`TRANSPORT`] as a [`TransportChoice`] (`option` from the session
/// builder; env accepts `sim` / `channel`; default: `Sim` — the analytic
/// charger, the historical behavior).
pub fn transport_choice(option: Option<TransportChoice>) -> TransportChoice {
    resolve(option, TRANSPORT, TransportChoice::by_name, TransportChoice::default)
}

/// Budget values must be positive — a zero thread or byte budget would
/// make every admission fail, and a zero batch size would never serve.
fn parse_positive(s: &str) -> Option<usize> {
    s.parse().ok().filter(|&v: &usize| v > 0)
}

/// [`SERVE_THREADS`] as the coordinator's worker-thread budget
/// (`option` from [`ServeBudget::resolve`]; default 16).
///
/// [`ServeBudget::resolve`]: crate::serve::ServeBudget::resolve
pub fn serve_threads(option: Option<usize>) -> usize {
    resolve(option, SERVE_THREADS, parse_positive, || 16)
}

/// [`SERVE_SNAPSHOT_BYTES`] as the coordinator's resident
/// snapshot-memory budget (default 64 MiB).
pub fn serve_snapshot_bytes(option: Option<usize>) -> usize {
    resolve(option, SERVE_SNAPSHOT_BYTES, parse_positive, || 64 * 1024 * 1024)
}

/// [`SERVE_BATCH`] as the engine's maximum batch length (default 1024).
pub fn serve_batch(option: Option<usize>) -> usize {
    resolve(option, SERVE_BATCH, parse_positive, || 1024)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The tests go through `resolve_with` — never `std::env::set_var`,
    // which is a getenv/setenv data race under the parallel test
    // harness (other tests read the environment concurrently).

    fn parse_u32(s: &str) -> Option<u32> {
        s.parse().ok()
    }

    #[test]
    fn typed_option_beats_env() {
        let got = resolve_with(
            Some(1u32),
            "TUCKER_TEST",
            Some("2".to_string()),
            parse_u32,
            || 3,
        );
        assert_eq!(got, 1);
    }

    #[test]
    fn env_beats_default_when_valid() {
        let got =
            resolve_with(None, "TUCKER_TEST", Some("7".to_string()), parse_u32, || 3);
        assert_eq!(got, 7);
    }

    #[test]
    fn invalid_env_falls_back_to_default() {
        let got = resolve_with(
            None,
            "TUCKER_TEST",
            Some("not-a-number".to_string()),
            parse_u32,
            || 3,
        );
        assert_eq!(got, 3);
    }

    #[test]
    fn unset_env_uses_default() {
        let got = resolve_with(None, "TUCKER_TEST", None, parse_u32, || 42);
        assert_eq!(got, 42);
        // reading a variable that was never set is race-free and must
        // come back as None/default through the public entry points too
        assert_eq!(raw("TUCKER_TEST_NEVER_SET_ANYWHERE"), None);
        assert!(!is_set("TUCKER_TEST_NEVER_SET_ANYWHERE"));
        let got =
            resolve(None, "TUCKER_TEST_NEVER_SET_ANYWHERE", parse_u32, || 42u32);
        assert_eq!(got, 42);
    }

    #[test]
    fn executor_parse_accepts_both_names_case_insensitively() {
        assert_eq!(parse_executor("serial"), Some(false));
        assert_eq!(parse_executor("SERIAL"), Some(false));
        assert_eq!(parse_executor("parallel"), Some(true));
        assert_eq!(parse_executor("threads"), None);
    }

    #[test]
    fn executor_typed_choice_beats_env() {
        // phase_executor_parallel reads the real PHASE_EXECUTOR variable;
        // only exercise the Some(..) arm, which never touches it.
        assert!(phase_executor_parallel(Some(true)));
        assert!(!phase_executor_parallel(Some(false)));
    }

    #[test]
    fn serve_knob_precedence_typed_env_default() {
        // typed option beats a valid env value
        let got = resolve_with(
            Some(4usize),
            SERVE_THREADS,
            Some("8".to_string()),
            parse_positive,
            || 16,
        );
        assert_eq!(got, 4);
        // valid env value beats the default
        let got =
            resolve_with(None, SERVE_THREADS, Some("8".to_string()), parse_positive, || 16);
        assert_eq!(got, 8);
        // zero and garbage are rejected → default (a zero budget would
        // deadlock every admission)
        let got =
            resolve_with(None, SERVE_BATCH, Some("0".to_string()), parse_positive, || 1024);
        assert_eq!(got, 1024);
        let got = resolve_with(
            None,
            SERVE_SNAPSHOT_BYTES,
            Some("lots".to_string()),
            parse_positive,
            || 64,
        );
        assert_eq!(got, 64);
        // unset env: the default
        let got = resolve_with(None, SERVE_SNAPSHOT_BYTES, None, parse_positive, || 64);
        assert_eq!(got, 64);
        // the typed accessors' Some(..) arm never reads the environment
        assert_eq!(serve_threads(Some(2)), 2);
        assert_eq!(serve_snapshot_bytes(Some(1 << 20)), 1 << 20);
        assert_eq!(serve_batch(Some(64)), 64);
    }

    #[test]
    fn plan_and_pin_precedence_typed_env_default() {
        // typed option beats a valid env value
        let got = resolve_with(
            Some(false),
            PLAN,
            Some("shared".to_string()),
            parse_plan,
            || false,
        );
        assert!(!got);
        // valid env values beat the default, case-insensitively
        for v in ["shared", "CSF"] {
            let got =
                resolve_with(None, PLAN, Some(v.to_string()), parse_plan, || false);
            assert!(got, "{v}");
        }
        for v in ["per-mode", "PerMode"] {
            let got =
                resolve_with(None, PLAN, Some(v.to_string()), parse_plan, || true);
            assert!(!got, "{v}");
        }
        // invalid env value warns and falls back to the default
        let got =
            resolve_with(None, PLAN, Some("tree".to_string()), parse_plan, || false);
        assert!(!got);
        // unset env: per-mode
        assert!(!resolve_with(None, PLAN, None, parse_plan, || false));
        // pinning: same table, on/off/1/0 spellings
        let got = resolve_with(
            Some(true),
            PIN_THREADS,
            Some("off".to_string()),
            parse_on_off,
            || false,
        );
        assert!(got);
        assert_eq!(parse_on_off("on"), Some(true));
        assert_eq!(parse_on_off("1"), Some(true));
        assert_eq!(parse_on_off("OFF"), Some(false));
        assert_eq!(parse_on_off("0"), Some(false));
        assert_eq!(parse_on_off("yes"), None);
        assert!(!resolve_with(None, PIN_THREADS, None, parse_on_off, || false));
        // the typed accessors' Some(..) arm never reads the environment
        assert!(plan_shared_csf(Some(true)));
        assert!(!plan_shared_csf(Some(false)));
        assert!(pin_threads(Some(true)));
        assert!(!pin_threads(Some(false)));
    }

    #[test]
    fn transport_precedence_typed_env_default() {
        // typed option beats a valid env value
        let got = resolve_with(
            Some(TransportChoice::Sim),
            TRANSPORT,
            Some("channel".to_string()),
            TransportChoice::by_name,
            TransportChoice::default,
        );
        assert_eq!(got, TransportChoice::Sim);
        // valid env value beats the default (case-insensitively)
        let got = resolve_with(
            None,
            TRANSPORT,
            Some("CHANNEL".to_string()),
            TransportChoice::by_name,
            TransportChoice::default,
        );
        assert_eq!(got, TransportChoice::Channel);
        // invalid env value warns and falls back to the default
        let got = resolve_with(
            None,
            TRANSPORT,
            Some("mpi".to_string()),
            TransportChoice::by_name,
            TransportChoice::default,
        );
        assert_eq!(got, TransportChoice::Sim);
        // unset env: the default (Sim)
        let got = resolve_with(
            None,
            TRANSPORT,
            None,
            TransportChoice::by_name,
            TransportChoice::default,
        );
        assert_eq!(got, TransportChoice::Sim);
        // the typed accessor's Some(..) arm never reads the environment
        assert_eq!(
            transport_choice(Some(TransportChoice::Channel)),
            TransportChoice::Channel
        );
    }
}
