//! Support substrates built from scratch for the offline image (no rand,
//! clap, serde, proptest or criterion are vendored — DESIGN.md §3 item 11).

pub mod args;
pub mod check;
pub mod config;
pub mod env;
pub mod float;
pub mod json;
pub mod rng;
pub mod table;
pub mod timer;
