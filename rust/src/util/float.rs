//! Exact floating-point comparison helpers.
//!
//! This module is the **only** place allowed to write bare `==`/`!=`
//! against an `f32`/`f64` literal (lint rule L6, `cargo run -p
//! tucker-lint`). Everywhere else an exact comparison must go through
//! these helpers or `to_bits()`, so each use states *which* exactness
//! it means: sparse skip-zero fast paths want IEEE equality (where
//! `-0.0 == 0.0`), while bit-exactness pins want `to_bits()` (where
//! they differ, and NaNs compare equal to themselves).

/// IEEE equality with zero: true for `+0.0` and `-0.0`, false for NaN.
/// The sanctioned spelling of the sparse fast-path test `x == 0.0`,
/// where a signed zero still contributes nothing to an accumulation.
#[inline(always)]
pub fn exactly_zero_f32(x: f32) -> bool {
    x == 0.0
}

/// IEEE equality with zero for `f64`; see [`exactly_zero_f32`].
#[inline(always)]
pub fn exactly_zero_f64(x: f64) -> bool {
    x == 0.0
}

/// True iff `x` has no fractional part (an exact integer, including
/// ±0.0 and values too large to hold a fraction). False for NaN and
/// infinities (`fract` is NaN there).
#[inline(always)]
pub fn is_integral_f64(x: f64) -> bool {
    x.fract() == 0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_semantics_match_ieee() {
        assert!(exactly_zero_f32(0.0));
        assert!(exactly_zero_f32(-0.0));
        assert!(!exactly_zero_f32(f32::NAN));
        assert!(!exactly_zero_f32(f32::MIN_POSITIVE));
        assert!(exactly_zero_f64(0.0));
        assert!(exactly_zero_f64(-0.0));
        assert!(!exactly_zero_f64(f64::NAN));
    }

    #[test]
    fn integrality() {
        assert!(is_integral_f64(3.0));
        assert!(is_integral_f64(-0.0));
        assert!(is_integral_f64(1e300)); // no room for a fraction
        assert!(!is_integral_f64(3.5));
        assert!(!is_integral_f64(f64::NAN));
        assert!(!is_integral_f64(f64::INFINITY));
    }
}
