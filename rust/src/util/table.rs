//! Aligned text tables — every experiment harness prints the paper's
//! rows/series through this (plus CSV mirrors under results/).

#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // left-align first col, right-align numerics
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = w[i]));
                } else {
                    line.push_str(&format!("{:>width$}", c, width = w[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write the CSV mirror under `results/` (created on demand).
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Short human formatting helpers shared by tables.
pub fn fmt_si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.1}K", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}s")
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub fn fmt_bytes(b: f64) -> String {
    if b >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2}GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if b >= 1024.0 * 1024.0 {
        format!("{:.1}MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1}KiB", b / 1024.0)
    } else {
        format!("{b:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "x"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "200".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("long-name"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "2".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn si_format() {
        assert_eq!(fmt_si(1_500_000.0), "1.50M");
        assert_eq!(fmt_si(999.0), "999");
        assert_eq!(fmt_si(4_600_000_000.0), "4.60G");
    }

    #[test]
    fn secs_format() {
        assert_eq!(fmt_secs(0.0005), "500.0us");
        assert_eq!(fmt_secs(0.25), "250.00ms");
        assert_eq!(fmt_secs(2.5), "2.50s");
    }
}
