//! Key=value config files (offline image vendors no serde/toml).
//!
//! Format: one `key = value` per line, `#` comments, sections ignored.
//! CLI options override file values; see coordinator::job for the schema.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Config {
    kv: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut kv = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with('[') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or(format!("line {}: expected key = value", lineno + 1))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(Config { kv })
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn set(&mut self, key: &str, val: &str) {
        self.kv.insert(key.to_string(), val.to_string());
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.kv.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let c = Config::parse("p = 64\nscheme = lite\n# comment\n\nk=10").unwrap();
        assert_eq!(c.get("p"), Some("64"));
        assert_eq!(c.get("scheme"), Some("lite"));
        assert_eq!(c.parse_or::<usize>("k", 0), 10);
    }

    #[test]
    fn inline_comments_stripped() {
        let c = Config::parse("alpha = 2e-6 # seconds").unwrap();
        assert_eq!(c.parse_or::<f64>("alpha", 0.0), 2e-6);
    }

    #[test]
    fn bad_line_is_error() {
        assert!(Config::parse("just-a-word").is_err());
    }

    #[test]
    fn sections_ignored() {
        let c = Config::parse("[cluster]\np = 8").unwrap();
        assert_eq!(c.get("p"), Some("8"));
    }
}
