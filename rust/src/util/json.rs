//! Tiny JSON value + writer/parser (offline image vendors no serde).
//! Used for machine-readable experiment records under results/ and for
//! EXPERIMENTS.md appendices. The parser handles the subset we emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::float::is_integral_f64;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if is_integral_f64(*x) && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    x.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    let _ = write!(out, "{pad}\"{}\": ", escape(k));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }

    /// Parse the subset this module emits (sufficient for round-trips).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            c => vec![c],
        })
        .collect()
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_whitespace() {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.b.get(self.i) {
            None => Err("eof".into()),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.i += 1;
                let mut xs = Vec::new();
                self.ws();
                if self.b.get(self.i) == Some(&b']') {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    xs.push(self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => return Err(format!("bad array at {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.b.get(self.i) == Some(&b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    if self.b.get(self.i) != Some(&b':') {
                        return Err(format!("expected : at {}", self.i));
                    }
                    self.i += 1;
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.b.get(self.i) {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at {}", self.i)),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.b.get(self.i) != Some(&b'"') {
            return Err(format!("expected string at {}", self.i));
        }
        self.i += 1;
        let mut s = String::new();
        while let Some(&c) = self.b.get(self.i) {
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.b.get(self.i).copied().ok_or("eof in escape")?;
                    self.i += 1;
                    s.push(match e {
                        b'n' => '\n',
                        b't' => '\t',
                        b'"' => '"',
                        b'\\' => '\\',
                        _ => return Err("bad escape".into()),
                    });
                }
                c => s.push(c as char),
            }
        }
        Err("unterminated string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(format!("bad number at {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("lite".into()))
            .set("p", Json::Num(64.0))
            .set("times", Json::Arr(vec![Json::Num(1.5), Json::Num(2.0)]));
        let text = j.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": {"b": [1, 2, {"c": true}]}, "d": null}"#).unwrap();
        assert!(matches!(j.get("d"), Some(Json::Null)));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\nc".into());
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{oops}").is_err());
        assert!(Json::parse("[1,").is_err());
    }

    #[test]
    fn integers_render_clean() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(1.5).render(), "1.5");
    }
}
