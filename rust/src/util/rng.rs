//! Deterministic PRNG + distributions, built from scratch (the offline
//! image vendors no `rand`). SplitMix64 seeds a PCG-XSH-RR-like generator;
//! Zipf sampling drives the synthetic slice-size skew (DESIGN.md §2).

/// SplitMix64 — used for seeding and cheap stateless streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Main PRNG: xoshiro256** (public domain construction), seeded via
/// SplitMix64 so any u64 seed yields a well-mixed state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    /// Derive an independent stream (for per-mode / per-rank reproducibility).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The raw generator state — the "RNG cursor" a `SessionCheckpoint`
    /// captures so a restored run draws the exact same stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Rng::state`] cursor.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller (cached second value omitted for
    /// simplicity; callers are not throughput-bound on this).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n (used by MediumG index relabeling).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }
}

/// Zipf sampler over ranks 1..=n with exponent `s`, via rejection-inversion
/// (Hörmann-Derflinger). Drives the power-law slice-size skew that makes
/// real FROSTT tensors hard for CoarseG (paper §7.2: enron's 5M-element
/// slices vs a 105K average).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dd: f64,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1);
        let nf = n as f64;
        let h_x1 = Self::h(1.5, s) - 1.0;
        let h_n = Self::h(nf + 0.5, s);
        let dd = 12.0 * (Self::h_integral_inv_guard(s));
        Zipf { n: nf, s, h_x1, h_n, dd }
    }

    fn h(x: f64, s: f64) -> f64 {
        // integral of x^-s: H(x) = (x^{1-s} - 1)/(1-s), with the s=1 limit ln x
        if (s - 1.0).abs() < 1e-9 {
            x.ln()
        } else {
            (x.powf(1.0 - s) - 1.0) / (1.0 - s)
        }
    }

    fn h_inv(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-9 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - s)).powf(1.0 / (1.0 - s))
        }
    }

    fn h_integral_inv_guard(_s: f64) -> f64 {
        1.0
    }

    /// Sample a rank in [1, n].
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let _ = self.dd;
        loop {
            let u = self.h_n + rng.f64() * (self.h_x1 - self.h_n);
            let x = Self::h_inv(u, self.s);
            let k = x.round().clamp(1.0, self.n);
            // acceptance test
            let left = Self::h(k - 0.5, self.s);
            let right = Self::h(k + 0.5, self.s);
            let p = right - left; // mass proxy for rank k
            if rng.f64() * (Self::h(x + 0.5, self.s) - Self::h(x - 0.5, self.s)) <= p {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_small_ranks() {
        let z = Zipf::new(1000, 1.2);
        let mut rng = Rng::new(11);
        let mut low = 0usize;
        let mut n = 0usize;
        for _ in 0..5000 {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
            if k <= 10 {
                low += 1;
            }
            n += 1;
        }
        // with s=1.2, the top-10 ranks carry a large share of the mass
        assert!(low as f64 / n as f64 > 0.3, "low share {}", low);
    }

    #[test]
    fn normal_mean_near_zero() {
        let mut rng = Rng::new(13);
        let m: f64 = (0..20_000).map(|_| rng.normal()).sum::<f64>() / 20_000.0;
        assert!(m.abs() < 0.05, "mean {}", m);
    }

    #[test]
    fn state_roundtrip_resumes_the_exact_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let cursor = a.state();
        let mut b = Rng::from_state(cursor);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }
}
