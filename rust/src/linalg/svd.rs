//! One-sided Jacobi SVD for small dense matrices.
//!
//! The HOOI SVD step runs Lanczos bidiagonalization over the distributed
//! penultimate matrix (hooi::lanczos); what remains is the SVD of the tiny
//! J×J projected matrix (J = 2K ≤ 40), which this module solves directly.
//! One-sided Jacobi is simple, backward-stable and accurate for small
//! matrices — the role SLEPc's dense kernels play in the paper's stack.

use super::dense::{dot, norm2, scale, Mat};
use crate::util::float::exactly_zero_f64;

#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors, m×r (columns).
    pub u: Mat,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// Right singular vectors, n×r (columns of V, not V^T).
    pub v: Mat,
}

/// Compute the thin SVD of `a` (m×n, any shape) via one-sided Jacobi on the
/// taller orientation. r = min(m, n).
pub fn svd(a: &Mat) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        // A = U S V^T  =>  A^T = V S U^T
        let t = svd_tall(&a.transpose());
        Svd { u: t.v, s: t.s, v: t.u }
    }
}

fn svd_tall(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(m >= n);
    // Work columns of A; V accumulates the rotations.
    let mut cols: Vec<Vec<f32>> = (0..n).map(|j| a.col(j)).collect();
    let mut v = Mat::identity(n);
    let eps = 1e-10f64;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let alpha = dot(&cols[p], &cols[p]) as f64;
                let beta = dot(&cols[q], &cols[q]) as f64;
                let gamma = dot(&cols[p], &cols[q]) as f64;
                if exactly_zero_f64(alpha * beta) {
                    continue;
                }
                off = off.max(gamma.abs() / (alpha * beta).sqrt());
                if gamma.abs() <= eps * (alpha * beta).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let (cp, cq) = (cols[p][i], cols[q][i]);
                    cols[p][i] = cf * cp - sf * cq;
                    cols[q][i] = sf * cp + cf * cq;
                }
                for i in 0..n {
                    let (vp, vq) = (v.get(i, p), v.get(i, q));
                    v.set(i, p, cf * vp - sf * vq);
                    v.set(i, q, sf * vp + cf * vq);
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // singular values = column norms; U = normalized columns
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols.iter().map(|c| norm2(c)).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut vv = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (slot, &j) in order.iter().enumerate() {
        let nrm = norms[j];
        s.push(nrm as f32);
        let mut col = cols[j].clone();
        if nrm > 0.0 {
            scale(1.0 / nrm as f32, &mut col);
        }
        for i in 0..m {
            u.set(i, slot, col[i]);
        }
        for i in 0..n {
            vv.set(i, slot, v.get(i, j));
        }
    }
    Svd { u, s, v: vv }
}

impl Svd {
    /// Reconstruct U diag(S) V^T.
    pub fn reconstruct(&self) -> Mat {
        let r = self.s.len();
        let us = Mat::from_fn(self.u.rows, r, |i, j| self.u.get(i, j) * self.s[j]);
        us.matmul(&self.v.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::ortho_defect;
    use crate::util::rng::Rng;

    #[test]
    fn reconstructs_random_tall() {
        let mut rng = Rng::new(31);
        let a = Mat::from_fn(12, 5, |_, _| rng.normal() as f32);
        let d = svd(&a);
        assert!(d.reconstruct().max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn reconstructs_random_wide() {
        let mut rng = Rng::new(32);
        let a = Mat::from_fn(4, 9, |_, _| rng.normal() as f32);
        let d = svd(&a);
        assert!(d.reconstruct().max_abs_diff(&a) < 1e-4);
        assert_eq!(d.s.len(), 4);
    }

    #[test]
    fn singular_values_descend_and_factors_orthonormal() {
        let mut rng = Rng::new(33);
        let a = Mat::from_fn(20, 8, |_, _| rng.normal() as f32);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(ortho_defect(&d.u) < 1e-4);
        assert!(ortho_defect(&d.v) < 1e-4);
    }

    #[test]
    fn diagonal_matrix_exact() {
        let a = Mat::from_fn(3, 3, |r, c| {
            if r == c {
                [3.0, 1.0, 2.0][r]
            } else {
                0.0
            }
        });
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
        assert!((d.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rank_deficient_handled() {
        // two identical columns -> one zero singular value
        let a = Mat::from_rows(&[
            vec![1.0, 1.0, 0.0],
            vec![2.0, 2.0, 1.0],
            vec![3.0, 3.0, 0.0],
            vec![4.0, 4.0, 0.0],
        ]);
        let d = svd(&a);
        assert!(d.s[2] < 1e-4, "smallest sv {}", d.s[2]);
        assert!(d.reconstruct().max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn bidiagonal_case_matches_frobenius() {
        // the shape hooi::lanczos feeds: upper bidiagonal J×J
        let j = 8;
        let mut rng = Rng::new(34);
        let mut b = Mat::zeros(j, j);
        for i in 0..j {
            b.set(i, i, rng.f32() + 0.5);
            if i + 1 < j {
                b.set(i, i + 1, rng.f32());
            }
        }
        let d = svd(&b);
        let fro: f64 = b.frob_norm();
        let sv_fro: f64 = d.s.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        assert!((fro - sv_fro).abs() < 1e-4);
    }
}
