//! Dense row-major f32 matrices + the vector kernels HOOI needs.
//!
//! This replaces the paper's ATLAS dependency for everything outside the
//! PJRT-compiled hot path: factor matrices, Lanczos state, small SVDs.

use crate::util::float::exactly_zero_f32;

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.row_mut(i).copy_from_slice(row);
        }
        m
    }

    pub fn identity(n: usize) -> Self {
        Mat::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.get(c, r))
    }

    /// C = A * B (naive triple loop with the k-loop innermost over rows of
    /// B — row-major friendly; adequate for the small matrices on this
    /// path, the big multiplies go through PJRT).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            for (k, &aik) in arow.iter().enumerate() {
                if exactly_zero_f32(aik) {
                    continue;
                }
                let brow = b.row(k);
                let crow = c.row_mut(i);
                for (j, &bkj) in brow.iter().enumerate() {
                    crow[j] += aik * bkj;
                }
            }
        }
        c
    }

    /// y = A x
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }

    /// y = A^T x
    pub fn tmatvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0f32; self.cols];
        for (r, &xr) in x.iter().enumerate() {
            if exactly_zero_f32(xr) {
                continue;
            }
            axpy(xr, self.row(r), &mut y);
        }
        y
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Column c as an owned vector.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Keep the first k columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        Mat::from_fn(self.rows, k, |r, c| self.get(r, c))
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, b: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        self.data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // accumulate in f64 for stable Lanczos coefficients
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum::<f64>() as f32
}

#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_and_tmatvec_agree_with_transpose() {
        let a = Mat::from_fn(4, 3, |r, c| (r * 3 + c) as f32 * 0.5 - 1.0);
        let x3 = vec![1.0, -2.0, 0.5];
        let x4 = vec![0.25, 1.0, -1.0, 2.0];
        assert_eq!(a.matvec(&x3), a.transpose().tmatvec(&x3));
        assert_eq!(a.tmatvec(&x4), a.transpose().matvec(&x4));
    }

    #[test]
    fn identity_matmul_is_noop() {
        let a = Mat::from_fn(3, 3, |r, c| (r + 2 * c) as f32);
        let i = Mat::identity(3);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(5, 2, |r, c| (r * 7 + c * 3) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn dot_axpy_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, -1.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn take_cols_prefix() {
        let a = Mat::from_fn(2, 4, |r, c| (10 * r + c) as f32);
        let b = a.take_cols(2);
        assert_eq!(b.data, vec![0.0, 1.0, 10.0, 11.0]);
    }
}
