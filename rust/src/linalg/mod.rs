//! Dense linear algebra substrate (the paper uses ATLAS; we build the
//! pieces HOOI needs from scratch — see DESIGN.md §2).

pub mod dense;
pub mod qr;
pub mod svd;

pub use dense::{axpy, dot, norm2, scale, Mat};
pub use qr::{orthonormal_random, qr_mgs};
pub use svd::{svd, Svd};
