//! Thin QR via modified Gram-Schmidt with one reorthogonalization pass.
//!
//! Used to orthonormalize random initial factor matrices (HOOI bootstrap,
//! paper §2.2: "a random set of factor matrices can also be used") and in
//! tests as an orthogonality oracle.

use super::dense::{axpy, dot, norm2, scale, Mat};

/// Returns (Q, R) with A = Q R, Q: m×n column-orthonormal (requires m ≥ n).
pub fn qr_mgs(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin QR needs rows >= cols");
    // work on columns
    let mut q: Vec<Vec<f32>> = (0..n).map(|j| a.col(j)).collect();
    let mut r = Mat::zeros(n, n);
    for j in 0..n {
        // two MGS passes for numerical robustness
        for _pass in 0..2 {
            for i in 0..j {
                let rij = dot(&q[i], &q[j]);
                r.set(i, j, r.get(i, j) + rij);
                let qi = q[i].clone();
                axpy(-rij, &qi, &mut q[j]);
            }
        }
        let nrm = norm2(&q[j]) as f32;
        r.set(j, j, nrm);
        if nrm > 0.0 {
            scale(1.0 / nrm, &mut q[j]);
        }
    }
    let mut qm = Mat::zeros(m, n);
    for (j, col) in q.iter().enumerate() {
        for i in 0..m {
            qm.set(i, j, col[i]);
        }
    }
    (qm, r)
}

/// Column-orthonormalize a random matrix (bootstrap factor matrices).
///
/// When `rows < cols` (a scaled-down analogue can have L_n < K; the
/// paper's tensors never do) only the first `rows` columns can be
/// orthonormal — the remainder are zero, which keeps every downstream
/// computation well-defined: zero factor columns contribute nothing to
/// Kronecker rows, and the SVD step naturally reproduces rank ≤ L_n.
pub fn orthonormal_random(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Mat {
    let rank = cols.min(rows);
    let a = Mat::from_fn(rows, rank, |_, _| rng.normal() as f32);
    let q = qr_mgs(&a).0;
    if rank == cols {
        return q;
    }
    Mat::from_fn(rows, cols, |r, c| if c < rank { q.get(r, c) } else { 0.0 })
}

/// ||Q^T Q - I||_max — orthogonality defect, used by tests.
pub fn ortho_defect(q: &Mat) -> f32 {
    let qtq = q.transpose().matmul(q);
    let mut worst = 0.0f32;
    for i in 0..qtq.rows {
        for j in 0..qtq.cols {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((qtq.get(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(5);
        let a = Mat::from_fn(20, 6, |_, _| rng.normal() as f32);
        let (q, r) = qr_mgs(&a);
        let back = q.matmul(&r);
        assert!(back.max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(6);
        let a = Mat::from_fn(50, 10, |_, _| rng.normal() as f32);
        let (q, _) = qr_mgs(&a);
        assert!(ortho_defect(&q) < 1e-5);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(7);
        let a = Mat::from_fn(12, 5, |_, _| rng.normal() as f32);
        let (_, r) = qr_mgs(&a);
        for i in 0..r.rows {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn orthonormal_random_is_orthonormal() {
        let mut rng = Rng::new(8);
        let q = orthonormal_random(40, 8, &mut rng);
        assert!(ortho_defect(&q) < 1e-5);
    }
}
