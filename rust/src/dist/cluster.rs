//! The simulated P-rank cluster. Compute phases really execute and are
//! timed per rank; the phase charges the *makespan* (max per-rank time) to
//! the elapsed bucket, so the totals behave like a synchronized SPMD run.
//! Communication is charged to the α–β [`NetModel`] with exact unit
//! volumes ([`SimCluster::p2p`], [`SimCluster::allreduce`]).
//!
//! Execution model: per-rank closures run on a scoped-thread worker pool
//! capped at the host's available parallelism (never oversubscribed, so
//! the per-rank wall-times that feed the simulation stay honest — a rank
//! timed while descheduled would inflate the simulated makespan). Results
//! are always collected in rank order, so any reduction the caller does
//! over them is bit-identical to serial execution. Set
//! `TUCKER_PHASE_EXECUTOR=serial` (or use [`SimCluster::serial`] /
//! [`SimCluster::with_parallel`]) to force the serial executor, e.g. for
//! timing-sensitive figure runs on a busy host.
//!
//! Failure model: the phase methods are *fallible*. Each task runs under
//! `catch_unwind`, so a panicking rank closure surfaces as a
//! [`RankFailure`] from the phase call instead of tearing the process
//! down, and an armed [`FaultInjector`] (see [`super::fault`]) can
//! deterministically fail or slow chosen ranks at chosen `(sweep,
//! phase)` positions. Phase positions are tracked by
//! [`SimCluster::begin_sweep`] plus a per-sweep compute-phase counter.
//! Communication charges (`p2p`/`allreduce`) run on the configured
//! [`Transport`] and are failure points too: under `ChannelTransport` a
//! really hung, crashed, or corrupting peer surfaces as a [`RankFailure`]
//! classified by the transport's liveness monitor. Regardless of
//! transport, the *predicted* α–β cost is what lands in `elapsed` /
//! `volume` (so accounting is transport-invariant and decompositions stay
//! bit-identical); what the transport actually measured lands in the
//! separate `net_measured` / `net_units_measured` buckets, and
//! [`SimCluster::net_model_error`] reports the relative gap per category.

use super::fault::{FailureKind, FaultInjector, FaultKind, RankFailure};
use super::net::NetModel;
use super::transport::{
    self, Transport, TransportChoice, TransportFailure, TransportStats, TransportTuning,
};
use crate::util::timer::Buckets;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use crate::util::timer::Stopwatch;

/// Phase / volume category names, shared by the HOOI driver, the oracle
/// and the experiment harness (Fig 11 breakup keys off these).
pub mod cat {
    /// TTM assembly compute.
    pub const TTM: &str = "ttm";
    /// SVD (Lanczos) compute.
    pub const SVD: &str = "svd";
    /// End-of-run core computation (G = F̃^T·Z partials).
    pub const CORE: &str = "core";
    /// Distribution construction (Fig 16).
    pub const DIST: &str = "dist";
    /// Streaming rebalance redistribution: Lite re-plan of the flagged
    /// modes plus the element migration a `MigrationPlan` puts on the
    /// wire. Charged by the session when a rebalance lands, reported as
    /// `RunRecord::redist_secs` alongside the Fig 16 distribution time.
    pub const REDIST: &str = "redist";
    /// Fault recovery: survivor re-placement of a dead rank's elements,
    /// checkpoint rollback, and the migration a recovery puts on the
    /// wire. Reported as `RunRecord::recovery_secs` alongside the HOOI
    /// phase breakdown (which stays sum-invariant without it).
    pub const RECOVER: &str = "recover";
    /// Oracle query communication (x/y reductions).
    pub const COMM_SVD: &str = "comm-svd";
    /// Factor-matrix transfer communication.
    pub const COMM_FM: &str = "comm-fm";
    /// Common collectives (dots, norms, core allreduce).
    pub const COMM_COMMON: &str = "comm-common";

    /// The Fig 11 phase-sum partition, side A: categories whose elapsed
    /// seconds are **inside** `RunRecord::hooi_secs`. `collect_record`
    /// folds over this array, and lint rule L5 (`cargo run -p
    /// tucker-lint`) checks that every category above appears in exactly
    /// one of the two partition arrays — adding a category without
    /// deciding its accounting side is a build-breaking offence.
    pub const IN_PHASE_SUM: &[&str] = &[TTM, SVD, CORE, COMM_SVD, COMM_FM, COMM_COMMON];

    /// Partition side B: categories reported in their own `RunRecord`
    /// buckets, **outside** `hooi_secs` (distribution construction,
    /// streaming redistribution, fault recovery).
    pub const OUT_OF_PHASE_SUM: &[&str] = &[DIST, REDIST, RECOVER];
}

/// Per-phase concurrency provenance: how a category's compute phases
/// were actually produced. Recorded into `RunRecord` so figure CSVs
/// carry their own execution conditions.
#[derive(Debug, Clone)]
pub struct ConcurrencyReport {
    /// `"parallel"` (scoped-thread executor) or `"serial"`.
    pub executor: &'static str,
    /// Worker threads the executor can use (1 when serial).
    pub workers: usize,
    /// Microkernel the ranks recorded (`"mixed"` if they disagree,
    /// `"unrecorded"` if the phase never reported one).
    pub kernel: &'static str,
    /// Measured speedup: Σ per-rank busy seconds / wall seconds of the
    /// category's compute phases (≈1.0 under the serial executor).
    pub speedup: f64,
}

/// Simulated cluster of `p` ranks accumulating elapsed time and
/// communication volume per category.
#[derive(Debug)]
pub struct SimCluster {
    /// World size P.
    pub p: usize,
    /// Network model for communication charging.
    pub net: NetModel,
    /// Simulated seconds per category (makespans + comm charges).
    pub elapsed: Buckets,
    /// Communication volume per category, in units (one f32 = one unit).
    pub volume: Buckets,
    /// Predicted α–β seconds per comm category (mirror of what `elapsed`
    /// received from communication; comm-only, no compute makespans).
    pub net_predicted: Buckets,
    /// Transport-measured seconds per comm category. Under `SimTransport`
    /// this equals `net_predicted` by definition; under `ChannelTransport`
    /// it is the wall time of the real byte exchange.
    pub net_measured: Buckets,
    /// Predicted units per comm category (mirror of `volume`'s comm part).
    pub net_units_predicted: Buckets,
    /// Transport-measured delivered units per comm category.
    pub net_units_measured: Buckets,
    /// Σ per-rank busy seconds per compute category (elapsed holds the
    /// makespans; busy/wall is the measured executor speedup).
    pub busy: Buckets,
    /// Host wall seconds per compute category (what the phases really
    /// cost this process, executor overhead included).
    pub wall: Buckets,
    /// Per-rank busy seconds of the most recent phase (diagnostics;
    /// straggler inflation included).
    pub last_phase: Vec<f64>,
    /// Kernel names the ranks reported, keyed by compute category (rank
    /// order within each entry; see [`SimCluster::record_kernels`]).
    /// Keyed so one category's provenance (e.g. SVD) never reports
    /// another's kernels (e.g. the TTM microkernel names).
    kernels: Vec<(String, Vec<&'static str>)>,
    parallel: bool,
    /// Pin parallel-executor workers to CPUs with static round-robin
    /// rank assignment (NUMA first-touch placement; see
    /// [`run_scoped_pinned`]).
    pin: bool,
    /// Armed fault schedule (None = fault-free run; panics are still
    /// caught and surfaced as failures).
    injector: Option<FaultInjector>,
    /// Current sweep label for failure reporting / fault addressing.
    sweep: usize,
    /// Compute-phase counter within the current sweep.
    phase_idx: usize,
    /// Straggler escalation threshold in simulated seconds (from the
    /// session's `RetryPolicy`); `None` means stragglers only slow the
    /// makespan.
    phase_timeout: Option<f64>,
    /// The communication transport: analytic charger or real in-process
    /// byte mover (see [`super::transport`]).
    transport: Box<dyn Transport>,
}

impl SimCluster {
    /// New cluster; the parallel rank executor is enabled when the host
    /// has more than one core and `TUCKER_PHASE_EXECUTOR` is not `serial`
    /// (the env read is centralized in `util::env`; typed callers pass
    /// their choice through [`SimCluster::with_parallel`]).
    pub fn new(p: usize) -> SimCluster {
        let parallel = crate::util::env::phase_executor_parallel(None);
        let pin = crate::util::env::pin_threads(None);
        let choice = crate::util::env::transport_choice(None);
        SimCluster {
            p,
            net: NetModel::default(),
            elapsed: Buckets::new(),
            volume: Buckets::new(),
            net_predicted: Buckets::new(),
            net_measured: Buckets::new(),
            net_units_predicted: Buckets::new(),
            net_units_measured: Buckets::new(),
            busy: Buckets::new(),
            wall: Buckets::new(),
            last_phase: Vec::new(),
            kernels: Vec::new(),
            parallel,
            pin,
            injector: None,
            sweep: 0,
            phase_idx: 0,
            phase_timeout: None,
            transport: transport::from_choice(choice, p, TransportTuning::default()),
        }
    }

    /// New cluster with the serial executor (reference semantics).
    pub fn serial(p: usize) -> SimCluster {
        SimCluster::new(p).with_parallel(false)
    }

    pub fn with_net(mut self, net: NetModel) -> SimCluster {
        self.net = net;
        self
    }

    /// Force the executor on or off (overrides the host/env default).
    pub fn with_parallel(mut self, on: bool) -> SimCluster {
        self.parallel = on;
        self
    }

    /// Force worker pinning on or off (overrides the
    /// `TUCKER_PIN_THREADS` env default). Only meaningful with the
    /// parallel executor; pinned phases assign ranks to workers
    /// statically so first-touch pages stay on their worker's socket.
    pub fn with_pinned(mut self, on: bool) -> SimCluster {
        self.pin = on;
        self
    }

    /// Is worker pinning active?
    pub fn is_pinned(&self) -> bool {
        self.pin
    }

    /// Builder form of [`set_transport`](Self::set_transport).
    pub fn with_transport(mut self, transport: Box<dyn Transport>) -> SimCluster {
        self.transport = transport;
        self
    }

    /// Replace the communication transport (typed callers — the session
    /// builder — override the `TUCKER_TRANSPORT` env default here).
    pub fn set_transport(&mut self, transport: Box<dyn Transport>) {
        self.transport = transport;
    }

    /// Convenience: install a fresh transport for `choice` with `tuning`.
    pub fn set_transport_choice(&mut self, choice: TransportChoice, tuning: TransportTuning) {
        self.transport = transport::from_choice(choice, self.p, tuning);
    }

    /// Name of the active transport ("sim" / "channel").
    pub fn transport_name(&self) -> &'static str {
        self.transport.name()
    }

    /// Traffic counters from the active transport.
    pub fn transport_stats(&self) -> TransportStats {
        self.transport.stats()
    }

    /// Tell the transport a rank has been evicted: future collectives run
    /// over the survivors only.
    pub fn mark_rank_dead(&mut self, rank: usize) {
        self.transport.mark_dead(rank);
    }

    /// Predicted-vs-measured `NetModel` error per comm category: signed
    /// relative seconds error `(measured − predicted) / predicted`,
    /// exactly `0.0` under `SimTransport` (measured is defined as the
    /// prediction) and for categories with no predicted cost.
    pub fn net_model_error(&self) -> Vec<(String, f64)> {
        self.net_predicted
            .iter()
            .map(|(cat, pred)| {
                let err = if pred > 0.0 {
                    (self.net_measured.get(cat) - pred) / pred
                } else {
                    0.0
                };
                (cat.to_string(), err)
            })
            .collect()
    }

    /// Arm a fault injector: subsequent compute phases consult it at
    /// their `(sweep, phase)` position and fail the ranks it fires.
    pub fn set_injector(&mut self, injector: FaultInjector) {
        self.injector = Some(injector);
    }

    /// The armed injector, if any (recovery bookkeeping reads the
    /// fired-fault count and dead-rank tombstones from here).
    pub fn injector(&self) -> Option<&FaultInjector> {
        self.injector.as_ref()
    }

    /// Straggler escalation threshold in simulated seconds (`None`
    /// disables escalation — stragglers then only slow the makespan).
    pub fn set_phase_timeout(&mut self, timeout: Option<f64>) {
        self.phase_timeout = timeout;
    }

    /// Faults fired so far by the armed injector (0 when none armed).
    pub fn faults_injected(&self) -> usize {
        self.injector.as_ref().map_or(0, FaultInjector::faults_injected)
    }

    /// Label the phases that follow as belonging to `sweep` (0-based)
    /// and reset the per-sweep compute-phase counter. The HOOI driver
    /// calls this at every sweep boundary so fault positions and failure
    /// reports are addressed consistently.
    pub fn begin_sweep(&mut self, sweep: usize) {
        self.sweep = sweep;
        self.phase_idx = 0;
        if let Some(inj) = self.injector.as_mut() {
            inj.begin_sweep(sweep);
        }
    }

    /// Is the parallel rank executor active?
    pub fn is_parallel(&self) -> bool {
        self.parallel
    }

    /// Worker threads the rank executor can use (1 when serial).
    pub fn workers(&self) -> usize {
        if self.parallel {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            self.p.min(cores).max(1)
        } else {
            1
        }
    }

    /// Record which microkernel each rank executes for one compute
    /// category (the HOOI driver reports its TTM workspaces under
    /// [`cat::TTM`]). Later records for the same category replace
    /// earlier ones; categories that never report stay `"unrecorded"`.
    pub fn record_kernels(&mut self, cat: &str, names: Vec<&'static str>) {
        if let Some(e) = self.kernels.iter_mut().find(|(c, _)| c == cat) {
            e.1 = names;
        } else {
            self.kernels.push((cat.to_string(), names));
        }
    }

    /// Kernel names recorded for one category (empty if never reported).
    fn kernels_of(&self, cat: &str) -> &[&'static str] {
        self.kernels
            .iter()
            .find(|(c, _)| c == cat)
            .map(|(_, names)| names.as_slice())
            .unwrap_or(&[])
    }

    /// Concurrency provenance for one compute category — see
    /// [`ConcurrencyReport`].
    pub fn concurrency_report(&self, cat: &str) -> ConcurrencyReport {
        let busy = self.busy.get(cat);
        let wall = self.wall.get(cat);
        let recorded = self.kernels_of(cat);
        let kernel = match recorded.first() {
            Some(&k) if recorded.iter().all(|&n| n == k) => k,
            Some(_) => "mixed",
            None => "unrecorded",
        };
        ConcurrencyReport {
            executor: if self.parallel { "parallel" } else { "serial" },
            workers: self.workers(),
            kernel,
            speedup: if wall > 0.0 { busy / wall } else { 1.0 },
        }
    }

    /// Consult the injector for the compute phase starting now,
    /// advancing the per-sweep phase counter. Returns the per-rank
    /// actions plus the phase's position label.
    fn arm_phase(&mut self, n: usize) -> (Vec<Option<FaultKind>>, usize) {
        let phase = self.phase_idx;
        self.phase_idx += 1;
        let actions = match self.injector.as_mut() {
            Some(inj) => inj.arm(phase, n),
            None => vec![None; n],
        };
        (actions, phase)
    }

    /// Classify the lowest failed rank of a finished phase, if any:
    /// caught panics first, then injected crash/transient faults, then
    /// straggler timeouts. `times` already carries straggler inflation.
    fn classify_failure(
        &self,
        cat: &str,
        phase: usize,
        actions: &[Option<FaultKind>],
        panics: &[Option<String>],
        times: &[f64],
    ) -> Option<RankFailure> {
        for rank in 0..actions.len().max(panics.len()) {
            let (kind, detail) = if let Some(msg) = panics.get(rank).and_then(Clone::clone) {
                (FailureKind::Panic, format!("caught panic: {msg}"))
            } else {
                match actions.get(rank).copied().flatten() {
                    Some(FaultKind::Crash) => {
                        (FailureKind::Crash, "injected rank crash".to_string())
                    }
                    Some(FaultKind::Transient) => (
                        FailureKind::Transient,
                        "injected transient failure".to_string(),
                    ),
                    Some(FaultKind::Straggler(factor)) => {
                        let secs = times.get(rank).copied().unwrap_or(0.0);
                        match self.phase_timeout {
                            Some(limit) if secs > limit => (
                                FailureKind::StragglerTimeout,
                                format!(
                                    "straggler x{factor:.1} took {secs:.3e}s > timeout {limit:.3e}s"
                                ),
                            ),
                            _ => continue,
                        }
                    }
                    None => continue,
                }
            };
            return Some(RankFailure {
                rank,
                cat: cat.to_string(),
                sweep: self.sweep,
                phase,
                kind,
                detail,
            });
        }
        None
    }

    /// Execute one closure per rank, record per-rank wall-times, charge
    /// the makespan to `cat`, and return the results in rank order — or
    /// the lowest failed rank's [`RankFailure`]. Time is charged either
    /// way (the work ran before the failure was detected).
    fn run_tasks<T, F>(&mut self, cat: &str, tasks: Vec<F>) -> Result<Vec<T>, RankFailure>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = tasks.len();
        let (actions, phase) = self.arm_phase(n);
        let guarded: Vec<_> = tasks
            .into_iter()
            .map(|task| move || catch_unwind(AssertUnwindSafe(task)))
            .collect();
        let t0 = Stopwatch::start();
        let timed = run_scoped_pinned(guarded, self.parallel, self.pin);
        let wall = t0.seconds();
        let mut times = Vec::with_capacity(n);
        let mut results: Vec<Option<T>> = Vec::with_capacity(n);
        let mut panics: Vec<Option<String>> = vec![None; n];
        for (rank, (outcome, mut secs)) in timed.into_iter().enumerate() {
            if let Some(FaultKind::Straggler(factor)) = actions.get(rank).copied().flatten() {
                secs *= factor.max(1.0);
            }
            match outcome {
                Ok(v) => results.push(Some(v)),
                Err(payload) => {
                    panics[rank] = Some(panic_message(payload.as_ref()));
                    results.push(None);
                }
            }
            times.push(secs);
        }
        let makespan = times.iter().copied().fold(0.0, f64::max);
        self.elapsed.add(cat, makespan);
        self.busy.add(cat, times.iter().sum::<f64>());
        self.wall.add(cat, wall);
        let failure = self.classify_failure(cat, phase, &actions, &panics, &times);
        self.last_phase = times;
        match failure {
            Some(f) => Err(f),
            None => Ok(results.into_iter().flatten().collect()),
        }
    }

    /// Serial phase (legacy / order-dependent callers): run `f(rank)` for
    /// every rank in order, charging the makespan. Use [`phase_map`] or
    /// [`phase_tasks`] for the parallel executor.
    ///
    /// [`phase_map`]: SimCluster::phase_map
    /// [`phase_tasks`]: SimCluster::phase_tasks
    pub fn phase(&mut self, cat: &str, mut f: impl FnMut(usize)) -> Result<(), RankFailure> {
        let (actions, phase) = self.arm_phase(self.p);
        let mut times = vec![0.0f64; self.p];
        let mut panics: Vec<Option<String>> = vec![None; self.p];
        for rank in 0..self.p {
            let t0 = Stopwatch::start();
            let outcome = catch_unwind(AssertUnwindSafe(|| f(rank)));
            let mut secs = t0.seconds();
            if let Some(FaultKind::Straggler(factor)) = actions.get(rank).copied().flatten() {
                secs *= factor.max(1.0);
            }
            times[rank] = secs;
            if let Err(payload) = outcome {
                panics[rank] = Some(panic_message(payload.as_ref()));
            }
        }
        let makespan = times.iter().copied().fold(0.0, f64::max);
        self.elapsed.add(cat, makespan);
        let total: f64 = times.iter().sum();
        self.busy.add(cat, total);
        self.wall.add(cat, total);
        let failure = self.classify_failure(cat, phase, &actions, &panics, &times);
        self.last_phase = times;
        match failure {
            Some(f) => Err(f),
            None => Ok(()),
        }
    }

    /// Parallel phase over a shared closure: results come back in rank
    /// order, so rank-ordered reductions are bit-identical to serial.
    pub fn phase_map<T, F>(&mut self, cat: &str, f: F) -> Result<Vec<T>, RankFailure>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let fr = &f;
        let tasks: Vec<_> = (0..self.p).map(|rank| move || fr(rank)).collect();
        self.run_tasks(cat, tasks)
    }

    /// Parallel phase over per-rank closures (one per rank, in rank
    /// order) — the form that lets each rank own `&mut` state such as its
    /// TTM plan workspace.
    pub fn phase_tasks<T, F>(&mut self, cat: &str, tasks: Vec<F>) -> Result<Vec<T>, RankFailure>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        self.run_tasks(cat, tasks)
    }

    /// Point-to-point round: `per_rank[r] = (messages, units)` sent by
    /// rank r. Runs on the transport; the *predicted* time (max over ranks
    /// of α·msgs + β·units — rounds overlap across ranks) and volume
    /// (Σ units) are charged to `cat`, while the transport's measurement
    /// lands in the `net_measured` buckets. A transport-detected peer
    /// failure aborts the round: the predicted cost is then charged to
    /// [`cat::RECOVER`] instead (the phase never completed, so it must not
    /// pollute the Fig 11 phase sums) and the classified [`RankFailure`]
    /// is returned.
    pub fn p2p(&mut self, cat: &str, per_rank: &[(u64, u64)]) -> Result<(), RankFailure> {
        let pred_secs = self.net.p2p(per_rank);
        let pred_units = self.net.p2p_volume(per_rank) as f64;
        match self.transport.p2p(&self.net, per_rank) {
            Ok(m) => {
                self.charge_comm(cat, pred_secs, pred_units, m.secs, m.units);
                Ok(())
            }
            Err(f) => Err(self.comm_failure(cat, pred_secs, f)),
        }
    }

    /// Allreduce of `units` units across all ranks. Same charging and
    /// failure contract as [`p2p`](Self::p2p).
    pub fn allreduce(&mut self, cat: &str, units: u64) -> Result<(), RankFailure> {
        let pred_secs = self.net.allreduce(self.p, units);
        let pred_units = self.net.allreduce_volume(self.p, units);
        match self.transport.allreduce(&self.net, self.p, units) {
            Ok(m) => {
                self.charge_comm(cat, pred_secs, pred_units, m.secs, m.units);
                Ok(())
            }
            Err(f) => Err(self.comm_failure(cat, pred_secs, f)),
        }
    }

    /// Book one successful collective: predicted α–β cost into the
    /// category's `elapsed`/`volume` (transport-invariant accounting),
    /// prediction and measurement side by side into the `net_*` buckets.
    fn charge_comm(
        &mut self,
        cat: &str,
        pred_secs: f64,
        pred_units: f64,
        meas_secs: f64,
        meas_units: f64,
    ) {
        self.elapsed.add(cat, pred_secs);
        self.volume.add(cat, pred_units);
        self.net_predicted.add(cat, pred_secs);
        self.net_measured.add(cat, meas_secs);
        self.net_units_predicted.add(cat, pred_units);
        self.net_units_measured.add(cat, meas_units);
    }

    /// Book one failed collective and build its [`RankFailure`]. The
    /// aborted round's predicted cost goes to [`cat::RECOVER`] — never to
    /// the comm category or its volume — so the Fig 11 phase-sum
    /// invariance holds under real faults too.
    fn comm_failure(&mut self, cat: &str, pred_secs: f64, f: TransportFailure) -> RankFailure {
        self.elapsed.add(self::cat::RECOVER, pred_secs);
        RankFailure {
            rank: f.rank,
            cat: cat.to_string(),
            sweep: self.sweep,
            phase: self.phase_idx,
            kind: f.kind,
            detail: f.detail,
        }
    }

    /// Charge measured serial seconds of perfectly-distributable work:
    /// every rank does 1/P of it.
    pub fn charge_balanced(&mut self, cat: &str, secs: f64) {
        self.elapsed.add(cat, secs / self.p.max(1) as f64);
    }
}

/// Best-effort panic payload message for failure reports.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Execute independent tasks on a scoped worker pool of
/// `min(tasks, host cores)` threads (serial when `parallel` is false),
/// returning `(result, busy seconds)` per task in input order.
///
/// Workers claim tasks off a shared counter — never oversubscribed, so
/// each measured time is an honest busy time for that task (a task timed
/// while descheduled would inflate any makespan derived from it). Also
/// used outside the cluster for independent per-rank setup work (e.g.
/// TTM plan compilation in `hooi::prepare_modes`).
pub fn run_scoped<T, F>(tasks: Vec<F>, parallel: bool) -> Vec<(T, f64)>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    run_scoped_pinned(tasks, parallel, false)
}

/// [`run_scoped`] with optional NUMA-aware worker pinning. With `pin`
/// on, worker `w` pins itself to CPU `w` and runs the statically
/// assigned tasks `w, w+workers, w+2·workers, …` instead of claiming
/// off the shared counter: rank `r`'s work lands on the same CPU every
/// phase, so the pages its first task touches (plan buffers, the Z
/// arena a workspace grows on first assembly) stay local to that
/// socket, and per-rank timings stop depending on which worker happened
/// to claim the rank. Pinning is best-effort (`sched_setaffinity` may
/// be denied under cpuset restrictions; non-Linux hosts no-op) and
/// bit-neutral either way: results are slot-indexed, so assignment
/// order never changes them.
pub fn run_scoped_pinned<T, F>(tasks: Vec<F>, parallel: bool, pin: bool) -> Vec<(T, f64)>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = tasks.len();
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    let workers = if parallel { n.min(cores) } else { 1 };
    if workers <= 1 || n <= 1 {
        return tasks
            .into_iter()
            .map(|task| {
                let t0 = Stopwatch::start();
                let r = task();
                (r, t0.seconds())
            })
            .collect();
    }
    let slots: Vec<Mutex<Option<F>>> =
        tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let done: Vec<Mutex<Option<(T, f64)>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let run_task = |i: usize| {
        let task = slots[i]
            .lock()
            .unwrap()
            .take()
            .expect("each task is claimed exactly once");
        let t0 = Stopwatch::start();
        let r = task();
        *done[i].lock().unwrap() = Some((r, t0.seconds()));
    };
    std::thread::scope(|s| {
        for w in 0..workers {
            let run_task = &run_task;
            let next = &next;
            s.spawn(move || {
                if pin {
                    pin_current_thread(w);
                    // static round-robin: stable task→CPU mapping
                    for i in (w..n).step_by(workers) {
                        run_task(i);
                    }
                } else {
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        run_task(i);
                    }
                }
            });
        }
    });
    done.into_iter()
        .map(|cell| {
            cell.into_inner()
                .unwrap()
                .expect("worker completed every claimed task")
        })
        .collect()
}

/// Pin the calling thread to one CPU via `sched_setaffinity` (the
/// declaration is local — the crate links libc anyway and takes no
/// crate dependencies). Best-effort: failures (cpuset restrictions,
/// CPU index beyond the mask) leave the thread unpinned.
#[cfg(target_os = "linux")]
fn pin_current_thread(cpu: usize) {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const usize) -> i32;
    }
    const BITS: usize = usize::BITS as usize;
    // 1024-CPU mask, the kernel's historical cpu_set_t width
    let mut mask = [0usize; 1024 / BITS];
    let word = cpu / BITS;
    if word >= mask.len() {
        return;
    }
    mask[word] |= 1usize << (cpu % BITS);
    // SAFETY: pid 0 = calling thread; the mask buffer outlives the call
    // and its length is passed exactly.
    unsafe {
        let _ = sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
    }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_cpu: usize) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::fault::FaultPlan;

    #[test]
    fn phase_sum_partition_is_disjoint() {
        // lint L5 checks coverage (every cat const appears somewhere);
        // this checks the other half: no category is counted twice
        for c in cat::IN_PHASE_SUM {
            assert!(
                !cat::OUT_OF_PHASE_SUM.contains(c),
                "category {c} appears on both sides of the phase-sum partition"
            );
        }
        let mut all: Vec<&str> = cat::IN_PHASE_SUM
            .iter()
            .chain(cat::OUT_OF_PHASE_SUM)
            .copied()
            .collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate category within a partition side");
    }

    #[test]
    fn run_scoped_preserves_order_and_times() {
        let tasks: Vec<_> = (0..6u64)
            .map(|i| move || (0..2_000).map(|j| i * j).sum::<u64>())
            .collect();
        let par = run_scoped(tasks, true);
        let tasks: Vec<_> = (0..6u64)
            .map(|i| move || (0..2_000).map(|j| i * j).sum::<u64>())
            .collect();
        let ser = run_scoped(tasks, false);
        let pv: Vec<u64> = par.iter().map(|(r, _)| *r).collect();
        let sv: Vec<u64> = ser.iter().map(|(r, _)| *r).collect();
        assert_eq!(pv, sv);
        assert!(par.iter().all(|&(_, s)| s >= 0.0));
    }

    #[test]
    fn pinned_executor_matches_unpinned_results() {
        // static round-robin under pinning returns the same slot-ordered
        // results as dynamic claiming (pinning itself is best-effort)
        let mk = || {
            (0..7u64)
                .map(|i| move || (0..2_000).map(|j| i ^ j).sum::<u64>())
                .collect::<Vec<_>>()
        };
        let pinned: Vec<u64> =
            run_scoped_pinned(mk(), true, true).into_iter().map(|(r, _)| r).collect();
        let plain: Vec<u64> =
            run_scoped_pinned(mk(), true, false).into_iter().map(|(r, _)| r).collect();
        let serial: Vec<u64> =
            run_scoped_pinned(mk(), false, true).into_iter().map(|(r, _)| r).collect();
        assert_eq!(pinned, plain);
        assert_eq!(pinned, serial);
    }

    #[test]
    fn phase_charges_makespan_not_sum() {
        let mut c = SimCluster::serial(3);
        c.phase("work", |rank| {
            // rank 2 does ~10x the work of rank 0
            let n = 10_000 * (rank + 1) * (rank + 1);
            std::hint::black_box((0..n).sum::<usize>());
        })
        .unwrap();
        let max = c.last_phase.iter().copied().fold(0.0, f64::max);
        assert_eq!(c.last_phase.len(), 3);
        assert!((c.elapsed.get("work") - max).abs() < 1e-12);
        assert!(c.elapsed.get("work") < c.last_phase.iter().sum::<f64>());
    }

    #[test]
    fn phase_map_results_in_rank_order_parallel_and_serial() {
        let mut par = SimCluster::new(8).with_parallel(true);
        let mut ser = SimCluster::serial(8);
        let f = |rank: usize| (0..1000u64).map(|i| i * rank as u64).sum::<u64>();
        let a = par.phase_map("w", f).unwrap();
        let b = ser.phase_map("w", f).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert_eq!(par.last_phase.len(), 8);
    }

    #[test]
    fn phase_tasks_allows_mutable_per_rank_state() {
        let mut c = SimCluster::new(4);
        let mut scratch: Vec<Vec<u64>> = vec![Vec::new(); 4];
        let tasks: Vec<_> = scratch
            .iter_mut()
            .enumerate()
            .map(|(rank, buf)| {
                move || {
                    buf.push(rank as u64 + 1);
                    buf.iter().sum::<u64>()
                }
            })
            .collect();
        let out = c.phase_tasks("w", tasks).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4]);
        assert_eq!(scratch[3], vec![4]);
    }

    #[test]
    fn p2p_charges_worst_rank_and_total_volume() {
        let mut c = SimCluster::serial(3).with_net(NetModel { alpha: 1.0, beta: 0.1 });
        c.p2p("comm", &[(1, 10), (2, 5), (0, 0)]).unwrap();
        // worst = max(1 + 1.0, 2 + 0.5, 0) = 2.5
        assert!((c.elapsed.get("comm") - 2.5).abs() < 1e-12);
        assert_eq!(c.volume.get("comm"), 15.0);
    }

    #[test]
    fn allreduce_single_rank_is_free() {
        let mut c = SimCluster::serial(1);
        c.allreduce("comm", 1_000).unwrap();
        assert_eq!(c.elapsed.get("comm"), 0.0);
        assert_eq!(c.volume.get("comm"), 0.0);
    }

    #[test]
    fn sim_transport_measures_exactly_the_model() {
        use crate::dist::transport::SimTransport;
        let mut c = SimCluster::serial(4)
            .with_net(NetModel { alpha: 1.0, beta: 0.1 })
            .with_transport(Box::new(SimTransport::new()));
        assert_eq!(c.transport_name(), "sim");
        c.p2p("comm", &[(1, 10), (2, 5), (0, 0)]).unwrap();
        c.allreduce("comm2", 64).unwrap();
        // measured is defined as the prediction: the model error is 0.0
        for (cat, err) in c.net_model_error() {
            assert_eq!(err, 0.0, "category {cat}");
        }
        assert_eq!(c.net_measured.get("comm"), c.net_predicted.get("comm"));
        assert_eq!(
            c.net_units_measured.get("comm2"),
            c.net_units_predicted.get("comm2")
        );
    }

    #[test]
    fn failed_collective_charges_recover_not_the_comm_bucket() {
        use crate::dist::transport::{ChannelTransport, TransportTuning};
        let net = NetModel { alpha: 1.0, beta: 0.1 };
        let tuning = TransportTuning {
            phase_deadline: 0.05,
            ..TransportTuning::default()
        };
        let mut t = ChannelTransport::new(3, tuning);
        t.wedge_rank(1);
        let mut c = SimCluster::serial(3)
            .with_net(net)
            .with_transport(Box::new(t));
        c.begin_sweep(2);
        let per_rank = [(1u64, 10u64), (1, 10), (1, 10)];
        let err = c.p2p("comm", &per_rank).unwrap_err();
        assert_eq!(err.rank, 1, "the wedged rank is blamed: {}", err.detail);
        assert_eq!(err.kind, FailureKind::Crash, "{}", err.detail);
        assert_eq!(err.cat, "comm");
        assert_eq!(err.sweep, 2);
        // the aborted round never lands in the comm bucket: its predicted
        // cost is classified under RECOVER (Fig 11 sum invariance)
        assert_eq!(c.elapsed.get("comm"), 0.0);
        assert_eq!(c.volume.get("comm"), 0.0);
        let pred = net.p2p(&per_rank);
        assert!((c.elapsed.get(cat::RECOVER) - pred).abs() < 1e-12);
    }

    #[test]
    fn charge_balanced_divides_by_p() {
        let mut c = SimCluster::serial(4);
        c.charge_balanced("svd", 2.0);
        assert!((c.elapsed.get("svd") - 0.5).abs() < 1e-12);
    }

    #[test]
    fn executor_defaults_respect_override() {
        let c = SimCluster::new(4).with_parallel(false);
        assert!(!c.is_parallel());
        let c = SimCluster::new(4).with_parallel(true);
        assert!(c.is_parallel());
    }

    #[test]
    fn busy_and_wall_track_compute_phases() {
        let mut c = SimCluster::new(4).with_parallel(true);
        c.phase_map("w", |rank| {
            std::hint::black_box((0..20_000 * (rank + 1)).sum::<usize>())
        })
        .unwrap();
        let busy = c.busy.get("w");
        let wall = c.wall.get("w");
        assert!(busy > 0.0 && wall > 0.0);
        // busy sums per-rank times; the makespan never exceeds it
        assert!(c.elapsed.get("w") <= busy + 1e-12);
        assert!((busy - c.last_phase.iter().sum::<f64>()).abs() < 1e-12);
    }

    #[test]
    fn concurrency_report_provenance() {
        let mut c = SimCluster::serial(3);
        let rep = c.concurrency_report("w");
        assert_eq!(rep.executor, "serial");
        assert_eq!(rep.workers, 1);
        assert_eq!(rep.kernel, "unrecorded");
        assert_eq!(rep.speedup, 1.0, "no phases yet");
        c.phase("w", |_| {
            std::hint::black_box((0..10_000).sum::<usize>());
        })
        .unwrap();
        c.record_kernels("w", vec!["portable"; 3]);
        let rep = c.concurrency_report("w");
        assert_eq!(rep.kernel, "portable");
        // serial executor: wall == busy, so the measured speedup is ~1
        assert!((rep.speedup - 1.0).abs() < 1e-9);
        c.record_kernels("w", vec!["portable", "avx2", "portable"]);
        assert_eq!(c.concurrency_report("w").kernel, "mixed");
        let par = SimCluster::new(8).with_parallel(true);
        let rep = par.concurrency_report("w");
        assert_eq!(rep.executor, "parallel");
        assert!(rep.workers >= 1 && rep.workers <= 8);
    }

    #[test]
    fn kernel_provenance_is_keyed_by_category() {
        // regression: SVD provenance must never report TTM kernel names
        let mut c = SimCluster::serial(2);
        c.record_kernels(cat::TTM, vec!["avx2"; 2]);
        assert_eq!(c.concurrency_report(cat::TTM).kernel, "avx2");
        assert_eq!(c.concurrency_report(cat::SVD).kernel, "unrecorded");
        c.record_kernels(cat::SVD, vec!["engine-batched"; 2]);
        assert_eq!(c.concurrency_report(cat::SVD).kernel, "engine-batched");
        assert_eq!(c.concurrency_report(cat::TTM).kernel, "avx2");
        // re-recording a category replaces its entry
        c.record_kernels(cat::TTM, vec!["scalar"; 2]);
        assert_eq!(c.concurrency_report(cat::TTM).kernel, "scalar");
    }

    #[test]
    fn injected_crash_surfaces_failure_and_marks_dead() {
        for parallel in [false, true] {
            let mut c = SimCluster::new(4).with_parallel(parallel);
            c.set_injector(FaultPlan::new().crash_at(0, 1, 2).injector());
            c.begin_sweep(0);
            // phase 0 is clean
            assert!(c.phase_map("w", |r| r).is_ok());
            // phase 1 fires the crash on rank 2
            let err = c.phase_map("w", |r| r).unwrap_err();
            assert_eq!(err.rank, 2);
            assert_eq!(err.kind, FailureKind::Crash);
            assert_eq!(err.sweep, 0);
            assert_eq!(err.phase, 1);
            assert_eq!(c.faults_injected(), 1);
            assert!(c.injector().unwrap().is_dead(2));
            // the crash was consumed: a retried sweep runs clean
            c.begin_sweep(0);
            assert!(c.phase_map("w", |r| r).is_ok());
            assert!(c.phase_map("w", |r| r).is_ok());
        }
    }

    #[test]
    fn transient_failure_is_consumed_on_retry() {
        let mut c = SimCluster::serial(3);
        c.set_injector(FaultPlan::new().transient_at(1, 0, 0).injector());
        c.begin_sweep(0);
        assert!(c.phase("w", |_| {}).is_ok());
        c.begin_sweep(1);
        let err = c.phase("w", |_| {}).unwrap_err();
        assert_eq!(err.kind, FailureKind::Transient);
        assert_eq!(err.rank, 0);
        c.begin_sweep(1);
        assert!(c.phase("w", |_| {}).is_ok());
        assert_eq!(c.faults_injected(), 1);
    }

    #[test]
    fn panics_are_caught_at_the_executor_boundary() {
        for parallel in [false, true] {
            let mut c = SimCluster::new(3).with_parallel(parallel);
            let err = c
                .phase_map("w", |rank| {
                    if rank == 1 {
                        panic!("rank 1 exploded");
                    }
                    rank
                })
                .unwrap_err();
            assert_eq!(err.rank, 1);
            assert_eq!(err.kind, FailureKind::Panic);
            assert!(err.detail.contains("rank 1 exploded"), "{}", err.detail);
            // the cluster object stays usable after the caught panic
            assert!(c.phase_map("w", |r| r).is_ok());
        }
    }

    #[test]
    fn serial_phase_catches_panics_too() {
        let mut c = SimCluster::serial(2);
        let err = c
            .phase("w", |rank| {
                assert!(rank != 0, "rank 0 assertion trips");
            })
            .unwrap_err();
        assert_eq!(err.rank, 0);
        assert_eq!(err.kind, FailureKind::Panic);
    }

    #[test]
    fn straggler_inflates_time_and_escalates_past_timeout() {
        // no timeout: the phase succeeds but the straggler dominates
        let mut c = SimCluster::serial(3);
        c.set_injector(FaultPlan::new().straggler_at(0, 0, 1, 1e6).injector());
        c.begin_sweep(0);
        c.phase("w", |_| {
            std::hint::black_box((0..10_000).sum::<usize>());
        })
        .unwrap();
        let max = c.last_phase.iter().copied().fold(0.0, f64::max);
        assert_eq!(c.faults_injected(), 1);
        assert!((c.last_phase[1] - max).abs() < 1e-12, "straggler is slowest");
        assert!(c.last_phase[1] > 100.0 * c.last_phase[0].max(1e-12));

        // with a timeout: the same straggler escalates to a failure
        let mut c = SimCluster::serial(3);
        c.set_injector(FaultPlan::new().straggler_at(0, 0, 1, 1e6).injector());
        c.set_phase_timeout(Some(1e-9));
        c.begin_sweep(0);
        let err = c
            .phase("w", |_| {
                std::hint::black_box((0..10_000).sum::<usize>());
            })
            .unwrap_err();
        assert_eq!(err.kind, FailureKind::StragglerTimeout);
        assert_eq!(err.rank, 1);
    }

    #[test]
    fn begin_sweep_resets_the_phase_counter() {
        let mut c = SimCluster::serial(2);
        c.set_injector(FaultPlan::new().transient_at(1, 0, 1).injector());
        c.begin_sweep(0);
        assert!(c.phase("w", |_| {}).is_ok()); // sweep 0 phase 0: clean
        c.begin_sweep(1);
        let err = c.phase("w", |_| {}).unwrap_err(); // sweep 1 phase 0: fires
        assert_eq!((err.sweep, err.phase), (1, 0));
    }
}
