//! Transport seam: the boundary where communication either *charges*
//! analytic α–β time (simulation) or *moves real bytes* between ranks.
//!
//! [`Transport`] exposes the two collective shapes the HOOI driver uses —
//! per-rank [`p2p`](Transport::p2p) exchanges and [`allreduce`](Transport::allreduce) —
//! and returns what was *measured* ([`Measured`]) alongside the possibility
//! of a *real* failure ([`TransportFailure`]).
//!
//! Two implementations:
//!
//! * [`SimTransport`] — the historical analytic charger. No bytes move;
//!   "measured" is defined to equal the [`NetModel`] prediction, so every
//!   bit-exact accounting contract from before the seam existed still holds
//!   verbatim.
//! * [`ChannelTransport`] — each live rank runs on its own scoped thread and
//!   exchanges framed, sequence-numbered, checksummed payloads over
//!   in-process channels (a ring topology). A robustness envelope watches
//!   the exchange: per-rank heartbeats, a per-phase wall-clock deadline,
//!   bounded retransmit with exponential backoff on checksum mismatch, and
//!   a poisoned-drain path so a single wedged peer cannot deadlock the
//!   collective. Detected failures are classified into the existing
//!   [`FailureKind`] taxonomy (crash / transient / straggler) and flow into
//!   the PR 6 recovery loop unchanged.
//!
//! Crucially, payload bytes never feed the numerics: factors and core are
//! computed from the same local data under either transport, so
//! decompositions are bit-identical across [`TransportChoice`]s. What the
//! channel transport adds is *evidence* — measured seconds and measured
//! units per category — which `SimCluster` reports against the α–β
//! prediction as `net_model_error`.
#![warn(clippy::unwrap_used)]

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::Duration;

use super::fault::FailureKind;
use crate::util::timer::{Deadline, Stopwatch};
use super::net::NetModel;

/// Which transport a cluster runs its collectives on.
///
/// Resolved with the usual precedence: typed builder option >
/// `TUCKER_TRANSPORT` env var > default ([`Sim`](TransportChoice::Sim)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportChoice {
    /// Analytic α–β charging only; no bytes move. The historical behavior.
    #[default]
    Sim,
    /// In-process channel transport: real framed bytes, checksums,
    /// heartbeats, deadlines, retry/backoff.
    Channel,
}

impl TransportChoice {
    /// Parse a (case-insensitive) name: `"sim"` or `"channel"`.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "sim" => Some(Self::Sim),
            "channel" => Some(Self::Channel),
            _ => None,
        }
    }

    /// Stable lowercase name, matching what [`by_name`](Self::by_name) accepts.
    pub fn name(self) -> &'static str {
        match self {
            Self::Sim => "sim",
            Self::Channel => "channel",
        }
    }
}

/// Knobs for the channel transport's robustness envelope.
///
/// The defaults are tuned for correctness under an oversubscribed test
/// harness: in-process exchanges complete in microseconds, so the phase
/// deadline only matters when a peer is genuinely hung — it is deliberately
/// generous (2 s) to keep a descheduled thread from being mistaken for a
/// crash. Fault-detection tests tighten it explicitly.
#[derive(Debug, Clone, Copy)]
pub struct TransportTuning {
    /// Seconds between heartbeat refreshes while a rank idles in its
    /// receive loop.
    pub heartbeat_interval: f64,
    /// Wall-clock seconds a single collective may take before the liveness
    /// monitor declares the slowest peer failed.
    pub phase_deadline: f64,
    /// Maximum retransmissions of one frame after checksum mismatch before
    /// the error is surfaced as a transient failure.
    pub max_retries: u32,
    /// Base backoff in seconds; retransmission `n` sleeps
    /// `backoff_base * 2^(n-1)`.
    pub backoff_base: f64,
    /// Chaos hook: corrupt the checksums of the next N physical frame
    /// sends (retransmissions included). Consumed across collectives.
    pub corrupt_frames: u32,
    /// Chaos hook: delay this rank's first participation...
    pub delay_rank: Option<usize>,
    /// ...by this many seconds (one-shot; cleared after it fires).
    pub delay_secs: f64,
}

impl Default for TransportTuning {
    fn default() -> Self {
        Self {
            heartbeat_interval: 0.05,
            phase_deadline: 2.0,
            max_retries: 3,
            backoff_base: 5e-4,
            corrupt_frames: 0,
            delay_rank: None,
            delay_secs: 0.0,
        }
    }
}

/// Cumulative counters a transport keeps about the traffic it carried.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Completed p2p collectives.
    pub p2p_ops: u64,
    /// Completed allreduce collectives.
    pub allreduce_ops: u64,
    /// Physical frames delivered (retransmissions included).
    pub frames_sent: u64,
    /// Frames retransmitted after a checksum mismatch.
    pub frames_retried: u64,
    /// Payload units (u32 words) delivered across all frames.
    pub payload_units: u64,
    /// Bytes moved on the wire (headers + payload).
    pub bytes_moved: u64,
}

/// What one collective actually cost: wall seconds and delivered units
/// (normalized to the same per-rank convention `NetModel` predicts in).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measured {
    /// Wall-clock seconds the collective took.
    pub secs: f64,
    /// Delivered payload units, normalized to `NetModel`'s convention
    /// (total units for p2p; per-rank ring traffic for allreduce).
    pub units: f64,
}

/// A real failure detected by the transport's liveness monitor, already
/// classified into the injected-fault taxonomy so the recovery loop treats
/// it identically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransportFailure {
    /// The rank held responsible (the peer everyone was waiting on, or the
    /// rank whose frames kept failing verification).
    pub rank: usize,
    /// Classification: crash (no heartbeat), straggler (alive but past
    /// deadline), or transient (retry budget exhausted).
    pub kind: FailureKind,
    /// Human-readable evidence for the classification.
    pub detail: String,
}

/// The seam `SimCluster` charges communication through.
///
/// `p2p` models each rank exchanging `(msgs, units)` with peers; the
/// returned [`Measured::units`] must total the per-rank sum. `allreduce`
/// models a P-rank reduction of `units` units; measured units follow the
/// ring convention `NetModel::allreduce_volume` predicts (`2(P-1)/P · u`).
pub trait Transport: std::fmt::Debug + Send {
    /// Stable name for reports ("sim", "channel").
    fn name(&self) -> &'static str;

    /// Run one per-rank point-to-point exchange phase.
    fn p2p(
        &mut self,
        net: &NetModel,
        per_rank: &[(u64, u64)],
    ) -> Result<Measured, TransportFailure>;

    /// Run one allreduce over `p` ranks of `units` units.
    fn allreduce(&mut self, net: &NetModel, p: usize, units: u64)
        -> Result<Measured, TransportFailure>;

    /// Exclude a rank from future collectives (post-eviction).
    fn mark_dead(&mut self, _rank: usize) {}

    /// Traffic counters accumulated so far.
    fn stats(&self) -> TransportStats {
        TransportStats::default()
    }
}

/// Construct a boxed transport for `choice` over `p` ranks.
pub fn from_choice(
    choice: TransportChoice,
    p: usize,
    tuning: TransportTuning,
) -> Box<dyn Transport> {
    match choice {
        TransportChoice::Sim => Box::new(SimTransport::new()),
        TransportChoice::Channel => Box::new(ChannelTransport::new(p, tuning)),
    }
}

/// The analytic charger: measured ≡ predicted, no bytes move, never fails.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimTransport {
    stats: TransportStats,
}

impl SimTransport {
    /// A fresh analytic transport.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Transport for SimTransport {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn p2p(
        &mut self,
        net: &NetModel,
        per_rank: &[(u64, u64)],
    ) -> Result<Measured, TransportFailure> {
        self.stats.p2p_ops += 1;
        Ok(Measured {
            secs: net.p2p(per_rank),
            units: net.p2p_volume(per_rank) as f64,
        })
    }

    fn allreduce(
        &mut self,
        net: &NetModel,
        p: usize,
        units: u64,
    ) -> Result<Measured, TransportFailure> {
        self.stats.allreduce_ops += 1;
        Ok(Measured {
            secs: net.allreduce(p, units),
            units: net.allreduce_volume(p, units),
        })
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }
}

// ---------------------------------------------------------------------------
// Channel transport: real bytes over in-process channels.
// ---------------------------------------------------------------------------

/// Bytes of framing overhead per frame (seq + src + checksum as u64s).
pub const FRAME_HEADER_BYTES: u64 = 24;

/// One framed message: sequence number, source rank, synthesized payload,
/// and an FNV-1a checksum over all of it.
#[derive(Debug, Clone)]
struct Frame {
    seq: u64,
    src: usize,
    payload: Vec<u32>,
    checksum: u64,
}

fn fnv1a_word(mut h: u64, word: u64) -> u64 {
    for b in word.to_le_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn checksum_of(seq: u64, src: usize, payload: &[u32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h = fnv1a_word(h, seq);
    h = fnv1a_word(h, src as u64);
    for &w in payload {
        h = fnv1a_word(h, u64::from(w));
    }
    h
}

impl Frame {
    /// Build frame `seq` from `src` carrying `units` deterministic payload
    /// words. The payload content is synthetic (collectives here carry
    /// *volume*, not numerics) but checksummed for real, so corruption on
    /// the wire is detected exactly as it would be for meaningful bytes.
    fn synthesize(seq: u64, src: usize, units: u64) -> Self {
        let payload: Vec<u32> = (0..units)
            .map(|j| {
                (seq as u32)
                    .wrapping_mul(0x9E37_79B9)
                    .wrapping_add(j as u32)
                    ^ (src as u32).rotate_left(16)
            })
            .collect();
        let checksum = checksum_of(seq, src, &payload);
        Self {
            seq,
            src,
            payload,
            checksum,
        }
    }

    fn verify(&self) -> bool {
        checksum_of(self.seq, self.src, &self.payload) == self.checksum
    }

    fn wire_bytes(&self) -> u64 {
        FRAME_HEADER_BYTES + 4 * self.payload.len() as u64
    }
}

/// Receiver → sender acknowledgement for frame `seq`.
#[derive(Debug, Clone, Copy)]
struct Ack {
    seq: u64,
    ok: bool,
}

/// Split `(msgs, units)` into per-frame payload sizes: `max(msgs, 1)`
/// frames (zero frames only for the `(0, 0)` no-op), units spread evenly
/// with the remainder on the leading frames.
fn split_frames(msgs: u64, units: u64) -> Vec<u64> {
    if msgs == 0 && units == 0 {
        return Vec::new();
    }
    let n = msgs.max(1);
    (0..n).map(|k| units / n + u64::from(k < units % n)).collect()
}

/// Consume one unit of a shared corruption budget; returns whether the
/// frame about to be sent should be corrupted.
fn take_corruption(budget: &AtomicU32) -> bool {
    let mut cur = budget.load(Ordering::Relaxed);
    while cur > 0 {
        match budget.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
    false
}

/// Why one rank's exchange loop gave up.
#[derive(Debug, Clone)]
enum RankError {
    /// The phase deadline passed while waiting on `waiting_on`.
    TimedOut { waiting_on: usize },
    /// Frame `seq` to rank `peer` failed verification `attempts` times.
    CorruptExhausted { peer: usize, seq: u64, attempts: u32 },
}

/// What one rank's exchange thread reports back.
#[derive(Debug, Default)]
struct RankReport {
    frames_sent: u64,
    frames_retried: u64,
    bytes_moved: u64,
    units_delivered: u64,
    error: Option<RankError>,
}

/// Everything one rank's exchange thread needs, bundled so the spawn site
/// stays readable.
struct RankCtx<'a> {
    /// This rank's world id (not ring position).
    rank: usize,
    tuning: TransportTuning,
    /// One-shot startup delay for this rank, if the chaos hook armed one.
    delay: Option<f64>,
    /// Frame sizes this rank sends to its next ring neighbor.
    sizes: &'a [u64],
    /// Number of frames expected from the previous ring neighbor.
    expected: usize,
    to_next: mpsc::Sender<Frame>,
    ack_to_prev: mpsc::Sender<Ack>,
    rx: mpsc::Receiver<Frame>,
    arx: mpsc::Receiver<Ack>,
    beats: &'a [AtomicU64],
    poisoned: &'a AtomicBool,
    corrupt: &'a AtomicU32,
    deadline: Deadline,
    peer_prev: usize,
    peer_next: usize,
    /// Chaos hook: a wedged rank never participates (simulated hang).
    wedged_self: bool,
}

/// Outcome of one full ring exchange across all live ranks.
struct ExchangeOutcome {
    wall_secs: f64,
    delivered_units: u64,
    failure: Option<TransportFailure>,
}

/// In-process channel transport over the live ranks of a `p`-rank world.
#[derive(Debug)]
pub struct ChannelTransport {
    p: usize,
    tuning: TransportTuning,
    dead: Vec<bool>,
    wedged: Vec<bool>,
    corrupt_budget: AtomicU32,
    delay_pending: Option<(usize, f64)>,
    stats: TransportStats,
}

impl ChannelTransport {
    /// A fresh channel transport over `p` ranks, seeding the chaos hooks
    /// (corruption budget, one-shot delay) from `tuning`.
    pub fn new(p: usize, tuning: TransportTuning) -> Self {
        let delay_pending = tuning
            .delay_rank
            .filter(|_| tuning.delay_secs > 0.0)
            .map(|r| (r, tuning.delay_secs));
        Self {
            p,
            tuning,
            dead: vec![false; p],
            wedged: vec![false; p],
            corrupt_budget: AtomicU32::new(tuning.corrupt_frames),
            delay_pending,
            stats: TransportStats::default(),
        }
    }

    /// Chaos hook: make `rank` stop participating in collectives without
    /// telling anyone — a real hang, detectable only by heartbeat/deadline.
    pub fn wedge_rank(&mut self, rank: usize) {
        if rank < self.wedged.len() {
            self.wedged[rank] = true;
        }
    }

    /// Chaos hook: corrupt the checksums of the next `n` physical sends.
    pub fn corrupt_next_frames(&mut self, n: u32) {
        self.corrupt_budget.store(n, Ordering::Relaxed);
    }

    /// Chaos hook: delay `rank`'s next participation by `secs` (one-shot).
    pub fn delay_rank_once(&mut self, rank: usize, secs: f64) {
        self.delay_pending = Some((rank, secs));
    }

    /// Ranks that still participate in collectives: not dead. Wedged ranks
    /// are *included* — they are live as far as the world knows, which is
    /// exactly why detecting them takes a deadline.
    fn live_ranks(&self, world: usize) -> Vec<usize> {
        (0..world.min(self.p))
            .filter(|&r| !self.dead.get(r).copied().unwrap_or(false))
            .collect()
    }

    /// Run one ring exchange: live rank at position `i` sends `sizes[i]`
    /// frames to position `(i+1) % n` and acks what it receives from
    /// `(i-1+n) % n`. Returns wall time, total delivered payload units,
    /// and the classified failure if the envelope tripped.
    fn exchange(&mut self, live: &[usize], sizes: &[Vec<u64>]) -> ExchangeOutcome {
        let n = live.len();
        let delay = self.delay_pending.take();
        let tuning = self.tuning;
        let wedged = &self.wedged;
        let corrupt = &self.corrupt_budget;

        let t0 = Stopwatch::start();
        let deadline = Deadline::in_secs(tuning.phase_deadline);
        let beats: Vec<AtomicU64> = (0..self.p).map(|_| AtomicU64::new(0)).collect();
        let poisoned = AtomicBool::new(false);

        let mut data_tx = Vec::with_capacity(n);
        let mut data_rx = Vec::with_capacity(n);
        let mut ack_tx = Vec::with_capacity(n);
        let mut ack_rx = Vec::with_capacity(n);
        for _ in 0..n {
            let (dt, dr) = mpsc::channel::<Frame>();
            let (at, ar) = mpsc::channel::<Ack>();
            data_tx.push(dt);
            data_rx.push(Some(dr));
            ack_tx.push(at);
            ack_rx.push(Some(ar));
        }

        let reports: Vec<RankReport> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(n);
            for (i, rx_slot) in data_rx.iter_mut().enumerate() {
                let nx = (i + 1) % n;
                let pv = (i + n - 1) % n;
                let rank = live[i];
                let ctx = RankCtx {
                    rank,
                    tuning,
                    delay: delay.filter(|&(r, _)| r == rank).map(|(_, secs)| secs),
                    sizes: &sizes[i],
                    expected: sizes[pv].len(),
                    to_next: data_tx[nx].clone(),
                    ack_to_prev: ack_tx[pv].clone(),
                    rx: rx_slot
                        .take()
                        .expect("invariant: each data receiver is taken exactly once"),
                    arx: ack_rx[i]
                        .take()
                        .expect("invariant: each ack receiver is taken exactly once"),
                    beats: &beats,
                    poisoned: &poisoned,
                    corrupt,
                    deadline,
                    peer_prev: live[pv],
                    peer_next: live[nx],
                    wedged_self: wedged.get(rank).copied().unwrap_or(false),
                };
                handles.push(s.spawn(move || run_rank(ctx)));
            }
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(_) => RankReport {
                        error: Some(RankError::TimedOut { waiting_on: 0 }),
                        ..RankReport::default()
                    },
                })
                .collect()
        });
        let wall_secs = t0.seconds();

        let mut delivered_units = 0u64;
        for r in &reports {
            self.stats.frames_sent += r.frames_sent;
            self.stats.frames_retried += r.frames_retried;
            self.stats.bytes_moved += r.bytes_moved;
            self.stats.payload_units += r.units_delivered;
            delivered_units += r.units_delivered;
        }

        // Classify. A corruption-budget exhaustion anywhere is transient
        // (the data kept arriving, just damaged); otherwise blame the peer
        // the earliest-timed-out rank was waiting on, and distinguish
        // crash (never heartbeated) from straggler (alive but late).
        let mut failure = None;
        for (i, r) in reports.iter().enumerate() {
            if let Some(RankError::CorruptExhausted {
                peer,
                seq,
                attempts,
            }) = r.error
            {
                failure = Some(TransportFailure {
                    rank: live[i],
                    kind: FailureKind::Transient,
                    detail: format!(
                        "checksum mismatch persisted through {attempts} retransmissions \
                         of frame {seq} to rank {peer}"
                    ),
                });
                break;
            }
        }
        if failure.is_none() {
            let mut culprit: Option<usize> = None;
            for r in &reports {
                if let Some(RankError::TimedOut { waiting_on }) = r.error {
                    culprit = Some(match culprit {
                        Some(c) => c.min(waiting_on),
                        None => waiting_on,
                    });
                }
            }
            if let Some(c) = culprit {
                let beat_seen = beats.get(c).is_some_and(|b| b.load(Ordering::Relaxed) > 0);
                failure = Some(if beat_seen {
                    TransportFailure {
                        rank: c,
                        kind: FailureKind::StragglerTimeout,
                        detail: format!(
                            "rank {c} heartbeating but past the {:.3}s phase deadline",
                            tuning.phase_deadline
                        ),
                    }
                } else {
                    TransportFailure {
                        rank: c,
                        kind: FailureKind::Crash,
                        detail: format!(
                            "rank {c} sent no heartbeat within the {:.3}s phase deadline",
                            tuning.phase_deadline
                        ),
                    }
                });
            }
        }

        ExchangeOutcome {
            wall_secs,
            delivered_units,
            failure,
        }
    }
}

impl Transport for ChannelTransport {
    fn name(&self) -> &'static str {
        "channel"
    }

    fn p2p(
        &mut self,
        net: &NetModel,
        per_rank: &[(u64, u64)],
    ) -> Result<Measured, TransportFailure> {
        let live = self.live_ranks(per_rank.len().max(self.p));
        if live.len() <= 1 {
            // Nothing to exchange with; measured ≡ predicted by definition.
            self.stats.p2p_ops += 1;
            return Ok(Measured {
                secs: net.p2p(per_rank),
                units: net.p2p_volume(per_rank) as f64,
            });
        }
        let sizes: Vec<Vec<u64>> = live
            .iter()
            .map(|&r| {
                let (m, u) = per_rank.get(r).copied().unwrap_or((0, 0));
                split_frames(m, u)
            })
            .collect();
        let out = self.exchange(&live, &sizes);
        match out.failure {
            Some(f) => Err(f),
            None => {
                self.stats.p2p_ops += 1;
                Ok(Measured {
                    secs: out.wall_secs,
                    units: out.delivered_units as f64,
                })
            }
        }
    }

    fn allreduce(
        &mut self,
        net: &NetModel,
        p: usize,
        units: u64,
    ) -> Result<Measured, TransportFailure> {
        let live = self.live_ranks(p);
        let l = live.len();
        if l <= 1 || units == 0 {
            self.stats.allreduce_ops += 1;
            return Ok(Measured {
                secs: net.allreduce(p, units),
                units: net.allreduce_volume(p, units),
            });
        }
        // Ring allreduce: units split into l blocks; 2(l-1) steps of
        // reduce-scatter + allgather, each step moving one block to the
        // next neighbor. Total wire traffic is exactly 2(l-1) · units / l
        // per rank — the quantity `NetModel::allreduce_volume` predicts.
        let lu = l as u64;
        let steps = 2 * (lu - 1);
        let sizes: Vec<Vec<u64>> = (0..lu)
            .map(|i| {
                (0..steps)
                    .map(|k| units / lu + u64::from((i + k) % lu < units % lu))
                    .collect()
            })
            .collect();
        let out = self.exchange(&live, &sizes);
        match out.failure {
            Some(f) => Err(f),
            None => {
                self.stats.allreduce_ops += 1;
                Ok(Measured {
                    secs: out.wall_secs,
                    units: out.delivered_units as f64 / l as f64,
                })
            }
        }
    }

    fn mark_dead(&mut self, rank: usize) {
        if rank >= self.dead.len() {
            self.dead.resize(rank + 1, false);
            self.wedged.resize(rank + 1, false);
        }
        self.dead[rank] = true;
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }
}

/// One rank's side of the exchange: pipeline all sends up front (channels
/// are unbounded, so sends never block), then poll data + ack channels,
/// heartbeating while idle and bailing out on poison or deadline. This
/// shape is deadlock-free by construction — no rank ever blocks waiting
/// for an ack before servicing its own receive side.
fn run_rank(ctx: RankCtx<'_>) -> RankReport {
    let mut report = RankReport::default();
    if ctx.wedged_self {
        // A wedged rank is a silent hang: it holds its channels open (a
        // hung peer's sockets do not close) but never heartbeats, sends,
        // or acks — detectable only by the deadline monitor.
        while !ctx.poisoned.load(Ordering::Relaxed) && !ctx.deadline.expired() {
            std::thread::sleep(Duration::from_micros(200));
        }
        return report;
    }
    ctx.beats[ctx.rank].fetch_add(1, Ordering::Relaxed);
    if let Some(secs) = ctx.delay {
        std::thread::sleep(Duration::from_secs_f64(secs));
    }

    // Send every outgoing frame immediately; keep copies for retransmit.
    let frames: Vec<Frame> = ctx
        .sizes
        .iter()
        .enumerate()
        .map(|(k, &u)| Frame::synthesize(k as u64, ctx.rank, u))
        .collect();
    let mut attempts: Vec<u32> = vec![0; frames.len()];
    let mut acked: Vec<bool> = vec![false; frames.len()];
    for f in &frames {
        let mut wire = f.clone();
        if take_corruption(ctx.corrupt) {
            wire.checksum ^= 0xDEAD_BEEF;
        }
        report.frames_sent += 1;
        report.bytes_moved += wire.wire_bytes();
        if ctx.to_next.send(wire).is_err() {
            // Peer's receiver dropped: it already bailed; poison flag or
            // deadline below will end this loop.
            ctx.poisoned.store(true, Ordering::Relaxed);
        }
    }

    let mut got: Vec<bool> = vec![false; ctx.expected];
    let mut got_count = 0usize;
    let mut acked_count = 0usize;
    let mut last_beat = Stopwatch::start();

    loop {
        if ctx.poisoned.load(Ordering::Relaxed) {
            return report;
        }
        let mut progressed = false;

        // Drain incoming data frames: verify, ack/nack, count first-valid.
        while let Ok(frame) = ctx.rx.try_recv() {
            progressed = true;
            let ok = frame.verify();
            let _ = ctx.ack_to_prev.send(Ack {
                seq: frame.seq,
                ok,
            });
            let k = frame.seq as usize;
            if ok && k < got.len() && !got[k] {
                got[k] = true;
                got_count += 1;
                report.units_delivered += frame.payload.len() as u64;
            }
        }

        // Drain acks: mark clean deliveries, retransmit on nack with
        // exponential backoff, give up past the retry budget.
        while let Ok(ack) = ctx.arx.try_recv() {
            progressed = true;
            let k = ack.seq as usize;
            if k >= frames.len() {
                continue;
            }
            if ack.ok {
                if !acked[k] {
                    acked[k] = true;
                    acked_count += 1;
                }
            } else {
                attempts[k] += 1;
                if attempts[k] > ctx.tuning.max_retries {
                    ctx.poisoned.store(true, Ordering::Relaxed);
                    report.error = Some(RankError::CorruptExhausted {
                        peer: ctx.peer_next,
                        seq: ack.seq,
                        attempts: attempts[k],
                    });
                    return report;
                }
                let backoff =
                    ctx.tuning.backoff_base * f64::from(1u32 << (attempts[k] - 1).min(16));
                std::thread::sleep(Duration::from_secs_f64(backoff));
                let mut wire = frames[k].clone();
                if take_corruption(ctx.corrupt) {
                    wire.checksum ^= 0xDEAD_BEEF;
                }
                report.frames_sent += 1;
                report.frames_retried += 1;
                report.bytes_moved += wire.wire_bytes();
                if ctx.to_next.send(wire).is_err() {
                    ctx.poisoned.store(true, Ordering::Relaxed);
                }
            }
        }

        if acked_count == frames.len() && got_count == ctx.expected {
            return report;
        }

        if !progressed {
            // Idle: refresh our heartbeat (throttled) and check the
            // phase deadline against whoever we are still waiting on.
            if last_beat.seconds() >= ctx.tuning.heartbeat_interval {
                ctx.beats[ctx.rank].fetch_add(1, Ordering::Relaxed);
                last_beat = Stopwatch::start();
            }
            if ctx.deadline.expired() {
                ctx.poisoned.store(true, Ordering::Relaxed);
                report.error = Some(RankError::TimedOut {
                    waiting_on: if got_count < ctx.expected {
                        ctx.peer_prev
                    } else {
                        ctx.peer_next
                    },
                });
                return report;
            }
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn checksum_roundtrip_and_corruption_detection() {
        let f = Frame::synthesize(3, 1, 16);
        assert!(f.verify());
        let mut bad = f.clone();
        bad.checksum ^= 0xDEAD_BEEF;
        assert!(!bad.verify());
        let mut flipped = f.clone();
        flipped.payload[7] ^= 1;
        assert!(!flipped.verify());
    }

    #[test]
    fn split_frames_spreads_units_evenly() {
        assert!(split_frames(0, 0).is_empty());
        assert_eq!(split_frames(0, 5), vec![5]);
        assert_eq!(split_frames(3, 7), vec![3, 2, 2]);
        assert_eq!(split_frames(4, 8), vec![2, 2, 2, 2]);
        let total: u64 = split_frames(7, 23).iter().sum();
        assert_eq!(total, 23);
    }

    #[test]
    fn choice_by_name_is_case_insensitive() {
        assert_eq!(TransportChoice::by_name("sim"), Some(TransportChoice::Sim));
        assert_eq!(
            TransportChoice::by_name("CHANNEL"),
            Some(TransportChoice::Channel)
        );
        assert_eq!(TransportChoice::by_name("tcp"), None);
        assert_eq!(TransportChoice::default().name(), "sim");
    }

    #[test]
    fn corruption_budget_is_consumed_exactly() {
        let budget = AtomicU32::new(2);
        assert!(take_corruption(&budget));
        assert!(take_corruption(&budget));
        assert!(!take_corruption(&budget));
        assert!(!take_corruption(&budget));
    }

    #[test]
    fn sim_transport_measures_the_model_exactly() {
        let net = NetModel::default();
        let mut t = SimTransport::new();
        let per_rank = [(2u64, 100u64), (1, 50), (3, 10)];
        let m = t.p2p(&net, &per_rank).expect("sim p2p never fails");
        assert_eq!(m.secs, net.p2p(&per_rank));
        assert_eq!(m.units, net.p2p_volume(&per_rank) as f64);
        let a = t.allreduce(&net, 4, 64).expect("sim allreduce never fails");
        assert_eq!(a.secs, net.allreduce(4, 64));
        assert_eq!(a.units, net.allreduce_volume(4, 64));
        assert_eq!(t.stats().p2p_ops, 1);
        assert_eq!(t.stats().allreduce_ops, 1);
    }
}
