//! Seeded, deterministic fault injection for the simulated cluster.
//!
//! The paper's setting is MPI ranks on distributed memory, where rank
//! loss and stragglers are the operating reality. A [`FaultPlan`] is a
//! declarative, seed-reproducible schedule of faults — rank crashes,
//! transient failures that succeed on retry, and straggler slowdowns —
//! addressed by `(sweep, phase, rank)` position. [`SimCluster`] arms a
//! [`FaultInjector`] built from the plan and consults it before every
//! compute phase; a fired fault surfaces as a [`RankFailure`] from the
//! phase call instead of tearing the process down, and the session layer
//! (`TuckerSession`) decides whether to retry from a checkpoint or evict
//! the dead rank and re-place its elements across survivors.
//!
//! Everything here is deterministic: a plan built from a seed fires the
//! same faults at the same positions on every run, which is what makes
//! the recovery-equivalence property tests possible.
//!
//! The same taxonomy now also carries *real* failures: the channel
//! transport's liveness monitor (see [`super::transport`]) classifies a
//! detected hang, crash, or unrecoverable corruption into the same
//! [`FailureKind`]s, so the session's recovery loop treats a really
//! wedged rank exactly like an injected crash.
//!
//! [`SimCluster`]: super::cluster::SimCluster
#![warn(clippy::unwrap_used)]

use std::fmt;

use crate::util::rng::Rng;

/// What kind of fault fires at a scheduled position.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The rank dies: the phase fails and the rank stays dead (it fires
    /// no further faults; after recovery it owns zero elements).
    Crash,
    /// The phase fails once; the retry runs clean (the event is
    /// consumed when it fires).
    Transient,
    /// The rank's measured phase seconds are multiplied by the factor.
    /// Escalates to a [`FailureKind::StragglerTimeout`] failure only
    /// when the inflated time exceeds the cluster's per-phase timeout
    /// (set from the session's `RetryPolicy`); otherwise the phase
    /// succeeds with a slower makespan.
    Straggler(f64),
}

/// One scheduled fault: `kind` fires when rank `rank` executes compute
/// phase number `phase` (0-based within the sweep) of sweep `sweep`
/// (0-based count of completed sweeps before it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub sweep: usize,
    pub phase: usize,
    pub rank: usize,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults. Build one with the `*_at`
/// combinators (explicit positions) or [`FaultPlan::random_crash`]
/// (seed-driven position), hand it to the session builder, and the same
/// faults fire at the same positions on every run.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan { specs: Vec::new() }
    }

    /// Schedule a rank crash at `(sweep, phase)`.
    pub fn crash_at(mut self, sweep: usize, phase: usize, rank: usize) -> FaultPlan {
        self.specs.push(FaultSpec { sweep, phase, rank, kind: FaultKind::Crash });
        self
    }

    /// Schedule a transient (retry-succeeds) failure at `(sweep, phase)`.
    pub fn transient_at(mut self, sweep: usize, phase: usize, rank: usize) -> FaultPlan {
        self.specs.push(FaultSpec { sweep, phase, rank, kind: FaultKind::Transient });
        self
    }

    /// Schedule a straggler slowdown: rank's measured seconds for that
    /// phase are multiplied by `factor` (>= 1).
    pub fn straggler_at(
        mut self,
        sweep: usize,
        phase: usize,
        rank: usize,
        factor: f64,
    ) -> FaultPlan {
        self.specs.push(FaultSpec {
            sweep,
            phase,
            rank,
            kind: FaultKind::Straggler(factor),
        });
        self
    }

    /// A single seed-driven crash somewhere in `sweeps x phases x p`
    /// positions — the same seed always picks the same position.
    pub fn random_crash(seed: u64, sweeps: usize, phases: usize, p: usize) -> FaultPlan {
        let mut rng = Rng::new(seed);
        let sweep = rng.usize_below(sweeps.max(1));
        let phase = rng.usize_below(phases.max(1));
        let rank = rng.usize_below(p.max(1));
        FaultPlan::new().crash_at(sweep, phase, rank)
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Arm the plan: the injector the cluster consults phase by phase.
    pub fn injector(&self) -> FaultInjector {
        FaultInjector {
            pending: self.specs.clone(),
            dead: Vec::new(),
            sweep: 0,
            injected: 0,
        }
    }
}

/// Run-time state of a [`FaultPlan`]: pending events, the current sweep
/// cursor, dead-rank tombstones, and the fired-fault count surfaced as
/// `RunRecord::faults_injected`.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    pending: Vec<FaultSpec>,
    dead: Vec<bool>,
    sweep: usize,
    injected: usize,
}

impl FaultInjector {
    fn ensure_world(&mut self, p: usize) {
        if self.dead.len() < p {
            self.dead.resize(p, false);
        }
    }

    /// Position the sweep cursor (the cluster forwards its
    /// `begin_sweep`; retried sweeps re-arm nothing because fired events
    /// are consumed).
    pub fn begin_sweep(&mut self, sweep: usize) {
        self.sweep = sweep;
    }

    /// Faults fired so far.
    pub fn faults_injected(&self) -> usize {
        self.injected
    }

    /// Is `rank` a tombstone (crashed earlier)?
    pub fn is_dead(&self, rank: usize) -> bool {
        self.dead.get(rank).copied().unwrap_or(false)
    }

    /// Ranks that have crashed so far, ascending.
    pub fn dead_ranks(&self) -> Vec<usize> {
        self.dead
            .iter()
            .enumerate()
            .filter_map(|(r, &d)| if d { Some(r) } else { None })
            .collect()
    }

    /// Decide the per-rank actions for compute phase `phase` of the
    /// current sweep, consuming the events that fire. Dead ranks fire
    /// nothing further; a crash marks its rank dead.
    pub fn arm(&mut self, phase: usize, p: usize) -> Vec<Option<FaultKind>> {
        self.ensure_world(p);
        let mut actions: Vec<Option<FaultKind>> = vec![None; p];
        let sweep = self.sweep;
        let dead = &self.dead;
        self.pending.retain(|s| {
            let fires = s.sweep == sweep
                && s.phase == phase
                && s.rank < p
                && !dead.get(s.rank).copied().unwrap_or(false);
            if fires {
                actions[s.rank] = Some(s.kind);
            }
            !fires
        });
        for (rank, action) in actions.iter().enumerate() {
            if action.is_some() {
                self.injected += 1;
            }
            if matches!(action, Some(FaultKind::Crash)) {
                self.dead[rank] = true;
            }
        }
        actions
    }
}

/// How a phase failed — the executor-boundary classification carried by
/// [`RankFailure`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// The rank is gone and must be evicted (survivor re-placement)
    /// before the sweep can be retried — whether injected or detected
    /// for real by the transport's heartbeat monitor (a peer that never
    /// heartbeated within the phase deadline).
    Crash,
    /// A failure that clears on retry: an injected transient, or a real
    /// one (e.g. frame corruption that persisted through the transport's
    /// retransmit budget). A retry from the last checkpoint runs clean.
    Transient,
    /// A task closure panicked; the panic was caught at the executor
    /// boundary. Treated like a transient failure by recovery.
    Panic,
    /// A live-but-slow rank: an injected straggler exceeded the
    /// per-phase timeout, or a real peer kept heartbeating but missed
    /// the transport's phase deadline.
    StragglerTimeout,
}

/// A phase-level failure: which rank failed, where (category, sweep,
/// phase), how, and a human-readable detail. Returned by the fallible
/// `SimCluster` phase methods instead of propagating a panic.
#[derive(Debug, Clone)]
pub struct RankFailure {
    pub rank: usize,
    pub cat: String,
    pub sweep: usize,
    pub phase: usize,
    pub kind: FailureKind,
    pub detail: String,
}

impl fmt::Display for RankFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "rank {} failed ({:?}) in phase {} ('{}') of sweep {}: {}",
            self.rank, self.kind, self.phase, self.cat, self.sweep, self.detail
        )
    }
}

impl std::error::Error for RankFailure {}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_deterministic_for_seed() {
        let a = FaultPlan::random_crash(77, 4, 9, 8);
        let b = FaultPlan::random_crash(77, 4, 9, 8);
        assert_eq!(a.specs(), b.specs());
        assert_eq!(a.specs().len(), 1);
        let s = a.specs()[0];
        assert!(s.sweep < 4 && s.phase < 9 && s.rank < 8);
        assert_eq!(s.kind, FaultKind::Crash);
    }

    #[test]
    fn events_fire_once_and_only_at_their_position() {
        let plan = FaultPlan::new().transient_at(1, 2, 3);
        let mut inj = plan.injector();
        inj.begin_sweep(0);
        assert!(inj.arm(2, 4).iter().all(Option::is_none));
        inj.begin_sweep(1);
        assert!(inj.arm(0, 4).iter().all(Option::is_none));
        let acts = inj.arm(1, 4); // phase counter 1 then 2
        assert!(acts.iter().all(Option::is_none));
        let acts = inj.arm(2, 4);
        assert_eq!(acts[3], Some(FaultKind::Transient));
        assert_eq!(inj.faults_injected(), 1);
        // consumed: the retried sweep runs clean
        inj.begin_sweep(1);
        assert!(inj.arm(2, 4).iter().all(Option::is_none));
        assert_eq!(inj.faults_injected(), 1);
    }

    #[test]
    fn crash_marks_rank_dead_and_suppresses_later_events() {
        let plan = FaultPlan::new().crash_at(0, 0, 1).transient_at(2, 0, 1);
        let mut inj = plan.injector();
        inj.begin_sweep(0);
        let acts = inj.arm(0, 3);
        assert_eq!(acts[1], Some(FaultKind::Crash));
        assert!(inj.is_dead(1));
        assert_eq!(inj.dead_ranks(), vec![1]);
        // the later transient on the dead rank never fires
        inj.begin_sweep(2);
        assert!(inj.arm(0, 3).iter().all(Option::is_none));
        assert_eq!(inj.faults_injected(), 1);
    }

    #[test]
    fn straggler_spec_carries_factor() {
        let plan = FaultPlan::new().straggler_at(0, 1, 2, 50.0);
        let mut inj = plan.injector();
        inj.begin_sweep(0);
        inj.arm(0, 4);
        let acts = inj.arm(1, 4);
        assert_eq!(acts[2], Some(FaultKind::Straggler(50.0)));
    }

    #[test]
    fn failure_display_mentions_position() {
        let f = RankFailure {
            rank: 2,
            cat: "ttm".into(),
            sweep: 1,
            phase: 3,
            kind: FailureKind::Crash,
            detail: "injected rank crash".into(),
        };
        let s = f.to_string();
        assert!(s.contains("rank 2") && s.contains("sweep 1") && s.contains("ttm"));
    }
}
