//! Simulated distributed runtime (paper §7 methodology): a P-rank cluster
//! where compute really executes (and is timed per rank) while
//! communication is charged to an α–β network model with byte-exact
//! volumes.
//!
//! - [`net`]: the α–β [`NetModel`] and its collective-cost formulas.
//! - [`cluster`]: [`SimCluster`] — phase execution (makespan timing),
//!   point-to-point and allreduce charging, and the scoped-thread
//!   parallel rank executor that makes multi-rank experiments wall-clock
//!   scale with host cores while keeping per-rank timings honest.
//! - [`fault`]: seeded deterministic fault injection ([`FaultPlan`] /
//!   [`FaultInjector`]) and the [`RankFailure`] the fallible phase
//!   methods surface instead of propagating panics.

pub mod cluster;
pub mod fault;
pub mod net;

pub use cluster::{cat, run_scoped, ConcurrencyReport, SimCluster};
pub use fault::{FailureKind, FaultInjector, FaultKind, FaultPlan, FaultSpec, RankFailure};
pub use net::NetModel;
