//! Simulated distributed runtime (paper §7 methodology): a P-rank cluster
//! where compute really executes (and is timed per rank) while
//! communication runs on a pluggable [`Transport`] — either charged to an
//! α–β network model with byte-exact volumes ([`SimTransport`]) or moved
//! as real framed bytes over in-process channels ([`ChannelTransport`]).
//!
//! - [`net`]: the α–β [`NetModel`] and its collective-cost formulas.
//! - [`cluster`]: [`SimCluster`] — phase execution (makespan timing),
//!   point-to-point and allreduce charging, and the scoped-thread
//!   parallel rank executor that makes multi-rank experiments wall-clock
//!   scale with host cores while keeping per-rank timings honest.
//! - [`transport`]: the [`Transport`] seam — the analytic charger and the
//!   channel transport with framing, checksums, heartbeats, phase
//!   deadlines, and retry/backoff, whose detected failures feed the same
//!   recovery loop as injected ones.
//! - [`fault`]: seeded deterministic fault injection ([`FaultPlan`] /
//!   [`FaultInjector`]) and the [`RankFailure`] the fallible phase
//!   methods surface instead of propagating panics.

pub mod cluster;
pub mod fault;
pub mod net;
pub mod transport;

pub use cluster::{cat, run_scoped, run_scoped_pinned, ConcurrencyReport, SimCluster};
pub use fault::{FailureKind, FaultInjector, FaultKind, FaultPlan, FaultSpec, RankFailure};
pub use net::NetModel;
pub use transport::{
    ChannelTransport, Measured, SimTransport, Transport, TransportChoice, TransportFailure,
    TransportStats, TransportTuning,
};
