//! Simulated distributed runtime (paper §7 methodology): a P-rank cluster
//! where compute really executes (and is timed per rank) while
//! communication is charged to an α–β network model with byte-exact
//! volumes.
//!
//! - [`net`]: the α–β [`NetModel`] and its collective-cost formulas.
//! - [`cluster`]: [`SimCluster`] — phase execution (makespan timing),
//!   point-to-point and allreduce charging, and the scoped-thread
//!   parallel rank executor that makes multi-rank experiments wall-clock
//!   scale with host cores while keeping per-rank timings honest.

pub mod cluster;
pub mod net;

pub use cluster::{cat, run_scoped, ConcurrencyReport, SimCluster};
pub use net::NetModel;
