//! The α–β communication model (paper §7): a message of `u` units between
//! two ranks costs α + β·u seconds; collectives compose from it with the
//! usual logarithmic-tree formulas. A *unit* is one transferred scalar
//! (f32) — the paper reports volumes in units, so we charge β per unit.

/// Network parameters. Defaults approximate the paper's cluster
/// (InfiniBand-class: ~2 µs latency, ~1 GB/s effective per-rank f32
/// bandwidth ⇒ ~4 ns per 4-byte unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// Per-message latency in seconds.
    pub alpha: f64,
    /// Per-unit (one f32) transfer time in seconds.
    pub beta: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel { alpha: 2e-6, beta: 4e-9 }
    }
}

impl NetModel {
    /// Cost of one rank sending `msgs` messages totalling `units` units.
    #[inline]
    pub fn xfer(&self, msgs: u64, units: u64) -> f64 {
        msgs as f64 * self.alpha + units as f64 * self.beta
    }

    /// Predicted wall time of one p2p exchange phase: every rank sends
    /// concurrently, so the phase costs what the *worst* rank's
    /// `(msgs, units)` pair costs.
    pub fn p2p(&self, per_rank: &[(u64, u64)]) -> f64 {
        per_rank
            .iter()
            .map(|&(m, u)| self.xfer(m, u))
            .fold(0.0, f64::max)
    }

    /// Total units moved by one p2p exchange phase (volume accounting).
    pub fn p2p_volume(&self, per_rank: &[(u64, u64)]) -> u64 {
        per_rank.iter().map(|&(_, u)| u).sum()
    }

    /// Allreduce of `units` units over `p` ranks (recursive doubling /
    /// ring hybrid): ⌈log₂ P⌉ latency terms + 2·(P−1)/P·units bandwidth.
    pub fn allreduce(&self, p: usize, units: u64) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let log_p = (usize::BITS - (p - 1).leading_zeros()) as f64;
        let bw_units = 2.0 * (p as f64 - 1.0) / p as f64 * units as f64;
        log_p * self.alpha + bw_units * self.beta
    }

    /// Per-rank units actually moved by an allreduce (volume accounting).
    pub fn allreduce_volume(&self, p: usize, units: u64) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        2.0 * (p as f64 - 1.0) / p as f64 * units as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_is_alpha_beta_affine() {
        let n = NetModel { alpha: 1.0, beta: 0.5 };
        assert_eq!(n.xfer(2, 10), 2.0 + 5.0);
        assert_eq!(n.xfer(0, 0), 0.0);
    }

    #[test]
    fn allreduce_zero_on_single_rank() {
        let n = NetModel::default();
        assert_eq!(n.allreduce(1, 1_000), 0.0);
        assert_eq!(n.allreduce_volume(1, 1_000), 0.0);
    }

    #[test]
    fn allreduce_latency_grows_logarithmically() {
        let n = NetModel { alpha: 1.0, beta: 0.0 };
        assert_eq!(n.allreduce(2, 100), 1.0);
        assert_eq!(n.allreduce(4, 100), 2.0);
        assert_eq!(n.allreduce(8, 100), 3.0);
        assert_eq!(n.allreduce(5, 100), 3.0); // ⌈log₂ 5⌉
    }

    #[test]
    fn p2p_charges_worst_rank_and_sums_volume() {
        let n = NetModel { alpha: 1.0, beta: 0.5 };
        let per_rank = [(1u64, 2u64), (2, 10), (0, 0)];
        assert_eq!(n.p2p(&per_rank), 2.0 + 5.0);
        assert_eq!(n.p2p_volume(&per_rank), 12);
        assert_eq!(n.p2p(&[]), 0.0);
        assert_eq!(n.p2p_volume(&[]), 0);
    }

    #[test]
    fn zero_cost_network_charges_nothing() {
        let n = NetModel { alpha: 0.0, beta: 0.0 };
        assert_eq!(n.xfer(5, 500), 0.0);
        assert_eq!(n.allreduce(8, 500), 0.0);
    }
}
