//! Std-only stand-in for the PJRT backend, compiled when the `pjrt`
//! feature is off (the offline image vendors neither the `xla` nor the
//! `anyhow` crate). It preserves the exact API surface of
//! `runtime/pjrt.rs` so the engine seam, examples and benches compile
//! unchanged; construction always fails with a clear message, which makes
//! every caller fall back to the native engine.

use super::artifacts::{ArtifactMeta, Registry};

/// Error type standing in for `anyhow::Error` in the stub signatures.
#[derive(Debug, Clone)]
pub struct PjrtUnavailable(pub String);

impl std::fmt::Display for PjrtUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for PjrtUnavailable {}

type Result<T> = std::result::Result<T, PjrtUnavailable>;

fn unavailable<T>() -> Result<T> {
    Err(PjrtUnavailable(
        "PJRT backend not compiled: the offline image vendors no `xla` \
         crate (build with `--features pjrt` against a vendored xla to \
         enable it)"
            .into(),
    ))
}

/// Stub runtime: never constructible, so the `Engine::Pjrt` arms in the
/// engine seam are statically present but dynamically unreachable.
pub struct PjrtRuntime {
    registry: Registry,
}

impl PjrtRuntime {
    pub fn new(_registry: Registry) -> Result<PjrtRuntime> {
        unavailable()
    }

    pub fn from_default_dir() -> Result<PjrtRuntime> {
        unavailable()
    }

    pub fn has_ttm(&self, n: usize, k: usize) -> bool {
        self.registry.find_ttm(n, k).is_some()
    }

    pub fn has_matvec(&self, khat: usize) -> bool {
        self.registry.find_matvec("matvec", khat).is_some()
            && self.registry.find_matvec("rmatvec", khat).is_some()
    }

    pub fn ttm_batch(&self, n: usize, k: usize) -> Option<usize> {
        self.registry.find_ttm(n, k).map(|m| m.b)
    }

    pub fn matvec_rtile(&self, khat: usize) -> Option<usize> {
        self.registry.find_matvec("matvec", khat).map(|m| m.rtile)
    }

    pub fn kron3(
        &self,
        _k: usize,
        _rows_a: &[f32],
        _rows_b: &[f32],
        _vals: &[f32],
    ) -> Result<Vec<f32>> {
        unavailable()
    }

    pub fn kron4(
        &self,
        _k: usize,
        _rows_a: &[f32],
        _rows_b: &[f32],
        _rows_c: &[f32],
        _vals: &[f32],
    ) -> Result<Vec<f32>> {
        unavailable()
    }

    pub fn matvec(&self, _khat: usize, _z_tile: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }

    pub fn rmatvec(&self, _khat: usize, _y: &[f32], _z_tile: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }

    pub fn upload_z(&self, _khat: usize, _rows: usize, _z: &[f32]) -> Result<ZDevice> {
        unavailable()
    }

    pub fn matvec_dev(&self, _z: &ZDevice, _x: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }

    pub fn rmatvec_dev(&self, _z: &ZDevice, _y: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }
}

/// Stub device-resident Z (never constructed).
pub struct ZDevice {
    pub rows: usize,
    pub khat: usize,
    pub rtile: usize,
}

/// Keep the meta type referenced so the stub mirrors the real module's
/// imports (and rustc flags signature drift between the two).
#[allow(dead_code)]
fn _signature_anchor(_m: &ArtifactMeta) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_never_constructs() {
        assert!(PjrtRuntime::from_default_dir().is_err());
        let reg = Registry::default();
        assert!(PjrtRuntime::new(reg).is_err());
    }

    #[test]
    fn error_mentions_feature() {
        let err = PjrtRuntime::from_default_dir().unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }
}
