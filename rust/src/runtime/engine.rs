//! Compute engine: the seam between the L3 coordinator and the AOT
//! artifacts. `Engine::Pjrt` is the deliverable architecture (compiled HLO
//! on the request path); `Engine::Native` is the in-process reference used
//! for cross-checking and the runtime ablation bench. Both expose the same
//! padded-batch contract; shapes the artifact set does not cover fall back
//! to native (reported by `coverage_note`).

use super::pjrt::{PjrtRuntime, ZDevice};
use crate::linalg::Mat;
use crate::util::float::exactly_zero_f32;

/// A local penultimate matrix prepared for repeated Lanczos queries.
/// `Device` holds Z^p tiles resident on the PJRT device — uploaded once
/// per mode, reused across all Q_n queries (§Perf: removes the dominant
/// per-call transfer; 2.7 ms → 34 µs per 512×100 x-query on this host).
pub enum PreparedZ {
    Host,
    Device(ZDevice),
}

pub enum Engine {
    /// In-process reference. The TTM assembly uses the scatter-fused path
    /// (no batch materialization — §Perf iteration 2: 1.46× over batched).
    Native,
    /// Native but through the same batched contract as the PJRT path —
    /// kept for the runtime ablation (benches/ablate_runtime.rs).
    NativeBatched,
    /// Compiled HLO artifacts on the PJRT CPU client.
    Pjrt(PjrtRuntime),
}

impl Engine {
    /// Build the PJRT engine from the default artifact dir, or fall back to
    /// native with a note (used by examples so they run pre-`make artifacts`).
    pub fn pjrt_or_native() -> (Engine, &'static str) {
        match PjrtRuntime::from_default_dir() {
            Ok(rt) => (Engine::Pjrt(rt), "pjrt"),
            Err(_) => (Engine::Native, "native (artifacts not built)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            Engine::NativeBatched => "native-batched",
            Engine::Pjrt(_) => "pjrt",
        }
    }

    /// Should the TTM assembly use the scatter-fused path (no batch)?
    /// Both the legacy `assemble_local_z` and the precompiled
    /// `hooi::plan::TtmPlan::assemble` dispatch on this.
    pub fn prefers_fused_ttm(&self) -> bool {
        matches!(self, Engine::Native)
    }

    /// Preferred TTM batch size for arity n, core length k.
    pub fn ttm_batch_size(&self, n: usize, k: usize) -> usize {
        match self {
            Engine::Native | Engine::NativeBatched => 4096,
            Engine::Pjrt(rt) => rt.ttm_batch(n, k).unwrap_or(4096),
        }
    }

    /// Is the PJRT path actually covering (n, k) + its K̂ matvecs?
    pub fn covers(&self, n: usize, k: usize) -> bool {
        match self {
            Engine::Native | Engine::NativeBatched => true,
            Engine::Pjrt(rt) => {
                let khat = k.pow(n as u32 - 1);
                rt.has_ttm(n, k) && rt.has_matvec(khat)
            }
        }
    }

    /// Batched 3-D contribution kernel: rows_a/rows_b are (B,K) flattened,
    /// vals length B (padding rows must carry val=0). Returns (B,K²).
    pub fn kron3_batch(&self, k: usize, rows_a: &[f32], rows_b: &[f32], vals: &[f32]) -> Vec<f32> {
        if let Engine::Pjrt(rt) = self {
            if rt.has_ttm(3, k) && vals.len() == rt.ttm_batch(3, k).unwrap_or(0) {
                return rt
                    .kron3(k, rows_a, rows_b, vals)
                    .expect("pjrt kron3 execution failed");
            }
        }
        native_kron3(k, rows_a, rows_b, vals)
    }

    /// Batched 4-D contribution kernel. Returns (B,K³).
    pub fn kron4_batch(
        &self,
        k: usize,
        rows_a: &[f32],
        rows_b: &[f32],
        rows_c: &[f32],
        vals: &[f32],
    ) -> Vec<f32> {
        if let Engine::Pjrt(rt) = self {
            if rt.has_ttm(4, k) && vals.len() == rt.ttm_batch(4, k).unwrap_or(0) {
                return rt
                    .kron4(k, rows_a, rows_b, rows_c, vals)
                    .expect("pjrt kron4 execution failed");
            }
        }
        native_kron4(k, rows_a, rows_b, rows_c, vals)
    }

    /// Prepare a local Z^p for repeated queries (uploads tiles to the
    /// device on the PJRT path; no-op for native engines).
    pub fn prepare_z(&self, z: &Mat) -> PreparedZ {
        if let Engine::Pjrt(rt) = self {
            if z.rows > 0 && rt.has_matvec(z.cols) {
                if let Ok(dev) = rt.upload_z(z.cols, z.rows, &z.data) {
                    return PreparedZ::Device(dev);
                }
            }
        }
        PreparedZ::Host
    }

    /// x-query against a prepared Z (falls back to the host path).
    pub fn matvec_prepared(&self, p: &PreparedZ, z: &Mat, x: &[f32]) -> Vec<f32> {
        if let (Engine::Pjrt(rt), PreparedZ::Device(dev)) = (self, p) {
            return rt.matvec_dev(dev, x).expect("pjrt matvec_dev failed");
        }
        self.local_matvec(z, x)
    }

    /// y-query against a prepared Z (falls back to the host path).
    pub fn rmatvec_prepared(&self, p: &PreparedZ, y: &[f32], z: &Mat) -> Vec<f32> {
        if let (Engine::Pjrt(rt), PreparedZ::Device(dev)) = (self, p) {
            return rt.rmatvec_dev(dev, y).expect("pjrt rmatvec_dev failed");
        }
        self.local_rmatvec(y, z)
    }

    /// Local x-query: Z^p · x over the truncated local copy. The PJRT path
    /// tiles rows to the artifact's R_TILE, zero-padding the ragged tail.
    pub fn local_matvec(&self, z: &Mat, x: &[f32]) -> Vec<f32> {
        let khat = z.cols;
        if let Engine::Pjrt(rt) = self {
            if let Some(rtile) = rt.matvec_rtile(khat) {
                let mut out = Vec::with_capacity(z.rows);
                let mut start = 0usize;
                while start < z.rows {
                    let rows = (z.rows - start).min(rtile);
                    let tile = &z.data[start * khat..(start + rows) * khat];
                    let res = if rows == rtile {
                        rt.matvec(khat, tile, x).expect("pjrt matvec failed")
                    } else {
                        let mut padded = vec![0.0f32; rtile * khat];
                        padded[..tile.len()].copy_from_slice(tile);
                        rt.matvec(khat, &padded, x).expect("pjrt matvec failed")
                    };
                    out.extend_from_slice(&res[..rows]);
                    start += rows;
                }
                return out;
            }
        }
        z.matvec(x)
    }

    /// Local y-query: y · Z^p (length K̂), tiled like `local_matvec`.
    pub fn local_rmatvec(&self, y: &[f32], z: &Mat) -> Vec<f32> {
        let khat = z.cols;
        if let Engine::Pjrt(rt) = self {
            if let Some(rtile) = rt.matvec_rtile(khat) {
                let mut out = vec![0.0f32; khat];
                let mut start = 0usize;
                while start < z.rows {
                    let rows = (z.rows - start).min(rtile);
                    let tile = &z.data[start * khat..(start + rows) * khat];
                    let ytile = &y[start..start + rows];
                    let res = if rows == rtile {
                        rt.rmatvec(khat, ytile, tile).expect("pjrt rmatvec failed")
                    } else {
                        let mut zp = vec![0.0f32; rtile * khat];
                        zp[..tile.len()].copy_from_slice(tile);
                        let mut yp = vec![0.0f32; rtile];
                        yp[..rows].copy_from_slice(ytile);
                        rt.rmatvec(khat, &yp, &zp).expect("pjrt rmatvec failed")
                    };
                    for (o, r) in out.iter_mut().zip(&res) {
                        *o += r;
                    }
                    start += rows;
                }
                return out;
            }
        }
        z.tmatvec(y)
    }
}

// The dist::SimCluster scoped-thread rank executor shares `&Engine` (and
// oracle-prepared Z handles) across rank threads — keep that a
// compile-time invariant so a non-thread-safe backend cannot sneak in.
const _: () = {
    const fn assert_sync<T: Sync>() {}
    assert_sync::<Engine>();
    assert_sync::<PreparedZ>();
};

/// Native reference: batched 3-D Kronecker contributions, layout contract
/// of python/compile/kernels/ref.py (earlier mode fastest).
pub fn native_kron3(k: usize, rows_a: &[f32], rows_b: &[f32], vals: &[f32]) -> Vec<f32> {
    let b = vals.len();
    let mut out = vec![0.0f32; b * k * k];
    for e in 0..b {
        let v = vals[e];
        if exactly_zero_f32(v) {
            continue;
        }
        let ra = &rows_a[e * k..(e + 1) * k];
        let rb = &rows_b[e * k..(e + 1) * k];
        let o = &mut out[e * k * k..(e + 1) * k * k];
        for cb in 0..k {
            let w = v * rb[cb];
            let seg = &mut o[cb * k..(cb + 1) * k];
            for ca in 0..k {
                seg[ca] = w * ra[ca];
            }
        }
    }
    out
}

/// Native reference: batched 4-D contributions (kron of three rows).
pub fn native_kron4(
    k: usize,
    rows_a: &[f32],
    rows_b: &[f32],
    rows_c: &[f32],
    vals: &[f32],
) -> Vec<f32> {
    let b = vals.len();
    let k3 = k * k * k;
    let mut out = vec![0.0f32; b * k3];
    for e in 0..b {
        let v = vals[e];
        if exactly_zero_f32(v) {
            continue;
        }
        let ra = &rows_a[e * k..(e + 1) * k];
        let rb = &rows_b[e * k..(e + 1) * k];
        let rc = &rows_c[e * k..(e + 1) * k];
        let o = &mut out[e * k3..(e + 1) * k3];
        for cc in 0..k {
            let wv = v * rc[cc];
            for cb in 0..k {
                let w = wv * rb[cb];
                let seg = &mut o[(cc * k + cb) * k..(cc * k + cb) * k + k];
                for ca in 0..k {
                    seg[ca] = w * ra[ca];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_kron3_layout() {
        // contr[ca + cb*K] = v * a[ca] * b[cb]
        let k = 3;
        let a = [1.0, 2.0, 3.0];
        let b = [10.0, 100.0, 1000.0];
        let out = native_kron3(k, &a, &b, &[2.0]);
        for cb in 0..k {
            for ca in 0..k {
                assert_eq!(out[ca + cb * k], 2.0 * a[ca] * b[cb]);
            }
        }
    }

    #[test]
    fn native_kron4_layout() {
        let k = 2;
        let a = [1.0, 2.0];
        let b = [3.0, 5.0];
        let c = [7.0, 11.0];
        let out = native_kron4(k, &a, &b, &c, &[1.0]);
        for cc in 0..k {
            for cb in 0..k {
                for ca in 0..k {
                    assert_eq!(out[ca + cb * k + cc * k * k], a[ca] * b[cb] * c[cc]);
                }
            }
        }
    }

    #[test]
    fn zero_val_padding_rows_are_zero() {
        let k = 2;
        let rows = [1.0, 2.0, 3.0, 4.0];
        let out = native_kron3(k, &rows, &rows, &[1.0, 0.0]);
        assert!(out[4..].iter().all(|&x| exactly_zero_f32(x)));
    }

    #[test]
    fn native_engine_matvec_matches_mat() {
        let z = Mat::from_fn(7, 4, |r, c| (r * 4 + c) as f32 * 0.25);
        let x = vec![1.0, -1.0, 0.5, 2.0];
        let e = Engine::Native;
        assert_eq!(e.local_matvec(&z, &x), z.matvec(&x));
        let y = vec![1.0; 7];
        assert_eq!(e.local_rmatvec(&y, &z), z.tmatvec(&y));
    }
}
