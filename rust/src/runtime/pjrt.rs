//! PJRT execution backend: load AOT HLO-text artifacts, compile them once
//! on the CPU PJRT client, execute padded fixed-shape batches from the L3
//! hot loop. This is the request-path half of the three-layer
//! architecture — Python authored the graphs (build time), rust runs them.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, avoiding the 64-bit-id proto incompatibility between
//! jax ≥ 0.5 and xla_extension 0.5.1.

use super::artifacts::{ArtifactMeta, Registry};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One compiled executable + its static shape metadata.
struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// PJRT runtime: compile-once execute-many artifact cache.
///
/// The cache is a `Mutex` (not `RefCell`) so the runtime can be shared
/// across the `dist::SimCluster` scoped-thread rank executor; the PJRT
/// C API client and loaded executables are documented thread-safe, which
/// the `unsafe impl`s below assert for the wrapper types.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    registry: Registry,
    /// (kind, n, k|khat) -> compiled executable, compiled lazily. The
    /// lock guards only lookup/compile-insert; executions run on a
    /// cloned `Arc` with the lock released, so concurrent ranks never
    /// serialize on the hot path.
    cache: Mutex<HashMap<(String, usize, usize), Arc<Loaded>>>,
}

// SAFETY: the PJRT CPU client, compiled executables and device buffers
// are thread-safe per the PJRT C API contract (concurrent Execute calls
// are supported); all interior mutability on the rust side goes through
// the Mutex above.
unsafe impl Send for PjrtRuntime {}
// SAFETY: shared references only reach the runtime through &self
// methods whose rust-side mutable state is behind the cache Mutex; the
// PJRT client itself supports concurrent use (note above).
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    pub fn new(registry: Registry) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, registry, cache: Mutex::new(HashMap::new()) })
    }

    pub fn from_default_dir() -> Result<PjrtRuntime> {
        let dir = Registry::default_dir();
        let registry = Registry::load(&dir).map_err(|e| anyhow!(e))?;
        Self::new(registry)
    }

    fn compile(&self, meta: &ArtifactMeta) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.registry.path_of(meta);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", meta.file))?;
        Ok(exe)
    }

    fn with_loaded<R>(
        &self,
        key: (String, usize, usize),
        find: impl Fn(&Registry) -> Option<ArtifactMeta>,
        f: impl FnOnce(&Loaded) -> Result<R>,
    ) -> Result<R> {
        let loaded = {
            let mut cache = self.cache.lock().expect("pjrt cache poisoned");
            match cache.get(&key) {
                Some(l) => l.clone(),
                None => {
                    let meta = find(&self.registry).ok_or_else(|| {
                        anyhow!("no artifact for {key:?} (rebuild with `make artifacts`)")
                    })?;
                    let exe = self.compile(&meta)?;
                    let l = Arc::new(Loaded { exe, meta });
                    cache.insert(key, l.clone());
                    l
                }
            }
            // lock dropped here: the execute below must not serialize ranks
        };
        f(&loaded)
    }

    /// Does the artifact set cover a TTM kernel for (n, k)?
    pub fn has_ttm(&self, n: usize, k: usize) -> bool {
        self.registry.find_ttm(n, k).is_some()
    }

    /// Does the artifact set cover matvec tiles for K̂?
    pub fn has_matvec(&self, khat: usize) -> bool {
        self.registry.find_matvec("matvec", khat).is_some()
            && self.registry.find_matvec("rmatvec", khat).is_some()
    }

    /// Static batch size of the (n, k) TTM artifact.
    pub fn ttm_batch(&self, n: usize, k: usize) -> Option<usize> {
        self.registry.find_ttm(n, k).map(|m| m.b)
    }

    /// Row-tile of the matvec artifacts for K̂.
    pub fn matvec_rtile(&self, khat: usize) -> Option<usize> {
        self.registry.find_matvec("matvec", khat).map(|m| m.rtile)
    }

    /// Host→device buffer (§Perf iteration 5: `buffer_from_host_buffer` +
    /// `execute_b` skips the intermediate Literal entirely — the Literal
    /// round-trip was the dominant per-call cost of the TTM batches).
    fn buf(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    fn run1b(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<f32>> {
        let result = exe.execute_b(inputs)?[0][0].to_literal_sync()?;
        // graphs are lowered with return_tuple=True
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute the 3-D TTM contribution kernel on one full batch.
    /// Inputs are flattened (B,K) row-major; output (B, K²) flattened.
    pub fn kron3(&self, k: usize, rows_a: &[f32], rows_b: &[f32], vals: &[f32]) -> Result<Vec<f32>> {
        self.with_loaded(
            ("ttm".into(), 3, k),
            |reg| reg.find_ttm(3, k).cloned(),
            |loaded| {
                let b = loaded.meta.b;
                debug_assert_eq!(rows_a.len(), b * k);
                debug_assert_eq!(vals.len(), b);
                let la = self.buf(rows_a, &[b, k])?;
                let lb = self.buf(rows_b, &[b, k])?;
                let lv = self.buf(vals, &[b])?;
                Self::run1b(&loaded.exe, &[&la, &lb, &lv])
            },
        )
    }

    /// Execute the 4-D TTM contribution kernel (kron of three row blocks).
    pub fn kron4(
        &self,
        k: usize,
        rows_a: &[f32],
        rows_b: &[f32],
        rows_c: &[f32],
        vals: &[f32],
    ) -> Result<Vec<f32>> {
        self.with_loaded(
            ("ttm".into(), 4, k),
            |reg| reg.find_ttm(4, k).cloned(),
            |loaded| {
                let b = loaded.meta.b;
                let la = self.buf(rows_a, &[b, k])?;
                let lb = self.buf(rows_b, &[b, k])?;
                let lc = self.buf(rows_c, &[b, k])?;
                let lv = self.buf(vals, &[b])?;
                Self::run1b(&loaded.exe, &[&la, &lb, &lc, &lv])
            },
        )
    }

    /// One x-query tile: z_tile (R_TILE × K̂, flattened) · x (K̂) -> R_TILE.
    pub fn matvec(&self, khat: usize, z_tile: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.with_loaded(
            ("matvec".into(), 0, khat),
            |reg| reg.find_matvec("matvec", khat).cloned(),
            |loaded| {
                let r = loaded.meta.rtile;
                let lz = self.buf(z_tile, &[r, khat])?;
                let lx = self.buf(x, &[khat])?;
                Self::run1b(&loaded.exe, &[&lz, &lx])
            },
        )
    }

    /// One y-query tile: y (R_TILE) · z_tile (R_TILE × K̂) -> K̂.
    pub fn rmatvec(&self, khat: usize, y: &[f32], z_tile: &[f32]) -> Result<Vec<f32>> {
        self.with_loaded(
            ("rmatvec".into(), 0, khat),
            |reg| reg.find_matvec("rmatvec", khat).cloned(),
            |loaded| {
                let r = loaded.meta.rtile;
                let ly = self.buf(y, &[r])?;
                let lz = self.buf(z_tile, &[r, khat])?;
                Self::run1b(&loaded.exe, &[&ly, &lz])
            },
        )
    }
}

/// Device-resident local penultimate matrix: Z^p tiles uploaded once per
/// mode and reused across all Q_n = 4K Lanczos queries (§Perf iteration:
/// amortizes the host→device transfer of the only large matvec operand).
pub struct ZDevice {
    tiles: Vec<xla::PjRtBuffer>,
    pub rows: usize,
    pub khat: usize,
    pub rtile: usize,
}

// SAFETY: device buffers are immutable after upload and the PJRT C API
// permits concurrent executions referencing them (see the PjrtRuntime
// thread-safety note above).
unsafe impl Send for ZDevice {}
// SAFETY: all ZDevice methods take &self and never mutate the uploaded
// tiles, so concurrent shared access is read-only on both sides of the
// FFI boundary.
unsafe impl Sync for ZDevice {}

impl PjrtRuntime {
    /// Upload a local Z^p (rows × K̂ flattened) as padded R_TILE tiles.
    pub fn upload_z(&self, khat: usize, rows: usize, z: &[f32]) -> Result<ZDevice> {
        let rtile = self
            .matvec_rtile(khat)
            .ok_or_else(|| anyhow!("no matvec artifact for khat={khat}"))?;
        let mut tiles = Vec::new();
        let mut start = 0usize;
        while start < rows {
            let n = (rows - start).min(rtile);
            let tile = &z[start * khat..(start + n) * khat];
            let buf = if n == rtile {
                self.client.buffer_from_host_buffer::<f32>(tile, &[rtile, khat], None)?
            } else {
                let mut padded = vec![0.0f32; rtile * khat];
                padded[..tile.len()].copy_from_slice(tile);
                self.client.buffer_from_host_buffer::<f32>(&padded, &[rtile, khat], None)?
            };
            tiles.push(buf);
            start += n;
        }
        Ok(ZDevice { tiles, rows, khat, rtile })
    }

    /// x-query against a device-resident Z: uploads only x per call.
    pub fn matvec_dev(&self, z: &ZDevice, x: &[f32]) -> Result<Vec<f32>> {
        let xb = self.client.buffer_from_host_buffer::<f32>(x, &[z.khat], None)?;
        self.with_loaded(
            ("matvec".into(), 0, z.khat),
            |reg| reg.find_matvec("matvec", z.khat).cloned(),
            |loaded| {
                let mut out = Vec::with_capacity(z.rows);
                for (i, tile) in z.tiles.iter().enumerate() {
                    let res = loaded.exe.execute_b(&[tile, &xb])?[0][0]
                        .to_literal_sync()?
                        .to_tuple1()?;
                    let v = res.to_vec::<f32>()?;
                    let n = (z.rows - i * z.rtile).min(z.rtile);
                    out.extend_from_slice(&v[..n]);
                }
                Ok(out)
            },
        )
    }

    /// y-query against a device-resident Z: uploads only the y tiles.
    pub fn rmatvec_dev(&self, z: &ZDevice, y: &[f32]) -> Result<Vec<f32>> {
        self.with_loaded(
            ("rmatvec".into(), 0, z.khat),
            |reg| reg.find_matvec("rmatvec", z.khat).cloned(),
            |loaded| {
                let mut out = vec![0.0f32; z.khat];
                for (i, tile) in z.tiles.iter().enumerate() {
                    let n = (z.rows - i * z.rtile).min(z.rtile);
                    let yb = if n == z.rtile {
                        self.client.buffer_from_host_buffer::<f32>(
                            &y[i * z.rtile..i * z.rtile + n],
                            &[z.rtile],
                            None,
                        )?
                    } else {
                        let mut padded = vec![0.0f32; z.rtile];
                        padded[..n].copy_from_slice(&y[i * z.rtile..i * z.rtile + n]);
                        self.client.buffer_from_host_buffer::<f32>(&padded, &[z.rtile], None)?
                    };
                    let res = loaded.exe.execute_b(&[&yb, tile])?[0][0]
                        .to_literal_sync()?
                        .to_tuple1()?;
                    let v = res.to_vec::<f32>()?;
                    for (o, r) in out.iter_mut().zip(&v) {
                        *o += r;
                    }
                }
                Ok(out)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in rust/tests/pjrt_roundtrip.rs (they need
    // built artifacts); here we only test pure helpers.
    use super::super::artifacts::Registry;

    #[test]
    fn default_dir_env_override() {
        // Can't mutate env safely in parallel tests; just check the default.
        let d = Registry::default_dir();
        assert!(d.ends_with("artifacts") || d.is_absolute());
    }
}
