//! PJRT runtime (the request-path executor of the AOT artifacts) and the
//! native reference engine. See DESIGN.md §1: rust loads HLO text once,
//! compiles on the PJRT CPU client, and dispatches padded fixed-shape
//! batches from the HOOI hot loop — Python never runs at request time.

pub mod artifacts;
pub mod engine;
// The real PJRT backend needs the `xla`/`anyhow` crates, which the
// offline image does not vendor; the default build uses a std-only stub
// with the same API so every caller falls back to the native engine.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{ArtifactMeta, Registry};
pub use engine::Engine;
pub use pjrt::PjrtRuntime;
