//! Artifact registry: parse `artifacts/manifest.txt` (written by
//! python/compile/aot.py) and locate the HLO-text module for a requested
//! kernel shape. Shapes are static in HLO — the registry is how the
//! dynamic L3 hot loop maps onto the fixed-(B, K, R_TILE) artifact set.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub file: String,
    pub kind: String,
    /// Tensor arity for ttm kernels (3 or 4); 0 for matvec kernels.
    pub n: usize,
    /// Core length K (ttm kernels).
    pub k: usize,
    /// K̂ = K^{N-1} (ttm) or the tile's column count (matvec).
    pub khat: usize,
    /// Batch size B (ttm kernels).
    pub b: usize,
    /// Row tile R_TILE (matvec kernels).
    pub rtile: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Registry {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactMeta>,
}

impl Registry {
    /// Parse `<dir>/manifest.txt`. Lines: `file k=v k=v ...`.
    pub fn load(dir: &Path) -> Result<Registry, String> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", manifest.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let file = parts
                .next()
                .ok_or(format!("manifest line {}: empty", lineno + 1))?
                .to_string();
            let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
            for part in parts {
                let (k, v) = part
                    .split_once('=')
                    .ok_or(format!("manifest line {}: bad field {part:?}", lineno + 1))?;
                kv.insert(k, v);
            }
            let get = |key: &str| kv.get(key).and_then(|v| v.parse::<usize>().ok()).unwrap_or(0);
            entries.push(ArtifactMeta {
                file,
                kind: kv.get("kind").unwrap_or(&"?").to_string(),
                n: get("n"),
                k: get("k"),
                khat: get("khat"),
                b: get("b"),
                rtile: get("rtile"),
            });
        }
        Ok(Registry { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact location: `$TUCKER_ARTIFACTS` or `./artifacts`
    /// (env read centralized in `util::env`).
    pub fn default_dir() -> PathBuf {
        crate::util::env::raw(crate::util::env::ARTIFACTS)
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn path_of(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// TTM contribution kernel for arity `n` and core length `k`.
    pub fn find_ttm(&self, n: usize, k: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .find(|m| m.kind == "ttm" && m.n == n && m.k == k)
    }

    /// Matvec / rmatvec tile for a given K̂.
    pub fn find_matvec(&self, kind: &str, khat: usize) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|m| m.kind == kind && m.khat == khat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(lines: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tucker_lite_manifest_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), lines).unwrap();
        dir
    }

    #[test]
    fn parses_manifest_lines() {
        let dir = write_manifest(
            "ttm3d_k10_b8192.hlo.txt kind=ttm n=3 k=10 khat=100 b=8192\n\
             matvec_kh100_r512.hlo.txt kind=matvec khat=100 rtile=512\n",
        );
        let reg = Registry::load(&dir).unwrap();
        assert_eq!(reg.entries.len(), 2);
        let ttm = reg.find_ttm(3, 10).unwrap();
        assert_eq!(ttm.b, 8192);
        assert_eq!(ttm.khat, 100);
        let mv = reg.find_matvec("matvec", 100).unwrap();
        assert_eq!(mv.rtile, 512);
        assert!(reg.find_ttm(4, 10).is_none());
        assert!(reg.find_matvec("rmatvec", 100).is_none());
    }

    #[test]
    fn missing_manifest_is_error_with_hint() {
        let err = Registry::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn real_manifest_if_built() {
        // integration: when `make artifacts` has run, the real manifest
        // must expose the configurations the benches rely on.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let reg = Registry::load(&dir).unwrap();
        for (n, k) in [(3, 10), (3, 20), (4, 10)] {
            assert!(reg.find_ttm(n, k).is_some(), "ttm n={n} k={k}");
        }
        for khat in [100, 400, 1000] {
            assert!(reg.find_matvec("matvec", khat).is_some(), "matvec {khat}");
            assert!(reg.find_matvec("rmatvec", khat).is_some(), "rmatvec {khat}");
        }
    }
}
