//! The row-index mapping σ_n (paper §3/§5): assigns each row index
//! l ∈ [1, L_n] of the penultimate matrix to an owner rank. The owner is
//! chosen among the ranks *sharing* Slice_n^l (so the x-query reduction
//! terminates at a rank that already holds a partial row), balancing the
//! number of owned rows across ranks — the paper's stated policy
//! ("taking into account communication load balance").

use super::metrics::Sharers;

#[derive(Debug, Clone)]
pub struct RowMap {
    /// owner[l] = σ_n(l).
    pub owner: Vec<u32>,
    pub p: usize,
}

impl RowMap {
    /// Greedy min-load owner among sharers. Empty slices (no sharers) get
    /// round-robin owners — their rows are identically zero but the
    /// Lanczos vectors still need a home for every index.
    pub fn build(sharers: &Sharers, p: usize) -> RowMap {
        let l_n = sharers.num_slices();
        let mut owned = vec![0u32; p];
        let mut owner = vec![0u32; l_n];
        // process most-constrained slices first (fewest sharers), so
        // single-sharer slices don't get starved by flexible ones
        let mut order: Vec<u32> = (0..l_n as u32).collect();
        order.sort_by_key(|&l| sharers.of(l as usize).len());
        let mut rr = 0u32;
        for &lu in &order {
            let l = lu as usize;
            let cands = sharers.of(l);
            let pick = if cands.is_empty() {
                let r = rr % p as u32;
                rr += 1;
                r
            } else {
                *cands
                    .iter()
                    .min_by_key(|&&r| owned[r as usize])
                    .expect("nonempty cands")
            };
            owner[l] = pick;
            owned[pick as usize] += 1;
        }
        RowMap { owner, p }
    }

    #[inline]
    pub fn of(&self, l: usize) -> u32 {
        self.owner[l]
    }

    /// Rows owned per rank (communication balance diagnostic).
    pub fn owned_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.p];
        for &r in &self.owner {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Row indices owned by each rank.
    pub fn rows_of_rank(&self) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.p];
        for (l, &r) in self.owner.iter().enumerate() {
            out[r as usize].push(l as u32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::ModePolicy;
    use crate::tensor::{SliceIndex, SparseTensor};
    use crate::util::rng::Rng;

    fn setup(p: usize, seed: u64) -> (SliceIndex, ModePolicy) {
        let mut rng = Rng::new(seed);
        let t = SparseTensor::random(vec![40, 6, 6], 600, &mut rng);
        let idx = SliceIndex::build(&t, 0);
        let assign: Vec<u32> =
            (0..t.nnz()).map(|_| rng.below(p as u64) as u32).collect();
        (idx, ModePolicy::new(p, assign))
    }

    #[test]
    fn owner_is_a_sharer_for_nonempty_slices() {
        let (idx, pol) = setup(4, 1);
        let sharers = Sharers::build(&idx, &pol);
        let map = RowMap::build(&sharers, 4);
        for l in 0..sharers.num_slices() {
            let s = sharers.of(l);
            if !s.is_empty() {
                assert!(s.contains(&map.of(l)), "slice {l}");
            }
        }
    }

    #[test]
    fn every_row_has_an_owner_in_range() {
        let (idx, pol) = setup(3, 2);
        let sharers = Sharers::build(&idx, &pol);
        let map = RowMap::build(&sharers, 3);
        assert_eq!(map.owner.len(), idx.num_slices());
        assert!(map.owner.iter().all(|&r| (r as usize) < 3));
        assert_eq!(map.owned_counts().iter().sum::<usize>(), idx.num_slices());
    }

    #[test]
    fn balances_when_everyone_shares_everything() {
        // all ranks share every slice -> owners should spread evenly
        let mut t = SparseTensor::new(vec![12, 2, 2]);
        for l in 0..12u32 {
            for r in 0..4u32 {
                t.push(&[l, 0, 0], (r + 1) as f32);
            }
        }
        let idx = SliceIndex::build(&t, 0);
        // element i belongs to rank i%4; each slice has one element per rank
        let assign: Vec<u32> = (0..t.nnz()).map(|e| (e % 4) as u32).collect();
        let pol = ModePolicy::new(4, assign);
        let sharers = Sharers::build(&idx, &pol);
        let map = RowMap::build(&sharers, 4);
        let counts = map.owned_counts();
        assert_eq!(counts, vec![3, 3, 3, 3]);
    }

    #[test]
    fn rows_of_rank_partitions() {
        let (idx, pol) = setup(5, 3);
        let sharers = Sharers::build(&idx, &pol);
        let map = RowMap::build(&sharers, 5);
        let by_rank = map.rows_of_rank();
        let total: usize = by_rank.iter().map(|v| v.len()).sum();
        assert_eq!(total, idx.num_slices());
    }
}
