//! Distribution policies and schemes (paper §3, "Distribution Schemes").
//!
//! A *policy* π_n maps each non-zero element to an owner rank for the
//! computation along mode n. A *scheme* is the sequence (π_1..π_N);
//! uni-policy schemes use one π for all modes (one stored tensor copy),
//! multi-policy schemes customize per mode (N copies).

use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;

/// Element → rank assignment along one mode.
#[derive(Debug, Clone)]
pub struct ModePolicy {
    /// World size P.
    pub p: usize,
    /// assign[e] = owner rank of element e under this mode's policy.
    pub assign: Vec<u32>,
}

impl ModePolicy {
    /// Per-rank element counts |E_n^p| (the E metric's raw data).
    pub fn rank_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.p];
        for &r in &self.assign {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Per-rank element id lists, slice-grouped iteration order preserved
    /// from the provided slice index (cache-friendly TTM walks).
    pub fn rank_elements(&self, idx: &SliceIndex) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.p];
        for l in 0..idx.num_slices() {
            for &e in idx.slice(l) {
                out[self.assign[e as usize] as usize].push(e);
            }
        }
        out
    }
}

/// Timing of the distribution step (Fig 16): the real measured
/// construction cost and the simulated parallel cost charged to the
/// cluster (lightweight schemes run in parallel in the paper; HyperG is
/// offline-serial).
#[derive(Debug, Clone, Copy, Default)]
pub struct DistTime {
    pub serial_secs: f64,
    pub simulated_secs: f64,
}

/// A constructed distribution: one policy per mode.
#[derive(Debug, Clone)]
pub struct Distribution {
    pub scheme: String,
    pub p: usize,
    /// policies[n] = π_n. Uni-policy schemes store N clones of the same
    /// assignment (and set `uni` so memory/FM accounting knows).
    pub policies: Vec<ModePolicy>,
    pub uni: bool,
    pub time: DistTime,
}

impl Distribution {
    pub fn ndim(&self) -> usize {
        self.policies.len()
    }

    /// Number of stored tensor copies (memory model, Fig 17).
    pub fn tensor_copies(&self) -> usize {
        if self.uni {
            1
        } else {
            self.ndim()
        }
    }

    /// Sanity: every element assigned a valid rank in every mode.
    pub fn validate(&self, t: &SparseTensor) -> Result<(), String> {
        if self.policies.len() != t.ndim() {
            return Err(format!(
                "{} policies for {}-mode tensor",
                self.policies.len(),
                t.ndim()
            ));
        }
        for (n, pol) in self.policies.iter().enumerate() {
            if pol.assign.len() != t.nnz() {
                return Err(format!("mode {n}: {} assigns != nnz", pol.assign.len()));
            }
            if pol.p != self.p {
                return Err(format!("mode {n}: policy P mismatch"));
            }
            if let Some(&bad) = pol.assign.iter().find(|&&r| r as usize >= self.p) {
                return Err(format!("mode {n}: rank {bad} out of range"));
            }
        }
        Ok(())
    }
}

/// A distribution scheme constructor.
pub trait Scheme {
    fn name(&self) -> &'static str;
    fn uni(&self) -> bool;
    /// Build the per-mode policies. `idx` holds the slice index of every
    /// mode. Implementations must fill `Distribution::time.serial_secs`
    /// (their own measured construction cost) and `simulated_secs` (the
    /// parallel-execution model documented per scheme).
    fn distribute(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
    ) -> Distribution;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_counts_sum_to_nnz() {
        let pol = ModePolicy { p: 3, assign: vec![0, 1, 1, 2, 0, 0] };
        assert_eq!(pol.rank_counts(), vec![3, 2, 1]);
    }

    #[test]
    fn rank_elements_partition() {
        let mut t = SparseTensor::new(vec![3, 2]);
        for i in 0..6 {
            t.push(&[(i % 3) as u32, (i % 2) as u32], 1.0);
        }
        let idx = SliceIndex::build(&t, 0);
        let pol = ModePolicy { p: 2, assign: vec![0, 1, 0, 1, 0, 1] };
        let per_rank = pol.rank_elements(&idx);
        let total: usize = per_rank.iter().map(|v| v.len()).sum();
        assert_eq!(total, 6);
        for (r, elems) in per_rank.iter().enumerate() {
            for &e in elems {
                assert_eq!(pol.assign[e as usize] as usize, r);
            }
        }
    }

    #[test]
    fn validate_catches_bad_rank() {
        let mut t = SparseTensor::new(vec![2, 2]);
        t.push(&[0, 0], 1.0);
        let d = Distribution {
            scheme: "x".into(),
            p: 2,
            policies: vec![
                ModePolicy { p: 2, assign: vec![5] },
                ModePolicy { p: 2, assign: vec![0] },
            ],
            uni: false,
            time: DistTime::default(),
        };
        assert!(d.validate(&t).is_err());
    }

    #[test]
    fn copies_follow_uni_flag() {
        let d = Distribution {
            scheme: "x".into(),
            p: 2,
            policies: vec![ModePolicy { p: 2, assign: vec![] }; 3],
            uni: true,
            time: DistTime::default(),
        };
        assert_eq!(d.tensor_copies(), 1);
        let mut d2 = d.clone();
        d2.uni = false;
        assert_eq!(d2.tensor_copies(), 3);
    }
}
