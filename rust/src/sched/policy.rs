//! Distribution policies, schemes, and the first-class placement plan
//! (paper §3, "Distribution Schemes"; §4, the metrics the plan carries).
//!
//! A *policy* π_n maps each non-zero element to an owner rank for the
//! computation along mode n. A *scheme* is the sequence (π_1..π_N);
//! uni-policy schemes use one π for all modes (one stored tensor copy),
//! multi-policy schemes customize per mode (N copies).
//!
//! [`Distribution`] is the raw object — policies plus provenance.
//! [`PlacementPlan`] promotes it to the API's first-class citizen: the
//! same policies *plus* the per-mode §4 metrics/sharer indices they
//! induce and a cost estimate under a [`CostModel`], which is what lets
//! two plans be [`diff`](PlacementPlan::diff)-ed into a
//! [`MigrationPlan`] and compared by predicted per-sweep cost — the
//! machinery behind `TuckerSession`'s streaming rebalance loop.

use super::cost::{CostEstimate, CostModel};
use super::diff::MigrationPlan;
use super::metrics::{ModeMetrics, Sharers};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Element → rank assignment along one mode.
#[derive(Debug, Clone)]
pub struct ModePolicy {
    /// World size P.
    pub p: usize,
    /// assign[e] = owner rank of element e under this mode's policy.
    ///
    /// Shared (`Arc`) so uni-policy schemes alias *one* buffer across
    /// all N modes instead of storing N identical clones; mutate
    /// through [`Arc::make_mut`] (copy-on-write keeps shared plans of
    /// other sessions intact).
    pub assign: Arc<Vec<u32>>,
}

impl ModePolicy {
    /// Wrap a freshly built assignment vector.
    pub fn new(p: usize, assign: Vec<u32>) -> ModePolicy {
        ModePolicy { p, assign: Arc::new(assign) }
    }

    /// Per-rank element counts |E_n^p| (the E metric's raw data).
    pub fn rank_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.p];
        for &r in self.assign.iter() {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Per-rank element id lists, slice-grouped iteration order preserved
    /// from the provided slice index (cache-friendly TTM walks).
    pub fn rank_elements(&self, idx: &SliceIndex) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); self.p];
        for l in 0..idx.num_slices() {
            for &e in idx.slice(l) {
                out[self.assign[e as usize] as usize].push(e);
            }
        }
        out
    }
}

/// Timing of the distribution step (Fig 16): the real measured
/// construction cost and the simulated parallel cost charged to the
/// cluster (lightweight schemes run in parallel in the paper; HyperG is
/// offline-serial).
#[derive(Debug, Clone, Copy, Default)]
pub struct DistTime {
    pub serial_secs: f64,
    pub simulated_secs: f64,
}

/// A constructed distribution: one policy per mode.
#[derive(Debug, Clone)]
pub struct Distribution {
    pub scheme: String,
    pub p: usize,
    /// policies[n] = π_n. Uni-policy schemes share one `Arc`'d
    /// assignment buffer across all N entries (and set `uni` so
    /// memory/FM accounting knows).
    pub policies: Vec<ModePolicy>,
    pub uni: bool,
    pub time: DistTime,
}

impl Distribution {
    pub fn ndim(&self) -> usize {
        self.policies.len()
    }

    /// Number of stored tensor copies (memory model, Fig 17).
    pub fn tensor_copies(&self) -> usize {
        if self.uni {
            1
        } else {
            self.ndim()
        }
    }

    /// Bytes the stored assignment vectors occupy, counting each
    /// `Arc`-aliased buffer exactly once — uni-policy schemes pay one
    /// copy, not N.
    pub fn assignment_bytes(&self) -> u64 {
        let mut seen: Vec<*const Vec<u32>> = Vec::new();
        let mut bytes = 0u64;
        for pol in &self.policies {
            let ptr = Arc::as_ptr(&pol.assign);
            if !seen.contains(&ptr) {
                seen.push(ptr);
                bytes += 4 * pol.assign.len() as u64;
            }
        }
        bytes
    }

    /// Sanity: every element assigned a valid rank in every mode.
    pub fn validate(&self, t: &SparseTensor) -> Result<(), String> {
        if self.policies.len() != t.ndim() {
            return Err(format!(
                "{} policies for {}-mode tensor",
                self.policies.len(),
                t.ndim()
            ));
        }
        for (n, pol) in self.policies.iter().enumerate() {
            if pol.assign.len() != t.nnz() {
                return Err(format!("mode {n}: {} assigns != nnz", pol.assign.len()));
            }
            if pol.p != self.p {
                return Err(format!("mode {n}: policy P mismatch"));
            }
            if let Some(&bad) = pol.assign.iter().find(|&&r| r as usize >= self.p) {
                return Err(format!("mode {n}: rank {bad} out of range"));
            }
        }
        Ok(())
    }
}

/// One mode's slot in a [`PlacementPlan`]: the §4 metrics the policy
/// induces and the sharer index they were computed from.
#[derive(Debug, Clone)]
pub struct PlanMode {
    /// E_n^max / R_n^sum / R_n^max and their per-rank raw data.
    pub metrics: ModeMetrics,
    /// Ranks sharing each mode-n slice (CSR) — reused by diff apply and
    /// introspection; `hooi::prepare_modes` builds its own copy for the
    /// TTM state.
    pub sharers: Sharers,
}

/// A distribution promoted to a first-class plan: the policies, their
/// scheme provenance and [`DistTime`], the per-mode
/// [`ModeMetrics`]/[`Sharers`] they induce, and a §4 cost estimate
/// ([`CostEstimate`]) under the model the plan was compiled with.
///
/// Two plans over the same tensor diff into a [`MigrationPlan`] — the
/// exact per-(mode, rank) moved-element sets plus migration byte volume
/// — which is what `TuckerSession::rebalance` applies through the HOOI
/// layer's splice/rebuild machinery instead of re-running
/// `prepare_modes` wholesale.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    /// The raw policies + provenance (scheme name, P, uni flag, timing).
    pub dist: Distribution,
    /// Per-mode metrics and sharer indices, in mode order.
    pub modes: Vec<PlanMode>,
    /// The per-mode core ranks `[K_0, …, K_{N−1}]` the cost estimate
    /// was computed for.
    pub ks: Vec<usize>,
    /// Predicted per-sweep cost under the compile-time [`CostModel`].
    pub cost: CostEstimate,
}

impl PlacementPlan {
    /// Compile a raw [`Distribution`] into a plan: build each mode's
    /// sharer index and §4 metrics, then price a HOOI sweep under
    /// `model`. `ks` are the resolved per-mode core ranks (they set
    /// K̂_n and the oracle query counts in the estimate).
    pub fn compile(
        dist: Distribution,
        idx: &[SliceIndex],
        ks: &[usize],
        model: &CostModel,
    ) -> PlacementPlan {
        assert_eq!(idx.len(), dist.ndim(), "one slice index per mode");
        assert_eq!(ks.len(), dist.ndim(), "one core rank per mode");
        let modes: Vec<PlanMode> = idx
            .iter()
            .zip(dist.policies.iter())
            .map(|(i, pol)| {
                let sharers = Sharers::build(i, pol);
                let metrics = ModeMetrics::from_sharers(i, pol, &sharers);
                PlanMode { metrics, sharers }
            })
            .collect();
        let mrefs: Vec<&ModeMetrics> = modes.iter().map(|m| &m.metrics).collect();
        let cost = CostEstimate::from_metrics(&mrefs, ks, model);
        PlacementPlan { dist, modes, ks: ks.to_vec(), cost }
    }

    /// Recompute the metrics and cost estimate after the policies were
    /// mutated in place (streaming placement extension) — the plan's
    /// provenance tracks the live assignment. Callers that already hold
    /// freshly rebuilt sharer indices should prefer
    /// [`refresh_from`](PlacementPlan::refresh_from), which skips the
    /// O(nnz) per-mode `Sharers::build`.
    pub fn refresh(&mut self, idx: &[SliceIndex], model: &CostModel) {
        self.modes = idx
            .iter()
            .zip(self.dist.policies.iter())
            .map(|(i, pol)| {
                let sharers = Sharers::build(i, pol);
                let metrics = ModeMetrics::from_sharers(i, pol, &sharers);
                PlanMode { metrics, sharers }
            })
            .collect();
        let mrefs: Vec<&ModeMetrics> = self.modes.iter().map(|m| &m.metrics).collect();
        self.cost = CostEstimate::from_metrics(&mrefs, &self.ks, model);
    }

    /// [`refresh`](PlacementPlan::refresh) reusing sharer indices the
    /// caller already rebuilt against the current policies (one per
    /// mode) — the streaming ingest path hands over the `ModeState`
    /// sharers `apply_delta` just recomputed instead of paying a second
    /// full `Sharers::build` pass per mode.
    pub fn refresh_from(
        &mut self,
        idx: &[SliceIndex],
        sharers: &[&Sharers],
        model: &CostModel,
    ) {
        assert_eq!(sharers.len(), self.dist.ndim(), "one sharer index per mode");
        self.modes = idx
            .iter()
            .zip(self.dist.policies.iter())
            .zip(sharers.iter())
            .map(|((i, pol), sh)| PlanMode {
                metrics: ModeMetrics::from_sharers(i, pol, sh),
                sharers: (*sh).clone(),
            })
            .collect();
        let mrefs: Vec<&ModeMetrics> = self.modes.iter().map(|m| &m.metrics).collect();
        self.cost = CostEstimate::from_metrics(&mrefs, &self.ks, model);
    }

    /// Exact per-(mode, rank) element movements turning this placement
    /// into `other` (same tensor, same P) — see [`MigrationPlan`].
    pub fn diff(&self, other: &PlacementPlan) -> MigrationPlan {
        MigrationPlan::compute(&self.dist, &other.dist)
    }

    /// World size P.
    pub fn p(&self) -> usize {
        self.dist.p
    }

    /// Tensor order N.
    pub fn ndim(&self) -> usize {
        self.dist.ndim()
    }

    /// Scheme provenance (registry name of the constructor).
    pub fn scheme(&self) -> &str {
        &self.dist.scheme
    }

    /// Drop the metrics/cost envelope, keeping the raw distribution.
    pub fn into_distribution(self) -> Distribution {
        self.dist
    }
}

/// A distribution scheme constructor.
///
/// Implementations override [`policies`](Scheme::policies) — the raw
/// per-mode assignment construction. Callers should use
/// [`plan`](Scheme::plan), which wraps the policies into a cost-modeled
/// [`PlacementPlan`], or call `policies` directly when the raw
/// [`Distribution`] suffices.
pub trait Scheme {
    fn name(&self) -> &'static str;
    fn uni(&self) -> bool;

    /// Build the raw per-mode policies. `idx` holds the slice index of
    /// every mode. Implementations must fill `Distribution::time`'s
    /// `serial_secs` (their own measured construction cost) and
    /// `simulated_secs` (the parallel-execution model documented per
    /// scheme).
    fn policies(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
    ) -> Distribution;

    /// The primary constructor: build the policies and compile them
    /// into a [`PlacementPlan`] carrying scheme provenance, per-mode
    /// metrics/sharers and the §4 cost estimate for the given core
    /// ranks under `model`.
    fn plan(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
        ks: &[usize],
        model: &CostModel,
    ) -> PlacementPlan {
        PlacementPlan::compile(self.policies(t, idx, p, rng), idx, ks, model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Lite;
    use crate::tensor::slices::build_all;

    #[test]
    fn rank_counts_sum_to_nnz() {
        let pol = ModePolicy::new(3, vec![0, 1, 1, 2, 0, 0]);
        assert_eq!(pol.rank_counts(), vec![3, 2, 1]);
    }

    #[test]
    fn rank_elements_partition() {
        let mut t = SparseTensor::new(vec![3, 2]);
        for i in 0..6 {
            t.push(&[(i % 3) as u32, (i % 2) as u32], 1.0);
        }
        let idx = SliceIndex::build(&t, 0);
        let pol = ModePolicy::new(2, vec![0, 1, 0, 1, 0, 1]);
        let per_rank = pol.rank_elements(&idx);
        let total: usize = per_rank.iter().map(|v| v.len()).sum();
        assert_eq!(total, 6);
        for (r, elems) in per_rank.iter().enumerate() {
            for &e in elems {
                assert_eq!(pol.assign[e as usize] as usize, r);
            }
        }
    }

    #[test]
    fn validate_catches_bad_rank() {
        let mut t = SparseTensor::new(vec![2, 2]);
        t.push(&[0, 0], 1.0);
        let d = Distribution {
            scheme: "x".into(),
            p: 2,
            policies: vec![ModePolicy::new(2, vec![5]), ModePolicy::new(2, vec![0])],
            uni: false,
            time: DistTime::default(),
        };
        assert!(d.validate(&t).is_err());
    }

    #[test]
    fn copies_follow_uni_flag() {
        let d = Distribution {
            scheme: "x".into(),
            p: 2,
            policies: vec![ModePolicy::new(2, vec![]); 3],
            uni: true,
            time: DistTime::default(),
        };
        assert_eq!(d.tensor_copies(), 1);
        let mut d2 = d.clone();
        d2.uni = false;
        assert_eq!(d2.tensor_copies(), 3);
    }

    #[test]
    fn shared_assignments_are_accounted_once() {
        // uni-style sharing: cloning a ModePolicy clones the Arc, so N
        // policy slots alias one buffer and assignment_bytes charges it
        // once; distinct buffers are charged each.
        let pol = ModePolicy::new(2, vec![0, 1, 0, 1]);
        let shared = Distribution {
            scheme: "uni".into(),
            p: 2,
            policies: vec![pol.clone(); 3],
            uni: true,
            time: DistTime::default(),
        };
        assert!(Arc::ptr_eq(
            &shared.policies[0].assign,
            &shared.policies[2].assign
        ));
        assert_eq!(shared.assignment_bytes(), 4 * 4);
        let multi = Distribution {
            scheme: "multi".into(),
            p: 2,
            policies: (0..3).map(|_| ModePolicy::new(2, vec![0, 1, 0, 1])).collect(),
            uni: false,
            time: DistTime::default(),
        };
        assert_eq!(multi.assignment_bytes(), 3 * 4 * 4);
    }

    #[test]
    fn plan_carries_metrics_and_cost() {
        let mut rng = Rng::new(11);
        let t = SparseTensor::random(vec![20, 15, 10], 600, &mut rng);
        let idx = build_all(&t);
        let model = CostModel::default();
        let plan = Lite.plan(&t, &idx, 4, &mut rng, &[4, 4, 4], &model);
        assert_eq!(plan.scheme(), "Lite");
        assert_eq!(plan.p(), 4);
        assert_eq!(plan.ndim(), 3);
        assert_eq!(plan.modes.len(), 3);
        for (n, pm) in plan.modes.iter().enumerate() {
            assert_eq!(pm.metrics.mode, n);
            assert_eq!(pm.metrics.e_counts.iter().sum::<usize>(), t.nnz());
            assert_eq!(pm.sharers.r_sum(), pm.metrics.r_sum);
        }
        assert!(plan.cost.secs_per_sweep > 0.0);
        assert_eq!(plan.cost.per_mode.len(), 3);
        // raw policies and the plan build the same assignment from the
        // same rng (plan is a pure wrapper over policies)
        let mut rng_a = Rng::new(7);
        let mut rng_b = Rng::new(7);
        let d = Lite.policies(&t, &idx, 4, &mut rng_a);
        let p2 = Lite.plan(&t, &idx, 4, &mut rng_b, &[4, 4, 4], &model);
        for (a, b) in d.policies.iter().zip(&p2.dist.policies) {
            assert_eq!(a.assign, b.assign);
        }
    }

    #[test]
    fn refresh_tracks_policy_mutation() {
        let mut rng = Rng::new(13);
        let t = SparseTensor::random(vec![12, 10, 8], 300, &mut rng);
        let idx = build_all(&t);
        let model = CostModel::default();
        let mut plan = Lite.plan(&t, &idx, 3, &mut rng, &[3, 3, 3], &model);
        let e_max_before = plan.modes[0].metrics.e_max;
        // pile every element of mode 0 onto rank 0 and refresh
        plan.dist.policies[0] = ModePolicy::new(3, vec![0; t.nnz()]);
        plan.refresh(&idx, &model);
        assert_eq!(plan.modes[0].metrics.e_max, t.nnz());
        assert!(plan.modes[0].metrics.e_max >= e_max_before);
    }
}
