//! Parallel sample sort (Hightower–Prins–Reif, the algorithm the paper's
//! Lite implementation uses to sort slices by cardinality in parallel,
//! §6.1). Executed here on one host but structured exactly as the
//! parallel algorithm — sample, splitter selection, bucket partition,
//! independent per-bucket sorts — with each bucket's sort individually
//! timed so the simulated cluster can charge the makespan.

use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone)]
pub struct SampleSortOutcome {
    /// Indices of the input, sorted ascending by key.
    pub order: Vec<u32>,
    /// Measured seconds of the slowest bucket sort (the parallel critical
    /// path), plus the serial sampling/partition prefix divided across
    /// ranks by the caller.
    pub max_bucket_secs: f64,
    /// Measured seconds of the sampling + splitter + partition prefix.
    pub prefix_secs: f64,
}

/// Sort `keys` (by value ascending, ties by index for determinism) with a
/// `p`-bucket sample sort. Returns the permutation and the timing split.
pub fn sample_sort(keys: &[u32], p: usize, rng: &mut Rng) -> SampleSortOutcome {
    let n = keys.len();
    let t0 = Stopwatch::start();
    if n == 0 {
        return SampleSortOutcome {
            order: Vec::new(),
            max_bucket_secs: 0.0,
            prefix_secs: t0.seconds(),
        };
    }
    let buckets = p.max(1).min(n);
    // oversample: s·p samples, take every s-th as splitter
    let oversample = 8usize;
    let mut sample: Vec<u32> = (0..buckets * oversample)
        .map(|_| keys[rng.usize_below(n)])
        .collect();
    sample.sort_unstable();
    let splitters: Vec<u32> = (1..buckets)
        .map(|i| sample[i * oversample])
        .collect();
    // partition into buckets
    let mut bucket_of = vec![0u32; n];
    let mut counts = vec![0usize; buckets];
    for (i, &k) in keys.iter().enumerate() {
        // first splitter > k  (upper_bound)
        let b = splitters.partition_point(|&s| s <= k);
        bucket_of[i] = b as u32;
        counts[b] += 1;
    }
    let mut starts = vec![0usize; buckets + 1];
    for b in 0..buckets {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut bucketed = vec![0u32; n];
    let mut cursor = starts.clone();
    for (i, &b) in bucket_of.iter().enumerate() {
        bucketed[cursor[b as usize]] = i as u32;
        cursor[b as usize] += 1;
    }
    let prefix_secs = t0.seconds();
    // independent bucket sorts — the parallel part
    let mut max_bucket_secs = 0.0f64;
    for b in 0..buckets {
        let tb = Stopwatch::start();
        let seg = &mut bucketed[starts[b]..starts[b + 1]];
        seg.sort_unstable_by_key(|&i| (keys[i as usize], i));
        max_bucket_secs = max_bucket_secs.max(tb.seconds());
    }
    SampleSortOutcome { order: bucketed, max_bucket_secs, prefix_secs }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorts_correctly_various_p() {
        let mut rng = Rng::new(2);
        let keys: Vec<u32> = (0..5000).map(|_| rng.below(1000) as u32).collect();
        for p in [1, 2, 7, 16, 64] {
            let mut r = Rng::new(99);
            let out = sample_sort(&keys, p, &mut r);
            assert_eq!(out.order.len(), keys.len());
            for w in out.order.windows(2) {
                assert!(keys[w[0] as usize] <= keys[w[1] as usize], "p={p}");
            }
            // permutation check
            let mut seen = vec![false; keys.len()];
            for &i in &out.order {
                assert!(!seen[i as usize]);
                seen[i as usize] = true;
            }
        }
    }

    #[test]
    fn deterministic_tie_break() {
        let keys = vec![5u32; 100];
        let mut rng = Rng::new(1);
        let out = sample_sort(&keys, 4, &mut rng);
        let expect: Vec<u32> = (0..100).collect();
        assert_eq!(out.order, expect);
    }

    #[test]
    fn empty_and_singleton() {
        let mut rng = Rng::new(1);
        assert!(sample_sort(&[], 4, &mut rng).order.is_empty());
        assert_eq!(sample_sort(&[42], 4, &mut rng).order, vec![0]);
    }

    #[test]
    fn handles_skewed_keys() {
        // all-equal except a few: buckets degenerate but output must sort
        let mut keys = vec![7u32; 2000];
        keys[1999] = 1;
        keys[0] = 9;
        let mut rng = Rng::new(5);
        let out = sample_sort(&keys, 8, &mut rng);
        assert_eq!(keys[out.order[0] as usize], 1);
        assert_eq!(keys[*out.order.last().unwrap() as usize], 9);
    }
}
