//! Incremental policy extension for streaming nonzero appends (the
//! distribution side of the plan-invalidation subsystem).
//!
//! A [`super::policy::ModePolicy`] maps element ids to ranks; appended
//! elements get ids past the current end, so extending a policy is an
//! append to its `assign` vector. [`extend_policy`] places each new
//! element with Lite's stage-2 discipline (§6, Fig 8): per-bin load
//! counters against the hard limit ⌈|E′|/P⌉, preferring ranks that
//! *already share* the element's slice so the Theorem 6.1 sharing
//! bounds (R_n^sum ≤ L_n + P, R_n^max ≤ ⌈L_n/P⌉ + 2) degrade as little
//! as possible.
//!
//! Guarantees:
//! - Metric 1 (E_n^max ≤ ⌈|E′|/P⌉) is preserved *unconditionally*: a
//!   bin at the limit is never picked, and a bin under the limit always
//!   exists while elements remain (P·⌈|E′|/P⌉ ≥ |E′|). This is a hard
//!   assert, mirroring Lite's stage-2 capacity check.
//! - Metrics 2/3 are best-effort under streaming: an append into a
//!   slice none of whose sharers has capacity must open a new
//!   (slice, rank) pair. [`theorem_bounds`] revalidates the bounds
//!   after a batch; a violated bound means the caller should schedule a
//!   full redistribution (which Lite makes cheap — the paper's point).
//!
//! Placement is deterministic (min-load, then lowest rank), so an
//! extended policy is reproducible from the same inputs — the property
//! the session's fresh-rebuild equivalence tests pin.

use super::metrics::{ModeMetrics, Sharers};
use super::policy::ModePolicy;
use crate::tensor::SliceIndex;
use std::sync::Arc;

/// Outcome of one [`extend_policy`] batch.
#[derive(Debug, Clone)]
pub struct PlacementReport {
    /// The hard per-bin limit ⌈|E′|/P⌉ the placement enforced.
    pub limit: usize,
    /// New (slice, rank) sharer pairs the batch had to open — each one
    /// adds 1 to this mode's R_n^sum.
    pub new_sharer_pairs: usize,
}

/// Extend `pol` over a batch of appended elements (their mode-`n`
/// coordinates in id order). `sharers` is the mode's pre-delta sharer
/// index; `nnz_after` the element count after the batch. Appends one
/// rank per element to `pol.assign`.
pub fn extend_policy(
    pol: &mut ModePolicy,
    sharers: &Sharers,
    slice_coords: &[u32],
    nnz_after: usize,
) -> PlacementReport {
    let p = pol.p;
    let limit = nnz_after.div_ceil(p);
    let mut load = pol.rank_counts();
    // copy-on-write: an assignment buffer shared with another policy
    // slot (or a cloned plan) is split before the in-place appends
    let assign = Arc::make_mut(&mut pol.assign);
    let mut new_pairs = 0usize;
    // (slice, rank) pairs opened within this batch: later appends to the
    // same slice treat them as sharers (batches are small; linear scan)
    let mut opened: Vec<(u32, u32)> = Vec::new();
    for &l in slice_coords {
        let batch_ranks = opened
            .iter()
            .filter(|&&(sl, _)| sl == l)
            .map(|&(_, r)| r);
        let pick = sharers
            .of(l as usize)
            .iter()
            .copied()
            .chain(batch_ranks)
            .filter(|&r| load[r as usize] < limit)
            .min_by_key(|&r| (load[r as usize], r));
        let r = match pick {
            Some(r) => r,
            None => {
                // no sharer has capacity: open a new pair on the least
                // loaded rank (always under the limit — see module docs)
                let r = (0..p as u32)
                    .min_by_key(|&r| (load[r as usize], r))
                    .expect("P >= 1");
                opened.push((l, r));
                new_pairs += 1;
                r
            }
        };
        assert!(
            load[r as usize] < limit,
            "incremental placement: bin {r} already at ⌈|E|/P⌉ = {limit}"
        );
        assign.push(r);
        load[r as usize] += 1;
    }
    PlacementReport { limit, new_sharer_pairs: new_pairs }
}

/// Re-place every element owned by a dead rank across the survivors,
/// with Lite's stage-2 discipline: prefer a *surviving* rank that
/// already shares the element's slice, under the hard per-survivor
/// limit ⌈|E|/S⌉ (S = survivor count), breaking ties by (load, lowest
/// rank). Deterministic and RNG-free, so the recovery path and a
/// planned `TuckerSession::evict_rank` call at the same sweep boundary
/// produce bit-identical placements — the equivalence the
/// fault-tolerance tests pin.
///
/// The returned policy keeps the original world size `p`; dead ranks
/// simply own zero elements (the simulated cluster still schedules
/// them, they just have no work). Capacity always suffices: survivor
/// loads start ≤ the previous limit ≤ ⌈|E|/S⌉, and while any dead-rank
/// element remains unplaced the survivors hold < |E| ≤ S·⌈|E|/S⌉
/// elements, so some bin is strictly under the limit.
///
/// Panics if every rank is dead (the session surfaces that as
/// `SessionError::NoSurvivors` before calling this).
pub fn evict_rank(pol: &ModePolicy, idx: &SliceIndex, dead: &[bool]) -> ModePolicy {
    assert_eq!(dead.len(), pol.p, "one liveness flag per rank");
    let survivors: Vec<u32> =
        (0..pol.p as u32).filter(|&r| !dead[r as usize]).collect();
    assert!(!survivors.is_empty(), "evict_rank: no surviving ranks");
    let nnz = pol.assign.len();
    let limit = nnz.div_ceil(survivors.len());
    let mut load = vec![0usize; pol.p];
    for &r in pol.assign.iter() {
        if !dead[r as usize] {
            load[r as usize] += 1;
        }
    }
    let mut assign: Vec<u32> = pol.assign.as_ref().clone();
    // walk slice-grouped so each slice's surviving-sharer set is built
    // once and the "prefer existing sharers" discipline is exact
    for l in 0..idx.num_slices() {
        let elems = idx.slice(l);
        let mut sharers: Vec<u32> = Vec::new();
        let mut needs_move = false;
        for &e in elems {
            let r = assign[e as usize];
            if dead[r as usize] {
                needs_move = true;
            } else if !sharers.contains(&r) {
                sharers.push(r);
            }
        }
        if !needs_move {
            continue;
        }
        for &e in elems {
            if !dead[assign[e as usize] as usize] {
                continue;
            }
            let pick = sharers
                .iter()
                .copied()
                .filter(|&s| load[s as usize] < limit)
                .min_by_key(|&s| (load[s as usize], s));
            let s = match pick {
                Some(s) => s,
                None => {
                    // no surviving sharer has capacity: open a new
                    // (slice, rank) pair on the least loaded survivor
                    let s = survivors
                        .iter()
                        .copied()
                        .filter(|&s| load[s as usize] < limit)
                        .min_by_key(|&s| (load[s as usize], s))
                        .expect("a survivor under ⌈|E|/S⌉ exists");
                    sharers.push(s);
                    s
                }
            };
            assign[e as usize] = s;
            load[s as usize] += 1;
        }
    }
    ModePolicy { p: pol.p, assign: Arc::new(assign) }
}

/// Theorem 6.1's three bounds for one (mode, policy) pair — the
/// revalidation a streaming caller runs after extending a policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoundsCheck {
    /// E_n^max ≤ ⌈|E|/P⌉.
    pub e_max_ok: bool,
    /// R_n^sum ≤ L_n + P.
    pub r_sum_ok: bool,
    /// R_n^max ≤ ⌈L_n/P⌉ + 2.
    pub r_max_ok: bool,
}

impl BoundsCheck {
    /// All three bounds hold?
    pub fn all_ok(&self) -> bool {
        self.e_max_ok && self.r_sum_ok && self.r_max_ok
    }
}

/// Recompute the §4 metrics and check them against the Theorem 6.1
/// bounds. (The bounds are Lite's guarantee; for other schemes the
/// result is informational.)
pub fn theorem_bounds(idx: &SliceIndex, pol: &ModePolicy) -> BoundsCheck {
    let nnz = idx.elems.len();
    let m = ModeMetrics::compute(idx, pol);
    let l_n = idx.num_slices();
    BoundsCheck {
        e_max_ok: m.e_max <= nnz.div_ceil(pol.p),
        r_sum_ok: m.r_sum <= l_n + pol.p,
        r_max_ok: m.r_max <= l_n.div_ceil(pol.p) + 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Lite, Scheme};
    use crate::tensor::slices::build_all;
    use crate::tensor::SparseTensor;
    use crate::util::rng::Rng;

    fn lite_mode0(t: &SparseTensor, p: usize) -> (SliceIndex, ModePolicy, Sharers) {
        let idx = build_all(t);
        let d = Lite.policies(t, &idx, p, &mut Rng::new(3));
        let pol = d.policies[0].clone();
        let sharers = Sharers::build(&idx[0], &pol);
        (idx.into_iter().next().unwrap(), pol, sharers)
    }

    #[test]
    fn extension_preserves_the_load_limit() {
        let mut rng = Rng::new(1);
        let t = SparseTensor::random(vec![30, 20, 10], 2000, &mut rng);
        let p = 7;
        let (_, mut pol, sharers) = lite_mode0(&t, p);
        // a skewed batch: half the appends hit one slice
        let coords: Vec<u32> =
            (0..300).map(|i| if i % 2 == 0 { 5 } else { (i % 30) as u32 }).collect();
        let nnz_after = t.nnz() + coords.len();
        let rep = extend_policy(&mut pol, &sharers, &coords, nnz_after);
        assert_eq!(pol.assign.len(), nnz_after);
        let counts = pol.rank_counts();
        assert!(
            counts.iter().all(|&c| c <= rep.limit),
            "limit {} violated: {counts:?}",
            rep.limit
        );
        assert_eq!(counts.iter().sum::<usize>(), nnz_after);
    }

    #[test]
    fn placement_prefers_existing_sharers() {
        // a policy with spare capacity everywhere: appends to slice l
        // must land on a rank already sharing l (no new pairs)
        let mut rng = Rng::new(2);
        let t = SparseTensor::random(vec![10, 8, 6], 200, &mut rng);
        let (_, mut pol, sharers) = lite_mode0(&t, 4);
        let l = (0..10u32)
            .find(|&l| !sharers.of(l as usize).is_empty())
            .expect("some nonempty slice");
        let before = pol.assign.len();
        let rep = extend_policy(&mut pol, &sharers, &[l, l], t.nnz() + 2);
        assert_eq!(rep.new_sharer_pairs, 0, "sharers had capacity");
        for &r in &pol.assign[before..] {
            assert!(sharers.of(l as usize).contains(&r));
        }
    }

    #[test]
    fn placement_is_deterministic() {
        let mut rng = Rng::new(4);
        let t = SparseTensor::random(vec![20, 10, 5], 800, &mut rng);
        let (_, pol0, sharers) = lite_mode0(&t, 5);
        let coords: Vec<u32> = (0..100).map(|i| (i * 7 % 20) as u32).collect();
        let mut a = pol0.clone();
        let mut b = pol0.clone();
        extend_policy(&mut a, &sharers, &coords, t.nnz() + 100);
        extend_policy(&mut b, &sharers, &coords, t.nnz() + 100);
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn bounds_check_matches_theorem_on_fresh_lite() {
        let mut rng = Rng::new(5);
        let t = SparseTensor::random(vec![25, 15, 10], 1500, &mut rng);
        let idx = build_all(&t);
        let d = Lite.policies(&t, &idx, 6, &mut Rng::new(6));
        for (i, pol) in idx.iter().zip(&d.policies) {
            let b = theorem_bounds(i, pol);
            assert!(b.all_ok(), "fresh Lite satisfies Theorem 6.1: {b:?}");
        }
    }

    #[test]
    fn eviction_moves_every_dead_element_to_a_survivor() {
        let mut rng = Rng::new(21);
        let t = SparseTensor::random(vec![30, 20, 10], 2000, &mut rng);
        let p = 6;
        let idx = build_all(&t);
        let d = Lite.policies(&t, &idx, p, &mut Rng::new(9));
        let pol = &d.policies[0];
        let mut dead = vec![false; p];
        dead[2] = true;
        let out = evict_rank(pol, &idx[0], &dead);
        assert_eq!(out.assign.len(), pol.assign.len());
        assert!(out.assign.iter().all(|&r| r != 2), "dead rank drained");
        // survivors respect the ⌈|E|/S⌉ limit
        let limit = t.nnz().div_ceil(p - 1);
        assert!(out.rank_counts().iter().all(|&c| c <= limit));
        // elements not on the dead rank are untouched
        for (a, b) in pol.assign.iter().zip(out.assign.iter()) {
            if *a != 2 {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn eviction_is_deterministic_and_prefers_surviving_sharers() {
        let mut rng = Rng::new(22);
        let t = SparseTensor::random(vec![15, 12, 9], 900, &mut rng);
        let p = 5;
        let idx = build_all(&t);
        let d = Lite.policies(&t, &idx, p, &mut Rng::new(10));
        let pol = &d.policies[0];
        let mut dead = vec![false; p];
        dead[0] = true;
        let a = evict_rank(pol, &idx[0], &dead);
        let b = evict_rank(pol, &idx[0], &dead);
        assert_eq!(a.assign, b.assign, "no RNG: eviction is a pure function");
    }

    #[test]
    fn eviction_prefers_a_surviving_sharer_over_the_min_load_rank() {
        // hand-built case: slice 0 = {e0, e1}, slice 1 = {e2};
        // assignment [2, 0, 1]; kill rank 0 (survivors {1, 2}, both at
        // load 1, limit ⌈3/2⌉ = 2). Plain (load, rank) min would send
        // e1 to rank 1; the sharer discipline keeps it on rank 2, which
        // already shares slice 0.
        let mut t = SparseTensor::new(vec![2, 2]);
        for l in [0u32, 0, 1] {
            t.push(&[l, 0], 1.0);
        }
        let idx0 = SliceIndex::build(&t, 0);
        let pol = ModePolicy::new(3, vec![2, 0, 1]);
        let out = evict_rank(&pol, &idx0, &[true, false, false]);
        assert_eq!(out.assign.as_ref(), &vec![2, 2, 1]);
    }

    #[test]
    fn successive_evictions_drain_down_to_one_survivor() {
        let mut rng = Rng::new(23);
        let t = SparseTensor::random(vec![10, 8, 6], 300, &mut rng);
        let p = 4;
        let idx = build_all(&t);
        let d = Lite.policies(&t, &idx, p, &mut Rng::new(11));
        let mut pol = d.policies[0].clone();
        let mut dead = vec![false; p];
        for victim in [3usize, 1, 0] {
            dead[victim] = true;
            pol = evict_rank(&pol, &idx[0], &dead);
            assert!(pol
                .assign
                .iter()
                .all(|&r| !dead[r as usize]));
            let s = dead.iter().filter(|&&x| !x).count();
            let limit = t.nnz().div_ceil(s);
            assert!(pol.rank_counts().iter().all(|&c| c <= limit));
        }
        assert_eq!(pol.rank_counts()[2], t.nnz(), "last survivor holds all");
    }

    #[test]
    fn e_max_bound_always_revalidates_after_extension() {
        let mut rng = Rng::new(7);
        let t = SparseTensor::random(vec![12, 9, 7], 400, &mut rng);
        let (_, mut pol, sharers) = lite_mode0(&t, 3);
        let coords: Vec<u32> = (0..50).map(|_| rng.below(12) as u32).collect();
        extend_policy(&mut pol, &sharers, &coords, t.nnz() + 50);
        // rebuild the tensor+index the appends describe and revalidate
        let mut t2 = t.clone();
        for &l in &coords {
            t2.push(&[l, 0, 0], 1.0);
        }
        let idx2 = crate::tensor::SliceIndex::build(&t2, 0);
        let b = theorem_bounds(&idx2, &pol);
        assert!(b.e_max_ok, "metric 1 is preserved unconditionally");
    }
}
