//! **Lite** — the paper's contribution (§6): a lightweight multi-policy
//! distribution scheme, provably near-optimal on all three §4 metrics
//! (Theorem 6.1):
//!
//!   1. E_n^max ≤ ⌈|E|/P⌉                 (perfect TTM balance)
//!   2. R_n^sum ≤ L_n + P                 (near-optimal SVD load/volume)
//!   3. R_n^max ≤ ⌈L_n/P⌉ + 2             (near-optimal SVD balance)
//!
//! Construction per mode (Fig 8): sort slices ascending by cardinality
//! (parallel sample sort); **stage 1** assigns whole slices round-robin
//! until the next assignment would push a bin over the hard limit
//! ⌈|E|/P⌉; **stage 2** fills the bins to the limit in order, splitting
//! the remaining (large) slices across contiguous ranks.

use super::policy::{DistTime, Distribution, ModePolicy, Scheme};
use super::samplesort::sample_sort;
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub struct Lite;

impl Scheme for Lite {
    fn name(&self) -> &'static str {
        "Lite"
    }

    fn uni(&self) -> bool {
        false
    }

    fn policies(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
    ) -> Distribution {
        let t0 = Stopwatch::start();
        let mut simulated = 0.0f64;
        let policies = idx
            .iter()
            .map(|i| {
                let (pol, sim) = distribute_mode(t, i, p, rng);
                simulated += sim;
                pol
            })
            .collect();
        Distribution {
            scheme: self.name().into(),
            p,
            policies,
            uni: false,
            time: DistTime {
                serial_secs: t0.seconds(),
                simulated_secs: simulated,
            },
        }
    }
}

/// Re-plan a *single* mode with Lite's Fig 8 construction — the
/// building block `TuckerSession::rebalance` uses to redistribute only
/// the modes whose Theorem 6.1 bounds broke under streaming, leaving
/// the other modes' policies (and TTM plans) untouched. Returns the
/// policy and the simulated parallel construction time, same model as
/// the full [`Lite`] path.
pub fn plan_mode(
    t: &SparseTensor,
    idx_n: &SliceIndex,
    p: usize,
    rng: &mut Rng,
) -> (ModePolicy, f64) {
    distribute_mode(t, idx_n, p, rng)
}

/// Fig 8 for a single mode. Returns the policy and the simulated parallel
/// construction time: sample-sort critical path (prefix work split across
/// ranks + slowest bucket) plus the assignment scan divided by P — the
/// paper implements both stages in parallel (§6.1/§7.3).
fn distribute_mode(
    t: &SparseTensor,
    idx: &SliceIndex,
    p: usize,
    rng: &mut Rng,
) -> (ModePolicy, f64) {
    let nnz = t.nnz();
    let limit = nnz.div_ceil(p);
    let sizes = idx.sizes();
    let sort = sample_sort(&sizes, p, rng);
    let t1 = Stopwatch::start();

    let mut assign = vec![0u32; nnz];
    let mut load = vec![0usize; p];
    let order = &sort.order;

    // Stage 1: whole slices, round-robin over bins, ascending sizes.
    let mut cur = 0usize; // next bin
    let mut stage2_from = order.len(); // first slice index not placed in stage 1
    for (pos, &lu) in order.iter().enumerate() {
        let l = lu as usize;
        let sz = idx.slice_len(l);
        if load[cur] + sz > limit {
            stage2_from = pos;
            break;
        }
        for &e in idx.slice(l) {
            assign[e as usize] = cur as u32;
        }
        load[cur] += sz;
        cur = (cur + 1) % p;
    }

    // Stage 2: fill bins 0..P to the limit, splitting large slices across
    // contiguous ranks.
    let mut bin = 0usize;
    let mut pos = stage2_from;
    let mut offset = 0usize; // elements of the current slice already placed
    while bin < p && pos < order.len() {
        let l = order[pos] as usize;
        let elems = idx.slice(l);
        let gap = limit - load[bin];
        let remaining = elems.len() - offset;
        if remaining <= gap {
            // whole (rest of the) slice fits: place and move to next slice
            for &e in &elems[offset..] {
                assign[e as usize] = bin as u32;
            }
            load[bin] += remaining;
            pos += 1;
            offset = 0;
        } else {
            // fill the bin to its limit, continue the slice on the next bin
            for &e in &elems[offset..offset + gap] {
                assign[e as usize] = bin as u32;
            }
            load[bin] += gap;
            offset += gap;
            bin += 1;
        }
    }
    // Hard capacity check (release builds included): if stage 2 ran out
    // of bins with slices left over, the leftover elements would keep
    // their zero-initialized `assign` entries and silently pile onto
    // rank 0 — an invariant violation that must never ship a corrupt
    // distribution. Mathematically P·⌈|E|/P⌉ ≥ |E|, so this only fires
    // on a construction bug.
    assert!(
        pos >= order.len(),
        "Lite stage 2 exhausted bins before slices: {} slice(s) unplaced \
         (nnz={nnz}, P={p}, limit={limit}) — capacity P·⌈|E|/P⌉ ≥ |E| violated",
        order.len() - pos
    );

    let scan_secs = t1.seconds();
    let simulated =
        sort.prefix_secs / p as f64 + sort.max_bucket_secs + scan_secs / p as f64;
    (ModePolicy::new(p, assign), simulated)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::metrics::ModeMetrics;
    use crate::tensor::slices::build_all;
    use crate::util::check::Runner;

    fn lite_dist(t: &SparseTensor, p: usize, seed: u64) -> Distribution {
        let idx = build_all(t);
        Lite.policies(t, &idx, p, &mut Rng::new(seed))
    }

    #[test]
    fn figure7_example_bounds() {
        // Paper Fig 7: |E| = 100, P = 5, limit 20; slice sizes
        // 5,5,5,5,5,5,5,18,22,25 along mode 0.
        let sizes = [5u32, 5, 5, 5, 5, 5, 5, 18, 22, 25];
        let mut t = SparseTensor::new(vec![10, 4]);
        for (l, &sz) in sizes.iter().enumerate() {
            for j in 0..sz {
                t.push(&[l as u32, j % 4], 1.0);
            }
        }
        let idx = build_all(&t);
        let d = Lite.policies(&t, &idx, 5, &mut Rng::new(1));
        let m = ModeMetrics::compute(&idx[0], &d.policies[0]);
        assert_eq!(m.e_max, 20, "hard limit is exactly |E|/P");
        assert!(m.r_sum <= 10 + 5);
        assert!(m.r_max <= 2 + 2);
        assert_eq!(m.e_counts.iter().sum::<usize>(), 100);
        // every bin filled exactly to the limit (100 = 5*20)
        assert_eq!(m.e_counts, vec![20; 5]);
    }

    #[test]
    fn theorem_6_1_property() {
        // The headline guarantee, property-tested over random tensors,
        // world sizes and skews.
        Runner::new(48, 120).run("theorem-6.1", |case, rng| {
            let p = 1 + rng.usize_below(9);
            let l0 = 1 + rng.usize_below(case.size.max(2));
            let l1 = 1 + rng.usize_below(20);
            let l2 = 1 + rng.usize_below(20);
            let nnz = 1 + rng.usize_below(case.size * 10 + 10);
            let t = SparseTensor::random(
                vec![l0 as u32, l1 as u32, l2 as u32],
                nnz,
                rng,
            );
            let idx = build_all(&t);
            let d = Lite.policies(&t, &idx, p, rng);
            d.validate(&t).map_err(|e| e)?;
            let limit = nnz.div_ceil(p);
            for (n, i) in idx.iter().enumerate() {
                let m = ModeMetrics::compute(i, &d.policies[n]);
                crate::prop_assert!(
                    m.e_max <= limit,
                    "mode {n}: E_max {} > limit {} (nnz={nnz} p={p})",
                    m.e_max,
                    limit
                );
                crate::prop_assert!(
                    m.r_sum <= i.num_slices() + p,
                    "mode {n}: R_sum {} > L+P {}",
                    m.r_sum,
                    i.num_slices() + p
                );
                crate::prop_assert!(
                    m.r_max <= i.num_slices().div_ceil(p) + 2,
                    "mode {n}: R_max {} > ceil(L/P)+2 {}",
                    m.r_max,
                    i.num_slices().div_ceil(p) + 2
                );
            }
            Ok(())
        });
    }

    #[test]
    fn huge_slice_gets_split_contiguously() {
        // one slice holds everything: stage 2 must split it across ranks
        let mut t = SparseTensor::new(vec![2, 3]);
        for i in 0..90 {
            t.push(&[0, (i % 3) as u32], 1.0);
        }
        for i in 0..10 {
            t.push(&[1, (i % 3) as u32], 1.0);
        }
        let d = lite_dist(&t, 4, 3);
        let m = ModeMetrics::compute(&build_all(&t)[0], &d.policies[0]);
        assert!(m.e_max <= 25);
        // the big slice is shared by several ranks, but contiguously:
        let pol = &d.policies[0];
        let mut ranks_of_big: Vec<u32> = (0..t.nnz())
            .filter(|&e| t.coord(0, e) == 0)
            .map(|e| pol.assign[e])
            .collect();
        ranks_of_big.sort_unstable();
        ranks_of_big.dedup();
        for w in ranks_of_big.windows(2) {
            assert_eq!(w[1], w[0] + 1, "contiguous rank range");
        }
    }

    #[test]
    fn all_elements_assigned_every_mode() {
        let mut rng = Rng::new(8);
        let t = SparseTensor::random(vec![30, 40, 20], 3000, &mut rng);
        let d = lite_dist(&t, 7, 9);
        assert!(d.validate(&t).is_ok());
        for pol in &d.policies {
            assert_eq!(pol.rank_counts().iter().sum::<usize>(), 3000);
        }
    }

    #[test]
    fn multi_policy_flags() {
        let mut rng = Rng::new(8);
        let t = SparseTensor::random(vec![10, 10, 10], 100, &mut rng);
        let d = lite_dist(&t, 4, 1);
        assert!(!d.uni);
        assert_eq!(d.tensor_copies(), 3);
        assert!(d.time.serial_secs > 0.0);
        assert!(d.time.simulated_secs > 0.0);
        assert!(d.time.simulated_secs < d.time.serial_secs);
    }

    #[test]
    fn stage2_capacity_check_holds_on_exact_and_skewed_fills() {
        // regression for the silently-overloaded-rank-0 hazard: the
        // stage-2 capacity check is now a hard assert, so these runs
        // double as its exercise. Exact fills (nnz = P·limit) and heavy
        // skew push stage 2 hardest.
        for (p, sizes) in [
            (5usize, vec![20u32; 5]),            // exact fill, equal slices
            (4, vec![97, 1, 1, 1]),              // one dominant slice
            (3, vec![50, 49, 1]),                // two near-limit slices
            (7, vec![13, 11, 7, 5, 3, 2, 1, 1]), // ragged, nnz % P != 0
        ] {
            let nnz: u32 = sizes.iter().sum();
            let mut t = SparseTensor::new(vec![sizes.len() as u32, 4]);
            for (l, &sz) in sizes.iter().enumerate() {
                for j in 0..sz {
                    t.push(&[l as u32, j % 4], 1.0);
                }
            }
            let idx = build_all(&t);
            let d = Lite.policies(&t, &idx, p, &mut Rng::new(7));
            d.validate(&t).unwrap();
            let limit = (nnz as usize).div_ceil(p);
            for (n, pol) in d.policies.iter().enumerate() {
                let counts = pol.rank_counts();
                assert_eq!(counts.iter().sum::<usize>(), nnz as usize);
                assert!(
                    counts.iter().all(|&c| c <= limit),
                    "mode {n}: a bin exceeds ⌈|E|/P⌉={limit}: {counts:?}"
                );
            }
        }
    }

    #[test]
    fn p_equals_one_trivial() {
        let mut rng = Rng::new(8);
        let t = SparseTensor::random(vec![10, 10], 50, &mut rng);
        let d = lite_dist(&t, 1, 1);
        for pol in &d.policies {
            assert!(pol.assign.iter().all(|&r| r == 0));
        }
    }
}
