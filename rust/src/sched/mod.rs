//! Distribution schemes (paper §5–§6) and the §4 performance metrics.
//!
//! - [`lite`]: the paper's contribution — lightweight, multi-policy,
//!   provably near-optimal on E_max / R_sum / R_max (Theorem 6.1).
//! - [`coarse`]: CoarseG — whole slices per rank (optimal R_sum, poor E_max).
//! - [`medium`]: MediumG — processor-grid medium-grained scheme [25].
//! - [`hypergraph`]: HyperG — fine-grained via multilevel hypergraph
//!   partitioning (from-scratch Zoltan stand-in).
//! - [`metrics`]: E_n^max, R_n^sum, R_n^max + Fig 12 aggregates.
//! - [`rowmap`]: the σ_n row-index mapping.
//! - [`samplesort`]: the parallel sample sort Lite's slice ordering uses.
//! - [`incremental`]: streaming policy extension + Theorem 6.1
//!   revalidation for appended nonzeros.
//! - [`policy`]: the [`Scheme`] trait and the first-class
//!   [`PlacementPlan`] (policies + provenance + metrics + cost).
//! - [`cost`]: the §4 cost model pricing a HOOI sweep from the metrics.
//! - [`diff`]: [`MigrationPlan`] — exact per-(mode, rank) element
//!   movements between two placements, with byte volumes.

pub mod coarse;
pub mod cost;
pub mod diff;
pub mod hypergraph;
pub mod incremental;
pub mod lite;
pub mod medium;
pub mod metrics;
pub mod policy;
pub mod rowmap;
pub mod samplesort;

pub use coarse::CoarseG;
pub use cost::{CostEstimate, CostModel, ModeCost};
pub use diff::{MigrationPlan, ModeMigration};
pub use hypergraph::HyperG;
pub use incremental::{
    evict_rank, extend_policy, theorem_bounds, BoundsCheck, PlacementReport,
};
pub use lite::Lite;
pub use medium::MediumG;
pub use metrics::{ModeMetrics, SchemeMetrics, Sharers};
pub use policy::{DistTime, Distribution, ModePolicy, PlacementPlan, PlanMode, Scheme};
pub use rowmap::RowMap;

/// Construct a scheme by name (CLI / config entry point).
pub fn by_name(name: &str) -> Option<Box<dyn Scheme>> {
    match name.to_ascii_lowercase().as_str() {
        "lite" => Some(Box::new(Lite)),
        "coarseg" | "coarse" => Some(Box::new(CoarseG::default())),
        "coarseg-bpf" | "bpf" => Some(Box::new(CoarseG {
            strategy: coarse::SliceAssign::BestFit,
        })),
        "mediumg" | "medium" => Some(Box::new(MediumG)),
        "hyperg" | "hyper" => Some(Box::new(HyperG::default())),
        _ => None,
    }
}

/// The paper's four evaluated schemes, in presentation order.
pub fn all_schemes() -> Vec<Box<dyn Scheme>> {
    vec![
        Box::new(CoarseG::default()),
        Box::new(MediumG),
        Box::new(HyperG::default()),
        Box::new(Lite),
    ]
}

/// The three lightweight schemes (big-tensor experiments exclude HyperG,
/// as the paper could not partition the big tensors either).
pub fn lightweight_schemes() -> Vec<Box<dyn Scheme>> {
    vec![Box::new(CoarseG::default()), Box::new(MediumG), Box::new(Lite)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all() {
        for n in ["lite", "CoarseG", "mediumg", "HyperG", "bpf"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn scheme_lists() {
        assert_eq!(all_schemes().len(), 4);
        assert_eq!(lightweight_schemes().len(), 3);
        assert_eq!(all_schemes()[3].name(), "Lite");
    }
}
