//! The paper's fundamental metrics (§4): per mode n,
//!
//!   Metric 1  E_n^max = max_p |E_n^p|      (TTM load balance)
//!   Metric 2  R_n^sum = Σ_p R_n^p          (SVD load + oracle comm volume)
//!   Metric 3  R_n^max = max_p R_n^p        (SVD load balance)
//!
//! where R_n^p is the number of mode-n slices rank p shares. Optimal
//! values: ⌈|E|/P⌉, L_n, ⌈L_n/P⌉ respectively.

use super::policy::{Distribution, ModePolicy};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::float::exactly_zero_f64;

/// Sharer lists per slice, CSR layout: ranks sharing Slice_n^l are
/// `ranks[offsets[l]..offsets[l+1]]`. Built once per (mode, policy) and
/// reused by metrics, σ_n construction and FM-transfer accounting.
#[derive(Debug, Clone)]
pub struct Sharers {
    pub offsets: Vec<u32>,
    pub ranks: Vec<u32>,
}

impl Sharers {
    /// O(nnz + L + R_sum) construction using a per-rank last-seen stamp.
    pub fn build(idx: &SliceIndex, pol: &ModePolicy) -> Sharers {
        let l_n = idx.num_slices();
        let mut stamp = vec![u32::MAX; pol.p];
        let mut offsets = Vec::with_capacity(l_n + 1);
        let mut ranks = Vec::new();
        offsets.push(0u32);
        for l in 0..l_n {
            for &e in idx.slice(l) {
                let r = pol.assign[e as usize];
                if stamp[r as usize] != l as u32 {
                    stamp[r as usize] = l as u32;
                    ranks.push(r);
                }
            }
            offsets.push(ranks.len() as u32);
        }
        Sharers { offsets, ranks }
    }

    #[inline]
    pub fn of(&self, l: usize) -> &[u32] {
        &self.ranks[self.offsets[l] as usize..self.offsets[l + 1] as usize]
    }

    pub fn num_slices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// R_n^sum — total sharing count.
    pub fn r_sum(&self) -> usize {
        self.ranks.len()
    }

    /// R_n^p per rank.
    pub fn r_counts(&self, p: usize) -> Vec<usize> {
        let mut counts = vec![0usize; p];
        for &r in &self.ranks {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Number of bad slices (shared by ≥ 2 ranks; §4.1).
    pub fn bad_slices(&self) -> usize {
        (0..self.num_slices()).filter(|&l| self.of(l).len() > 1).count()
    }
}

/// All the paper's §4 metrics for one mode.
#[derive(Debug, Clone)]
pub struct ModeMetrics {
    pub mode: usize,
    pub l_n: usize,
    /// Non-empty slice count (empty slices share with nobody).
    pub l_nonempty: usize,
    pub e_counts: Vec<usize>,
    pub e_max: usize,
    pub r_counts: Vec<usize>,
    pub r_sum: usize,
    pub r_max: usize,
}

impl ModeMetrics {
    pub fn compute(idx: &SliceIndex, pol: &ModePolicy) -> ModeMetrics {
        let sharers = Sharers::build(idx, pol);
        Self::from_sharers(idx, pol, &sharers)
    }

    pub fn from_sharers(idx: &SliceIndex, pol: &ModePolicy, sharers: &Sharers) -> ModeMetrics {
        let e_counts = pol.rank_counts();
        let e_max = e_counts.iter().copied().max().unwrap_or(0);
        let r_counts = sharers.r_counts(pol.p);
        let r_max = r_counts.iter().copied().max().unwrap_or(0);
        ModeMetrics {
            mode: idx.mode,
            l_n: idx.num_slices(),
            l_nonempty: idx.nonempty(),
            e_counts,
            e_max,
            r_counts,
            r_sum: sharers.r_sum(),
            r_max,
        }
    }

    /// TTM load balance = E_max / (|E|/P); 1.0 is perfect.
    pub fn ttm_imbalance(&self) -> f64 {
        let total: usize = self.e_counts.iter().sum();
        let avg = total as f64 / self.e_counts.len() as f64;
        if exactly_zero_f64(avg) {
            1.0
        } else {
            self.e_max as f64 / avg
        }
    }

    /// SVD redundancy = R_sum / L_nonempty; 1.0 is optimal (all good slices).
    pub fn svd_redundancy(&self) -> f64 {
        if self.l_nonempty == 0 {
            1.0
        } else {
            self.r_sum as f64 / self.l_nonempty as f64
        }
    }

    /// SVD load balance = R_max / (R_sum/P); 1.0 is perfect.
    pub fn svd_imbalance(&self) -> f64 {
        let avg = self.r_sum as f64 / self.r_counts.len() as f64;
        if exactly_zero_f64(avg) {
            1.0
        } else {
            self.r_max as f64 / avg
        }
    }

    /// Oracle communication volume per matvec query: R_sum − L_nonempty
    /// (§4.2; empty slices have no sharers and no owner traffic).
    pub fn oracle_volume_per_query(&self) -> usize {
        self.r_sum - self.l_nonempty
    }
}

/// Metrics for every mode of a distribution + the paper's aggregates.
#[derive(Debug, Clone)]
pub struct SchemeMetrics {
    pub per_mode: Vec<ModeMetrics>,
}

impl SchemeMetrics {
    pub fn compute(t: &SparseTensor, idx: &[SliceIndex], dist: &Distribution) -> SchemeMetrics {
        let per_mode = idx
            .iter()
            .zip(&dist.policies)
            .map(|(i, pol)| ModeMetrics::compute(i, pol))
            .collect();
        let _ = t;
        SchemeMetrics { per_mode }
    }

    /// Fig 12(a): aggregate TTM balance — max over ranks of total elements
    /// across modes, over the average (each mode's TTM does |E| Kronecker
    /// products, so aggregating element counts aggregates FLOPs).
    pub fn ttm_balance(&self) -> f64 {
        let p = self.per_mode[0].e_counts.len();
        let mut per_rank = vec![0usize; p];
        for m in &self.per_mode {
            for (r, &c) in m.e_counts.iter().enumerate() {
                per_rank[r] += c;
            }
        }
        let total: usize = per_rank.iter().sum();
        let avg = total as f64 / p as f64;
        per_rank.iter().copied().max().unwrap_or(0) as f64 / avg.max(1e-12)
    }

    /// Fig 12(b): normalized SVD load — Σ_n R_n^sum·K̂_n over the optimal
    /// Σ_n L_n·K̂_n. `khat[n]` = Π_{j≠n} K_j.
    pub fn svd_load_normalized(&self, khat: &[f64]) -> f64 {
        let load: f64 = self
            .per_mode
            .iter()
            .zip(khat)
            .map(|(m, &kh)| m.r_sum as f64 * kh)
            .sum();
        let opt: f64 = self
            .per_mode
            .iter()
            .zip(khat)
            .map(|(m, &kh)| m.l_nonempty as f64 * kh)
            .sum();
        load / opt.max(1e-12)
    }

    /// Fig 12(c): aggregate SVD balance — max over ranks of Σ_n R_n^p·K̂_n
    /// over the average.
    pub fn svd_balance(&self, khat: &[f64]) -> f64 {
        let p = self.per_mode[0].r_counts.len();
        let mut per_rank = vec![0.0f64; p];
        for (m, &kh) in self.per_mode.iter().zip(khat) {
            for (r, &c) in m.r_counts.iter().enumerate() {
                per_rank[r] += c as f64 * kh;
            }
        }
        let total: f64 = per_rank.iter().sum();
        let avg = total / p as f64;
        per_rank.iter().cloned().fold(0.0, f64::max) / avg.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::DistTime;
    use crate::util::rng::Rng;

    fn tensor_and_index() -> (SparseTensor, Vec<SliceIndex>) {
        let mut rng = Rng::new(17);
        let t = SparseTensor::random(vec![10, 8, 6], 400, &mut rng);
        let idx = crate::tensor::slices::build_all(&t);
        (t, idx)
    }

    #[test]
    fn figure4_example_r_sum() {
        // Paper Fig 4: 8 elements over 3 ranks, every mode-1 slice shared by
        // exactly two ranks -> R_sum = 6 with L_1 = 3.
        let mut t = SparseTensor::new(vec![3, 4, 4]);
        let mode0 = [0, 1, 0, 2, 2, 0, 1, 2];
        for (i, &c0) in mode0.iter().enumerate() {
            t.push(&[c0, (i % 4) as u32, ((i * 2) % 4) as u32], 1.0);
        }
        let idx = SliceIndex::build(&t, 0);
        // lexicographic thirds: {e0,e1,e2}, {e3,e4,e5}, {e6,e7}
        let pol = ModePolicy::new(3, vec![0, 0, 0, 1, 1, 1, 2, 2]);
        let m = ModeMetrics::compute(&idx, &pol);
        assert_eq!(m.r_sum, 6);
        assert_eq!(m.l_n, 3);
        assert!((m.svd_redundancy() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn single_rank_is_all_optimal() {
        let (_, idx) = tensor_and_index();
        let pol = ModePolicy::new(1, vec![0; 400]);
        for i in &idx {
            let m = ModeMetrics::compute(i, &pol);
            assert_eq!(m.e_max, 400);
            assert_eq!(m.r_sum, i.nonempty());
            assert_eq!(m.r_max, i.nonempty());
            assert!((m.svd_redundancy() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn slice_aligned_policy_has_no_bad_slices() {
        let (t, idx) = tensor_and_index();
        // assign whole slices of mode 0 by l % p — every slice good
        let p = 4;
        let assign: Vec<u32> = (0..t.nnz()).map(|e| t.coord(0, e) % p).collect();
        let pol = ModePolicy::new(p as usize, assign);
        let sharers = Sharers::build(&idx[0], &pol);
        assert_eq!(sharers.bad_slices(), 0);
        let m = ModeMetrics::from_sharers(&idx[0], &pol, &sharers);
        assert_eq!(m.r_sum, idx[0].nonempty());
    }

    #[test]
    fn random_policy_metrics_within_bounds() {
        let (t, idx) = tensor_and_index();
        let mut rng = Rng::new(3);
        let p = 5usize;
        let assign: Vec<u32> = (0..t.nnz()).map(|_| rng.below(p as u64) as u32).collect();
        let pol = ModePolicy::new(p, assign);
        for i in &idx {
            let m = ModeMetrics::compute(i, &pol);
            assert!(m.r_sum >= i.nonempty());
            assert!(m.r_sum <= i.nonempty() * p);
            assert!(m.r_max <= i.num_slices());
            assert!(m.e_max <= t.nnz());
            assert_eq!(m.e_counts.iter().sum::<usize>(), t.nnz());
            assert_eq!(m.r_counts.iter().sum::<usize>(), m.r_sum);
        }
    }

    #[test]
    fn aggregates_compute() {
        let (t, idx) = tensor_and_index();
        let mut rng = Rng::new(4);
        let p = 4usize;
        let policies: Vec<ModePolicy> = (0..3)
            .map(|_| {
                ModePolicy::new(
                    p,
                    (0..t.nnz()).map(|_| rng.below(p as u64) as u32).collect(),
                )
            })
            .collect();
        let dist = Distribution {
            scheme: "rand".into(),
            p,
            policies,
            uni: false,
            time: DistTime::default(),
        };
        let sm = SchemeMetrics::compute(&t, &idx, &dist);
        let khat = vec![100.0, 100.0, 100.0];
        assert!(sm.ttm_balance() >= 1.0);
        assert!(sm.svd_load_normalized(&khat) >= 1.0);
        assert!(sm.svd_balance(&khat) >= 1.0);
    }
}
