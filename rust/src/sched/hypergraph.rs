//! **HyperG** — fine-grained uni-policy scheme via hypergraph partitioning
//! (Kaya–Uçar, paper §5). Vertices are non-zero elements, hyperedges (nets)
//! are the slices along *all* modes; a balanced min-connectivity partition
//! simultaneously models E^max (balance constraint) and Σ_n R_n^sum (the
//! connectivity-1 objective: Σ_net (λ(net) − 1) = Σ_n (R_n^sum − L_n)).
//!
//! The paper uses the parallel Zoltan library offline; this module is the
//! from-scratch stand-in (DESIGN.md §2): a multilevel partitioner with
//! heavy-connectivity matching coarsening, greedy-growing initial
//! partitioning and K-way FM-style local refinement on every level. It is
//! deliberately the *slow, high-quality* scheme — its distribution time is
//! orders of magnitude above the lightweight schemes, exactly the tradeoff
//! Fig 16 reports.

use super::policy::{DistTime, Distribution, ModePolicy, Scheme};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

/// A hypergraph in dual CSR form.
#[derive(Debug, Clone)]
pub struct Hypergraph {
    /// vertex -> incident nets
    pub v_off: Vec<u32>,
    pub v_nets: Vec<u32>,
    /// net -> pins (vertices)
    pub n_off: Vec<u32>,
    pub n_pins: Vec<u32>,
    /// vertex weights (element multiplicity after contraction)
    pub v_w: Vec<u32>,
}

impl Hypergraph {
    pub fn num_vertices(&self) -> usize {
        self.v_off.len() - 1
    }

    pub fn num_nets(&self) -> usize {
        self.n_off.len() - 1
    }

    #[inline]
    pub fn nets_of(&self, v: usize) -> &[u32] {
        &self.v_nets[self.v_off[v] as usize..self.v_off[v + 1] as usize]
    }

    #[inline]
    pub fn pins_of(&self, n: usize) -> &[u32] {
        &self.n_pins[self.n_off[n] as usize..self.n_off[n + 1] as usize]
    }

    pub fn total_weight(&self) -> u64 {
        self.v_w.iter().map(|&w| w as u64).sum()
    }

    /// Build from (net -> pins) adjacency + weights.
    pub fn from_nets(num_vertices: usize, nets: &[Vec<u32>], v_w: Vec<u32>) -> Hypergraph {
        let mut n_off = Vec::with_capacity(nets.len() + 1);
        n_off.push(0u32);
        let mut n_pins = Vec::new();
        for pins in nets {
            n_pins.extend_from_slice(pins);
            n_off.push(n_pins.len() as u32);
        }
        // invert to vertex -> nets
        let mut deg = vec![0u32; num_vertices + 1];
        for &v in &n_pins {
            deg[v as usize + 1] += 1;
        }
        for i in 0..num_vertices {
            deg[i + 1] += deg[i];
        }
        let v_off = deg.clone();
        let mut cursor = deg;
        let mut v_nets = vec![0u32; n_pins.len()];
        for (net, pins) in nets.iter().enumerate() {
            for &v in pins {
                v_nets[cursor[v as usize] as usize] = net as u32;
                cursor[v as usize] += 1;
            }
        }
        Hypergraph { v_off, v_nets, n_off, n_pins, v_w }
    }

    /// The tensor-to-hypergraph reduction: one net per (mode, slice).
    pub fn from_tensor(t: &SparseTensor, idx: &[SliceIndex]) -> Hypergraph {
        let mut nets: Vec<Vec<u32>> = Vec::new();
        for i in idx {
            for l in 0..i.num_slices() {
                if i.slice_len(l) > 0 {
                    nets.push(i.slice(l).to_vec());
                }
            }
        }
        Hypergraph::from_nets(t.nnz(), &nets, vec![1; t.nnz()])
    }

    /// Connectivity-1 cut: Σ_net (λ − 1) for a given part assignment.
    pub fn connectivity_cut(&self, part: &[u32], p: usize) -> u64 {
        let mut stamp = vec![u32::MAX; p];
        let mut cut = 0u64;
        for n in 0..self.num_nets() {
            let mut lambda = 0u64;
            for &v in self.pins_of(n) {
                let pt = part[v as usize] as usize;
                if stamp[pt] != n as u32 {
                    stamp[pt] = n as u32;
                    lambda += 1;
                }
            }
            cut += lambda.saturating_sub(1);
        }
        cut
    }
}

/// Multilevel partitioner parameters.
#[derive(Debug, Clone, Copy)]
pub struct PartitionParams {
    /// Balance tolerance: part weight ≤ (1+ε)·total/P.
    pub epsilon: f64,
    /// Stop coarsening below this vertex count (scaled by P).
    pub coarse_per_part: usize,
    /// Refinement passes per level.
    pub passes: usize,
    /// Skip matching through nets larger than this (hub slices carry
    /// little signal and cost O(|net|²)).
    pub max_match_net: usize,
}

impl Default for PartitionParams {
    fn default() -> Self {
        PartitionParams {
            epsilon: 0.10,
            coarse_per_part: 30,
            passes: 3,
            max_match_net: 64,
        }
    }
}

/// Multilevel K-way partition. Returns part[v] ∈ [0, p).
pub fn partition(hg: &Hypergraph, p: usize, params: PartitionParams, rng: &mut Rng) -> Vec<u32> {
    if p == 1 {
        return vec![0; hg.num_vertices()];
    }
    // --- coarsening ---
    let mut levels: Vec<(Hypergraph, Vec<u32>)> = Vec::new(); // (coarse hg, fine->coarse map)
    let mut cur = hg.clone();
    let target = (params.coarse_per_part * p).max(64);
    while cur.num_vertices() > target {
        let map = match_vertices(&cur, params.max_match_net, rng);
        let coarse = contract(&cur, &map);
        let shrink = coarse.num_vertices() as f64 / cur.num_vertices() as f64;
        levels.push((cur, map));
        cur = coarse;
        if shrink > 0.95 {
            break; // matching stalled (e.g. all nets huge)
        }
    }
    // --- initial partition on the coarsest level ---
    let mut part = greedy_grow(&cur, p, params.epsilon, rng);
    refine(&cur, &mut part, p, params, rng);
    // --- uncoarsen + refine ---
    while let Some((fine, map)) = levels.pop() {
        let mut fine_part = vec![0u32; fine.num_vertices()];
        for v in 0..fine.num_vertices() {
            fine_part[v] = part[map[v] as usize];
        }
        part = fine_part;
        refine(&fine, &mut part, p, params, rng);
    }
    part
}

/// Heavy-connectivity matching: visit vertices in random order; each
/// unmatched vertex pairs with an unmatched neighbour found through its
/// smallest nets. Returns fine -> coarse vertex map.
fn match_vertices(hg: &Hypergraph, max_net: usize, rng: &mut Rng) -> Vec<u32> {
    let nv = hg.num_vertices();
    let mut order: Vec<u32> = (0..nv as u32).collect();
    rng.shuffle(&mut order);
    let mut mate = vec![u32::MAX; nv];
    for &vu in &order {
        let v = vu as usize;
        if mate[v] != u32::MAX {
            continue;
        }
        // pick the first unmatched co-pin through a small net
        let mut best: Option<u32> = None;
        for &net in hg.nets_of(v) {
            let pins = hg.pins_of(net as usize);
            if pins.len() > max_net {
                continue;
            }
            for &u in pins {
                if u as usize != v && mate[u as usize] == u32::MAX {
                    best = Some(u);
                    break;
                }
            }
            if best.is_some() {
                break;
            }
        }
        match best {
            Some(u) => {
                mate[v] = u;
                mate[u as usize] = vu;
            }
            None => mate[v] = vu, // self-matched (singleton)
        }
    }
    // enumerate coarse ids
    let mut map = vec![u32::MAX; nv];
    let mut next = 0u32;
    for v in 0..nv {
        if map[v] != u32::MAX {
            continue;
        }
        map[v] = next;
        let m = mate[v] as usize;
        if m != v && map[m] == u32::MAX {
            map[m] = next;
        }
        next += 1;
    }
    map
}

/// Contract matched vertices; dedupe pins per net; drop trivial nets.
fn contract(hg: &Hypergraph, map: &[u32]) -> Hypergraph {
    let nc = map.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    let mut v_w = vec![0u32; nc];
    for v in 0..hg.num_vertices() {
        v_w[map[v] as usize] += hg.v_w[v];
    }
    let mut nets: Vec<Vec<u32>> = Vec::with_capacity(hg.num_nets());
    let mut seen = vec![u32::MAX; nc];
    for n in 0..hg.num_nets() {
        let mut pins = Vec::new();
        for &v in hg.pins_of(n) {
            let c = map[v as usize];
            if seen[c as usize] != n as u32 {
                seen[c as usize] = n as u32;
                pins.push(c);
            }
        }
        if pins.len() > 1 {
            nets.push(pins);
        }
    }
    Hypergraph::from_nets(nc, &nets, v_w)
}

/// Greedy growing initial partition: fill parts one at a time by BFS over
/// net neighbourhoods, bounded by the balance limit.
fn greedy_grow(hg: &Hypergraph, p: usize, eps: f64, rng: &mut Rng) -> Vec<u32> {
    let nv = hg.num_vertices();
    let total = hg.total_weight();
    let limit = ((total as f64 / p as f64) * (1.0 + eps)).ceil() as u64;
    let mut part = vec![u32::MAX; nv];
    let mut frontier: Vec<u32> = Vec::new();
    let mut unassigned = nv;
    let mut order: Vec<u32> = (0..nv as u32).collect();
    rng.shuffle(&mut order);
    let mut seed_cursor = 0usize;
    for pt in 0..p {
        let budget = if pt == p - 1 { u64::MAX } else { limit };
        let mut load = 0u64;
        frontier.clear();
        while unassigned > 0 && load < budget {
            let v = match frontier.pop() {
                Some(v) if part[v as usize] == u32::MAX => v,
                Some(_) => continue,
                None => {
                    // new seed
                    while seed_cursor < nv && part[order[seed_cursor] as usize] != u32::MAX
                    {
                        seed_cursor += 1;
                    }
                    if seed_cursor >= nv {
                        break;
                    }
                    order[seed_cursor]
                }
            };
            let vw = hg.v_w[v as usize] as u64;
            if load + vw > budget && load > 0 {
                break;
            }
            part[v as usize] = pt as u32;
            load += vw;
            unassigned -= 1;
            for &net in hg.nets_of(v as usize) {
                let pins = hg.pins_of(net as usize);
                if pins.len() <= 128 {
                    for &u in pins {
                        if part[u as usize] == u32::MAX {
                            frontier.push(u);
                        }
                    }
                }
            }
        }
    }
    // stragglers -> least-loaded part
    let mut loads = vec![0u64; p];
    for v in 0..nv {
        if part[v] != u32::MAX {
            loads[part[v] as usize] += hg.v_w[v] as u64;
        }
    }
    for v in 0..nv {
        if part[v] == u32::MAX {
            let pt = (0..p).min_by_key(|&q| loads[q]).unwrap();
            part[v] = pt as u32;
            loads[pt] += hg.v_w[v] as u64;
        }
    }
    part
}

/// K-way FM-style refinement: greedy positive-gain moves with a balance
/// constraint, driven by per-net part-pin counts.
fn refine(hg: &Hypergraph, part: &mut [u32], p: usize, params: PartitionParams, rng: &mut Rng) {
    let nv = hg.num_vertices();
    let total = hg.total_weight();
    let limit = ((total as f64 / p as f64) * (1.0 + params.epsilon)).ceil() as u64;
    let mut loads = vec![0u64; p];
    for v in 0..nv {
        loads[part[v] as usize] += hg.v_w[v] as u64;
    }
    // per-net part counts as small sorted vecs
    let mut net_counts: Vec<Vec<(u32, u32)>> = vec![Vec::new(); hg.num_nets()];
    for n in 0..hg.num_nets() {
        let counts = &mut net_counts[n];
        for &v in hg.pins_of(n) {
            let pt = part[v as usize];
            match counts.iter_mut().find(|(q, _)| *q == pt) {
                Some(e) => e.1 += 1,
                None => counts.push((pt, 1)),
            }
        }
    }
    let mut order: Vec<u32> = (0..nv as u32).collect();
    for _pass in 0..params.passes {
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        for &vu in &order {
            let v = vu as usize;
            let from = part[v];
            let vw = hg.v_w[v] as u64;
            // candidate parts: those already present in v's nets
            // gain(to) = #nets where v is the last `from` pin and `to` present
            //          - #nets where `to` absent  … computed directly:
            let mut cand: Vec<(u32, i64)> = Vec::new();
            for &net in hg.nets_of(v) {
                let counts = &net_counts[net as usize];
                let from_cnt = counts
                    .iter()
                    .find(|(q, _)| *q == from)
                    .map(|&(_, c)| c)
                    .unwrap_or(0);
                for &(q, _) in counts.iter() {
                    if q == from {
                        continue;
                    }
                    let entry = match cand.iter_mut().find(|(cq, _)| *cq == q) {
                        Some(e) => e,
                        None => {
                            cand.push((q, 0));
                            cand.last_mut().unwrap()
                        }
                    };
                    // moving v: if v is the sole `from` pin, λ decreases (+1 gain)
                    if from_cnt == 1 {
                        entry.1 += 1;
                    }
                }
                // penalty for destinations not in this net handled below by
                // initializing candidates per net; destinations absent from a
                // net gain nothing here and may lose if from_cnt == 1 is false
            }
            // subtract: for each candidate `to`, nets of v where `to` is
            // absent would raise λ by 1 unless from_cnt == 1 there too.
            for entry in cand.iter_mut() {
                let to = entry.0;
                let mut penalty = 0i64;
                for &net in hg.nets_of(v) {
                    let counts = &net_counts[net as usize];
                    let has_to = counts.iter().any(|&(q, _)| q == to);
                    if !has_to {
                        let from_cnt = counts
                            .iter()
                            .find(|(q, _)| *q == from)
                            .map(|&(_, c)| c)
                            .unwrap_or(0);
                        if from_cnt > 1 {
                            penalty += 1; // new part joins the net
                        }
                        // from_cnt == 1: from leaves, to joins — λ unchanged
                    }
                }
                entry.1 -= penalty;
            }
            let best = cand
                .into_iter()
                .filter(|&(to, _)| loads[to as usize] + vw <= limit)
                .max_by_key(|&(_, g)| g);
            if let Some((to, gain)) = best {
                if gain > 0 && to != from {
                    // apply
                    part[v] = to;
                    loads[from as usize] -= vw;
                    loads[to as usize] += vw;
                    for &net in hg.nets_of(v) {
                        let counts = &mut net_counts[net as usize];
                        if let Some(pos) =
                            counts.iter().position(|&(q, _)| q == from)
                        {
                            counts[pos].1 -= 1;
                            if counts[pos].1 == 0 {
                                counts.swap_remove(pos);
                            }
                        }
                        match counts.iter_mut().find(|(q, _)| *q == to) {
                            Some(e) => e.1 += 1,
                            None => counts.push((to, 1)),
                        }
                    }
                    moved += 1;
                }
            }
        }
        if moved == 0 {
            break;
        }
    }
}

pub struct HyperG {
    pub params: PartitionParams,
}

impl Default for HyperG {
    fn default() -> Self {
        HyperG { params: PartitionParams::default() }
    }
}

impl Scheme for HyperG {
    fn name(&self) -> &'static str {
        "HyperG"
    }

    fn uni(&self) -> bool {
        true
    }

    fn policies(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
    ) -> Distribution {
        let t0 = Stopwatch::start();
        let hg = Hypergraph::from_tensor(t, idx);
        let part = partition(&hg, p, self.params, rng);
        // one Arc'd buffer aliased by all N policy slots (uni-policy)
        let pol = ModePolicy::new(p, part);
        let serial = t0.seconds();
        Distribution {
            scheme: self.name().into(),
            p,
            policies: vec![pol; t.ndim()],
            uni: true,
            time: DistTime {
                serial_secs: serial,
                // offline scheme (paper §5/§7.3): no parallel model credit
                simulated_secs: serial,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::metrics::ModeMetrics;
    use crate::tensor::slices::build_all;

    fn random_tensor(seed: u64, nnz: usize) -> SparseTensor {
        let mut rng = Rng::new(seed);
        SparseTensor::random(vec![40, 30, 20], nnz, &mut rng)
    }

    #[test]
    fn dual_csr_consistent() {
        let t = random_tensor(1, 500);
        let idx = build_all(&t);
        let hg = Hypergraph::from_tensor(&t, &idx);
        assert_eq!(hg.num_vertices(), 500);
        // pins total = N * nnz
        assert_eq!(hg.n_pins.len(), 3 * 500);
        assert_eq!(hg.v_nets.len(), 3 * 500);
        // vertex->net and net->pin views agree
        for v in 0..hg.num_vertices() {
            for &n in hg.nets_of(v) {
                assert!(hg.pins_of(n as usize).contains(&(v as u32)));
            }
        }
    }

    #[test]
    fn partition_is_balanced_and_complete() {
        let t = random_tensor(2, 2000);
        let idx = build_all(&t);
        let hg = Hypergraph::from_tensor(&t, &idx);
        let p = 6;
        let part = partition(&hg, p, PartitionParams::default(), &mut Rng::new(3));
        assert_eq!(part.len(), 2000);
        let mut loads = vec![0u64; p];
        for &pt in &part {
            assert!((pt as usize) < p);
            loads[pt as usize] += 1;
        }
        let limit = ((2000.0 / p as f64) * 1.12).ceil() as u64;
        for (q, &l) in loads.iter().enumerate() {
            assert!(l <= limit, "part {q} load {l} > {limit}");
            assert!(l > 0, "part {q} empty");
        }
    }

    #[test]
    fn refinement_reduces_cut() {
        let t = random_tensor(4, 1500);
        let idx = build_all(&t);
        let hg = Hypergraph::from_tensor(&t, &idx);
        let p = 4;
        // random assignment as baseline
        let mut rng = Rng::new(5);
        let random_part: Vec<u32> =
            (0..hg.num_vertices()).map(|_| rng.below(p as u64) as u32).collect();
        let random_cut = hg.connectivity_cut(&random_part, p);
        let part = partition(&hg, p, PartitionParams::default(), &mut Rng::new(6));
        let cut = hg.connectivity_cut(&part, p);
        assert!(
            cut < random_cut,
            "partitioned cut {cut} should beat random {random_cut}"
        );
    }

    #[test]
    fn connectivity_cut_equals_metric_identity() {
        // Σ_net (λ−1) == Σ_n (R_n^sum − nonempty_n)
        let t = random_tensor(7, 800);
        let idx = build_all(&t);
        let hg = Hypergraph::from_tensor(&t, &idx);
        let p = 5;
        let d = HyperG::default().policies(&t, &idx, p, &mut Rng::new(8));
        let cut = hg.connectivity_cut(&d.policies[0].assign, p);
        let mut rsum_minus_l = 0u64;
        for (n, i) in idx.iter().enumerate() {
            let m = ModeMetrics::compute(i, &d.policies[n]);
            rsum_minus_l += (m.r_sum - m.l_nonempty) as u64;
        }
        assert_eq!(cut, rsum_minus_l);
    }

    #[test]
    fn scheme_is_uni_policy_offline() {
        let t = random_tensor(9, 400);
        let idx = build_all(&t);
        let d = HyperG::default().policies(&t, &idx, 3, &mut Rng::new(10));
        assert!(d.uni);
        assert!(d.validate(&t).is_ok());
        assert_eq!(d.time.serial_secs, d.time.simulated_secs);
    }

    #[test]
    fn single_part_shortcut() {
        let t = random_tensor(11, 100);
        let idx = build_all(&t);
        let hg = Hypergraph::from_tensor(&t, &idx);
        let part = partition(&hg, 1, PartitionParams::default(), &mut Rng::new(1));
        assert!(part.iter().all(|&x| x == 0));
    }

    #[test]
    fn contraction_preserves_weight() {
        let t = random_tensor(12, 600);
        let idx = build_all(&t);
        let hg = Hypergraph::from_tensor(&t, &idx);
        let map = match_vertices(&hg, 64, &mut Rng::new(2));
        let coarse = contract(&hg, &map);
        assert_eq!(coarse.total_weight(), hg.total_weight());
        assert!(coarse.num_vertices() <= hg.num_vertices());
        assert!(coarse.num_vertices() >= hg.num_vertices() / 2);
    }
}
