//! **MediumG** — the medium-grained uni-policy scheme of Smith–Karypis
//! [25] (paper §5): factorize P = q_1 × ··· × q_N, overlay a processor
//! grid on the tensor, assign each sub-tensor to a rank. Mode indices are
//! randomly permuted to offset skew; q_n is chosen in proportion to L_n
//! (each mode-n slice is then shared by at most P/q_n ranks).

use super::policy::{DistTime, Distribution, ModePolicy, Scheme};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub struct MediumG;

impl Scheme for MediumG {
    fn name(&self) -> &'static str {
        "MediumG"
    }

    fn uni(&self) -> bool {
        true
    }

    fn policies(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
    ) -> Distribution {
        let _ = idx;
        let t0 = Stopwatch::start();
        let n = t.ndim();
        let grid = factorize_grid(p, &t.dims);
        // random index permutation per mode (skew offset)
        let perms: Vec<Vec<u32>> =
            t.dims.iter().map(|&l| rng.permutation(l as usize)).collect();
        // block boundaries: mode-n index i -> grid coord i*q_n/L_n
        let mut assign = vec![0u32; t.nnz()];
        for e in 0..t.nnz() {
            let mut rank = 0usize;
            for m in 0..n {
                let l = perms[m][t.coord(m, e) as usize] as usize;
                let q = grid[m];
                let g = (l * q) / t.dims[m] as usize;
                rank = rank * q + g.min(q - 1);
            }
            assign[e] = rank as u32;
        }
        // one Arc'd buffer aliased by all N policy slots — uni-policy
        // schemes store a single assignment copy
        let pol = ModePolicy::new(p, assign);
        let serial = t0.seconds();
        Distribution {
            scheme: self.name().into(),
            p,
            policies: vec![pol; n],
            uni: true,
            time: DistTime {
                serial_secs: serial,
                // the element scan parallelizes perfectly (each rank maps
                // its own file chunk in the paper's implementation)
                simulated_secs: serial / p as f64,
            },
        }
    }
}

/// P = q_1 × ... × q_N with q_n proportional to L_n: distribute the prime
/// factors of P (largest first) to the mode with the largest current
/// "stretch" L_n / q_n.
pub fn factorize_grid(p: usize, dims: &[u32]) -> Vec<usize> {
    let n = dims.len();
    let mut q = vec![1usize; n];
    for f in prime_factors(p) {
        let m = (0..n)
            .max_by(|&a, &b| {
                let sa = dims[a] as f64 / q[a] as f64;
                let sb = dims[b] as f64 / q[b] as f64;
                sa.partial_cmp(&sb).unwrap()
            })
            .unwrap();
        q[m] *= f;
    }
    q
}

/// Prime factorization, largest factors first.
pub fn prime_factors(mut x: usize) -> Vec<usize> {
    let mut fs = Vec::new();
    let mut d = 2usize;
    while d * d <= x {
        while x % d == 0 {
            fs.push(d);
            x /= d;
        }
        d += 1;
    }
    if x > 1 {
        fs.push(x);
    }
    fs.sort_unstable_by(|a, b| b.cmp(a));
    fs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::metrics::ModeMetrics;
    use crate::tensor::slices::build_all;

    #[test]
    fn grid_multiplies_to_p() {
        for p in [1, 2, 6, 16, 60, 64, 128, 512] {
            let q = factorize_grid(p, &[1000, 10, 100]);
            assert_eq!(q.iter().product::<usize>(), p);
        }
    }

    #[test]
    fn grid_favors_long_modes() {
        let q = factorize_grid(16, &[1_000_000, 100, 10]);
        assert!(q[0] >= q[1] && q[1] >= q[2], "{q:?}");
        assert!(q[0] >= 8);
    }

    #[test]
    fn prime_factors_correct() {
        assert_eq!(prime_factors(12), vec![3, 2, 2]);
        assert_eq!(prime_factors(17), vec![17]);
        assert_eq!(prime_factors(1), Vec::<usize>::new());
    }

    #[test]
    fn slice_sharing_bounded_by_grid() {
        // each mode-n slice can be shared by at most P/q_n ranks (§5)
        let mut rng = Rng::new(4);
        let t = SparseTensor::random(vec![64, 32, 16], 6000, &mut rng);
        let idx = build_all(&t);
        let p = 16;
        let d = MediumG.policies(&t, &idx, p, &mut Rng::new(5));
        assert!(d.validate(&t).is_ok());
        let grid = factorize_grid(p, &t.dims);
        for (n, i) in idx.iter().enumerate() {
            let m = ModeMetrics::compute(i, &d.policies[n]);
            let bound = p / grid[n];
            for l in 0..i.num_slices() {
                let _ = l;
            }
            assert!(
                m.r_max <= i.num_slices().div_ceil(grid[n]) * bound / 1.max(1),
                "sanity"
            );
            // per-slice bound via r_sum: r_sum <= nonempty * P/q_n
            assert!(m.r_sum <= i.nonempty() * bound.max(1));
        }
    }

    #[test]
    fn uni_policy_same_assignment_all_modes() {
        let mut rng = Rng::new(6);
        let t = SparseTensor::random(vec![20, 20, 20], 500, &mut rng);
        let idx = build_all(&t);
        let d = MediumG.policies(&t, &idx, 8, &mut Rng::new(7));
        assert!(d.uni);
        assert_eq!(d.tensor_copies(), 1);
        for n in 1..3 {
            assert_eq!(d.policies[n].assign, d.policies[0].assign);
            // not just equal: the same Arc'd buffer (one stored copy)
            assert!(std::sync::Arc::ptr_eq(
                &d.policies[n].assign,
                &d.policies[0].assign
            ));
        }
        assert_eq!(d.assignment_bytes(), 4 * t.nnz() as u64);
    }

    #[test]
    fn sub_tensor_blocks_are_contiguous_in_permuted_space() {
        // elements with equal permuted grid coordinates land on one rank
        let mut rng = Rng::new(8);
        let t = SparseTensor::random(vec![12, 12], 300, &mut rng);
        let idx = build_all(&t);
        let d = MediumG.policies(&t, &idx, 4, &mut Rng::new(9));
        // 4 ranks over 2 modes -> at most 4 distinct ranks, all used for a
        // tensor this dense
        let mut used: Vec<u32> = d.policies[0].assign.to_vec();
        used.sort_unstable();
        used.dedup();
        assert_eq!(used.len(), 4);
    }
}
