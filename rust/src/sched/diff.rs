//! Placement diffing: turn two distributions over the *same* tensor
//! into the exact element movements separating them — the object
//! `TuckerSession::rebalance` applies through the HOOI layer's
//! splice/rebuild machinery instead of re-running `prepare_modes`.
//!
//! A [`MigrationPlan`] is exact, not approximate: per (mode, rank) it
//! lists precisely the element ids leaving and arriving, so the HOOI
//! layer can touch exactly the dirty (mode, rank) TTM plans and the
//! byte volume below is what a real redistribution would put on the
//! wire ((N+1)·4 bytes per moved element copy; a uni→uni pair moves its
//! single stored copy once).

use super::policy::Distribution;
use crate::dist::NetModel;

/// One mode's share of a [`MigrationPlan`].
#[derive(Debug, Clone)]
pub struct ModeMigration {
    pub mode: usize,
    /// Per source rank: element ids leaving it, ascending.
    pub outgoing: Vec<Vec<u32>>,
    /// Per destination rank: element ids arriving, ascending.
    pub incoming: Vec<Vec<u32>>,
    /// Per source rank `(messages, units)` — one message per distinct
    /// destination, (N+1) units per moved element — in the shape
    /// `SimCluster::p2p` charges.
    pub per_rank_sends: Vec<(u64, u64)>,
}

impl ModeMigration {
    /// Elements changing owner along this mode.
    pub fn moved(&self) -> usize {
        self.incoming.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.moved() == 0
    }

    /// (mode, rank) pairs this migration dirties: ranks gaining *or*
    /// losing elements (either invalidates the rank's TTM plan).
    pub fn dirty_ranks(&self) -> usize {
        self.incoming
            .iter()
            .zip(&self.outgoing)
            .filter(|(inc, out)| !inc.is_empty() || !out.is_empty())
            .count()
    }
}

/// The exact movements turning one placement into another: per-(mode,
/// rank) moved-element sets plus the migration byte volume.
#[derive(Debug, Clone)]
pub struct MigrationPlan {
    /// Per-mode movements, in mode order.
    pub per_mode: Vec<ModeMigration>,
    /// Both endpoints are uni-policy: one stored copy moves (volume
    /// accounting charges mode 0 only; the per-mode TTM plans are still
    /// all dirtied).
    pub uni_pair: bool,
    /// Moved element copies summed over the *stored* copies (mode 0
    /// only for a uni pair) — `bytes = moved_elements ·
    /// bytes_per_element` by construction.
    pub moved_elements: usize,
    /// Bytes per moved element copy: (N+1)·4 (coordinates + value).
    pub bytes_per_element: u64,
    /// Total migration byte volume.
    pub bytes: u64,
}

impl MigrationPlan {
    /// Diff two distributions over the same tensor (equal nnz, equal P,
    /// equal order — asserted).
    pub fn compute(from: &Distribution, to: &Distribution) -> MigrationPlan {
        assert_eq!(from.p, to.p, "diff requires equal world size");
        assert_eq!(from.ndim(), to.ndim(), "diff requires equal order");
        let ndim = from.ndim();
        let p = from.p;
        let bpe = (ndim as u64 + 1) * 4;
        let mut per_mode = Vec::with_capacity(ndim);
        for n in 0..ndim {
            let a = &from.policies[n].assign;
            let b = &to.policies[n].assign;
            assert_eq!(a.len(), b.len(), "diff requires the same tensor (nnz)");
            let mut outgoing = vec![Vec::new(); p];
            let mut incoming = vec![Vec::new(); p];
            let mut pairs = vec![false; p * p];
            for (e, (&src, &dst)) in a.iter().zip(b.iter()).enumerate() {
                if src != dst {
                    outgoing[src as usize].push(e as u32);
                    incoming[dst as usize].push(e as u32);
                    pairs[src as usize * p + dst as usize] = true;
                }
            }
            let per_rank_sends = (0..p)
                .map(|r| {
                    let msgs = (0..p).filter(|&d| pairs[r * p + d]).count() as u64;
                    let units = outgoing[r].len() as u64 * (ndim as u64 + 1);
                    (msgs, units)
                })
                .collect();
            per_mode.push(ModeMigration { mode: n, outgoing, incoming, per_rank_sends });
        }
        let uni_pair = from.uni && to.uni;
        let moved_elements: usize = if uni_pair {
            per_mode[0].moved()
        } else {
            per_mode.iter().map(ModeMigration::moved).sum()
        };
        MigrationPlan {
            per_mode,
            uni_pair,
            moved_elements,
            bytes_per_element: bpe,
            bytes: moved_elements as u64 * bpe,
        }
    }

    /// No element changes owner along any mode.
    pub fn is_empty(&self) -> bool {
        self.per_mode.iter().all(ModeMigration::is_empty)
    }

    /// Total dirty (mode, rank) pairs — exactly the TTM plans
    /// `ModeState::apply_migration` will splice or rebuild.
    pub fn dirty_plans(&self) -> usize {
        self.per_mode.iter().map(ModeMigration::dirty_ranks).sum()
    }

    /// Simulated migration time under an α–β model: per stored copy a
    /// p2p round (rounds overlap across ranks, so each mode charges its
    /// worst sender — the same semantics as `SimCluster::p2p`); a uni
    /// pair moves one copy.
    pub fn simulated_secs(&self, net: &NetModel) -> f64 {
        let copies: &[ModeMigration] = if self.uni_pair {
            &self.per_mode[..1]
        } else {
            &self.per_mode
        };
        copies
            .iter()
            .map(|m| {
                m.per_rank_sends
                    .iter()
                    .map(|&(msgs, units)| net.xfer(msgs, units))
                    .fold(0.0, f64::max)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::{DistTime, ModePolicy};

    fn dist(p: usize, assigns: Vec<Vec<u32>>, uni: bool) -> Distribution {
        Distribution {
            scheme: "test".into(),
            p,
            policies: assigns.into_iter().map(|a| ModePolicy::new(p, a)).collect(),
            uni,
            time: DistTime::default(),
        }
    }

    #[test]
    fn diff_with_self_is_empty() {
        let d = dist(3, vec![vec![0, 1, 2, 0], vec![1, 1, 0, 2]], false);
        let m = MigrationPlan::compute(&d, &d);
        assert!(m.is_empty());
        assert_eq!(m.moved_elements, 0);
        assert_eq!(m.bytes, 0);
        assert_eq!(m.dirty_plans(), 0);
        assert_eq!(m.simulated_secs(&NetModel::default()), 0.0);
    }

    #[test]
    fn moved_sets_are_exact_and_disjoint() {
        let a = dist(3, vec![vec![0, 0, 1, 2, 1], vec![0, 1, 1, 2, 2]], false);
        let b = dist(3, vec![vec![0, 1, 1, 0, 2], vec![0, 1, 2, 2, 2]], false);
        let m = MigrationPlan::compute(&a, &b);
        // mode 0: e1 0→1, e3 2→0, e4 1→2; mode 1: e2 1→2
        let m0 = &m.per_mode[0];
        assert_eq!(m0.moved(), 3);
        assert_eq!(m0.outgoing[0], vec![1]);
        assert_eq!(m0.incoming[1], vec![1]);
        assert_eq!(m0.outgoing[2], vec![3]);
        assert_eq!(m0.incoming[0], vec![3]);
        assert_eq!(m0.outgoing[1], vec![4]);
        assert_eq!(m0.incoming[2], vec![4]);
        assert_eq!(m.per_mode[1].moved(), 1);
        // every rank both sends and receives in mode 0 → 3 dirty there
        assert_eq!(m0.dirty_ranks(), 3);
        assert_eq!(m.per_mode[1].dirty_ranks(), 2);
        assert_eq!(m.dirty_plans(), 5);
        // volumes match the byte accounting: 4 copies moved, (2+1)·4 each
        assert_eq!(m.moved_elements, 4);
        assert_eq!(m.bytes_per_element, 12);
        assert_eq!(m.bytes, 48);
        // per-rank sends: mode 0 rank 0 sends 1 element to 1 destination
        assert_eq!(m0.per_rank_sends[0], (1, 3));
    }

    #[test]
    fn uni_pair_charges_one_copy() {
        let a_assign = vec![0u32, 0, 1, 1];
        let b_assign = vec![0u32, 1, 1, 0];
        let a = dist(2, vec![a_assign.clone(); 3], true);
        let b = dist(2, vec![b_assign.clone(); 3], true);
        let m = MigrationPlan::compute(&a, &b);
        assert!(m.uni_pair);
        // two elements move per mode, but one stored copy is charged
        assert_eq!(m.per_mode[0].moved(), 2);
        assert_eq!(m.moved_elements, 2);
        assert_eq!(m.bytes, 2 * 16);
        // plans are per (mode, rank) regardless of storage sharing
        assert_eq!(m.dirty_plans(), 3 * 2);
        // simulated time covers one copy's p2p round
        let net = NetModel { alpha: 1.0, beta: 0.5 };
        // each rank sends 1 message of 4 units → max(1+2, 1+2) = 3
        assert_eq!(m.simulated_secs(&net), 1.0 + 4.0 * 0.5);
    }
}
