//! **CoarseG** — coarse-grained multi-policy scheme (paper §5): along each
//! mode, every slice is assigned *in its entirety* to one rank, so all
//! slices are good and R_n^sum hits the optimum L_n; the price is TTM load
//! imbalance whenever a slice is much larger than |E|/P.
//!
//! Slice-assignment strategy (Smith–Karypis [25], the paper's CoarseG):
//! arrange slices in random order, allocate contiguous blocks to ranks,
//! balancing element counts greedily. A best-processor-fit (BPF) variant —
//! the classical 2-approximation for makespan the paper discusses in §6.1
//! — is included for the ablation bench.

use super::policy::{DistTime, Distribution, ModePolicy, Scheme};
use crate::tensor::{SliceIndex, SparseTensor};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SliceAssign {
    /// Random order + contiguous blocks (the paper's CoarseG).
    RandomBlocks,
    /// Best processor fit: each slice to the least-loaded rank.
    BestFit,
}

pub struct CoarseG {
    pub strategy: SliceAssign,
}

impl Default for CoarseG {
    fn default() -> Self {
        CoarseG { strategy: SliceAssign::RandomBlocks }
    }
}

impl Scheme for CoarseG {
    fn name(&self) -> &'static str {
        match self.strategy {
            SliceAssign::RandomBlocks => "CoarseG",
            SliceAssign::BestFit => "CoarseG-BPF",
        }
    }

    fn uni(&self) -> bool {
        false
    }

    fn policies(
        &self,
        t: &SparseTensor,
        idx: &[SliceIndex],
        p: usize,
        rng: &mut Rng,
    ) -> Distribution {
        let t0 = Stopwatch::start();
        let policies: Vec<ModePolicy> = idx
            .iter()
            .map(|i| match self.strategy {
                SliceAssign::RandomBlocks => random_blocks(t, i, p, rng),
                SliceAssign::BestFit => best_fit(t, i, p),
            })
            .collect();
        let serial = t0.seconds();
        Distribution {
            scheme: self.name().into(),
            p,
            policies,
            uni: false,
            time: DistTime {
                serial_secs: serial,
                // lightweight scheme run in parallel in the paper (§7.3);
                // the per-mode scans parallelize over slices
                simulated_secs: serial / p as f64,
            },
        }
    }
}

/// Random slice order, contiguous blocks targeting |E|/P elements per rank.
fn random_blocks(t: &SparseTensor, idx: &SliceIndex, p: usize, rng: &mut Rng) -> ModePolicy {
    let nnz = t.nnz();
    let target = nnz.div_ceil(p);
    let mut order: Vec<u32> = (0..idx.num_slices() as u32).collect();
    rng.shuffle(&mut order);
    let mut assign = vec![0u32; nnz];
    let mut rank = 0usize;
    let mut filled = 0usize;
    for &lu in &order {
        let l = lu as usize;
        for &e in idx.slice(l) {
            assign[e as usize] = rank as u32;
        }
        filled += idx.slice_len(l);
        // advance once the current rank reached its quota (last rank
        // absorbs the remainder)
        if filled >= target && rank + 1 < p {
            rank += 1;
            filled = 0;
        }
    }
    ModePolicy::new(p, assign)
}

/// Classical BPF: largest-first over slices, each to the least-loaded rank.
fn best_fit(t: &SparseTensor, idx: &SliceIndex, p: usize) -> ModePolicy {
    let mut order: Vec<u32> = (0..idx.num_slices() as u32).collect();
    order.sort_by_key(|&l| std::cmp::Reverse(idx.slice_len(l as usize)));
    let mut load = vec![0usize; p];
    let mut assign = vec![0u32; t.nnz()];
    for &lu in &order {
        let l = lu as usize;
        let rank = (0..p).min_by_key(|&r| load[r]).unwrap();
        for &e in idx.slice(l) {
            assign[e as usize] = rank as u32;
        }
        load[rank] += idx.slice_len(l);
    }
    ModePolicy::new(p, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::metrics::{ModeMetrics, Sharers};
    use crate::tensor::slices::build_all;

    fn random_tensor(seed: u64) -> SparseTensor {
        let mut rng = Rng::new(seed);
        SparseTensor::random(vec![50, 30, 20], 4000, &mut rng)
    }

    #[test]
    fn all_slices_good_both_strategies() {
        // the defining property: R_n^sum == number of nonempty slices
        let t = random_tensor(1);
        let idx = build_all(&t);
        for strategy in [SliceAssign::RandomBlocks, SliceAssign::BestFit] {
            let d = CoarseG { strategy }.policies(&t, &idx, 6, &mut Rng::new(2));
            assert!(d.validate(&t).is_ok());
            for (n, i) in idx.iter().enumerate() {
                let sharers = Sharers::build(i, &d.policies[n]);
                assert_eq!(sharers.bad_slices(), 0, "{strategy:?} mode {n}");
                let m = ModeMetrics::from_sharers(i, &d.policies[n], &sharers);
                assert_eq!(m.r_sum, i.nonempty());
            }
        }
    }

    #[test]
    fn bpf_beats_random_blocks_on_makespan() {
        // skewed slice sizes: BPF (2-approx) should not be worse
        let mut t = SparseTensor::new(vec![20, 4]);
        let mut rng = Rng::new(5);
        for l in 0..20u32 {
            let sz = if l == 0 { 500 } else { 20 + rng.below(30) as u32 };
            for _ in 0..sz {
                t.push(&[l, rng.below(4) as u32], 1.0);
            }
        }
        let idx = build_all(&t);
        let db = CoarseG { strategy: SliceAssign::BestFit }
            .policies(&t, &idx, 4, &mut Rng::new(1));
        let dr = CoarseG { strategy: SliceAssign::RandomBlocks }
            .policies(&t, &idx, 4, &mut Rng::new(1));
        let mb = ModeMetrics::compute(&idx[0], &db.policies[0]);
        let mr = ModeMetrics::compute(&idx[0], &dr.policies[0]);
        assert!(mb.e_max <= mr.e_max);
    }

    #[test]
    fn giant_slice_causes_imbalance() {
        // CoarseG's weakness (§7.2): a slice >> |E|/P pins E_max at its size
        let mut t = SparseTensor::new(vec![10, 4]);
        for i in 0..900 {
            t.push(&[0, (i % 4) as u32], 1.0);
        }
        for l in 1..10u32 {
            for i in 0..10 {
                t.push(&[l, (i % 4) as u32], 1.0);
            }
        }
        let idx = build_all(&t);
        let d = CoarseG::default().policies(&t, &idx, 5, &mut Rng::new(1));
        let m = ModeMetrics::compute(&idx[0], &d.policies[0]);
        assert!(m.e_max >= 900, "giant slice stays whole");
        assert!(m.ttm_imbalance() > 3.0);
    }

    #[test]
    fn partitions_all_elements() {
        let t = random_tensor(7);
        let idx = build_all(&t);
        let d = CoarseG::default().policies(&t, &idx, 8, &mut Rng::new(3));
        for pol in &d.policies {
            assert_eq!(pol.rank_counts().iter().sum::<usize>(), t.nnz());
        }
    }
}
