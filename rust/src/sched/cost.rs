//! The §4 cost model: price one HOOI sweep directly from a placement's
//! fundamental metrics (E_n^max, R_n^sum, R_n^max), so two candidate
//! placements can be compared — and a migration amortized — *without*
//! running either one.
//!
//! Per mode n with core rank K_n and K̂_n = Π_{j≠n} K_j:
//!
//! - **TTM compute** — the bottleneck rank assembles E_n^max fused
//!   Kronecker contributions of width K̂_n: `2·E_max·K̂` flops.
//! - **SVD compute** — the Lanczos oracle issues Q_n = 4·K_n matvec
//!   queries (the same query-count convention Fig 13 uses); the
//!   bottleneck rank touches its R_n^max shared slices at width K̂_n
//!   per query: `2·Q·R_max·K̂` flops.
//! - **Oracle communication** — Q_n·(R_n^sum − L_n^nonempty) units
//!   (§4.2: each query moves one unit per redundant sharer).
//! - **FM communication** — K_n·(R_n^sum − L_n^nonempty) units (the
//!   §4.2 uni-policy transfer identity, used as the model for every
//!   scheme; the multi-policy exact pattern is measured, not modeled).
//!
//! Seconds combine the flop terms at [`CostModel::flops_per_sec`] and
//! the unit terms under the α–β [`NetModel`] — the same network model
//! the simulated cluster charges, so predicted and simulated costs are
//! commensurable.

use super::metrics::ModeMetrics;
use crate::dist::NetModel;

/// How metric counts translate into seconds: an effective per-rank flop
/// rate plus the cluster's α–β network model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// α–β parameters for the communication terms.
    pub net: NetModel,
    /// Effective per-rank compute rate for the flop terms. The default
    /// (2 GFLOP/s) is deliberately conservative — what matters for the
    /// rebalance decision is the *ratio* of sweep savings to migration
    /// time, and both sides use the same constants.
    pub flops_per_sec: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { net: NetModel::default(), flops_per_sec: 2e9 }
    }
}

impl CostModel {
    /// Same flop rate, explicit network parameters (sessions pass their
    /// configured [`NetModel`] so predictions match their cluster).
    pub fn with_net(mut self, net: NetModel) -> CostModel {
        self.net = net;
        self
    }
}

/// One mode's share of a [`CostEstimate`].
#[derive(Debug, Clone, Default)]
pub struct ModeCost {
    pub mode: usize,
    /// Bottleneck-rank TTM flops per sweep: 2·E_n^max·K̂_n.
    pub ttm_flops: f64,
    /// Bottleneck-rank SVD flops per sweep: 2·Q_n·R_n^max·K̂_n.
    pub svd_flops: f64,
    /// Oracle query volume per sweep in units: Q_n·(R_n^sum − L_n).
    pub oracle_units: f64,
    /// Factor-matrix transfer volume per sweep in units: K_n·(R_n^sum − L_n).
    pub fm_units: f64,
    /// This mode's modeled seconds per sweep.
    pub secs: f64,
}

/// Predicted cost of one HOOI sweep under a placement — the quantity
/// `TuckerSession`'s auto-rebalance compares between the live plan and
/// a Lite re-plan.
#[derive(Debug, Clone, Default)]
pub struct CostEstimate {
    pub per_mode: Vec<ModeCost>,
    /// Σ over modes of the flop terms.
    pub flops_per_sweep: f64,
    /// Σ over modes of the communication terms, in units (one f32).
    pub comm_units_per_sweep: f64,
    /// Σ over modes of the modeled seconds.
    pub secs_per_sweep: f64,
}

impl CostEstimate {
    /// Price a sweep from per-mode metrics and core ranks. `metrics`
    /// and `ks` are in mode order and must have equal length.
    pub fn from_metrics(
        metrics: &[&ModeMetrics],
        ks: &[usize],
        model: &CostModel,
    ) -> CostEstimate {
        assert_eq!(metrics.len(), ks.len(), "one core rank per mode");
        let mut per_mode = Vec::with_capacity(ks.len());
        let (mut flops, mut units, mut secs) = (0.0f64, 0.0f64, 0.0f64);
        for (n, (m, &k_n)) in metrics.iter().zip(ks.iter()).enumerate() {
            let khat: f64 = ks
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != n)
                .map(|(_, &k)| k as f64)
                .product();
            let q_n = 4.0 * k_n as f64;
            let redundant = m.r_sum.saturating_sub(m.l_nonempty) as f64;
            let ttm_flops = 2.0 * m.e_max as f64 * khat;
            let svd_flops = 2.0 * q_n * m.r_max as f64 * khat;
            let oracle_units = q_n * redundant;
            let fm_units = k_n as f64 * redundant;
            let mode_secs = (ttm_flops + svd_flops) / model.flops_per_sec
                + model.net.alpha * (q_n + 1.0)
                + model.net.beta * (oracle_units + fm_units);
            flops += ttm_flops + svd_flops;
            units += oracle_units + fm_units;
            secs += mode_secs;
            per_mode.push(ModeCost {
                mode: n,
                ttm_flops,
                svd_flops,
                oracle_units,
                fm_units,
                secs: mode_secs,
            });
        }
        CostEstimate {
            per_mode,
            flops_per_sweep: flops,
            comm_units_per_sweep: units,
            secs_per_sweep: secs,
        }
    }

    /// Re-price this estimate for a `PlanChoice::SharedCsf` sweep. The
    /// shared tree's contribution cache computes each fiber's
    /// `2·E·K_0` value-weighted fast-factor accumulation once per sweep
    /// (mode 0 cannot share it — its fast factor is mode 1 — and the
    /// first non-leaf mode fills the cache), so every *later* non-leaf
    /// mode `n ≥ 2` skips that accumulation and keeps only its
    /// Kronecker-expansion share: its TTM term scales by
    /// `1 − K_0/K̂_n`. Communication and SVD terms are layout-invariant.
    /// The per-mode seconds and the sweep totals are recomputed under
    /// the same [`CostModel`] so rebalance comparisons stay
    /// commensurable with the per-mode estimate.
    pub fn with_shared_csf(&self, ks: &[usize], model: &CostModel) -> CostEstimate {
        assert_eq!(self.per_mode.len(), ks.len(), "one core rank per mode");
        let k0 = ks.first().copied().unwrap_or(1) as f64;
        let mut per_mode = Vec::with_capacity(self.per_mode.len());
        let (mut flops, mut units, mut secs) = (0.0f64, 0.0f64, 0.0f64);
        for (n, (mc, &k_n)) in self.per_mode.iter().zip(ks.iter()).enumerate() {
            let khat: f64 = ks
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != n)
                .map(|(_, &k)| k as f64)
                .product();
            let reuse = if n >= 2 { (1.0 - k0 / khat).max(0.0) } else { 1.0 };
            let ttm_flops = mc.ttm_flops * reuse;
            let q_n = 4.0 * k_n as f64;
            let mode_secs = (ttm_flops + mc.svd_flops) / model.flops_per_sec
                + model.net.alpha * (q_n + 1.0)
                + model.net.beta * (mc.oracle_units + mc.fm_units);
            flops += ttm_flops + mc.svd_flops;
            units += mc.oracle_units + mc.fm_units;
            secs += mode_secs;
            per_mode.push(ModeCost { ttm_flops, secs: mode_secs, ..mc.clone() });
        }
        CostEstimate {
            per_mode,
            flops_per_sweep: flops,
            comm_units_per_sweep: units,
            secs_per_sweep: secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::ModePolicy;
    use crate::tensor::slices::build_all;
    use crate::tensor::SparseTensor;
    use crate::util::rng::Rng;

    fn metrics_for(assigns: &[Vec<u32>], p: usize, t: &SparseTensor) -> Vec<ModeMetrics> {
        let idx = build_all(t);
        idx.iter()
            .zip(assigns)
            .map(|(i, a)| ModeMetrics::compute(i, &ModePolicy::new(p, a.clone())))
            .collect()
    }

    #[test]
    fn worse_balance_costs_more() {
        let mut rng = Rng::new(5);
        let t = SparseTensor::random(vec![20, 15, 10], 900, &mut rng);
        let p = 3usize;
        // balanced round-robin vs everything-on-rank-0
        let balanced: Vec<Vec<u32>> =
            (0..3).map(|_| (0..t.nnz()).map(|e| (e % p) as u32).collect()).collect();
        let skewed: Vec<Vec<u32>> = (0..3).map(|_| vec![0u32; t.nnz()]).collect();
        let model = CostModel::default();
        let mb = metrics_for(&balanced, p, &t);
        let ms = metrics_for(&skewed, p, &t);
        let ks = [4usize, 4, 4];
        let cb = CostEstimate::from_metrics(&mb.iter().collect::<Vec<_>>(), &ks, &model);
        let cs = CostEstimate::from_metrics(&ms.iter().collect::<Vec<_>>(), &ks, &model);
        // skewed E_max = nnz (3x the balanced one) dominates the TTM term
        assert!(cs.flops_per_sweep > cb.flops_per_sweep);
        // but round-robin scattering shares every slice everywhere:
        // its redundancy (comm units) exceeds the single-rank layout's
        assert!(cb.comm_units_per_sweep > cs.comm_units_per_sweep);
        assert!(cb.secs_per_sweep > 0.0 && cs.secs_per_sweep > 0.0);
    }

    #[test]
    fn shared_csf_discount_drops_reusing_modes_only() {
        let mut rng = Rng::new(7);
        let t = SparseTensor::random(vec![16, 12, 10], 700, &mut rng);
        let p = 3usize;
        let assigns: Vec<Vec<u32>> =
            (0..3).map(|_| (0..t.nnz()).map(|e| (e % p) as u32).collect()).collect();
        let ms = metrics_for(&assigns, p, &t);
        let ks = [4usize, 4, 4];
        let model = CostModel::default();
        let base = CostEstimate::from_metrics(&ms.iter().collect::<Vec<_>>(), &ks, &model);
        let shared = base.with_shared_csf(&ks, &model);
        // modes 0 and 1 pay full freight (mode 0 owns its streams; the
        // first non-leaf mode fills the cache)
        assert_eq!(shared.per_mode[0].ttm_flops, base.per_mode[0].ttm_flops);
        assert_eq!(shared.per_mode[1].ttm_flops, base.per_mode[1].ttm_flops);
        // mode 2 reuses: its accumulation share (K_0/K̂ = 4/16) drops
        let want = base.per_mode[2].ttm_flops * (1.0 - 4.0 / 16.0);
        assert!((shared.per_mode[2].ttm_flops - want).abs() < 1e-6);
        assert!(shared.flops_per_sweep < base.flops_per_sweep);
        assert!(shared.secs_per_sweep < base.secs_per_sweep);
        // comm and SVD are layout-invariant
        assert_eq!(shared.comm_units_per_sweep, base.comm_units_per_sweep);
        assert_eq!(shared.per_mode[2].svd_flops, base.per_mode[2].svd_flops);
    }

    #[test]
    fn estimate_shapes_and_sums() {
        let mut rng = Rng::new(6);
        let t = SparseTensor::random(vec![10, 8, 6, 4], 400, &mut rng);
        let p = 4usize;
        let assigns: Vec<Vec<u32>> = (0..4)
            .map(|_| (0..t.nnz()).map(|_| rng.below(p as u64) as u32).collect())
            .collect();
        let ms = metrics_for(&assigns, p, &t);
        let ks = [3usize, 3, 2, 2];
        let est = CostEstimate::from_metrics(
            &ms.iter().collect::<Vec<_>>(),
            &ks,
            &CostModel::default(),
        );
        assert_eq!(est.per_mode.len(), 4);
        let flops: f64 = est.per_mode.iter().map(|m| m.ttm_flops + m.svd_flops).sum();
        let units: f64 = est.per_mode.iter().map(|m| m.oracle_units + m.fm_units).sum();
        let secs: f64 = est.per_mode.iter().map(|m| m.secs).sum();
        assert!((flops - est.flops_per_sweep).abs() < 1e-6);
        assert!((units - est.comm_units_per_sweep).abs() < 1e-6);
        assert!((secs - est.secs_per_sweep).abs() < 1e-12);
    }
}
