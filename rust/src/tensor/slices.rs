//! Slice structure of a sparse tensor (paper §3): for mode n, Slice_n^l is
//! the set of elements whose n-th coordinate is l. Distribution schemes and
//! the TTM reformulation (Eq. 1) are all slice-driven, so we precompute a
//! CSR-like grouping per mode: element ids bucketed by slice index.

use super::coo::SparseTensor;

/// CSR-like grouping of elements by their mode-n coordinate.
#[derive(Debug, Clone)]
pub struct SliceIndex {
    /// Mode this index is for.
    pub mode: usize,
    /// offsets[l]..offsets[l+1] delimit elems of Slice_n^l; len = L_n + 1.
    pub offsets: Vec<u32>,
    /// Element ids grouped by slice.
    pub elems: Vec<u32>,
}

impl SliceIndex {
    /// Build by counting sort over the mode-n coordinate stream — O(nnz + L_n).
    pub fn build(t: &SparseTensor, mode: usize) -> Self {
        let ln = t.dims[mode] as usize;
        let coords = &t.coords[mode];
        let mut counts = vec![0u32; ln + 1];
        for &c in coords {
            counts[c as usize + 1] += 1;
        }
        for l in 0..ln {
            counts[l + 1] += counts[l];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut elems = vec![0u32; t.nnz()];
        for (e, &c) in coords.iter().enumerate() {
            let slot = cursor[c as usize];
            elems[slot as usize] = e as u32;
            cursor[c as usize] += 1;
        }
        SliceIndex { mode, offsets, elems }
    }

    /// Number of slices (= L_n).
    #[inline]
    pub fn num_slices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Elements of Slice_n^l.
    #[inline]
    pub fn slice(&self, l: usize) -> &[u32] {
        let a = self.offsets[l] as usize;
        let b = self.offsets[l + 1] as usize;
        &self.elems[a..b]
    }

    /// |Slice_n^l|.
    #[inline]
    pub fn slice_len(&self, l: usize) -> usize {
        (self.offsets[l + 1] - self.offsets[l]) as usize
    }

    /// Slice sizes as a vector (input to the schemes' sorting stages).
    pub fn sizes(&self) -> Vec<u32> {
        (0..self.num_slices())
            .map(|l| self.offsets[l + 1] - self.offsets[l])
            .collect()
    }

    /// Largest slice cardinality (drives CoarseG's imbalance, §7.2).
    pub fn max_slice_len(&self) -> usize {
        (0..self.num_slices()).map(|l| self.slice_len(l)).max().unwrap_or(0)
    }

    /// Number of non-empty slices.
    pub fn nonempty(&self) -> usize {
        (0..self.num_slices()).filter(|&l| self.slice_len(l) > 0).count()
    }
}

/// Slice indices for all modes of a tensor.
pub fn build_all(t: &SparseTensor) -> Vec<SliceIndex> {
    (0..t.ndim()).map(|n| SliceIndex::build(t, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fig3_tensor() -> SparseTensor {
        // the paper's Figure 3 example: 8 elements, L_1 = 3,
        // Slice_1^0 = {e1,e3,e6}, Slice_1^1 = {e2,e7}, Slice_1^2 = {e4,e5,e8}
        // (1-based in the paper; 0-based ids/coords here)
        let mut t = SparseTensor::new(vec![3, 4, 4]);
        let mode0 = [0, 1, 0, 2, 2, 0, 1, 2];
        for (i, &c0) in mode0.iter().enumerate() {
            t.push(&[c0, (i % 4) as u32, ((i * 2) % 4) as u32], i as f32 + 1.0);
        }
        t
    }

    #[test]
    fn groups_match_figure3() {
        let t = fig3_tensor();
        let idx = SliceIndex::build(&t, 0);
        assert_eq!(idx.num_slices(), 3);
        assert_eq!(idx.slice(0), &[0, 2, 5]);
        assert_eq!(idx.slice(1), &[1, 6]);
        assert_eq!(idx.slice(2), &[3, 4, 7]);
        assert_eq!(idx.slice_len(0), 3);
        assert_eq!(idx.max_slice_len(), 3);
        assert_eq!(idx.nonempty(), 3);
    }

    #[test]
    fn all_elements_appear_exactly_once() {
        let mut rng = Rng::new(9);
        let t = SparseTensor::random(vec![11, 7, 5], 300, &mut rng);
        for n in 0..3 {
            let idx = SliceIndex::build(&t, n);
            let mut seen = vec![false; t.nnz()];
            for l in 0..idx.num_slices() {
                for &e in idx.slice(l) {
                    assert!(!seen[e as usize]);
                    seen[e as usize] = true;
                    assert_eq!(t.coord(n, e as usize), l as u32);
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn empty_slices_allowed() {
        let mut t = SparseTensor::new(vec![5, 2]);
        t.push(&[4, 0], 1.0);
        let idx = SliceIndex::build(&t, 0);
        assert_eq!(idx.nonempty(), 1);
        assert_eq!(idx.slice_len(0), 0);
        assert_eq!(idx.slice_len(4), 1);
        assert_eq!(idx.sizes(), vec![0, 0, 0, 0, 1]);
    }
}
