//! Sparse tensor in coordinate (COO) format, structure-of-arrays layout.
//!
//! This is the paper's input representation (§3): each non-zero element e
//! has a coordinate vector (l_1..l_N), 0-based here, and a value val(e).
//! SoA keeps per-mode coordinate streams contiguous — the TTM gather walks
//! exactly two (3-D) or three (4-D) of them plus vals.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SparseTensor {
    /// Mode lengths L_1..L_N.
    pub dims: Vec<u32>,
    /// coords[n][e] = n-th coordinate of element e (0-based).
    pub coords: Vec<Vec<u32>>,
    /// vals[e] = val(e).
    pub vals: Vec<f32>,
}

/// Largest storable element count: ids are `u32` across the slice
/// indices, distribution policies and TTM plan streams, so a tensor may
/// hold at most 2³² elements. The paper's 4-billion-element tensors sit
/// right at this boundary — exceeding it must be a hard error, not a
/// silent id wraparound.
pub const MAX_NNZ: u64 = 1 << 32;

impl SparseTensor {
    pub fn new(dims: Vec<u32>) -> Self {
        let n = dims.len();
        SparseTensor { dims, coords: vec![Vec::new(); n], vals: Vec::new() }
    }

    /// Would a tensor of `nnz` elements keep every element id within
    /// `u32`? (`nnz` counts elements; ids run `0..nnz`, so the last id
    /// after one more [`push`](SparseTensor::push) is `nnz` itself.)
    #[inline]
    pub fn ids_fit(nnz: usize) -> bool {
        (nnz as u64) < MAX_NNZ
    }

    pub fn with_capacity(dims: Vec<u32>, cap: usize) -> Self {
        let n = dims.len();
        SparseTensor {
            dims,
            coords: vec![Vec::with_capacity(cap); n],
            vals: Vec::with_capacity(cap),
        }
    }

    /// Number of modes N.
    #[inline]
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Number of non-zero elements.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append one element. Panics (debug) on out-of-range coordinates
    /// and (all builds) when the new element's id would overflow `u32`.
    pub fn push(&mut self, coord: &[u32], val: f32) {
        assert!(
            Self::ids_fit(self.nnz()),
            "SparseTensor: element id would overflow u32 (nnz = {}, max = {MAX_NNZ})",
            self.nnz()
        );
        debug_assert_eq!(coord.len(), self.ndim());
        for (n, &c) in coord.iter().enumerate() {
            debug_assert!(c < self.dims[n], "coord {c} >= L_{n}={}", self.dims[n]);
            self.coords[n].push(c);
        }
        self.vals.push(val);
    }

    /// Coordinate of element e along mode n.
    #[inline]
    pub fn coord(&self, n: usize, e: usize) -> u32 {
        self.coords[n][e]
    }

    /// Total dense size Π L_n as f64 (overflows u64 for the paper's tensors).
    pub fn dense_size(&self) -> f64 {
        self.dims.iter().map(|&d| d as f64).product()
    }

    /// Sparsity = nnz / dense size (Fig 9 column).
    pub fn sparsity(&self) -> f64 {
        self.nnz() as f64 / self.dense_size()
    }

    /// Frobenius norm squared of the tensor (= Σ val²; used for fit).
    pub fn norm_sq(&self) -> f64 {
        self.vals.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// Deduplicate repeated coordinates by summing values (generators and
    /// file readers may produce duplicates). Sorts elements lexicographically.
    pub fn coalesce(&mut self) {
        let nnz = self.nnz();
        let n = self.ndim();
        let mut order: Vec<u32> = (0..nnz as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            for m in 0..n {
                let (ca, cb) = (self.coords[m][a as usize], self.coords[m][b as usize]);
                if ca != cb {
                    return ca.cmp(&cb);
                }
            }
            std::cmp::Ordering::Equal
        });
        let mut out = SparseTensor::with_capacity(self.dims.clone(), nnz);
        let mut coord = vec![0u32; n];
        for &eu in &order {
            let e = eu as usize;
            for m in 0..n {
                coord[m] = self.coords[m][e];
            }
            let same = out.nnz() > 0
                && (0..n).all(|m| out.coords[m][out.nnz() - 1] == coord[m]);
            if same {
                let last = out.vals.len() - 1;
                out.vals[last] += self.vals[e];
            } else {
                out.push(&coord, self.vals[e]);
            }
        }
        *self = out;
    }

    /// Random tensor with i.i.d. uniform coordinates (test helper; the
    /// calibrated generators live in tensor::synth).
    pub fn random(dims: Vec<u32>, nnz: usize, rng: &mut Rng) -> Self {
        let mut t = SparseTensor::with_capacity(dims.clone(), nnz);
        let n = dims.len();
        let mut coord = vec![0u32; n];
        for _ in 0..nnz {
            for m in 0..n {
                coord[m] = rng.below(dims[m] as u64) as u32;
            }
            t.push(&coord, rng.f32() * 2.0 - 1.0);
        }
        t
    }

    /// Memory footprint of one stored copy in bytes (u32 per mode + f32).
    pub fn bytes_per_element(&self) -> usize {
        self.ndim() * 4 + 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read_back() {
        let mut t = SparseTensor::new(vec![3, 4, 5]);
        t.push(&[0, 1, 2], 1.5);
        t.push(&[2, 3, 4], -2.0);
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.coord(0, 1), 2);
        assert_eq!(t.coord(2, 0), 2);
        assert_eq!(t.ndim(), 3);
    }

    #[test]
    fn sparsity_and_norm() {
        let mut t = SparseTensor::new(vec![10, 10]);
        t.push(&[0, 0], 3.0);
        t.push(&[1, 1], 4.0);
        assert!((t.sparsity() - 0.02).abs() < 1e-12);
        assert!((t.norm_sq() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn coalesce_merges_duplicates() {
        let mut t = SparseTensor::new(vec![4, 4]);
        t.push(&[1, 2], 1.0);
        t.push(&[0, 0], 5.0);
        t.push(&[1, 2], 2.5);
        t.coalesce();
        assert_eq!(t.nnz(), 2);
        // sorted lexicographically: (0,0) then (1,2)
        assert_eq!(t.coord(0, 0), 0);
        assert!((t.vals[1] - 3.5).abs() < 1e-6);
    }

    #[test]
    fn random_respects_dims() {
        let mut rng = Rng::new(1);
        let t = SparseTensor::random(vec![7, 3, 9], 500, &mut rng);
        assert_eq!(t.nnz(), 500);
        for n in 0..3 {
            assert!(t.coords[n].iter().all(|&c| c < t.dims[n]));
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn out_of_range_coord_panics_in_debug() {
        let mut t = SparseTensor::new(vec![2, 2]);
        t.push(&[2, 0], 1.0);
    }

    #[test]
    fn id_capacity_boundary() {
        // ids run 0..nnz: nnz = 2³² means the last id is u32::MAX — ok;
        // one more would wrap. (Checked arithmetically — 2³² elements
        // cannot be allocated in a test.)
        assert!(SparseTensor::ids_fit(0));
        assert!(SparseTensor::ids_fit(u32::MAX as usize));
        assert!(!SparseTensor::ids_fit((MAX_NNZ) as usize));
        assert!(!SparseTensor::ids_fit((MAX_NNZ + 1) as usize));
    }
}
