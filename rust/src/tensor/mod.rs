//! Sparse tensor substrate: COO storage, slice indexing, streaming
//! deltas, FROSTT I/O and the calibrated synthetic benchmark datasets
//! (Fig 9 analogues).

pub mod coo;
pub mod datasets;
pub mod delta;
pub mod io;
pub mod slices;
pub mod synth;

pub use coo::SparseTensor;
pub use delta::{AppliedDelta, DeltaError, TensorDelta};
pub use slices::SliceIndex;
