//! Sparse tensor substrate: COO storage, slice indexing, FROSTT I/O and the
//! calibrated synthetic benchmark datasets (Fig 9 analogues).

pub mod coo;
pub mod datasets;
pub mod io;
pub mod slices;
pub mod synth;

pub use coo::SparseTensor;
pub use slices::SliceIndex;
