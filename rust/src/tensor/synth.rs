//! Synthetic sparse-tensor generators calibrated to the paper's workloads.
//!
//! The FROSTT tensors (Fig 9) are 50M–4.6B-element downloads we cannot
//! fetch on this testbed; the schemes' relative behaviour, however, is
//! driven by the *slice-size distribution* per mode (huge head slices ruin
//! CoarseG's TTM balance; low skew keeps everything easy) and by the
//! nnz/L_n ratios. Each mode's coordinates are therefore drawn from a Zipf
//! law with a per-mode exponent; exponent 0 means uniform.
//!
//! Duplicated coordinates are allowed and treated additively by HOOI —
//! consistent with Eq. 1, which sums contributions per slice regardless.

use super::coo::SparseTensor;
use crate::util::rng::{Rng, Zipf};

/// Per-mode coordinate distribution.
#[derive(Debug, Clone)]
pub struct ModeDist {
    pub len: u32,
    /// Zipf exponent; 0.0 = uniform.
    pub zipf: f64,
}

/// Generate a tensor with independent per-mode marginals.
pub fn generate(modes: &[ModeDist], nnz: usize, seed: u64) -> SparseTensor {
    let dims: Vec<u32> = modes.iter().map(|m| m.len).collect();
    let mut t = SparseTensor::with_capacity(dims.clone(), nnz);
    let mut rng = Rng::new(seed);
    // Pre-build samplers and per-mode index relabelings. The relabeling
    // scatters the Zipf head across the index space so "slice 0 is always
    // huge" artifacts don't align across modes.
    let samplers: Vec<Option<Zipf>> = modes
        .iter()
        .map(|m| (m.zipf > 0.0).then(|| Zipf::new(m.len as u64, m.zipf)))
        .collect();
    let relabel: Vec<Vec<u32>> = modes
        .iter()
        .map(|m| {
            let mut r = rng.fork(m.len as u64);
            r.permutation(m.len as usize)
        })
        .collect();
    let mut coord = vec![0u32; modes.len()];
    for _ in 0..nnz {
        for (n, m) in modes.iter().enumerate() {
            let raw = match &samplers[n] {
                Some(z) => (z.sample(&mut rng) - 1) as u32,
                None => rng.below(m.len as u64) as u32,
            };
            coord[n] = relabel[n][raw as usize];
        }
        t.push(&coord, rng.normal() as f32);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::slices::SliceIndex;

    #[test]
    fn respects_dims_and_nnz() {
        let modes = vec![
            ModeDist { len: 50, zipf: 1.1 },
            ModeDist { len: 80, zipf: 0.0 },
            ModeDist { len: 30, zipf: 0.8 },
        ];
        let t = generate(&modes, 5000, 7);
        assert_eq!(t.nnz(), 5000);
        assert_eq!(t.dims, vec![50, 80, 30]);
        for n in 0..3 {
            assert!(t.coords[n].iter().all(|&c| c < t.dims[n]));
        }
    }

    #[test]
    fn zipf_mode_has_skew_uniform_does_not() {
        let modes = vec![
            ModeDist { len: 200, zipf: 1.2 },
            ModeDist { len: 200, zipf: 0.0 },
        ];
        let t = generate(&modes, 40_000, 11);
        let skewed = SliceIndex::build(&t, 0);
        let flat = SliceIndex::build(&t, 1);
        let avg = 40_000.0 / 200.0;
        let max_skewed = skewed.max_slice_len() as f64;
        let max_flat = flat.max_slice_len() as f64;
        // skewed mode: head slice far above average; uniform: close to it
        assert!(max_skewed / avg > 10.0, "skew ratio {}", max_skewed / avg);
        assert!(max_flat / avg < 3.0, "flat ratio {}", max_flat / avg);
    }

    #[test]
    fn deterministic_per_seed() {
        let modes = vec![ModeDist { len: 20, zipf: 0.9 }; 3];
        let a = generate(&modes, 1000, 42);
        let b = generate(&modes, 1000, 42);
        assert_eq!(a.coords, b.coords);
        let c = generate(&modes, 1000, 43);
        assert_ne!(a.coords, c.coords);
    }
}
