//! The eight benchmark tensors of Fig 9 as calibrated synthetic analogues.
//!
//! Scaling (DESIGN.md §2): medium tensors are scaled ~1/400 in nnz, big
//! tensors ~1/1000, with mode lengths scaled to preserve the average slice
//! size nnz/L_n wherever the dense size permits (patents and nell2 are
//! near-dense; their dims shrink less so nnz ≤ dense size holds). Per-mode
//! Zipf exponents reproduce the qualitative skew the paper reports: enron's
//! giant slices (5M elements vs a 105K average at 512 ranks, §7.2), the
//! very large slices of the big tensors, and the milder skew of nell2.

use super::coo::SparseTensor;
use super::synth::{generate, ModeDist};
use crate::util::table::{fmt_si, Table};

#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub dims: Vec<u32>,
    pub nnz: usize,
    pub zipf: Vec<f64>,
    pub seed: u64,
    pub big: bool,
    /// Paper's figures for the table (Fig 9 parity check).
    pub paper_nnz: f64,
}

impl DatasetSpec {
    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    pub fn generate(&self) -> SparseTensor {
        let modes: Vec<ModeDist> = self
            .dims
            .iter()
            .zip(&self.zipf)
            .map(|(&len, &zipf)| ModeDist { len, zipf })
            .collect();
        generate(&modes, self.nnz, self.seed)
    }

    /// Scale the spec (dims and nnz) by `f` — used by quick tests and the
    /// smoke configurations so they stay O(seconds).
    pub fn scaled(&self, f: f64) -> DatasetSpec {
        let mut s = self.clone();
        s.dims = s
            .dims
            .iter()
            .map(|&d| ((d as f64 * f).round() as u32).max(4))
            .collect();
        s.nnz = ((s.nnz as f64 * f).round() as usize).max(64);
        s
    }
}

/// All eight analogues, in the paper's order (Fig 9).
pub fn all() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "delicious",
            dims: vec![1330, 43_000, 6000, 16],
            nnz: 350_000,
            zipf: vec![0.8, 1.0, 0.9, 0.6],
            seed: 0xD311,
            big: false,
            paper_nnz: 140e6,
        },
        DatasetSpec {
            name: "enron",
            dims: vec![64, 48, 2440, 16],
            nnz: 135_000,
            zipf: vec![1.6, 1.1, 0.9, 0.7],
            seed: 0xE4701,
            big: false,
            paper_nnz: 54e6,
        },
        DatasetSpec {
            name: "flickr",
            dims: vec![800, 70_000, 4000, 12],
            nnz: 280_000,
            zipf: vec![1.0, 1.1, 0.9, 0.5],
            seed: 0xF11C4,
            big: false,
            paper_nnz: 112e6,
        },
        DatasetSpec {
            name: "nell1",
            dims: vec![7250, 5250, 63_500],
            nnz: 357_000,
            zipf: vec![1.0, 1.0, 1.1],
            seed: 0x4E111,
            big: false,
            paper_nnz: 143e6,
        },
        DatasetSpec {
            name: "nell2",
            dims: vec![300, 225, 700],
            nnz: 192_000,
            zipf: vec![0.9, 0.9, 0.9],
            seed: 0x4E112,
            big: false,
            paper_nnz: 77e6,
        },
        DatasetSpec {
            name: "amazon",
            dims: vec![4800, 1700, 1800],
            nnz: 1_700_000,
            zipf: vec![1.1, 1.0, 1.0],
            seed: 0xA307,
            big: true,
            paper_nnz: 1.7e9,
        },
        DatasetSpec {
            name: "patents",
            dims: vec![46, 2390, 2390],
            nnz: 3_500_000,
            zipf: vec![0.5, 0.9, 0.5],
            seed: 0x9A7E,
            big: true,
            paper_nnz: 3.5e9,
        },
        DatasetSpec {
            name: "reddit",
            dims: vec![8200, 176, 8100],
            nnz: 4_600_000,
            zipf: vec![1.2, 0.9, 1.2],
            seed: 0x4EDD17,
            big: true,
            paper_nnz: 4.6e9,
        },
    ]
}

pub fn medium() -> Vec<DatasetSpec> {
    all().into_iter().filter(|d| !d.big).collect()
}

pub fn big() -> Vec<DatasetSpec> {
    all().into_iter().filter(|d| d.big).collect()
}

pub fn by_name(name: &str) -> Option<DatasetSpec> {
    all().into_iter().find(|d| d.name == name)
}

/// Fig 9: the dataset table (synthetic analogue columns + paper nnz).
pub fn fig9_table() -> Table {
    let mut t = Table::new(
        "Fig 9 — tensor datasets (synthetic analogues)",
        &["tensor", "L1", "L2", "L3", "L4", "nnz", "sparsity", "paper nnz"],
    );
    for d in all() {
        let dense: f64 = d.dims.iter().map(|&x| x as f64).product();
        let l = |i: usize| {
            d.dims
                .get(i)
                .map(|&x| fmt_si(x as f64))
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![
            d.name.to_string(),
            l(0),
            l(1),
            l(2),
            l(3),
            fmt_si(d.nnz as f64),
            format!("{:.1e}", d.nnz as f64 / dense),
            fmt_si(d.paper_nnz),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::slices::SliceIndex;

    #[test]
    fn eight_datasets_in_paper_order() {
        let ds = all();
        assert_eq!(ds.len(), 8);
        assert_eq!(ds[0].name, "delicious");
        assert_eq!(ds[7].name, "reddit");
        assert_eq!(medium().len(), 5);
        assert_eq!(big().len(), 3);
    }

    #[test]
    fn dims_match_paper_arity() {
        for d in all() {
            match d.name {
                "delicious" | "enron" | "flickr" => assert_eq!(d.ndim(), 4),
                _ => assert_eq!(d.ndim(), 3),
            }
            assert_eq!(d.zipf.len(), d.ndim());
        }
    }

    #[test]
    fn nnz_fits_dense_size() {
        for d in all() {
            let dense: f64 = d.dims.iter().map(|&x| x as f64).product();
            assert!(
                (d.nnz as f64) < dense,
                "{}: nnz {} >= dense {}",
                d.name,
                d.nnz,
                dense
            );
        }
    }

    #[test]
    fn enron_has_giant_slices() {
        // the paper's imbalance example (§7.2): enron's biggest slice is
        // orders of magnitude above the average.
        let d = by_name("enron").unwrap();
        let t = d.generate();
        let idx = SliceIndex::build(&t, 0);
        let avg = t.nnz() as f64 / t.dims[0] as f64;
        assert!(
            idx.max_slice_len() as f64 / avg > 10.0,
            "max/avg = {}",
            idx.max_slice_len() as f64 / avg
        );
    }

    #[test]
    fn fig9_renders_all_rows() {
        let t = fig9_table();
        let r = t.render();
        for d in all() {
            assert!(r.contains(d.name));
        }
    }

    #[test]
    fn scaled_floor() {
        let d = by_name("patents").unwrap().scaled(0.001);
        assert!(d.dims.iter().all(|&x| x >= 4));
        assert!(d.nnz >= 64);
    }
}
