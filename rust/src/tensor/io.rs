//! FROSTT `.tns` tensor I/O (http://frostt.io — the paper's benchmark
//! repository). Format: whitespace-separated lines of N 1-based integer
//! coordinates followed by a value; `#` comments.
//!
//! The synthetic analogues (tensor::synth) are the default workload on this
//! testbed, but any real FROSTT download drops in through this reader.
//!
//! [`load_tns`] is the typed entry point: it distinguishes OS-level
//! failures ([`TensorIoError::Io`]) from malformed content
//! ([`TensorIoError::Parse`], with the 1-based line number), so callers
//! like `Workload::resolve` can report "file missing" and "file broken"
//! differently. [`read_tns`] survives as a `std::io::Result` shim for
//! pre-typed callers (parse errors degrade to `InvalidData`).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::coo::SparseTensor;

/// Why a `.tns` file could not be loaded.
#[derive(Debug)]
pub enum TensorIoError {
    /// The OS could not produce the bytes (missing file, permissions,
    /// a read that failed mid-stream).
    Io(std::io::Error),
    /// The bytes arrived but are not a FROSTT tensor; `line` is 1-based.
    Parse { line: usize, msg: String },
}

impl TensorIoError {
    /// Degrade to a `std::io::Error` (parse errors become `InvalidData`
    /// with the line number in the message) — the [`read_tns`] shim.
    pub fn into_io(self) -> std::io::Error {
        match self {
            TensorIoError::Io(e) => e,
            TensorIoError::Parse { line, msg } => std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {line}: {msg}"),
            ),
        }
    }
}

impl std::fmt::Display for TensorIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TensorIoError::Io(e) => write!(f, "{e}"),
            TensorIoError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for TensorIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TensorIoError::Io(e) => Some(e),
            TensorIoError::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for TensorIoError {
    fn from(e: std::io::Error) -> Self {
        TensorIoError::Io(e)
    }
}

/// Read a `.tns` file with typed errors. `ndim` is inferred from the
/// first data line; mode lengths from the coordinate maxima.
pub fn load_tns(path: &Path) -> Result<SparseTensor, TensorIoError> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::with_capacity(1 << 20, f);
    let mut coords: Vec<Vec<u32>> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut dims: Vec<u32> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let body = line.split('#').next().unwrap_or("").trim();
        if body.is_empty() {
            continue;
        }
        let mut parts = body.split_whitespace();
        let fields: Vec<&str> = parts.by_ref().collect();
        if fields.len() < 2 {
            return Err(bad(lineno, "need at least 1 coordinate and a value"));
        }
        let n = fields.len() - 1;
        if coords.is_empty() {
            coords = vec![Vec::new(); n];
            dims = vec![0; n];
        } else if coords.len() != n {
            return Err(bad(lineno, "inconsistent arity"));
        }
        for (m, fld) in fields[..n].iter().enumerate() {
            let c1: u64 = fld.parse().map_err(|_| bad(lineno, "bad coordinate"))?;
            if c1 == 0 {
                return Err(bad(lineno, "coordinates are 1-based"));
            }
            let c0 = (c1 - 1) as u32;
            coords[m].push(c0);
            if c0 + 1 > dims[m] {
                dims[m] = c0 + 1;
            }
        }
        let v: f32 = fields[n].parse().map_err(|_| bad(lineno, "bad value"))?;
        vals.push(v);
    }
    if coords.is_empty() {
        return Err(TensorIoError::Parse {
            line: 1,
            msg: "empty tensor file".into(),
        });
    }
    Ok(SparseTensor { dims, coords, vals })
}

/// [`load_tns`] degraded to `std::io::Result` — compatibility shim for
/// callers that predate [`TensorIoError`].
pub fn read_tns(path: &Path) -> std::io::Result<SparseTensor> {
    load_tns(path).map_err(TensorIoError::into_io)
}

/// Write a `.tns` file (1-based coordinates, one element per line).
pub fn write_tns(t: &SparseTensor, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::with_capacity(1 << 20, f);
    for e in 0..t.nnz() {
        for n in 0..t.ndim() {
            write!(w, "{} ", t.coord(n, e) + 1)?;
        }
        writeln!(w, "{}", t.vals[e])?;
    }
    w.flush()
}

fn bad(lineno: usize, msg: &str) -> TensorIoError {
    TensorIoError::Parse { line: lineno + 1, msg: msg.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(21);
        let t = SparseTensor::random(vec![20, 10, 30], 200, &mut rng);
        let dir = std::env::temp_dir().join("tucker_lite_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tns");
        write_tns(&t, &path).unwrap();
        let back = read_tns(&path).unwrap();
        assert_eq!(back.nnz(), t.nnz());
        for n in 0..3 {
            assert_eq!(back.coords[n], t.coords[n]);
            // dims inferred from maxima, so <= original
            assert!(back.dims[n] <= t.dims[n]);
        }
        for (a, b) in back.vals.iter().zip(&t.vals) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn parses_comments_and_blanks() {
        let text = "# header\n\n1 2 3 1.5\n2 1 1 -2 # inline\n";
        let dir = std::env::temp_dir().join("tucker_lite_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.tns");
        std::fs::write(&path, text).unwrap();
        let t = read_tns(&path).unwrap();
        assert_eq!(t.nnz(), 2);
        assert_eq!(t.dims, vec![2, 2, 3]);
        assert_eq!(t.coord(0, 0), 0); // 1-based -> 0-based
    }

    #[test]
    fn rejects_zero_based_coords() {
        let dir = std::env::temp_dir().join("tucker_lite_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("z.tns");
        std::fs::write(&path, "0 1 1 3.0\n").unwrap();
        assert!(read_tns(&path).is_err());
    }

    #[test]
    fn rejects_inconsistent_arity() {
        let dir = std::env::temp_dir().join("tucker_lite_io_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.tns");
        std::fs::write(&path, "1 1 1 3.0\n1 1 2.0\n").unwrap();
        assert!(read_tns(&path).is_err());
    }

    #[test]
    fn typed_errors_distinguish_missing_from_malformed() {
        let dir = std::env::temp_dir().join("tucker_lite_io_test5");
        std::fs::create_dir_all(&dir).unwrap();
        // missing file → Io
        match load_tns(&dir.join("absent.tns")) {
            Err(TensorIoError::Io(e)) => {
                assert_eq!(e.kind(), std::io::ErrorKind::NotFound)
            }
            other => panic!("expected Io, got {other:?}"),
        }
        // malformed line → Parse with the 1-based line number
        let path = dir.join("bad.tns");
        std::fs::write(&path, "1 1 1 2.0\n1 1 1 notafloat\n").unwrap();
        match load_tns(&path) {
            Err(TensorIoError::Parse { line, msg }) => {
                assert_eq!(line, 2);
                assert_eq!(msg, "bad value");
            }
            other => panic!("expected Parse, got {other:?}"),
        }
        // the shim degrades Parse to InvalidData, keeping the line
        let e = read_tns(&path).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        assert!(e.to_string().contains("line 2"), "{e}");
    }
}
